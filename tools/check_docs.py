"""Docs consistency checker (the CI docs lane runs this).

Checks, exiting non-zero with a findings list on any failure:

  1. Markdown links in README.md / DESIGN.md / docs/BENCHMARKS.md that
     point at local files resolve (and their #anchors, if any, match a
     heading's GitHub slug in the target file).
  2. Every `DESIGN.md §X` / `DESIGN §X` citation — in README.md,
     DESIGN.md, and every .py docstring/comment under src/, examples/,
     benchmarks/, tests/ — names a section heading that actually exists
     in DESIGN.md.
  3. Bare `§X` references inside DESIGN.md itself (which refer to its
     own sections) resolve too; references prefixed with "paper" (the
     source paper's numbering) are exempt.

Usage:  python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = [ROOT / "README.md", ROOT / "DESIGN.md",
        ROOT / "docs" / "BENCHMARKS.md"]
PY_DIRS = ["src", "examples", "benchmarks", "tests", "tools"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# meta-references to "a section number", not to a concrete section
PLACEHOLDER_TOKENS = {"N", "X"}
DESIGN_REF_RE = re.compile(r"DESIGN(?:\.md)?\s+§([0-9][0-9.]*|[A-Za-z][\w-]*)")
BARE_REF_RE = re.compile(r"§([0-9][0-9.]*|[A-Za-z][\w-]*)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.M)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, strip punctuation, spaces -> dashes."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def design_sections(design_text: str) -> set[str]:
    """§-tokens defined by DESIGN.md headings, with numeric prefixes.

    '## §2 Batched SPMD…' defines '2'; '### §2.1 …' defines '2.1';
    '## §Paper-fidelity deviations' defines 'Paper-fidelity'.
    """
    tokens = set()
    for _, title in HEADING_RE.findall(design_text):
        m = re.match(r"§([0-9][0-9.]*|[A-Za-z][\w-]*)", title.strip())
        if m:
            tokens.add(m.group(1).rstrip("."))
    return tokens


def check() -> list[str]:
    errors: list[str] = []
    design_text = (ROOT / "DESIGN.md").read_text()
    sections = design_sections(design_text)
    if not sections:
        return ["DESIGN.md defines no §-sections at all?"]

    # 1. markdown links
    for doc in DOCS:
        text = doc.read_text()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            tgt = (doc.parent / path_part) if path_part else doc
            if not tgt.exists():
                errors.append(f"{doc.name}: broken link -> {target}")
                continue
            if anchor and tgt.suffix == ".md":
                slugs = {github_slug(t) for _, t in
                         HEADING_RE.findall(tgt.read_text())}
                if anchor not in slugs:
                    errors.append(
                        f"{doc.name}: anchor #{anchor} not found in "
                        f"{tgt.name}")

    # 2. DESIGN.md §X citations across docs and code
    files = list(DOCS)
    for d in PY_DIRS:
        files += sorted((ROOT / d).rglob("*.py"))
    for f in files:
        text = f.read_text()
        for tok in DESIGN_REF_RE.findall(text):
            if tok.rstrip(".") in PLACEHOLDER_TOKENS:
                continue
            if tok.rstrip(".") not in sections:
                errors.append(
                    f"{f.relative_to(ROOT)}: cites DESIGN.md §{tok}, "
                    f"which is not a DESIGN.md section "
                    f"(have: {sorted(sections)})")

    # 3. bare §X self-references inside DESIGN.md ("paper §X" exempt)
    for m in BARE_REF_RE.finditer(design_text):
        prefix = design_text[max(0, m.start() - 24):m.start()].lower()
        if "paper" in prefix.split("\n")[-1]:
            continue
        tok = m.group(1).rstrip(".")
        if tok in PLACEHOLDER_TOKENS:
            continue
        if tok not in sections:
            line = design_text.count("\n", 0, m.start()) + 1
            errors.append(
                f"DESIGN.md:{line}: §{m.group(1)} does not resolve to a "
                f"DESIGN.md section (have: {sorted(sections)})")
    return errors


def main() -> int:
    errors = check()
    if errors:
        print(f"check_docs: {len(errors)} problem(s):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print("check_docs: all links and §-references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
