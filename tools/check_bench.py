"""Perf-regression gate: compare fresh BENCH_*.json against committed baselines.

The CI `bench-guard` job runs `benchmarks.run --quick` and then this
checker, which compares the fresh `results/BENCH_<name>.json` files against
the committed quick-mode baselines (`results/baselines/quick/`) with
per-metric tolerance bands. Only *machine-portable* metrics are gated —
recall, hop counts, Eq. 1 evaluation counts, and same-machine time ratios
(bulk-vs-incremental build speedup, mixed-vs-grouped serving speedup) —
never absolute wall-clock, which CI runners cannot reproduce.

A metric regresses when it leaves its band:

    higher-is-better:  fresh < base * (1 - rel_tol) - abs_slack
    lower-is-better:   fresh > base * (1 + rel_tol) + abs_slack

The default band is the 20% regression budget; recall metrics carry a
tighter 2 pt absolute band (20% of a 0.95 recall would be absurdly lax),
and cold-ratio metrics a wider one (jit-compile noise). Boolean metrics
(bitwise_equal) must never flip to False. Rows are matched on identifying
key fields; a baseline row with no fresh counterpart fails (the gate must
notice dropped coverage), a fresh row with no baseline is reported and
skipped (new coverage).

Usage:
  python tools/check_bench.py --baseline results/baselines/quick --fresh results
  python tools/check_bench.py --selftest   # prove the gate trips on a
                                           # synthetic 25% regression
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# direction: "higher" / "lower" / "bool-true"
# band: (rel_tol, abs_slack)
_RECALL_BAND = (0.0, 0.02)     # 2 pt absolute
_RATIO_BAND = (0.20, 0.0)      # the 20% regression budget
_COLD_BAND = (0.40, 0.0)       # cold ratios include jit compiles: noisy
_LAT_BAND = (0.25, 0.05)       # same-machine latency ratios (p50)
_LAT95_BAND = (0.35, 0.10)     # tail latency: noisier than the median

SPECS = {
    "build": {
        "keys": ("dataset", "n", "p"),
        "metrics": {
            "recall_bulk": ("higher", _RECALL_BAND),
            "recall_incremental": ("higher", _RECALL_BAND),
            "speedup_steady": ("higher", _RATIO_BAND),
            "speedup_cold": ("higher", _COLD_BAND),
        },
    },
    "beam": {
        "keys": ("dataset", "p", "k", "expand_width"),
        "metrics": {
            "recall": ("higher", _RECALL_BAND),
            "mean_hops": ("lower", _RATIO_BAND),
            "mean_n_b": ("lower", _RATIO_BAND),
            "hops_speedup_vs_w1": ("higher", _RATIO_BAND),
        },
    },
    # serving gates both throughput (engine-vs-grouped speedups) and the
    # paced open-loop latency comparison against the v1 scheduler: the
    # p50/p95 ratios are same-machine, same-stream ratios (engine /
    # v1 — lower is better, < 1 means the engine is faster), so they are
    # machine-portable where absolute milliseconds are not.
    "serving": {
        "keys": ("dataset", "distinct_p", "k"),
        "metrics": {
            "recall_mixed": ("higher", _RECALL_BAND),
            "speedup_warm": ("higher", (0.25, 0.0)),
            "speedup_cold": ("higher", _COLD_BAND),
            "bitwise_equal": ("bool-true", None),
            "p50_vs_v1": ("lower", _LAT_BAND),
            "p95_vs_v1": ("lower", _LAT95_BAND),
        },
    },
    # cross-segment threshold propagation (DESIGN.md §3): N_b per policy is
    # the tentpole metric, plus two *absolute* flagship acceptances — the
    # 4-segment two_phase policy must stay within 2x the monolithic N_b at
    # <= 0.5 pt recall cost, and the conservative two_phase_safe variant
    # must return ids identical to the exhaustive independent policy.
    # Absolute checks run on the fresh rows (not baseline-relative), so a
    # regenerated baseline can never quietly loosen them.
    "sharded": {
        "keys": ("dataset", "index", "policy", "segments", "p"),
        "metrics": {
            "recall": ("higher", _RECALL_BAND),
            "N_b": ("lower", _RATIO_BAND),
            "nb_ratio_vs_mono": ("lower", _RATIO_BAND),
            "ids_match_independent": ("bool-true", None),
            "self_nn_ok": ("bool-true", None),
        },
        "absolute": [
            {"match": {"policy": "two_phase", "p": 1.25},
             "metric": "nb_ratio_vs_mono", "op": "max", "limit": 2.0},
            {"match": {"policy": "two_phase", "p": 1.25},
             "metric": "recall_delta_vs_mono", "op": "min", "limit": -0.005},
            {"match": {"policy": "two_phase_safe", "p": 2.0},
             "metric": "ids_match_independent", "op": "true"},
        ],
    },
    # early-abandoning verification (DESIGN.md §8): the scanned-dimension
    # fraction is the tentpole metric — lower is better, and a fresh run
    # scanning >20%+2pt more than the committed baseline means the
    # abandonment machinery regressed. ids_equal flipping means the
    # exactness guarantee broke: hard fail.
    "verify": {
        "keys": ("dataset", "d", "p"),
        "metrics": {
            "n_dim_frac": ("lower", (0.20, 0.02)),
            "recall_abandon": ("higher", _RECALL_BAND),
            "recall_full": ("higher", _RECALL_BAND),
            "ids_equal": ("bool-true", None),
        },
    },
    # compressed-band two-band verification (DESIGN.md §10): screen_out is
    # the tentpole metric (fraction of f32 row gathers the certified int8
    # screen avoided — higher is better), bytes_ratio the honest total-
    # bandwidth cost (band reads + surviving f32 reads, relative to the
    # uncompressed path — lower is better). ids_equal flipping means the
    # lower bound stopped being admissible: hard fail. The absolute checks
    # pin the ISSUE 9 flagship acceptance — >= 2x f32-byte reduction at
    # p in {0.5, 0.8} — so a regenerated baseline can never loosen it.
    "compressed": {
        "keys": ("dataset", "d", "p"),
        "metrics": {
            "screen_out": ("higher", (0.20, 0.02)),
            "bytes_ratio": ("lower", (0.20, 0.02)),
            "n_dim_frac": ("lower", (0.20, 0.02)),
            "ids_equal": ("bool-true", None),
        },
        "absolute": [
            {"match": {"p": 0.5}, "metric": "f32_bytes_reduction",
             "op": "min", "limit": 2.0},
            {"match": {"p": 0.8}, "metric": "f32_bytes_reduction",
             "op": "min", "limit": 2.0},
            {"match": {"p": 2.0}, "metric": "ids_equal", "op": "true"},
        ],
    },
    # degraded serving under injected segment faults (DESIGN.md §11): the
    # faulted stream must sustain near-full coverage (quarantine + snapshot
    # recovery keep segments out only briefly) at >= 0.8x the clean-stream
    # throughput, and a poisoned segment's ids must NEVER surface while it
    # is poisoned. The absolute checks pin the ISSUE 10 flagship acceptance
    # so a regenerated baseline can never quietly loosen them.
    "health": {
        "keys": ("dataset", "segments", "fault_rate"),
        "metrics": {
            "coverage_mean": ("higher", (0.0, 0.02)),
            "throughput_ratio": ("higher", _RATIO_BAND),
            "p50_ratio": ("lower", _LAT_BAND),
            "no_poisoned_ids": ("bool-true", None),
            "recovered_all_segments": ("bool-true", None),
        },
        "absolute": [
            {"match": {"fault_rate": 0.05},
             "metric": "coverage_mean", "op": "min", "limit": 0.95},
            {"match": {"fault_rate": 0.05},
             "metric": "throughput_ratio", "op": "min", "limit": 0.8},
            {"match": {"fault_rate": 0.05},
             "metric": "no_poisoned_ids", "op": "true"},
        ],
    },
}


def _load(path: Path) -> dict | None:
    """Load one BENCH payload. None = file missing; a dict with the
    "__malformed__" key = file exists but is not a usable payload (the
    caller turns that into an actionable failure, never a traceback)."""
    if not path.exists():
        return None
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        return {"__malformed__": str(e)}
    if not isinstance(payload, dict):
        return {"__malformed__": f"top-level JSON is a "
                                 f"{type(payload).__name__}, expected an "
                                 f"object with status/quick/rows"}
    return payload


def _regen_hint(name: str) -> str:
    return (f"regenerate it with `PYTHONPATH=src python -m benchmarks.run "
            f"--quick --only {name}`")


def _row_key(row: dict, keys: tuple[str, ...]) -> tuple:
    return tuple(str(row.get(k)) for k in keys)


def _check_metric(name, direction, band, base, fresh) -> str | None:
    """Returns a problem description, or None if within the band."""
    if direction == "bool-true":
        if bool(base) and not bool(fresh):
            return f"{name}: flipped {base} -> {fresh}"
        return None
    try:
        base_v, fresh_v = float(base), float(fresh)
    except (TypeError, ValueError):
        return f"{name}: non-numeric ({base!r} -> {fresh!r})"
    rel, slack = band
    if direction == "higher":
        floor = base_v * (1.0 - rel) - slack
        if fresh_v < floor:
            return (f"{name}: {fresh_v:g} < allowed {floor:g} "
                    f"(baseline {base_v:g}, band -{rel:.0%}/-{slack:g})")
    else:
        ceil = base_v * (1.0 + rel) + slack
        if fresh_v > ceil:
            return (f"{name}: {fresh_v:g} > allowed {ceil:g} "
                    f"(baseline {base_v:g}, band +{rel:.0%}/+{slack:g})")
    return None


def _check_absolute(name: str, spec: dict, fresh_rows: list[dict]) -> list:
    """Flagship acceptance gates: fixed limits on fresh rows, independent
    of whatever the committed baseline says. A check whose match pattern
    selects no fresh row is itself a failure — dropping the flagship row
    must not silently disarm its gate."""
    problems = []
    for chk in spec.get("absolute", []):
        matched = [r for r in fresh_rows
                   if all(r.get(k) == v for k, v in chk["match"].items())]
        if not matched:
            problems.append(f"{name}: no fresh row matches absolute check "
                            f"{chk['match']} (flagship coverage dropped)")
            continue
        for row in matched:
            val = row.get(chk["metric"])
            if chk["op"] == "true":
                if not bool(val):
                    problems.append(f"{name} {chk['match']}: "
                                    f"{chk['metric']} is {val!r}, must be "
                                    f"True (absolute)")
                continue
            try:
                v = float(val)
            except (TypeError, ValueError):
                problems.append(f"{name} {chk['match']}: {chk['metric']} "
                                f"non-numeric ({val!r})")
                continue
            lim = chk["limit"]
            if chk["op"] == "max" and v > lim:
                problems.append(f"{name} {chk['match']}: {chk['metric']} "
                                f"{v:g} > absolute limit {lim:g}")
            elif chk["op"] == "min" and v < lim:
                problems.append(f"{name} {chk['match']}: {chk['metric']} "
                                f"{v:g} < absolute limit {lim:g}")
    return problems


def compare_bench(name: str, baseline: dict, fresh: dict) -> tuple[list, list]:
    """Compare one bench's payloads. Returns (problems, notes)."""
    spec = SPECS[name]
    problems, notes = [], []
    if fresh.get("status") != "ok":
        return [f"{name}: fresh run status={fresh.get('status')!r} "
                f"({fresh.get('error', 'no error recorded')})"], notes
    if baseline.get("status") != "ok":
        return problems, [f"{name}: baseline status!=ok, skipped"]
    if bool(baseline.get("quick")) != bool(fresh.get("quick")):
        return problems, [
            f"{name}: quick-mode mismatch (baseline quick="
            f"{baseline.get('quick')}, fresh quick={fresh.get('quick')}) — "
            f"rows are not comparable, skipped"]
    fresh_rows = {_row_key(r, spec["keys"]): r for r in fresh.get("rows", [])}
    for brow in baseline.get("rows", []):
        key = _row_key(brow, spec["keys"])
        frow = fresh_rows.pop(key, None)
        if frow is None:
            problems.append(f"{name} {key}: row missing from fresh results "
                            f"(coverage dropped)")
            continue
        for metric, (direction, band) in spec["metrics"].items():
            if metric not in brow:
                continue  # e.g. summary-only columns on per-p rows
            if metric not in frow:
                problems.append(f"{name} {key}: metric {metric} missing "
                                f"from fresh row")
                continue
            bad = _check_metric(metric, direction, band, brow[metric],
                                frow[metric])
            if bad:
                problems.append(f"{name} {key}: {bad}")
    for key in fresh_rows:
        notes.append(f"{name} {key}: new row (no baseline), skipped")
    problems += _check_absolute(name, spec, fresh.get("rows", []))
    return problems, notes


def run_check(baseline_dir: Path, fresh_dir: Path, benches: list[str],
              expect_quick: bool | None = None) -> int:
    """expect_quick: in CI the --fresh dir starts as the checkout (which
    commits full-run BENCH_*.json) and the quick bench run is supposed to
    overwrite it. Requiring quick=True on the fresh side turns "the bench
    silently didn't run, we compared against the stale committed file"
    from a vacuous skip into a failure."""
    problems, notes = [], []
    for name in benches:
        base = _load(baseline_dir / f"BENCH_{name}.json")
        fresh = _load(fresh_dir / f"BENCH_{name}.json")
        if base is not None and "__malformed__" in base:
            problems.append(
                f"{name}: committed baseline "
                f"{baseline_dir / f'BENCH_{name}.json'} is malformed "
                f"({base['__malformed__']}) — {_regen_hint(name)} and "
                f"commit the result")
            continue
        if fresh is not None and "__malformed__" in fresh:
            problems.append(
                f"{name}: fresh {fresh_dir / f'BENCH_{name}.json'} is "
                f"malformed ({fresh['__malformed__']}) — the bench run was "
                f"interrupted or wrote garbage; {_regen_hint(name)}")
            continue
        if base is None:
            notes.append(f"{name}: no committed baseline under "
                         f"{baseline_dir}, skipped — to gate this bench, "
                         f"{_regen_hint(name)} and commit it there")
            continue
        if fresh is None:
            problems.append(f"{name}: fresh BENCH_{name}.json missing from "
                            f"{fresh_dir} — did the bench run? "
                            f"{_regen_hint(name)}")
            continue
        if expect_quick is not None:
            # under the CI invocation a skip is a hole in the gate, so BOTH
            # sides must be healthy quick-mode payloads, else fail
            if bool(fresh.get("quick")) != expect_quick:
                problems.append(
                    f"{name}: fresh BENCH_{name}.json has quick="
                    f"{fresh.get('quick')} but the gate expected "
                    f"quick={expect_quick} — the bench run did not "
                    f"overwrite the committed file (did it run at all?)")
                continue
            if base.get("status") != "ok" or \
                    bool(base.get("quick")) != expect_quick:
                problems.append(
                    f"{name}: committed baseline is not a healthy "
                    f"quick-mode payload (status="
                    f"{base.get('status')!r}, quick={base.get('quick')}) — "
                    f"regenerate results/baselines/quick/BENCH_{name}.json "
                    f"from `benchmarks.run --quick`")
                continue
        p, n = compare_bench(name, base, fresh)
        problems += p
        notes += n
    for n in notes:
        print(f"  note: {n}")
    if problems:
        print(f"check_bench: {len(problems)} regression(s) vs "
              f"{baseline_dir}:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"check_bench: all gated metrics within tolerance vs "
          f"{baseline_dir}")
    return 0


def _degrade(payload: dict, factor: float) -> dict:
    """Worsen every gated metric by `factor` (the injected regression)."""
    out = json.loads(json.dumps(payload))  # deep copy
    spec = SPECS[out["bench"]]
    for row in out.get("rows", []):
        for metric, (direction, _band) in spec["metrics"].items():
            if metric not in row:
                continue
            if direction == "bool-true":
                row[metric] = False
            elif direction == "higher":
                row[metric] = round(float(row[metric]) * (1 - factor), 4)
            else:
                row[metric] = round(float(row[metric]) * (1 + factor), 4)
    return out


def selftest(baseline_dir: Path, benches: list[str]) -> int:
    """The gate must (a) pass a baseline against itself, (b) fail once a
    25% regression is injected into every gated metric, and (c) fail when
    *only* the serving p50 latency ratio regresses — proving the latency
    gate trips on its own, not just riding along with the others."""
    import tempfile

    found = [n for n in benches
             if (baseline_dir / f"BENCH_{n}.json").exists()]
    if not found:
        print(f"selftest: no BENCH_*.json under {baseline_dir}")
        return 1
    with tempfile.TemporaryDirectory() as td:
        tmp = Path(td) / "all"
        tmp.mkdir()
        for n in found:
            payload = _load(baseline_dir / f"BENCH_{n}.json")
            (tmp / f"BENCH_{n}.json").write_text(
                json.dumps(_degrade(payload, 0.25)))
        print("selftest phase 1: baseline vs itself (must pass)")
        if run_check(baseline_dir, baseline_dir, found) != 0:
            print("selftest FAIL: baseline does not pass against itself")
            return 1
        print("selftest phase 2: injected 25% regression (must fail)")
        if run_check(baseline_dir, tmp, found) == 0:
            print("selftest FAIL: 25% regression slipped through the gate")
            return 1
        if "serving" in found:
            payload = _load(baseline_dir / "BENCH_serving.json")
            p50only = json.loads(json.dumps(payload))
            touched = 0
            for row in p50only.get("rows", []):
                if "p50_vs_v1" in row:
                    row["p50_vs_v1"] = round(
                        float(row["p50_vs_v1"]) * 1.5, 4)
                    touched += 1
            if not touched:
                print("selftest FAIL: serving baseline has no p50_vs_v1 "
                      "rows to regress — latency gate untestable")
                return 1
            tmp50 = Path(td) / "p50"
            tmp50.mkdir()
            (tmp50 / "BENCH_serving.json").write_text(json.dumps(p50only))
            print("selftest phase 3: injected p50-only serving latency "
                  "regression (must fail)")
            if run_check(baseline_dir, tmp50, ["serving"]) == 0:
                print("selftest FAIL: a 1.5x p50 latency regression "
                      "slipped through the serving gate")
                return 1
        if "sharded" in found:
            payload = _load(baseline_dir / "BENCH_sharded.json")
            nbonly = json.loads(json.dumps(payload))
            touched = 0
            for row in nbonly.get("rows", []):
                if "N_b" in row:
                    row["N_b"] = round(float(row["N_b"]) * 1.5, 1)
                    if "nb_ratio_vs_mono" in row:
                        row["nb_ratio_vs_mono"] = round(
                            float(row["nb_ratio_vs_mono"]) * 1.5, 4)
                    touched += 1
            if not touched:
                print("selftest FAIL: sharded baseline has no N_b rows to "
                      "regress — threshold-propagation gate untestable")
                return 1
            tmpnb = Path(td) / "nb"
            tmpnb.mkdir()
            (tmpnb / "BENCH_sharded.json").write_text(json.dumps(nbonly))
            print("selftest phase 4: injected N_b-only sharded regression "
                  "(must fail)")
            if run_check(baseline_dir, tmpnb, ["sharded"]) == 0:
                print("selftest FAIL: a 1.5x sharded N_b regression "
                      "slipped through the gate")
                return 1
            idsflip = json.loads(json.dumps(payload))
            touched = 0
            for row in idsflip.get("rows", []):
                if row.get("policy") == "two_phase_safe" and \
                        row.get("p") == 2.0:
                    row["ids_match_independent"] = False
                    touched += 1
            if not touched:
                print("selftest FAIL: sharded baseline has no two_phase_safe"
                      " p=2.0 row — ids-parity gate untestable")
                return 1
            tmpids = Path(td) / "ids"
            tmpids.mkdir()
            (tmpids / "BENCH_sharded.json").write_text(json.dumps(idsflip))
            print("selftest phase 5: flipped two_phase_safe ids parity "
                  "(must fail)")
            if run_check(baseline_dir, tmpids, ["sharded"]) == 0:
                print("selftest FAIL: an ids-parity flip slipped through "
                      "the sharded gate")
                return 1
        if "compressed" in found:
            payload = _load(baseline_dir / "BENCH_compressed.json")
            sconly = json.loads(json.dumps(payload))
            touched = 0
            for row in sconly.get("rows", []):
                if "screen_out" in row:
                    # the screen silently letting half its kills through:
                    # f32 rows gathered goes up, only screen_out moves here
                    row["screen_out"] = round(
                        float(row["screen_out"]) * 0.5, 4)
                    touched += 1
            if not touched:
                print("selftest FAIL: compressed baseline has no screen_out"
                      " rows to regress — screen gate untestable")
                return 1
            tmpsc = Path(td) / "screen"
            tmpsc.mkdir()
            (tmpsc / "BENCH_compressed.json").write_text(json.dumps(sconly))
            print("selftest phase 6: injected screen-out-only compressed "
                  "regression (must fail)")
            if run_check(baseline_dir, tmpsc, ["compressed"]) == 0:
                print("selftest FAIL: a 2x screen-out regression slipped "
                      "through the compressed gate")
                return 1
        if "health" in found:
            payload = _load(baseline_dir / "BENCH_health.json")
            covonly = json.loads(json.dumps(payload))
            touched = 0
            for row in covonly.get("rows", []):
                if "coverage_mean" in row:
                    # serving quietly dropping a segment: only achieved
                    # coverage moves, throughput and latency stay healthy
                    row["coverage_mean"] = round(
                        float(row["coverage_mean"]) - 0.10, 4)
                    touched += 1
            if not touched:
                print("selftest FAIL: health baseline has no coverage_mean "
                      "rows to regress — coverage gate untestable")
                return 1
            tmpcov = Path(td) / "cov"
            tmpcov.mkdir()
            (tmpcov / "BENCH_health.json").write_text(json.dumps(covonly))
            print("selftest phase 7: injected coverage-only health "
                  "regression (must fail)")
            if run_check(baseline_dir, tmpcov, ["health"]) == 0:
                print("selftest FAIL: a 10 pt coverage regression slipped "
                      "through the health gate")
                return 1
    print("selftest PASS: gate is live (self-compare clean, 25% regression "
          "caught, p50-only latency regression caught, sharded N_b, "
          "ids-parity, compressed screen-out, and degraded-coverage "
          "regressions caught)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", type=Path,
                    default=ROOT / "results" / "baselines" / "quick")
    ap.add_argument("--fresh", type=Path, default=ROOT / "results")
    ap.add_argument("--benches", type=str,
                    default="build,beam,serving,verify,sharded,compressed,"
                            "health")
    ap.add_argument("--selftest", action="store_true",
                    help="inject a 25% regression and assert the gate trips")
    ap.add_argument("--expect-quick", action="store_true",
                    help="fail (instead of skip) any bench whose fresh "
                         "JSON is not from a --quick run — guards against "
                         "comparing a stale committed full-run file")
    args = ap.parse_args(argv)
    benches = [b for b in args.benches.split(",") if b]
    unknown = [b for b in benches if b not in SPECS]
    if unknown:
        print(f"check_bench: no spec for bench(es) {unknown}; "
              f"known: {sorted(SPECS)}")
        return 2
    if args.selftest:
        return selftest(args.baseline, benches)
    return run_check(args.baseline, args.fresh, benches,
                     expect_quick=True if args.expect_quick else None)


if __name__ == "__main__":
    sys.exit(main())
