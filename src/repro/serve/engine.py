"""Batched serving engine: prefill + decode over jit'd steps.

The engine owns the compiled prefill/decode executables and the KV cache;
requests are served in fixed-size batches (continuous batching is modeled as
slot reuse: a finished sequence's slot is refilled at the next prefill).
Greedy and temperature sampling.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist.sharding import Runtime
from repro.models.model import decode_step, prefill


@dataclass
class ServeEngine:
    cfg: ArchConfig
    rt: Runtime
    params: dict
    max_seq: int = 512

    def __post_init__(self):
        cfg, rt = self.cfg, self.rt

        def _prefill(params, batch):
            return prefill(params, batch, cfg, rt, s_max=self.max_seq)

        def _decode(params, tokens, cache, pos):
            return decode_step(params, tokens, cache, pos, cfg, rt)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(2,))

    def generate(
        self,
        prompts: np.ndarray,   # (B, S0) int32 prompt tokens
        steps: int,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> np.ndarray:
        """Generate `steps` tokens for each prompt (greedy if temperature=0)."""
        cfg = self.cfg
        b, s0 = prompts.shape
        assert s0 + steps <= self.max_seq
        batch = {"tokens": jnp.asarray(prompts, dtype=jnp.int32)}
        last_hidden, cache = self._prefill(self.params, batch)
        # first generated token from the prefill's last hidden state
        from repro.models.model import _head_matrix

        logits = jnp.einsum(
            "bsd,dv->bsv", last_hidden, _head_matrix(self.params, cfg)
        )
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits[:, -1, :], temperature, key)
        out.append(tok)
        pos = s0
        for i in range(steps - 1):
            logits, cache = self._decode(
                self.params, tok[:, None], cache, jnp.int32(pos)
            )
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, -1, :], temperature, sub)
            out.append(tok)
            pos += 1
        return np.stack([np.asarray(t) for t in out], axis=1)

    def _sample(self, logits, temperature, key):
        logits = logits[..., : self.cfg.vocab_size].astype(jnp.float32)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(
            jnp.int32
        )
