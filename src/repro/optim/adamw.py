"""AdamW with sharded fp32 moments over bf16 parameters.

Moments (m, v) inherit each parameter's sharding (FSDP/TP), so optimizer
memory scales down with the mesh exactly like ZeRO. Updates are computed in
fp32 and cast back to the parameter dtype (bf16 master-free training with
fp32 moments — the memory/quality point used by large production runs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def adamw_update(
    params,
    grads,
    state,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    """One AdamW step. lr may be a scalar or a schedule fn of state['step']."""
    step = state["step"] + 1
    lr_t = lr(step) if callable(lr) else lr

    # global-norm clip in fp32
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)) + 1e-12
    )
    scale = jnp.minimum(1.0, grad_clip / gnorm)
    g32 = jax.tree.map(lambda g: g * scale, g32)

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], g32)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], g32)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        decay = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr_t * (u + decay)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {
        "grad_norm": gnorm, "lr": lr_t,
    }
