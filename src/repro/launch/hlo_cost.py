"""Loop-aware HLO cost analysis.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` reports) visits each
while-loop body ONCE — for scan-over-layers models that undercounts FLOPs by
the layer count (verified in tests). This module parses the optimized HLO
text and rolls costs up through the call graph, multiplying while-loop body
costs by their trip counts (recovered from the loop condition's comparison
constant).

Accounting rules (mirroring HloCostAnalysis semantics where it is right):
  * dot: 2 * prod(result_dims) * prod(lhs_contracting_dims) FLOPs
  * elementwise / reduce / others: 1 FLOP per output (or input) element
  * fusion ops: FLOPs of the called computation; BYTES only at the fusion
    boundary (operands + result — fusion internals never touch HBM)
  * while: trip_count x (body + condition)
  * conditional: max over branches (upper bound)
  * collective ops: result bytes, attributed per kind, loop-scaled
  * dynamic-update-slice: 2 x update bytes (in-place semantics)

Everything is per-device (the HLO is the per-device SPMD module).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_KNOWN_TRIPS = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
# result-type group is lazy up to the first word(-with-dashes) followed by
# '(' — tuple types may embed /*index=N*/ comments (which contain '=') so we
# cannot exclude '=' from the type text.
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_SHAPE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0, "s4": 1, "u4": 1,
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
    "opt-barrier", "domain",
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    elems = 0
    total = 0
    for dtype, dims in _SHAPE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0      # upper bound: every op's operands+result (CPU-
    #                         fusion pessimistic; XLA:TPU fuses elementwise)
    bytes_min: float = 0.0  # lower bound: perfect elementwise fusion — only
    #                         dots/convs/gathers/scatters/reduces/copies and
    #                         collectives touch HBM
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.bytes_min += other.bytes_min * scale
        self.transcendentals += other.transcendentals * scale
        self.collective_bytes += other.collective_bytes * scale
        for k, v in other.collectives.items():
            d = self.collectives.setdefault(k, {"count": 0.0, "bytes": 0.0})
            d["count"] += v["count"] * scale
            d["bytes"] += v["bytes"] * scale


@dataclass
class _Op:
    name: str
    result: str
    kind: str
    line: str
    operands: list[str]
    called: list[str]


def _parse_computations(hlo: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    current: str | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.strip().endswith("{"):
                current = m.group(1)
                comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, result, kind = m.group(1), m.group(2), m.group(3)
        paren = _OPERANDS.search(line[m.end(3):])
        operands = _OPERAND_NAME.findall(paren.group(1)) if paren else []
        called: list[str] = []
        for cm in _CALLS.finditer(line):
            called.extend(c.strip().lstrip("%") for c in cm.group(1).split(","))
        comps[current].append(_Op(name, result, kind, line, operands, called))
    return comps


def _trip_count(cond_ops: list[_Op]) -> int:
    """Scan-generated loop conditions compare the induction var to a constant."""
    consts = []
    for op in cond_ops:
        consts.extend(int(c) for c in _CONST_INT.findall(op.line))
    return max(consts) if consts else 1


_TRANSCENDENTAL = {"exponential", "log", "rsqrt", "sqrt", "power", "tanh",
                   "logistic", "sine", "cosine", "exponential-minus-one",
                   "log-plus-one", "atan2", "cbrt", "erf"}


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = _parse_computations(hlo_text)
        self._symbols: dict[str, dict[str, str]] = {
            c: {op.name: op.result for op in ops} for c, ops in self.comps.items()
        }
        self._cache: dict[tuple[str, bool], Cost] = {}
        self.entry = self._find_entry(hlo_text)

    def _find_entry(self, hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.MULTILINE)
        if m:
            return m.group(1)
        return next(iter(self.comps))

    def _operand_bytes(self, comp: str, op: _Op) -> int:
        total = 0
        sym = self._symbols.get(comp, {})
        for o in op.operands:
            if o in sym:
                total += _shape_elems_bytes(sym[o])[1]
        return total

    def _dot_flops(self, comp: str, op: _Op) -> float:
        out_elems, _ = _shape_elems_bytes(op.result)
        m = _CONTRACT.search(op.line)
        contract = 1
        if m and op.operands:
            lhs = self._symbols.get(comp, {}).get(op.operands[0], "")
            sm = _SHAPE.search(lhs)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contract *= dims[int(ci)]
        return 2.0 * out_elems * contract

    def comp_cost(self, comp: str, fused: bool) -> Cost:
        key = (comp, fused)
        if key in self._cache:
            return self._cache[key]
        self._cache[key] = Cost()  # break recursion defensively
        total = Cost()
        for op in self.comps.get(comp, []):
            total.add(self._op_cost(comp, op, fused))
        self._cache[key] = total
        return total

    def _op_cost(self, comp: str, op: _Op, fused: bool) -> Cost:
        c = Cost()
        kind = op.kind
        out_elems, out_bytes = _shape_elems_bytes(op.result)
        if kind in _FREE_OPS:
            return c
        coll = next((k for k in COLLECTIVES if kind.startswith(k)), None)
        if coll is not None:
            if kind.endswith("-done"):
                return c
            c.collective_bytes = out_bytes
            c.collectives[coll] = {"count": 1, "bytes": out_bytes}
            c.bytes = out_bytes + self._operand_bytes(comp, op)
            c.bytes_min = c.bytes
            return c
        if kind == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", op.line)
            cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
            if bm:
                tm = _KNOWN_TRIPS.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = _trip_count(self.comps.get(cm.group(1), [])) if cm else 1
                c.add(self.comp_cost(bm.group(1), False), scale=max(trips, 1))
            return c
        if kind == "conditional":
            best = Cost()
            for called in op.called:
                cand = self.comp_cost(called, False)
                if cand.flops + cand.bytes > best.flops + best.bytes:
                    best = cand
            c.add(best)
            return c
        if kind in ("call", "async-start"):
            m = re.search(r"to_apply=%?([\w\.\-]+)", op.line)
            if m:
                c.add(self.comp_cost(m.group(1), fused))
            return c
        if kind == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", op.line)
            if m:
                inner = self.comp_cost(m.group(1), True)
                c.flops += inner.flops
                c.bytes_min += inner.bytes_min
                c.transcendentals += inner.transcendentals
                c.collective_bytes += inner.collective_bytes
                for k, v in inner.collectives.items():
                    d = c.collectives.setdefault(k, {"count": 0, "bytes": 0})
                    d["count"] += v["count"]
                    d["bytes"] += v["bytes"]
            if not fused:
                c.bytes += out_bytes + self._operand_bytes(comp, op)
            return c
        heavy = False  # ops that must touch HBM even under perfect fusion
        if kind == "dot":
            c.flops = self._dot_flops(comp, op)
            heavy = True
        elif kind == "convolution":
            c.flops = 2.0 * out_elems  # lower bound; convs absent from zoo
            heavy = True
        elif kind in ("dynamic-update-slice",):
            upd = 0
            sym = self._symbols.get(comp, {})
            if len(op.operands) >= 2 and op.operands[1] in sym:
                upd = _shape_elems_bytes(sym[op.operands[1]])[1]
            if not fused:
                c.bytes = 2 * upd
            c.bytes_min = 2 * upd
            return c
        elif kind in ("reduce", "reduce-window"):
            c.flops = float(self._operand_bytes(comp, op)) / 4.0  # ~1 flop/elem
            heavy = True
        elif kind in ("gather", "dynamic-slice"):
            # reads only the sliced/gathered window, not the whole operand
            c.bytes_min = 2 * out_bytes
            if not fused:
                c.bytes = 2 * out_bytes
            return c
        elif kind in ("scatter", "copy", "transpose", "sort", "custom-call"):
            heavy = True
        else:
            c.flops = float(out_elems)
            if kind in _TRANSCENDENTAL:
                c.transcendentals = float(out_elems)
        if not fused:
            c.bytes = out_bytes + self._operand_bytes(comp, op)
        if heavy:
            c.bytes_min = out_bytes + self._operand_bytes(comp, op)
        return c

    def total(self) -> Cost:
        return self.comp_cost(self.entry, False)


def analyze_hlo(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    t = model.total()
    return {
        "flops": t.flops,
        "bytes_accessed": t.bytes_min,  # TPU-realistic (perfect fusion)
        "bytes_upper": t.bytes,
        "transcendentals": t.transcendentals,
        "collective_bytes": t.collective_bytes,
        "collectives": t.collectives,
    }
