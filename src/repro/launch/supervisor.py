"""Process supervisor: restart-on-failure with exponential backoff.

Wraps any repro entry point (typically launch.train) and restarts it when it
exits nonzero or its heartbeat stalls — combined with checkpoint auto-resume
this is the node-failure story: a crashed/preempted worker rejoins from the
last committed checkpoint.

  PYTHONPATH=src python -m repro.launch.supervisor --retries 3 -- \
      python -m repro.launch.train --arch tinyllama_1_1b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --fail-at-step 7
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import time


def supervise(cmd: list[str], retries: int = 3, backoff_s: float = 1.0,
              backoff_factor: float = 2.0) -> int:
    attempt = 0
    while True:
        t0 = time.time()
        print(f"[supervisor] attempt {attempt}: {' '.join(cmd)}", flush=True)
        proc = subprocess.run(cmd)
        if proc.returncode == 0:
            print(f"[supervisor] success after {attempt} restarts", flush=True)
            return 0
        attempt += 1
        if attempt > retries:
            print(f"[supervisor] giving up after {retries} restarts", flush=True)
            return proc.returncode
        delay = backoff_s * backoff_factor ** (attempt - 1)
        print(f"[supervisor] exit code {proc.returncode} after "
              f"{time.time() - t0:.1f}s; restarting in {delay:.1f}s",
              flush=True)
        time.sleep(delay)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--retries", type=int, default=3)
    ap.add_argument("--backoff", type=float, default=1.0)
    ap.add_argument("cmd", nargs=argparse.REMAINDER)
    args = ap.parse_args(argv)
    cmd = args.cmd
    if cmd and cmd[0] == "--":
        cmd = cmd[1:]
    if not cmd:
        ap.error("no command given after --")
    return supervise(cmd, retries=args.retries, backoff_s=args.backoff)


if __name__ == "__main__":
    sys.exit(main())
