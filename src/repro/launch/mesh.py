"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axis semantics: 'pod' = inter-pod DP (DCN), 'data' = intra-pod DP/FSDP,
    'model' = tensor/expert parallelism (ICI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_with_stage_axis(stages: int, data: int, model: int):
    """Pipeline-parallel mesh hook (documented, not used by the baseline
    512-chip configuration — DP x FSDP x TP covers it; see DESIGN.md §5)."""
    return jax.make_mesh((stages, data, model), ("stage", "data", "model"))
