"""Serving entry point: LM decode + optional universal-Lp retrieval tier.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b --smoke \
      --batch 4 --prompt-len 16 --steps 32
  PYTHONPATH=src python -m repro.launch.serve --retrieval --requests 64

On real hardware the same engine runs under launch/mesh.py's production
meshes with the decode cache sequence-sharded over 'model' and (for MoE
archs) the weights-stationary decode MoE (DESIGN.md §5).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.dist.sharding import Runtime, set_mesh
from repro.launch.mesh import make_local_mesh


def serve_lm(args) -> int:
    from repro.models.model import init_params
    from repro.serve.engine import ServeEngine

    cfg = get_arch(args.arch, smoke=args.smoke)
    mesh = make_local_mesh(args.data, args.model)
    rt = Runtime(mesh=mesh, moe_decode_gather=args.moe_decode_gather)
    with set_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(args.seed))
        eng = ServeEngine(cfg, rt, params,
                          max_seq=args.prompt_len + args.steps)
        prompts = np.random.default_rng(args.seed).integers(
            0, cfg.vocab_size, size=(args.batch, args.prompt_len)
        ).astype(np.int32)
        t0 = time.time()
        out = eng.generate(prompts, steps=args.steps,
                           temperature=args.temperature)
        dt = time.time() - t0
    tok = args.batch * args.steps
    print(f"generated {out.shape} tokens in {dt:.1f}s "
          f"({tok / dt:.1f} tok/s on this host)")
    print("sample:", out[0][:16].tolist())
    return 0


def serve_retrieval(args) -> int:
    from repro.core.datasets import make_dataset
    from repro.core.uhnsw import UHNSWParams
    from repro.index.persist import DurableIndex, latest_durable_snapshot
    from repro.index.sharded import ShardedUHNSW
    from repro.retrieval.engine import FaultInjector
    from repro.retrieval.service import QueryRequest, UniversalVectorService

    # chaos rehearsal (DESIGN.md §9, §11): a seeded injector at the
    # engine's device-call boundary; 0.0 leaves the happy path untouched.
    # --fault-sites segment adds the per-segment sites (opt-in — the
    # classic three-site schedules never shift), which exercises the
    # health tracker's EWMA quarantine path under the coverage floor.
    injector = None
    if args.fault_rate > 0:
        sites = tuple(args.fault_sites.split(",")) if args.fault_sites \
            else None
        injector = FaultInjector(rate=args.fault_rate, seed=args.fault_seed,
                                 sites=sites)
    ds = make_dataset("deep", n=args.n, n_queries=128, seed=args.seed)
    # --compressed: two-band verification (DESIGN.md §10) — candidates are
    # screened against the int8 band and only survivors gather f32 rows;
    # results are bitwise-identical, f32-rows tells what the screen saved
    params = UHNSWParams(t=200, compressed_band=args.compressed)
    if args.state_dir:
        # durable lifecycle: recover an existing state dir (snapshot + WAL
        # replay, bit-identical) or snapshot a fresh build into it
        if latest_durable_snapshot(args.state_dir) is not None:
            index = DurableIndex.recover(args.state_dir, params=params)
            print(f"recovered durable index from {args.state_dir}: "
                  f"n={index.n}, {index.num_segments} segments, "
                  f"{len(index.delta)} delta-resident inserts")
        else:
            index = DurableIndex.create(
                ShardedUHNSW.build(ds.data, num_segments=args.segments,
                                   m=16, params=params),
                args.state_dir)
            print(f"created durable index at {args.state_dir}: n={index.n}")
        service = UniversalVectorService(index=index,
                                         fault_injector=injector,
                                         min_coverage=args.min_coverage)
    else:
        service = UniversalVectorService.build(ds.data, params, m=16,
                                               num_segments=args.segments,
                                               fault_injector=injector,
                                               min_coverage=args.min_coverage)
    rng = np.random.default_rng(args.seed)
    reqs = [
        QueryRequest(
            vector=ds.queries[int(rng.integers(len(ds.queries)))],
            p=float(rng.choice([0.5, 0.8, 1.0, 1.3, 1.7, 2.0])),
            k=10, request_id=i,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    out = service.serve(reqs)
    dt = time.time() - t0
    st = service.stats
    lat = service.latency_summary()
    print(f"served {len(out)} mixed-p requests in {dt:.1f}s "
          f"({len(out) / dt:.0f} qps, {st['batches']} ladder waves, "
          f"queue peak {st['queue_peak']}); "
          f"avg N_b={st['n_b'] / len(reqs):.0f} "
          # probe = threshold-free work, spill = work under an inherited
          # cross-segment bound (DESIGN.md §3); spill=0 off the
          # two_phase/round_robin policies
          f"(probe={st['n_b_probe'] / len(reqs):.0f} "
          f"spill={st['n_b_spill'] / len(reqs):.0f}) "
          f"N_p={st['n_p'] / len(reqs):.0f} "
          # effective T_p under early-abandoning verification (DESIGN.md
          # §8); no verification at all (n_p == 0) means full-dim = 1.0
          f"dim-scan="
          f"{st['dim_frac_w'] / st['n_p'] if st['n_p'] else 1.0:.2f} "
          # f32 rows gathered per scored candidate (DESIGN.md §10); 1.0
          # without --compressed, < 1 when the int8 screen is saving HBM
          f"f32-rows="
          f"{st['f32_rows_w'] / st['n_p'] if st['n_p'] else 1.0:.2f}; "
          f"latency p50={lat['p50']:.0f}ms p95={lat['p95']:.0f}ms")
    # engine scheduling outcomes (DESIGN.md §6): why batches dispatched,
    # what admission control did, and where each request's time went
    fl = st["flushes"]
    print(f"  flushes: full={fl['full']} deadline={fl['deadline']} "
          f"drain={fl['drain']}; shed={st['shed']} "
          f"degraded={st['degraded']} padded_rows={st['padded_rows']}")
    # fault tolerance (DESIGN.md §9): every admitted request ended DONE or
    # deterministic FAILED; the counters say what the recovery paid
    failures = service.engine.take_failures()
    if args.fault_rate > 0 or st["faults"]:
        print(f"  faults: caught={st['faults']} retries={st['retries']} "
              f"quarantine_splits={st['quarantine_splits']} "
              f"failed={st['failed']}"
              + (f" (injector: rate={args.fault_rate}, "
                 f"seed={args.fault_seed}, "
                 f"injected={injector.injected})" if injector else ""))
        for rid, err in sorted(failures.items())[:5]:
            print(f"    request {rid} FAILED: {err}")
    # degraded serving (DESIGN.md §11): achieved coverage, what the NaN
    # guard caught, and the quarantine/recovery/probe tallies — printed
    # whenever the engine ran degraded or the operator set a floor
    hl = lat.get("health") or {}
    tracker = hl.get("tracker")
    if hl and (args.min_coverage > 0 or hl.get("poison_detected")
               or hl.get("seg_quarantined") or hl.get("min_coverage_failed")
               or (tracker and tracker.get("quarantined"))):
        print(f"  health: coverage_mean={hl['coverage_mean']:.4f} "
              f"(floor {args.min_coverage}) "
              f"poison_detected={hl['poison_detected']} "
              f"quarantined={hl['seg_quarantined']} "
              f"recovered={hl['seg_recovered']} "
              f"min_coverage_failed={hl['min_coverage_failed']}")
        if tracker:
            print(f"    tracker: by_state={tracker['by_state']} "
                  f"probes={tracker['probes']} "
                  f"failures={tracker['failures']} "
                  f"generation={tracker['generation']}")
    qm, cm = lat.get("queue_ms") or {}, lat.get("compute_ms") or {}
    if qm and cm:
        warm = lat.get("warm") or {}
        warm_txt = (f", warm-only p50={warm['p50']:.0f}ms "
                    f"p95={warm['p95']:.0f}ms" if warm else "")
        print(f"  latency split: queue-wait p50={qm['p50']:.0f}ms "
              f"p95={qm['p95']:.0f}ms | device-compute p50={cm['p50']:.0f}ms "
              f"p95={cm['p95']:.0f}ms | {lat['cold_count']} requests rode a "
              f"first-compile batch shape{warm_txt}")
    for name, pb in st["per_base"].items():
        if pb["queries"]:
            print(f"  {name}: {pb['queries']} queries / {pb['batches']} "
                  f"batches, avg N_b={pb['n_b'] / pb['queries']:.0f} "
                  f"N_p={pb['n_p'] / pb['queries']:.0f} dim-scan="
                  f"{pb['dim_frac_w'] / pb['n_p'] if pb['n_p'] else 1.0:.2f}"
                  f" f32-rows="
                  f"{pb['f32_rows_w'] / pb['n_p'] if pb['n_p'] else 1.0:.2f}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--moe-decode-gather", action="store_true")
    ap.add_argument("--retrieval", action="store_true",
                    help="serve the universal-Lp vector search tier instead")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--segments", type=int, default=4,
                    help="frozen segments in the sharded index (the unit "
                         "of quarantine under --fault-sites segment)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="inject transient device-call faults at this "
                         "rate (seeded, deterministic; DESIGN.md §9)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fault-sites", default=None,
                    help="comma-separated injector site filter, e.g. "
                         "'search' or 'segment' (the per-segment wildcard; "
                         "DESIGN.md §11). Default: the three classic sites")
    ap.add_argument("--min-coverage", type=float, default=0.0,
                    help="degraded-serving floor (DESIGN.md §11): waves "
                         "collected below this alive-coverage fraction "
                         "retry after segment recovery or FAIL their "
                         "requests with the achieved coverage attached")
    ap.add_argument("--state-dir", default=None,
                    help="durable index state: recover from this directory "
                         "if it holds a snapshot, else snapshot the fresh "
                         "build into it (inserts ride the WAL)")
    ap.add_argument("--compressed", action="store_true",
                    help="two-band verification over the int8 compressed "
                         "band (DESIGN.md §10): bitwise-identical results, "
                         "f32 row gathers only for screen survivors")
    args = ap.parse_args(argv)
    return serve_retrieval(args) if args.retrieval else serve_lm(args)


if __name__ == "__main__":
    sys.exit(main())
