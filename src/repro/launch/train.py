"""Training entry point with checkpoint/restart fault tolerance.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  # failure injection (integration-tested): crash at step 7, then rerun with
  # the same --ckpt-dir to resume from the last checkpoint
  ... --fail-at-step 7

Use launch/supervisor.py to get automatic restart-on-failure semantics.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.checkpoint.store import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs.base import get_arch
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist.sharding import Runtime, set_mesh, spec_shardings
from repro.launch.mesh import make_local_mesh
from repro.models.params import param_specs
from repro.train.monitor import HeartbeatMonitor
from repro.train.step import TrainConfig, init_train_state, make_train_step


def state_shardings(cfg, rt, tc: TrainConfig):
    from jax.sharding import NamedSharding, PartitionSpec as P

    specs = param_specs(cfg)
    p_sh = spec_shardings(specs, rt)
    f_sh = p_sh  # moments share the param shardings
    state = {
        "params": p_sh,
        "opt": {"m": f_sh, "v": f_sh,
                "step": NamedSharding(rt.mesh, P())},
    }
    if tc.grad_compression:
        state["err"] = f_sh
    return state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis size")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis size")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="crash deliberately at this step (fault-tolerance test)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--metrics-out", type=str, default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch, smoke=args.smoke)
    mesh = make_local_mesh(args.data, args.model)
    rt = Runtime(mesh=mesh, remat=args.remat)
    tc = TrainConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps,
                     microbatches=args.microbatches,
                     grad_compression=args.grad_compression)

    pipe = SyntheticTokenPipeline(cfg, args.batch, args.seq, seed=args.seed)
    step_fn = make_train_step(cfg, rt, tc)

    start = 0
    with set_mesh(mesh):
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            skeleton = jax.eval_shape(
                lambda: init_train_state(cfg, rt, tc, jax.random.PRNGKey(args.seed))
            )
            shardings = state_shardings(cfg, rt, tc)
            state, start = restore_checkpoint(args.ckpt_dir, skeleton, shardings)
            start += 1
            print(f"resumed from step {start - 1}", flush=True)
        else:
            state = init_train_state(cfg, rt, tc, jax.random.PRNGKey(args.seed))

        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        hb = HeartbeatMonitor(f"{args.ckpt_dir}/heartbeat.json") if args.ckpt_dir else None
        losses = []
        for step in range(start, args.steps):
            if step == args.fail_at_step:
                print(f"FAULT-INJECTION: crashing at step {step}", flush=True)
                sys.stdout.flush()
                raise SystemExit(42)
            batch = pipe.batch(step)
            if tc.microbatches > 1:
                batch = jax.tree.map(
                    lambda a: a.reshape(tc.microbatches,
                                        a.shape[0] // tc.microbatches,
                                        *a.shape[1:]),
                    batch,
                )
            t0 = time.time()
            state, metrics = jit_step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if hb:
                hb.beat(step, {"loss": loss})
            if step % args.log_every == 0:
                print(f"step {step}: loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({time.time() - t0:.2f}s)", flush=True)
            if ckpt and (step + 1) % args.save_every == 0:
                ckpt.save(step, state)
        if ckpt:
            ckpt.save(args.steps - 1, state)
            ckpt.wait()
    if args.metrics_out:
        import json
        from pathlib import Path
        Path(args.metrics_out).write_text(json.dumps({"losses": losses}))
    print(f"done: final loss {losses[-1] if losses else float('nan'):.4f}",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
