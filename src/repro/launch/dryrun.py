import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, extract memory/cost/collective analyses.

MUST be run as its own process (the XLA_FLAGS line above executes before any
other import, including jax — device count locks at first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama_1_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
Options: --multi-pod (2x16x16 instead of 16x16), --remat, --microbatches N.
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_arch
from repro.dist.sharding import Runtime, set_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs, state_specs
from repro.models.model import decode_step, prefill
from repro.train.step import TrainConfig, make_train_step

# TPU v5e hardware constants (per chip) for the roofline terms
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link

_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<shape>\(?[a-z0-9_]+\[[^\]=]*\][^\s]*\)?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind byte totals from the compiled HLO (per-device shapes)."""
    out: dict[str, dict] = {}
    seen_done = set()
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        op = m.group("op")
        # async pairs (-start/-done) would double count; the regex strips the
        # suffix so both match — count each line once via span dedup
        if m.start() in seen_done:
            continue
        seen_done.add(m.start())
        b = _shape_bytes(m.group("shape"))
        d = out.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def analyze_compiled(lowered, compiled, mesh) -> dict:
    from repro.launch.hlo_cost import analyze_hlo

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    ca = ca or {}
    txt = compiled.as_text()
    # loop-aware accounting: XLA's HloCostAnalysis counts while bodies once,
    # which undercounts scan-over-layers models by the layer count
    loop_aware = analyze_hlo(txt)
    n_chips = mesh.devices.size
    flops = loop_aware["flops"]                    # per-device
    bytes_accessed = loop_aware["bytes_accessed"]
    coll_bytes = loop_aware["collective_bytes"]
    return {
        "n_chips": int(n_chips),
        "per_device": {
            "flops": flops,
            "bytes_accessed": bytes_accessed,
            "collective_bytes": coll_bytes,
            "transcendentals": loop_aware["transcendentals"],
            "xla_flops_unscaled": float(ca.get("flops", 0.0)),
            "xla_bytes_unscaled": float(ca.get("bytes accessed", 0.0)),
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
        },
        "collectives": loop_aware["collectives"],
        "roofline_seconds": {
            "compute": flops / PEAK_FLOPS,
            "memory": bytes_accessed / HBM_BW,
            "collective": coll_bytes / ICI_BW,
        },
    }


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             remat: bool = True, microbatches: int = 1,
             rules: dict | None = None, verbose: bool = True,
             explicit_tp: bool = False, seq_shard: bool = False,
             moe_decode_gather: bool = False, full_dp: bool = False,
             weights_once: bool = False) -> dict:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return {"arch": arch_id, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch; long_500k needs sub-quadratic "
                          "attention (DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rt = Runtime(mesh=mesh, remat=remat and shape.kind == "train",
                 rules=rules or {}, explicit_tp=explicit_tp,
                 seq_shard=seq_shard, moe_decode_gather=moe_decode_gather,
                 full_dp=full_dp)
    t0 = time.time()
    with set_mesh(mesh):
        if shape.kind == "train":
            tc = TrainConfig(microbatches=microbatches,
                             weights_once=weights_once)
            step = make_train_step(cfg, rt, tc)
            state = state_specs(cfg, rt)
            batch = batch_specs(cfg, shape, rt, microbatches=microbatches)
            lowered = jax.jit(step, donate_argnums=(0,)).lower(state, batch)
        elif shape.kind == "prefill":
            fn = lambda p, b: prefill(p, b, cfg, rt)
            state = state_specs(cfg, rt)["params"]
            batch = batch_specs(cfg, shape, rt)
            lowered = jax.jit(fn).lower(state, batch)
        else:  # decode
            fn = lambda p, t, c, pos: decode_step(p, t, c, pos, cfg, rt)
            params = state_specs(cfg, rt)["params"]
            tokens, cache, pos = decode_specs(cfg, shape, rt)
            lowered = jax.jit(fn, donate_argnums=(2,)).lower(
                params, tokens, cache, pos
            )
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "remat": rt.remat,
        "microbatches": microbatches,
        **analyze_compiled(lowered, compiled, mesh),
    }
    if verbose:
        ma = result["per_device"]
        rf = result["roofline_seconds"]
        print(
            f"  {arch_id} x {shape_name} [{result['mesh']}]: "
            f"args={ma['argument_bytes']/2**30:.2f}GiB "
            f"temp={ma['temp_bytes']/2**30:.2f}GiB "
            f"flops={ma['flops']:.3g} coll={ma['collective_bytes']/2**20:.1f}MiB | "
            f"roofline c/m/x = {rf['compute']:.3g}/{rf['memory']:.3g}/"
            f"{rf['collective']:.3g}s "
            f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
            flush=True,
        )
    return result


def optimized_settings(arch_id: str, shape_name: str) -> dict:
    """Per-family winning settings from the EXPERIMENTS.md §Perf hillclimb:
      * weights-stationary MoE for all MoE decode cells (34x / 11.5x);
      * full-DP (ZeRO-3, no TP) for <10B dense/ssm/hybrid archs (7.7x
        collective term, fits HBM);
      * gradient-accumulation microbatching for every train cell
        (liveness /mb at equal FLOPs); deepseek uses mb=4 — the expert
        FSDP-gather collective grows with mb, measured optimum.
    """
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    s: dict = {}
    if cfg.moe and shape.kind == "decode":
        s["moe_decode_gather"] = True
    # full-DP only where measured to win: small GQA-dense TRAIN cells.
    # Counter-measurements: recurrent mixers (RG-LRU/SSD scans) and MHA
    # (musicgen) go pathological under GSPMD when channel dims replicate,
    # and decode batches (128 < 256 chips) fall back to replicated caches.
    small = cfg.moe is None and cfg.param_count() < 10e9 and cfg.family == "dense"
    if small and shape.kind == "train":
        s["full_dp"] = True
    if shape.kind == "train":
        if s.get("full_dp"):
            # full-DP already runs 1 sequence/device; microbatching would
            # make the slice (gb/mb) indivisible by the chip count and GSPMD
            # falls back to a replicated batch (measured: 146x regression)
            pass
        elif arch_id == "deepseek_v3_671b":
            s["microbatches"] = 4
        else:
            s["microbatches"] = 16
    return s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--explicit-tp", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--moe-decode-gather", action="store_true")
    ap.add_argument("--full-dp", action="store_true")
    ap.add_argument("--weights-once", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the per-family §Perf winning settings")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)

    if args.all:
        todo = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch_id, shape_name in todo:
        for mp in meshes:
            kw = dict(
                remat=not args.no_remat,
                microbatches=args.microbatches,
                explicit_tp=args.explicit_tp,
                seq_shard=args.seq_shard,
                moe_decode_gather=args.moe_decode_gather,
                full_dp=args.full_dp,
                weights_once=args.weights_once,
            )
            if args.optimized:
                kw.update(optimized_settings(arch_id, shape_name))
            try:
                r = run_cell(arch_id, shape_name, multi_pod=mp, **kw)
            except Exception as e:  # a failure here is a bug in the system
                traceback.print_exc()
                r = {"arch": arch_id, "shape": shape_name,
                     "mesh": "2x16x16" if mp else "16x16",
                     "status": "error", "error": f"{type(e).__name__}: {e}"}
            results.append(r)
            if args.out:
                path = Path(args.out)
                path.mkdir(parents=True, exist_ok=True)
                name = f"{arch_id}__{shape_name}__{r.get('mesh', 'na')}.json"
                (path / name).write_text(json.dumps(r, indent=2))
    bad = [r for r in results if r["status"] == "error"]
    print(f"\ndry-run: {len(results)} cells, {len(bad)} errors", flush=True)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
