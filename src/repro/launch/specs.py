"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run contract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import Runtime, logical_to_spec, param_struct
from repro.models.model import cache_specs
from repro.models.params import param_specs, _map_specs, ParamSpec


def _sds(shape, dtype, rt: Runtime, logical):
    sh = NamedSharding(rt.mesh, logical_to_spec(logical, shape, rt))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime,
                microbatches: int = 1) -> dict:
    """Train/prefill batch ShapeDtypeStructs (tokens or stub-frontend frames)."""
    gb, s = shape.global_batch, shape.seq_len
    if microbatches > 1:
        assert gb % microbatches == 0, (gb, microbatches)
        gb = gb // microbatches  # per-microbatch slice

    def lead(dims, logical):
        if microbatches > 1:
            return (microbatches, *dims), (None, *logical)
        return dims, logical

    out = {}
    if cfg.frontend and shape.kind in ("train", "prefill"):
        dims, logical = lead((gb, s, cfg.frontend_dim), ("batch", None, None))
        out["frames"] = _sds(dims, jnp.bfloat16, rt, logical)
    else:
        dims, logical = lead((gb, s), ("batch", None))
        out["tokens"] = _sds(dims, jnp.int32, rt, logical)
    if shape.kind == "train":
        dims, logical = lead((gb, s), ("batch", None))
        out["labels"] = _sds(dims, jnp.int32, rt, logical)
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeConfig, rt: Runtime):
    """(tokens, cache, pos) ShapeDtypeStructs for serve_step lowering."""
    gb, s = shape.global_batch, shape.seq_len
    tokens = _sds((gb, 1), jnp.int32, rt, ("batch", None))

    def mk(spec: ParamSpec):
        sh = NamedSharding(rt.mesh, logical_to_spec(spec.logical, spec.shape, rt))
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sh)

    cache = _map_specs(mk, cache_specs(cfg, gb, s))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, cache, pos


def state_specs(cfg: ArchConfig, rt: Runtime, grad_compression: bool = False):
    """Train-state ShapeDtypeStructs: bf16 params + fp32 AdamW moments."""
    specs = param_specs(cfg)
    params = param_struct(specs, rt)

    def f32_like(s: ParamSpec):
        sh = NamedSharding(rt.mesh, logical_to_spec(s.logical, s.shape, rt))
        return jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=sh)

    opt = {
        "m": _map_specs(f32_like, specs),
        "v": _map_specs(f32_like, specs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    state = {"params": params, "opt": opt}
    if grad_compression:
        state["err"] = _map_specs(f32_like, specs)
    return state
