"""Launch layer: mesh construction, dry-run, train/serve entry points."""
