"""Deadline-triggered bucket scheduler for the serving engine.

The engine's scheduling problem (DESIGN.md §6): group an arrival stream
of mixed-p queries into homogeneous-base device batches *without* the v1
micro-batcher's two failure modes —

  * waiting for a bucket to fill (unbounded queue-wait at low traffic),
  * power-of-two padding + a hard verify-batch cap (wasted device rows
    and fragmented calls at high traffic).

Two mechanisms replace them:

**Deadline flush.** Buckets are keyed (base, k, exact) exactly like v1.
A bucket dispatches when it is FULL (max_batch rows ready) or when its
oldest request's deadline (`arrival + max_wait`) expires — whichever
comes first, evaluated against an *injectable clock* so tests (and the
simulated-time latency benchmark) drive time explicitly and never sleep.
`flush_all` force-flushes the remainder (reason "drain") when the caller
has no more arrivals.

**Half-octave ladder + exact-fit chunking.** A flush is cut into device
calls with sizes drawn greedily (largest first) from the ladder

    {min_bucket * 2^i} U {1.5 * min_bucket * 2^i}    (capped at max_batch)

e.g. min_bucket=8, max_batch=128 -> {8, 12, 16, 24, 32, 48, 64, 96, 128}.
Any multiple of min_bucket/2 >= min_bucket decomposes exactly (96 -> 96;
60 -> 48+12), so only sub-min_bucket tails ever pad — v1's pure
power-of-two ladder pads every non-power-of-two flush (96 -> 128 = 33%
wasted rows). The ladder stays a fixed finite set, so the jit cache
holds a bounded number of program shapes per (base, k-lane) family,
independent of traffic.

Admission control lives here too: past a queue-depth watermark the
scheduler either sheds new requests (reject, counted) or degrades them
onto the exact-base fast lane (approximate base-metric answer, no
verification, counted) — the engine stays live under overload instead
of queueing into its own deadline misses.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.retrieval.engine.request import FLUSHED, EngineRequest

# flush reasons (stats keys)
FULL = "full"
DEADLINE = "deadline"
DRAIN = "drain"

# overload policies
SHED = "shed"
DEGRADE = "degrade"


class ManualClock:
    """A hand-advanced clock for deterministic tests and simulated-time
    benchmarks: `clock()` returns the current simulated seconds and
    `advance(dt)` / `set(t)` move it. No wall-clock sleeps, ever."""

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t

    def set(self, t: float) -> float:
        self.t = float(t)
        return self.t


def bucket_ladder(min_bucket: int, max_batch: int) -> list[int]:
    """The half-octave device-batch size ladder (ascending)."""
    sizes = set()
    s = min_bucket
    while s <= max_batch:
        sizes.add(s)
        if s + s // 2 <= max_batch:
            sizes.add(s + s // 2)
        s *= 2
    sizes.add(max_batch)
    return sorted(sizes)


def chunk_plan(n: int, ladder: list[int]) -> list[int]:
    """Decompose n rows into ladder-sized device calls, minimizing
    (padded rows, number of calls) lexicographically — padded rows cost
    full device compute, an extra call only dispatch overhead.

    Returns sizes (descending) summing to >= n. E.g. ladder {8..128}:
    96 -> [96] (exact), 60 -> [48, 12] (exact), 30 -> [32] (2 pad, beats
    24+8's same padding with two calls), 11 -> [12] (1 pad). Exhaustive
    DP over n <= max_batch x a ~9-entry ladder: negligible host work.
    """
    assert n > 0
    # best[r] = (pad, calls, plan) to cover r remaining rows
    best: list[tuple[int, int, list[int]] | None] = [None] * (n + 1)
    best[0] = (0, 0, [])
    for r in range(1, n + 1):
        cand = None
        for s in ladder:
            if s >= r:  # one padded (or exact) chunk finishes it
                c = (s - r, 1, [s])
            elif best[r - s] is not None:
                pad, calls, plan = best[r - s]
                c = (pad, calls + 1, plan + [s])
            else:
                continue
            if cand is None or (c[0], c[1]) < (cand[0], cand[1]):
                cand = c
        best[r] = cand
    pad, calls, plan = best[n]
    return sorted(plan, reverse=True)


@dataclass
class Flush:
    """One dispatched bucket: a homogeneous-(base, k, exact) FIFO slice
    of the queue plus why it left the scheduler now."""

    base: float
    k: int
    exact: bool
    requests: list[EngineRequest]
    reason: str  # FULL | DEADLINE | DRAIN


@dataclass
class EnginePolicy:
    """Scheduling knobs (service-level defaults mirror v1 where shared).

    max_wait_ms bounds queue-wait: it is the deadline-flush trigger.
    watermark/overload are admission control — None disables it (the
    offline `serve` path never sheds).
    """

    max_batch: int = 128
    min_bucket: int = 8
    max_wait_ms: float = 2.0
    queue_capacity: int = 4096
    watermark: int | None = None   # queued-request depth that trips overload
    overload: str = SHED           # SHED (reject) | DEGRADE (exact-base lane)
    # failure recovery (DESIGN.md §9): a wave that raises is retried up to
    # max_retries times, then bisected (quarantine) with fresh budgets per
    # half; a *singleton* wave that exhausts its budget marks its request
    # FAILED — total device calls are bounded by (max_retries+1)*(2n-1).
    max_retries: int = 2
    retry_backoff_ms: float = 0.0  # exponential base; ManualClock advances
    # degraded serving (DESIGN.md §11): minimum acceptable index coverage
    # fraction for a wave's results. A wave collected below it first
    # triggers an inline recovery attempt of quarantined segments; if
    # coverage still cannot be met, its requests are marked FAILED with
    # the achieved coverage attached. 0.0 = serve at any coverage.
    min_coverage: float = 0.0

    def __post_init__(self):
        assert self.min_bucket >= 1 and self.max_batch >= self.min_bucket
        assert 0.0 <= self.min_coverage <= 1.0, self.min_coverage
        if self.overload not in (SHED, DEGRADE):
            raise ValueError(f"unknown overload policy {self.overload!r}")
        self.ladder = bucket_ladder(self.min_bucket, self.max_batch)


class BucketScheduler:
    """FIFO buckets keyed (base, k, exact) with full-or-deadline flush.

    The clock is any zero-arg callable returning seconds; the default is
    `time.perf_counter`. All flush decisions are made against it, so a
    `ManualClock` makes every deadline test deterministic.
    """

    def __init__(self, policy: EnginePolicy, clock=None):
        self.policy = policy
        self.clock = clock if clock is not None else time.perf_counter
        self._buckets: dict[tuple[float, int, bool], list[EngineRequest]] = {}
        self._depth = 0

    @property
    def depth(self) -> int:
        """Requests queued (admitted, not yet flushed)."""
        return self._depth

    def admit(self, req: EngineRequest) -> None:
        self._buckets.setdefault(req.group_key(), []).append(req)
        self._depth += 1

    def over_watermark(self) -> bool:
        wm = self.policy.watermark
        return wm is not None and self._depth >= wm

    def next_deadline(self) -> float | None:
        """Earliest queued deadline (the next time a poll could flush),
        or None when nothing is queued. Event-driven callers (the paced
        simulation in benchmarks/serving.py) advance their clock to this."""
        heads = [b[0].deadline_t for b in self._buckets.values() if b]
        return min(heads) if heads else None

    def _pop(self, key, n: int, reason: str, now: float) -> Flush:
        entries = self._buckets[key]
        taken, rest = entries[:n], entries[n:]
        if rest:
            self._buckets[key] = rest
        else:
            del self._buckets[key]
        self._depth -= len(taken)
        for r in taken:
            r.stage = FLUSHED
            r.flush_t = now
        base, k, exact = key
        return Flush(base=base, k=k, exact=exact, requests=taken,
                     reason=reason)

    def poll(self, now: float | None = None) -> list[Flush]:
        """Flush decisions as of `now`: every full bucket (max_batch FIFO
        rows each, repeatedly while over-full) and every bucket whose
        oldest request's deadline has expired."""
        now = self.clock() if now is None else now
        mb = self.policy.max_batch
        flushes = []
        for key in sorted(self._buckets):  # deterministic dispatch order
            while key in self._buckets and len(self._buckets[key]) >= mb:
                flushes.append(self._pop(key, mb, FULL, now))
            if key in self._buckets and \
                    self._buckets[key][0].deadline_t <= now:
                flushes.append(self._pop(key, mb, DEADLINE, now))
        return flushes

    def flush_all(self, now: float | None = None,
                  reason: str = DRAIN) -> list[Flush]:
        """Force-flush everything queued (end of stream / explicit drain)."""
        now = self.clock() if now is None else now
        mb = self.policy.max_batch
        flushes = []
        for key in sorted(self._buckets):
            while key in self._buckets:
                n = min(mb, len(self._buckets[key]))
                flushes.append(self._pop(
                    key, n, FULL if n == mb else reason, now))
        return flushes

    def requeue(self, requests: list[EngineRequest]) -> None:
        """Put flushed-but-unserved requests back at the FRONT of their
        buckets, preserving FIFO order (failure recovery)."""
        by_key: dict[tuple, list[EngineRequest]] = {}
        for r in requests:
            by_key.setdefault(r.group_key(), []).append(r)
        for key, reqs in by_key.items():
            self._buckets[key] = reqs + self._buckets.get(key, [])
            self._depth += len(reqs)
