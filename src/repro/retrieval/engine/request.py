"""Per-request state for the continuous-batching serving engine.

An `EngineRequest` wraps one ANNS-U-Lp query (`retrieval.service
.QueryRequest`) with everything the engine's scheduler and pipeline need
to track it through its life cycle (DESIGN.md §6):

    queued -> flushed -> searching -> verifying -> done
                 \\-> shed    (admission control, overload policy "shed")
                 \\-> failed  (retries exhausted after quarantine isolation;
                               `error` carries the final exception message)

Timestamps come from the engine's *injectable clock* (seconds, monotonic
by contract) — `arrival_t` at admission, `flush_t` when the scheduler
dispatches the request's bucket, `finish_t` when its wave's results
materialize on host. The deadline (`deadline_t = arrival_t + max_wait`)
is what drives deadline-triggered bucket flush: a partial bucket
dispatches the moment its *oldest* request's deadline expires, so tail
latency is bounded by max_wait + one wave of device time instead of by
"when does this bucket happen to fill".

Between the two pipeline stages the batched query tensor and the
candidate set stay device-resident (see `pipeline.Wave`); the request
object itself only ever holds host-side metadata.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# life-cycle stages (plain strings: cheap, printable, json-able)
QUEUED = "queued"
FLUSHED = "flushed"
SEARCHING = "searching"
VERIFYING = "verifying"
DONE = "done"
SHED = "shed"
FAILED = "failed"   # terminal: retry budget exhausted on an isolated wave


@dataclass
class EngineRequest:
    """One in-flight query and its scheduling metadata.

    `degraded=True` marks a request the overload policy short-circuited
    onto the exact-base fast lane (served under its base metric, skipping
    general-p verification): the response is approximate and the caller
    can tell from `stats["degraded"]`.
    """

    vector: np.ndarray          # (d,) f32 host copy
    p: float                    # the request's own metric (paper §1)
    k: int
    request_id: int
    base: float                 # base graph pick: 1.0 = G1, 2.0 = G2
    exact: bool                 # p == base: no verification needed
    arrival_t: float            # clock() at admission
    deadline_t: float           # arrival_t + max_wait (flush trigger)
    stage: str = QUEUED
    flush_t: float = field(default=0.0)
    finish_t: float = field(default=0.0)
    degraded: bool = False
    retries: int = 0            # device-call re-executions this request rode
    error: str | None = None    # final exception message when stage == FAILED

    @property
    def queue_wait_s(self) -> float:
        """Admission -> dispatch (what deadline flush bounds)."""
        return self.flush_t - self.arrival_t

    @property
    def compute_s(self) -> float:
        """Dispatch -> host materialization (device + pipeline residency)."""
        return self.finish_t - self.flush_t

    def group_key(self) -> tuple[float, int, bool]:
        """The scheduler's two-way-partition bucket key (DESIGN.md §6):
        base graph x k x exact-lane — never one bucket per distinct p."""
        return (self.base, self.k, self.exact)
