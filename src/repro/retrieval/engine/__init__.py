"""Continuous-batching serving engine (DESIGN.md §6).

`ServingEngine` is the latency-first replacement for the v1 synchronous
micro-batching scheduler (`UniversalVectorService.serve_v1`): requests
are admitted into (base, k, exact) buckets, buckets flush when FULL or
when their oldest request's DEADLINE expires (injectable clock — tests
and simulated-time benchmarks never sleep), flushes are cut into
exact-fit half-octave ladder waves, and waves flow through the two-stage
search/verify pipeline with a one-wave lookahead: wave N+1's base-graph
search is dispatched before wave N's verification is materialized.

Results are bitwise-identical to `serve_grouped` and `serve_v1` for the
same request set: every wave runs the same traced-p (verify lane) or
scalar-base (exact lane) programs, and per-row results are invariant to
batch composition (tests/test_mixed_p.py pins this).

The engine shares the service's stats dict (`default_stats` is the one
schema both write): Eq. 1 counters, per-base/per-p attribution, flush
reasons, shed/degraded counts, and per-request latency records that
separate queue-wait from device-compute and flag cold (first-compile)
program shapes.

Fault tolerance (DESIGN.md §9): every device interaction — stage A/B
dispatch and host collection — sits behind a fault boundary. A wave that
raises is retried up to `EnginePolicy.max_retries` times (optionally with
exponential backoff against the injectable clock), then *bisected*: each
half gets a fresh retry budget, so a single poison request is isolated in
O(log n) splits instead of failing its whole wave. A singleton wave that
exhausts its budget marks its request FAILED (terminal, with the
exception message) — total device calls are bounded by
(max_retries+1)·(2n−1), so there are no unbounded retries and no hangs.
A seeded `FaultInjector` can be threaded through the same boundary to
rehearse all of this deterministically; with no injector the boundary is
a single `is not None` check (zero overhead disabled). The engine itself
is a three-state machine — live → draining (after `close()`) and a
terminal failed state if the recovery machinery itself breaks — and
admission into a non-live engine raises `EngineClosed` rather than
silently queueing.
"""

from __future__ import annotations

import time
from collections import deque
from types import SimpleNamespace

import numpy as np

from repro.core.metrics import base_metric_for
from repro.index.health import QUARANTINED
from repro.retrieval.engine.faults import (
    SEGMENT_WILDCARD,
    FaultInjector,
    InjectedFault,
    InjectedSegmentFault,
    InjectedTimeout,
    segment_site,
)
from repro.retrieval.engine.pipeline import TwoStagePipeline, Wave, make_waves
from repro.retrieval.engine.request import FAILED as STAGE_FAILED
from repro.retrieval.engine.request import SHED as STAGE_SHED
from repro.retrieval.engine.request import EngineRequest
from repro.retrieval.engine.scheduler import (
    DEADLINE,
    DEGRADE,
    DRAIN,
    FULL,
    SHED,
    BucketScheduler,
    EnginePolicy,
    Flush,
    ManualClock,
    bucket_ladder,
    chunk_plan,
)

# engine lifecycle states (satellite: admissions are rejected — not
# silently queued — once the engine is no longer live)
LIVE = "live"
DRAINING = "draining"
ENGINE_FAILED = "failed"

__all__ = [
    "ServingEngine", "EnginePolicy", "EngineRequest", "BucketScheduler",
    "TwoStagePipeline", "Wave", "Flush", "ManualClock", "bucket_ladder",
    "chunk_plan", "make_waves", "default_stats",
    "FaultInjector", "InjectedFault", "InjectedTimeout",
    "InjectedSegmentFault", "segment_site", "EngineClosed",
    "PoisonedResultError", "CoverageError",
    "FULL", "DEADLINE", "DRAIN", "SHED", "DEGRADE",
    "LIVE", "DRAINING", "ENGINE_FAILED",
]


class EngineClosed(RuntimeError):
    """Admission attempted on an engine that is draining or failed."""


class PoisonedResultError(RuntimeError):
    """A wave's collected results tripped the NaN/inf poison guard. The
    offending segment has already been located (O(log S) bisection) and
    quarantined by the time this raises — the normal retry machinery then
    re-runs the wave at reduced coverage, so no poisoned id ever reaches
    a results dict."""


class CoverageError(RuntimeError):
    """A wave was collected below `EnginePolicy.min_coverage` but a
    background recovery re-admitted at least one segment — raised to send
    the wave back through retry at the improved coverage."""


def default_stats() -> dict:
    """The serving stats schema (shared by the engine and the v1 path)."""
    return {
        "queries": 0, "batches": 0, "inserts": 0, "compactions": 0,
        "n_b": 0.0, "n_p": 0.0,      # aggregate Eq. 1 counters
        # cross-segment phase attribution (DESIGN.md §3): probe = work
        # done without an inherited bound, spill = work under one. For
        # monolithic indexes / the independent policy, probe == total and
        # spill == 0; delta-tier scans join n_p but neither phase.
        "n_b_probe": 0.0, "n_b_spill": 0.0,
        "n_p_probe": 0.0, "n_p_spill": 0.0,
        # N_p-weighted scanned-dimension work (DESIGN.md §8): the
        # early-abandoning verify buckets report effective T_p as
        # dim_frac_w / n_p (1.0 = full-dimension scans everywhere)
        "dim_frac_w": 0.0,
        # N_p-weighted f32 rows gathered (DESIGN.md §10): the compressed
        # two-band path reports gathered-f32-bytes reduction as
        # n_p / f32_rows_w (1.0 = every scored candidate hit f32 HBM)
        "f32_rows_w": 0.0,
        "padded_rows": 0,            # bucket-padding rows executed
        "queue_peak": 0,             # high-water queue depth
        # engine scheduling outcomes
        "flushes": {FULL: 0, DEADLINE: 0, DRAIN: 0},
        "shed": 0,                   # admission control: rejected
        "degraded": 0,               # admission control: exact-base lane
        # fault tolerance (DESIGN.md §9)
        "faults": 0,                 # device-call exceptions caught
        "retries": 0,                # wave re-executions
        "quarantine_splits": 0,      # bisections isolating poison requests
        "failed": 0,                 # requests in terminal FAILED state
        # degraded serving (DESIGN.md §11)
        "coverage_w": 0.0,           # sum(coverage_frac * real rows) served
        "poison_detected": 0,        # result rows caught by the NaN guard
        "seg_quarantined": 0,        # segments quarantined by the engine
        "seg_recovered": 0,          # segments restored + re-admitted
        "min_coverage_failed": 0,    # requests FAILED for low coverage
        # attribution: one bucket per base graph and one per distinct
        # requested p, each with its own Eq. 1 split
        "per_base": {
            "G1": {"queries": 0, "batches": 0, "n_b": 0.0, "n_p": 0.0,
                   "dim_frac_w": 0.0, "f32_rows_w": 0.0},
            "G2": {"queries": 0, "batches": 0, "n_b": 0.0, "n_p": 0.0,
                   "dim_frac_w": 0.0, "f32_rows_w": 0.0},
        },
        "per_p": {},                 # "%g" % p -> {queries, n_b, n_p}
        # per-request latency; bounded so a long-running service cannot
        # grow it without limit (latency_summary reports over the window).
        # latency_ms holds total ms (back-compat); latency_records holds
        # (total_ms, queue_ms, compute_ms, cold) per request — the
        # attribution fix: queue-wait vs device-compute vs first-call
        # compile are separable.
        "latency_ms": deque(maxlen=10_000),
        "latency_records": deque(maxlen=10_000),
    }


class ServingEngine:
    """The continuous-batching loop: admit -> (poll-flush -> pipeline) ->
    collect, against an injectable clock.

    Drive it either offline (`serve(reqs)` = admit + drain) or
    incrementally (`admit` as requests arrive, `pump()` per tick to
    dispatch full/deadline flushes, `drain()` to finish the stream).
    `stats` may be a shared dict (the service passes its own) or None
    for a private one.
    """

    def __init__(self, index, policy: EnginePolicy | None = None,
                 clock=None, stats: dict | None = None,
                 fault_injector: FaultInjector | None = None):
        self.index = index
        self.policy = policy or EnginePolicy()
        self.clock = clock if clock is not None else time.perf_counter
        self.sched = BucketScheduler(self.policy, self.clock)
        self.pipeline = TwoStagePipeline(index)
        self.stats = stats if stats is not None else default_stats()
        # None = no injection and ZERO overhead: the device-call boundary
        # is one attribute `is not None` test (the acceptance criterion)
        self.fault_injector = fault_injector
        self.state = LIVE
        self._inflight: Wave | None = None     # dispatched, not collected
        self._results: dict[int, tuple] = {}
        self._failures: dict[int, str] = {}    # request_id -> error message
        self._seen_shapes: set[tuple] = set()  # cold-program detection

    # -- admission -----------------------------------------------------------

    @property
    def pending(self) -> int:
        """Requests inside the engine: queued + in the pipeline."""
        inflight = self._inflight.n_real if self._inflight is not None else 0
        return self.sched.depth + inflight

    def _check_live(self) -> None:
        if self.state != LIVE:
            raise EngineClosed(
                f"engine is {self.state}: not accepting new requests")

    def make_request(self, r, now: float | None = None) -> EngineRequest:
        """Wrap a service QueryRequest with engine scheduling metadata."""
        self._check_live()
        now = self.clock() if now is None else now
        p = float(r.p)
        base = base_metric_for(p, self.index.params.cutoff)
        return EngineRequest(
            vector=np.asarray(r.vector, np.float32).reshape(-1),
            p=p, k=int(r.k),
            request_id=r.request_id, base=float(base), exact=p == base,
            arrival_t=now,
            deadline_t=now + self.policy.max_wait_ms / 1e3,
        )

    def admit(self, requests: list[EngineRequest]) -> list[EngineRequest]:
        """Admission control + enqueue. Returns the admitted subset —
        above the watermark the overload policy sheds the request (no
        response, counted) or degrades it onto the exact-base fast lane
        (approximate base-metric response, counted). Raises EngineClosed
        once the engine has left the live state (close() or an engine
        failure) — a request must never queue into an engine that will
        not serve it."""
        self._check_live()
        admitted = []
        for r in requests:
            if self.sched.over_watermark():
                if self.policy.overload == SHED:
                    r.stage = STAGE_SHED
                    self.stats["shed"] += 1
                    continue
                if not r.exact:  # DEGRADE: short-circuit past verification
                    r.exact = True
                    r.degraded = True
                    self.stats["degraded"] += 1
            self.sched.admit(r)
            admitted.append(r)
        self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                       self.sched.depth)
        return admitted

    def submit(self, r, now: float | None = None) -> EngineRequest | None:
        """Admit ONE service-level request (wrap + admission control).
        Returns the EngineRequest, or None if the overload policy shed
        it; raises EngineClosed when the engine is not live."""
        admitted = self.admit([self.make_request(r, now=now)])
        return admitted[0] if admitted else None

    # -- the serving loop ----------------------------------------------------

    def pump(self, now: float | None = None) -> None:
        """Dispatch every flush that is due (full buckets + expired
        deadlines) through the pipeline, then finish whatever is left in
        flight: the one-wave lookahead only helps while another wave is
        ready to overlap with, and holding a dispatched wave for a
        *future* arrival would charge that wave the inter-arrival gap —
        exactly what a latency-first engine must not do."""
        self._maintain()
        flushes = self.sched.poll(now)
        while flushes:
            self._run(flushes)
            flushes = self.sched.poll(now)
        self._settle()

    def drain(self, now: float | None = None) -> dict[int, tuple]:
        """Flush everything queued, finish the pipeline, and hand back
        all results accumulated since the last drain."""
        self._maintain()
        self._run(self.sched.poll(now))          # due flushes keep their
        self._run(self.sched.flush_all(now))     # full/deadline reasons
        self._settle()
        out, self._results = self._results, {}
        return out

    def serve(self, requests: list[EngineRequest]) -> dict[int, tuple]:
        self.admit(requests)
        return self.drain()

    def close(self, now: float | None = None) -> dict[int, tuple]:
        """Stop admissions and finish everything queued/in-flight.

        The engine enters DRAINING — terminal: make_request/admit/submit
        raise EngineClosed from here on (an engine failure leaves it in
        ENGINE_FAILED, with the same admission behavior). Returns the
        final batch of results."""
        if self.state == LIVE:
            self.state = DRAINING
        return self.drain(now)

    def take_results(self) -> dict[int, tuple]:
        """Hand over results collected so far without flushing anything —
        the incremental (admit/pump) driving mode's harvest step."""
        out, self._results = self._results, {}
        return out

    def take_failures(self) -> dict[int, str]:
        """Hand over terminally FAILED requests (request_id -> the final
        exception message) accumulated since the last call. A request is
        either in a results dict, a failures dict, or was shed — the
        accounting invariant the chaos tests pin."""
        out, self._failures = self._failures, {}
        return out

    @property
    def failures(self) -> dict[int, str]:
        """Read-only view of not-yet-harvested terminal failures."""
        return dict(self._failures)

    def warmup(self, k: int = 10,
               ps: tuple[float, ...] = (0.8, 1.8)) -> int:
        """Boot-time pre-compilation: serve one synthetic batch of every
        ladder size for each lane the given p values map to, so steady
        traffic never rides a compiling program. The ladder is a fixed
        finite set — this is the structural advantage over
        data-dependent-shape scheduling, made explicit as a one-time
        step. Verify lanes share one traced-p program family per (base,
        k, size), so one verify p per base covers *any* metric mix;
        exact-base p values compile per scalar p and should be listed
        explicitly if the traffic is known to contain them. Served
        counters/latency stats are left untouched (the shapes do land in
        the cold-detection set). Returns device batches executed."""
        zero = np.zeros(self.index.dim, np.float32)
        keep_stats, self.stats = self.stats, default_stats()
        keep_results, self._results = self._results, {}
        # warmup is a compile pass, not traffic — never inject faults into
        # it (and never burn the injector's deterministic draw sequence)
        keep_inj, self.fault_injector = self.fault_injector, None
        batches = 0
        try:
            for p in dict.fromkeys(float(p) for p in ps):
                for size in self.policy.ladder:
                    for i in range(size):
                        r = SimpleNamespace(vector=zero, p=p, k=k,
                                            request_id=-(i + 1))
                        self.sched.admit(self.make_request(r))
                    self.drain()
                    batches += 1
        finally:
            self.stats = keep_stats
            self._results = keep_results
            self.fault_injector = keep_inj
        return batches

    def _run(self, flushes: list[Flush]) -> None:
        work: deque[Wave] = deque()
        for fl in flushes:
            self.stats["flushes"][fl.reason] += 1
            work.extend(make_waves(fl, self.policy.ladder))
        self._run_waves(work)

    def _run_waves(self, work: deque[Wave]) -> None:
        """Drive the wave deque to empty. Per-wave device failures are
        recovered *inside* `_advance` (retry/bisect/FAILED — they never
        surface here); an exception escaping it means the recovery
        machinery itself broke, so request accounting can no longer be
        trusted: the engine enters its terminal failed state (admissions
        start raising EngineClosed), unserved requests are requeued for
        inspection, and the error propagates with partial_results."""
        while work:
            wave = work.popleft()
            try:
                self._advance(wave, work)
            except Exception as e:
                self.state = ENGINE_FAILED
                unserved = list(wave.requests)
                unserved += [r for w in work for r in w.requests]
                if self._inflight is not None:
                    unserved = list(self._inflight.requests) + unserved
                    self._inflight = None
                self.sched.requeue(unserved)
                partial = dict(getattr(e, "partial_results", {}))
                partial.update(self._results)
                e.partial_results = partial
                self._results = {}
                raise

    def _inject(self, site: str) -> None:
        if self.fault_injector is not None:
            self.fault_injector.check(site)

    def _inject_segments(self) -> None:
        """Draw the per-segment fault sites for every currently-alive
        segment, in segment order. Strictly opt-in (faults.py contract):
        a no-op unless an injector is configured with a `sites` filter
        that names segment sites AND the index carries a health tracker —
        so classic three-site chaos schedules never shift."""
        inj = self.fault_injector
        if inj is None or inj.sites is None:
            return
        if not any(s == SEGMENT_WILDCARD or s.startswith("segment:")
                   for s in inj.sites):
            return
        health = getattr(self.index, "health", None)
        if health is None:
            return
        for seg in health.alive():
            inj.check(segment_site(seg))

    # rows per localization probe: the poisoned rows' queries tiled to one
    # fixed small batch shape, so every bisection probe compiles once and
    # costs a fraction of a full wave re-run
    PROBE_BATCH = 8

    def _locate_poisoned_segment(self, wave: Wave,
                                 pois: np.ndarray) -> int | None:
        """Attribute a poisoned wave to ONE alive segment by bisection:
        re-run stage A over half the alive set and read its poison flags,
        keeping whichever half still trips the guard — at most
        ceil(log2 S) device probes per event (the detection bound the
        chaos tests pin). Returns None without any probing when the wave
        was dispatched under a *stale* serving-set generation (its
        poisoned segment is already quarantined — the one-wave lookahead
        makes this ordinary): there is nothing new to quarantine, the
        retry alone fixes it, and bisecting the now-clean set would
        convict an innocent segment. (If a concurrent *readmission* bumped
        the generation instead, the retry re-detects under the current
        generation and bisection proceeds then.) When the generation
        matches, the wave itself is the full-set probe — it searched
        exactly the current alive set and tripped the guard — so
        bisection starts immediately.

        Probes re-use the queries of the rows that tripped the guard
        (`pois`), tiled to the fixed PROBE_BATCH shape: those rows
        provably surface the poison, and a subset search only *lowers*
        the competition a non-finite candidate must beat to be flagged."""
        health = self.index.health
        if wave.health_gen != health.generation:
            return None
        alive = sorted(health.alive())
        if not alive:
            return None
        bad = np.flatnonzero(np.asarray(pois))
        reps = int(np.ceil(self.PROBE_BATCH / len(bad)))
        q = np.tile(wave.q[bad], (reps, 1))[:self.PROBE_BATCH]

        def poisoned(subset: list[int]) -> bool:
            cands = self.index.search_stage_candidates(
                q, wave.base, k=wave.k, alive=subset)
            return bool(np.asarray(cands.poisoned).any())

        while len(alive) > 1:
            left = alive[:len(alive) // 2]
            # the full set is known-poisoned, so a clean left half puts
            # the poison in the right half — no confirmation probe needed
            alive = left if poisoned(left) else alive[len(alive) // 2:]
        return alive[0]

    def _maintain(self) -> int:
        """Background recovery of quarantined segments (DESIGN.md §11):
        for each quarantined segment, re-materialize its rows from the
        latest *durable* snapshot (checksums re-verified by the manifest
        read inside restore_segment), then gate re-admission behind the
        health policy's canary-probe streak — a segment that cannot be
        restored or fails a probe goes straight back to quarantine.
        Returns the number of segments re-admitted. No-op (returns 0)
        for monolithic indexes and for indexes without a durable home
        (no snapshot to restore from)."""
        health = getattr(self.index, "health", None)
        if health is None:
            return 0
        quarantined = health.quarantined()
        if not quarantined:
            return 0
        directory = getattr(self.index, "directory", None)
        if directory is None:
            return 0
        from repro.index.persist import restore_segment
        st = self.stats
        recovered = 0
        for seg in quarantined:
            if not restore_segment(self.index, seg, directory):
                continue                    # no durable copy of this segment
            health.begin_recovery(seg)
            ok = True
            for i in range(health.policy.probe_successes):
                ok = self.index.canary_probe(seg, seed=i)
                if not ok:
                    break
            if ok and health.probe_passed(seg):
                health.readmit(seg)
                st["seg_recovered"] += 1
                recovered += 1
            else:
                health.quarantine(seg)      # canary failed: stay out
        return recovered

    def _advance(self, wave: Wave, work: deque[Wave]) -> None:
        """One pipeline step: dispatch A(N), collect B(N-1), dispatch
        B(N). The collect sits *between* the dispatches so wave N's base
        search is already enqueued while wave N-1's verify materializes.

        Each of the three device interactions is its own fault boundary:
        a stage A/B failure recovers *this* wave (the predecessor is
        unaffected — on an A failure it simply stays in flight); a
        collect failure recovers the *predecessor* and this wave's stage
        B still dispatches. Recovery re-executes from stage A — dispatches
        are pure compute, so re-running them is always safe.
        """
        prev, self._inflight = self._inflight, None
        try:
            self._inject_segments()
            self._inject("search")
            health = getattr(self.index, "health", None)
            # pin the serving-set generation the wave searches under: a
            # poison flag collected from a *stale* generation needs no
            # bisection (its culprit is already quarantined — retry fixes
            # it), and from the *current* one the wave itself is the
            # full-set probe
            wave.health_gen = None if health is None else health.generation
            self.pipeline.dispatch_search(wave)
        except Exception as e:
            self._inflight = prev          # predecessor is untouched
            self._recover(wave, e, work)
            return
        if prev is not None:
            try:
                self._inject("collect")
                self._collect(prev)
            except Exception as e:
                self._recover(prev, e, work)
        try:
            self._inject("verify")
            self.pipeline.dispatch_finish(wave)
        except Exception as e:
            self._recover(wave, e, work)
            return
        self._inflight = wave

    def _settle(self) -> None:
        """Collect the in-flight wave (and any recovery work its failure
        spawns) until nothing is left in the pipeline."""
        while self._inflight is not None:
            wave, self._inflight = self._inflight, None
            work: deque[Wave] = deque()
            try:
                self._inject("collect")
                self._collect(wave)
            except Exception as e:
                self._recover(wave, e, work)
            if work:
                self._run_waves(work)

    def _recover(self, wave: Wave, exc: Exception, work: deque[Wave]):
        """Bounded failure recovery for one wave (DESIGN.md §9).

        Retry the wave whole up to max_retries times (front of the work
        deque, optional exponential backoff). A wave that exhausts its
        budget and holds >1 request is bisected — each half a fresh wave
        with a fresh budget, so a poison request is isolated in O(log n)
        splits while its healthy wave-mates still get served. A singleton
        that exhausts its budget is terminally FAILED with the exception
        message. Total device calls per n-request flush are bounded by
        (max_retries+1)·(2n−1): no unbounded retries, ever.
        """
        st = self.stats
        st["faults"] += 1
        # segment-attributable fault: feed the health tracker's failure
        # EWMA before retrying — enough consecutive hits quarantine the
        # segment, and the retried wave then runs with it masked out
        # (reduced coverage) instead of failing requests (DESIGN.md §11).
        health = getattr(self.index, "health", None)
        if isinstance(exc, InjectedSegmentFault) and health is not None \
                and 0 <= exc.segment < health.num_segments:
            was = health.state(exc.segment)
            health.record_failure(exc.segment)
            if was != QUARANTINED and health.state(exc.segment) == QUARANTINED:
                st["seg_quarantined"] += 1
        wave.cands = None    # drop device buffers; re-execute from stage A
        wave.result = None
        if wave.attempt < self.policy.max_retries:
            wave.attempt += 1
            st["retries"] += 1
            for r in wave.requests:
                r.retries += 1
            self._backoff(wave.attempt)
            work.appendleft(wave)
            return
        if wave.n_real > 1:
            st["quarantine_splits"] += 1
            mid = (wave.n_real + 1) // 2
            subs: list[Wave] = []
            for part in (wave.requests[:mid], wave.requests[mid:]):
                fl = Flush(base=wave.base, k=wave.k, exact=wave.exact,
                           requests=part, reason=wave.reason)
                subs.extend(make_waves(fl, self.policy.ladder))
            for w in reversed(subs):
                work.appendleft(w)
            return
        r, = wave.requests   # quarantine isolated it down to one request
        r.stage = STAGE_FAILED
        r.error = f"{type(exc).__name__}: {exc}"
        st["failed"] += 1
        self._failures[r.request_id] = r.error

    def _backoff(self, attempt: int) -> None:
        ms = self.policy.retry_backoff_ms
        if ms <= 0:
            return
        dt = ms * (2 ** (attempt - 1)) / 1e3
        advance = getattr(self.clock, "advance", None)
        if advance is not None:  # ManualClock: simulated time, no sleeping
            advance(dt)
        else:
            time.sleep(dt)

    # -- collection + stats --------------------------------------------------

    def _collect(self, wave: Wave) -> None:
        ids, dists, n_b, n_p, frac, f32, phases, cov, pois = \
            self.pipeline.collect(wave)
        st = self.stats
        health = getattr(self.index, "health", None)
        if pois.any():
            # NaN/inf guard tripped (DESIGN.md §11): locate the poisoned
            # segment, quarantine it, and raise into the retry machinery —
            # the re-run serves at reduced coverage and nothing from this
            # collection is ever recorded as a result.
            st["poison_detected"] += int(pois.sum())
            seg = None
            if health is not None:
                seg = self._locate_poisoned_segment(wave, pois)
                if seg is not None:
                    was = health.state(seg)
                    health.quarantine(seg)
                    if was != QUARANTINED:
                        st["seg_quarantined"] += 1
            raise PoisonedResultError(
                f"{int(pois.sum())} poisoned result rows"
                f" (quarantined segment {seg})")
        if wave.n_real and cov < self.policy.min_coverage:
            # below the coverage floor: try to win segments back first;
            # any re-admission earns the wave a retry at the improved
            # coverage, otherwise its requests FAIL with the achieved
            # coverage attached (DESIGN.md §11).
            if self._maintain() > 0:
                raise CoverageError(
                    f"coverage {cov:.4f} <"
                    f" min_coverage {self.policy.min_coverage:.4f};"
                    " segments recovered, retrying")
            for r in wave.requests:
                r.stage = STAGE_FAILED
                r.error = (f"coverage {cov:.4f} <"
                           f" min_coverage {self.policy.min_coverage:.4f}")
                self._failures[r.request_id] = r.error
            st["failed"] += wave.n_real
            st["min_coverage_failed"] += wave.n_real
            return
        if health is not None:
            for seg in health.alive():
                health.record_success(seg)
        done = self.clock()
        shape_key = (wave.base, wave.k, wave.exact, wave.size)
        cold = shape_key not in self._seen_shapes
        self._seen_shapes.add(shape_key)
        frac_w = float((frac * n_p).sum())
        f32_w = float((f32 * n_p).sum())
        nb_pr, nb_sp, np_pr, np_sp = phases
        st["queries"] += wave.n_real
        st["coverage_w"] += cov * wave.n_real
        st["batches"] += 1
        st["padded_rows"] += wave.padded_rows
        st["n_b"] += float(n_b.sum())
        st["n_p"] += float(n_p.sum())
        st["n_b_probe"] += float(nb_pr.sum())
        st["n_b_spill"] += float(nb_sp.sum())
        st["n_p_probe"] += float(np_pr.sum())
        st["n_p_spill"] += float(np_sp.sum())
        st["dim_frac_w"] += frac_w
        st["f32_rows_w"] += f32_w
        pb = st["per_base"]["G1" if wave.base == 1.0 else "G2"]
        pb["queries"] += wave.n_real
        pb["batches"] += 1
        pb["n_b"] += float(n_b.sum())
        pb["n_p"] += float(n_p.sum())
        pb["dim_frac_w"] += frac_w
        pb["f32_rows_w"] += f32_w
        for i, r in enumerate(wave.requests):
            r.finish_t = done
            self._results[r.request_id] = (ids[i], dists[i])
            pp = st["per_p"].setdefault(
                "%g" % r.p, {"queries": 0, "n_b": 0.0, "n_p": 0.0})
            pp["queries"] += 1
            pp["n_b"] += float(n_b[i])
            pp["n_p"] += float(n_p[i])
            total = (done - r.arrival_t) * 1e3
            queue = max(r.flush_t - r.arrival_t, 0.0) * 1e3
            compute = max(done - r.flush_t, 0.0) * 1e3
            st["latency_ms"].append(total)
            st["latency_records"].append((total, queue, compute, cold))
