"""Deterministic fault injection for the serving engine (DESIGN.md §9, §11).

Production accelerator calls fail: transient XLA/driver errors, preempted
devices, collective timeouts. The engine's recovery machinery (bounded
retry, bisection quarantine, terminal FAILED marking) has to be exercised
against *reproducible* failure schedules, so the injector is a seeded PRNG
drawn once per guarded site — the same seed and the same wave schedule
produce the same faults, which is what lets the chaos CI lane assert exact
terminal states across runs.

The seeded-schedule contract, precisely:

  * One uniform draw is consumed per *enabled* call to `check`, in call
    order. Same seed + same sequence of enabled `check` calls => the same
    fault schedule, independent of wall clock, host, or jax version.
  * A `check` on a site excluded by the `sites` filter consumes NO draw —
    filtering a site out never perturbs the schedule the remaining sites
    see. This is what lets a chaos run target, say, a search-only
    schedule (`sites=("search",)`) and still reproduce the exact faults
    of a full-site run restricted to its search draws.
  * `reset()` rewinds the PRNG to the seed state and zeroes every
    injected counter (total and per-site), giving a byte-identical
    replay of the schedule from the top.

The engine consults the injector only at its device-call boundary
(`ServingEngine._advance`), guarded by a single `is not None` check —
with no injector configured the happy path carries zero overhead (the
acceptance criterion: fault tolerance compiled out when disabled).

Sites (the engine's three device interactions, plus per-segment sites):

    "search"      — before stage A dispatch (base-graph candidate gen)
    "verify"      — before stage B dispatch (general-p verification)
    "collect"     — before host materialization of a wave's results
    "segment:<i>" — a fault attributable to frozen segment i of a
                    sharded index (DESIGN.md §11). Segment sites are
                    *opt-in*: the engine only draws them when the
                    `sites` filter names them (exactly, or via the
                    "segment" wildcard entry), so adding segment chaos
                    never shifts the classic three-site schedules.

`InjectedTimeout` models a stuck device call (distinct type so tests can
assert the retry path is exception-type agnostic); `InjectedSegmentFault`
carries the segment it hit so the engine can feed the health tracker's
failure EWMA. All derive from `InjectedFault`, and the engine treats
*any* exception from a device call identically — real faults get the
same bounded recovery as injected ones.
"""

from __future__ import annotations

import numpy as np

SITES = ("search", "verify", "collect")

# `sites` filter entry that enables every per-segment site at once
SEGMENT_WILDCARD = "segment"


def segment_site(seg: int) -> str:
    """The per-segment fault-site name for frozen segment `seg`."""
    return f"segment:{int(seg)}"


class InjectedFault(RuntimeError):
    """A simulated transient device-call failure."""


class InjectedTimeout(InjectedFault):
    """A simulated stuck/timed-out device call."""


class InjectedSegmentFault(InjectedFault):
    """A simulated fault attributable to one frozen segment."""

    def __init__(self, msg: str, segment: int):
        super().__init__(msg)
        self.segment = int(segment)


def poison_segment(index, seg: int) -> np.ndarray:
    """NaN-poison every row of frozen segment `seg`, everywhere the query
    path can gather it: the host mirror `_X_host`, the device verify copy
    `X`, the stacked per-segment `segments.X`, and the per-graph data
    arrays a later restack would read. Models silent row corruption (bad
    DMA, a flipped HBM page) rather than a failed call — nothing raises;
    the index's query-time NaN/inf guard (DESIGN.md §11) is what must
    notice. Accepts a DurableIndex or a bare ShardedUHNSW; returns the
    poisoned segment's global ids (the set no result may ever contain).
    """
    import jax.numpy as jnp

    index = getattr(index, "index", index)  # unwrap DurableIndex
    gids = np.asarray(index.segments.global_ids[seg], dtype=np.int64)
    # copy-on-write: _X_host may alias the caller's dataset array (build
    # avoids a copy) — corrupt only the index's view, never the dataset
    index._X_host = np.array(index._X_host, dtype=np.float32)
    index._X_host[gids] = np.nan
    index.X = jnp.asarray(index._X_host)
    segs = index.segments
    segs.X = segs.X.at[seg, : len(gids)].set(jnp.nan)
    bad = np.full_like(segs.graphs1[seg].data, np.nan)
    segs.graphs1[seg].data = bad
    segs.graphs2[seg].data = bad
    index._band = None        # caches quantized the clean rows
    index._scan_cache = None
    return gids


class FaultInjector:
    """Seeded Bernoulli fault source, one draw per enabled call site.

    rate: probability a guarded call raises InjectedFault.
    timeout_rate: additional probability it raises InjectedTimeout.
    sites: restrict injection to a site subset (None = the three classic
      SITES). Entries may be classic site names, explicit per-segment
      sites ("segment:3"), or the "segment" wildcard enabling all
      per-segment sites. Per-segment sites fire only when named here —
      see the module docstring for the full seeded-schedule contract
      (enabled calls consume draws in call order; filtered calls consume
      nothing; `reset()` replays the schedule exactly and clears the
      `injected` / `injected_by_site` counters).
    """

    def __init__(self, rate: float = 0.1, timeout_rate: float = 0.0,
                 seed: int = 0, sites: tuple[str, ...] | None = None):
        assert 0.0 <= rate + timeout_rate <= 1.0, (rate, timeout_rate)
        if sites is not None:
            unknown = {s for s in sites
                       if s not in SITES and s != SEGMENT_WILDCARD
                       and not s.startswith("segment:")}
            assert not unknown, f"unknown fault sites {sorted(unknown)}"
        self.rate = float(rate)
        self.timeout_rate = float(timeout_rate)
        self.seed = int(seed)
        self.sites = tuple(sites) if sites is not None else None
        self.injected = 0
        self.injected_by_site: dict[str, int] = {}
        self._rng = np.random.default_rng(self.seed)

    def reset(self) -> None:
        """Rewind to the seed state (fresh deterministic schedule) and
        zero the injected counters, total and per-site."""
        self._rng = np.random.default_rng(self.seed)
        self.injected = 0
        self.injected_by_site = {}

    def enabled(self, site: str) -> bool:
        """Whether `check(site)` would consume a draw. Segment sites are
        opt-in; classic sites default on (module docstring)."""
        if site.startswith("segment:"):
            return self.sites is not None and (
                site in self.sites or SEGMENT_WILDCARD in self.sites)
        return self.sites is None or site in self.sites

    def _record(self, site: str) -> int:
        self.injected += 1
        self.injected_by_site[site] = self.injected_by_site.get(site, 0) + 1
        return self.injected

    def check(self, site: str) -> None:
        """Raise iff this draw lands inside the configured fault mass.
        Disabled sites consume no draw (seeded-schedule contract)."""
        if not self.enabled(site):
            return
        u = self._rng.random()
        if u < self.rate:
            n = self._record(site)
            if site.startswith("segment:"):
                raise InjectedSegmentFault(
                    f"injected segment fault at {site} (#{n})",
                    segment=int(site.split(":", 1)[1]))
            raise InjectedFault(
                f"injected transient fault at {site} (#{n})")
        if u < self.rate + self.timeout_rate:
            n = self._record(site)
            raise InjectedTimeout(f"injected timeout at {site} (#{n})")
