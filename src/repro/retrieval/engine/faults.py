"""Deterministic fault injection for the serving engine (DESIGN.md §9).

Production accelerator calls fail: transient XLA/driver errors, preempted
devices, collective timeouts. The engine's recovery machinery (bounded
retry, bisection quarantine, terminal FAILED marking) has to be exercised
against *reproducible* failure schedules, so the injector is a seeded PRNG
drawn once per guarded site — the same seed and the same wave schedule
produce the same faults, which is what lets the chaos CI lane assert exact
terminal states across runs.

The engine consults the injector only at its device-call boundary
(`ServingEngine._advance`), guarded by a single `is not None` check —
with no injector configured the happy path carries zero overhead (the
acceptance criterion: fault tolerance compiled out when disabled).

Sites (the engine's three device interactions):

    "search"  — before stage A dispatch (base-graph candidate generation)
    "verify"  — before stage B dispatch (general-p verification)
    "collect" — before host materialization of a wave's results

`InjectedTimeout` models a stuck device call (distinct type so tests can
assert the retry path is exception-type agnostic); both derive from
`InjectedFault`, and the engine treats *any* exception from a device call
identically — real faults get the same bounded recovery as injected ones.
"""

from __future__ import annotations

import numpy as np

SITES = ("search", "verify", "collect")


class InjectedFault(RuntimeError):
    """A simulated transient device-call failure."""


class InjectedTimeout(InjectedFault):
    """A simulated stuck/timed-out device call."""


class FaultInjector:
    """Seeded Bernoulli fault source, one draw per guarded call site.

    rate: probability a guarded call raises InjectedFault.
    timeout_rate: additional probability it raises InjectedTimeout.
    sites: restrict injection to a subset of SITES (None = all).
    """

    def __init__(self, rate: float = 0.1, timeout_rate: float = 0.0,
                 seed: int = 0, sites: tuple[str, ...] | None = None):
        assert 0.0 <= rate + timeout_rate <= 1.0, (rate, timeout_rate)
        if sites is not None:
            unknown = set(sites) - set(SITES)
            assert not unknown, f"unknown fault sites {sorted(unknown)}"
        self.rate = float(rate)
        self.timeout_rate = float(timeout_rate)
        self.seed = int(seed)
        self.sites = tuple(sites) if sites is not None else None
        self.injected = 0
        self._rng = np.random.default_rng(self.seed)

    def reset(self) -> None:
        """Rewind to the seed state (fresh deterministic schedule)."""
        self._rng = np.random.default_rng(self.seed)
        self.injected = 0

    def check(self, site: str) -> None:
        """Raise iff this draw lands inside the configured fault mass."""
        if self.sites is not None and site not in self.sites:
            return
        u = self._rng.random()
        if u < self.rate:
            self.injected += 1
            raise InjectedFault(
                f"injected transient fault at {site} (#{self.injected})")
        if u < self.rate + self.timeout_rate:
            self.injected += 1
            raise InjectedTimeout(
                f"injected timeout at {site} (#{self.injected})")
