"""Two-stage search/verify pipeline over the staged index API.

The index (UHNSW / ShardedUHNSW) exposes the query path as two device
stages (DESIGN.md §6):

    search_stage_candidates(Q, base_p)      -> CandidateSet   (stage A)
    search_stage_finish(Q, cands, p, k)     -> ids/dists/stats (stage B)

Both stages are *async dispatches* under JAX: they enqueue device work
and return device arrays without blocking. The pipeline exploits that by
dispatching wave N+1's stage A before materializing wave N's stage B —
the dispatch order is

    A1, B1, A2, <collect B1>, B2, A3, <collect B2>, B3, ...

so on an accelerator the next wave's base-graph beam search overlaps the
previous wave's general-p verification; the only blocking point is the
`np.asarray` collection of a wave whose successor is already in flight.
`search` composes exactly these two stage methods, so pipelined results
are bitwise-identical to the fused call — and batch-composition
invariance (tests/test_mixed_p.py) makes them bitwise-identical to
`serve_grouped` regardless of how the scheduler chunked the stream.

A `Wave` is one device-call unit: a ladder-sized, padded, homogeneous
(base, k, exact) slice of a scheduler flush. Its query tensor and
candidate set stay device-resident between the stages.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.retrieval.engine.request import (
    DONE,
    SEARCHING,
    VERIFYING,
    EngineRequest,
)
from repro.retrieval.engine.scheduler import Flush, chunk_plan


@dataclass
class Wave:
    """One ladder-sized device batch flowing through the two stages."""

    base: float
    k: int
    exact: bool
    reason: str                      # the flush reason that released it
    requests: list[EngineRequest]    # n_real entries
    size: int                        # padded device batch size (ladder)
    q: np.ndarray                    # (size, d) f32, rows >= n_real padded
    p_vec: np.ndarray | None         # (size,) f32 for the verify lane
    cands: object = None             # CandidateSet (device) after stage A
    result: tuple | None = None      # (ids, dists, stats) after stage B
    attempt: int = 0                 # failed executions so far (retry budget)
    health_gen: int | None = None    # health generation at stage-A dispatch

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def padded_rows(self) -> int:
        return self.size - self.n_real


def make_waves(flush: Flush, ladder: list[int]) -> list[Wave]:
    """Cut one flush into exact-fit ladder waves (greedy largest-first).

    Padding rows replicate row 0 of their wave (same base graph, any p is
    valid there) and are sliced off before results or stats are read —
    identical to the v1 scheduler's padding contract.
    """
    reqs = flush.requests
    waves = []
    start = 0
    for size in chunk_plan(len(reqs), ladder):
        chunk = reqs[start:start + min(size, len(reqs) - start)]
        start += len(chunk)
        q = np.stack([np.asarray(r.vector, np.float32).reshape(-1)
                      for r in chunk])
        if size > len(chunk):
            q = np.concatenate(
                [q, np.repeat(q[:1], size - len(chunk), axis=0)])
        p_vec = None
        if not flush.exact:
            p_vec = np.array([float(r.p) for r in chunk], np.float32)
            if size > len(chunk):
                p_vec = np.concatenate(
                    [p_vec, np.repeat(p_vec[:1], size - len(chunk))])
        waves.append(Wave(base=flush.base, k=flush.k, exact=flush.exact,
                          reason=flush.reason, requests=chunk, size=size,
                          q=q, p_vec=p_vec))
    return waves


@dataclass
class TwoStagePipeline:
    """Dispatch/collect the two index stages for a stream of waves.

    The pipeline itself is stateless about ordering — the engine owns the
    one-wave lookahead (`ServingEngine._inflight`) and the failure
    recovery; this class just knows how to run one wave's stages and
    materialize its results.
    """

    index: object  # UHNSW | ShardedUHNSW (any object with the stage API)

    def dispatch_search(self, wave: Wave) -> None:
        """Stage A: async-dispatch base-graph candidate generation."""
        wave.cands = self.index.search_stage_candidates(wave.q, wave.base,
                                                        k=wave.k)
        for r in wave.requests:
            r.stage = SEARCHING

    def dispatch_finish(self, wave: Wave) -> None:
        """Stage B: async-dispatch verification (or the exact-base skip).

        The exact lane passes the scalar base metric (the skip path: no
        verification program at all); the verify lane passes the per-row
        p vector — the same traced-p program `serve_grouped` runs, which
        is what makes engine results bitwise-equal to the baselines.
        """
        p_arg = wave.base if wave.exact else wave.p_vec
        wave.result = self.index.search_stage_finish(
            wave.q, wave.cands, p_arg, wave.k)
        wave.cands = None  # device buffers free as soon as B consumes them
        for r in wave.requests:
            r.stage = VERIFYING

    def collect(self, wave: Wave):
        """Materialize one wave on host (the pipeline's only blocking
        point). Returns (ids, dists, n_b, n_p, frac, f32, phases, cov,
        pois) sliced to real rows; `f32` is the per-row f32-rows-gathered
        fraction (DESIGN.md §10 — 1.0 off the compressed two-band path);
        phases is the per-phase (n_b_probe, n_b_spill, n_p_probe,
        n_p_spill) attribution from the sharded two-phase search (probe =
        everything, spill = 0 for monolithic indexes and the independent
        policy); `cov` is the exact alive-coverage fraction the wave was
        served at (1.0 for monolithic indexes) and `pois` the per-row
        NaN/inf poison flags from the sharded query-time guard
        (DESIGN.md §11 — all-False for monolithic indexes).
        """
        ids, dists, st = wave.result
        n = wave.n_real

        def rows(x):
            x = np.asarray(x, dtype=np.float64)
            return x[:n] if x.ndim else np.full(n, float(x))

        ids = np.asarray(ids)[:n]
        dists = np.asarray(dists)[:n]
        n_b = rows(st.n_b)
        n_p = rows(st.n_p)
        frac = rows(st.n_dim_frac)
        f32 = rows(st.n_f32_rows_frac)
        nb_pr, nb_sp = st.phase_n_b()
        np_pr, np_sp = st.phase_n_p()
        phases = (rows(nb_pr), rows(nb_sp), rows(np_pr), rows(np_sp))
        cov = float(getattr(st, "coverage_frac", 1.0))
        pois = rows(getattr(st, "poisoned", 0.0)).astype(bool)
        wave.result = None
        for r in wave.requests:
            r.stage = DONE
        return ids, dists, n_b, n_p, frac, f32, phases, cov, pois
