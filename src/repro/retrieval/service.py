"""Universal vector-search service: mixed-p micro-batching scheduler.

The ANNS-U-Lp contract is that *every request carries its own p* (paper
§1: the optimal metric is task-specific). The naive way to serve that —
group the stream by exact (p, k) and run one device call per group — runs
tiny, data-dependently-shaped batches and compiles one program per
distinct p, which collapses under realistic traffic with many distinct p
values. This scheduler instead threads p through the kernel stack as a
*per-query tensor* (DESIGN.md §6):

  * bounded FIFO request queue (`queue_capacity`; `submit` raises
    `QueueFull` rather than buffering unboundedly);
  * two-way partition by base graph (G1 for p <= cutoff, G2 otherwise) ×
    k — never one group per distinct p;
  * padded power-of-two batch buckets (`min_bucket` … `max_batch`): every
    device call has one of a fixed set of shapes, so the jit cache holds
    two compiled entry points (one per base graph) per bucket size × k,
    independent of how many distinct p values the stream contains;
  * per-request latency, queue-depth, and per-base-graph / per-p-bucket
    N_b / N_p stats, so benchmark results are attributable (`stats`,
    `latency_summary`). Verify buckets additionally report their
    N_p-weighted scanned-dimension work (`stats["dim_frac_w"]`,
    DESIGN.md §8) so Eq. 1's effective T_p under early-abandoning
    verification is observable per base graph.

Results are bit-identical to per-p grouped serving (`serve_grouped`, kept
as the measurement baseline): the vector-p kernels select each row's
scalar op sequence exactly (repro.core.lp_ops).

The index is a ShardedUHNSW by default — its stacked segment axis shards
over the ('pod','data') mesh axes (`ShardedUHNSW.shard_over`) and its
delta tier accepts online inserts, so the service supports a full
read/write mixed-metric workload (DESIGN.md §3).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.metrics import base_metric_for
from repro.core.uhnsw import UHNSW, UHNSWParams
from repro.index.sharded import ShardedUHNSW
from repro.retrieval.engine import EnginePolicy, ServingEngine, default_stats


class QueueFull(RuntimeError):
    """Raised by `submit` when the bounded request queue is at capacity."""


def _with_expand_width(params: UHNSWParams | None,
                       expand_width: int | None) -> UHNSWParams | None:
    """Apply an explicit expand_width override to the query params."""
    if expand_width is None:
        return params
    return replace(params or UHNSWParams(), expand_width=expand_width)


@dataclass
class QueryRequest:
    """One ANNS-U-Lp query: a (d,) vector, its own metric p ∈ [0.5, 2],
    result size k, and a caller-chosen id the response is keyed by."""

    vector: np.ndarray
    p: float
    k: int = 10
    request_id: int = 0


@dataclass
class InsertRequest:
    vector: np.ndarray
    request_id: int = 0


# one stats schema for both serve paths — see engine.default_stats
_empty_stats = default_stats


@dataclass
class UniversalVectorService:
    """Mixed-p batched serving engine over a U-HNSW index.

    Public surface:
      * `build(data, ...)` / `build_monolithic(data, ...)` — construct the
        backing index (segmented+delta ShardedUHNSW, or the paper-exact
        monolithic UHNSW).
      * `submit(requests)` + `drain()` — enqueue into the bounded queue,
        then serve everything queued in padded mixed-p buckets.
      * `serve(requests)` — submit+drain convenience wrapper; returns
        {request_id: (ids (k,) int32, rooted dists (k,) f32)}.
      * `serve_grouped(requests)` — the legacy per-(p, k) grouped path,
        kept as the benchmark baseline; bit-identical results.
      * `insert(requests)` — streaming inserts into the delta tier.
      * `stats` / `latency_summary()` — scheduler + Eq. 1 accounting.

    Scheduling parameters: `max_batch` caps device batch size,
    `min_bucket` is the smallest padded bucket (buckets are the
    power-of-two ladder min_bucket … max_batch), `queue_capacity` bounds
    the request queue (DESIGN.md §6). `max_verify_batch` caps buckets
    that need the verification pass: the convergence while_loop runs
    until the slowest row in the bucket terminates, so smaller verify
    buckets bound that gating cost (measured sweet spot ~32 on CPU);
    exact-base buckets have no such loop and use the full max_batch.
    """

    index: ShardedUHNSW | UHNSW
    max_batch: int = 256
    max_verify_batch: int = 32
    min_bucket: int = 8
    queue_capacity: int = 4096
    # engine scheduling knobs (repro.retrieval.engine): deadline-flush
    # max-wait, admission-control watermark + overload policy, and the
    # injectable clock every deadline decision is made against (None ->
    # time.perf_counter; tests pass engine.ManualClock and never sleep)
    max_wait_ms: float = 2.0
    watermark: int | None = None
    overload: str = "shed"
    clock: object = None
    # failure recovery (DESIGN.md §9): per-flush retry budget + backoff,
    # and an optional seeded engine.FaultInjector for chaos rehearsal
    # (None = fault injection compiled out of the happy path)
    max_retries: int = 2
    retry_backoff_ms: float = 0.0
    fault_injector: object = None
    # degraded serving (DESIGN.md §11): coverage floor forwarded to
    # EnginePolicy.min_coverage (0.0 = serve at any coverage)
    min_coverage: float = 0.0
    stats: dict = field(default_factory=_empty_stats)

    def __post_init__(self):
        assert self.min_bucket >= 1 and self.max_batch >= self.min_bucket
        self._queue: deque = deque()  # (QueryRequest, enqueue_time)
        self._engine: ServingEngine | None = None
        self._seen_shapes: set = set()  # v1 cold-program detection

    @property
    def engine(self) -> ServingEngine:
        """The continuous-batching engine behind `serve` (lazy: the v1
        submit/drain path never constructs it)."""
        if self._engine is None:
            policy = EnginePolicy(
                max_batch=self.max_batch, min_bucket=self.min_bucket,
                max_wait_ms=self.max_wait_ms,
                queue_capacity=self.queue_capacity,
                watermark=self.watermark, overload=self.overload,
                max_retries=self.max_retries,
                retry_backoff_ms=self.retry_backoff_ms,
                min_coverage=self.min_coverage,
            )
            self._engine = ServingEngine(self.index, policy,
                                         clock=self.clock, stats=self.stats,
                                         fault_injector=self.fault_injector)
        return self._engine

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, data: np.ndarray, params: UHNSWParams | None = None,
              m: int = 32, num_segments: int = 4, seed: int = 0,
              delta_capacity: int = 1024, rt=None,
              expand_width: int | None = None, method: str | None = None,
              sharded_params=None, **kw):
        """Build a segmented sharded index over `data` (n, d) f32.

        With rt (a repro.dist Runtime), the segment axis is placed over the
        mesh's data axes. expand_width (if given) overrides the params'
        W-way multi-expansion factor for the level-0 beam. `method` picks
        the per-segment graph builder ("incremental" / "bulk" /
        "bulk_host", DESIGN.md §7; None = auto by segment size — the
        batched bulk path above index.segment.BULK_THRESHOLD) and carries
        over to delta compaction. `sharded_params` (a
        repro.index.sharded.ShardedParams) selects the cross-segment
        search policy — e.g. two_phase threshold propagation; the phase
        split lands in stats["n_b_probe"] / ["n_b_spill"]. Remaining
        kwargs configure the service (max_batch, min_bucket,
        queue_capacity).
        """
        index = ShardedUHNSW.build(
            data, num_segments=num_segments, m=m,
            params=_with_expand_width(params, expand_width), seed=seed,
            delta_capacity=delta_capacity, method=method,
            sharded_params=sharded_params,
        )
        if rt is not None:
            index.shard_over(rt)
        return cls(index=index, **kw)

    @classmethod
    def build_monolithic(cls, data: np.ndarray,
                         params: UHNSWParams | None = None,
                         m: int = 32, bulk: bool = True, seed: int = 0,
                         expand_width: int | None = None,
                         method: str | None = None, **kw):
        """Single-segment paper-exact index (no streaming inserts).

        `method` overrides the legacy `bulk` flag, which maps exactly as
        on the segmented surfaces (index.segment.resolve_build_method):
        bulk=True -> "bulk" (the batched shared-pass G1+G2 builder,
        DESIGN.md §7), bulk=False -> "incremental"; "bulk_host" (the
        vectorized NumPy per-graph builder) is reachable by name. The
        actual method dispatch lives in `UHNSW.build`.
        """
        params = _with_expand_width(params, expand_width)
        if method is None:
            method = "bulk" if bulk else "incremental"
        index = UHNSW.build(data, m=m, seed=seed, params=params,
                            method=method)
        return cls(index=index, **kw)

    # -- writes -------------------------------------------------------------

    def insert(self, requests: list[InsertRequest]) -> dict[int, int]:
        """Streaming inserts (ShardedUHNSW only). request_id -> global id."""
        if not hasattr(self.index, "add"):
            raise TypeError("index does not support online inserts "
                            "(build with UniversalVectorService.build)")
        out: dict[int, int] = {}
        segs_before = self.index.num_segments
        for r in requests:
            out[r.request_id] = self.index.add(r.vector)
        self.stats["inserts"] += len(requests)
        self.stats["compactions"] += self.index.num_segments - segs_before
        return out

    # -- the micro-batching scheduler ---------------------------------------

    def _validate(self, requests: list[QueryRequest]) -> None:
        """Reject malformed requests before ANY of the batch is accepted:
        p outside the universal range (NaN included), k < 1, a vector of
        the wrong dimensionality (reported as expected vs actual d), or a
        non-finite vector — so a malformed request can never reach (and
        abort) a device batch it shares with healthy ones."""
        dim = int(self.index.X.shape[1])
        for r in requests:
            base_metric_for(float(r.p))  # range-validates p (NaN included)
            if int(r.k) < 1:
                raise ValueError(
                    f"request {r.request_id}: k must be >= 1, got {r.k}")
            v = np.asarray(r.vector)
            if v.size != dim:
                raise ValueError(
                    f"request {r.request_id}: dimension mismatch — "
                    f"expected d={dim}, got d={v.size}"
                )
            if not np.all(np.isfinite(v)):
                raise ValueError(
                    f"request {r.request_id}: vector has non-finite "
                    f"entries (NaN/Inf)"
                )

    def submit(self, requests: list[QueryRequest]) -> None:
        """Enqueue requests into the bounded FIFO queue.

        Raises QueueFull if the batch would exceed `queue_capacity` (no
        partial enqueue) or ValueError for a malformed request (see
        `_validate`) — all *before* any request of the batch is accepted.
        """
        if len(self._queue) + len(requests) > self.queue_capacity:
            raise QueueFull(
                f"queue at {len(self._queue)}/{self.queue_capacity}; "
                f"cannot accept {len(requests)} more"
            )
        self._validate(requests)
        now = time.perf_counter()
        for r in requests:
            self._queue.append((r, now))
        self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                       len(self._queue))

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def drain(self) -> dict[int, tuple]:
        """Serve everything queued. Returns request_id -> (ids, dists).

        Scheduling (DESIGN.md §6): the queued requests partition two ways
        by base graph (cutoff rule), then by k; each partition is cut into
        FIFO chunks of <= max_batch and every chunk is padded up to the
        next power-of-two bucket size, so each device call has one of a
        fixed set of shapes regardless of how many distinct p values are
        in flight. Padding rows replicate the chunk's first request and
        are sliced off before stats are counted.
        """
        cutoff = self.index.params.cutoff
        out: dict[int, tuple] = {}
        # two-way base partition × k — insertion order stays FIFO per group.
        # Rows whose p IS a base metric (exactly 1 or 2) never need
        # verification (paper §3 preamble); they bucket separately and take
        # the scalar skip path — the mixed engine's fast lane for the most
        # common production metrics.
        groups: dict[tuple[float, int, bool], list] = {}
        while self._queue:
            r, t0 = self._queue.popleft()
            base = base_metric_for(float(r.p), cutoff)
            exact = float(r.p) == base
            groups.setdefault((base, int(r.k), exact), []).append((r, t0))
        buckets = []
        for (base, k, exact), entries in sorted(groups.items()):
            cap = self.max_batch if exact else min(self.max_verify_batch,
                                                   self.max_batch)
            for start in range(0, len(entries), cap):
                buckets.append((base, k, exact, entries[start:start + cap],
                                cap))
        for i, (base, k, exact, chunk, cap) in enumerate(buckets):
            try:
                self._run_bucket(base, k, exact, chunk, out, cap)
            except Exception as e:
                # a failing bucket must not lose the rest of the drained
                # queue: re-enqueue every unserved request (including the
                # failing bucket's) so the caller can inspect or retry,
                # and hand back the responses already computed this call —
                # those requests are NOT re-enqueued (their stats are
                # already counted), so the partial dict is their only copy.
                for _, _, _, ch, _ in buckets[i:]:
                    self._queue.extend(ch)
                if not hasattr(e, "partial_results"):
                    e.partial_results = out
                raise
        return out

    def _bucket_size(self, n: int, cap: int) -> int:
        """Smallest power-of-two ladder size >= n (min_bucket … cap)."""
        size = self.min_bucket
        while size < n and size < cap:
            size *= 2
        return min(size, cap)

    def _run_bucket(self, base: float, k: int, exact: bool, chunk: list,
                    out: dict[int, tuple], cap: int) -> None:
        """One padded fixed-shape device call for a homogeneous-base chunk.

        exact=True means every row's p equals the base metric — the call
        drops to the scalar skip path (no verification program at all).
        """
        t_start = time.perf_counter()
        n_real = len(chunk)
        size = self._bucket_size(n_real, cap)
        reqs = [r for r, _ in chunk]
        q = np.stack([np.asarray(r.vector, np.float32).reshape(-1)
                      for r in reqs])
        if size > n_real:  # pad by replicating row 0 (same base, any p ok)
            q = np.concatenate([q, np.repeat(q[:1], size - n_real, axis=0)])
        if exact:
            ids, dists, stats = self.index.search(q, base, k)
        else:
            p = np.array([float(r.p) for r in reqs], np.float32)
            if size > n_real:
                p = np.concatenate([p, np.repeat(p[:1], size - n_real)])
            ids, dists, stats = self.index.search(q, p, k)
        ids = np.asarray(ids)[:n_real]
        dists = np.asarray(dists)[:n_real]
        def rows(x):
            x = np.asarray(x, dtype=np.float64)
            return x[:n_real] if x.ndim else np.full(n_real, float(x))

        n_b = rows(stats.n_b)
        n_p = rows(stats.n_p)
        # N_p-weighted scanned-dim fraction (1.0 on full-dimension paths)
        frac = rows(stats.n_dim_frac)
        frac_w = float((frac * n_p).sum())
        # N_p-weighted f32-rows fraction (DESIGN.md §10 two-band scan)
        f32_w = float((rows(stats.n_f32_rows_frac) * n_p).sum())
        # per-phase attribution (probe == total for monolithic/independent)
        nb_pr, nb_sp = stats.phase_n_b()
        np_pr, np_sp = stats.phase_n_p()
        nb_pr, nb_sp, np_pr, np_sp = map(rows, (nb_pr, nb_sp, np_pr, np_sp))
        done = time.perf_counter()
        shape_key = (base, k, exact, size)
        cold = shape_key not in self._seen_shapes
        self._seen_shapes.add(shape_key)
        st = self.stats
        st["queries"] += n_real
        st["batches"] += 1
        st["padded_rows"] += size - n_real
        st["n_b"] += float(n_b.sum())
        st["n_p"] += float(n_p.sum())
        st["n_b_probe"] += float(nb_pr.sum())
        st["n_b_spill"] += float(nb_sp.sum())
        st["n_p_probe"] += float(np_pr.sum())
        st["n_p_spill"] += float(np_sp.sum())
        st["dim_frac_w"] += frac_w
        st["f32_rows_w"] += f32_w
        pb = st["per_base"]["G1" if base == 1.0 else "G2"]
        pb["queries"] += n_real
        pb["batches"] += 1
        pb["n_b"] += float(n_b.sum())
        pb["n_p"] += float(n_p.sum())
        pb["dim_frac_w"] += frac_w
        pb["f32_rows_w"] += f32_w
        for i, (r, t0) in enumerate(chunk):
            out[r.request_id] = (ids[i], dists[i])
            pp = st["per_p"].setdefault(
                "%g" % float(r.p), {"queries": 0, "n_b": 0.0, "n_p": 0.0})
            pp["queries"] += 1
            pp["n_b"] += float(n_b[i])
            pp["n_p"] += float(n_p[i])
            st["latency_ms"].append((done - t0) * 1e3)
            st["latency_records"].append((
                (done - t0) * 1e3,            # total
                max(t_start - t0, 0.0) * 1e3,  # queue-wait
                (done - t_start) * 1e3,        # device-compute
                cold,
            ))

    def serve(self, requests: list[QueryRequest]) -> dict[int, tuple]:
        """Serve a mixed-p request list through the continuous-batching
        engine (DESIGN.md §6) — the default serve path since the engine
        PR; `serve_v1` keeps the synchronous submit/drain scheduler as a
        bit-identical baseline.

        Anything already queued via `submit` migrates into the engine
        first (FIFO, original enqueue timestamps preserved), then the
        request list is admitted in waves sized to the queue's remaining
        capacity, so arbitrarily long lists never trip the bound. Returns
        request_id -> (ids (k,) int32, rooted dists (k,) f32); requests
        shed by admission control (watermark + overload="shed") have no
        entry, and neither do requests the engine's bounded failure
        recovery marked terminally FAILED (retries exhausted after
        quarantine isolation, DESIGN.md §9) — those carry their final
        exception message in `engine.take_failures()` and count in
        `stats["failed"]`. Transient device faults are invisible here:
        the engine retries/bisects them and the retried results are
        bitwise-identical. If the recovery machinery itself fails, the
        engine enters its terminal failed state and the error propagates
        with responses already computed as `partial_results`."""
        eng = self.engine
        out: dict[int, tuple] = {}
        i = 0
        try:
            while i < len(requests) or self._queue or eng.pending:
                while self._queue:  # migrate pre-queued v1 submissions
                    r, t0 = self._queue.popleft()
                    eng.admit([eng.make_request(r, now=t0)])
                room = self.queue_capacity - eng.pending
                if room > 0 and i < len(requests):
                    wave = requests[i:i + room]
                    self._validate(wave)
                    eng.admit([eng.make_request(r) for r in wave])
                    i += len(wave)
                out.update(eng.drain())
        except Exception as e:
            out.update(getattr(e, "partial_results", {}))
            e.partial_results = out
            raise
        return out

    def serve_v1(self, requests: list[QueryRequest]) -> dict[int, tuple]:
        """The v1 synchronous scheduler: submit + drain, in waves sized to
        the queue's *remaining* capacity, so arbitrarily long lists never
        trip the bound — even when other requests were already queued via
        `submit` (those are served too, FIFO, and their responses are
        included in the returned dict, as with any `drain`). Kept as the
        engine's bit-identical correctness/latency baseline
        (benchmarks/serving.py). Returns request_id -> (ids, dists); on
        failure, computed responses ride on the exception as
        `partial_results`."""
        out: dict[int, tuple] = {}
        i = 0
        try:
            while i < len(requests) or self._queue:
                room = self.queue_capacity - len(self._queue)
                if room > 0 and i < len(requests):
                    wave = requests[i:i + room]
                    self.submit(wave)
                    i += len(wave)
                out.update(self.drain())
        except Exception as e:
            out.update(getattr(e, "partial_results", {}))
            e.partial_results = out
            raise
        return out

    # -- the grouped baseline ------------------------------------------------

    def serve_grouped(self, requests: list[QueryRequest]) -> dict[int, tuple]:
        """Legacy per-(p, k) grouped serving: one device call per exact
        (p, k) group with data-dependent batch shapes — the scheduling this
        PR's micro-batcher replaces. Kept as the benchmark baseline
        (benchmarks/serving.py) and the parity oracle.

        Each group runs through the same traced-p kernel programs `serve`
        uses (a constant p vector), so grouped-vs-mixed is a pure
        *scheduling* comparison and results are bit-identical to `serve`
        by construction — per-row kernel results are independent of batch
        composition (tests/test_mixed_p.py pins this). Does not touch the
        scheduler stats."""
        groups: dict[tuple[float, int], list[QueryRequest]] = {}
        for r in requests:
            groups.setdefault((float(r.p), int(r.k)), []).append(r)
        out: dict[int, tuple] = {}
        cutoff = self.index.params.cutoff
        for (p, k), reqs in sorted(groups.items()):
            for start in range(0, len(reqs), self.max_batch):
                chunk = reqs[start:start + self.max_batch]
                q = np.stack([r.vector for r in chunk]).astype(np.float32)
                if p == base_metric_for(p, cutoff):
                    # base-metric group: the scalar skip path (no verify) —
                    # the same program family the mixed exact lane uses
                    ids, dists, _ = self.index.search(q, p, k)
                else:
                    p_vec = np.full(len(chunk), p, dtype=np.float32)
                    ids, dists, _ = self.index.search(q, p_vec, k)
                ids, dists = np.asarray(ids), np.asarray(dists)
                for i, r in enumerate(chunk):
                    out[r.request_id] = (ids[i], dists[i])
        return out

    # -- stats ---------------------------------------------------------------

    def latency_summary(self) -> dict:
        """Request-latency summary over the most recent window (the
        backing buffers keep the last 10k requests).

        Beyond the total-latency percentiles, the summary *attributes*
        each request's time (the ISSUE's accounting fix): `queue_ms` is
        admission -> dispatch wait, `compute_ms` is dispatch -> host
        materialization, `cold_count` is how many requests rode a batch
        shape's first (compiling) execution, and `warm` re-reports the
        total-latency percentiles over non-cold requests only — so a
        7-second first-call compile can never masquerade as steady-state
        serving latency again."""
        # fault-tolerance counters (DESIGN.md §9) ride on every summary so
        # operational dashboards see retries/quarantines next to latency
        faults = {key: int(self.stats.get(key, 0))
                  for key in ("faults", "retries", "quarantine_splits",
                              "failed")}
        # degraded-serving counters (DESIGN.md §11): queries-weighted mean
        # coverage plus the engine's poison/quarantine/recovery totals and
        # (for health-tracked indexes) the tracker's own state summary
        q = int(self.stats.get("queries", 0))
        health = {
            "coverage_mean": (float(self.stats.get("coverage_w", 0.0)) / q
                              if q else 1.0),
            **{key: int(self.stats.get(key, 0))
               for key in ("poison_detected", "seg_quarantined",
                           "seg_recovered", "min_coverage_failed")},
        }
        tracker = getattr(self.index, "health", None)
        if tracker is not None:
            health["tracker"] = tracker.summary()
        lat = np.asarray(self.stats["latency_ms"], dtype=np.float64)
        if lat.size == 0:
            return {"count": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0,
                    "max": 0.0, "queue_ms": {}, "compute_ms": {},
                    "cold_count": 0, "warm": {}, "faults": faults,
                    "health": health}
        out = {
            "count": int(lat.size),
            "mean": float(lat.mean()),
            "p50": float(np.percentile(lat, 50)),
            "p95": float(np.percentile(lat, 95)),
            "max": float(lat.max()),
            "faults": faults,
            "health": health,
        }
        recs = list(self.stats["latency_records"])
        if recs:
            arr = np.asarray([r[:3] for r in recs], dtype=np.float64)
            cold = np.asarray([bool(r[3]) for r in recs])
            for name, col in (("queue_ms", arr[:, 1]),
                              ("compute_ms", arr[:, 2])):
                out[name] = {
                    "mean": float(col.mean()),
                    "p50": float(np.percentile(col, 50)),
                    "p95": float(np.percentile(col, 95)),
                }
            out["cold_count"] = int(cold.sum())
            warm = arr[~cold, 0]
            out["warm"] = {} if warm.size == 0 else {
                "count": int(warm.size),
                "p50": float(np.percentile(warm, 50)),
                "p95": float(np.percentile(warm, 95)),
            }
        else:
            out["queue_ms"], out["compute_ms"] = {}, {}
            out["cold_count"], out["warm"] = 0, {}
        return out
