"""Universal vector-search service: the paper's engine as a serving feature.

Wraps an index behind a request API where *every request carries its own p*
(the ANNS-U-Lp contract). Mixed-p request streams are grouped by p into
sub-batches (the per-p jit cache makes each group a single device program);
the index is a ShardedUHNSW by default — its stacked segment axis shards
over the ('pod','data') mesh axes (`ShardedUHNSW.shard_over`) and its delta
tier accepts online inserts, so the service supports a full
read/write mixed-metric workload (DESIGN.md §3).

This is the deployment surface the paper motivates (§1: per-application /
per-task optimal p) — e.g. a multi-tenant retrieval tier where each tenant
tuned its own metric.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.uhnsw import UHNSW, UHNSWParams
from repro.index.sharded import ShardedUHNSW


def _with_expand_width(params: UHNSWParams | None,
                       expand_width: int | None) -> UHNSWParams | None:
    """Apply an explicit expand_width override to the query params."""
    if expand_width is None:
        return params
    return replace(params or UHNSWParams(), expand_width=expand_width)


@dataclass
class QueryRequest:
    vector: np.ndarray
    p: float
    k: int = 10
    request_id: int = 0


@dataclass
class InsertRequest:
    vector: np.ndarray
    request_id: int = 0


@dataclass
class UniversalVectorService:
    index: ShardedUHNSW | UHNSW
    max_batch: int = 256
    stats: dict = field(default_factory=lambda: {
        "queries": 0, "batches": 0, "inserts": 0, "compactions": 0,
        "n_b": 0.0, "n_p": 0.0,
    })

    @classmethod
    def build(cls, data: np.ndarray, params: UHNSWParams | None = None,
              m: int = 32, num_segments: int = 4, seed: int = 0,
              delta_capacity: int = 1024, rt=None,
              expand_width: int | None = None, **kw):
        """Build a segmented sharded index over `data`.

        With rt (a repro.dist Runtime), the segment axis is placed over the
        mesh's data axes. expand_width (if given) overrides the params'
        W-way multi-expansion factor for the level-0 beam.
        """
        index = ShardedUHNSW.build(
            data, num_segments=num_segments, m=m,
            params=_with_expand_width(params, expand_width), seed=seed,
            delta_capacity=delta_capacity,
        )
        if rt is not None:
            index.shard_over(rt)
        return cls(index=index, **kw)

    @classmethod
    def build_monolithic(cls, data: np.ndarray,
                         params: UHNSWParams | None = None,
                         m: int = 32, bulk: bool = True, seed: int = 0,
                         expand_width: int | None = None, **kw):
        """Single-segment paper-exact index (no streaming inserts)."""
        from repro.core.build import build_hnsw, build_hnsw_bulk

        builder = build_hnsw_bulk if bulk else build_hnsw
        g1 = builder(data, 1.0, m=m, seed=seed)
        g2 = builder(data, 2.0, m=m, seed=seed + 1)
        params = _with_expand_width(params, expand_width)
        return cls(index=UHNSW(g1, g2, params), **kw)

    def insert(self, requests: list[InsertRequest]) -> dict[int, int]:
        """Streaming inserts (ShardedUHNSW only). request_id -> global id."""
        if not hasattr(self.index, "add"):
            raise TypeError("index does not support online inserts "
                            "(build with UniversalVectorService.build)")
        out: dict[int, int] = {}
        segs_before = self.index.num_segments
        for r in requests:
            out[r.request_id] = self.index.add(r.vector)
        self.stats["inserts"] += len(requests)
        self.stats["compactions"] += self.index.num_segments - segs_before
        return out

    def serve(self, requests: list[QueryRequest]) -> dict[int, tuple]:
        """Serve a mixed-p request list. Returns request_id -> (ids, dists)."""
        # group by (p, k): each group is one batched device call
        groups: dict[tuple[float, int], list[QueryRequest]] = {}
        for r in requests:
            groups.setdefault((float(r.p), int(r.k)), []).append(r)
        out: dict[int, tuple] = {}
        for (p, k), reqs in sorted(groups.items()):
            for start in range(0, len(reqs), self.max_batch):
                chunk = reqs[start : start + self.max_batch]
                q = np.stack([r.vector for r in chunk]).astype(np.float32)
                ids, dists, stats = self.index.search(q, p, k)
                ids, dists = np.asarray(ids), np.asarray(dists)
                for i, r in enumerate(chunk):
                    out[r.request_id] = (ids[i], dists[i])
                self.stats["queries"] += len(chunk)
                self.stats["batches"] += 1
                self.stats["n_b"] += float(np.asarray(stats.n_b).sum())
                self.stats["n_p"] += float(np.asarray(stats.n_p).sum())
        return out
