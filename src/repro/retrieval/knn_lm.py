"""kNN-LM over a U-HNSW datastore: retrieval-augmented decoding where the
retrieval metric p is a *per-request* knob.

Standard kNN-LM (Khandelwal et al. 2020) interpolates the LM's next-token
distribution with a nearest-neighbor distribution over (hidden-state ->
next-token) pairs:  p(y) = (1-lam) p_LM(y) + lam p_kNN(y), where p_kNN
weights neighbors by softmax(-d(h, h_i) / T).

The U-HNSW index makes d an *arbitrary Lp* distance chosen at query time —
the paper's motivating observation is that the most discriminative p varies
by dataset/task, and with U-HNSW the serving tier can explore p without
rebuilding the datastore index (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.uhnsw import UHNSW


@dataclass
class KnnLM:
    index: UHNSW
    values: np.ndarray          # (n,) int32 next-token id per datastore entry
    vocab_size: int
    lam: float = 0.25
    temperature: float = 1.0
    k: int = 8

    def build_from_hidden(hidden: np.ndarray, next_tokens: np.ndarray,
                          vocab_size: int, m: int = 16, seed: int = 0,
                          **kw) -> "KnnLM":
        from repro.core.build import build_hnsw_bulk

        g1 = build_hnsw_bulk(hidden, 1.0, m=m, seed=seed)
        g2 = build_hnsw_bulk(hidden, 2.0, m=m, seed=seed + 1)
        return KnnLM(UHNSW(g1, g2), next_tokens.astype(np.int32),
                     vocab_size, **kw)

    build_from_hidden = staticmethod(build_from_hidden)

    def knn_logprobs(self, h: np.ndarray, p: float) -> np.ndarray:
        """p_kNN over the vocab for query hidden states h (B, d), metric Lp."""
        ids, dists, _ = self.index.search(jnp.asarray(h), p, self.k)
        ids, dists = np.asarray(ids), np.asarray(dists, dtype=np.float64)
        w = np.exp(-dists / self.temperature)
        w = w / np.maximum(w.sum(axis=1, keepdims=True), 1e-30)
        out = np.zeros((h.shape[0], self.vocab_size))
        for b in range(h.shape[0]):
            np.add.at(out[b], self.values[ids[b]], w[b])
        return np.log(np.maximum(out, 1e-30))

    def mix(self, lm_logprobs: np.ndarray, h: np.ndarray, p: float) -> np.ndarray:
        """(1-lam) p_LM + lam p_kNN in probability space; returns logprobs."""
        knn_lp = self.knn_logprobs(h, p)
        mixed = (1 - self.lam) * np.exp(lm_logprobs) + self.lam * np.exp(knn_lp)
        return np.log(np.maximum(mixed, 1e-30))
