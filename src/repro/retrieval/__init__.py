from repro.retrieval.service import UniversalVectorService  # noqa: F401
from repro.retrieval.knn_lm import KnnLM  # noqa: F401