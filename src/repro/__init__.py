"""repro: U-HNSW (ANNS under universal Lp metrics) as a first-class retrieval
feature of a multi-pod JAX LM training/serving framework.

Layers:
  repro.core       — the paper's contribution (U-HNSW, HNSW, MLSH baseline)
  repro.index      — segmented sharded U-HNSW + streaming-insert delta tier
  repro.kernels    — Pallas TPU kernels for Lp distance computation
  repro.models     — LM model zoo (10 assigned architectures)
  repro.dist       — mesh / sharding / collective helpers
  repro.train      — training loop substrate
  repro.serve      — prefill/decode serving substrate
  repro.retrieval  — U-HNSW <-> LM integration (kNN-LM / RAG)
  repro.checkpoint — sharded fault-tolerant checkpointing
  repro.launch     — mesh construction, dry-run, train/serve entry points
"""

__version__ = "0.1.0"
