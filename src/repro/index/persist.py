"""Durable snapshots + crash recovery for ShardedUHNSW (DESIGN.md §9).

A snapshot is an atomic, manifest-based dump of the whole index state:
per-segment graph topology (`GraphArrays` leaves), the frozen data matrix,
the global-id maps, query params, the remembered build method, and the
delta-buffer contents at save time. It is written with the same
write-tmp/fsync/rename idiom as `repro.checkpoint.store` — a crash
mid-write leaves only a `.tmp` directory that loaders never look at — and
every array file carries a CRC32 recorded in the manifest, so a *torn*
snapshot (post-crash corruption, partial copy) is detected and skipped,
never loaded.

Recovery composes the snapshot with the delta write-ahead log
(`repro.index.wal`):

    recover(dir) = load newest durable snapshot
                 + replay the durable prefix of every WAL segment

Replay re-runs each logged insert through `ShardedUHNSW.add`, so a
compaction that happened in the crashed process is *re-derived* during
replay (segment builds are deterministic: same vectors, same seed, same
remembered build method). Records whose global id is already frozen in the
snapshot are skipped (idempotence guard); a replay that would *skip past*
an id (a lost WAL segment) raises `RecoveryError` instead of silently
dropping inserts. The result is bit-identical — ids and distances — to the
index a never-crashed process would hold, at every p (tests/test_persist).

`DurableIndex` packages the lifecycle: WAL-append before every insert,
snapshot rotation at compaction (the delta is empty right then, so the
snapshot is the cheap full-frozen dump the compaction already paid for),
and pruning that always keeps enough history to fall back one snapshot.
"""

from __future__ import annotations

import json
import os
import shutil
import warnings
import zlib
from dataclasses import asdict, fields
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.bulk_build import DeviceGraph
from repro.core.hnsw import GraphArrays
from repro.core.uhnsw import UHNSWParams
from repro.index.segment import SegmentedGraphs
from repro.index.sharded import ShardedUHNSW
from repro.index.wal import WriteAheadLog, list_wals, replay, wal_path

SNAPSHOT_PREFIX = "snapshot_"
SNAPSHOT_FORMAT = 1


class SnapshotError(RuntimeError):
    """A snapshot directory is structurally invalid or fails its CRC."""


class RecoveryError(RuntimeError):
    """Recovery cannot reach a consistent state (e.g. a WAL id gap)."""


def snapshot_path(directory, seq: int) -> Path:
    return Path(directory) / f"{SNAPSHOT_PREFIX}{seq:08d}"


def list_snapshots(directory) -> list[tuple[int, Path]]:
    """All committed snapshot dirs (tmp excluded), ascending by sequence."""
    directory = Path(directory)
    if not directory.exists():
        return []
    out = []
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith(SNAPSHOT_PREFIX) \
                and not p.name.endswith(".tmp"):
            try:
                out.append((int(p.name[len(SNAPSHOT_PREFIX):]), p))
            except ValueError:
                continue
    return sorted(out)


def _fsync_write(path: Path, data: bytes):
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def _graph_meta(g) -> dict:
    arrays = GraphArrays.from_graph(g)
    return {
        "metric_p": float(arrays.metric_p),
        "m": int(g.m),
        "m0": int(g.m0),
        "entry_point": int(np.asarray(arrays.entry)),
        "n": int(arrays.n),
        "n_levels": len(arrays.upper_adj),
    }


def _graph_arrays_items(prefix: str, g):
    arrays = GraphArrays.from_graph(g)
    yield f"{prefix}.adj0", np.asarray(arrays.adj0)
    for l, (adj, g2l) in enumerate(zip(arrays.upper_adj, arrays.upper_g2l)):
        yield f"{prefix}.up{l}", np.asarray(adj)
        yield f"{prefix}.g2l{l}", np.asarray(g2l)
    levels = getattr(g, "levels", None)
    if levels is not None:
        yield f"{prefix}.levels", np.asarray(levels)


def save_snapshot(index: ShardedUHNSW, directory, seq: int | None = None,
                  ) -> Path:
    """Write one atomic snapshot of `index` as snapshot_<seq>.

    seq defaults to one past the newest committed snapshot. The manifest is
    written last (fsync'd), then the directory renames into place — the
    rename is the commit point, exactly as in checkpoint/store.py.

    On-disk layout: `<dir>/snapshot_<seq:08d>/{manifest.json, arrays.npz}`.
    The npz holds `X` ((n, d) f32 frozen rows), per-segment
    `s<i:04d>.{ids,g1.*,g2.*}` graph arrays (int32/int64 exactly as the
    `GraphArrays` leaves), `delta.{vecs,ids}` ((c, d) f32 / (c,) int64),
    and — when a compressed band exists or `params.compressed_band` is
    set — `band.{codes,scale,radius,perm}` ((n, d) int8, 3x (d,) f32/
    int32; DESIGN.md §10). The manifest duplicates the band's energy
    permutation (`band.perm`) so operators can inspect it without
    unpacking arrays. Failure modes: a crash before the final rename
    leaves only a `.tmp` directory loaders ignore; a crash after it
    leaves a fully durable snapshot (rename is atomic on POSIX).
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    if seq is None:
        snaps = list_snapshots(directory)
        seq = snaps[-1][0] + 1 if snaps else 0
    final = snapshot_path(directory, seq)
    tmp = directory / (final.name + ".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    seg = index.segments
    payload: dict[str, np.ndarray] = {"X": index._X_host}
    seg_meta = []
    for i, (g1, g2, ids) in enumerate(
            zip(seg.graphs1, seg.graphs2, seg.global_ids)):
        pref = f"s{i:04d}"
        payload[f"{pref}.ids"] = np.asarray(ids, dtype=np.int64)
        for key, arr in _graph_arrays_items(f"{pref}.g1", g1):
            payload[key] = arr
        for key, arr in _graph_arrays_items(f"{pref}.g2", g2):
            payload[key] = arr
        seg_meta.append({"n": int(g1.n), "g1": _graph_meta(g1),
                         "g2": _graph_meta(g2)})
    delta_vecs, delta_ids = index.delta.vectors(), index.delta.ids()
    payload["delta.vecs"] = delta_vecs
    payload["delta.ids"] = delta_ids.astype(np.int64)

    # compressed storage band (DESIGN.md §10): persisted whenever the
    # params ask for it (force-built here if no query has yet) or one was
    # already built — recovery then skips the quantization pass and the
    # energy permutation survives in the manifest alongside the arrays
    band = index._band
    if band is None and index.params.compressed_band:
        band = index.compressed_band()
    band_meta = None
    if band is not None:
        payload["band.codes"] = np.asarray(band.codes)
        payload["band.scale"] = np.asarray(band.scale)
        payload["band.radius"] = np.asarray(band.radius)
        payload["band.perm"] = np.asarray(band.perm)
        band_meta = {"n": band.n, "d": band.d,
                     "perm": np.asarray(band.perm).tolist()}

    arrays_file = tmp / "arrays.npz"
    np.savez(arrays_file, **payload)
    with open(arrays_file, "rb") as f:
        os.fsync(f.fileno())
    raw = arrays_file.read_bytes()
    manifest = {
        "format": SNAPSHOT_FORMAT,
        "kind": "uhnsw-sharded",
        "seq": int(seq),
        "next_id": int(index._next_id),
        "delta_capacity": int(index.delta.capacity),
        "delta_count": int(len(index.delta)),
        "build_method": index._build_method,
        "params": asdict(index.params),
        "d": int(index.dim),
        "segments": seg_meta,
        "band": band_meta,
        "arrays": {"file": "arrays.npz", "crc32": zlib.crc32(raw),
                   "size": len(raw)},
    }
    _fsync_write(tmp / "manifest.json", json.dumps(manifest).encode())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def read_manifest(path: Path) -> dict:
    """Load + structurally validate one snapshot's manifest, CRC included.

    Raises SnapshotError on any torn/invalid state — callers that want
    fallback semantics use `latest_durable_snapshot`.
    """
    path = Path(path)
    mf = path / "manifest.json"
    try:
        manifest = json.loads(mf.read_text())
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise SnapshotError(f"{path}: unreadable manifest ({e})") from e
    if not isinstance(manifest, dict) \
            or manifest.get("format") != SNAPSHOT_FORMAT \
            or manifest.get("kind") != "uhnsw-sharded":
        raise SnapshotError(f"{path}: manifest is not a format-"
                            f"{SNAPSHOT_FORMAT} uhnsw-sharded snapshot")
    info = manifest.get("arrays") or {}
    af = path / str(info.get("file", ""))
    try:
        raw = af.read_bytes()
    except OSError as e:
        raise SnapshotError(f"{path}: missing array file ({e})") from e
    if len(raw) != info.get("size") or zlib.crc32(raw) != info.get("crc32"):
        raise SnapshotError(
            f"{path}: array file failed its CRC/size check — torn snapshot")
    return manifest


def latest_durable_snapshot(directory) -> Path | None:
    """Newest snapshot that passes full validation; torn/invalid newer
    snapshots are skipped with a warning (crash-corruption fallback)."""
    for seq, path in reversed(list_snapshots(directory)):
        try:
            read_manifest(path)
            return path
        except SnapshotError as e:
            warnings.warn(f"skipping non-durable snapshot: {e}",
                          stacklevel=2)
    return None


def _params_from(manifest: dict) -> UHNSWParams:
    known = {f.name for f in fields(UHNSWParams)}
    kw = {k: v for k, v in (manifest.get("params") or {}).items()
          if k in known}
    return UHNSWParams(**kw)


def _load_graph(npz, prefix: str, meta: dict, data: np.ndarray) -> DeviceGraph:
    n = meta["n"]
    upper_adj, upper_g2l = [], []
    for l in range(meta["n_levels"]):
        upper_adj.append(jnp.asarray(npz[f"{prefix}.up{l}"]))
        upper_g2l.append(jnp.asarray(npz[f"{prefix}.g2l{l}"]))
    arrays = GraphArrays(
        adj0=jnp.asarray(npz[f"{prefix}.adj0"]),
        upper_adj=tuple(upper_adj),
        upper_g2l=tuple(upper_g2l),
        entry=jnp.asarray(meta["entry_point"], dtype=jnp.int32),
        n=n,
        metric_p=float(meta["metric_p"]),
    )
    lv_key = f"{prefix}.levels"
    levels = npz[lv_key] if lv_key in getattr(npz, "files", ()) else None
    return DeviceGraph(
        metric_p=float(meta["metric_p"]), m=int(meta["m"]),
        m0=int(meta["m0"]), entry_point=int(meta["entry_point"]),
        max_level=meta["n_levels"], levels=levels, data=data, arrays=arrays,
    )


def load_snapshot(path, params: UHNSWParams | None = None) -> ShardedUHNSW:
    """Reconstruct a ShardedUHNSW from one snapshot directory.

    The rebuilt index is bit-identical to the saved one: the per-segment
    `GraphArrays` round-trip exactly (the restack re-pads the same inputs
    to the same envelope), the data matrix is byte-preserved, the
    delta contents saved with the snapshot are restored verbatim, and a
    persisted compressed band (DESIGN.md §10) is reattached byte-for-byte
    — no re-quantization pass on the recovery path (an index saved
    *without* a band lazily rebuilds one on first use; `build_band` is
    deterministic, so either route lands on identical bytes).

    `params` overrides the saved UHNSWParams (the manifest copy is
    filtered against the current dataclass fields, so snapshots written
    before a param existed load with its default). Raises SnapshotError
    via `read_manifest` on a torn/invalid snapshot.
    """
    path = Path(path)
    manifest = read_manifest(path)
    npz = np.load(path / manifest["arrays"]["file"])
    X = np.ascontiguousarray(npz["X"], dtype=np.float32)
    graphs1, graphs2, global_ids = [], [], []
    for i, meta in enumerate(manifest["segments"]):
        pref = f"s{i:04d}"
        ids = np.asarray(npz[f"{pref}.ids"], dtype=np.int64)
        data = np.ascontiguousarray(X[ids])
        graphs1.append(_load_graph(npz, f"{pref}.g1", meta["g1"], data))
        graphs2.append(_load_graph(npz, f"{pref}.g2", meta["g2"], data))
        global_ids.append(ids)
    segments = SegmentedGraphs(graphs1=graphs1, graphs2=graphs2,
                               global_ids=global_ids)
    idx = ShardedUHNSW(segments, X,
                       params=params or _params_from(manifest),
                       delta_capacity=manifest["delta_capacity"])
    idx._build_method = manifest.get("build_method")
    idx.delta.restore(npz["delta.vecs"], npz["delta.ids"])
    idx._next_id = int(manifest["next_id"])
    assert idx._next_id == len(X) + len(idx.delta), \
        (idx._next_id, len(X), len(idx.delta))
    if "band.codes" in npz.files:
        from repro.index.compressed import CompressedBand

        perm = np.asarray(npz["band.perm"], dtype=np.int32)
        band_meta = manifest.get("band") or {}
        if "perm" in band_meta:  # the manifest copy is authoritative
            mperm = np.asarray(band_meta["perm"], dtype=np.int32)
            assert np.array_equal(mperm, perm), "band perm mismatch"
        idx._band = CompressedBand(
            codes=jnp.asarray(npz["band.codes"]),
            scale=jnp.asarray(npz["band.scale"]),
            radius=jnp.asarray(npz["band.radius"]),
            perm=jnp.asarray(perm),
        )
    return idx


def restore_segment(index, seg: int, directory) -> bool:
    """Restore one quarantined segment's rows from the newest durable
    snapshot (DESIGN.md §11) — the data-plane half of segment recovery.

    Graph topology never goes bad in place (it is immutable after build);
    what poison/corruption hits is the *row storage* — `_X_host`, the
    device copy `X`, the stacked per-segment `segments.X`, and the
    per-graph data arrays the next restack would read. This rewrites all
    four from snapshot bytes that passed the manifest CRC re-verification
    (`read_manifest` — a torn snapshot is never a restore source) and
    drops the §10 band/scan caches, which quantized the poisoned rows.

    The snapshot segment is matched by *global-id equality*, not by
    position: compactions after the snapshot may have appended segments,
    and a segment created after the newest snapshot has no restore source
    at all. Returns True when `seg` was restored; False when there is no
    durable snapshot or none of its segments matches (the caller leaves
    the segment quarantined). Accepts a DurableIndex or a bare
    ShardedUHNSW.

    Re-admission stays with the caller: a restored segment must still
    pass its canary probes (`ShardedUHNSW.canary_probe`) before the
    health tracker returns it to serving.
    """
    index = getattr(index, "index", index)  # unwrap DurableIndex
    snap = latest_durable_snapshot(directory)
    if snap is None:
        return False
    manifest = read_manifest(snap)  # CRC re-verification (commit point)
    npz = np.load(snap / manifest["arrays"]["file"])
    live_ids = np.asarray(index.segments.global_ids[seg], dtype=np.int64)
    for i in range(len(manifest["segments"])):
        ids = np.asarray(npz[f"s{i:04d}.ids"], dtype=np.int64)
        if not np.array_equal(ids, live_ids):
            continue
        rows = np.ascontiguousarray(npz["X"][ids], dtype=np.float32)
        # copy-on-write (mirrors faults.poison_segment): never write into
        # an _X_host that may alias the caller's dataset array
        index._X_host = np.array(index._X_host, dtype=np.float32)
        index._X_host[live_ids] = rows
        index.X = jnp.asarray(index._X_host)
        segs = index.segments
        segs.X = segs.X.at[seg, : len(rows)].set(jnp.asarray(rows))
        # the next compaction restacks from the per-graph data arrays
        segs.graphs1[seg].data = rows
        segs.graphs2[seg].data = rows
        index._band = None        # quantized over the poisoned rows
        index._scan_cache = None
        if index._rt is not None:  # .at[].set dropped the placement
            index.shard_over(index._rt)
        return True
    return False


def recover(directory, params: UHNSWParams | None = None) -> ShardedUHNSW:
    """Newest durable snapshot + durable WAL prefix -> live index.

    Replays every WAL segment in sequence order through `index.add`, so
    mid-log compactions are re-derived deterministically. Records already
    frozen in the snapshot are skipped (id guard); an id *gap* — replay
    would have to invent a missing insert — raises RecoveryError.
    """
    directory = Path(directory)
    snap = latest_durable_snapshot(directory)
    if snap is None:
        raise FileNotFoundError(f"no durable snapshot under {directory}")
    idx = load_snapshot(snap, params=params)
    for seq, path in list_wals(directory):
        batches, clean = replay(path)
        if not clean:
            warnings.warn(f"{path}: torn/corrupt tail — replay stopped at "
                          f"the last durable record", stacklevel=2)
        for ids, vecs in batches:
            for gid, vec in zip(ids, vecs):
                gid = int(gid)
                if gid < idx.n:
                    continue       # already durable in the snapshot
                if gid > idx.n:
                    raise RecoveryError(
                        f"WAL id gap: next insert id is {idx.n} but "
                        f"{path.name} logs id {gid} — a WAL segment is "
                        f"missing; refusing to recover silently")
                idx.add(vec)
    return idx


class DurableIndex:
    """Fault-tolerant lifecycle wrapper around a ShardedUHNSW.

    Every insert is WAL-appended (fsync'd) *before* it touches the index;
    compaction triggers snapshot rotation (new snapshot + fresh WAL
    segment) via the index's `on_compact` hook. Reads and the staged
    search API delegate to the wrapped index, so a DurableIndex drops into
    `UniversalVectorService(index=...)` and `service.insert` rides the WAL
    automatically.

    Args:
      index: the live ShardedUHNSW to wrap (its `on_compact` hook is
        claimed; `close()` releases it).
      directory: snapshot + WAL root; created on first save.
      sync: fsync every WAL append (True, the durable default) or leave
        flushing to the OS (False — faster, loses the tail on power cut).
      keep_snapshots: how many newest snapshots `prune()` retains
        (floored at 1); WALs are kept from one sequence before the
        oldest retained snapshot onward.

    Failure modes: `add`/`add_batch` raise RuntimeError if no WAL is open
    (constructed directly instead of via create/recover); recovery raises
    FileNotFoundError with no durable snapshot and RecoveryError on a WAL
    id gap (see module docstring).
    """

    def __init__(self, index: ShardedUHNSW, directory, sync: bool = True,
                 keep_snapshots: int = 2):
        self.index = index
        self.directory = Path(directory)
        self.sync = sync
        self.keep_snapshots = max(1, int(keep_snapshots))
        snaps = list_snapshots(self.directory)
        self._seq = snaps[-1][0] if snaps else None
        self._wal: WriteAheadLog | None = None
        index.on_compact = self._on_compact

    # -- lifecycle -----------------------------------------------------------

    @classmethod
    def create(cls, index: ShardedUHNSW, directory, sync: bool = True,
               keep_snapshots: int = 2) -> "DurableIndex":
        """Snapshot `index` now and open a WAL for subsequent inserts."""
        dur = cls(index, directory, sync=sync, keep_snapshots=keep_snapshots)
        dur.save()
        return dur

    @classmethod
    def recover(cls, directory, params: UHNSWParams | None = None,
                sync: bool = True, keep_snapshots: int = 2) -> "DurableIndex":
        """Recover from `directory` and re-arm durability: the recovered
        state is immediately re-snapshotted (a fresh durable baseline — a
        WAL with a torn tail is never appended to) and a new WAL opened."""
        idx = recover(directory, params=params)
        return cls.create(idx, directory, sync=sync,
                          keep_snapshots=keep_snapshots)

    def save(self) -> Path:
        """Rotate now: snapshot the current state, open a fresh WAL."""
        seq = 0 if self._seq is None else self._seq + 1
        path = save_snapshot(self.index, self.directory, seq=seq)
        self._seq = seq
        if self._wal is not None:
            self._wal.close()
        self._wal = WriteAheadLog(wal_path(self.directory, seq),
                                  sync=self.sync)
        self.prune()
        return path

    def close(self):
        if self._wal is not None:
            self._wal.close()
            self._wal = None
        if self.index.on_compact == self._on_compact:
            self.index.on_compact = None

    def prune(self):
        """Drop snapshots/WALs no longer needed for fallback recovery.

        Keeps the newest `keep_snapshots` snapshots, and every WAL from
        one sequence *before* the oldest kept snapshot onward — so even if
        the newest snapshot is later found torn, the previous one plus the
        retained WALs still reconstruct the full state (an insert batch
        that straddled a rotation lives in the pre-rotation WAL).
        """
        snaps = list_snapshots(self.directory)
        if len(snaps) > self.keep_snapshots:
            for _, path in snaps[: -self.keep_snapshots]:
                shutil.rmtree(path, ignore_errors=True)
            snaps = snaps[-self.keep_snapshots:]
        if snaps:
            floor = snaps[0][0] - 1
            for seq, path in list_wals(self.directory):
                if seq < floor:
                    path.unlink(missing_ok=True)

    # -- writes --------------------------------------------------------------

    def _on_compact(self):
        self.save()

    def _wal_required(self) -> WriteAheadLog:
        if self._wal is None:
            raise RuntimeError(
                "DurableIndex has no open WAL — construct it with "
                "DurableIndex.create/recover (or call save()) first")
        return self._wal

    def add(self, vec: np.ndarray) -> int:
        """WAL-append, then insert. Durable before it is searchable."""
        wal = self._wal_required()
        gid = self.index.n
        wal.append([gid], np.asarray(vec, np.float32).reshape(1, -1))
        out = self.index.add(vec)
        assert out == gid, (out, gid)
        return out

    def add_batch(self, vecs: np.ndarray) -> list[int]:
        """One fsync for the whole batch (the WAL's amortization unit)."""
        vecs = np.ascontiguousarray(vecs, dtype=np.float32)
        wal = self._wal_required()
        gid0 = self.index.n
        wal.append(np.arange(gid0, gid0 + len(vecs)), vecs)
        return [self.index.add(v) for v in vecs]

    # -- reads delegate to the wrapped index ---------------------------------

    def __getattr__(self, name):
        return getattr(self.index, name)
