"""Write-ahead log for delta-tier inserts (DESIGN.md §9).

The mutable delta buffer is the only index state that changes between
compactions, so it is the only state that needs a log: every `add()` (or
batch of adds) is appended as one CRC-framed record *before* it is applied
to the in-memory index, and the file is fsync'd per append. Recovery
(`repro.index.persist.recover`) replays the durable prefix of the log on
top of the last durable snapshot; because the records carry explicit
global ids and inserts are idempotent under the id guard, replay lands
bit-identically on the state of a never-crashed index.

File format (little-endian):

    header   : 8 bytes  b"UWAL0001"
    record   : 4 bytes  b"UREC"            record magic
               u32      payload length
               u32      crc32(payload)
               payload  u32 count, u32 d,
                        count  x i64 global ids,
                        count*d x f32 vector data

A torn tail (crash mid-append) fails the magic/length/CRC checks and
replay simply stops at the last intact record — torn data is *detected*,
never loaded. Corruption mid-file likewise stops replay; the recovery
layer then notices the global-id gap and refuses to proceed silently.
"""

from __future__ import annotations

import os
import struct
import zlib
from pathlib import Path

import numpy as np

WAL_HEADER = b"UWAL0001"
RECORD_MAGIC = b"UREC"
_REC_HDR = struct.Struct("<4sII")      # magic, payload_len, crc32
_PAYLOAD_HDR = struct.Struct("<II")    # count, d
# sanity bound on a single record: 1M vectors x 4k dims would be absurd
# for a delta batch; anything larger is treated as corruption.
MAX_PAYLOAD = 1 << 31


class WalCorruption(RuntimeError):
    """A WAL file failed a structural check (bad header)."""


def wal_path(directory, seq: int) -> Path:
    return Path(directory) / f"wal_{seq:08d}.log"


def list_wals(directory) -> list[tuple[int, Path]]:
    """All WAL segments under `directory`, ascending by sequence number."""
    directory = Path(directory)
    if not directory.exists():
        return []
    out = []
    for p in directory.iterdir():
        if p.is_file() and p.name.startswith("wal_") \
                and p.name.endswith(".log"):
            try:
                out.append((int(p.name[4:-4]), p))
            except ValueError:
                continue
    return sorted(out)


def _pack_record(ids: np.ndarray, vecs: np.ndarray) -> bytes:
    count, d = vecs.shape
    payload = (_PAYLOAD_HDR.pack(count, d)
               + np.ascontiguousarray(ids, dtype=np.int64).tobytes()
               + np.ascontiguousarray(vecs, dtype=np.float32).tobytes())
    return _REC_HDR.pack(RECORD_MAGIC, len(payload),
                         zlib.crc32(payload)) + payload


class WriteAheadLog:
    """Append-only CRC-framed insert log with fsync-per-batch durability.

    `path` is created (with the 8-byte header) on first open of an empty
    or missing file; an existing file is opened append-only, so re-opening
    a live segment never rewrites history. `sync=False` skips the fsync
    (still flushes to the OS) for tests and throwaway runs; production
    appends are durable before `append` returns, which is what makes the
    write-*ahead* ordering meaningful. Usable as a context manager
    (closes on exit); `append` after `close()` raises (file is closed).
    """

    def __init__(self, path, sync: bool = True):
        self.path = Path(path)
        self.sync = sync
        new = not self.path.exists() or self.path.stat().st_size == 0
        self._f = open(self.path, "ab")
        if new:
            self._f.write(WAL_HEADER)
            self._flush()

    def _flush(self):
        self._f.flush()
        if self.sync:
            os.fsync(self._f.fileno())

    def append(self, ids, vecs) -> int:
        """Durably log one insert batch. Returns the file size afterwards
        (the record boundary — crash-consistency tests truncate at these).

        ids: (c,) int-like global ids (stored i64). vecs: (c, d) f32 (a
        single (d,) vector is promoted to (1, d)). One CRC-framed record
        + one fsync per call — `DurableIndex.add_batch` rides this as its
        amortization unit. Raises AssertionError on a length mismatch
        between ids and vecs.
        """
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        vecs = np.ascontiguousarray(vecs, dtype=np.float32)
        if vecs.ndim == 1:
            vecs = vecs[None, :]
        assert len(ids) == len(vecs), (len(ids), len(vecs))
        self._f.write(_pack_record(ids, vecs))
        self._flush()
        return self._f.tell()

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def replay(path) -> tuple[list[tuple[np.ndarray, np.ndarray]], bool]:
    """Read the durable prefix of one WAL file.

    Returns (batches, clean): `batches` is a list of (ids (c,) i64,
    vecs (c, d) f32) in append order; `clean` is False when the file ends
    in a torn or corrupt record (replay stops at the last intact one —
    the crash-consistency contract) and True when every byte parsed.

    Raises WalCorruption only for a bad *file header* — that means the
    path is not a WAL at all, which is a caller bug, not a torn write.
    """
    path = Path(path)
    data = path.read_bytes()
    if len(data) < len(WAL_HEADER):
        return [], False
    if data[: len(WAL_HEADER)] != WAL_HEADER:
        raise WalCorruption(f"{path} does not start with a WAL header")
    batches: list[tuple[np.ndarray, np.ndarray]] = []
    off = len(WAL_HEADER)
    while off < len(data):
        if off + _REC_HDR.size > len(data):
            return batches, False          # torn record header
        magic, length, crc = _REC_HDR.unpack_from(data, off)
        if magic != RECORD_MAGIC or length > MAX_PAYLOAD \
                or length < _PAYLOAD_HDR.size:
            return batches, False          # corrupt framing
        start = off + _REC_HDR.size
        payload = data[start: start + length]
        if len(payload) < length or zlib.crc32(payload) != crc:
            return batches, False          # torn / corrupt payload
        count, d = _PAYLOAD_HDR.unpack_from(payload, 0)
        need = _PAYLOAD_HDR.size + count * 8 + count * d * 4
        if need != length:
            return batches, False          # inconsistent payload sizing
        ids = np.frombuffer(payload, dtype=np.int64, count=count,
                            offset=_PAYLOAD_HDR.size)
        vecs = np.frombuffer(
            payload, dtype=np.float32, count=count * d,
            offset=_PAYLOAD_HDR.size + count * 8,
        ).reshape(count, d)
        batches.append((ids.copy(), vecs.copy()))
        off = start + length
    return batches, True
