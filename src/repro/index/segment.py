"""Dataset partitioning + per-segment graph construction (DESIGN.md §3).

A segment is an independently-built U-HNSW pair (G1 under L1, G2 under L2)
over a random subset of the corpus. Random (not clustered) partitioning is
deliberate: every segment is then a uniform sample of the data distribution,
so each per-segment top-t candidate list is an unbiased cover of the global
top-k and the merge loses no recall (cf. the sharded-HNSW recipe in the
graph-ANNS survey, PAPERS.md).

All segments are padded to one uniform shape (GraphArrays.pad_to) and
stacked on a leading (S,) axis (GraphArrays.stack) so the batched beam
search vmaps across segments as a single device program — same-shaped
segments are what turn S independent graph traversals into one SPMD kernel.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.build import HNSWGraph, build_hnsw, build_hnsw_bulk
from repro.core.hnsw import GraphArrays

# below this size the sequential (faithful) builder is both faster to warm up
# and higher quality; above it the batched bulk builder wins
BULK_THRESHOLD = 512

# segment build methods (DESIGN.md §7): "bulk" is the device-side shared-pass
# builder (G1+G2 from one candidate-generation pass), "bulk_host" the older
# vectorized NumPy per-graph builder, "incremental" the paper-faithful
# sequential insertion.
BUILD_METHODS = ("incremental", "bulk", "bulk_host")


def partition_dataset(n: int, num_segments: int, seed: int = 0) -> list[np.ndarray]:
    """Random balanced partition of [0, n) into `num_segments` id arrays."""
    assert 1 <= num_segments <= n, (num_segments, n)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(part).astype(np.int64) for part in
            np.array_split(perm, num_segments)]


def resolve_build_method(n: int, bulk: bool | None = None,
                         method: str | None = None) -> str:
    """Pick a segment build method.

    `method` (one of BUILD_METHODS) wins when given; else the legacy `bulk`
    flag maps True -> "bulk", False -> "incremental"; else auto by size
    (incremental below BULK_THRESHOLD, device bulk above).
    """
    if method is not None:
        if method not in BUILD_METHODS:
            raise ValueError(
                f"unknown build method {method!r} (options: {BUILD_METHODS})")
        return method
    if bulk is not None:
        return "bulk" if bulk else "incremental"
    return "bulk" if n >= BULK_THRESHOLD else "incremental"


def build_segment_pair(
    data: np.ndarray, m: int, seed: int, bulk: bool | None = None,
    method: str | None = None,
):
    """Build one segment's (G1, G2) over `data` (local ids)."""
    method = resolve_build_method(len(data), bulk=bulk, method=method)
    if method == "bulk":
        from repro.core.bulk_build import build_bulk_pair

        return build_bulk_pair(data, m=m, seed=seed)
    if method == "bulk_host":
        g1 = build_hnsw_bulk(data, 1.0, m=m, seed=seed)
        g2 = build_hnsw_bulk(data, 2.0, m=m, seed=seed + 1)
    else:
        efc = min(200, max(16, 4 * m))
        g1 = build_hnsw(data, 1.0, m=m, ef_construction=efc, seed=seed)
        g2 = build_hnsw(data, 2.0, m=m, ef_construction=efc, seed=seed + 1)
    return g1, g2


def _stack_uniform(graphs: list[HNSWGraph]) -> GraphArrays:
    """pad_to every graph to the common shape envelope, then stack."""
    arrays = [GraphArrays.from_graph(g) for g in graphs]
    n_pad = max(a.n for a in arrays)
    n_levels = max(len(a.upper_adj) for a in arrays)
    upper_m = max((g.m for g in graphs), default=0) or None
    level_sizes = tuple(
        max((a.upper_adj[l].shape[0] for a in arrays if l < len(a.upper_adj)),
            default=1)
        for l in range(n_levels)
    )
    padded = [a.pad_to(n_pad, n_levels, level_sizes, upper_m=upper_m)
              for a in arrays]
    return GraphArrays.stack(padded)


@dataclass
class SegmentedGraphs:
    """S frozen segments, stacked for vmapped traversal.

    Host-side state (graphs, global_ids) persists so new segments can join
    (delta compaction) — appending restacks the device arrays to the new
    shape envelope; the per-segment graphs themselves never rebuild.
    """

    graphs1: list[HNSWGraph]          # per-segment G1 (L1)
    graphs2: list[HNSWGraph]          # per-segment G2 (L2)
    global_ids: list[np.ndarray]      # per-segment local -> global id map
    # stacked device state (derived; rebuilt by _restack):
    arrays1: GraphArrays = field(init=False)
    arrays2: GraphArrays = field(init=False)
    X: jax.Array = field(init=False)          # (S, n_pad, d) segment data
    node_ids: jax.Array = field(init=False)   # (S, n_pad) int32, -1 pad

    def __post_init__(self):
        self._restack()

    @property
    def num_segments(self) -> int:
        return len(self.graphs1)

    @property
    def n_pad(self) -> int:
        return self.arrays1.n

    def _restack(self):
        self.arrays1 = _stack_uniform(self.graphs1)
        self.arrays2 = _stack_uniform(self.graphs2)
        n_pad = max(self.arrays1.n, self.arrays2.n)
        d = self.graphs1[0].d
        s = self.num_segments
        X = np.zeros((s, n_pad, d), dtype=np.float32)
        node_ids = np.full((s, n_pad), -1, dtype=np.int32)
        for i, (g, ids) in enumerate(zip(self.graphs1, self.global_ids)):
            X[i, : g.n] = g.data
            node_ids[i, : g.n] = ids
        self.X = jnp.asarray(X)
        self.node_ids = jnp.asarray(node_ids)

    def append(self, g1: HNSWGraph, g2: HNSWGraph, global_ids: np.ndarray):
        """Add a frozen segment (delta compaction) and restack."""
        assert g1.n == g2.n == len(global_ids)
        self.graphs1.append(g1)
        self.graphs2.append(g2)
        self.global_ids.append(np.asarray(global_ids, dtype=np.int64))
        self._restack()

    def index_size_bytes(self) -> int:
        return sum(g.index_size_bytes() for g in self.graphs1 + self.graphs2)


def build_segments(
    data: np.ndarray,
    num_segments: int = 4,
    m: int = 16,
    seed: int = 0,
    bulk: bool | None = None,
    method: str | None = None,
) -> SegmentedGraphs:
    """Partition `data` and build every segment's G1/G2 pair.

    Per-segment builds are independent (parallelizable across hosts at
    production scale — the sequential global insert order of monolithic HNSW
    is the scaling bottleneck this removes). `method` / `bulk` select the
    per-segment builder (see `resolve_build_method`); the device bulk path
    additionally builds each segment's G1 and G2 from one shared
    candidate-generation pass (DESIGN.md §7).
    """
    data = np.ascontiguousarray(data, dtype=np.float32)
    parts = partition_dataset(len(data), num_segments, seed=seed)
    graphs1, graphs2, global_ids = [], [], []
    for i, ids in enumerate(parts):
        g1, g2 = build_segment_pair(data[ids], m=m, seed=seed + 17 * i,
                                    bulk=bulk, method=method)
        graphs1.append(g1)
        graphs2.append(g2)
        global_ids.append(ids)
    return SegmentedGraphs(graphs1=graphs1, graphs2=graphs2,
                           global_ids=global_ids)
