"""Mutable delta tier for streaming inserts (DESIGN.md §3).

Graph indexes are cheap to query but expensive to mutate; the standard
serving design is therefore frozen segments + a small mutable delta buffer.
`add()` is O(1) (append); queries brute-force the delta under *exact* Lp via
the Lp dispatch entry point (repro.kernels.ops.lp_gather_distance) — exact
distances, so delta hits need no verification pass and merge directly with
the verified graph top-k.
When the buffer reaches capacity it compacts: the owner (ShardedUHNSW)
builds a new frozen segment from the buffered vectors and clears the buffer.

Because the delta scan is exact, a freshly-added vector is findable at every
p immediately — there is no index-lag window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class DeltaBuffer:
    """Append-only vector buffer with exact-Lp search.

    Global ids are assigned by the owner at add() time (`base_id + slot`)
    and stay stable across compaction — the compacted segment reuses them.
    """

    def __init__(self, d: int, capacity: int = 1024):
        assert capacity >= 1
        self.d = d
        self.capacity = capacity
        self._vecs: list[np.ndarray] = []
        self._ids: list[int] = []
        self._cache: jax.Array | None = None  # device copy, invalidated on add

    def __len__(self) -> int:
        return len(self._vecs)

    @property
    def full(self) -> bool:
        return len(self._vecs) >= self.capacity

    def add(self, vec: np.ndarray, global_id: int) -> int:
        v = np.asarray(vec, dtype=np.float32).reshape(-1)
        assert v.shape == (self.d,), (v.shape, self.d)
        self._vecs.append(v)
        self._ids.append(int(global_id))
        self._cache = None
        return global_id

    def vectors(self) -> np.ndarray:
        """(n_delta, d) snapshot (host)."""
        if not self._vecs:
            return np.zeros((0, self.d), dtype=np.float32)
        return np.stack(self._vecs)

    def ids(self) -> np.ndarray:
        return np.asarray(self._ids, dtype=np.int32)

    def restore(self, vecs: np.ndarray, ids: np.ndarray) -> None:
        """Bulk re-load buffered contents (snapshot recovery path).

        Appends in order with the saved global ids, so a restored buffer is
        indistinguishable from one that reached this state through `add`.
        """
        vecs = np.asarray(vecs, dtype=np.float32)
        assert len(vecs) == len(ids), (len(vecs), len(ids))
        for v, gid in zip(vecs, ids):
            self.add(v, int(gid))

    def drain(self) -> tuple[np.ndarray, np.ndarray]:
        """Return (vectors, ids) and empty the buffer (compaction step)."""
        vecs, ids = self.vectors(), self.ids()
        self._vecs, self._ids, self._cache = [], [], None
        return vecs, ids

    def search(self, Q: jax.Array, p, interpret: bool | None = None,
               thresh: jax.Array | None = None, block_d: int | None = None,
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """Exact rooted Lp distances of every buffered vector to each query.

        Q: (B, d) f32. p: Python float or (B,) array — row i of a mixed-p
        batch is scored under p[i] (the scalar-vs-vector contract,
        DESIGN.md §6). Returns (ids (B, n_delta) int32 global, dists
        (B, n_delta) f32, nd (B, n_delta) int32 dimensions scanned).
        Empty buffer -> (B, 0) arrays, so callers can concatenate blindly.

        With `thresh` (per-query rooted k-th-best distances from the
        already-verified graph top-k) the scan routes through the
        early-abandoning blocked kernel (kernels/ops.lp_gather_abandon,
        DESIGN.md §8): buffered vectors whose partial power sum already
        exceeds the bound score +inf and skip their remaining dimension
        blocks — exact, since they provably cannot enter the top-k. The
        rooted threshold is un-rooted with a 1e-4 inflation so the
        root/power float round trip can never abandon a true top-k entry.

        Without `thresh` scoring stays on the exact-Lp dispatch entry
        point (kernels/ops.lp_gather_distance) in its 1-D shared-ids form,
        which runs as one pairwise block over the once-gathered buffer (no
        per-query re-gather; p=2 keeps its MXU matmul). `interpret`
        forwards to the dispatcher either way.
        """
        b = Q.shape[0]
        if not self._vecs:
            z = jnp.zeros((b, 0))
            return z.astype(jnp.int32), z, z.astype(jnp.int32)
        if self._cache is None:
            self._cache = jnp.asarray(self.vectors())
        n_delta = len(self._vecs)
        d = self.d
        ids = jnp.broadcast_to(jnp.asarray(self.ids())[None, :],
                               (b, n_delta))
        if thresh is not None:
            from repro.core.lp_ops import pow_from_abs
            from repro.kernels.ops import lp_gather_abandon

            rows2d = jnp.broadcast_to(
                jnp.arange(n_delta, dtype=jnp.int32)[None, :], (b, n_delta))
            thr_pow = pow_from_abs(jnp.asarray(thresh, jnp.float32),
                                   jnp.asarray(p, jnp.float32)) * (1 + 1e-4)
            dists, nd = lp_gather_abandon(
                Q, rows2d, self._cache, thr_pow,
                jnp.zeros((b, n_delta), jnp.float32), p, root=True,
                interpret=interpret, block_d=block_d,
            )
            return ids, dists, nd
        from repro.kernels.ops import lp_gather_distance

        rows = jnp.arange(n_delta, dtype=jnp.int32)
        dists = lp_gather_distance(Q, rows, self._cache, p, root=True,
                                   interpret=interpret)
        return ids, dists, jnp.full((b, n_delta), d, jnp.int32)
