"""Compressed storage band with certified Lp lower bounds (DESIGN.md §10).

The verification stage gathers full f32 rows for every candidate a kappa
batch offers. Because Lp is coordinate-separable, an int8 replica of the
corpus admits *exact per-coordinate* error bounds: with dequantized value
x̂_j = scale_j * code_j and a per-coordinate radius

    radius_j >= |x_j - x̂_j|   for every row x in the corpus,

the reverse triangle inequality gives, coordinate by coordinate,

    |q_j - x_j| >= max(|q_j - x̂_j| - radius_j, 0),

and monotonicity of t -> t^p lifts the inequality through the power sum —
so a blocked power sum over compressed rows minus the accumulated radius
term is a certified lower bound on the true f32 power-sum distance (the
same admissibility style as `lp_entry_bound`/`lp_suffix_bound`, applied
to a storage tier). The two-band scan (core/uhnsw._verify_two_band_impl)
screens candidates against the running k-th best using this bound and
gathers f32 rows only for survivors.

Coordinates are stored in *energy order* (decreasing per-coordinate
variance): Lp is coordinate-separable, so a fixed permutation is bit-exact
after unpermuting, and front-loading the mass makes both the compressed
screen and the PR-5 suffix bounds go dead after fewer blocks at small p.

Quantization is the symmetric per-coordinate affine scheme of
`train/compression.py::quantize_params` (prior art): one f32 scale per
coordinate, codes in [-127, 127]. Radii are computed *exactly* in f32 as
the max dequantization error over the corpus — the scan evaluates the
identical dequant expression `codes.astype(f32) * scale`, so the radius
covers every row bit-for-bit; accumulated f32 rounding in the blocked sum
is dwarfed by the BOUND_SLACK deflation applied at comparison time.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lp_ops import is_static_p, pow_from_abs


@dataclass(frozen=True)
class CompressedBand:
    """Device-resident int8 replica of a frozen corpus, in energy order.

    Attributes:
      codes: (n, d) int8 — quantized corpus, coordinate j of the band is
        original coordinate `perm[j]` (energy order).
      scale: (d,) f32 — per-coordinate dequant scales (band order);
        x̂ = codes.astype(f32) * scale.
      radius: (d,) f32 — exact per-coordinate max dequant error over the
        corpus (band order): max_i |Xp[i, j] - scale[j] * codes[i, j]|.
      perm: (d,) int32 — band coord j = original coord perm[j]. Queries
        enter the screen as Q[:, perm]; results never need unpermuting
        (the screen emits keep decisions, not distances).
    """

    codes: jax.Array
    scale: jax.Array
    radius: jax.Array
    perm: jax.Array

    @property
    def n(self) -> int:
        return int(self.codes.shape[0])

    @property
    def d(self) -> int:
        return int(self.codes.shape[1])

    def nbytes(self) -> int:
        """Band storage footprint (codes + scales + radii + perm)."""
        return self.n * self.d + 3 * 4 * self.d


def energy_order(X) -> np.ndarray:
    """(d,) int32 permutation: coordinates by decreasing variance.

    Stable (ties keep their original order), computed on host in f64 so
    the ordering is deterministic across backends. Constant coordinates
    (zero variance) sink to the tail, where the suffix bounds lose
    nothing by scanning them last.
    """
    var = np.var(np.asarray(X, dtype=np.float64), axis=0)
    # argsort of -var is stable under kind="stable": equal-variance coords
    # keep ascending original index, matching jnp.take round-trip tests
    return np.argsort(-var, kind="stable").astype(np.int32)


def build_band(X, perm: np.ndarray | None = None) -> CompressedBand:
    """Quantize a frozen corpus into its compressed band.

    X: (n, d) f32 (host or device). perm: optional (d,) coordinate
    permutation; None derives the energy order. Returns a device-resident
    CompressedBand whose radii are exact f32 maxima of the dequant error,
    so the screen's per-coordinate bound is admissible for every row.

    Deterministic: same X -> bit-identical band (compaction and snapshot
    recovery rebuild it and land on the same bytes).
    """
    Xh = np.ascontiguousarray(np.asarray(X), dtype=np.float32)
    n, d = Xh.shape
    if perm is None:
        perm = energy_order(Xh)
    perm = np.asarray(perm, dtype=np.int32)
    assert perm.shape == (d,), (perm.shape, d)
    Xp = np.ascontiguousarray(Xh[:, perm])
    # symmetric per-coordinate affine quantization (train/compression.py):
    # scale = max|col| / 127, codes = round(col / scale) in [-127, 127]
    absmax = np.abs(Xp).max(axis=0) if n else np.zeros(d, np.float32)
    scale = (np.maximum(absmax, 1e-12) / 127.0).astype(np.float32)
    codes = np.clip(np.round(Xp / scale), -127, 127).astype(np.int8)
    # exact f32 radii over the SAME dequant expression the scan evaluates
    dequant = (codes.astype(np.float32) * scale).astype(np.float32)
    err = np.abs(Xp - dequant)
    radius = (err.max(axis=0) if n else np.zeros(d)).astype(np.float32)
    return CompressedBand(
        codes=jnp.asarray(codes),
        scale=jnp.asarray(scale),
        radius=jnp.asarray(radius),
        perm=jnp.asarray(perm),
    )


def compressed_lower_bound(qp: jax.Array, codes: jax.Array,
                           scale: jax.Array, radius: jax.Array,
                           p) -> jax.Array:
    """Certified lower bound on the f32 Lp power sum, full-dimension form.

    qp: (B, d) queries in band (permuted) coordinate order; codes: (C, d)
    int8 band rows; scale/radius: (d,) f32. p: Python float or (B,)
    per-row array (the scalar-vs-vector contract, DESIGN.md §6). Returns
    (B, C) f32 — the un-deflated bound sum_j max(|q_j - x̂_j| - r_j, 0)^p,
    which real-arithmetic admissibility puts at or below the true power
    sum (the scan deflates by BOUND_SLACK before comparing, absorbing the
    accumulated f32 rounding of both sides).

    This is the property-test oracle for the blocked screen (kernels/
    ref.gather_lp_screen_ref accumulates exactly these per-block terms).
    """
    xh = codes.astype(jnp.float32) * scale[None, :]        # (C, d)
    a = jnp.abs(qp[:, None, :] - xh[None, :, :])           # (B, C, d)
    a = jnp.maximum(a - radius[None, None, :], 0.0)
    p_b = float(p) if is_static_p(p) else jnp.asarray(p)[:, None, None]
    return jnp.sum(pow_from_abs(a, p_b), axis=-1)
