"""Per-segment health tracking for degraded-coverage serving (DESIGN.md §11).

A ShardedUHNSW's frozen segments are its failure domains: a segment's
device copy can be lost (preemption), its rows corrupted (a poisoned
gather path), or its device calls can start failing transiently. Before
PR 10 the index was all-or-nothing — one bad segment poisoned or failed
every query that touched it. This module gives each segment a tiny
state machine so the rest of the index keeps serving, at *known,
reported* coverage:

      HEALTHY ──(failure EWMA ≥ suspect_threshold)──▶ SUSPECT
      SUSPECT ──(failure EWMA ≥ quarantine_threshold)▶ QUARANTINED
      SUSPECT ──(EWMA decays below suspect)──────────▶ HEALTHY
      QUARANTINED ──(restore begins)─────────────────▶ RECOVERING
      RECOVERING ──(canary probes pass)──────────────▶ HEALTHY
      RECOVERING ──(restore/probe fails)─────────────▶ QUARANTINED

HEALTHY and SUSPECT segments serve queries (SUSPECT is a warning level,
not an exclusion); QUARANTINED and RECOVERING segments are masked out of
the vmapped search (`ShardedUHNSW` reads `alive_mask()` per query), and
every result reports the exact fraction of the corpus it actually
searched (`SearchStats.coverage_frac`).

Two paths into quarantine:

  * **EWMA**: transient per-segment device faults (`record_failure`,
    e.g. the engine attributing an `InjectedSegmentFault`) drive the
    exponentially-weighted failure rate up through SUSPECT into
    QUARANTINED; successes decay it back.
  * **direct**: `quarantine(seg)` — the engine's poison bisection
    (DESIGN.md §11) attributes a NaN-poisoned result to one segment in
    O(log S) probes and quarantines it immediately.

Re-admission is gated on **canary probes**: after a segment's rows are
restored from the latest durable snapshot (CRC re-verified,
`persist.restore_segment`), `ShardedUHNSW.canary_probe` self-queries
segment members (top-1 must be the member itself, at a finite
distance, with the NaN guard clean) `probe_successes` times before
`readmit` returns the segment to serving.

Every transition that changes the serving set bumps `generation`, which
keys the index's host-side policy caches (phase sub-stacks) and tells
the engine a retried wave will see a different mask.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"
RECOVERING = "recovering"

STATES = (HEALTHY, SUSPECT, QUARANTINED, RECOVERING)

# states that serve traffic (feed the alive mask)
SERVING_STATES = (HEALTHY, SUSPECT)


@dataclass(frozen=True)
class HealthPolicy:
    """Knobs for the per-segment failure state machine.

    ewma_alpha: weight of the newest observation in the failure EWMA
      (higher = faster reaction, noisier). Must be in (0, 1].
    suspect_threshold: EWMA failure rate at which a HEALTHY segment
      becomes SUSPECT (still serving — a warning level).
    quarantine_threshold: EWMA at which a SUSPECT segment is pulled
      from serving. Must be >= suspect_threshold.
    probe_successes: consecutive canary-probe passes required before a
      RECOVERING segment is re-admitted.
    """

    ewma_alpha: float = 0.3
    suspect_threshold: float = 0.3
    quarantine_threshold: float = 0.7
    probe_successes: int = 2

    def __post_init__(self):
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")
        if not 0.0 < self.suspect_threshold <= self.quarantine_threshold:
            raise ValueError(
                f"need 0 < suspect_threshold <= quarantine_threshold, got "
                f"{self.suspect_threshold} / {self.quarantine_threshold}")
        if self.probe_successes < 1:
            raise ValueError(
                f"probe_successes must be >= 1, got {self.probe_successes}")


class SegmentHealthTracker:
    """The per-segment state machine + failure EWMA (module docstring).

    Host-side and cheap: O(S) python state, consulted once per search to
    build the alive mask. Not thread-safe (the serving engine drives it
    from its single pump loop).
    """

    def __init__(self, num_segments: int, policy: HealthPolicy | None = None):
        self.policy = policy or HealthPolicy()
        self.states: list[str] = [HEALTHY] * int(num_segments)
        self.ewma: list[float] = [0.0] * int(num_segments)
        self._probe_streak: list[int] = [0] * int(num_segments)
        # bumps whenever the serving set changes: callers key caches on it
        self.generation = 0
        self.counters = {
            "quarantined": 0,    # transitions into QUARANTINED (any path)
            "recovered": 0,      # RECOVERING -> HEALTHY re-admissions
            "probes": 0,         # canary probes run
            "failures": 0,       # per-segment failures recorded
        }

    # -- observation ---------------------------------------------------------

    @property
    def num_segments(self) -> int:
        return len(self.states)

    def resize(self, num_segments: int) -> None:
        """Grow to a compacted segment count; new segments start HEALTHY
        and existing states (quarantines included) are preserved."""
        grow = int(num_segments) - len(self.states)
        if grow < 0:
            raise ValueError(
                f"segment count cannot shrink ({len(self.states)} -> "
                f"{num_segments}); segments are append-only")
        self.states += [HEALTHY] * grow
        self.ewma += [0.0] * grow
        self._probe_streak += [0] * grow

    def state(self, seg: int) -> str:
        return self.states[seg]

    def alive(self) -> list[int]:
        """Segment indices currently serving (HEALTHY or SUSPECT)."""
        return [i for i, s in enumerate(self.states) if s in SERVING_STATES]

    def quarantined(self) -> list[int]:
        return [i for i, s in enumerate(self.states) if s == QUARANTINED]

    def alive_mask(self) -> np.ndarray:
        """(S,) bool mask over the stacked segment axis."""
        return np.asarray([s in SERVING_STATES for s in self.states])

    def coverage(self, sizes: list[int], extra: int = 0) -> float:
        """Exact served fraction of the corpus: alive frozen rows plus
        `extra` (the always-served delta tier) over the total."""
        total = sum(sizes) + extra
        if total <= 0:
            return 1.0
        live = sum(n for i, n in enumerate(sizes)
                   if self.states[i] in SERVING_STATES)
        return (live + extra) / total

    # -- EWMA transitions ----------------------------------------------------

    def record_success(self, seg: int) -> str:
        """A clean device interaction touching `seg`: decay its EWMA, and
        let a SUSPECT segment return to HEALTHY once it decays back under
        the suspect threshold."""
        a = self.policy.ewma_alpha
        self.ewma[seg] = (1.0 - a) * self.ewma[seg]
        if self.states[seg] == SUSPECT \
                and self.ewma[seg] < self.policy.suspect_threshold:
            self.states[seg] = HEALTHY
        return self.states[seg]

    def record_failure(self, seg: int) -> str:
        """A device failure attributed to `seg` (e.g. an injected
        per-segment fault site): bump the EWMA and walk the state machine
        HEALTHY -> SUSPECT -> QUARANTINED as thresholds are crossed."""
        a = self.policy.ewma_alpha
        self.ewma[seg] = (1.0 - a) * self.ewma[seg] + a
        self.counters["failures"] += 1
        st = self.states[seg]
        if st == HEALTHY and self.ewma[seg] >= self.policy.suspect_threshold:
            self.states[seg] = SUSPECT
            st = SUSPECT
        if st == SUSPECT \
                and self.ewma[seg] >= self.policy.quarantine_threshold:
            self._enter_quarantine(seg)
        return self.states[seg]

    # -- direct transitions (poison attribution + recovery) ------------------

    def _enter_quarantine(self, seg: int) -> None:
        self.states[seg] = QUARANTINED
        self._probe_streak[seg] = 0
        self.counters["quarantined"] += 1
        self.generation += 1

    def quarantine(self, seg: int) -> None:
        """Pull `seg` from serving immediately (the engine's poison
        bisection lands here; also RECOVERING segments that fail their
        restore or canary probes). Idempotent."""
        if self.states[seg] != QUARANTINED:
            self._enter_quarantine(seg)

    def begin_recovery(self, seg: int) -> None:
        """QUARANTINED -> RECOVERING (a restore is in progress; the
        segment stays out of the serving set until re-admitted)."""
        if self.states[seg] != QUARANTINED:
            raise ValueError(
                f"segment {seg} is {self.states[seg]}, not quarantined")
        self.states[seg] = RECOVERING

    def record_probe(self, seg: int, ok: bool) -> int:
        """One canary-probe outcome for a RECOVERING segment. Returns the
        current pass streak (a failure resets it to zero)."""
        self.counters["probes"] += 1
        self._probe_streak[seg] = self._probe_streak[seg] + 1 if ok else 0
        return self._probe_streak[seg]

    def probe_passed(self, seg: int) -> bool:
        """Has `seg` accumulated enough consecutive canary passes?"""
        return self._probe_streak[seg] >= self.policy.probe_successes

    def readmit(self, seg: int) -> None:
        """RECOVERING -> HEALTHY after the canary gate. Resets the EWMA —
        the restored rows are a fresh copy, old failures don't carry."""
        if self.states[seg] != RECOVERING:
            raise ValueError(
                f"segment {seg} is {self.states[seg]}, not recovering")
        if not self.probe_passed(seg):
            raise ValueError(
                f"segment {seg} has probe streak {self._probe_streak[seg]} "
                f"< required {self.policy.probe_successes}")
        self.states[seg] = HEALTHY
        self.ewma[seg] = 0.0
        self._probe_streak[seg] = 0
        self.counters["recovered"] += 1
        self.generation += 1

    # -- reporting -----------------------------------------------------------

    def summary(self) -> dict:
        """Operator-facing snapshot (latency_summary / launch.serve)."""
        by_state = {s: 0 for s in STATES}
        for s in self.states:
            by_state[s] += 1
        return {
            "segments": len(self.states),
            "by_state": by_state,
            "generation": self.generation,
            **{k: int(v) for k, v in self.counters.items()},
        }
