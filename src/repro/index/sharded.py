"""ShardedUHNSW: segmented U-HNSW with one merged verification pass.

Query path (DESIGN.md §3):

  1. Candidate generation — policy-dependent (`ShardedParams.policy`):

     * "independent" (default): every segment runs a fully independent
       beam (the pre-threshold behavior; the exhaustive reference the
       other policies are measured against).
     * "two_phase": phase A probes a prior-ordered subset of
       segments (largest/oldest first, `probe` of them) with the full
       beam; its merged k-th-best base distance becomes the *inherited
       pruning threshold* for phase B, which searches the remaining
       segments with a shrunken beam whose admission is cut at the bound
       (core/hnsw.knn_search `thresh`). Pruning is admissible for the
       merged top-t whenever the threshold rank r satisfies
       (S / probe) * r >= t — the bound then upper-bounds the global
       t-th-best, so no pruned candidate could have entered the merged
       list (`resolve_thresh_rank` picks r accordingly).
     * "round_robin": single-phase cascade — every segment takes its turn
       in prior order with the full beam, inheriting the running merged
       k-th-best of all earlier turns as its threshold (first turn
       unthresholded). Maximum pruning, S sequential device calls.

     Per-segment searches `jax.vmap` over the stacked (S,) segment axis of
     the selected base graph (G1 for p <= 1.4, G2 otherwise); the segment
     axis shards over the mesh's data axes (`shard_over`).
  2. Merge — per-segment top-t lists (already ascending) concatenate and a
     single `lax.sort` keeps the global top-t under the base metric.
     Segments hold disjoint ids, so no dedup is needed.
  3. Verification — ONE `verify_candidates` pass over the merged list.
     Running verification after the merge (not per segment) preserves the
     paper's early-termination N_p savings end-to-end: the convergence test
     sees the same globally-ordered candidate stream a monolithic index
     would produce.
  4. Delta merge — exact rooted-Lp distances for the mutable delta buffer
     (repro.index.delta) sort-merge into the verified top-k. Exactness means
     no verification is owed for delta hits; with abandonment on, the scan
     inherits the verified k-th-best as its threshold (DESIGN.md §8).

Streaming inserts: `add()` appends to the delta buffer; at capacity the
buffer compacts into a new frozen segment — built with the index's build
method (DESIGN.md §7; by default the batched bulk builder once the buffer
holds >= BULK_THRESHOLD vectors), stacks re-pad — and the cycle repeats.
Ids are assigned once and never change.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.hnsw import GraphArrays, knn_search
from repro.core.metrics import base_metric_for
from repro.core.uhnsw import (
    CandidateSet,
    SearchStats,
    UHNSWParams,
    mask_base_rows,
    modeled_query_cost,
    two_way_mixed_search,
    verify_candidates,
)
from repro.index.delta import DeltaBuffer
from repro.index.health import SegmentHealthTracker
from repro.index.segment import SegmentedGraphs, build_segment_pair, build_segments


@dataclass(frozen=True)
class ShardedParams:
    """Cross-segment search policy knobs (DESIGN.md §3).

    Frozen dataclass; invalid values raise ValueError at construction
    (`__post_init__`), never at query time.

    Attributes:
      policy: str — one of POLICIES. "independent" (the default — no
        cross-segment state; every segment runs a fully independent beam,
        the exhaustive reference the bench's ids-equal gate compares
        against), "two_phase" (probe + threshold-pruned spill — the
        cheap cross-segment policy the bench flags), or "round_robin"
        (single-phase cascade, every turn inherits the running bound).
        The default stays exhaustive because threshold pruning trades a
        bounded recall loss for N_b; deployments opt in per index
        (benchmarks/sharded_index.py quantifies the trade). Any other
        string raises ValueError.
      probe: int >= 1 — number of prior-ordered segments phase A
        searches with the full beam (two_phase only; the prior order is
        `ShardedUHNSW._probe_order`, largest segments first). Clamped to
        [1, S-1] at query time; with S == 1 or probe >= S every policy
        degenerates to independent. probe < 1 raises ValueError.
      ef_shrink: float in (0, 1] — phase-B beam-width multiplier,
        floored at the spill t (two_phase only — round_robin keeps the
        full beam every turn and relies on the threshold admission cut
        alone). Out-of-range raises ValueError.
      thresh_rank: int | None — rank r of the inherited running k-th
        best used as the pruning bound; None derives
        max(k, ceil(t * probe / S)) — the smallest rank that keeps
        pruning admissible for the merged top-t (see the module
        docstring) while never pruning inside the caller's top-k.
        Clamped to [1, t] by `resolve_thresh_rank`.
    """

    policy: str = "independent"
    probe: int = 1
    ef_shrink: float = 0.5
    thresh_rank: int | None = None

    POLICIES = ("two_phase", "round_robin", "independent")

    def __post_init__(self):
        if self.policy not in self.POLICIES:
            raise ValueError(
                f"unknown policy {self.policy!r} (options: {self.POLICIES})")
        if not self.probe >= 1:
            raise ValueError(f"probe must be >= 1, got {self.probe}")
        if not 0.0 < self.ef_shrink <= 1.0:
            raise ValueError(
                f"ef_shrink must be in (0, 1], got {self.ef_shrink}")

    def resolve_thresh_rank(self, t: int, num_segments: int,
                            k: int | None) -> int:
        """The rank whose running best becomes the inherited bound."""
        if self.thresh_rank is not None:
            return max(1, min(self.thresh_rank, t))
        probe = max(1, min(self.probe, num_segments))
        admissible = -(-t * probe // num_segments)  # ceil(t*probe/S)
        return max(1, min(max(k or 1, admissible), t))

    def validate_for(self, num_segments: int, t: int) -> None:
        """Instance-dependent bounds, checked where the index is built.

        `__post_init__` can only see the params themselves; these two
        constraints involve the index (segment count, candidate width) and
        used to surface as shape errors deep inside
        `segmented_knn_search`. ShardedUHNSW calls this at construction so
        they fail immediately, with a fix attached. probe == num_segments
        stays legal (the policy degenerates to independent).
        """
        if self.probe > num_segments:
            raise ValueError(
                f"ShardedParams.probe={self.probe} exceeds the index's "
                f"{num_segments} segments — phase A cannot probe more "
                f"segments than exist; lower probe to <= {num_segments} "
                f"or build with more segments")
        if self.thresh_rank is not None and self.thresh_rank > t:
            raise ValueError(
                f"ShardedParams.thresh_rank={self.thresh_rank} exceeds the "
                f"candidate width t={t} — the running rank-r best only "
                f"exists for r <= t; lower thresh_rank or raise "
                f"UHNSWParams.t")


@functools.partial(
    jax.jit, static_argnames=("ef", "t", "max_hops", "expand_width")
)
def segmented_knn_search(
    arrays: GraphArrays,   # stacked, leading (S,) axis, n = n_pad
    X: jax.Array,          # (S, n_pad, d)
    node_ids: jax.Array,   # (S, n_pad) local -> global, -1 pad
    Q: jax.Array,          # (B, d)
    ef: int,
    t: int,
    max_hops: int = 4096,
    expand_width: int = 1,
    thresh: jax.Array | None = None,
    alive: jax.Array | None = None,
):
    """Vmapped per-segment base-metric search + one-sort global merge.

    `thresh` (optional (B,) root-free base-metric bounds, shared by every
    segment in the stack) routes each per-segment beam through the
    admission early-cut (core/hnsw.knn_search): evaluations past a query's
    bound count toward n_b but are never admitted, so pruned segments
    terminate as soon as their sub-threshold region is exhausted. None
    compiles the unmodified exhaustive program.

    `alive` (optional (S,) bool, *traced* — one compiled program serves
    every mask) implements degraded-coverage search (DESIGN.md §11): dead
    segments still run inside the vmap (the stacked shape is fixed) but
    their outputs are masked to the padding encoding (-1 ids, inf dists,
    zero counters) before the merge, which makes the merged result
    bitwise identical to a search over an index holding only the alive
    segments. None compiles the unmasked program.

    Every gathered per-segment distance also passes a NaN/inf guard: a
    candidate with a real id but a non-finite base distance (poisoned
    rows, a corrupt gather) is masked to padding — it can never reach a
    top-k — and raises that query's `poisoned` flag so the serving engine
    can bisect the poison back to a segment. Because a beam never
    *admits* a NaN distance (every comparison against it is false), a
    fully poisoned segment would otherwise return only sentinels and slip
    past a final-list check — so the guard additionally recomputes each
    query's base distance to the segment's entry-point row (one O(B*d)
    evaluation per segment, the row every beam must gather first) and
    flags non-finite entry distances too.

    Returns (gids (B, t) int32 global ids (-1 past the end of real data),
    dists (B, t) base-metric root-free distances, n_b (B,), hops (B,),
    poisoned (B,) bool).
    """
    n_pad = arrays.n
    base_p = arrays.metric_p

    def per_segment(arr, x, ni, al):
        ids, dists, nb, hops = knn_search(
            arr, x, Q, ef=ef, t=t, max_hops=max_hops,
            expand_width=expand_width, thresh=thresh,
        )
        valid = ids < n_pad
        g = jnp.where(valid, ni[jnp.clip(ids, 0, n_pad - 1)], -1)
        d = jnp.where(valid & (g >= 0), dists, jnp.inf)
        # NaN/inf guard: non-finite distance on a real id -> padding
        bad = (g >= 0) & ~jnp.isfinite(d)
        pois = bad.any(axis=1)
        g = jnp.where(bad, -1, g)
        d = jnp.where(bad, jnp.inf, d)
        # entry-row probe: catches a fully poisoned segment whose beam
        # admitted nothing (docstring) — base_p is 1 or 2, so the power
        # sum needs no transcendentals
        diff = jnp.abs(Q - x[jnp.clip(arr.entry, 0, n_pad - 1)][None, :])
        entry_d = (diff if base_p == 1.0 else diff * diff).sum(axis=1)
        pois = pois | ~jnp.isfinite(entry_d)
        if al is not None:  # degraded mask: dead segment -> all padding
            g = jnp.where(al, g, -1)
            d = jnp.where(al, d, jnp.inf)
            nb = jnp.where(al, nb, jnp.zeros_like(nb))
            hops = jnp.where(al, hops, jnp.zeros_like(hops))
            pois = pois & al
        return g, d, nb, hops, pois

    if alive is None:
        g, d, nb, hops, pois = jax.vmap(
            lambda arr, x, ni: per_segment(arr, x, ni, None)
        )(arrays, X, node_ids)
    else:
        g, d, nb, hops, pois = jax.vmap(per_segment)(
            arrays, X, node_ids, alive)
    b = Q.shape[0]
    g = jnp.moveaxis(g, 0, 1).reshape(b, -1)  # (B, S*t)
    d = jnp.moveaxis(d, 0, 1).reshape(b, -1)
    sd, si = jax.lax.sort((d, g), num_keys=1)
    return (si[:, :t], sd[:, :t], nb.sum(axis=0), hops.sum(axis=0),
            pois.any(axis=0))


@functools.partial(jax.jit, static_argnames=("t",))
def merge_phase_lists(g_a, d_a, g_b, d_b, t: int):
    """Sort-merge probe (flag 0) and spill (flag 1) candidate lists.

    g_a/d_a are phase-A (probe) global ids and base distances, g_b/d_b the
    phase-B (spill) lists; widths may differ. Returns (gids (B, t), dists
    (B, t), flags (B, t)) — flags mark each survivor's phase for the
    per-phase N_p attribution.
    """
    g = jnp.concatenate([g_a, g_b], axis=1)
    d = jnp.concatenate([d_a, d_b], axis=1)
    flag = jnp.concatenate(
        [jnp.zeros_like(g_a), jnp.ones_like(g_b)], axis=1)
    sd, sg, sf = jax.lax.sort((d, g, flag), num_keys=1)
    return sg[:, :t], sd[:, :t], sf[:, :t]


@functools.partial(jax.jit, static_argnames=("t",))
def merge_tagged_lists(g, d, f, g_new, d_new, t: int):
    """One round_robin cascade step: merge a flag-carrying running list
    with a new segment's (spill, flag 1) list, keeping the top-t."""
    ga = jnp.concatenate([g, g_new], axis=1)
    da = jnp.concatenate([d, d_new], axis=1)
    fa = jnp.concatenate([f, jnp.ones_like(g_new)], axis=1)
    sd, sg, sf = jax.lax.sort((da, ga, fa), num_keys=1)
    return sg[:, :t], sd[:, :t], sf[:, :t]


class ShardedUHNSW:
    """Segmented U-HNSW index with streaming inserts.

    Drop-in for UHNSW at the serving layer: `search(Q, p, k)` has the same
    contract — Q (B, d) f32; p a Python float or a (B,) array (each query
    row under its own metric, DESIGN.md §6); returns (ids (B, k) int32,
    rooted dists (B, k) f32, SearchStats with per-row n_b/n_p/hops). Adds
    `add(vec)` for online insertion (O(1), delta tier; DESIGN.md §3) and
    `shard_over(rt)` for multi-device placement (segment axis over the
    mesh's data axes).

    Mixed-p batches partition two ways by base graph (G1/G2) — never one
    group per distinct p — and each side runs one traced-p program whose
    per-row results are bit-identical to the scalar-p call at that row's p.
    """

    def __init__(
        self,
        segments: SegmentedGraphs,
        data: np.ndarray,
        params: UHNSWParams | None = None,
        delta_capacity: int = 1024,
        sharded_params: "ShardedParams | None" = None,
    ):
        self.segments = segments
        self.params = params or UHNSWParams()
        self.sharded_params = sharded_params or ShardedParams()
        self.sharded_params.validate_for(segments.num_segments,
                                         self.params.t)
        # per-segment failure state machine (DESIGN.md §11): quarantined
        # segments drop out of `_alive_segments()` and every search
        # reports the exact coverage it served at
        self.health = SegmentHealthTracker(segments.num_segments)
        # per-(base graph, probe count, alive set) device sub-stacks for
        # the phase split; invalidated whenever the segment set restacks
        # (compaction) or placement changes (shard_over)
        self._phase_cache: dict = {}
        # _X_host holds only *frozen* rows (segment members); delta-resident
        # vectors live in the DeltaBuffer until compaction appends them here
        self._X_host = np.ascontiguousarray(data, dtype=np.float32)
        self.X = jnp.asarray(self._X_host)
        self.delta = DeltaBuffer(d=self._X_host.shape[1],
                                 capacity=delta_capacity)
        self._next_id = len(self._X_host)
        self._rt = None  # set by shard_over; re-applied after compaction
        self._build_method = None  # compaction builder; None = auto by size
        # lazy verification-scan caches (DESIGN.md §10): the int8 band /
        # energy-permuted view cover the *frozen* rows only (the delta
        # tier stays f32 and is scanned exactly); compaction rebuilds
        # both over the grown corpus (deterministic, so recovery lands on
        # identical bytes)
        self._band = None
        self._scan_cache = None
        # durability hook (repro.index.persist.DurableIndex): called after a
        # compaction commits, when the delta is empty — the cheap moment to
        # rotate the snapshot + WAL pair. None = no durability layer.
        self.on_compact = None

    # -- construction -------------------------------------------------------

    @classmethod
    def build(
        cls,
        data: np.ndarray,
        num_segments: int = 4,
        m: int = 16,
        params: UHNSWParams | None = None,
        seed: int = 0,
        bulk: bool | None = None,
        delta_capacity: int = 1024,
        method: str | None = None,
        sharded_params: "ShardedParams | None" = None,
    ) -> "ShardedUHNSW":
        """Partition + build. `method` selects the per-segment builder
        ("incremental" / "bulk" / "bulk_host", DESIGN.md §7; None = auto by
        segment size) and is remembered: delta compaction builds its frozen
        segments with the same method."""
        segments = build_segments(data, num_segments=num_segments, m=m,
                                  seed=seed, bulk=bulk, method=method)
        idx = cls(segments, data, params=params,
                  delta_capacity=delta_capacity,
                  sharded_params=sharded_params)
        idx._build_method = method if method is not None else (
            None if bulk is None else ("bulk" if bulk else "incremental"))
        return idx

    @property
    def n(self) -> int:
        """Total searchable points (frozen segments + delta)."""
        return self._next_id

    @property
    def dim(self) -> int:
        """Vector dimensionality served by this index."""
        return int(self._X_host.shape[1])

    @property
    def num_segments(self) -> int:
        return self.segments.num_segments

    def index_size_bytes(self, p_range_max: float = 2.0) -> int:
        if p_range_max <= 1.0:
            return sum(g.index_size_bytes() for g in self.segments.graphs1)
        return self.segments.index_size_bytes()

    # -- placement ----------------------------------------------------------

    def shard_over(self, rt) -> "ShardedUHNSW":
        """Shard the stacked segment axis over the mesh's data axes.

        Picks the first dp axis whose size divides S; replicates (no-op)
        when none does — single-device tests and uneven meshes stay valid.
        The Runtime is retained so compaction (which restacks the arrays)
        re-applies the placement.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        self._rt = rt
        self._phase_cache.clear()  # sub-stacks must re-derive placement
        s = self.num_segments
        axis = next((a for a in rt.dp_axes
                     if s % int(rt.mesh.shape[a]) == 0), None)
        if axis is None:
            return self

        def place(x):
            spec = P(axis, *([None] * (x.ndim - 1)))
            return jax.device_put(x, NamedSharding(rt.mesh, spec))

        seg = self.segments
        for name in ("arrays1", "arrays2"):
            arr = getattr(seg, name)
            children, aux = arr.tree_flatten()
            children = jax.tree.map(place, children)
            setattr(seg, name, GraphArrays.tree_unflatten(aux, children))
        seg.X = place(seg.X)
        seg.node_ids = place(seg.node_ids)
        return self

    # -- query --------------------------------------------------------------

    def base_arrays_for(self, p: float) -> tuple[GraphArrays, float]:
        """Scalar-p base-graph pick (G1 iff p <= cutoff); mixed-p batches
        use the two-way partition in `_search_mixed` instead."""
        base = base_metric_for(p, self.params.cutoff)
        seg = self.segments
        return (seg.arrays1, 1.0) if base == 1.0 else (seg.arrays2, 2.0)

    def compressed_band(self):
        """The lazily-built int8 CompressedBand over the frozen rows
        (DESIGN.md §10); rebuilt from scratch after each compaction."""
        if self._band is None:
            from repro.index.compressed import build_band

            self._band = build_band(self.X)
        return self._band

    def _scan_view(self):
        """(x_scan, perm) energy-ordered frozen-corpus view (energy_perm)."""
        if self._scan_cache is None:
            from repro.index.compressed import energy_order

            perm = jnp.asarray(energy_order(self.X))
            self._scan_cache = (jnp.take(self.X, perm, axis=1), perm)
        return self._scan_cache

    def _verify_extras(self) -> dict:
        """Band / scan-view kwargs for `verify_candidates` under the
        current params (empty when both §10 features are off)."""
        prm = self.params
        if not prm.abandon:
            return {}
        if prm.compressed_band:
            return {"band": self.compressed_band()}
        if prm.energy_perm:
            x_scan, perm = self._scan_view()
            return {"x_scan": x_scan, "scan_perm": perm}
        return {}

    def search(self, Q, p, k: int):
        """Batched ANNS-U-Lp over all segments + delta.

        Q: (B, d) f32; p: Python float or (B,) array (mixed-p batch — see
        the class docstring); returns (ids (B, k) int32, rooted dists
        (B, k) f32, SearchStats).
        """
        if metrics.is_static_p(p):
            p = float(p)
            _, base_p = self.base_arrays_for(p)
            cands = self.search_stage_candidates(Q, base_p, k=k)
            return self.search_stage_finish(Q, cands, p, k)
        return self._search_mixed(Q, p, k)

    def _alive_segments(self) -> list[int]:
        """Serving segment set from the health tracker (DESIGN.md §11)."""
        return self.health.alive()

    def coverage_frac(self, alive: list[int] | None = None) -> float:
        """Exact served fraction of the corpus for an alive set: alive
        frozen rows plus the (always-served) delta tier, over all rows."""
        sizes = [g.n for g in self.segments.graphs1]
        if alive is None:
            alive = self._alive_segments()
        total = sum(sizes) + len(self.delta)
        if total <= 0:
            return 1.0
        return (sum(sizes[i] for i in alive) + len(self.delta)) / total

    def search_stage_candidates(self, Q, base_p: float,
                                k: int | None = None,
                                alive: list[int] | None = None,
                                ) -> CandidateSet:
        """Stage 1 of 2: segmented base-metric candidate generation.

        Same contract as `UHNSW.search_stage_candidates` (DESIGN.md §6):
        dispatches the policy-selected cross-segment search (module
        docstring) on the base graph named by `base_p` and returns the
        device-resident CandidateSet without a host sync, so the serving
        engine can overlap wave N+1's search with wave N's verification.
        `k` (the caller's final top-k, when known) tightens the derived
        threshold rank; None falls back to the admissible minimum.

        `alive` restricts the search to a segment subset (DESIGN.md §11);
        None serves the health tracker's current alive set. The returned
        CandidateSet carries the exact `coverage_frac` for that set and
        the per-row `poisoned` flag from the NaN/inf guard.
        """
        Q = jnp.asarray(Q, dtype=jnp.float32)
        seg = self.segments
        arrays = seg.arrays1 if base_p == 1.0 else seg.arrays2
        alive_list = (self._alive_segments() if alive is None
                      else sorted(int(i) for i in alive))
        (cand_ids, cand_dists, n_b, hops, n_b_probe, n_b_spill,
         n_cand_spill, poisoned) = self._segment_candidates(
            arrays, Q, k=k, alive=alive_list)
        return CandidateSet(ids=cand_ids, base_dists=cand_dists, n_b=n_b,
                            hops=hops, base_p=base_p, n_b_probe=n_b_probe,
                            n_b_spill=n_b_spill, n_cand_spill=n_cand_spill,
                            poisoned=poisoned,
                            coverage_frac=self.coverage_frac(alive_list))

    def search_stage_finish(self, Q, cands: CandidateSet, p, k: int):
        """Stage 2 of 2: verification (or base-metric skip) + delta merge.

        Unlike the monolithic index, finishing here includes the exact
        delta-tier sort-merge — delta hits need no verification, so they
        belong to this stage, and `search` composes exactly these two
        stages (bitwise parity with staged execution by construction).
        """
        prm = self.params
        Q = jnp.asarray(Q, dtype=jnp.float32)
        base_p = cands.base_p
        cand_ids, cand_dists = cands.ids, cands.base_dists
        n_b, hops = cands.n_b, cands.hops
        kappa = prm.kappa or max(k // 2, 1)
        if metrics.is_static_p(p):
            p = float(p)
            if p == base_p:
                # base-metric query: merged graph ordering is already exact
                ids = cand_ids[:, :k]
                dists = metrics._root(cand_dists[:, :k], p)
                n_p = jnp.zeros_like(n_b)
                iters = jnp.int32(0)
                frac = jnp.ones(n_b.shape, jnp.float32)
                f32f = jnp.ones(n_b.shape, jnp.float32)
                bandf = jnp.zeros(n_b.shape, jnp.float32)
            else:
                # -1 padding passes through: verify_candidates scores it inf
                ids, dists, n_p, iters, frac, f32f, bandf = \
                    verify_candidates(
                        Q, cand_ids, self.X, p, k, kappa, prm.tau,
                        interpret=prm.interpret, cand_base=cand_dists,
                        base_p=base_p, abandon=prm.abandon,
                        block_d=prm.abandon_block_d,
                        **self._verify_extras(),
                    )
            phases = self._phase_split(cands, n_p)
            return self._merge_delta(Q, p, k, ids, dists, n_p, iters, n_b,
                                     hops, base_p, frac, f32f, bandf,
                                     phases, coverage=cands.coverage_frac,
                                     poisoned=cands.poisoned)
        # vector p over one homogeneous base: the traced-p program + the
        # per-row base-metric skip mask, exactly as _search_mixed runs it
        ids, dists, n_p, iters, frac, f32f, bandf = verify_candidates(
            Q, cand_ids, self.X, p, k, kappa, prm.tau,
            interpret=prm.interpret, cand_base=cand_dists, base_p=base_p,
            abandon=prm.abandon, block_d=prm.abandon_block_d,
            **self._verify_extras(),
        )
        ids, dists, n_p, frac, f32f, bandf = mask_base_rows(
            cand_ids, cand_dists, ids, dists, n_p, p, base_p, k,
            n_dim_frac=frac, n_f32_frac=f32f, n_band_frac=bandf)
        phases = self._phase_split(cands, n_p)
        p_arr = np.broadcast_to(np.asarray(p, np.float32).reshape(-1),
                                (int(Q.shape[0]),))
        return self._merge_delta(Q, p_arr, k, ids, dists, n_p, iters, n_b,
                                 hops, base_p, frac, f32f, bandf, phases,
                                 coverage=cands.coverage_frac,
                                 poisoned=cands.poisoned)

    def _phase_split(self, cands: CandidateSet, n_p):
        """Per-phase (probe, spill) N_b/N_p attribution (DESIGN.md §3).

        N_b splits exactly (counted per phase in the beams). N_p is one
        merged verification pass, so it splits by each phase's share of
        the merged candidate list — the verify work a phase's survivors
        brought in. The delta tier's exact scans (added later in
        `_merge_delta`) belong to neither phase.
        """
        n_b_probe = cands.n_b if cands.n_b_probe is None else cands.n_b_probe
        n_b_spill = cands.n_b_spill
        n_valid = (cands.ids >= 0).sum(axis=1)
        spill_frac = (jnp.asarray(cands.n_cand_spill, jnp.float32)
                      / jnp.maximum(n_valid, 1).astype(jnp.float32))
        n_p_spill = n_p.astype(jnp.float32) * spill_frac
        n_p_probe = n_p.astype(jnp.float32) - n_p_spill
        return n_b_probe, n_b_spill, n_p_probe, n_p_spill

    def _probe_order(self) -> list[int]:
        """Prior ordering for the probe phase: largest segments first
        (they cover the most data, so their running k-th best is the
        tightest available bound), oldest first among equals — freshly
        compacted slivers probe last."""
        sizes = [g.n for g in self.segments.graphs1]
        return sorted(range(len(sizes)), key=lambda i: (-sizes[i], i))

    def _phase_stacks(self, base_p: float, probe: int,
                      alive_key: tuple | None = None):
        """Cached (probe, spill) device sub-stacks of the segment axis.

        Slicing the stacked pytrees is a handful of gathers; caching them
        per (base graph, probe count, alive set) keeps the steady-state
        query path free of per-call restacking. `alive_key` (a sorted
        tuple of alive segment indices; None = all alive) filters the
        probe order for degraded serving — dead segments are physically
        absent from the sub-stacks, so the phase searches match an index
        built from only the alive segments (DESIGN.md §11). The cache
        clears on compaction and re-placement (`shard_over`).
        """
        key = ("split", base_p, probe, alive_key)
        hit = self._phase_cache.get(key)
        if hit is not None:
            return hit
        seg = self.segments
        arrays = seg.arrays1 if base_p == 1.0 else seg.arrays2
        order = self._probe_order()
        if alive_key is not None:
            keep = set(alive_key)
            order = [i for i in order if i in keep]
        sel_a = np.asarray(order[:probe])
        sel_b = np.asarray(order[probe:])

        def take(sel):
            return (jax.tree.map(lambda x: x[sel], arrays),
                    seg.X[sel], seg.node_ids[sel])

        val = (take(sel_a), take(sel_b))
        self._phase_cache[key] = val
        return val

    def _segment_stack(self, base_p: float, i: int):
        """Cached singleton sub-stack of segment `i` (round_robin turns)."""
        key = ("one", base_p, i)
        hit = self._phase_cache.get(key)
        if hit is None:
            seg = self.segments
            arrays = seg.arrays1 if base_p == 1.0 else seg.arrays2
            sel = np.asarray([i])
            hit = (jax.tree.map(lambda x: x[sel], arrays),
                   seg.X[sel], seg.node_ids[sel])
            self._phase_cache[key] = hit
        return hit

    def _segment_candidates(self, arrays, Q, k: int | None = None,
                            alive: list[int] | None = None):
        """Policy-dispatched cross-segment candidate generation.

        Returns (gids (B, t), dists (B, t), n_b, hops, n_b_probe,
        n_b_spill, n_cand_spill, poisoned) — the middle three feed the
        per-phase stats split (DESIGN.md §3); threshold-free work is
        "probe", work under an inherited bound is "spill". `poisoned` is
        the per-row NaN/inf-guard flag (DESIGN.md §11).

        `alive` (sorted segment indices; None = all) restricts the search
        to a subset: every derived quantity — candidate width t, the
        threshold rank, the probe order and count — is computed over the
        subset exactly as an index built from only those segments would
        compute it, which is what makes degraded results bitwise equal to
        the healthy-subset index (the §11 parity invariant).
        """
        prm = self.params
        sp = self.sharded_params
        s_total = self.num_segments
        alive = list(range(s_total)) if alive is None else alive
        if not alive:
            raise RuntimeError(
                "no alive segments to search — every frozen segment is "
                "quarantined; recover from a snapshot (DESIGN.md §11) or "
                "rebuild the index")
        all_alive = len(alive) == s_total
        sizes = [g.n for g in self.segments.graphs1]
        n_frozen = sum(sizes[i] for i in alive)
        t = min(prm.t, n_frozen)
        ef = max(prm.ef or 2 * prm.t, t)
        # degenerate tiny beams can't host the full W; clamp, don't fail
        width = min(prm.expand_width, ef)
        s = len(alive)
        probe = max(1, min(sp.probe, s))
        single = s == 1 or (sp.policy == "two_phase" and probe >= s)
        if sp.policy == "independent" or single:
            if all_alive:
                mask = None
            else:  # traced mask: one compiled program serves any subset
                m = np.zeros(s_total, dtype=bool)
                m[alive] = True
                mask = jnp.asarray(m)
            gids, dists, n_b, hops, pois = segmented_knn_search(
                arrays, self.segments.X, self.segments.node_ids, Q,
                ef=ef, t=t, max_hops=prm.max_hops, expand_width=width,
                alive=mask,
            )
            zero = jnp.zeros_like(n_b)
            return gids, dists, n_b, hops, n_b, zero, zero, pois
        rank = sp.resolve_thresh_rank(t, s, k)
        base_p = arrays.metric_p
        alive_key = None if all_alive else tuple(alive)
        if sp.policy == "two_phase":
            (arr_a, x_a, ni_a), (arr_b, x_b, ni_b) = self._phase_stacks(
                base_p, probe, alive_key)
            g_a, d_a, nb_a, hops_a, pois_a = segmented_knn_search(
                arr_a, x_a, ni_a, Q, ef=ef, t=t, max_hops=prm.max_hops,
                expand_width=width,
            )
            thresh = d_a[:, rank - 1]
            # spill beams only contribute candidates below the bound, so
            # their width floors at the caller's k (not the global t) —
            # phase A already guarantees t merged candidates exist. The
            # floor also includes `rank`: a rank-r bound can admit up to r
            # merged-list entrants per segment, and a narrower beam would
            # silently drop some — at thresh_rank=t this keeps the
            # conservative variant's ids==independent contract honest even
            # on ef=t builds (ef*ef_shrink < t there).
            ef_b = max(k or 1, rank, int(round(ef * sp.ef_shrink)))
            t_b = min(t, ef_b)
            g_b, d_b, nb_b, hops_b, pois_b = segmented_knn_search(
                arr_b, x_b, ni_b, Q, ef=ef_b, t=t_b, max_hops=prm.max_hops,
                expand_width=min(width, ef_b), thresh=thresh,
            )
            gids, dists, flags = merge_phase_lists(g_a, d_a, g_b, d_b, t)
            n_cand_spill = ((flags == 1) & (gids >= 0)).sum(axis=1)
            return (gids, dists, nb_a + nb_b, hops_a + hops_b,
                    nb_a, nb_b, n_cand_spill.astype(jnp.int32),
                    pois_a | pois_b)
        # round_robin: single-phase cascade — every turn inherits the
        # running merged rank-r best of all earlier turns as its bound
        order = [i for i in self._probe_order() if i in set(alive)]
        gids = dists = flags = pois = None
        nb_probe = nb_spill = hops = None
        for turn, i in enumerate(order):
            arr_i, x_i, ni_i = self._segment_stack(base_p, i)
            thresh = dists[:, rank - 1] if turn else None
            g_i, d_i, nb_i, hops_i, pois_i = segmented_knn_search(
                arr_i, x_i, ni_i, Q, ef=ef, t=t, max_hops=prm.max_hops,
                expand_width=width, thresh=thresh,
            )
            if turn == 0:
                gids, dists, pois = g_i, d_i, pois_i
                flags = jnp.zeros_like(g_i)
                nb_probe, nb_spill, hops = nb_i, jnp.zeros_like(nb_i), hops_i
            else:
                gids, dists, flags = merge_tagged_lists(
                    gids, dists, flags, g_i, d_i, t)
                nb_spill = nb_spill + nb_i
                hops = hops + hops_i
                pois = pois | pois_i
        n_cand_spill = ((flags == 1) & (gids >= 0)).sum(axis=1)
        return (gids, dists, nb_probe + nb_spill, hops,
                nb_probe, nb_spill, n_cand_spill.astype(jnp.int32), pois)

    def _graph_search_base_vec(self, Q, p_vec, k: int, base_p: float):
        """One homogeneous-base sub-batch with per-row p (traced-p program),
        mirroring UHNSW._search_base_vec over the segmented candidates."""
        prm = self.params
        Q = jnp.asarray(Q, dtype=jnp.float32)
        cands = self.search_stage_candidates(Q, base_p, k=k)
        cand_ids, cand_dists = cands.ids, cands.base_dists
        kappa = prm.kappa or max(k // 2, 1)
        ids, dists, n_p, iters, frac, f32f, bandf = verify_candidates(
            Q, cand_ids, self.X, p_vec, k, kappa, prm.tau,
            interpret=prm.interpret, cand_base=cand_dists, base_p=base_p,
            abandon=prm.abandon, block_d=prm.abandon_block_d,
            **self._verify_extras(),
        )
        ids, dists, n_p, frac, f32f, bandf = mask_base_rows(
            cand_ids, cand_dists, ids, dists, n_p, p_vec, base_p, k,
            n_dim_frac=frac, n_f32_frac=f32f, n_band_frac=bandf)
        nb_pr, nb_sp, np_pr, np_sp = self._phase_split(cands, n_p)
        return (ids, dists, n_p, iters, cands.n_b, cands.hops, frac,
                f32f, bandf, nb_pr, nb_sp, np_pr, np_sp, cands.poisoned)

    def _search_mixed(self, Q, p, k: int):
        """Mixed-p batch: two-way G1/G2 partition, then one delta merge."""
        ids, dists, stats = two_way_mixed_search(
            Q, p, k, self.params.cutoff, self._graph_search_base_vec
        )
        p_arr = np.asarray(stats.base_p)  # aligned (B,) — reuse its shape
        p_arr = np.broadcast_to(np.asarray(p, np.float32).reshape(-1),
                                p_arr.shape)
        phases = (stats.n_b_probe, stats.n_b_spill,
                  stats.n_p_probe, stats.n_p_spill)
        return self._merge_delta(Q, p_arr, k, ids, dists, stats.n_p,
                                 stats.iterations, stats.n_b, stats.hops,
                                 stats.base_p, stats.n_dim_frac,
                                 stats.n_f32_rows_frac, stats.n_band_frac,
                                 phases, coverage=self.coverage_frac(),
                                 poisoned=stats.poisoned)

    def _merge_delta(self, Q, p, k, ids, dists, n_p, iters, n_b, hops,
                     base_p, n_dim_frac, n_f32_frac, n_band_frac,
                     phases=None, coverage: float = 1.0, poisoned=0.0):
        """Sort-merge exact delta-tier hits into the verified top-k.

        With abandonment on, the delta scan inherits the verified top-k's
        k-th-best as its abandon threshold (DESIGN.md §8): buffered
        vectors that provably cannot enter the top-k skip their remaining
        dimension blocks. `n_dim_frac` is then updated as the N_p-weighted
        mean of the graph-verify fraction and the delta scan's fraction;
        likewise `n_f32_frac`/`n_band_frac` (DESIGN.md §10) — the delta
        tier is f32-only, so its scans count as full-f32 rows with zero
        band traffic regardless of `compressed_band`.
        `phases` is the (n_b_probe, n_b_spill, n_p_probe, n_p_spill)
        split from `_phase_split`; delta scans join the N_p total but
        neither phase (they are the mutable tier, not segment work).
        """
        if len(self.delta):
            n_delta = len(self.delta)
            d = self.X.shape[1]
            # scalar basic-p scans have no transcendental work to skip and
            # the no-thresh path keeps the 1-D shared-ids pairwise form
            # (one gather for all queries, MXU matmul for p=2) — strictly
            # cheaper than a per-query blocked scan
            basic = metrics.is_static_p(p) and float(p) in (1.0, 2.0)
            thresh = dists[:, k - 1] if (self.params.abandon and not basic) \
                else None
            d_ids, d_dists, d_nd = self.delta.search(
                jnp.asarray(Q, dtype=jnp.float32), p,
                interpret=self.params.interpret, thresh=thresh,
                block_d=self.params.abandon_block_d,
            )
            all_ids = jnp.concatenate([ids, d_ids], axis=1)
            all_d = jnp.concatenate([dists, d_dists], axis=1)
            sd, si = jax.lax.sort((all_d, all_ids), num_keys=1)
            ids, dists = si[:, :k], sd[:, :k]
            delta_frac = d_nd.sum(axis=1).astype(jnp.float32) / (n_delta * d)
            denom = jnp.maximum(n_p + n_delta, 1)
            n_dim_frac = (n_dim_frac * n_p + delta_frac * n_delta) / denom
            # delta rows are full f32 gathers (no compressed replica of
            # the mutable tier) and contribute no band-dimension traffic
            n_f32_frac = (n_f32_frac * n_p + 1.0 * n_delta) / denom
            n_band_frac = (n_band_frac * n_p) / denom
            n_p = n_p + n_delta  # exact-Lp scans count toward N_p
        nb_pr, nb_sp, np_pr, np_sp = phases if phases is not None else (
            n_b, jnp.zeros_like(n_b), n_p, jnp.zeros_like(n_p))
        stats = SearchStats(n_b=n_b, n_p=n_p, iterations=iters, base_p=base_p,
                            hops=hops, n_dim_frac=n_dim_frac,
                            n_b_probe=nb_pr, n_b_spill=nb_sp,
                            n_p_probe=np_pr, n_p_spill=np_sp,
                            n_f32_rows_frac=n_f32_frac,
                            n_band_frac=n_band_frac,
                            coverage_frac=float(coverage),
                            degraded=bool(coverage < 1.0),
                            poisoned=poisoned)
        return ids, dists, stats

    def modeled_query_cost(self, stats: SearchStats, p, d: int) -> dict:
        """Paper Eq. 1 cost split — the shared core/uhnsw helper."""
        return modeled_query_cost(stats, p, d)

    # -- segment health (DESIGN.md §11) --------------------------------------

    def canary_probe(self, seg: int, n_probes: int = 2,
                     seed: int = 0) -> bool:
        """One canary health check of segment `seg`: self-query a few of
        its own members against *only* that segment. A healthy segment
        must return each member as its own top-1 at a finite distance
        with the NaN/inf guard clean — restored-but-corrupt rows, a
        broken graph, or lingering poison all fail the probe. Records the
        outcome with the health tracker (re-admission requires
        `HealthPolicy.probe_successes` consecutive passes) and returns it.
        """
        ids = np.asarray(self.segments.global_ids[seg])
        rng = np.random.default_rng(seed * 1009 + seg)
        pick = rng.choice(len(ids), size=min(n_probes, len(ids)),
                          replace=False)
        gids = ids[np.sort(pick)]
        q = self._X_host[gids]
        cands = self.search_stage_candidates(q, 2.0, k=1, alive=[seg])
        top = np.asarray(cands.ids[:, 0])
        top_d = np.asarray(cands.base_dists[:, 0])
        pois = np.asarray(cands.poisoned)
        ok = bool(np.array_equal(top, gids) and np.all(np.isfinite(top_d))
                  and not pois.any())
        self.health.record_probe(seg, ok)
        return ok

    # -- streaming inserts --------------------------------------------------

    def add(self, vec: np.ndarray) -> int:
        """Insert one vector online. Returns its (stable) global id.

        O(1): the vector lands in the delta buffer only; the frozen data
        array grows once per compaction, not once per insert.
        """
        v = np.asarray(vec, dtype=np.float32).reshape(-1)
        # validate before touching any state: a failed add must not burn an
        # id (ids index data rows — a gap would desync every later insert)
        d = self._X_host.shape[1]
        if v.shape[0] != d:
            raise ValueError(f"vector has dim {v.shape[0]}, index has dim {d}")
        gid = self._next_id
        self._next_id += 1
        self.delta.add(v, gid)
        if self.delta.full:
            self.compact()
        return gid

    def get_vector(self, gid: int) -> np.ndarray:
        """Look up a vector by global id, whichever tier it lives in."""
        if 0 <= gid < len(self._X_host):
            return self._X_host[gid]
        pos = gid - len(self._X_host)
        if 0 <= pos < len(self.delta):
            return self.delta.vectors()[pos]
        raise IndexError(f"id {gid} not in index (n={self.n})")

    def compact(self):
        """Freeze the delta buffer into a new segment (graphs + restack)."""
        if not len(self.delta):
            return
        vecs, ids = self.delta.drain()
        assert int(ids[0]) == len(self._X_host)  # ids stay row-aligned
        self._X_host = np.concatenate([self._X_host, vecs], axis=0)
        m = self.segments.graphs1[0].m
        g1, g2 = build_segment_pair(vecs, m=m, seed=int(ids[0]) + 1,
                                    method=self._build_method)
        self.segments.append(g1, g2, ids)
        # the new segment starts HEALTHY; existing quarantines survive the
        # compaction (the rows they cover are still suspect)
        self.health.resize(self.num_segments)
        self._phase_cache.clear()  # restack invalidates cached sub-stacks
        self.X = jnp.asarray(self._X_host)
        # the frozen corpus grew: quantize the new rows into a fresh band
        # (full deterministic rebuild — scales/radii/perm may all shift)
        self._band = None
        self._scan_cache = None
        if self._rt is not None:  # restacking dropped the device placement
            self.shard_over(self._rt)
        if self.on_compact is not None:
            self.on_compact()
