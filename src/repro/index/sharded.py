"""ShardedUHNSW: segmented U-HNSW with one merged verification pass.

Query path (DESIGN.md §3):

  1. Candidate generation — `jax.vmap` the batched beam search over the
     stacked (S,) segment axis of the selected base graph (G1 for p <= 1.4,
     G2 otherwise). One device program traverses all S segments; the segment
     axis shards over the mesh's data axes (`shard_over`), so segments run
     on different chips at scale.
  2. Merge — the S per-segment top-t lists (already ascending) concatenate
     to (B, S*t) and a single `lax.sort` keeps the global top-t under the
     base metric. Segments hold disjoint ids, so no dedup is needed.
  3. Verification — ONE `verify_candidates` pass over the merged list.
     Running verification after the merge (not per segment) preserves the
     paper's early-termination N_p savings end-to-end: the convergence test
     sees the same globally-ordered candidate stream a monolithic index
     would produce.
  4. Delta merge — exact rooted-Lp distances for the mutable delta buffer
     (repro.index.delta) sort-merge into the verified top-k. Exactness means
     no verification is owed for delta hits.

Streaming inserts: `add()` appends to the delta buffer; at capacity the
buffer compacts into a new frozen segment — built with the index's build
method (DESIGN.md §7; by default the batched bulk builder once the buffer
holds >= BULK_THRESHOLD vectors), stacks re-pad — and the cycle repeats.
Ids are assigned once and never change.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.hnsw import GraphArrays, knn_search
from repro.core.metrics import base_metric_for
from repro.core.uhnsw import (
    CandidateSet,
    SearchStats,
    UHNSWParams,
    mask_base_rows,
    modeled_query_cost,
    two_way_mixed_search,
    verify_candidates,
)
from repro.index.delta import DeltaBuffer
from repro.index.segment import SegmentedGraphs, build_segment_pair, build_segments


@functools.partial(
    jax.jit, static_argnames=("ef", "t", "max_hops", "expand_width")
)
def segmented_knn_search(
    arrays: GraphArrays,   # stacked, leading (S,) axis, n = n_pad
    X: jax.Array,          # (S, n_pad, d)
    node_ids: jax.Array,   # (S, n_pad) local -> global, -1 pad
    Q: jax.Array,          # (B, d)
    ef: int,
    t: int,
    max_hops: int = 4096,
    expand_width: int = 1,
):
    """Vmapped per-segment base-metric search + one-sort global merge.

    Returns (gids (B, t) int32 global ids (-1 past the end of real data),
    dists (B, t) base-metric root-free distances, n_b (B,), hops (B,)).
    """
    n_pad = arrays.n

    def per_segment(arr, x, ni):
        ids, dists, nb, hops = knn_search(
            arr, x, Q, ef=ef, t=t, max_hops=max_hops,
            expand_width=expand_width,
        )
        valid = ids < n_pad
        g = jnp.where(valid, ni[jnp.clip(ids, 0, n_pad - 1)], -1)
        d = jnp.where(valid & (g >= 0), dists, jnp.inf)
        return g, d, nb, hops

    g, d, nb, hops = jax.vmap(per_segment)(arrays, X, node_ids)
    b = Q.shape[0]
    g = jnp.moveaxis(g, 0, 1).reshape(b, -1)  # (B, S*t)
    d = jnp.moveaxis(d, 0, 1).reshape(b, -1)
    sd, si = jax.lax.sort((d, g), num_keys=1)
    return si[:, :t], sd[:, :t], nb.sum(axis=0), hops.sum(axis=0)


class ShardedUHNSW:
    """Segmented U-HNSW index with streaming inserts.

    Drop-in for UHNSW at the serving layer: `search(Q, p, k)` has the same
    contract — Q (B, d) f32; p a Python float or a (B,) array (each query
    row under its own metric, DESIGN.md §6); returns (ids (B, k) int32,
    rooted dists (B, k) f32, SearchStats with per-row n_b/n_p/hops). Adds
    `add(vec)` for online insertion (O(1), delta tier; DESIGN.md §3) and
    `shard_over(rt)` for multi-device placement (segment axis over the
    mesh's data axes).

    Mixed-p batches partition two ways by base graph (G1/G2) — never one
    group per distinct p — and each side runs one traced-p program whose
    per-row results are bit-identical to the scalar-p call at that row's p.
    """

    def __init__(
        self,
        segments: SegmentedGraphs,
        data: np.ndarray,
        params: UHNSWParams | None = None,
        delta_capacity: int = 1024,
    ):
        self.segments = segments
        self.params = params or UHNSWParams()
        # _X_host holds only *frozen* rows (segment members); delta-resident
        # vectors live in the DeltaBuffer until compaction appends them here
        self._X_host = np.ascontiguousarray(data, dtype=np.float32)
        self.X = jnp.asarray(self._X_host)
        self.delta = DeltaBuffer(d=self._X_host.shape[1],
                                 capacity=delta_capacity)
        self._next_id = len(self._X_host)
        self._rt = None  # set by shard_over; re-applied after compaction
        self._build_method = None  # compaction builder; None = auto by size
        # durability hook (repro.index.persist.DurableIndex): called after a
        # compaction commits, when the delta is empty — the cheap moment to
        # rotate the snapshot + WAL pair. None = no durability layer.
        self.on_compact = None

    # -- construction -------------------------------------------------------

    @classmethod
    def build(
        cls,
        data: np.ndarray,
        num_segments: int = 4,
        m: int = 16,
        params: UHNSWParams | None = None,
        seed: int = 0,
        bulk: bool | None = None,
        delta_capacity: int = 1024,
        method: str | None = None,
    ) -> "ShardedUHNSW":
        """Partition + build. `method` selects the per-segment builder
        ("incremental" / "bulk" / "bulk_host", DESIGN.md §7; None = auto by
        segment size) and is remembered: delta compaction builds its frozen
        segments with the same method."""
        segments = build_segments(data, num_segments=num_segments, m=m,
                                  seed=seed, bulk=bulk, method=method)
        idx = cls(segments, data, params=params,
                  delta_capacity=delta_capacity)
        idx._build_method = method if method is not None else (
            None if bulk is None else ("bulk" if bulk else "incremental"))
        return idx

    @property
    def n(self) -> int:
        """Total searchable points (frozen segments + delta)."""
        return self._next_id

    @property
    def dim(self) -> int:
        """Vector dimensionality served by this index."""
        return int(self._X_host.shape[1])

    @property
    def num_segments(self) -> int:
        return self.segments.num_segments

    def index_size_bytes(self, p_range_max: float = 2.0) -> int:
        if p_range_max <= 1.0:
            return sum(g.index_size_bytes() for g in self.segments.graphs1)
        return self.segments.index_size_bytes()

    # -- placement ----------------------------------------------------------

    def shard_over(self, rt) -> "ShardedUHNSW":
        """Shard the stacked segment axis over the mesh's data axes.

        Picks the first dp axis whose size divides S; replicates (no-op)
        when none does — single-device tests and uneven meshes stay valid.
        The Runtime is retained so compaction (which restacks the arrays)
        re-applies the placement.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        self._rt = rt
        s = self.num_segments
        axis = next((a for a in rt.dp_axes
                     if s % int(rt.mesh.shape[a]) == 0), None)
        if axis is None:
            return self

        def place(x):
            spec = P(axis, *([None] * (x.ndim - 1)))
            return jax.device_put(x, NamedSharding(rt.mesh, spec))

        seg = self.segments
        for name in ("arrays1", "arrays2"):
            arr = getattr(seg, name)
            children, aux = arr.tree_flatten()
            children = jax.tree.map(place, children)
            setattr(seg, name, GraphArrays.tree_unflatten(aux, children))
        seg.X = place(seg.X)
        seg.node_ids = place(seg.node_ids)
        return self

    # -- query --------------------------------------------------------------

    def base_arrays_for(self, p: float) -> tuple[GraphArrays, float]:
        """Scalar-p base-graph pick (G1 iff p <= cutoff); mixed-p batches
        use the two-way partition in `_search_mixed` instead."""
        base = base_metric_for(p, self.params.cutoff)
        seg = self.segments
        return (seg.arrays1, 1.0) if base == 1.0 else (seg.arrays2, 2.0)

    def search(self, Q, p, k: int):
        """Batched ANNS-U-Lp over all segments + delta.

        Q: (B, d) f32; p: Python float or (B,) array (mixed-p batch — see
        the class docstring); returns (ids (B, k) int32, rooted dists
        (B, k) f32, SearchStats).
        """
        if metrics.is_static_p(p):
            p = float(p)
            _, base_p = self.base_arrays_for(p)
            cands = self.search_stage_candidates(Q, base_p)
            return self.search_stage_finish(Q, cands, p, k)
        return self._search_mixed(Q, p, k)

    def search_stage_candidates(self, Q, base_p: float) -> CandidateSet:
        """Stage 1 of 2: segmented base-metric candidate generation.

        Same contract as `UHNSW.search_stage_candidates` (DESIGN.md §6):
        dispatches the vmapped per-segment beam search + one-sort merge on
        the base graph named by `base_p` and returns the device-resident
        CandidateSet without a host sync, so the serving engine can overlap
        wave N+1's search with wave N's verification.
        """
        Q = jnp.asarray(Q, dtype=jnp.float32)
        seg = self.segments
        arrays = seg.arrays1 if base_p == 1.0 else seg.arrays2
        cand_ids, cand_dists, n_b, hops = self._segment_candidates(arrays, Q)
        return CandidateSet(ids=cand_ids, base_dists=cand_dists, n_b=n_b,
                            hops=hops, base_p=base_p)

    def search_stage_finish(self, Q, cands: CandidateSet, p, k: int):
        """Stage 2 of 2: verification (or base-metric skip) + delta merge.

        Unlike the monolithic index, finishing here includes the exact
        delta-tier sort-merge — delta hits need no verification, so they
        belong to this stage, and `search` composes exactly these two
        stages (bitwise parity with staged execution by construction).
        """
        prm = self.params
        Q = jnp.asarray(Q, dtype=jnp.float32)
        base_p = cands.base_p
        cand_ids, cand_dists = cands.ids, cands.base_dists
        n_b, hops = cands.n_b, cands.hops
        kappa = prm.kappa or max(k // 2, 1)
        if metrics.is_static_p(p):
            p = float(p)
            if p == base_p:
                # base-metric query: merged graph ordering is already exact
                ids = cand_ids[:, :k]
                dists = metrics._root(cand_dists[:, :k], p)
                n_p = jnp.zeros_like(n_b)
                iters = jnp.int32(0)
                frac = jnp.ones(n_b.shape, jnp.float32)
            else:
                # -1 padding passes through: verify_candidates scores it inf
                ids, dists, n_p, iters, frac = verify_candidates(
                    Q, cand_ids, self.X, p, k, kappa, prm.tau,
                    interpret=prm.interpret, cand_base=cand_dists,
                    base_p=base_p, abandon=prm.abandon,
                    block_d=prm.abandon_block_d,
                )
            return self._merge_delta(Q, p, k, ids, dists, n_p, iters, n_b,
                                     hops, base_p, frac)
        # vector p over one homogeneous base: the traced-p program + the
        # per-row base-metric skip mask, exactly as _search_mixed runs it
        ids, dists, n_p, iters, frac = verify_candidates(
            Q, cand_ids, self.X, p, k, kappa, prm.tau,
            interpret=prm.interpret, cand_base=cand_dists, base_p=base_p,
            abandon=prm.abandon, block_d=prm.abandon_block_d,
        )
        ids, dists, n_p, frac = mask_base_rows(
            cand_ids, cand_dists, ids, dists, n_p, p, base_p, k,
            n_dim_frac=frac)
        p_arr = np.broadcast_to(np.asarray(p, np.float32).reshape(-1),
                                (int(Q.shape[0]),))
        return self._merge_delta(Q, p_arr, k, ids, dists, n_p, iters, n_b,
                                 hops, base_p, frac)

    def _segment_candidates(self, arrays, Q):
        """Vmapped per-segment beam search + one-sort merge (DESIGN.md §3)."""
        prm = self.params
        n_frozen = sum(g.n for g in self.segments.graphs1)
        t = min(prm.t, n_frozen)
        ef = max(prm.ef or 2 * prm.t, t)
        return segmented_knn_search(
            arrays, self.segments.X, self.segments.node_ids, Q,
            ef=ef, t=t, max_hops=prm.max_hops,
            # degenerate tiny beams can't host the full W; clamp, don't fail
            expand_width=min(prm.expand_width, ef),
        )

    def _graph_search_base_vec(self, Q, p_vec, k: int, base_p: float):
        """One homogeneous-base sub-batch with per-row p (traced-p program),
        mirroring UHNSW._search_base_vec over the segmented candidates."""
        prm = self.params
        seg = self.segments
        arrays = seg.arrays1 if base_p == 1.0 else seg.arrays2
        cand_ids, cand_dists, n_b, hops = self._segment_candidates(arrays, Q)
        kappa = prm.kappa or max(k // 2, 1)
        ids, dists, n_p, iters, frac = verify_candidates(
            Q, cand_ids, self.X, p_vec, k, kappa, prm.tau,
            interpret=prm.interpret, cand_base=cand_dists, base_p=base_p,
            abandon=prm.abandon, block_d=prm.abandon_block_d,
        )
        ids, dists, n_p, frac = mask_base_rows(
            cand_ids, cand_dists, ids, dists, n_p, p_vec, base_p, k,
            n_dim_frac=frac)
        return ids, dists, n_p, iters, n_b, hops, frac

    def _search_mixed(self, Q, p, k: int):
        """Mixed-p batch: two-way G1/G2 partition, then one delta merge."""
        ids, dists, stats = two_way_mixed_search(
            Q, p, k, self.params.cutoff, self._graph_search_base_vec
        )
        p_arr = np.asarray(stats.base_p)  # aligned (B,) — reuse its shape
        p_arr = np.broadcast_to(np.asarray(p, np.float32).reshape(-1),
                                p_arr.shape)
        return self._merge_delta(Q, p_arr, k, ids, dists, stats.n_p,
                                 stats.iterations, stats.n_b, stats.hops,
                                 stats.base_p, stats.n_dim_frac)

    def _merge_delta(self, Q, p, k, ids, dists, n_p, iters, n_b, hops,
                     base_p, n_dim_frac):
        """Sort-merge exact delta-tier hits into the verified top-k.

        With abandonment on, the delta scan inherits the verified top-k's
        k-th-best as its abandon threshold (DESIGN.md §8): buffered
        vectors that provably cannot enter the top-k skip their remaining
        dimension blocks. `n_dim_frac` is then updated as the N_p-weighted
        mean of the graph-verify fraction and the delta scan's fraction.
        """
        if len(self.delta):
            n_delta = len(self.delta)
            d = self.X.shape[1]
            # scalar basic-p scans have no transcendental work to skip and
            # the no-thresh path keeps the 1-D shared-ids pairwise form
            # (one gather for all queries, MXU matmul for p=2) — strictly
            # cheaper than a per-query blocked scan
            basic = metrics.is_static_p(p) and float(p) in (1.0, 2.0)
            thresh = dists[:, k - 1] if (self.params.abandon and not basic) \
                else None
            d_ids, d_dists, d_nd = self.delta.search(
                jnp.asarray(Q, dtype=jnp.float32), p,
                interpret=self.params.interpret, thresh=thresh,
                block_d=self.params.abandon_block_d,
            )
            all_ids = jnp.concatenate([ids, d_ids], axis=1)
            all_d = jnp.concatenate([dists, d_dists], axis=1)
            sd, si = jax.lax.sort((all_d, all_ids), num_keys=1)
            ids, dists = si[:, :k], sd[:, :k]
            delta_frac = d_nd.sum(axis=1).astype(jnp.float32) / (n_delta * d)
            n_dim_frac = (n_dim_frac * n_p + delta_frac * n_delta) / \
                jnp.maximum(n_p + n_delta, 1)
            n_p = n_p + n_delta  # exact-Lp scans count toward N_p
        stats = SearchStats(n_b=n_b, n_p=n_p, iterations=iters, base_p=base_p,
                            hops=hops, n_dim_frac=n_dim_frac)
        return ids, dists, stats

    def modeled_query_cost(self, stats: SearchStats, p, d: int) -> dict:
        """Paper Eq. 1 cost split — the shared core/uhnsw helper."""
        return modeled_query_cost(stats, p, d)

    # -- streaming inserts --------------------------------------------------

    def add(self, vec: np.ndarray) -> int:
        """Insert one vector online. Returns its (stable) global id.

        O(1): the vector lands in the delta buffer only; the frozen data
        array grows once per compaction, not once per insert.
        """
        v = np.asarray(vec, dtype=np.float32).reshape(-1)
        # validate before touching any state: a failed add must not burn an
        # id (ids index data rows — a gap would desync every later insert)
        d = self._X_host.shape[1]
        if v.shape[0] != d:
            raise ValueError(f"vector has dim {v.shape[0]}, index has dim {d}")
        gid = self._next_id
        self._next_id += 1
        self.delta.add(v, gid)
        if self.delta.full:
            self.compact()
        return gid

    def get_vector(self, gid: int) -> np.ndarray:
        """Look up a vector by global id, whichever tier it lives in."""
        if 0 <= gid < len(self._X_host):
            return self._X_host[gid]
        pos = gid - len(self._X_host)
        if 0 <= pos < len(self.delta):
            return self.delta.vectors()[pos]
        raise IndexError(f"id {gid} not in index (n={self.n})")

    def compact(self):
        """Freeze the delta buffer into a new segment (graphs + restack)."""
        if not len(self.delta):
            return
        vecs, ids = self.delta.drain()
        assert int(ids[0]) == len(self._X_host)  # ids stay row-aligned
        self._X_host = np.concatenate([self._X_host, vecs], axis=0)
        m = self.segments.graphs1[0].m
        g1, g2 = build_segment_pair(vecs, m=m, seed=int(ids[0]) + 1,
                                    method=self._build_method)
        self.segments.append(g1, g2, ids)
        self.X = jnp.asarray(self._X_host)
        if self._rt is not None:  # restacking dropped the device placement
            self.shard_over(self._rt)
        if self.on_compact is not None:
            self.on_compact()
