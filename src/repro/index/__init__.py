"""Segmented, sharded, streaming U-HNSW index (DESIGN.md §3).

  segment — partition a dataset into S segments; per-segment G1/G2 graphs
            pad_to'd to uniform shapes and stacked for vmapped traversal
  sharded — ShardedUHNSW: vmapped per-segment beam search, one lax.sort
            merge, a single verify_candidates pass (paper N_p preserved)
  delta   — mutable delta buffer for online add(): brute-force exact-Lp
            scan merged into graph results; compaction -> new frozen segment
  health  — per-segment health state machine (DESIGN.md §11): failure-EWMA
            driven HEALTHY/SUSPECT/QUARANTINED/RECOVERING transitions, the
            alive mask behind degraded-coverage search
  persist — atomic CRC-checked snapshots + recovery (DESIGN.md §9):
            recover(dir) = last durable snapshot + WAL replay, bit-identical;
            restore_segment re-materializes one quarantined segment
  wal     — fsync'd CRC-framed write-ahead log for delta-tier inserts
"""

from repro.index.delta import DeltaBuffer  # noqa: F401
from repro.index.health import (  # noqa: F401
    HEALTHY,
    QUARANTINED,
    RECOVERING,
    SUSPECT,
    HealthPolicy,
    SegmentHealthTracker,
)
from repro.index.persist import (  # noqa: F401
    DurableIndex,
    RecoveryError,
    SnapshotError,
    latest_durable_snapshot,
    load_snapshot,
    recover,
    restore_segment,
    save_snapshot,
)
from repro.index.segment import SegmentedGraphs, build_segments, partition_dataset  # noqa: F401
from repro.index.sharded import ShardedParams, ShardedUHNSW  # noqa: F401
from repro.index.wal import WalCorruption, WriteAheadLog, replay  # noqa: F401
