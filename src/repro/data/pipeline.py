"""Deterministic synthetic data pipeline.

Produces a Markov-ish token stream (not uniform noise: a learnable LM target
so smoke-training shows a *decreasing* loss) with:

  * deterministic content as a function of (seed, step, host_shard) —
    restart-safe: resuming from step N regenerates exactly the batches a
    failed run would have seen (checkpoint/restart tests rely on this);
  * host sharding: each process materializes only its slice of the global
    batch (process_index/process_count), the multi-host contract;
  * stub frontends: frame/patch embeddings for the audio/vlm architectures.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclass
class SyntheticTokenPipeline:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def __post_init__(self):
        v = self.cfg.vocab_size
        rng = np.random.default_rng(self.seed)
        # fixed random transition table: next-token logits depend on current
        # token bucket -> learnable structure
        self.n_buckets = min(64, v)
        self.trans = rng.dirichlet(
            np.full(min(v, 512), 0.1), size=self.n_buckets
        ).astype(np.float32)
        self.top_ids = rng.integers(0, v, size=(self.n_buckets, min(v, 512)))

    def _host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count

    def batch(self, step: int) -> dict:
        """Batch for `step` (host-local slice of the global batch)."""
        b, s, v = self._host_batch(), self.seq_len, self.cfg.vocab_size
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.host_index
        )
        tokens = np.empty((b, s + 1), dtype=np.int32)
        tokens[:, 0] = rng.integers(0, v, size=b)
        bucket = tokens[:, 0] % self.n_buckets
        for t in range(s):
            choice_idx = np.array([
                rng.choice(self.trans.shape[1], p=self.trans[bk]) for bk in bucket
            ])
            tokens[:, t + 1] = self.top_ids[bucket, choice_idx]
            bucket = tokens[:, t + 1] % self.n_buckets
        batch = {"labels": jnp.asarray(tokens[:, 1:])}
        if self.cfg.frontend:
            # stub frontend: deterministic embeddings derived from token ids
            proj = np.sin(
                tokens[:, :-1, None] * np.linspace(0.01, 1, self.cfg.frontend_dim)
            ).astype(np.float32)
            batch["frames"] = jnp.asarray(proj, dtype=jnp.bfloat16)
        else:
            batch["tokens"] = jnp.asarray(tokens[:, :-1])
        return batch


def make_batch_iterator(cfg, global_batch, seq_len, seed=0, start_step=0):
    pipe = SyntheticTokenPipeline(
        cfg, global_batch, seq_len, seed,
        host_index=jax.process_index(), host_count=jax.process_count(),
    )
    step = start_step
    while True:
        yield step, pipe.batch(step)
        step += 1
