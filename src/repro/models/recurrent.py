"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma) and Mamba2 SSD.

Both are implemented in their TPU-native chunked/scan forms:
  * RG-LRU uses `jax.lax.associative_scan` over the (decay, input) pairs —
    log-space decays in fp32 for stability;
  * SSD uses the chunked state-space-duality algorithm (Mamba2 §6): quadratic
    attention-like intra-chunk einsums (MXU food) + a linear inter-chunk
    state scan. Chunk length = cfg.ssm.chunk.

Decode paths carry O(1) state: (B, d) for RG-LRU, (B, H, N, P) for SSD,
plus (conv_width-1) convolution tails. This is what makes the long_500k
decode shape feasible for these families.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.attention import rmsnorm

RGLRU_C = 8.0  # Griffin's recurrence-gate temperature


def causal_conv1d(x, w, tail=None):
    """Depthwise causal conv. x: (B, S, C), w: (W, C), tail: (B, W-1, C)."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_tail = xp[:, -(width - 1) :, :] if width > 1 else tail
    return out, new_tail


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _rglru_gates(params, u):
    """Per-channel (diagonal) gates -> (log_a, beta_scaled_input) in fp32."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(params["wa"] * u32 + params["ba"])
    i = jax.nn.sigmoid(params["wi_g"] * u32 + params["bi_g"])
    log_a = RGLRU_C * r * jax.nn.log_sigmoid(params["lam"].astype(jnp.float32))
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * (i * u32)


def rglru_forward(params, x, cfg: ArchConfig, state=None, conv_tail=None):
    """Griffin recurrent block. Returns (out, (h_last, conv_tail))."""
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", h, params["w_gate"]))
    u = jnp.einsum("bsd,de->bse", h, params["w_x"])
    u, new_tail = causal_conv1d(u, params["conv"], conv_tail)
    log_a, b = _rglru_gates(params, u)
    if state is not None:
        # fold the carried state into the first step: b_0 += a_0 * h_prev
        b = b.at[:, 0, :].add(jnp.exp(log_a[:, 0, :]) * state)

    def combine(c1, c2):
        la1, b1 = c1
        la2, b2 = c2
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    out = jnp.einsum("bse,ed->bsd", (gate.astype(jnp.float32) * hs).astype(x.dtype),
                     params["w_out"])
    return out, (hs[:, -1, :], new_tail)


def rglru_decode(params, x, state, conv_tail, cfg: ArchConfig):
    """Single-step RG-LRU. state: (B, d) fp32; conv_tail: (B, W-1, d)."""
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", h, params["w_gate"]))
    u = jnp.einsum("bsd,de->bse", h, params["w_x"])
    u, new_tail = causal_conv1d(u, params["conv"], conv_tail)
    log_a, b = _rglru_gates(params, u)
    h_new = jnp.exp(log_a[:, 0]) * state + b[:, 0]
    out = (gate[:, 0].astype(jnp.float32) * h_new).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", out, params["w_out"])[:, None, :]
    return out, (h_new, new_tail)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def _ssd_project(params, x, cfg: ArchConfig, conv_tail=None):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    gn = s.n_groups * s.state_dim
    n_heads = d_in // s.head_dim
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, params["w_in"])
    z = proj[..., :d_in]
    conv_in = proj[..., d_in : d_in + d_in + 2 * gn]
    dt_raw = proj[..., -n_heads:]
    conv_out, new_tail = causal_conv1d(conv_in, params["conv"], conv_tail)
    conv_out = jax.nn.silu(conv_out)
    xs = conv_out[..., :d_in]
    b_ = conv_out[..., d_in : d_in + gn]
    c_ = conv_out[..., d_in + gn :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    return z, xs, b_, c_, dt, new_tail, n_heads


def ssd_forward(params, x, cfg: ArchConfig, state=None, conv_tail=None):
    """Chunked SSD. Returns (out, (ssm_state, conv_tail)).

    Shapes: x (B,S,d); heads H = expand*d/P; state N; G broadcast groups.
    """
    s = cfg.ssm
    b, seq, _ = x.shape
    z, xs, b_, c_, dt, new_tail, nh = _ssd_project(params, x, cfg, conv_tail)
    p, n, g = s.head_dim, s.state_dim, s.n_groups
    q = min(s.chunk, seq)
    assert seq % q == 0, (seq, q)
    nc = seq // q

    xh = xs.reshape(b, nc, q, nh, p)
    bh = b_.reshape(b, nc, q, g, n)
    ch = c_.reshape(b, nc, q, g, n)
    if g == 1:
        bh, ch = bh[..., 0, :], ch[..., 0, :]  # (B,nc,Q,N) shared across heads
    dtc = dt.reshape(b, nc, q, nh)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (H,)
    da = dtc * a[None, None, None, :]                   # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(da, axis=2)                        # inclusive
    xdt = xh * dtc[..., None]

    # intra-chunk (quadratic, MXU): scores_ij = C_i . B_j * exp(cum_i-cum_j), i>=j
    scores = jnp.einsum("bcin,bcjn->bcij", ch, bh)      # (B,nc,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(decay), 0.0)
    y_intra = jnp.einsum(
        "bcij,bcijh,bcjhp->bcihp", scores, l_mat.astype(scores.dtype),
        xdt, preferred_element_type=jnp.float32,
    )

    # chunk states: S_c = sum_j exp(cum_last - cum_j) B_j (dt_j x_j)^T
    tail_decay = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,Q,H)
    s_c = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchnp", bh, tail_decay.astype(bh.dtype), xdt,
        preferred_element_type=jnp.float32,
    )

    # inter-chunk recurrence over nc (linear scan)
    chunk_decay = jnp.exp(cum[:, :, -1, :])             # (B,nc,H)

    def scan_body(carry, inp):
        s_chunk, dec = inp  # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + s_chunk
        return new, carry  # emit the *incoming* state for this chunk

    init = (
        state.astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, nh, n, p), jnp.float32)
    )
    s_cm = jnp.moveaxis(s_c, 1, 0)          # (nc,B,H,N,P)
    dec_m = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,B,H)
    final_state, incoming = jax.lax.scan(scan_body, init, (s_cm, dec_m))
    incoming = jnp.moveaxis(incoming, 0, 1)  # (B,nc,H,N,P)

    in_decay = jnp.exp(cum)                  # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bcin,bchnp,bcih->bcihp", ch, incoming.astype(ch.dtype),
        in_decay.astype(ch.dtype), preferred_element_type=jnp.float32,
    )

    y = (y_intra + y_inter).astype(x.dtype).reshape(b, seq, nh, p)
    y = y + xh.reshape(b, seq, nh, p) * params["skip_d"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, seq, nh * p)
    y = rmsnorm(y * jax.nn.silu(z), params["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, (final_state, new_tail)


def ssd_decode(params, x, state, conv_tail, cfg: ArchConfig):
    """Single-step SSD recurrence. state: (B,H,N,P) fp32."""
    s = cfg.ssm
    b = x.shape[0]
    z, xs, b_, c_, dt, new_tail, nh = _ssd_project(params, x, cfg, conv_tail)
    p, n, g = s.head_dim, s.state_dim, s.n_groups
    xh = xs.reshape(b, 1, nh, p)[:, 0]
    bh = b_.reshape(b, 1, g, n)[:, 0, 0] if g == 1 else b_.reshape(b, g, n)
    ch = c_.reshape(b, 1, g, n)[:, 0, 0] if g == 1 else c_.reshape(b, g, n)
    dt0 = dt[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt0 * a[None, :])  # (B,H)
    upd = jnp.einsum("bn,bhp,bh->bhnp", bh.astype(jnp.float32),
                     xh.astype(jnp.float32), dt0)
    new_state = state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", ch.astype(jnp.float32), new_state)
    y = y.astype(x.dtype) + xh * params["skip_d"][None, :, None].astype(x.dtype)
    y = y.reshape(b, nh * p)
    y = rmsnorm(y * jax.nn.silu(z[:, 0]), params["gnorm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["w_out"])[:, None, :]
    return out, (new_state, new_tail)
