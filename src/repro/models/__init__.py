"""LM model zoo: composable blocks covering the 10 assigned architectures.

Block taxonomy (each layer = sequence mixer + channel mixer):
  sequence mixers : gqa | local_gqa | mla | rglru | ssd
  channel mixers  : ffn (swiglu / squared_relu / gelu) | moe | none

Layers stack via lax.scan over run-length-encoded segments of identical
layer kinds (keeps HLO size O(1) in depth — required to compile 96-layer
models for 512 devices on the CPU host).
"""

from repro.models.model import (  # noqa: F401
    decode_step,
    forward_train,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.params import param_specs, count_params  # noqa: F401
