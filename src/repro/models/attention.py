"""Attention mixers: GQA (full/local) + MLA, train and decode paths.

Train/prefill attention is a pure-JAX flash formulation: scan over query
chunks with an inner `fori_loop` over only the *causally reachable* (and,
for local attention, window-reachable) KV chunks, carrying online-softmax
statistics. This keeps peak memory at one (Tq, Tk) score tile per head
group and avoids the 2x FLOP waste of rectangular masking — important both
for the real TPU target and for honest roofline FLOP counts.

Decode attends one query position against the whole KV cache. The cache is
sequence-sharded over the 'model' mesh axis (GQA kv_heads are too few to
shard 16-way); the softmax reduction over the sharded axis is expressed
with ordinary jnp ops + sharding constraints so GSPMD inserts the
FlashDecoding-style partial-max/partial-sum collectives.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

_NEG = -1e30


def rmsnorm(x, w, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., S, H, hd), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


class FlashCarry(NamedTuple):
    acc: jax.Array  # (B, Tq, KV, G, vd) fp32
    m: jax.Array    # (B, Tq, KV, G) running max
    l: jax.Array    # (B, Tq, KV, G) running denom


def _chunk_mask(q_pos, k_pos, window):
    mask = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    return mask


def _flash_fwd_impl(q, k, v, window, chunk_q, chunk_k, scale):
    """Returns (out (B,S,KV,G,vd), lse (B,S,KV,G)). Exact causal/window FLOPs:
    the inner fori only visits reachable KV chunks (dynamic bounds are fine
    forward-only; the backward is a custom VJP below)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    vd = v.shape[-1]
    g = h // kv
    nq, nk = s // chunk_q, s // chunk_k
    qr = q.reshape(b, nq, chunk_q, kv, g, hd)
    kr = k.reshape(b, nk, chunk_k, kv, hd)
    vr = v.reshape(b, nk, chunk_k, kv, vd)

    def q_chunk_body(_, i):
        qc = qr[:, i]
        q_pos = i * chunk_q + jnp.arange(chunk_q)
        j_hi = (i + 1) * chunk_q // chunk_k
        if window is None:
            j_lo = jnp.int32(0)
        else:
            j_lo = jnp.maximum(i * chunk_q - (window - 1), 0) // chunk_k

        def kv_body(j, carry: FlashCarry):
            kc, vc = kr[:, j], vr[:, j]
            k_pos = j * chunk_k + jnp.arange(chunk_k)
            scores = jnp.einsum(
                "bqkgd,btkd->bqkgt", qc, kc, preferred_element_type=jnp.float32
            ) * scale
            mask = _chunk_mask(q_pos, k_pos, window)
            scores = jnp.where(mask[None, :, None, None, :], scores, _NEG)
            m_new = jnp.maximum(carry.m, scores.max(axis=-1))
            p = jnp.exp(scores - m_new[..., None])
            alpha = jnp.exp(carry.m - m_new)
            l_new = carry.l * alpha + p.sum(axis=-1)
            pv = jnp.einsum(
                "bqkgt,btkd->bqkgd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32,
            )
            return FlashCarry(carry.acc * alpha[..., None] + pv, m_new, l_new)

        init = FlashCarry(
            jnp.zeros((b, chunk_q, kv, g, vd), jnp.float32),
            jnp.full((b, chunk_q, kv, g), _NEG, jnp.float32),
            jnp.zeros((b, chunk_q, kv, g), jnp.float32),
        )
        carry = jax.lax.fori_loop(j_lo, j_hi, kv_body, init)
        l_safe = jnp.maximum(carry.l, 1e-30)
        out = (carry.acc / l_safe[..., None]).astype(q.dtype)
        lse = carry.m + jnp.log(l_safe)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_chunk_body, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, kv, g, vd)
    lse = jnp.moveaxis(lses, 0, 1).reshape(b, s, kv, g)
    return out, lse


def _flash_bwd_impl(q, k, v, out, lse, do, window, chunk_q, chunk_k, scale):
    """FlashAttention backward: scan over KV chunks (accumulating dk, dv),
    inner dynamic fori over the reachable q chunks, dq accumulated in the
    carry. Same exact-causal FLOP structure as forward."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    vd = v.shape[-1]
    g = h // kv
    nq, nk = s // chunk_q, s // chunk_k
    qr = q.reshape(b, nq, chunk_q, kv, g, hd)
    kr = k.reshape(b, nk, chunk_k, kv, hd)
    vr = v.reshape(b, nk, chunk_k, kv, vd)
    dor = do.reshape(b, nq, chunk_q, kv, g, vd)
    lser = lse.reshape(b, nq, chunk_q, kv, g)
    # delta_i = rowsum(do * out)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    deltar = delta.reshape(b, nq, chunk_q, kv, g)

    def kv_chunk_body(dq_acc, j):
        kc, vc = kr[:, j], vr[:, j]
        k_pos = j * chunk_k + jnp.arange(chunk_k)
        i_lo = (j * chunk_k) // chunk_q
        if window is None:
            i_hi = nq
        else:
            i_hi = jnp.minimum(
                ((j + 1) * chunk_k - 1 + window - 1) // chunk_q + 1, nq
            )

        def q_body(i, carry):
            dq_acc, dk_j, dv_j = carry
            qc = qr[:, i]
            doc = dor[:, i]
            q_pos = i * chunk_q + jnp.arange(chunk_q)
            scores = jnp.einsum(
                "bqkgd,btkd->bqkgt", qc, kc, preferred_element_type=jnp.float32
            ) * scale
            mask = _chunk_mask(q_pos, k_pos, window)
            p = jnp.where(
                mask[None, :, None, None, :],
                jnp.exp(scores - lser[:, i][..., None]), 0.0,
            )
            dv_j = dv_j + jnp.einsum(
                "bqkgt,bqkgd->btkd", p, doc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            dp = jnp.einsum(
                "bqkgd,btkd->bqkgt", doc, vc, preferred_element_type=jnp.float32
            )
            ds = p * (dp - deltar[:, i][..., None]) * scale
            dq_i = jnp.einsum(
                "bqkgt,btkd->bqkgd", ds, kc, preferred_element_type=jnp.float32
            )
            dq_acc = jax.lax.dynamic_update_index_in_dim(
                dq_acc, dq_acc[:, i] + dq_i, i, axis=1
            )
            dk_j = dk_j + jnp.einsum(
                "bqkgt,bqkgd->btkd", ds, qc.astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
            return dq_acc, dk_j, dv_j

        dk0 = jnp.zeros((b, chunk_k, kv, hd), jnp.float32)
        dv0 = jnp.zeros((b, chunk_k, kv, vd), jnp.float32)
        dq_acc, dk_j, dv_j = jax.lax.fori_loop(
            i_lo, i_hi, q_body, (dq_acc, dk0, dv0)
        )
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((b, nq, chunk_q, kv, g, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_chunk_body, dq0, jnp.arange(nk))
    dq = dq.reshape(b, s, kv, g, hd).reshape(b, s, h, hd).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, s, kv, hd).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, s, kv, vd).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_core(q, k, v, window, chunk_q, chunk_k, scale):
    out, _ = _flash_fwd_impl(q, k, v, window, chunk_q, chunk_k, scale)
    return out


def _flash_core_fwd(q, k, v, window, chunk_q, chunk_k, scale):
    out, lse = _flash_fwd_impl(q, k, v, window, chunk_q, chunk_k, scale)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(window, chunk_q, chunk_k, scale, res, g_out):
    q, k, v, out, lse = res
    b, s, kv, grp, vd = out.shape
    do = g_out.reshape(b, s, kv * grp, vd)
    out_flat = out.reshape(b, s, kv * grp, vd)
    lse_flat = lse
    dq, dk, dv = _flash_bwd_impl(
        q, k, v, out_flat, lse_flat.reshape(b, s, kv, grp), do,
        window, chunk_q, chunk_k, scale,
    )
    return dq, dk, dv


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(
    q: jax.Array,   # (B, S, H, hd)
    k: jax.Array,   # (B, S, KV, hd)
    v: jax.Array,   # (B, S, KV, vd)
    *,
    window: int | None = None,
    chunk_q: int = 512,
    chunk_k: int = 512,
    scale: float | None = None,
) -> jax.Array:
    """Causal (optionally windowed) flash attention with custom VJP.

    Both directions touch only causally/window-reachable KV chunks, so HLO
    FLOPs equal the true attention FLOPs (no rectangular masking waste) —
    this matters for the roofline accounting as much as for speed."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    vd = v.shape[-1]
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    chunk_q = min(chunk_q, s)
    chunk_k = min(chunk_k, s)
    assert s % chunk_q == 0 and s % chunk_k == 0, (s, chunk_q, chunk_k)
    out = _flash_core(q, k, v, window, chunk_q, chunk_k, scale)
    return out.reshape(b, s, h, vd)


def decode_attention(
    q: jax.Array,        # (B, 1, H, hd)
    k_cache: jax.Array,  # (B, S_max, KV, hd)
    v_cache: jax.Array,  # (B, S_max, KV, vd)
    pos: jax.Array,      # () current position (number of cached tokens)
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """One-token attention against the full cache (dense; GSPMD shards S)."""
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    qh = q.reshape(b, kv, g, hd) * scale
    scores = jnp.einsum(
        "bkgd,btkd->bkgt", qh, k_cache, preferred_element_type=jnp.float32
    )
    k_pos = jnp.arange(k_cache.shape[1])
    mask = k_pos[None, :] <= pos
    if window is not None:
        mask &= (pos - k_pos[None, :]) < window
    scores = jnp.where(mask[:, None, None, :], scores, _NEG)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum(
        "bkgt,btkd->bkgd", (p / l).astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, -1).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------


def gqa_forward(params, x, positions, cfg: ArchConfig, *, window=None):
    """Full-sequence GQA (train / prefill). Returns (out, (k, v))."""
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", h, params["wq"])
    k = jnp.einsum("bsd,dke->bske", h, params["wk"])
    v = jnp.einsum("bsd,dke->bske", h, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = flash_attention(q, k, v, window=window)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), (k, v)


def gqa_decode(params, x, k_cache, v_cache, pos, cfg: ArchConfig, *, window=None):
    """Single-token GQA. Returns (out, (k_new, v_new)) — caller updates cache.

    Windowed (local) attention uses a *ring buffer* cache of exactly
    `window` slots (write at pos % window): keys keep their absolute-rotary
    embedding, so attention over the ring needs no extra window masking —
    that sizing is what makes long_500k decode O(window) for the hybrid
    archs. Full attention is the window = cache_len special case of the
    same formula."""
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhe->bshe", h, params["wq"])
    k = jnp.einsum("bsd,dke->bske", h, params["wk"])
    v = jnp.einsum("bsd,dke->bske", h, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    cache_len = k_cache.shape[1]
    write_idx = jnp.mod(pos, cache_len)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, write_idx, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, write_idx, axis=1)
    out = decode_attention(q, k_cache, v_cache, pos)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V3)
# ---------------------------------------------------------------------------


def _mla_qkv(params, h, positions, cfg: ArchConfig):
    m = cfg.mla
    q_lat = rmsnorm(
        jnp.einsum("bsd,dr->bsr", h, params["wq_a"]), params["q_norm"], cfg.norm_eps
    )
    q = jnp.einsum("bsr,rhe->bshe", q_lat, params["wq_b"])
    q_nope, q_rope = q[..., : m.nope_head_dim], q[..., m.nope_head_dim :]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", h, params["wkv_a"])
    c_kv = rmsnorm(kv_a[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank :][:, :, None, :]  # (B, S, 1, rope_hd)
    k_rope = rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(params, x, positions, cfg: ArchConfig):
    """Full-sequence MLA: expand per-head K/V from the latent (train mode)."""
    m = cfg.mla
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, h, positions, cfg)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhe->bshe", c_kv, params["wv_b"])
    n_heads = cfg.n_heads
    k_rope_b = jnp.broadcast_to(
        k_rope, (*k_rope.shape[:2], n_heads, m.rope_head_dim)
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    out = flash_attention(q_full, k_full, v, scale=scale)
    return jnp.einsum("bshe,hed->bsd", out, params["wo"]), (c_kv, k_rope)


def mla_decode(params, x, ckv_cache, krope_cache, pos, cfg: ArchConfig):
    """Absorbed-matrix MLA decode: score directly against the latent cache.

    scores = (q_nope @ W_uk) . c_kv + q_rope . k_rope — the per-head K is
    never materialized; the value path likewise contracts the latent first.
    This is the memory-optimal MLA serving mode (DeepSeek-V3 §MLA).
    """
    m = cfg.mla
    b = x.shape[0]
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, h, positions, cfg)

    ckv_cache = jax.lax.dynamic_update_slice_in_dim(ckv_cache, c_kv_new, pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope_new, pos, axis=1
    )

    # absorb W_uk into q: (B,1,H,nope) x (r,H,nope) -> (B,H,r)
    q_lat = jnp.einsum("bshe,rhe->bhr", q_nope, params["wk_b"])
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bhr,btr->bht", q_lat, ckv_cache, preferred_element_type=jnp.float32)
        + jnp.einsum(
            "bshe,bte->bht", q_rope, krope_cache[:, :, 0, :],
            preferred_element_type=jnp.float32,
        )
    ) * scale
    k_pos = jnp.arange(ckv_cache.shape[1])
    scores = jnp.where(k_pos[None, None, :] <= pos, scores, _NEG)
    p = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum(
        "bht,btr->bhr", p.astype(ckv_cache.dtype), ckv_cache,
        preferred_element_type=jnp.float32,
    )
    out = jnp.einsum("bhr,rhe->bhe", ctx_lat.astype(x.dtype), params["wv_b"])
    out = jnp.einsum("bhe,hed->bd", out, params["wo"])[:, None, :]
    return out, (ckv_cache, krope_cache)
