"""Parameter specs: shapes + logical sharding axes for every architecture.

The spec tree is the single source of truth used by
  * init_params (real initialization),
  * the dry-run (ShapeDtypeStructs with NamedShardings — no allocation),
  * the analytic parameter counts (cross-checked in tests).

Logical axis vocabulary (mapped to mesh axes by repro.dist.sharding):
  vocab   — vocabulary dim            -> tensor-parallel ('model')
  embed   — residual stream dim       -> FSDP (('pod','data'))
  heads   — query heads               -> tensor-parallel ('model')
  kv      — kv heads (small, uneven)  -> replicated
  head    — per-head dim              -> replicated
  ff      — FFN hidden                -> tensor-parallel ('model')
  experts — MoE expert dim            -> expert-parallel ('model')
  eff     — per-expert FFN hidden     -> replicated
  inner   — SSM / recurrent inner dim -> tensor-parallel ('model')
  state   — SSM state dim             -> replicated
  layers  — scan-stacked layer dim    -> replicated
  lora    — MLA low-rank dims         -> replicated
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig

VOCAB_PAD_MULTIPLE = 128


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[str | None, ...]
    dtype: object = jnp.bfloat16
    init: str = "normal"        # normal | zeros | ones
    fan_in_axes: tuple[int, ...] = (0,)  # axes whose product is fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def padded_vocab(cfg: ArchConfig) -> int:
    v = cfg.vocab_size
    return ((v + VOCAB_PAD_MULTIPLE - 1) // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


# ---------------------------------------------------------------------------
# layer plan: RLE segments of identical layer kinds (scan units)
# ---------------------------------------------------------------------------


def layer_kind(cfg: ArchConfig, i: int) -> str:
    """'mixer+channel' kind string for layer i."""
    pattern = cfg.block_pattern
    mixer = pattern[i % len(pattern)]
    if mixer == "attn":
        mixer = cfg.attn_type  # gqa | mla
    if mixer in ("ssd",):
        return mixer  # ssd blocks have no separate channel mixer
    channel = "ffn"
    if cfg.moe is not None:
        m = cfg.moe
        if i >= m.moe_layer_start and (i - m.moe_layer_start) % m.moe_layer_period == 0:
            channel = "moe"
    return f"{mixer}+{channel}"


def layer_plan(cfg: ArchConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(unit_kinds, repeats), ...] — each segment scans `repeats` copies of
    the `unit_kinds` block sequence."""
    kinds = [layer_kind(cfg, i) for i in range(cfg.n_layers)]
    period = len(cfg.block_pattern)
    segments: list[tuple[tuple[str, ...], int]] = []
    i = 0
    while i < len(kinds):
        best_unit, best_cover = (kinds[i],), 1
        for p in {1, period}:
            unit = tuple(kinds[i : i + p])
            if len(unit) < p:
                continue
            r = 1
            while kinds[i + r * p : i + (r + 1) * p] == list(unit):
                r += 1
            if r * p > best_cover:
                best_unit, best_cover = unit, r * p
        segments.append((best_unit, best_cover // len(best_unit)))
        i += best_cover
    return segments


# ---------------------------------------------------------------------------
# per-block specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ArchConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv", "head")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv", "head")),
        "wo": ParamSpec((h, hd, d), ("heads", "head", "embed"), fan_in_axes=(0, 1)),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((h, hd), ("heads", "head"), init="zeros")
        s["bk"] = ParamSpec((kv, hd), ("kv", "head"), init="zeros")
        s["bv"] = ParamSpec((kv, hd), ("kv", "head"), init="zeros")
    return s


def _mla_specs(cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "wq_a": ParamSpec((d, m.q_lora_rank), ("embed", "lora")),
        "q_norm": ParamSpec((m.q_lora_rank,), ("lora",), init="ones"),
        "wq_b": ParamSpec((m.q_lora_rank, h, qk), ("lora", "heads", "head")),
        "wkv_a": ParamSpec(
            (d, m.kv_lora_rank + m.rope_head_dim), ("embed", "lora")
        ),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("lora",), init="ones"),
        "wk_b": ParamSpec(
            (m.kv_lora_rank, h, m.nope_head_dim), ("lora", "heads", "head")
        ),
        "wv_b": ParamSpec(
            (m.kv_lora_rank, h, m.v_head_dim), ("lora", "heads", "head")
        ),
        "wo": ParamSpec(
            (h, m.v_head_dim, d), ("heads", "head", "embed"), fan_in_axes=(0, 1)
        ),
    }


def _ffn_specs(cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "wi": ParamSpec((d, f), ("embed", "ff")),
        "wo": ParamSpec((f, d), ("ff", "embed")),
    }
    if cfg.ffn_act == "swiglu":
        s["wg"] = ParamSpec((d, f), ("embed", "ff"))
    return s


def _moe_specs(cfg: ArchConfig) -> dict:
    m = cfg.moe
    d, e, fe = cfg.d_model, m.num_experts, m.d_ff_expert
    s = {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "router": ParamSpec((d, e), ("embed", None), dtype=jnp.float32),
        "w_in": ParamSpec((e, d, fe), ("experts", "embed", "eff")),
        "w_out": ParamSpec((e, fe, d), ("experts", "eff", "embed"), fan_in_axes=(1,)),
    }
    if cfg.ffn_act == "swiglu":
        s["w_gate"] = ParamSpec((e, d, fe), ("experts", "embed", "eff"))
    if m.n_shared:
        fs = m.d_ff_shared * m.n_shared
        s["ws_in"] = ParamSpec((d, fs), ("embed", "ff"))
        s["ws_out"] = ParamSpec((fs, d), ("ff", "embed"))
        if cfg.ffn_act == "swiglu":
            s["ws_gate"] = ParamSpec((d, fs), ("embed", "ff"))
    return s


def _rglru_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    conv_w = cfg.ssm.conv_width if cfg.ssm else 4
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        "w_x": ParamSpec((d, d), ("embed", "inner")),
        "w_gate": ParamSpec((d, d), ("embed", "inner")),
        "conv": ParamSpec((conv_w, d), (None, "inner"), init="normal"),
        "lam": ParamSpec((d,), ("inner",), init="lru_lambda", dtype=jnp.float32),
        "wa": ParamSpec((d,), ("inner",), init="zeros", dtype=jnp.float32),
        "ba": ParamSpec((d,), ("inner",), init="zeros", dtype=jnp.float32),
        "wi_g": ParamSpec((d,), ("inner",), init="zeros", dtype=jnp.float32),
        "bi_g": ParamSpec((d,), ("inner",), init="zeros", dtype=jnp.float32),
        "w_out": ParamSpec((d, d), ("inner", "embed")),
    }


def _ssd_specs(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    h = d_in // s.head_dim
    gn = s.n_groups * s.state_dim
    conv_dim = d_in + 2 * gn
    return {
        "ln": ParamSpec((d,), ("embed",), init="ones"),
        # in_proj packs [z(d_in), x(d_in), B(gn), C(gn), dt(h)]
        "w_in": ParamSpec((d, 2 * d_in + 2 * gn + h), ("embed", "inner")),
        "conv": ParamSpec((s.conv_width, conv_dim), (None, "inner")),
        "a_log": ParamSpec((h,), (None,), init="ssd_alog", dtype=jnp.float32),
        "dt_bias": ParamSpec((h,), (None,), init="ssd_dt", dtype=jnp.float32),
        "skip_d": ParamSpec((h,), (None,), init="ones", dtype=jnp.float32),
        "gnorm": ParamSpec((d_in,), ("inner",), init="ones"),
        "w_out": ParamSpec((d_in, d), ("inner", "embed")),
    }


_MIXER_SPECS = {
    "gqa": _attn_specs,
    "local_attn": _attn_specs,
    "mla": _mla_specs,
    "rglru": _rglru_specs,
    "ssd": _ssd_specs,
}


def block_specs(cfg: ArchConfig, kind: str) -> dict:
    """Spec tree for one layer of the given kind ('mixer+channel' or 'ssd')."""
    if kind == "ssd":
        return {"mixer": _ssd_specs(cfg)}
    mixer, channel = kind.split("+")
    out = {"mixer": _MIXER_SPECS[mixer](cfg)}
    if channel == "ffn":
        out["channel"] = _ffn_specs(cfg)
    elif channel == "moe":
        out["channel"] = _moe_specs(cfg)
    return out


def _stack(spec: ParamSpec, n: int) -> ParamSpec:
    return ParamSpec(
        (n, *spec.shape), ("layers", *spec.logical), spec.dtype, spec.init,
        tuple(a + 1 for a in spec.fan_in_axes),
    )


def param_specs(cfg: ArchConfig) -> dict:
    """Full spec tree: embedding, segments (scan-stacked), final norm, head."""
    d = cfg.d_model
    v = padded_vocab(cfg)
    specs: dict = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), fan_in_axes=(1,)),
        "final_norm": ParamSpec((d,), ("embed",), init="ones"),
    }
    if cfg.frontend:
        specs["frontend_proj"] = ParamSpec(
            (cfg.frontend_dim, d), (None, "embed")
        )
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    if cfg.mtp_heads:
        specs["mtp_head"] = ParamSpec((d, v), ("embed", "vocab"))
    segs = []
    for unit, repeats in layer_plan(cfg):
        blocks = []
        for kind in unit:
            tree = block_specs(cfg, kind)
            blocks.append(jax.tree.map(
                lambda s: _stack(s, repeats), tree,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            ))
        segs.append({"kinds": unit, "repeats": repeats, "blocks": blocks})
    specs["segments"] = segs
    return specs


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------


def _init_leaf(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "lru_lambda":
        # Griffin: a = sigmoid(Lambda) with a^c in [0.9, 0.999]
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        a_c = u ** (1.0 / 8.0)
        return jnp.log(a_c / (1 - a_c)).astype(spec.dtype)
    if spec.init == "ssd_alog":
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(spec.dtype)
    if spec.init == "ssd_dt":
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1e-3, 1e-1)
        # inverse softplus
        return (u + jnp.log(-jnp.expm1(-u))).astype(spec.dtype)
    fan_in = int(np.prod([spec.shape[a] for a in spec.fan_in_axes]))
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def _map_specs(fn, specs):
    """tree-map over the spec tree (segments hold dicts with non-spec keys)."""
    if isinstance(specs, ParamSpec):
        return fn(specs)
    if isinstance(specs, dict):
        out = {}
        for k, v in specs.items():
            if k in ("kinds", "repeats"):
                continue
            out[k] = _map_specs(fn, v)
        return out
    if isinstance(specs, list):
        return [_map_specs(fn, v) for v in specs]
    raise TypeError(type(specs))


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    specs = param_specs(cfg)
    flat: list[ParamSpec] = []
    _map_specs(lambda s: flat.append(s) or s, specs)
    keys = jax.random.split(key, len(flat))
    it = iter(range(len(flat)))

    def mk(spec: ParamSpec):
        i = next(it)
        s = spec if spec.dtype != jnp.bfloat16 else ParamSpec(
            spec.shape, spec.logical, dtype, spec.init, spec.fan_in_axes
        )
        return _init_leaf(keys[i], s)

    return _map_specs(mk, specs)


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Analytic parameter count from the spec tree. With active_only, MoE
    expert params count only top_k/num_experts of routed experts (6*N_active
    roofline convention)."""
    specs = param_specs(cfg)
    total = 0

    def add(path_is_expert: bool, s: ParamSpec):
        n = int(np.prod(s.shape))
        if active_only and path_is_expert and cfg.moe:
            n = int(n * cfg.moe.top_k / cfg.moe.num_experts)
        return n

    def walk(tree, expert=False):
        nonlocal total
        if isinstance(tree, ParamSpec):
            total += add(expert, tree)
            return
        if isinstance(tree, dict):
            for k, v in tree.items():
                if k in ("kinds", "repeats"):
                    continue
                walk(v, expert or k in ("w_in", "w_out", "w_gate"))
            return
        if isinstance(tree, list):
            for v in tree:
                walk(v)

    walk(specs)
    return total
