"""Channel mixers: dense FFN variants + expert-parallel MoE.

MoE runs inside `shard_map` for explicit, predictable collectives
(DESIGN.md §5):

  * tokens arrive data-sharded (batch over ('pod','data')), replicated over
    'model';
  * experts are sharded over 'model' (expert parallelism) and their d_model
    axis is FSDP-sharded over ('pod','data') — each layer all-gathers its
    expert weights over the FSDP axes (ZeRO-3 semantics, required to fit
    671B-class models);
  * every model rank redundantly computes the (deterministic) router for its
    token shard, gathers the top-C tokens per *local* expert (capacity
    semantics: lowest-probability overflow drops, standard top-k capacity
    MoE), runs the expert FFNs as batched einsums, scatter-adds weighted
    outputs, and psums partial outputs over 'model'.

The psum combine is the baseline; EXPERIMENTS.md §Perf evaluates the
all-to-all alternative.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig
from repro.models.attention import rmsnorm


def _act(cfg: ArchConfig, gate_or_pre, pre=None):
    if cfg.ffn_act == "swiglu":
        return jax.nn.silu(gate_or_pre) * pre
    if cfg.ffn_act == "squared_relu":
        r = jax.nn.relu(gate_or_pre)
        return r * r
    if cfg.ffn_act == "gelu":
        return jax.nn.gelu(gate_or_pre)
    raise ValueError(cfg.ffn_act)


def ffn_forward(params, x, cfg: ArchConfig, rt=None):
    from repro.dist.tp import col_matmul_ffn, row_matmul_ffn

    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    if rt is None or not rt.explicit_tp:
        pre = jnp.einsum("bsd,df->bsf", h, params["wi"])
        if cfg.ffn_act == "swiglu":
            act = _act(cfg, jnp.einsum("bsd,df->bsf", h, params["wg"]), pre)
        else:
            act = _act(cfg, pre)
        return jnp.einsum("bsf,fd->bsd", act, params["wo"])
    pre = col_matmul_ffn(h, params["wi"], rt)
    if cfg.ffn_act == "swiglu":
        act = _act(cfg, col_matmul_ffn(h, params["wg"], rt), pre)
    else:
        act = _act(cfg, pre)
    return row_matmul_ffn(act, params["wo"], rt)


def _shared_expert(params, h, cfg: ArchConfig):
    pre = jnp.einsum("bsd,df->bsf", h, params["ws_in"])
    if cfg.ffn_act == "swiglu":
        act = _act(cfg, jnp.einsum("bsd,df->bsf", h, params["ws_gate"]), pre)
    else:
        act = _act(cfg, pre)
    return jnp.einsum("bsf,fd->bsd", act, params["ws_out"])


def _capacity(t: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(t * m.top_k / m.num_experts * m.capacity_factor)
    c = max(8, (c + 7) // 8 * 8)
    return min(t, c)


def _moe_decode_gather(params, h, cfg: ArchConfig, rt):
    """Weights-stationary decode MoE (EXPERIMENTS.md §Perf, deepseek cell).

    The baseline path FSDP-gathers full expert weights per layer — at decode
    that moves ~GBs of parameters to process a handful of tokens. Here the
    weights never move: the *tokens* (tiny at decode) are all-gathered over
    the dp axes, every device applies its (E_loc, d_loc) weight shard with
    the d-contraction completed by a psum over dp, and the (tokens, d_loc)
    partial outputs return to the batch-sharded layout with one small
    all-to-all. Collective volume scales with tokens, not parameters.
    """
    m = cfg.moe
    b, s, d = h.shape
    has_gate = cfg.ffn_act == "swiglu"
    dp, tp = rt.dp_axes, rt.tp_axis
    e_total = m.num_experts

    def inner(h_loc, router, w_in, w_gate, w_out):
        # h_loc (B_loc, 1, d); w_* (E_loc, d_loc, f) / (E_loc, f, d_loc)
        x = jax.lax.all_gather(h_loc, dp, axis=0, tiled=True)  # (B, 1, d)
        t = x.shape[0]
        xt = x.reshape(t, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        vals, ids = jax.lax.top_k(probs, m.top_k)
        vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
        e_loc = e_total // rt.tp_size
        rank = jax.lax.axis_index(tp)
        local_ids = rank * e_loc + jnp.arange(e_loc)
        match = ids[:, :, None] == local_ids[None, None, :]
        gate = jnp.einsum("tk,tke->te", vals, match.astype(vals.dtype))
        score = jnp.where(gate > 0, gate, -1.0)
        # generous decode capacity: drops at decode are a serving bug, and
        # the dense (e_loc, cap) compute is tiny at single-token batches
        cap = min(t, max(16, int(t * m.top_k / e_total * max(m.capacity_factor, 2.0)) + 8))
        top_gate, top_idx = jax.lax.top_k(score.T, cap)  # (e_loc, cap)
        valid = top_gate > 0
        # d-contraction on the local d_loc slice, completed by a dp psum
        d_loc = w_in.shape[1]
        drank = 0
        for ax in rt.dp_axes:  # linearized dp rank
            drank = drank * rt.mesh.shape[ax] + jax.lax.axis_index(ax)
        xe = jnp.take(xt, top_idx.reshape(-1), axis=0).reshape(e_loc, cap, d)
        xe_loc = jax.lax.dynamic_slice_in_dim(xe, drank * d_loc, d_loc, axis=2)
        pre = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xe_loc, w_in), dp)
        if has_gate:
            g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", xe_loc, w_gate), dp)
            act = jax.nn.silu(g) * pre
        else:
            act = _act(cfg, pre)
        ye = jnp.einsum("ecf,efd->ecd", act, w_out)  # (e_loc, cap, d_loc)
        w_comb = jnp.where(valid, top_gate, 0.0).astype(ye.dtype)
        ye = ye * w_comb[:, :, None]
        out = jnp.zeros((t, d_loc), ye.dtype).at[top_idx.reshape(-1)].add(
            ye.reshape(-1, d_loc)
        )
        out = jax.lax.psum(out, tp)  # (t, d_loc), complete over experts
        # (t, d_loc) -> (t_loc, d): transpose layouts with one all-to-all
        bl = t // rt.dp_size
        out = out.reshape(rt.dp_size, bl, d_loc)
        ex = jax.lax.all_to_all(
            out, dp, split_axis=0, concat_axis=0, tiled=True
        )  # (ranks, bl, d_loc), indexed by source (= d-slice) rank
        out = jnp.moveaxis(ex, 0, 1).reshape(bl, 1, d)
        return out

    w_gate = params.get("w_gate", params["w_in"])
    out = shard_map(
        inner,
        mesh=rt.mesh,
        in_specs=(
            P(dp, None, None),
            P(None, None),
            P(tp, dp, None),
            P(tp, dp, None),
            P(tp, None, dp),
        ),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(h, params["router"], params["w_in"], w_gate, params["w_out"])
    if m.n_shared:
        out = out + _shared_expert(params, h, cfg)
    return out


def moe_forward(params, x, cfg: ArchConfig, rt):
    """Expert-parallel MoE. x: (B, S, d) data-sharded. rt: Runtime (mesh)."""
    m = cfg.moe
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    b, s, d = h.shape
    if rt.moe_decode_gather and s == 1 and rt.dp_size > 1:
        return _moe_decode_gather(params, h, cfg, rt)
    cap = _capacity(max(b * s // rt.dp_size, 1), cfg)
    has_gate = cfg.ffn_act == "swiglu"
    dp, tp = rt.dp_axes, rt.tp_axis

    def inner(h_loc, router, w_in, w_gate, w_out):
        # h_loc (B_loc, S, d); w_* (E_loc, d_loc, f) / (E_loc, f, d_loc)
        bl, sl, _ = h_loc.shape
        t = bl * sl
        xt = h_loc.reshape(t, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        vals, ids = jax.lax.top_k(probs, m.top_k)  # (t, k)
        vals = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)

        e_loc = m.num_experts // rt.tp_size
        rank = jax.lax.axis_index(tp)
        local_ids = rank * e_loc + jnp.arange(e_loc)  # global expert ids
        # gate (t, e_loc): combine weight if token routed to local expert
        match = ids[:, :, None] == local_ids[None, None, :]  # (t, k, e_loc)
        gate = jnp.einsum("tk,tke->te", vals, match.astype(vals.dtype))
        score = jnp.where(gate > 0, gate, -1.0)
        top_gate, top_idx = jax.lax.top_k(score.T, cap)  # (e_loc, cap)
        valid = top_gate > 0

        # FSDP: re-materialize full expert weights for this layer
        w_in_f = jax.lax.all_gather(w_in, dp, axis=1, tiled=True)
        w_out_f = jax.lax.all_gather(w_out, dp, axis=2, tiled=True)
        xe = jnp.take(xt, top_idx.reshape(-1), axis=0).reshape(e_loc, cap, d)
        pre = jnp.einsum("ecd,edf->ecf", xe, w_in_f)
        if has_gate:
            w_g_f = jax.lax.all_gather(w_gate, dp, axis=1, tiled=True)
            act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_g_f)) * pre
        else:
            act = _act(cfg, pre)
        ye = jnp.einsum("ecf,efd->ecd", act, w_out_f)
        w_comb = jnp.where(valid, top_gate, 0.0).astype(ye.dtype)
        ye = ye * w_comb[:, :, None]
        out = jnp.zeros((t, d), ye.dtype).at[top_idx.reshape(-1)].add(
            ye.reshape(-1, d)
        )
        out = jax.lax.psum(out, tp)
        return out.reshape(bl, sl, d)

    w_gate = params.get("w_gate", params["w_in"])  # placeholder when not gated
    out = shard_map(
        inner,
        mesh=rt.mesh,
        in_specs=(
            P(dp, None, None),
            P(None, None),
            P(tp, dp, None),
            P(tp, dp, None),
            P(tp, None, dp),
        ),
        out_specs=P(dp, None, None),
        check_rep=False,
    )(h, params["router"], params["w_in"], w_gate, params["w_out"])

    if m.n_shared:
        out = out + _shared_expert(params, h, cfg)
    return out
