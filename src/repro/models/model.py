"""Model assembly: embedding -> scanned block segments -> loss / decode.

Layer stacking uses lax.scan over run-length-encoded segments of identical
layer kinds (see params.layer_plan): each segment's parameters are stacked
on a leading 'layers' axis, so HLO size is O(#segments), not O(depth).
Activation remat (jax.checkpoint) wraps each scan body when rt.remat.

Cross-entropy is computed in sequence chunks against the vocab-sharded head
so the full (B, S, V) logits tensor never materializes (V up to 256k).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import Runtime, constrain
from repro.models import attention as attn
from repro.models import ffn as ffn_mod
from repro.models import recurrent as rec
from repro.models.params import ParamSpec, layer_plan

LOSS_CHUNK = 1024
MTP_WEIGHT = 0.3


# ---------------------------------------------------------------------------
# embedding / frontends
# ---------------------------------------------------------------------------


def embed_input(params, batch: dict, cfg: ArchConfig):
    """tokens (B,S) int32 -> embeddings; or stub-frontend frames (B,S,fd)."""
    if "frames" in batch:
        return jnp.einsum("bsf,fd->bsd", batch["frames"],
                          params["frontend_proj"])
    tokens = batch["tokens"]
    return jnp.take(params["embed"], tokens, axis=0)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _apply_block(kind: str, bp, x, positions, cfg: ArchConfig, rt: Runtime):
    """One layer (sequence mixer + channel mixer), full-sequence mode.

    Returns (x, cache_entry) — cache entries feed the decode path when this
    runs as prefill."""
    if kind == "ssd":
        y, state = rec.ssd_forward(bp["mixer"], x, cfg)
        return x + y, {"state": state[0], "tail": state[1]}
    mixer, channel = kind.split("+")
    if mixer in ("gqa", "local_attn"):
        window = cfg.local_window if mixer == "local_attn" else None
        y, (k, v) = attn.gqa_forward(bp["mixer"], x, positions, cfg, window=window)
        cache = {"k": k, "v": v}
    elif mixer == "mla":
        y, (ckv, krope) = attn.mla_forward(bp["mixer"], x, positions, cfg)
        cache = {"ckv": ckv, "krope": krope}
    elif mixer == "rglru":
        y, (state, tail) = rec.rglru_forward(bp["mixer"], x, cfg)
        cache = {"state": state, "tail": tail}
    else:
        raise ValueError(mixer)
    x = x + y
    if channel == "ffn":
        x = x + ffn_mod.ffn_forward(bp["channel"], x, cfg, rt)
    elif channel == "moe":
        x = x + ffn_mod.moe_forward(bp["channel"], x, cfg, rt)
    return x, cache


def _apply_block_decode(kind: str, bp, x, cache, pos, cfg: ArchConfig, rt: Runtime):
    """One layer, single-token decode mode. Returns (x, new_cache)."""
    if kind == "ssd":
        y, (state, tail) = rec.ssd_decode(bp["mixer"], x, cache["state"],
                                          cache["tail"], cfg)
        return x + y, {"state": state, "tail": tail}
    mixer, channel = kind.split("+")
    if mixer in ("gqa", "local_attn"):
        window = cfg.local_window if mixer == "local_attn" else None
        y, (k_c, v_c) = attn.gqa_decode(bp["mixer"], x, cache["k"], cache["v"],
                                        pos, cfg, window=window)
        new_cache = {"k": k_c, "v": v_c}
    elif mixer == "mla":
        y, (ckv, krope) = attn.mla_decode(bp["mixer"], x, cache["ckv"],
                                          cache["krope"], pos, cfg)
        new_cache = {"ckv": ckv, "krope": krope}
    elif mixer == "rglru":
        y, (state, tail) = rec.rglru_decode(bp["mixer"], x, cache["state"],
                                            cache["tail"], cfg)
        new_cache = {"state": state, "tail": tail}
    else:
        raise ValueError(mixer)
    x = x + y
    if channel == "ffn":
        x = x + ffn_mod.ffn_forward(bp["channel"], x, cfg, rt)
    elif channel == "moe":
        x = x + ffn_mod.moe_forward(bp["channel"], x, cfg, rt)
    return x, new_cache


# ---------------------------------------------------------------------------
# backbone
# ---------------------------------------------------------------------------


def _backbone(params, x, positions, cfg: ArchConfig, rt: Runtime,
              collect_cache: bool = False):
    """Scan the segment stack. Returns (hidden, cache_segments|None)."""
    plan = layer_plan(cfg)
    caches = []
    for (unit, repeats), seg in zip(plan, params["segments"]):

        def seg_body(h, blocks, unit=unit):
            h = constrain(h, rt, ("batch", "seq_act", "embed_act"))
            entries = []
            for kind, bp in zip(unit, blocks):
                h, entry = _apply_block(kind, bp, h, positions, cfg, rt)
                entries.append(entry)
            return h, entries if collect_cache else None

        body = jax.checkpoint(seg_body) if rt.remat else seg_body
        x, ys = jax.lax.scan(body, x, seg["blocks"])
        caches.append(ys)
    return x, caches if collect_cache else None


def forward_train(params, batch, cfg: ArchConfig, rt: Runtime):
    """Full-sequence forward -> final hidden states (B, S, d)."""
    x = embed_input(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _backbone(params, x, positions, cfg, rt)
    return attn.rmsnorm(x, params["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def _head_matrix(params, cfg: ArchConfig):
    if cfg.tie_embeddings or "lm_head" not in params:
        return params["embed"].T
    return params["lm_head"]


def _chunked_xent(hidden, labels, head, cfg: ArchConfig):
    """Mean next-token cross-entropy without materializing (B, S, V)."""
    b, s, d = hidden.shape
    v_real = cfg.vocab_size
    chunk = min(LOSS_CHUNK, s)
    assert s % chunk == 0
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d)
    ls = labels.reshape(b, nc, chunk)

    def body(carry, inp):
        h, y = inp  # (B, C, d), (B, C)
        logits = jnp.einsum("bcd,dv->bcv", h, head).astype(jnp.float32)
        # mask padded vocab entries out of the partition function
        v_pad = logits.shape[-1]
        if v_pad > v_real:
            pad_mask = jnp.arange(v_pad) >= v_real
            logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(y, 0)[..., None], axis=-1
        )[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        tot, cnt = carry
        return (tot + ((lse - gold) * valid).sum(), cnt + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)),
        (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ls, 1, 0)),
    )
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, batch, cfg: ArchConfig, rt: Runtime):
    """Next-token LM loss (+ DeepSeek-style MTP auxiliary when configured).

    batch: {"tokens" | "frames", "labels" (B, S) with -1 padding}.
    """
    hidden = forward_train(params, batch, cfg, rt)
    head = _head_matrix(params, cfg)
    labels = batch["labels"]
    # shift: hidden[t] predicts labels[t] (labels are pre-shifted by the
    # pipeline: labels[t] = tokens[t+1])
    loss = _chunked_xent(hidden, labels, head, cfg)
    metrics = {"lm_loss": loss}
    if cfg.mtp_heads:
        # multi-token prediction: predict labels shifted one step further
        mtp_labels = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1
        )
        mtp_loss = _chunked_xent(hidden, mtp_labels, params["mtp_head"], cfg)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + MTP_WEIGHT * mtp_loss
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: cache specs, prefill, decode
# ---------------------------------------------------------------------------


def cache_specs(cfg: ArchConfig, batch: int, s_max: int) -> list:
    """ParamSpec tree for the decode cache, aligned with params['segments'].

    Attention caches shard sequence over 'model' (cache_seq) and batch over
    dp; recurrent states shard their channel dim over 'model'."""
    plan = layer_plan(cfg)
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    segs = []
    for unit, repeats in plan:
        entries = []
        for kind in unit:
            if kind == "ssd":
                s = cfg.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                gn = s.n_groups * s.state_dim
                entries.append({
                    "state": ParamSpec(
                        (repeats, batch, nh, s.state_dim, s.head_dim),
                        ("layers", "batch", "inner", None, None), jnp.float32),
                    "tail": ParamSpec(
                        (repeats, batch, s.conv_width - 1, d_in + 2 * gn),
                        ("layers", "batch", None, "inner"), jnp.bfloat16),
                })
                continue
            mixer, _ = kind.split("+")
            if mixer in ("gqa", "local_attn"):
                # local attention caches a ring buffer of `window` slots
                s_len = min(s_max, cfg.local_window) if mixer == "local_attn" else s_max
                entries.append({
                    "k": ParamSpec(
                        (repeats, batch, s_len, cfg.n_kv_heads, hd),
                        ("layers", "batch", "cache_seq", "kv", "head"),
                        jnp.bfloat16),
                    "v": ParamSpec(
                        (repeats, batch, s_len, cfg.n_kv_heads, hd),
                        ("layers", "batch", "cache_seq", "kv", "head"),
                        jnp.bfloat16),
                })
            elif mixer == "mla":
                m = cfg.mla
                entries.append({
                    "ckv": ParamSpec(
                        (repeats, batch, s_max, m.kv_lora_rank),
                        ("layers", "batch", "cache_seq", None), jnp.bfloat16),
                    "krope": ParamSpec(
                        (repeats, batch, s_max, 1, m.rope_head_dim),
                        ("layers", "batch", "cache_seq", None, None),
                        jnp.bfloat16),
                })
            elif mixer == "rglru":
                w = cfg.ssm.conv_width if cfg.ssm else 4
                entries.append({
                    "state": ParamSpec((repeats, batch, d),
                                       ("layers", "batch", "inner"),
                                       jnp.float32),
                    "tail": ParamSpec((repeats, batch, w - 1, d),
                                      ("layers", "batch", None, "inner"),
                                      jnp.bfloat16),
                })
        segs.append(entries)
    return segs


def init_cache(cfg: ArchConfig, batch: int, s_max: int, rt: Runtime):
    from repro.models.params import _map_specs
    from repro.dist.sharding import logical_to_spec
    from jax.sharding import NamedSharding

    def mk(s: ParamSpec):
        sh = NamedSharding(rt.mesh, logical_to_spec(s.logical, s.shape, rt))
        return jnp.zeros(s.shape, s.dtype, device=sh)

    return _map_specs(mk, cache_specs(cfg, batch, s_max))


def prefill(params, batch, cfg: ArchConfig, rt: Runtime, s_max: int | None = None):
    """Full-sequence forward that also materializes the decode cache.

    Returns (last_hidden (B, 1, d), cache). Attention caches come out sized
    (R, B, S, ...); pass s_max > S to right-pad for subsequent decode.
    """
    x = embed_input(params, batch, cfg)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, caches = _backbone(params, x, positions, cfg, rt, collect_cache=True)
    hidden = attn.rmsnorm(x, params["final_norm"], cfg.norm_eps)

    def pad_seq(a, axis):
        if s_max is None or a.shape[axis] >= s_max:
            return a
        pad = [(0, 0)] * a.ndim
        pad[axis] = (0, s_max - a.shape[axis])
        return jnp.pad(a, pad)

    fixed = []
    for entries in caches:
        seg_entries = []
        for entry in entries:
            e = dict(entry)
            for key in ("k", "v", "ckv", "krope"):
                if key in e:
                    e[key] = pad_seq(e[key], axis=2)  # (R, B, S, ...)
            for key in ("state",):
                if key in e and e[key].dtype != jnp.float32:
                    e[key] = e[key].astype(jnp.float32)
            seg_entries.append(e)
        fixed.append(seg_entries)
    return hidden[:, -1:, :], fixed


def decode_step(params, tokens, cache, pos, cfg: ArchConfig, rt: Runtime):
    """One decode step. tokens: (B, 1) int32; pos: () int32 — number of
    tokens already in the cache. Returns (logits (B, 1, V), new_cache)."""
    x = embed_input(params, {"tokens": tokens}, cfg)
    plan = layer_plan(cfg)
    new_cache = []
    for (unit, repeats), seg, seg_cache in zip(plan, params["segments"], cache):

        def seg_body(h, xs, unit=unit):
            blocks, entries = xs
            new_entries = []
            for kind, bp, entry in zip(unit, blocks, entries):
                h, ne = _apply_block_decode(kind, bp, h, entry, pos, cfg, rt)
                new_entries.append(ne)
            return h, new_entries

        x, updated = jax.lax.scan(seg_body, x, (seg["blocks"], seg_cache))
        new_cache.append(updated)
    hidden = attn.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = _head_matrix(params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", hidden, head)
    return logits, new_cache


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    from repro.models.params import init_params as _init

    return _init(cfg, key, dtype)
