"""LLaVA-NeXT-34B: VLM backbone (anyres tiling frontend is a stub).

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6 family; unverified]. The vision tower + anyres patch
projection is stubbed: input_specs() provides precomputed patch embeddings.
"""

from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llava_next_34b",
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20_480,
        vocab_size=64_000,
        rope_theta=5_000_000.0,
        ffn_act="swiglu",
        frontend="vision_patches",
        frontend_dim=1152,    # SigLIP-style patch embedding dim (stub)
        source="hf:llava-hf/llava-v1.6-34b; unverified",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="llava_next_34b_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab_size=512, frontend_dim=32,
    )
