"""Architecture configs: the 10 assigned architectures + the paper's own
retrieval configs. Each <arch>.py exposes `config()` (the exact published
configuration) and `smoke()` (a reduced same-family variant for CPU tests).
"""

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    MLAConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    get_arch,
    list_archs,
)
