"""Llama-4-Scout-17B-16E: MoE top-1 with shared expert, interleaved MoE
layers, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
"""

from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama4_scout_17b_a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,            # dense-layer / shared-path FFN width
        vocab_size=202_048,
        rope_theta=500_000.0,
        ffn_act="swiglu",
        moe=MoEConfig(
            num_experts=16,
            top_k=1,
            d_ff_expert=8192,
            n_shared=1,
            d_ff_shared=8192,
            moe_layer_start=0,
            moe_layer_period=1,   # every layer is MoE in Scout
            capacity_factor=1.25,
        ),
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="llama4_scout_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512,
        # generous smoke capacity: see deepseek smoke config note
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=128, n_shared=1,
                      d_ff_shared=128, capacity_factor=8.0),
    )
