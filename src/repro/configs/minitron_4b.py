"""Minitron-4B: pruned Nemotron (squared-ReLU FFN, GQA).

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000
[arXiv:2407.14679; hf].
"""

from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="minitron_4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab_size=256_000,
        ffn_act="squared_relu",
        source="arXiv:2407.14679; hf",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="minitron_4b_smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=288, vocab_size=512,
    )
