"""Mamba2-1.3B: attention-free SSM with state-space duality (SSD).

48L d_model=2048 vocab=50280, ssm_state=128 [arXiv:2405.21060; unverified].
Sub-quadratic -> runs the long_500k shape. Mamba2 blocks replace both
attention and FFN (d_ff=0 per the assignment).
"""

from repro.configs.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2_1_3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=64,           # SSD heads: expand*d_model / head_dim
        n_kv_heads=0,
        d_ff=0,               # attention-free, FFN-free (SSD block only)
        vocab_size=50_280,
        attn_type="none",
        block_pattern=("ssd",),
        ssm=SSMConfig(state_dim=128, head_dim=64, n_groups=1, conv_width=4,
                      expand=2, chunk=128),
        source="arXiv:2405.21060; unverified",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="mamba2_1_3b_smoke", n_layers=2, d_model=64, n_heads=4,
        vocab_size=256,
        ssm=SSMConfig(state_dim=16, head_dim=32, n_groups=1, conv_width=4,
                      expand=2, chunk=16),
    )
