"""Qwen2.5-32B: dense GQA with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B family config; hf].
"""

from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2_5_32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27_648,
        vocab_size=152_064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        ffn_act="swiglu",
        source="hf:Qwen/Qwen2.5-32B; hf",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="qwen2_5_32b_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab_size=512,
    )
