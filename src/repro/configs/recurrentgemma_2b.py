"""RecurrentGemma-2B (Griffin): RG-LRU recurrent blocks + local attention, 1:2.

26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf]. Pattern: (recurrent, recurrent, local_attn) cycled.
Sub-quadratic -> runs the long_500k shape.
"""

from repro.configs.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma_2b",
        family="hybrid",
        n_layers=26,          # 26 blocks: pattern cycles rglru,rglru,local
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256_000,
        ffn_act="swiglu",     # GeGLU in the paper; gated family
        block_pattern=("rglru", "rglru", "local_attn"),
        local_window=2048,
        ssm=SSMConfig(state_dim=0, head_dim=0, conv_width=4),  # conv width for rec block
        source="arXiv:2402.19427; hf",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="recurrentgemma_2b_smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=192, vocab_size=256, local_window=32,
    )
