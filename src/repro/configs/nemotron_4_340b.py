"""Nemotron-4-340B: dense GQA with squared-ReLU FFN.

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000
[arXiv:2402.16819; unverified].
"""

from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="nemotron_4_340b",
        family="dense",
        n_layers=96,
        d_model=18_432,
        n_heads=96,
        n_kv_heads=8,
        d_ff=73_728,
        vocab_size=256_000,
        ffn_act="squared_relu",
        source="arXiv:2402.16819; unverified",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="nemotron_4_340b_smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=384, vocab_size=512,
    )
