"""TinyLlama-1.1B: llama2-architecture small model.

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000 [arXiv:2401.02385; hf].
"""

from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama_1_1b",
        family="dense",
        n_layers=22,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=5632,
        vocab_size=32_000,
        ffn_act="swiglu",
        source="arXiv:2401.02385; hf",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="tinyllama_1_1b_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab_size=256,
    )
