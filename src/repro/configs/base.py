"""Config schema for the model zoo + the assigned input-shape grid."""

from __future__ import annotations

import importlib
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts
    d_ff_shared: int = 0         # hidden dim of the shared expert(s)
    moe_layer_start: int = 0     # first MoE layer (earlier layers are dense)
    moe_layer_period: int = 1    # every k-th layer is MoE (llama4 interleave)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128         # N
    head_dim: int = 64           # P
    n_groups: int = 1            # G (B/C groups)
    conv_width: int = 4
    expand: int = 2
    chunk: int = 128             # SSD chunk length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # None -> d_model // n_heads
    attn_type: str = "gqa"       # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    ffn_act: str = "swiglu"      # swiglu | squared_relu | gelu
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # per-layer block pattern, cycled: e.g. ("rglru", "rglru", "local_attn")
    block_pattern: tuple[str, ...] = ("attn",)
    local_window: int = 2048
    frontend: str | None = None  # audio_frames | vision_patches | None
    frontend_dim: int = 0        # embedding dim provided by the stub frontend
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp_heads: int = 0           # multi-token-prediction aux heads (DeepSeek)
    source: str = ""             # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """True when no block attends to unbounded context (long_500k gate)."""
        return all(b in ("rglru", "ssd", "local_attn") for b in self.block_pattern)

    def param_count(self) -> int:
        """Total parameters (analytic; cross-checked by tests)."""
        from repro.models.params import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.params import count_params

        return count_params(self, active_only=True)

    def with_overrides(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "musicgen_large",
    "tinyllama_1_1b",
    "qwen2_5_32b",
    "nemotron_4_340b",
    "minitron_4b",
    "recurrentgemma_2b",
    "deepseek_v3_671b",
    "llama4_scout_17b_a16e",
    "mamba2_1_3b",
    "llava_next_34b",
]


def get_arch(name: str, smoke: bool = False) -> ArchConfig:
    """Load an architecture config by id (dashes/dots tolerated)."""
    mod_name = name.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.smoke() if smoke else mod.config()


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; long_500k only for sub-quadratic."""
    out = []
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        for shape in SHAPES.values():
            skip = shape.name == "long_500k" and not cfg.sub_quadratic
            if skip and not include_skips:
                continue
            out.append((arch_id, shape.name, skip))
    return out
