"""MusicGen-Large: decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32, i.e. full MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]. The EnCodec frontend is a stub: input_specs()
provides precomputed frame embeddings (DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen_large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        ffn_act="gelu",          # MusicGen uses standard transformer FFN
        frontend="audio_frames",
        frontend_dim=128,        # EnCodec latent frame dim (stub)
        source="arXiv:2306.05284; hf",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="musicgen_large_smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=256, vocab_size=128, frontend_dim=16,
    )
