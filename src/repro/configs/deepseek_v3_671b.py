"""DeepSeek-V3-671B: MLA attention + fine-grained MoE (1 shared + 256 routed
top-8) + MTP.

61L d_model=7168 128H (MLA) d_ff(expert)=2048 vocab=129280, first 3 layers
dense (d_ff=18432) [arXiv:2412.19437; hf].
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek_v3_671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,       # MLA: per-head latent expansion
        d_ff=18_432,          # dense-layer FFN width (first 3 layers)
        vocab_size=129_280,
        attn_type="mla",
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            rope_head_dim=64,
            nope_head_dim=128,
            v_head_dim=128,
        ),
        ffn_act="swiglu",
        moe=MoEConfig(
            num_experts=256,
            top_k=8,
            d_ff_expert=2048,
            n_shared=1,
            d_ff_shared=2048,
            moe_layer_start=3,     # layers 0-2 are dense
            capacity_factor=1.25,
        ),
        mtp_heads=1,
        source="arXiv:2412.19437; hf",
    )


def smoke() -> ArchConfig:
    return config().with_overrides(
        name="deepseek_v3_671b_smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=192, vocab_size=512,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
                      nope_head_dim=16, v_head_dim=16),
        # generous capacity so smoke-scale token counts never overflow
        # (capacity drops are train-path-only semantics; the prefill/decode
        # consistency tests need drop-free routing at tiny T)
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, n_shared=1,
                      d_ff_shared=64, moe_layer_start=1, capacity_factor=8.0),
    )
