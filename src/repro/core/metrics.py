"""Lp distance computation under universal p (paper §2.1).

The paper's key systems observation is a *hardware cost asymmetry*:

  p = 1, 2        -> basic arithmetic only (CPU: AVX-512 add/sub/mul; TPU: VPU
                     full-rate elementwise, and for p=2 the MXU matmul identity
                     ||x-y||^2 = ||x||^2 + ||y||^2 - 2<x,y>).
  p = 0.5, 1.5    -> adds a sqrt (CPU: _mm512_sqrt_ps; TPU: VPU transcendental).
  other p         -> needs |d|^p = exp(p*log|d|), two transcendentals per
                     element -> more than an order of magnitude slower.

This module provides the pure-jnp implementations (the Pallas kernels in
repro.kernels mirror these exactly; kernels/ref.py re-exports from here) plus
the analytic TPU op-cost model used by benchmarks/fig1_lp_distance_cost.py.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.lp_ops import EPS as _EPS  # noqa: F401  (back-compat export)
from repro.core.lp_ops import abs_pow, is_static_p, lp_root

# p-values whose Lp distance evaluates without transcendentals (fast family).
BASIC_PS = (1.0, 2.0)
# p-values that need only a sqrt on top of basic arithmetic (paper §2.1).
SQRT_PS = (0.5, 1.5)

# The op-sequence table lives in repro.core.lp_ops (shared with the Pallas
# kernel bodies); these aliases keep the historical private names alive.
_abs_diff_pow = abs_pow
_root = lp_root


def _as_p_vec(p) -> jax.Array:
    """Coerce a per-query p to a (B,) float32 array (the traced-p contract).

    A 0-d jax scalar becomes (1,) so it broadcasts as "one p for every
    row" instead of crashing the per-row indexing.
    """
    p = jnp.asarray(p, dtype=jnp.float32)
    return p[None] if p.ndim == 0 else p


@partial(jax.jit, static_argnames=("p", "root"))
def _lp_distance_s(x, y, p: float, root: bool):
    s = jnp.sum(_abs_diff_pow(x - y, p), axis=-1)
    return _root(s, p) if root else s


@partial(jax.jit, static_argnames=("root",))
def _lp_distance_v(x, y, p, root: bool):
    s = jnp.sum(_abs_diff_pow(x - y, p[..., None]), axis=-1)
    return _root(s, p) if root else s


def lp_distance(x: jax.Array, y: jax.Array, p, root: bool = True) -> jax.Array:
    """Lp distance between broadcast-compatible vectors along the last axis.

    p: Python float (one compiled program per p) or an array broadcastable
    to the *result* shape (per-element metric; one program for any p mix —
    DESIGN.md §6). With root=False returns sum(|x-y|^p) (same ordering,
    cheaper), which is what the search loops use internally.
    """
    if is_static_p(p):
        return _lp_distance_s(x, y, float(p), root)
    return _lp_distance_v(x, y, _as_p_vec(p), root)


@partial(jax.jit, static_argnames=("p", "root"))
def _pairwise_lp_s(q, x, p: float, root: bool):
    if p == 2.0:
        qq = jnp.sum(q * q, axis=-1)
        xx = jnp.sum(x * x, axis=-1)
        s = qq[:, None] + xx[None, :] - 2.0 * (q @ x.T)
        s = jnp.maximum(s, 0.0)  # clamp fp cancellation
        return jnp.sqrt(s) if root else s
    s = jnp.sum(_abs_diff_pow(q[:, None, :] - x[None, :, :], p), axis=-1)
    return _root(s, p) if root else s


@partial(jax.jit, static_argnames=("root",))
def _pairwise_lp_v(q, x, p, root: bool):
    # Elementwise family selection; rows with p == 2 additionally take the
    # MXU matmul-identity value so they match the scalar p=2 path bit-for-bit
    # (the elementwise diff^2 sum and the matmul identity round differently).
    s = jnp.sum(_abs_diff_pow(q[:, None, :] - x[None, :, :], p[:, None, None]),
                axis=-1)
    qq = jnp.sum(q * q, axis=-1)
    xx = jnp.sum(x * x, axis=-1)
    s2 = jnp.maximum(qq[:, None] + xx[None, :] - 2.0 * (q @ x.T), 0.0)
    s = jnp.where(p[:, None] == 2.0, s2, s)
    return _root(s, p[:, None]) if root else s


def pairwise_lp(q: jax.Array, x: jax.Array, p, root: bool = True) -> jax.Array:
    """All-pairs Lp distances: q (B, d) f32 vs x (N, d) f32 -> (B, N) f32.

    p: Python float, or a (B,) array giving each query row its own metric
    (the mixed-p serving contract, DESIGN.md §6). For p=2 — the scalar
    specialization *and* vector rows equal to 2 — uses the MXU-friendly
    matmul identity (the TPU analogue of the paper's SIMD L2 fast path).
    Other p-values broadcast on the VPU.
    """
    if is_static_p(p):
        return _pairwise_lp_s(q, x, float(p), root)
    return _pairwise_lp_v(q, x, _as_p_vec(p), root)


@partial(jax.jit, static_argnames=("p", "root"))
def _rowwise_lp_s(q, c, p: float, root: bool):
    s = jnp.sum(_abs_diff_pow(q[:, None, :] - c, p), axis=-1)
    return _root(s, p) if root else s


@partial(jax.jit, static_argnames=("root",))
def _rowwise_lp_v(q, c, p, root: bool):
    s = jnp.sum(_abs_diff_pow(q[:, None, :] - c, p[:, None, None]), axis=-1)
    return _root(s, p[:, None]) if root else s


def rowwise_lp(q: jax.Array, c: jax.Array, p, root: bool = True) -> jax.Array:
    """Per-row candidate distances: q (B, d) f32 vs c (B, C, d) f32 -> (B, C).

    This is the verification-step shape: each query has its own gathered
    candidate block. p: Python float or (B,) array — row i is scored under
    p[i] (scalar-vs-vector contract, DESIGN.md §6).
    """
    if is_static_p(p):
        return _rowwise_lp_s(q, c, float(p), root)
    return _rowwise_lp_v(q, c, _as_p_vec(p), root)


# ---------------------------------------------------------------------------
# Analytic TPU op-cost model (reproduces the *shape* of paper Fig. 1).
#
# Costs are in VPU-lane-cycles per element. Calibrated against the public
# TPU ISA characterization: basic ALU ops are full rate (1), transcendentals
# (sqrt/exp/log) occupy the slow path (~7 cycle-equivalents per element).
# The MXU path for p=2 amortizes the d-dim reduction into a matmul running
# at ~128x the VPU flop rate for large candidate tiles.
# ---------------------------------------------------------------------------

VPU_BASIC = 1.0
VPU_TRANSCENDENTAL = 7.0
MXU_SPEEDUP = 64.0  # effective matmul advantage at the tile sizes we use


def lp_op_cost_per_element(p: float, use_mxu: bool = True) -> float:
    """Modelled per-element cost (VPU-cycle-equivalents) of |x-y|^p summation."""
    if p == 2.0:
        # sub, mul, add -- and the mul+add ride the MXU in pairwise form.
        return VPU_BASIC + 2.0 * VPU_BASIC / (MXU_SPEEDUP if use_mxu else 1.0)
    if p == 1.0:
        return 3.0 * VPU_BASIC  # sub, abs, add
    if p in SQRT_PS:
        extra = VPU_BASIC if p == 1.5 else 0.0  # p=1.5 also multiplies a*sqrt(a)
        return 3.0 * VPU_BASIC + VPU_TRANSCENDENTAL + extra
    # general p: sub, abs, log, mul, exp, add
    return 4.0 * VPU_BASIC + 2.0 * VPU_TRANSCENDENTAL


def lp_distance_cost_model(p: float, d: int, use_mxu: bool = True) -> float:
    """Modelled cost (VPU-cycle-equivalents) of one d-dim Lp Q2D distance."""
    per_elem = lp_op_cost_per_element(p, use_mxu=use_mxu)
    # the outer root is O(1) per distance; include it for completeness
    root_cost = 0.0 if p == 1.0 else VPU_TRANSCENDENTAL
    return per_elem * d + root_cost


def transcendental_op_count(p: float, d: int) -> int:
    """Exact transcendental-op count of one d-dim Lp distance (root excluded)."""
    if p in BASIC_PS:
        return 0
    if p in SQRT_PS:
        return d
    return 2 * d  # log + exp per element


def base_metric_for(p, cutoff: float = 1.4):
    """U-HNSW base-index selection rule (paper Alg. 1 line 3): G1 iff p <= 1.4.

    Scalar p -> scalar 1.0/2.0. Array p (a mixed-p batch) -> same-shape f32
    array: the *two-way* G1/G2 partition of the batch (DESIGN.md §6) — the
    number of distinct p values never matters, only which side of the
    cutoff each row falls on.
    """
    import numpy as np

    pa = np.asarray(p, dtype=np.float32)
    # NaN must fail too, so phrase the check as "all inside", not "any outside"
    if not np.all((pa >= 0.5) & (pa <= 2.0)):
        raise ValueError(f"p={p} outside the supported universal range [0.5, 2]")
    if pa.ndim == 0:
        return 1.0 if float(pa) <= cutoff else 2.0
    return np.where(pa <= cutoff, np.float32(1.0), np.float32(2.0))


def numpy_lp(q, x, p: float, root: bool = True):
    """NumPy oracle (no jit) used by tests and the CPU-side graph builder."""
    import numpy as np

    diff = np.abs(np.asarray(q)[..., None, :] - np.asarray(x)[None, :, :])
    s = (diff**p).sum(axis=-1)
    return s ** (1.0 / p) if root else s
