"""U-HNSW (paper Algorithm 1): ANNS under universal Lp metrics.

Query processing for (q, p):
  1. Candidate generation — select G1 (L1) if p <= 1.4 else G2 (L2), run the
     batched JAX beam search (repro.core.hnsw) for the top-t candidates under
     the base metric. t = 300 by default (paper §3.2).
  2. Candidate verification — re-rank candidates under exact Lp, popping
     batches of kappa and early-terminating when the running top-K stabilizes:
     |R_new ∩ R| / K >= tau  (tau = target recall + 0.02 = 0.92 default).

Batched SPMD adaptation (DESIGN.md §2): the verification loop runs with a
vectorized convergence mask — queries that have already terminated stop
counting Lp evaluations (their N_p is frozen), and the `lax.while_loop`
exits when every query in the shard is done. This preserves the paper's
per-query N_p savings while staying jittable.

Special p values: for p == 1 or p == 2 the query *is* a base-metric search
(paper §3 preamble) and the verification step is skipped entirely.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.build import HNSWGraph, build_hnsw
from repro.core.hnsw import GraphArrays, knn_search
from repro.core.metrics import base_metric_for


@dataclass(frozen=True)
class UHNSWParams:
    """Query-time parameters (paper Algorithm 1 + §3.2).

    Attributes:
      t: candidate set size fed to verification (paper §3.2; default 300).
      tau: early-termination threshold |R_new ∩ R| / K (target recall
        + 0.02; paper §3.1).
      kappa: verification batch size; None -> K // 2 (paper §3.1).
      cutoff: base-index selection crossover — G1 (L1) serves p <= cutoff,
        G2 (L2) the rest (paper Fig. 2). Applies per *query row* in a
        mixed-p batch (DESIGN.md §6).
      ef: beam width for candidate generation; None -> 2t.
      max_hops: hard cap on while_loop trips per layer (safety bound).
      expand_width: W-way multi-expansion in the level-0 beam
        (DESIGN.md §2.1); 1 = classic HNSW.
      interpret: exact-Lp scoring backend override, forwarded to
        `kernels.ops.lp_gather_distance` (DESIGN.md §2.1): None =
        backend-aware (fused Pallas kernel on TPU, jnp reference
        elsewhere), True = Pallas kernel in interpret mode (CPU parity
        testing), False = compiled Pallas kernel.
      abandon: early-abandoning blocked-dimension verification
        (DESIGN.md §8, default on). Each kappa batch carries the running
        k-th-best power sum as a per-query threshold; candidates whose
        partial sum over scanned dimension blocks — or whose provable
        base-distance lower bound — already exceeds it skip all remaining
        dimension work. Exact: abandoned candidates provably cannot enter
        the top-k, so returned ids/dists match the full-dimension path
        (`False` reproduces the pre-abandonment path bit-for-bit). The
        skip is real on the TPU kernel; the off-TPU jnp reference
        computes-then-masks, so CPU-bound deployments chasing wall-clock
        (not Eq. 1 dimension-work) may prefer `abandon=False`.
      abandon_block_d: dimension-block width for the abandoning scan;
        None = auto (`kernels.ops.pick_abandon_block_d`: 32 when it
        divides d, the TPU sublane-friendly default).
      compressed_band: two-band verification over the int8 compressed
        storage band (DESIGN.md §10, default off). Each kappa batch is
        first screened against the running k-th best using certified
        lower bounds from the quantized replica (index/compressed.py);
        only survivors issue f32 row gathers for the exact rerank.
        Returned ids *and* dists are bitwise-identical to the
        uncompressed path (a screened candidate's true distance provably
        exceeds the running k-th best, and survivors are rescored from
        the same f32 rows); `False` restores the pre-band program
        bit-for-bit. Requires abandon=True (the screen is the abandon
        path's storage-side sibling); `SearchStats.n_f32_rows_frac` /
        `n_band_frac` report the traffic split.
      energy_perm: scan coordinates in energy order (decreasing
        per-coordinate variance) inside the abandoning verification
        (DESIGN.md §10). Lp is coordinate-separable, so a fixed
        permutation leaves every distance mathematically unchanged;
        front-loading the mass makes the §8 suffix bounds go dead after
        fewer blocks at small p. Surviving candidates' sums reassociate
        across the permuted dimension order, so dists may wobble by
        float-accumulation ulps vs the unpermuted scan (ids ties
        included); default off to preserve the bit-exact legacy program.
    """

    t: int = 300          # candidate set size
    tau: float = 0.92     # early-termination threshold (target recall + 0.02)
    kappa: int | None = None  # verification batch size; None -> K // 2 (§3.1)
    cutoff: float = 1.4   # base-index selection crossover (Fig. 2)
    ef: int | None = None  # beam width for candidate generation; None -> 2t
    max_hops: int = 4096
    expand_width: int = 1  # W-way multi-expansion in the level-0 beam
                           # (DESIGN.md §2 hot path); 1 = classic HNSW
    interpret: bool | None = None  # exact-Lp kernel dispatch override
    abandon: bool = True  # early-abandoning verification (DESIGN.md §8)
    abandon_block_d: int | None = None  # dimension-block width; None = auto
    compressed_band: bool = False  # int8 screen + f32 rerank (DESIGN.md §10)
    energy_perm: bool = False  # energy-ordered abandon scan (DESIGN.md §10)


class CandidateSet(NamedTuple):
    """Device-resident output of the candidate-generation stage.

    The two-stage serving engine (repro.retrieval.engine, DESIGN.md §6)
    dispatches candidate generation and verification as separate device
    calls so the scheduler can pipeline wave N+1's base search against
    wave N's verification. Everything here stays on device between the
    stages; `base_p` names the base metric (1.0 = G1 / 2.0 = G2) the
    candidates were generated under.
    """

    ids: jax.Array         # (B, t) int32, ascending by base-metric distance
    base_dists: jax.Array  # (B, t) root-free base-metric power sums
    n_b: jax.Array         # (B,) base-metric evaluation counts (Eq. 1)
    hops: jax.Array        # (B,) level-0 while_loop trips
    base_p: float          # which base metric generated the candidates
    # cross-segment phase split (ShardedUHNSW two_phase / round_robin,
    # DESIGN.md §3): probe = threshold-free evaluations (phase A / the
    # first cascade turn), spill = evaluations under an inherited pruning
    # bound. n_b == n_b_probe + n_b_spill always; monolithic and
    # independent-policy candidate generation is all probe.
    n_b_probe: jax.Array | None = None   # (B,) defaults to n_b downstream
    n_b_spill: jax.Array | float = 0.0   # (B,) or scalar zero
    n_cand_spill: jax.Array | float = 0.0  # (B,) spill-phase survivors in
                                           # the merged candidate list
    # degraded-coverage serving (DESIGN.md §11). The sharded index's
    # query-time NaN/inf guard masks any candidate whose gathered base
    # distance is non-finite (it can never reach a top-k) and raises the
    # per-row flag so the engine can attribute the poison to a segment.
    poisoned: jax.Array | float = 0.0  # (B,) 1.0 where the guard tripped
    coverage_frac: float = 1.0  # exact served fraction of the corpus
                                # under the alive mask these candidates
                                # were generated with (host-side float)


class SearchStats(NamedTuple):
    n_b: jax.Array        # (B,) base-metric Q2D evaluation counts
    n_p: jax.Array        # (B,) Lp Q2D evaluation counts
    iterations: jax.Array  # () verification loop iterations executed
    base_p: float | np.ndarray  # which base metric generated candidates:
                                # scalar for a single-p batch, (B,) array
                                # for a mixed-p batch (DESIGN.md §6)
    hops: jax.Array | int = 0  # (B,) level-0 while_loop trips (one trip
                               # expands up to expand_width beam entries)
    n_dim_frac: jax.Array | float = 1.0  # (B,) fraction of verification
        # dimension-work actually scanned (DESIGN.md §8): the early-
        # abandoning path skips dimension blocks of candidates already
        # beaten by the running k-th best, so Eq. 1's effective T_p is
        # n_dim_frac * T_p. 1.0 on the full-dimension / base-metric-skip
        # paths. Counted over non-converged rows only, mirroring N_p.
    # cross-segment phase split (DESIGN.md §3). Invariants:
    # n_b == n_b_probe + n_b_spill; n_p_probe + n_p_spill == the graph-
    # verify share of n_p (delta-tier exact scans are neither phase). The
    # N_p split attributes verification work to each phase by its share of
    # merged candidates — probe-phase work is what a monolithic index
    # would also have paid; spill-phase work is the sharding overhead the
    # inherited threshold is squeezing out. Monolithic searches leave the
    # defaults (all probe, zero spill).
    n_b_probe: jax.Array | float | None = None  # None -> equals n_b
    n_b_spill: jax.Array | float = 0.0
    n_p_probe: jax.Array | float | None = None  # None -> equals n_p
    n_p_spill: jax.Array | float = 0.0
    n_f32_rows_frac: jax.Array | float = 1.0  # (B,) fraction of verified
        # candidates whose full f32 rows were actually gathered. The
        # two-band scan (DESIGN.md §10) screens candidates against the
        # compressed band first, so only (first-k + screen survivors)
        # rows hit f32 HBM: gathered f32 bytes = n_f32_rows_frac * n_p *
        # 4d. 1.0 everywhere else (every scored candidate cost a full-row
        # gather, even if the §8 scan then abandoned dimensions).
    n_band_frac: jax.Array | float = 0.0  # (B,) int8 band dimensions
        # scanned by the compressed screen, over n_p * d — the band-side
        # byte traffic (1 byte/dim vs 4 on the f32 side): bytes ratio
        # vs the uncompressed path = n_f32_rows_frac + n_band_frac / 4.
        # 0.0 when no compressed band is in play.
    # degraded-coverage serving (DESIGN.md §11): quarantined segments are
    # masked out of the search, and every result says exactly how much of
    # the corpus it covered. coverage_frac is exact — (alive frozen rows +
    # delta rows) / total rows, computed host-side from the health tracker
    # at candidate-generation time. Monolithic searches always report 1.0.
    coverage_frac: float = 1.0
    degraded: bool = False  # coverage_frac < 1.0
    poisoned: jax.Array | float = 0.0  # (B,) 1.0 where the query-time
        # NaN/inf guard masked non-finite gathered distances (the engine
        # bisects this back to a segment and quarantines it)

    def phase_n_b(self):
        """(probe, spill) N_b split with the None default resolved."""
        probe = self.n_b if self.n_b_probe is None else self.n_b_probe
        return probe, self.n_b_spill

    def phase_n_p(self):
        """(probe, spill) N_p split with the None default resolved."""
        probe = self.n_p if self.n_p_probe is None else self.n_p_probe
        return probe, self.n_p_spill


def _verify_impl(
    Q: jax.Array,         # (B, d)
    cand_ids: jax.Array,  # (B, t) sorted ascending by base-metric distance
    X: jax.Array,         # (n, d)
    p,                    # static float, or traced (B,) f32
    k: int,
    kappa: int,
    tau: float,
    interpret: bool | None,
):
    B, t = cand_ids.shape
    n_batches = max((t - k) // kappa, 0)
    # the root broadcast: scalar p applies as-is, per-row p gains a column
    p_col = p if metrics.is_static_p(p) else p[:, None]

    # Imported at trace time (not module scope): repro.core.__init__ pulls in
    # this module, so a top-level kernels import here would make the
    # repro.kernels <-> repro.core import order matter.
    from repro.kernels.ops import lp_gather_distance

    def lp_block(ids):
        """Exact Lp distances for a candidate id block; padding -> inf.

        Routed through the single dispatch entry point (kernels/ops.py):
        fused gather+distance Pallas kernel on TPU, jnp reference off-TPU.
        """
        return lp_gather_distance(Q, ids, X, p, root=False,
                                  interpret=interpret)

    def topk_merge(ids_a, d_a, ids_b, d_b):
        ids = jnp.concatenate([ids_a, ids_b], axis=1)
        d = jnp.concatenate([d_a, d_b], axis=1)
        sd, si = jax.lax.sort((d, ids), num_keys=1)
        return si[:, :k], sd[:, :k]

    # line 7: R <- first K points of C (their Lp distances count toward N_p)
    first = cand_ids[:, :k]
    r_dist = lp_block(first)
    r_dist, r_ids = jax.lax.sort((r_dist, first), num_keys=1)
    n_p0 = jnp.full((B,), k, dtype=jnp.int32)

    if n_batches == 0:
        return r_ids, metrics._root(r_dist, p_col), n_p0, jnp.int32(0)

    def cond(s):
        i, _, _, done, _ = s
        return (i < n_batches) & ~jnp.all(done)

    def body(s):
        i, r_ids, r_dist, done, n_p = s
        start = k + i * kappa
        batch = jax.lax.dynamic_slice(cand_ids, (0, start), (B, kappa))
        bd = lp_block(batch)  # (B, kappa) exact Lp, padding -> inf
        new_ids, new_dist = topk_merge(r_ids, r_dist, batch, bd)
        # |R_new ∩ R| via id-equality (ids are unique per query)
        inter = (new_ids[:, :, None] == r_ids[:, None, :]).any(-1).sum(-1)
        ratio = inter.astype(jnp.float32) / k
        newly_done = ratio >= tau
        keep = done[:, None]
        r_ids = jnp.where(keep, r_ids, new_ids)
        r_dist = jnp.where(keep, r_dist, new_dist)
        n_p = n_p + jnp.where(done, 0, kappa)
        return (i + 1, r_ids, r_dist, done | newly_done, n_p)

    state = (jnp.int32(0), r_ids, r_dist, jnp.zeros((B,), bool), n_p0)
    iters, r_ids, r_dist, done, n_p = jax.lax.while_loop(cond, body, state)
    return r_ids, metrics._root(r_dist, p_col), n_p, iters


def _verify_abandon_impl(
    Q: jax.Array,          # (B, d)
    cand_ids: jax.Array,   # (B, t) sorted ascending by base-metric distance
    cand_base: jax.Array,  # (B, t) base-metric power sums (beam distances)
    X: jax.Array,          # (n, d)
    p,                     # static float, or traced (B,) f32
    k: int,
    kappa: int,
    tau: float,
    base_p: float,
    interpret: bool | None,
    block_d: int | None,
    x_scan: jax.Array | None = None,  # (n, d) energy-permuted corpus view
    perm: jax.Array | None = None,    # (d,) the permutation (x_scan order)
):
    """Threshold-propagating early-abandoning verification (DESIGN.md §8).

    Same convergence protocol as `_verify_impl`, but each kappa batch
    passes the running k-th-best power sum into the abandoning kernel as
    a per-query threshold (frozen rows pass -inf, skipping their work
    entirely), and the full (k + kappa) `lax.sort` merge becomes a
    masked `lax.top_k` merge — abandoned candidates are +inf, so top_k's
    lowest-index tie rule selects exactly what the stable sort did.
    Returns the extra `n_dim_frac` (B,) — scanned dimension-work fraction.

    When (x_scan, perm) are given, the blocked scan runs over the
    energy-ordered corpus view (UHNSWParams.energy_perm, DESIGN.md §10):
    Lp is coordinate-separable, so permuting q and x identically leaves
    every distance mathematically unchanged while the high-variance
    coordinates land in the earliest blocks and trip the abandon
    thresholds sooner. The first-k scoring stays on the original (Q, X)
    so the starting R is bitwise-identical either way; surviving
    candidates' sums reassociate across the permuted order (ulp wobble
    covered by the kernel contract's float tolerance).
    """
    B, t = cand_ids.shape
    d = Q.shape[1]
    n_batches = max((t - k) // kappa, 0)
    p_col = p if metrics.is_static_p(p) else p[:, None]

    from repro.kernels.ops import lp_gather_abandon, lp_gather_distance

    Qs = Q if perm is None else jnp.take(Q, perm, axis=1)
    Xs = X if x_scan is None else x_scan

    # line 7: R <- first K points of C, scored full-dimension (no threshold
    # exists yet; these are also the rows the abandon path must match
    # bit-for-bit so both paths start from the identical R).
    first = cand_ids[:, :k]
    r_dist = lp_gather_distance(Q, first, X, p, root=False,
                                interpret=interpret)
    r_dist, r_ids = jax.lax.sort((r_dist, first), num_keys=1)
    n_p0 = jnp.full((B,), k, dtype=jnp.int32)
    ones = jnp.ones((B,), jnp.float32)

    if n_batches == 0:
        return r_ids, metrics._root(r_dist, p_col), n_p0, jnp.int32(0), ones

    dim0 = ones * (k * d)

    def cond(s):
        i, _, _, done, _, _ = s
        return (i < n_batches) & ~jnp.all(done)

    def body(s):
        i, r_ids, r_dist, done, n_p, dim_scan = s
        start = k + i * kappa
        batch = jax.lax.dynamic_slice(cand_ids, (0, start), (B, kappa))
        bbase = jax.lax.dynamic_slice(cand_base, (0, start), (B, kappa))
        # threshold propagation: the current k-th best power sum bounds
        # what can still enter R; frozen rows abandon everything at entry
        thresh = jnp.where(done, -jnp.inf, r_dist[:, k - 1])
        bd, nd = lp_gather_abandon(
            Qs, batch, Xs, thresh, bbase, p, base_p=base_p,
            interpret=interpret, block_d=block_d,
        )
        # masked top-k merge (abandoned candidates are +inf): lax.top_k
        # prefers the lower index on ties, matching the stable sort's
        # concat-order preference, so selection is identical to the
        # legacy (k + kappa) lax.sort at a fraction of the work.
        all_d = jnp.concatenate([r_dist, bd], axis=1)
        all_i = jnp.concatenate([r_ids, batch], axis=1)
        neg, sel = jax.lax.top_k(-all_d, k)
        new_dist = -neg
        new_ids = jnp.take_along_axis(all_i, sel, axis=1)
        inter = (new_ids[:, :, None] == r_ids[:, None, :]).any(-1).sum(-1)
        ratio = inter.astype(jnp.float32) / k
        newly_done = ratio >= tau
        keep = done[:, None]
        r_ids = jnp.where(keep, r_ids, new_ids)
        r_dist = jnp.where(keep, r_dist, new_dist)
        n_p = n_p + jnp.where(done, 0, kappa)
        dim_scan = dim_scan + jnp.where(
            done, 0.0, nd.sum(axis=1).astype(jnp.float32))
        return (i + 1, r_ids, r_dist, done | newly_done, n_p, dim_scan)

    state = (jnp.int32(0), r_ids, r_dist, jnp.zeros((B,), bool), n_p0,
             dim0)
    iters, r_ids, r_dist, done, n_p, dim_scan = \
        jax.lax.while_loop(cond, body, state)
    # the denominator needs no separate carry: n_p accrues kappa under
    # exactly the mask dim_scan uses, so total offered work == n_p * d
    return (r_ids, metrics._root(r_dist, p_col), n_p, iters,
            dim_scan / (n_p.astype(jnp.float32) * d))


def _verify_two_band_impl(
    Q: jax.Array,          # (B, d) original coordinate order
    Qp: jax.Array,         # (B, d) band (energy-permuted) coordinate order
    cand_ids: jax.Array,   # (B, t) sorted ascending by base-metric distance
    cand_base: jax.Array,  # (B, t) base-metric power sums (beam distances)
    X: jax.Array,          # (n, d) f32 exact rows
    codes: jax.Array,      # (n, d) int8 compressed band (band coord order)
    scale: jax.Array,      # (d,) f32 dequant scales (band order)
    radius: jax.Array,     # (d,) f32 max dequant error (band order)
    p,                     # static float, or traced (B,) f32
    k: int,
    kappa: int,
    tau: float,
    base_p: float,
    interpret: bool | None,
    block_d: int | None,
):
    """Two-band verification: int8 screen, then exact f32 rerank of the
    survivors (DESIGN.md §10).

    Same convergence protocol as `_verify_abandon_impl`, but each kappa
    batch first runs the compressed-band screen (`lp_gather_screen`):
    candidates whose certified lower bound already exceeds the running
    k-th best are dropped *before* any f32 row gather; only survivors hit
    f32 HBM, via `lp_gather_distance` on the keep-masked id block.

    Bitwise parity with the uncompressed paths, by construction: a
    screened candidate's true power sum provably exceeds the running
    k-th best (the bound is admissible and the kill strict), so it could
    never enter R; survivors are rescored full-dimension from the same
    f32 rows by the same elementwise-independent kernel, so ids AND
    dists match `abandon=False` exactly (the same masked top_k merge as
    the §8 path keeps selection identical to the stable sort).

    Returns (ids, rooted dists, n_p, iters, n_dim_frac, n_f32_rows_frac,
    n_band_frac) — the last two are the SearchStats traffic counters.
    """
    B, t = cand_ids.shape
    d = Q.shape[1]
    n_batches = max((t - k) // kappa, 0)
    p_col = p if metrics.is_static_p(p) else p[:, None]

    from repro.kernels.ops import lp_gather_distance, lp_gather_screen

    # line 7: R <- first K points of C, scored full-dimension from f32
    # rows (no threshold exists yet to screen against).
    first = cand_ids[:, :k]
    r_dist = lp_gather_distance(Q, first, X, p, root=False,
                                interpret=interpret)
    r_dist, r_ids = jax.lax.sort((r_dist, first), num_keys=1)
    n_p0 = jnp.full((B,), k, dtype=jnp.int32)
    ones = jnp.ones((B,), jnp.float32)
    zeros = jnp.zeros((B,), jnp.float32)

    if n_batches == 0:
        return (r_ids, metrics._root(r_dist, p_col), n_p0, jnp.int32(0),
                ones, ones, zeros)

    dim0 = ones * (k * d)   # the first-k full-dimension rows
    f32_0 = ones * k

    def cond(s):
        i, _, _, done, _, _, _, _ = s
        return (i < n_batches) & ~jnp.all(done)

    def body(s):
        i, r_ids, r_dist, done, n_p, dim_scan, f32_rows, band_scan = s
        start = k + i * kappa
        batch = jax.lax.dynamic_slice(cand_ids, (0, start), (B, kappa))
        bbase = jax.lax.dynamic_slice(cand_base, (0, start), (B, kappa))
        thresh = jnp.where(done, -jnp.inf, r_dist[:, k - 1])
        # band 1: int8 screen — certified-kill candidates that provably
        # cannot beat the running k-th best (frozen rows kill everything
        # at entry, so neither band touches their memory)
        keep, nd8 = lp_gather_screen(
            Qp, batch, codes, scale, radius, thresh, bbase, p,
            base_p=base_p, interpret=interpret, block_d=block_d,
        )
        # band 2: f32 rows for the survivors only; screened-out slots
        # become padding (-1) and score +inf without a gather
        rb = jnp.where(keep, batch, -1)
        bd = lp_gather_distance(Q, rb, X, p, root=False,
                                interpret=interpret)
        # identical masked top-k merge as the §8 abandon path (screened
        # candidates are +inf, lowest-index tie rule == stable sort)
        all_d = jnp.concatenate([r_dist, bd], axis=1)
        all_i = jnp.concatenate([r_ids, batch], axis=1)
        neg, sel = jax.lax.top_k(-all_d, k)
        new_dist = -neg
        new_ids = jnp.take_along_axis(all_i, sel, axis=1)
        inter = (new_ids[:, :, None] == r_ids[:, None, :]).any(-1).sum(-1)
        ratio = inter.astype(jnp.float32) / k
        newly_done = ratio >= tau
        keep_row = done[:, None]
        r_ids = jnp.where(keep_row, r_ids, new_ids)
        r_dist = jnp.where(keep_row, r_dist, new_dist)
        n_p = n_p + jnp.where(done, 0, kappa)
        n_kept = keep.sum(axis=1).astype(jnp.float32)
        live = ~done
        dim_scan = dim_scan + jnp.where(live, n_kept * d, 0.0)
        f32_rows = f32_rows + jnp.where(live, n_kept, 0.0)
        band_scan = band_scan + jnp.where(
            live, nd8.sum(axis=1).astype(jnp.float32), 0.0)
        return (i + 1, r_ids, r_dist, done | newly_done, n_p,
                dim_scan, f32_rows, band_scan)

    state = (jnp.int32(0), r_ids, r_dist, jnp.zeros((B,), bool), n_p0,
             dim0, f32_0, zeros)
    (iters, r_ids, r_dist, done, n_p,
     dim_scan, f32_rows, band_scan) = jax.lax.while_loop(cond, body, state)
    n_p_f = n_p.astype(jnp.float32)
    return (r_ids, metrics._root(r_dist, p_col), n_p, iters,
            dim_scan / (n_p_f * d), f32_rows / n_p_f,
            band_scan / (n_p_f * d))


_verify_jit_s = functools.partial(
    jax.jit, static_argnames=("p", "k", "kappa", "tau", "interpret")
)(_verify_impl)
_verify_jit_v = functools.partial(
    jax.jit, static_argnames=("k", "kappa", "tau", "interpret")
)(_verify_impl)
_verify_abandon_jit_s = functools.partial(
    jax.jit,
    static_argnames=("p", "k", "kappa", "tau", "base_p", "interpret",
                     "block_d"),
)(_verify_abandon_impl)
_verify_abandon_jit_v = functools.partial(
    jax.jit,
    static_argnames=("k", "kappa", "tau", "base_p", "interpret", "block_d"),
)(_verify_abandon_impl)
_verify_two_band_jit_s = functools.partial(
    jax.jit,
    static_argnames=("p", "k", "kappa", "tau", "base_p", "interpret",
                     "block_d"),
)(_verify_two_band_impl)
_verify_two_band_jit_v = functools.partial(
    jax.jit,
    static_argnames=("k", "kappa", "tau", "base_p", "interpret", "block_d"),
)(_verify_two_band_impl)


def verify_candidates(
    Q: jax.Array,         # (B, d) f32
    cand_ids: jax.Array,  # (B, t) int32, sorted ascending by base distance
    X: jax.Array,         # (n, d) f32
    p,
    k: int,
    kappa: int,
    tau: float,
    interpret: bool | None = None,
    *,
    cand_base: jax.Array | None = None,
    base_p: float = 1.0,
    abandon: bool = True,
    block_d: int | None = None,
    band=None,
    x_scan: jax.Array | None = None,
    scan_perm: jax.Array | None = None,
):
    """Early-terminated exact-Lp re-ranking (Algorithm 1 lines 7-11).

    Returns (ids (B, k) int32, dists (B, k) f32 with root applied,
    n_p (B,) int32, iters () int32, n_dim_frac (B,) f32,
    n_f32_rows_frac (B,) f32, n_band_frac (B,) f32) — the last two are
    the SearchStats byte-traffic counters (1.0 / 0.0 off the two-band
    path).

    p follows the scalar-vs-vector contract (DESIGN.md §6): a Python float
    re-ranks the whole batch under one metric (one compiled program per p);
    a (B,) array re-ranks row i under p[i] in ONE compiled program, each
    row bit-identical to the scalar call at its p. In a mixed batch the
    convergence `while_loop` runs until *every* row terminates, but rows
    freeze their (ids, dists, n_p) the moment they individually converge,
    so per-row results and Eq. 1 `N_p` accounting are independent of batch
    composition.

    abandon=True (default) runs the early-abandoning blocked-dimension
    scan (DESIGN.md §8): the running k-th-best power sum abandons
    candidates that provably cannot enter the top-k, making `T_p` itself
    adaptive — `n_dim_frac` reports the scanned fraction. The returned
    top-k is exact either way; abandon=False runs the pre-abandonment
    full-dimension path bit-for-bit (and reports n_dim_frac = 1).
    `cand_base` (the beam's base-metric power sums, metric named by the
    static `base_p`) enables the zero-scan entry/suffix lower bounds;
    None disables them (threshold-only abandonment).

    band (a CompressedBand, index/compressed.py) switches abandon=True to
    the two-band scan (DESIGN.md §10): kappa batches are screened against
    the running k-th best using certified int8 lower bounds and only
    survivors gather f32 rows — ids and dists stay bitwise-identical to
    band=None. (x_scan, scan_perm) instead keep the full-f32 abandon scan
    but run it in energy coordinate order (UHNSWParams.energy_perm) —
    x_scan is the pre-permuted corpus view, scan_perm its permutation;
    mutually exclusive with `band` (the band is already energy-ordered).

    Candidate ids outside [0, n) are padding (sentinels from underfilled
    beams / merges) and are scored as inf so they can never enter R.
    `interpret` forwards to the kernel dispatch (None = backend-aware).
    """
    B = Q.shape[0]
    ones = jnp.ones((B,), jnp.float32)
    zeros = jnp.zeros((B,), jnp.float32)
    if abandon and band is not None:
        if cand_base is None:
            cand_base = jnp.zeros(cand_ids.shape, jnp.float32)
        Qp = jnp.take(Q, band.perm, axis=1)
        if metrics.is_static_p(p):
            return _verify_two_band_jit_s(
                Q, Qp, cand_ids, cand_base, X, band.codes, band.scale,
                band.radius, float(p), k, kappa, tau, float(base_p),
                interpret, block_d)
        return _verify_two_band_jit_v(
            Q, Qp, cand_ids, cand_base, X, band.codes, band.scale,
            band.radius, jnp.atleast_1d(jnp.asarray(p, jnp.float32)),
            k, kappa, tau, float(base_p), interpret, block_d)
    if abandon:
        if cand_base is None:
            cand_base = jnp.zeros(cand_ids.shape, jnp.float32)
        if metrics.is_static_p(p):
            out = _verify_abandon_jit_s(
                Q, cand_ids, cand_base, X, float(p), k, kappa, tau,
                float(base_p), interpret, block_d, x_scan, scan_perm)
        else:
            out = _verify_abandon_jit_v(
                Q, cand_ids, cand_base, X,
                jnp.atleast_1d(jnp.asarray(p, jnp.float32)),
                k, kappa, tau, float(base_p), interpret, block_d,
                x_scan, scan_perm)
        ids, dists, n_p, iters, frac = out
        return ids, dists, n_p, iters, frac, ones, zeros
    if metrics.is_static_p(p):
        out = _verify_jit_s(Q, cand_ids, X, float(p), k, kappa, tau,
                            interpret)
    else:
        out = _verify_jit_v(Q, cand_ids, X,
                            jnp.atleast_1d(jnp.asarray(p, jnp.float32)),
                            k, kappa, tau, interpret)
    ids, dists, n_p, iters = out
    return ids, dists, n_p, iters, ones, ones, zeros


def mask_base_rows(cand_ids, cand_dists, ids, dists, n_p, p_vec, base_p,
                   k: int, n_dim_frac=None, n_f32_frac=None,
                   n_band_frac=None):
    """Per-row base-metric skip (paper §3 preamble) inside a mixed batch.

    Rows whose p equals the base metric take the beam's own ordering —
    the exact values the scalar skip path produces — and report n_p = 0
    (and, when given, the scalar skip path's neutral stats: n_dim_frac
    and n_f32_frac 1.0, n_band_frac 0.0). Returns 3, 4, or 6 values
    depending on which optional frac counters were supplied (the 6-form
    requires all three).
    """
    pj = jnp.asarray(p_vec, dtype=jnp.float32)
    is_base = pj == base_p
    ids = jnp.where(is_base[:, None], cand_ids[:, :k], ids)
    dists = jnp.where(is_base[:, None],
                      metrics._root(cand_dists[:, :k], pj[:, None]),
                      dists)
    n_p = jnp.where(is_base, 0, n_p)
    if n_dim_frac is None:
        return ids, dists, n_p
    frac = jnp.where(is_base, 1.0, n_dim_frac)
    if n_f32_frac is None:
        return ids, dists, n_p, frac
    return (ids, dists, n_p, frac, jnp.where(is_base, 1.0, n_f32_frac),
            jnp.where(is_base, 0.0, n_band_frac))


def two_way_mixed_search(Q, p, k: int, cutoff: float, search_base_vec):
    """Shared mixed-p driver: two-way G1/G2 partition + scatter (DESIGN.md
    §6). Used by both UHNSW and ShardedUHNSW.

    search_base_vec(Q_sub (B', d), p_sub (B',) f32, k, base_p) must run one
    homogeneous-base sub-batch and return (ids, dists, n_p, iters, n_b,
    hops, n_dim_frac, n_f32_rows_frac, n_band_frac) — optionally followed
    by the four per-phase counters (n_b_probe, n_b_spill, n_p_probe,
    n_p_spill), which the sharded index appends (DESIGN.md §3); absent,
    the whole sub-batch counts as probe. A 14th element, the per-row
    poisoned flag from the NaN/inf guard (DESIGN.md §11), is likewise
    optional and defaults to all-clean.
    Returns (ids (B, k), dists (B, k), SearchStats) with per-row stats
    scattered back into request order; stats.base_p is the (B,) host-side
    base-metric array (the partition itself is host logic).

    Sub-batch results stay *device-resident*: each output is restored to
    request order by one concatenate + one gather on device at the end —
    no per-sub-batch `np.asarray` round trip, so a scheduled mixed bucket
    never forces an extra device->host synchronization per side.
    """
    Q = jnp.asarray(Q, dtype=jnp.float32)
    b = Q.shape[0]
    p_arr = np.asarray(p, dtype=np.float32).reshape(-1)
    if p_arr.size == 1:
        p_arr = np.full(b, p_arr[0], dtype=np.float32)
    assert p_arr.shape[0] == b, (p_arr.shape, b)
    base = np.asarray(metrics.base_metric_for(p_arr, cutoff))
    if b == 0:  # a drained bucket: well-formed empties, no device calls
        z = jnp.zeros((0, k))
        zi = jnp.zeros((0,), jnp.int32)
        zf = jnp.zeros((0,), jnp.float32)
        return z.astype(jnp.int32), z, SearchStats(
            n_b=zi, n_p=zi, iterations=jnp.int32(0), base_p=base, hops=zi,
            n_dim_frac=zf, n_f32_rows_frac=zf, n_band_frac=zf)
    sels, parts = [], []
    iters = jnp.int32(0)
    for base_p in (1.0, 2.0):
        sel = np.flatnonzero(base == base_p)
        if sel.size == 0:
            continue
        res = search_base_vec(Q[sel], p_arr[sel], k, base_p)
        (s_ids, s_dists, s_np, s_it, s_nb, s_hops, s_frac, s_f32,
         s_band) = res[:9]
        if len(res) > 9:
            nb_pr, nb_sp, np_pr, np_sp = res[9:13]
        else:  # phase-unaware index: everything is probe work
            nb_pr, nb_sp = s_nb, jnp.zeros_like(s_nb)
            np_pr, np_sp = s_np, jnp.zeros_like(s_np)
        # NaN/inf-guard flag (DESIGN.md §11); absent = all-clean
        s_pois = res[13] if len(res) > 13 else jnp.zeros_like(s_frac)
        sels.append(sel)
        parts.append((s_ids, s_dists, s_np, s_nb, s_hops, s_frac,
                      s_f32, s_band, nb_pr, nb_sp, np_pr, np_sp, s_pois))
        iters = jnp.maximum(iters, jnp.asarray(s_it, jnp.int32))
    if len(parts) == 1:  # homogeneous batch: already in request order
        (ids, dists, n_p, n_b, hops, frac, f32f, bandf,
         nb_pr, nb_sp, np_pr, np_sp, pois) = parts[0]
    else:
        order = np.concatenate(sels)
        inv = np.empty(b, np.int64)
        inv[order] = np.arange(b)
        inv = jnp.asarray(inv)
        (ids, dists, n_p, n_b, hops, frac, f32f, bandf,
         nb_pr, nb_sp, np_pr, np_sp, pois) = (
            jnp.concatenate(xs, axis=0)[inv] for xs in zip(*parts)
        )
    stats = SearchStats(
        n_b=n_b, n_p=n_p, iterations=iters, base_p=base, hops=hops,
        n_dim_frac=frac, n_b_probe=nb_pr, n_b_spill=nb_sp,
        n_p_probe=np_pr, n_p_spill=np_sp, n_f32_rows_frac=f32f,
        n_band_frac=bandf, poisoned=pois,
    )
    return ids, dists, stats


def modeled_query_cost(stats: SearchStats, p, d: int) -> dict:
    """T_query = N_b * T_b + N_p * (n_dim_frac * T_p) (paper Eq. 1, with
    the §8 adaptive-T_p correction) via the TPU op-cost model. p and
    stats.base_p may be scalars or (B,) arrays (mixed-p batch); array
    inputs report batch-mean per-distance costs. `n_dim_frac` (1.0 on
    full-dimension paths) scales the verification term down to the
    dimension-work the early-abandoning scan actually performed."""
    t_b = float(np.mean([metrics.lp_distance_cost_model(float(bp), d)
                         for bp in np.atleast_1d(stats.base_p)]))
    t_p = float(np.mean([metrics.lp_distance_cost_model(float(pp), d)
                         for pp in np.atleast_1d(np.asarray(p))]))
    n_b = float(jnp.mean(stats.n_b))
    n_p = float(jnp.mean(stats.n_p))
    # N_p-weighted per-row product, not mean(n_p)*mean(frac): rows that
    # skipped verification (n_p=0, frac=1) must not dilute the estimate —
    # the same weighting the serving stats use (dim_frac_w)
    n_p_row = np.asarray(stats.n_p, dtype=np.float64)
    frac_row = np.broadcast_to(np.asarray(stats.n_dim_frac,
                                          dtype=np.float64), n_p_row.shape)
    weighted = float(np.mean(n_p_row * frac_row))
    frac = weighted / n_p if n_p > 0 else 1.0
    return {"N_b": n_b, "N_p": n_p, "T_b": t_b, "T_p": t_p,
            "n_dim_frac": frac,
            "total": n_b * t_b + weighted * t_p}


class UHNSW:
    """The paper's index: two HNSW graphs (G1 under L1, G2 under L2).

    Public contract:
      * `search(Q, p, k)` — batched ANNS-U-Lp (Algorithm 1). Q: (B, d)
        f32; p: Python float (whole batch under one metric) or (B,) array
        (each row under its own metric — the mixed-p serving contract,
        DESIGN.md §6); k: result size. Returns (ids (B, k) int32, rooted
        dists (B, k) f32, SearchStats).
      * `base_graph_for(p)` — scalar-p base-graph pick; a mixed-p batch is
        instead *two-way partitioned* (G1 rows / G2 rows) inside `search`.
      * `build(...)` — construction: method="incremental" (sequential,
        paper-faithful) or method="bulk" (batched device-side shared-pass
        builder, DESIGN.md §7 — the benchmark-scale default elsewhere).

    Supported p range is the paper's universal family [0.5, 2].
    """

    def __init__(self, g1: HNSWGraph, g2: HNSWGraph, params: UHNSWParams | None = None):
        assert g1.metric_p == 1.0 and g2.metric_p == 2.0
        self.g1, self.g2 = g1, g2
        self.params = params or UHNSWParams()
        self.X = jnp.asarray(g1.data)
        self.arrays1 = GraphArrays.from_graph(g1)
        self.arrays2 = GraphArrays.from_graph(g2)
        # lazy verification-scan caches (DESIGN.md §10): the int8 band
        # for compressed_band, the energy-permuted corpus view for
        # energy_perm. Built on first verified query, deterministic from
        # X, so rebuilds (e.g. after snapshot recovery) are bit-stable.
        self._band = None
        self._scan_cache = None

    @property
    def dim(self) -> int:
        """Vector dimensionality served by this index."""
        return int(self.X.shape[1])

    def compressed_band(self):
        """The lazily-built int8 CompressedBand over self.X (§10)."""
        if self._band is None:
            from repro.index.compressed import build_band

            self._band = build_band(self.X)
        return self._band

    def _scan_view(self):
        """(x_scan, perm) energy-ordered corpus view for energy_perm."""
        if self._scan_cache is None:
            from repro.index.compressed import energy_order

            perm = jnp.asarray(energy_order(self.X))
            self._scan_cache = (jnp.take(self.X, perm, axis=1), perm)
        return self._scan_cache

    def _verify_extras(self) -> dict:
        """The band / scan-view kwargs `verify_candidates` needs under
        the current params (empty when both §10 features are off)."""
        prm = self.params
        if not prm.abandon:
            return {}
        if prm.compressed_band:
            return {"band": self.compressed_band()}
        if prm.energy_perm:
            x_scan, perm = self._scan_view()
            return {"x_scan": x_scan, "scan_perm": perm}
        return {}

    # -- construction -------------------------------------------------------

    @classmethod
    def build(
        cls,
        data: np.ndarray,
        m: int = 32,
        ef_construction: int = 500,
        seed: int = 0,
        params: UHNSWParams | None = None,
        progress_every: int = 0,
        method: str = "incremental",
    ) -> "UHNSW":
        """Construct both base graphs and wrap them in a UHNSW.

        method (DESIGN.md §7):
          * "incremental" — paper-faithful sequential insertion (the
            default; ef_construction applies).
          * "bulk" — batched device-side shared-pass construction
            (repro.core.bulk_build): G1 and G2 from ONE candidate-
            generation pass, ~an order of magnitude faster at segment
            scale; ef_construction is ignored (the bulk path has no
            insertion beam).
          * "bulk_host" — the vectorized NumPy per-graph bulk builder
            (build_hnsw_bulk); ef_construction is ignored.
        """
        if method == "bulk":
            from repro.core.bulk_build import build_bulk_pair

            g1, g2 = build_bulk_pair(data, m=m, seed=seed,
                                     progress_every=progress_every)
            return cls(g1, g2, params)
        if method == "bulk_host":
            from repro.core.build import build_hnsw_bulk

            g1 = build_hnsw_bulk(data, 1.0, m=m, seed=seed,
                                 progress_every=progress_every)
            g2 = build_hnsw_bulk(data, 2.0, m=m, seed=seed + 1,
                                 progress_every=progress_every)
            return cls(g1, g2, params)
        if method != "incremental":
            raise ValueError(
                f"unknown build method {method!r} "
                "(options: 'incremental', 'bulk', 'bulk_host')")
        g1 = build_hnsw(data, 1.0, m, ef_construction, seed, progress_every=progress_every)
        g2 = build_hnsw(data, 2.0, m, ef_construction, seed + 1, progress_every=progress_every)
        return cls(g1, g2, params)

    def index_size_bytes(self, p_range_max: float = 2.0) -> int:
        """Index size (excluding data). For the MLSH comparison (p <= 1) only
        G1 is used, matching the paper's §4.2 accounting."""
        if p_range_max <= 1.0:
            return self.g1.index_size_bytes()
        return self.g1.index_size_bytes() + self.g2.index_size_bytes()

    # -- query --------------------------------------------------------------

    def base_graph_for(self, p: float) -> tuple[GraphArrays, float]:
        """Scalar-p base-graph pick (paper Alg. 1 line 3): G1 iff p <= cutoff.

        Mixed-p batches never call this per request — `_search_mixed` does
        the two-way G1/G2 partition with `metrics.base_metric_for` on the
        whole p vector instead (DESIGN.md §6).
        """
        base = base_metric_for(p, self.params.cutoff)
        return (self.arrays1, 1.0) if base == 1.0 else (self.arrays2, 2.0)

    def search(self, Q, p, k: int):
        """Batched ANNS-U-Lp query (Algorithm 1).

        Q: (B, d) f32. p: Python float (whole batch, one metric) or (B,)
        array — the mixed-p form partitions the batch *two ways* by base
        graph (G1/G2, never one group per distinct p) and runs one vector-p
        program per side; each row's result is bit-identical to the scalar
        call at its p (DESIGN.md §6). Returns (ids (B, k) int32, rooted
        dists (B, k) f32, SearchStats with per-row n_b/n_p/hops).

        The serving scheduler (repro.retrieval.service) pre-partitions its
        buckets by base graph, so each scheduled call hits exactly one side
        here — fixed shapes, two compiled entry points total.
        """
        if metrics.is_static_p(p):
            return self._search_scalar(Q, float(p), k)
        return self._search_mixed(Q, p, k)

    def search_stage_candidates(self, Q, base_p: float,
                                k: int | None = None) -> CandidateSet:
        """Stage 1 of 2: base-metric candidate generation (Alg. 1 lines 1-6).

        Dispatches the batched beam search on the base graph named by
        `base_p` (1.0 = G1, 2.0 = G2) and returns the device-resident
        CandidateSet without forcing a host sync — the serving engine
        (DESIGN.md §6) overlaps this call for wave N+1 with wave N's
        verification. `search` composes exactly this stage with
        `search_stage_finish`, so staged execution is bitwise-identical
        to the fused call by construction.

        `k` is accepted for signature parity with ShardedUHNSW (which
        uses it to size the cross-segment pruning threshold); the
        monolithic index has a single beam and ignores it.
        """
        del k
        prm = self.params
        Q = jnp.asarray(Q, dtype=jnp.float32)
        arrays = self.arrays1 if base_p == 1.0 else self.arrays2
        # bulk-built graphs want a beam wider than t (they trade the
        # sequential builder's deep exploration for vectorized construction)
        ef = max(prm.ef or 2 * prm.t, prm.t)
        cand_ids, cand_dists, n_b, hops = knn_search(
            arrays, self.X, Q, ef=ef, t=prm.t, max_hops=prm.max_hops,
            # degenerate tiny beams can't host the full W; clamp, don't fail
            expand_width=min(prm.expand_width, ef),
        )
        return CandidateSet(ids=cand_ids, base_dists=cand_dists, n_b=n_b,
                            hops=hops, base_p=base_p)

    def search_stage_finish(self, Q, cands: CandidateSet, p, k: int):
        """Stage 2 of 2: verification (or the base-metric skip) over a
        CandidateSet from `search_stage_candidates`.

        p follows the scalar-vs-vector contract: a float equal to
        `cands.base_p` takes the exact skip path (the beam ordering is
        already exact); any other float runs scalar-p verification; a
        (B,) array runs the traced-p program with the per-row base-metric
        mask. Returns (ids, dists, SearchStats) — all device-resident.
        """
        prm = self.params
        Q = jnp.asarray(Q, dtype=jnp.float32)
        base_p = cands.base_p
        cand_ids, cand_dists = cands.ids, cands.base_dists
        n_b, hops = cands.n_b, cands.hops
        if metrics.is_static_p(p) and float(p) == base_p:
            # p equals the base metric: the graph's own ordering is exact
            ids = cand_ids[:, :k]
            dists = metrics._root(cand_dists[:, :k], float(p))
            return ids, dists, SearchStats(
                n_b=n_b, n_p=jnp.zeros_like(n_b), iterations=jnp.int32(0),
                base_p=base_p, hops=hops,
                n_dim_frac=jnp.ones(n_b.shape, jnp.float32),
                n_f32_rows_frac=jnp.ones(n_b.shape, jnp.float32),
                n_band_frac=jnp.zeros(n_b.shape, jnp.float32))
        kappa = prm.kappa or max(k // 2, 1)
        p_arg = float(p) if metrics.is_static_p(p) else p
        ids, dists, n_p, iters, frac, f32f, bandf = verify_candidates(
            Q, cand_ids, self.X, p_arg, k, kappa, prm.tau,
            interpret=prm.interpret, cand_base=cand_dists, base_p=base_p,
            abandon=prm.abandon, block_d=prm.abandon_block_d,
            **self._verify_extras(),
        )
        if not metrics.is_static_p(p):
            # per-row base-metric skip: base-p rows return the exact values
            # the scalar skip path produces
            ids, dists, n_p, frac, f32f, bandf = mask_base_rows(
                cand_ids, cand_dists, ids, dists, n_p, p, base_p, k,
                n_dim_frac=frac, n_f32_frac=f32f, n_band_frac=bandf)
        return ids, dists, SearchStats(n_b=n_b, n_p=n_p, iterations=iters,
                                       base_p=base_p, hops=hops,
                                       n_dim_frac=frac,
                                       n_f32_rows_frac=f32f,
                                       n_band_frac=bandf)

    def _search_scalar(self, Q, p: float, k: int):
        _, base_p = self.base_graph_for(p)
        cands = self.search_stage_candidates(Q, base_p)
        return self.search_stage_finish(Q, cands, p, k)

    def _search_base_vec(self, Q, p_vec, k: int, base_p: float):
        """One homogeneous-base sub-batch with per-row p (traced-p program),
        as the two stages composed back-to-back."""
        cands = self.search_stage_candidates(Q, base_p)
        ids, dists, st = self.search_stage_finish(Q, cands, p_vec, k)
        return (ids, dists, st.n_p, st.iterations, st.n_b, st.hops,
                st.n_dim_frac, st.n_f32_rows_frac, st.n_band_frac)

    def _search_mixed(self, Q, p, k: int):
        """Mixed-p batch: two-way G1/G2 partition + per-row-p programs."""
        return two_way_mixed_search(Q, p, k, self.params.cutoff,
                                    self._search_base_vec)

    # -- paper Eq. 1 cost model ---------------------------------------------

    def modeled_query_cost(self, stats: SearchStats, p, d: int) -> dict:
        """Paper Eq. 1 cost split — see the module-level helper."""
        return modeled_query_cost(stats, p, d)


def recall(pred_ids, true_ids) -> float:
    """Top-K recall |S* ∩ S| / K averaged over the query batch (paper §4.1.2).

    Negative ids are padding (exact_topk emits -1 when the corpus has fewer
    than k points; searches emit -1 past the end of real data) and are
    excluded from both sets; the denominator counts only real ground-truth
    entries, so recall stays in [0, 1] on degenerate corpora.

    Vectorized as one NumPy broadcast intersection (every benchmark and the
    CI bench-guard sit on this path; the old per-row Python set loop was
    O(B*k) host work). Counts each ground-truth id at most once per row —
    set semantics, relying on search/oracle rows holding distinct real ids
    (every search path emits unique ids per row by construction).
    """
    pred = np.asarray(pred_ids)
    true = np.asarray(true_ids)
    valid_t = true >= 0
    # (B, k_true, k_pred) membership; a true id counts as hit if it appears
    # anywhere in the row's predictions (padding masked on both sides)
    eq = (true[:, :, None] == pred[:, None, :]) & valid_t[:, :, None] \
        & (pred >= 0)[:, None, :]
    hits = int(eq.any(-1).sum())
    denom = int(valid_t.sum())
    return hits / max(denom, 1)
