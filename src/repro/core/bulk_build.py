"""Batched device-side bulk construction of the U-HNSW graph pair (DESIGN.md §7).

The paper's structural cost over plain HNSW is that U-HNSW builds *two* base
graphs (G1 under L1, G2 under L2). The faithful incremental builder
(repro.core.build) inserts one point at a time on the host — the only layer
of the stack that is still sequential. This module replaces it at scale with
the kNN-graph-seeded + prune recipe (NN-Descent family, cf. the graph-ANNS
survey in PAPERS.md), restructured as batched device passes:

  1. **Seed** — chunked pairwise-Lp scoring builds a kNN pool per metric
     (`kernels.ops` dispatch: Pallas kernels on TPU, jnp reference
     off-TPU). At or below EXACT_SEED_THRESHOLD the pass scores *every*
     column — exact kNN pools, with L1 and L2 reduced from one shared diff
     block; above it each node scores a random candidate block instead.
  2. **NN-Descent rounds** — a fixed number of refinement rounds for
     random-seeded (large) corpora. Each round samples forward+reverse
     neighbors-of-neighbors from the *union* of the L1 and L2 pools,
     scores the block under both metrics, and sort-merges it into each
     pool (exact distances + keep-best-K, so pool recall is non-decreasing
     per round).
  3. **Emit** — geometric level assignment, then per level: vectorized HNSW
     heuristic (Alg. 4) pruning, reverse-edge symmetrization, a second
     backfilled prune, kNN top-up to full degree, and host-side connectivity
     repair — emitting `GraphArrays` directly (no `HNSWGraph` intermediate).

The shared-pass trick (DESIGN.md §7): steps 1–2 gather the *same* candidate
id blocks for both metrics and evaluate two distances per block (one L1, one
L2 — the gathered rows and all id bookkeeping are shared), so G1 and G2 cost
one candidate-generation pass instead of two. This attacks the paper's 2x
build-cost overhead head-on; `benchmarks/build.py` tracks the resulting
speedup over the incremental builder.

When to prefer the incremental builder: tiny segments (below
`index.segment.BULK_THRESHOLD` the jit warm-up dominates), or when paper-
exact construction semantics are the point of the experiment.
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.build import _repair_connectivity
from repro.core.hnsw import GraphArrays

# Row-chunk byte budget for gathered (B, C, d) candidate blocks. Off-TPU the
# scoring path materializes the gathered block in host memory; on TPU the
# fused kernel streams it, but the same chunking bounds per-call latency.
_SCORE_BUDGET = 96 * 1024 * 1024
_POS_INF = np.int32(2**30)  # position/id sentinel for the sort tricks


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _row_chunk_for(c: int, d: int, n_rows: int) -> int:
    """Rows per scoring/pruning call so (chunk, C, d) f32 fits the budget."""
    chunk = max(32, _SCORE_BUDGET // max(4 * c * d, 1))
    return min(_round_up(min(chunk, n_rows), 8), _round_up(n_rows, 8))


# ---------------------------------------------------------------------------
# jitted primitives: top-k pool merge, order-preserving dedup, heuristic prune
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_topk(pool_ids, pool_d, cand_ids, cand_d, k: int):
    """Sort-merge candidate blocks into per-row best-k pools with dedup.

    ids are -1-padded; padded / duplicate slots score +inf and sort last.
    Returns (ids (B, k) int32 ascending by distance, d (B, k) f32).
    """
    ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
    d = jnp.concatenate([pool_d, cand_d], axis=1)
    valid = ids >= 0
    d = jnp.where(valid, d, jnp.inf)
    key = jnp.where(valid, ids, _POS_INF)
    # dedup: group equal ids together, keep each group's best distance
    sk, sd = jax.lax.sort((key, d), num_keys=2)
    first = jnp.concatenate(
        [jnp.ones((ids.shape[0], 1), bool), sk[:, 1:] != sk[:, :-1]], axis=1
    )
    sd = jnp.where(first, sd, jnp.inf)
    sd2, sk2 = jax.lax.sort((sd, sk), num_keys=1)
    out_ids = jnp.where(jnp.isfinite(sd2), sk2, -1).astype(jnp.int32)
    return out_ids[:, :k], sd2[:, :k]


@functools.partial(jax.jit, static_argnames=("k",))
def _dedup_keep_first(ids, k: int):
    """Per-row order-preserving dedup of -1-padded id lists, cut to k."""
    b, c = ids.shape
    pos = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None, :], (b, c))
    key = jnp.where(ids >= 0, ids, _POS_INF)
    sk, sp = jax.lax.sort((key, pos), num_keys=2)
    first = jnp.concatenate(
        [jnp.ones((b, 1), bool), sk[:, 1:] != sk[:, :-1]], axis=1
    )
    sp = jnp.where(first & (sk < _POS_INF), sp, _POS_INF)
    sp2, sk2 = jax.lax.sort((sp, sk), num_keys=1)
    out = jnp.where(sp2 < _POS_INF, sk2, -1).astype(jnp.int32)[:, :k]
    if out.shape[1] < k:  # narrow candidate lists (tiny level subsets)
        out = jnp.pad(out, ((0, 0), (0, k - out.shape[1])),
                      constant_values=-1)
    return out


@functools.partial(
    jax.jit, static_argnames=("m_max", "alpha", "backfill")
)
def _prune_chunk(x_sub, node_idx, cand_ids, m_max: int, alpha: float,
                 backfill: bool):
    """Vectorized HNSW heuristic selection (Alg. 4) over a row chunk.

    cand_ids rows must be sorted ascending by *base-metric* distance to the
    node (-1 padded, self excluded). The diversity-rule distances are
    evaluated in L2^2 (one batched matmul) regardless of the base metric —
    the *ordering*, which dominates edge quality, is exact base metric via
    the caller's sort (same convention as the host bulk builder and
    documented there). backfill=True tops short selections up with the
    nearest skipped candidates. Returns (B, m_max) ids, -1 padded, selected
    diversity edges first, both groups ascending by base distance.
    """
    b, c = cand_ids.shape
    valid = cand_ids >= 0
    safe = jnp.clip(cand_ids, 0, x_sub.shape[0] - 1)
    node_vec = x_sub[node_idx]                      # (B, d)
    cand_vec = x_sub[safe]                          # (B, C, d)
    sq = jnp.einsum("bcd,bcd->bc", cand_vec, cand_vec)
    nsq = jnp.einsum("bd,bd->b", node_vec, node_vec)
    d_u = jnp.maximum(
        nsq[:, None] + sq
        - 2.0 * jnp.einsum("bd,bcd->bc", node_vec, cand_vec), 0.0
    )
    d_u = jnp.where(valid, d_u, jnp.inf)
    pair = jnp.maximum(
        sq[:, :, None] + sq[:, None, :]
        - 2.0 * jnp.einsum("bid,bjd->bij", cand_vec, cand_vec), 0.0
    )

    def body(j, st):
        run_min, count, selected = st
        sel = valid[:, j] & (d_u[:, j] <= alpha * run_min[:, j]) \
            & (count < m_max)
        selected = selected.at[:, j].set(sel)
        run_min = jnp.where(sel[:, None],
                            jnp.minimum(run_min, pair[:, j, :]), run_min)
        return run_min, count + sel, selected

    st = (jnp.full((b, c), jnp.inf), jnp.zeros((b,), jnp.int32),
          jnp.zeros((b, c), bool))
    _, _, selected = jax.lax.fori_loop(0, c, body, st)

    pos = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None, :], (b, c))
    if backfill:
        key = jnp.where(selected, pos, jnp.where(valid, pos + c, _POS_INF))
    else:
        key = jnp.where(selected & valid, pos, _POS_INF)
    sk, sids = jax.lax.sort((key, cand_ids), num_keys=1)
    out = jnp.where(sk < _POS_INF, sids, -1).astype(jnp.int32)[:, :m_max]
    if out.shape[1] < m_max:  # narrow candidate lists (tiny level subsets)
        out = jnp.pad(out, ((0, 0), (0, m_max - out.shape[1])),
                      constant_values=-1)
    return out


# ---------------------------------------------------------------------------
# chunked scoring (the shared distance pass)
# ---------------------------------------------------------------------------


def _score_ids(x_dev, node_rows: np.ndarray, ids: np.ndarray, p: float,
               interpret) -> np.ndarray:
    """Exact base-metric distances node_rows[i] -> ids[i, :] (chunked).

    Routed through the exact-Lp dispatch entry point
    (kernels.ops.lp_gather_distance): fused Pallas gather kernel on TPU,
    jnp reference off-TPU. ids < 0 score +inf. Rows are padded to one
    uniform chunk shape so the whole pass compiles exactly one program.
    """
    from repro.kernels.ops import lp_gather_distance

    n_rows, c = ids.shape
    d = x_dev.shape[1]
    chunk = _row_chunk_for(c, d, n_rows)
    out = np.empty((n_rows, c), np.float32)
    ids_j = jnp.asarray(ids, dtype=jnp.int32)
    rows_j = jnp.asarray(node_rows, dtype=jnp.int32)
    for s in range(0, n_rows, chunk):
        e = min(s + chunk, n_rows)
        pad = chunk - (e - s)
        q = x_dev[rows_j[s:e]]
        blk = ids_j[s:e]
        if pad:
            q = jnp.concatenate([q, jnp.zeros((pad, d), q.dtype)])
            blk = jnp.concatenate(
                [blk, jnp.full((pad, c), -1, jnp.int32)])
        dd = lp_gather_distance(q, blk, x_dev, p, root=False,
                                interpret=interpret)
        out[s:e] = np.asarray(dd[: e - s])
    return out


def _prune_all(x_dev, n_rows: int, cand_ids: np.ndarray, m_max: int,
               alpha: float, backfill: bool) -> np.ndarray:
    """Chunked driver for `_prune_chunk` over every row of a level."""
    c = cand_ids.shape[1]
    d = x_dev.shape[1]
    # the (B, C, C) pair matrix joins the working set
    chunk = max(8, min(_row_chunk_for(c, d + c, n_rows),
                       _row_chunk_for(c, d, n_rows)))
    out = np.empty((n_rows, m_max), np.int32)
    ids_j = jnp.asarray(cand_ids, dtype=jnp.int32)
    for s in range(0, n_rows, chunk):
        e = min(s + chunk, n_rows)
        pad = chunk - (e - s)
        rows = jnp.arange(s, e, dtype=jnp.int32)
        blk = ids_j[s:e]
        if pad:
            rows = jnp.concatenate([rows, jnp.zeros((pad,), jnp.int32)])
            blk = jnp.concatenate(
                [blk, jnp.full((pad, c), -1, jnp.int32)])
        sel = _prune_chunk(x_dev, rows, blk, m_max, float(alpha), backfill)
        out[s:e] = np.asarray(sel[: e - s])
    return out


# ---------------------------------------------------------------------------
# NN-Descent pools (shared candidate blocks, one distance eval per metric)
# ---------------------------------------------------------------------------


# Below this corpus size the seed phase scores ALL columns (exact kNN via
# chunked pairwise-Lp) instead of a random sample: at segment scale the
# full pass costs about the same as the 3 sampled NN-Descent rounds it
# replaces and leaves nothing for them to refine. Above it, random seeding
# + NN-Descent keeps the build subquadratic.
EXACT_SEED_THRESHOLD = 4096


def _exact_seed_pools(data, metric_ps, k: int, interpret,
                      pool_factor: int = 8):
    """Near-exact per-metric kNN pools via one chunked pairwise scan.

    The shared-pass core at segment scale (DESIGN.md §7): ONE full
    pairwise scan under L2 — the only base metric with a matmul-friendly
    (MXU / GEMM) form — ranks a `pool_factor * k`-wide candidate pool per
    node; every other metric then scores only that shared id block exactly
    (a narrow gather pass) and keeps its own top-k. L2 pools are exact;
    Lp pools are exact within the pool (the host bulk builder uses the
    same prefilter, with the same justification: the generous pool makes
    the re-ranked edges coincide with exact kNN edges in practice).
    """
    from repro.kernels.ops import lp_pairwise_distance

    n, d = data.shape
    x_dev = jnp.asarray(data)
    rows = np.arange(n, dtype=np.int32)
    need_pool = any(p != 2.0 for p in metric_ps)
    width = min(max(pool_factor * k, k) if need_pool else k, n - 1)
    # the (chunk, n) L2 block never materializes a diff tensor; budget on
    # the output tile
    chunk = min(_round_up(max(64, _SCORE_BUDGET // (8 * n)), 8),
                _round_up(n, 8))
    ids2 = np.empty((n, width), np.int32)
    d2 = np.empty((n, width), np.float32)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        # pad the tail to the uniform chunk shape (one compiled program
        # for the whole pass — same pattern as _score_ids); padded rows
        # mask their self-distance at column 0 and are sliced off below
        q = x_dev[s:e]
        if e - s < chunk:
            q = jnp.concatenate(
                [q, jnp.zeros((chunk - (e - s), q.shape[1]), q.dtype)])
        dd = lp_pairwise_distance(q, x_dev, 2.0, root=False,
                                  interpret=interpret)
        diag = jnp.where(jnp.arange(chunk) < e - s,
                         jnp.arange(chunk) + s, 0)
        dd = dd.at[jnp.arange(chunk), diag].set(jnp.inf)
        neg, idx = jax.lax.top_k(-dd, width)
        ids2[s:e] = np.asarray(idx, dtype=np.int32)[: e - s]
        d2[s:e] = np.asarray(-neg)[: e - s]
    pools = {}
    for p in metric_ps:
        if p == 2.0:
            pools[p] = (ids2[:, :k].copy(), d2[:, :k].copy())
            continue
        # exact-p scoring of the shared candidate block (chunked gather)
        dp = _score_ids(x_dev, rows, ids2, p, interpret)
        m_ids, m_d = _merge_topk(
            jnp.full((n, 1), -1, jnp.int32), jnp.full((n, 1), jnp.inf),
            jnp.asarray(ids2), jnp.asarray(dp), k,
        )
        pools[p] = (np.asarray(m_ids), np.asarray(m_d))
    return pools


def nn_descent_pools(
    data: np.ndarray,
    metric_ps: tuple[float, ...] = (1.0, 2.0),
    k: int = 64,
    rounds: int = 3,
    sample_t: int = 8,
    cand_cap: int | None = None,
    seed: int = 0,
    interpret=None,
    trajectory: bool = False,
    exact_seed_threshold: int = EXACT_SEED_THRESHOLD,
):
    """Build per-metric kNN candidate pools in one shared pass.

    Returns {p: (ids (n, k) int32 ascending, d (n, k) f32)}. For corpora
    at or below `exact_seed_threshold` the seed scoring pass covers every
    column — the pools are exact kNN and the refinement rounds are skipped
    (they cannot improve an exact pool). Above it, every node seeds from a
    random candidate block and `rounds` NN-Descent iterations refine it.
    With trajectory=True additionally returns a list of per-stage pool
    snapshots (seed, then one per round) for the round-monotonicity test —
    merges use exact distances and keep-best-k, so pool recall cannot
    decrease.
    """
    data = np.ascontiguousarray(data, dtype=np.float32)
    n, d = data.shape
    assert n >= 2, "need at least two points to build a graph"
    k = min(k, n - 1)
    cand_cap = cand_cap or max(3 * k, 128)
    rng = np.random.default_rng(seed)
    x_dev = jnp.asarray(data)
    own = np.arange(n, dtype=np.int32)[:, None]

    if n <= exact_seed_threshold:
        pools = _exact_seed_pools(data, metric_ps, k, interpret)
        if trajectory:
            return pools, [{p: pools[p][0].copy() for p in metric_ps}]
        return pools

    def score_and_merge(pools, cand):
        """The shared pass: one id block, one distance eval per metric."""
        cand = np.where(cand == own, -1, cand)  # no self-loops
        for p in metric_ps:
            dd = _score_ids(x_dev, own[:, 0], cand, p, interpret)
            ids_p, d_p = pools[p]
            pools[p] = _merge_topk(
                jnp.asarray(ids_p), jnp.asarray(d_p),
                jnp.asarray(cand, dtype=jnp.int32), jnp.asarray(dd), k
            )
            pools[p] = (np.asarray(pools[p][0]), np.asarray(pools[p][1]))
        return pools

    # 1. seed: a random candidate block per node (uniform, self excluded)
    seed_cand = rng.integers(0, n - 1, size=(n, max(k, 8)), dtype=np.int64)
    seed_cand = (seed_cand + (seed_cand >= own)).astype(np.int32)
    empty_ids = np.full((n, k), -1, np.int32)
    empty_d = np.full((n, k), np.inf, np.float32)
    pools = {p: (empty_ids, empty_d) for p in metric_ps}
    pools = score_and_merge(pools, seed_cand)
    snaps = [{p: pools[p][0].copy() for p in metric_ps}] if trajectory else []

    # 2. NN-Descent rounds over the joint pool. The local join samples
    # from forward AND reverse neighbors (the reverse join is what makes
    # NN-Descent converge on clustered data: a node's neighbors must learn
    # about *it*, not only about each other).
    for _ in range(rounds):
        join = np.concatenate([pools[p][0] for p in metric_ps], axis=1)
        width = join.shape[1]
        rev = _reverse_edges(join, n, width)
        base = np.concatenate([join, rev], axis=1)         # (n, 2*width)
        t = min(sample_t, base.shape[1])
        # sample T in/out neighbors per node, take their whole join sets
        sel = rng.integers(0, base.shape[1], size=(n, t))
        mid = np.take_along_axis(base, sel, axis=1)        # (n, T)
        mid = np.where(mid < 0, own[:, 0][:, None], mid)   # pad -> self
        nn2 = base[mid].reshape(n, t * base.shape[1])
        if nn2.shape[1] > cand_cap:
            sub = rng.integers(0, nn2.shape[1], size=(n, cand_cap))
            nn2 = np.take_along_axis(nn2, sub, axis=1)
        # the node's own join set rides along: reverse edges join the
        # pools directly, and each metric's merge sees the other metric's
        # current neighbors (cross-metric exchange), not only through the
        # sampled second hop
        cand = np.concatenate([base, nn2], axis=1)
        pools = score_and_merge(pools, cand)
        if trajectory:
            snaps.append({p: pools[p][0].copy() for p in metric_ps})

    if trajectory:
        return pools, snaps
    return pools


# ---------------------------------------------------------------------------
# level emission
# ---------------------------------------------------------------------------


def _assign_levels(n: int, m: int, seed: int) -> tuple[np.ndarray, int]:
    """Geometric level assignment (same law as the incremental builder)."""
    rng = np.random.default_rng(seed)
    ml = 1.0 / math.log(m)
    levels = np.minimum(
        (-np.log(np.maximum(rng.random(n), 1e-12)) * ml).astype(np.int32), 30
    )
    return levels, int(np.argmax(levels))


def _exact_knn_local(sub: np.ndarray, p: float, kk: int,
                     interpret=None) -> np.ndarray:
    """Exact base-metric kNN ids within a (small) level subset, chunked."""
    from repro.kernels.ops import lp_pairwise_distance

    nl, d = sub.shape
    sub_dev = jnp.asarray(sub)
    chunk = _row_chunk_for(nl, d, nl)
    out = np.empty((nl, kk), np.int32)
    for s in range(0, nl, chunk):
        e = min(s + chunk, nl)
        # tail padded to the uniform chunk shape (see _exact_seed_pools)
        q = sub_dev[s:e]
        if e - s < chunk:
            q = jnp.concatenate(
                [q, jnp.zeros((chunk - (e - s), d), q.dtype)])
        dd = lp_pairwise_distance(q, sub_dev, p, root=False,
                                  interpret=interpret)
        diag = jnp.where(jnp.arange(chunk) < e - s,
                         jnp.arange(chunk) + s, 0)
        dd = dd.at[jnp.arange(chunk), diag].set(jnp.inf)
        _, idx = jax.lax.top_k(-dd, kk)
        out[s:e] = np.asarray(idx, dtype=np.int32)[: e - s]
    return out


def _reverse_edges(sel: np.ndarray, nl: int, r_max: int) -> np.ndarray:
    """Capped reverse-adjacency (nl, r_max) of a -1-padded forward list.

    Fully vectorized (no per-node Python loop): group edges by target via a
    stable argsort, rank within each group with a cumulative-count trick,
    keep the first r_max per target.
    """
    m_max = sel.shape[1]
    src = np.repeat(np.arange(nl, dtype=np.int32), m_max)
    dst = sel.reshape(-1)
    keep = dst >= 0
    src, dst = src[keep], dst[keep]
    order = np.argsort(dst, kind="stable")
    dst_s, src_s = dst[order], src[order]
    counts = np.bincount(dst_s, minlength=nl)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(len(dst_s)) - np.repeat(starts, counts)
    rev = np.full((nl, r_max), -1, np.int32)
    sel_rows = pos < r_max
    rev[dst_s[sel_rows], pos[sel_rows]] = src_s[sel_rows]
    return rev


def _build_level(
    sub: np.ndarray, x_dev, cand_ids: np.ndarray, p: float, m_max: int,
    alpha: float, entry_local: int, interpret,
) -> np.ndarray:
    """One level's adjacency from sorted candidate pools (local ids).

    Phase 1: diversity prune (spread edges, no backfill). Phase 2:
    symmetrize + re-sort by exact base metric + backfilled prune (keeps the
    spread edges reverse edges would otherwise evict), then top up to full
    degree from the kNN pool and repair connectivity (host BFS) — the
    navigability property sequential insertion gets for free.
    """
    nl = len(sub)
    rows = np.arange(nl, dtype=np.int32)
    sel = _prune_all(x_dev, nl, cand_ids, m_max, alpha, backfill=False)
    # reverse cap of 2*m_max approximates the host builder's unbounded
    # symmetrize: hub nodes in clustered data collect well over m_max
    # reverse edges, and the phase-2 prune needs to see them to keep the
    # right ones
    rev = _reverse_edges(sel, nl, 2 * m_max)
    merged = np.concatenate([sel, rev], axis=1)
    merged = np.where(merged == rows[:, None], -1, merged)
    merged = np.asarray(_dedup_keep_first(jnp.asarray(merged),
                                          merged.shape[1]))
    # exact base-metric ordering for the phase-2 prune
    dd = _score_ids(x_dev, rows, merged, p, interpret)
    sd, si = jax.lax.sort(
        (jnp.asarray(dd), jnp.asarray(merged, dtype=jnp.int32)), num_keys=1
    )
    merged = np.where(np.isfinite(np.asarray(sd)), np.asarray(si), -1)
    pruned = _prune_all(x_dev, nl, merged.astype(np.int32), m_max, alpha,
                        backfill=True)
    # np.array (copy): the repair pass mutates rows in place, and
    # np.asarray over a device buffer yields a read-only view
    topped = np.array(_dedup_keep_first(
        jnp.asarray(np.concatenate([pruned, cand_ids], axis=1),
                    dtype=jnp.int32), m_max
    ))
    return _repair_connectivity(topped, rows, sub, p, entry_local)


def _emit_arrays(
    data: np.ndarray, pool_ids: np.ndarray, p: float, m: int,
    levels: np.ndarray, entry: int, alpha: float, interpret,
) -> GraphArrays:
    """Assemble the full GraphArrays hierarchy for one metric."""
    n = len(data)
    m0 = 2 * m
    x_dev = jnp.asarray(data)
    max_level = int(levels.max())

    adj0 = None
    upper_adj, upper_g2l = [], []
    for l in range(max_level + 1):
        nodes = np.nonzero(levels >= l)[0].astype(np.int32)
        m_max = m0 if l == 0 else m
        if l == 0:
            mat = _build_level(data, x_dev, pool_ids, p, m_max, alpha,
                               int(entry), interpret)
            adj0 = np.where(mat >= 0, mat, n).astype(np.int32)
            continue
        sub = data[nodes]
        sub_dev = jnp.asarray(sub)
        entry_local = int(np.nonzero(nodes == entry)[0][0])
        if len(nodes) <= 1:
            mat = np.full((len(nodes), m_max), -1, np.int32)
        else:
            kk = min(2 * m_max, len(nodes) - 1)
            cand = _exact_knn_local(sub, p, kk, interpret=interpret)
            mat = _build_level(sub, sub_dev, cand, p, m_max, alpha,
                               entry_local, interpret)
        gmat = np.where(mat >= 0, nodes[np.clip(mat, 0, None)], n)
        g2l = np.full(n, -1, np.int32)
        g2l[nodes] = np.arange(len(nodes), dtype=np.int32)
        upper_adj.append(jnp.asarray(gmat.astype(np.int32)))
        upper_g2l.append(jnp.asarray(g2l))

    return GraphArrays(
        adj0=jnp.asarray(adj0),
        upper_adj=tuple(upper_adj),
        upper_g2l=tuple(upper_g2l),
        entry=jnp.asarray(entry, dtype=jnp.int32),
        n=n,
        metric_p=p,
    )


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------


@dataclass
class DeviceGraph:
    """A bulk-built frozen graph: device `GraphArrays` + host metadata.

    Drop-in for `HNSWGraph` at every consumer surface (UHNSW, SegmentedGraphs,
    benchmarks): exposes metric_p/m/m0/data/levels/entry_point and
    `graph_arrays()` (which `GraphArrays.from_graph` prefers over re-packing
    host adjacency). The topology lives only in the GraphArrays — there is
    no host adjacency intermediate; `adjacency_host` derives one on demand
    for tests and tools.
    """

    metric_p: float
    m: int
    m0: int
    entry_point: int
    max_level: int
    levels: np.ndarray
    data: np.ndarray
    arrays: GraphArrays

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def d(self) -> int:
        return self.data.shape[1]

    def graph_arrays(self) -> GraphArrays:
        return self.arrays

    def adjacency_host(self, level: int) -> np.ndarray:
        """-1-padded host adjacency view of one level (tests/tools only)."""
        a = self.arrays.adj0 if level == 0 else self.arrays.upper_adj[level - 1]
        a = np.asarray(a)
        return np.where(a == self.n, -1, a).astype(np.int32)

    def index_size_bytes(self) -> int:
        """Index size excluding the dataset (HNSWGraph-compatible metric)."""
        total = np.asarray(self.arrays.adj0).nbytes
        for a in self.arrays.upper_adj:
            total += np.asarray(a).nbytes
        for a in self.arrays.upper_g2l:
            total += np.asarray(a).nbytes
        return total


def build_bulk_pair(
    data: np.ndarray,
    m: int = 32,
    *,
    k_pool: int | None = None,
    rounds: int = 3,
    sample_t: int = 8,
    cand_cap: int | None = None,
    alpha: float = 1.2,
    seed: int = 0,
    interpret=None,
    progress_every: int = 0,
    exact_seed_threshold: int = EXACT_SEED_THRESHOLD,
) -> tuple[DeviceGraph, DeviceGraph]:
    """Build the U-HNSW pair (G1 under L1, G2 under L2) in one shared pass.

    The NN-Descent candidate blocks are generated once and scored under both
    metrics (two distance evaluations per block — DESIGN.md §7); level
    assignment is shared, so the two graphs differ only in their edge sets.
    Returns (g1, g2) as `DeviceGraph`s ready for `UHNSW(g1, g2)`.
    """
    data = np.ascontiguousarray(data, dtype=np.float32)
    n = len(data)
    m0 = 2 * m
    # pool floor of 64: at small m a 2*m0-wide pool is too narrow for the
    # heuristic prune to find diverse edges on clustered data (measured on
    # the SIFT-like corpus: m=8 with a 32-wide pool loses ~12 recall pts)
    k_pool = k_pool or min(max(2 * m0, 64), max(n - 1, 1))
    pools = nn_descent_pools(
        data, (1.0, 2.0), k=k_pool, rounds=rounds, sample_t=sample_t,
        cand_cap=cand_cap, seed=seed, interpret=interpret,
        exact_seed_threshold=exact_seed_threshold,
    )
    levels, entry = _assign_levels(n, m, seed)
    graphs = []
    for p in (1.0, 2.0):
        if progress_every:
            print(f"  bulk pair: emitting G{int(p)} (p={p})", flush=True)
        arrays = _emit_arrays(data, pools[p][0], p, m, levels, entry, alpha,
                              interpret)
        graphs.append(DeviceGraph(
            metric_p=p, m=m, m0=m0, entry_point=entry,
            max_level=int(levels.max()), levels=levels, data=data,
            arrays=arrays,
        ))
    return graphs[0], graphs[1]


def build_bulk(
    data: np.ndarray,
    metric_p: float = 2.0,
    m: int = 32,
    *,
    k_pool: int | None = None,
    rounds: int = 3,
    sample_t: int = 8,
    cand_cap: int | None = None,
    alpha: float = 1.2,
    seed: int = 0,
    interpret=None,
    exact_seed_threshold: int = EXACT_SEED_THRESHOLD,
) -> DeviceGraph:
    """Single-metric bulk build (same pipeline, one pool).

    For a base metric other than 2.0 the seed pass still prefilters with
    the L2 scan and re-ranks the shared pool under `metric_p` exactly
    (see `_exact_seed_pools`).
    """
    data = np.ascontiguousarray(data, dtype=np.float32)
    n = len(data)
    m0 = 2 * m
    k_pool = k_pool or min(max(2 * m0, 64), max(n - 1, 1))
    pools = nn_descent_pools(
        data, (float(metric_p),), k=k_pool, rounds=rounds,
        sample_t=sample_t, cand_cap=cand_cap, seed=seed,
        interpret=interpret, exact_seed_threshold=exact_seed_threshold,
    )
    levels, entry = _assign_levels(n, m, seed)
    arrays = _emit_arrays(data, pools[float(metric_p)][0], float(metric_p),
                          m, levels, entry, alpha, interpret)
    return DeviceGraph(
        metric_p=float(metric_p), m=m, m0=m0, entry_point=entry,
        max_level=int(levels.max()), levels=levels, data=data, arrays=arrays,
    )
