"""HNSW graph construction (paper §2.2).

Faithful incremental HNSW (Malkov & Yashunin 2020) under an arbitrary base
metric Lp. Construction is a host-side (NumPy) procedure — it is offline,
sequential by nature (points insert one at a time), and the paper builds its
two base graphs G1 (L1) and G2 (L2) once. The *query* path, which is the
paper's performance subject, lives in repro.core.hnsw as batched JAX.

The builder vectorizes every distance evaluation over whole neighbor/frontier
blocks so it stays NumPy-bound rather than Python-bound.

Graph layout (frozen, accelerator-friendly):
  adjacency[0]   : (n, m0) int32, level-0 neighbor lists, padded with -1
  adjacency[l>0] : (n_l, m) int32 *global* ids for nodes with level >= l
  level_nodes[l] : (n_l,) global ids present at level l
  local_index[l] : (n,) global->local map at level l (-1 when absent)
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np


def _np_lp(q: np.ndarray, x: np.ndarray, p: float) -> np.ndarray:
    """Vectorized |q - x_i|_p^p over rows of x (no root: ordering-equivalent)."""
    d = np.abs(x - q)
    if p == 2.0:
        return np.einsum("nd,nd->n", d, d)
    if p == 1.0:
        return d.sum(axis=1)
    if p == 0.5:
        return np.sqrt(d).sum(axis=1)
    if p == 1.5:
        return (d * np.sqrt(d)).sum(axis=1)
    return (d**p).sum(axis=1)


@dataclass
class HNSWGraph:
    """A frozen HNSW index over `data` built under base metric L`metric_p`."""

    metric_p: float
    m: int
    m0: int
    ef_construction: int
    entry_point: int
    max_level: int
    adjacency: list[np.ndarray]
    level_nodes: list[np.ndarray]
    local_index: list[np.ndarray]
    data: np.ndarray
    levels: np.ndarray = field(default=None)  # (n,) per-node top level

    @property
    def n(self) -> int:
        return self.data.shape[0]

    @property
    def d(self) -> int:
        return self.data.shape[1]

    def index_size_bytes(self) -> int:
        """Index size excluding the dataset itself (paper's index-size metric)."""
        total = 0
        for a in self.adjacency:
            total += a.nbytes
        for a in self.level_nodes:
            total += a.nbytes
        for a in self.local_index:
            total += a.nbytes
        return total


class _Builder:
    def __init__(self, data: np.ndarray, p: float, m: int, ef_construction: int,
                 seed: int, extend_candidates: bool):
        self.data = np.ascontiguousarray(data, dtype=np.float32)
        self.n, self.dim = self.data.shape
        self.p = p
        self.m = m
        self.m0 = 2 * m
        self.efc = ef_construction
        self.ml = 1.0 / math.log(m)
        self.rng = np.random.default_rng(seed)
        self.extend_candidates = extend_candidates

        self.levels = np.zeros(self.n, dtype=np.int32)
        # neighbors[l][i] is a Python list during build; frozen at the end.
        self.neighbors: list[dict[int, list[int]]] = [dict()]
        self.entry = -1
        self.max_level = -1

    # -- primitives ---------------------------------------------------------

    def _dist_many(self, q: np.ndarray, ids: np.ndarray) -> np.ndarray:
        return _np_lp(q, self.data[ids], self.p)

    def _search_layer(self, q: np.ndarray, eps: list[int], ef: int, level: int):
        """Classic ef-search on one layer; returns [(dist, id)] sorted asc."""
        adj = self.neighbors[level]
        visited = set(eps)
        dists = self._dist_many(q, np.array(eps, dtype=np.int64))
        cand = [(float(d), e) for d, e in zip(dists, eps)]  # min-heap
        heapq.heapify(cand)
        result = [(-float(d), e) for d, e in zip(dists, eps)]  # max-heap (neg)
        heapq.heapify(result)
        while len(result) > ef:
            heapq.heappop(result)
        while cand:
            d_c, c = heapq.heappop(cand)
            worst = -result[0][0]
            if d_c > worst and len(result) >= ef:
                break
            nbrs = [u for u in adj.get(c, ()) if u not in visited]
            if not nbrs:
                continue
            visited.update(nbrs)
            nd = self._dist_many(q, np.array(nbrs, dtype=np.int64))
            worst = -result[0][0]
            for dist, u in zip(nd, nbrs):
                dist = float(dist)
                if len(result) < ef or dist < worst:
                    heapq.heappush(cand, (dist, u))
                    heapq.heappush(result, (-dist, u))
                    if len(result) > ef:
                        heapq.heappop(result)
                    worst = -result[0][0]
        out = sorted((-nd, u) for nd, u in result)
        return out

    def _select_neighbors(self, q: np.ndarray, cands: list[tuple[float, int]],
                          m: int) -> list[int]:
        """HNSW heuristic neighbor selection (Alg. 4 of the HNSW paper)."""
        if len(cands) <= m:
            return [u for _, u in cands]
        selected: list[int] = []
        sel_vecs: list[np.ndarray] = []
        for d_q, u in cands:  # cands sorted ascending by distance to q
            if len(selected) >= m:
                break
            uv = self.data[u]
            if sel_vecs:
                d_sel = _np_lp(uv, np.stack(sel_vecs), self.p)
                if (d_sel < d_q).any():
                    continue  # u is closer to an already-selected point
            selected.append(u)
            sel_vecs.append(uv)
        if len(selected) < m:  # backfill with nearest skipped candidates
            skipped = [u for _, u in cands if u not in set(selected)]
            selected.extend(skipped[: m - len(selected)])
        return selected

    def _prune(self, u: int, level: int):
        """Re-select u's neighbor list if it overflowed m_level."""
        m_max = self.m0 if level == 0 else self.m
        adj = self.neighbors[level]
        lst = adj[u]
        if len(lst) <= m_max:
            return
        uv = self.data[u]
        arr = np.array(lst, dtype=np.int64)
        d = _np_lp(uv, self.data[arr], self.p)
        order = np.argsort(d, kind="stable")
        cands = [(float(d[i]), int(arr[i])) for i in order]
        adj[u] = self._select_neighbors(uv, cands, m_max)

    # -- insertion ----------------------------------------------------------

    def insert(self, idx: int):
        q = self.data[idx]
        level = int(-math.log(max(self.rng.random(), 1e-12)) * self.ml)
        self.levels[idx] = level
        while len(self.neighbors) <= level:
            self.neighbors.append(dict())
        for l in range(level + 1):
            self.neighbors[l][idx] = []

        if self.entry < 0:
            self.entry = idx
            self.max_level = level
            return

        ep = [self.entry]
        # zoom down through layers above the insertion level (greedy, ef=1)
        for l in range(self.max_level, level, -1):
            ep = [u for _, u in self._search_layer(q, ep, 1, l)[:1]]
        # insert at each layer from min(level, max_level) down to 0
        for l in range(min(level, self.max_level), -1, -1):
            w = self._search_layer(q, ep, self.efc, l)
            m_max = self.m0 if l == 0 else self.m
            nbrs = self._select_neighbors(q, w, m_max)
            adj = self.neighbors[l]
            adj[idx] = list(nbrs)
            for u in nbrs:
                adj[u].append(idx)
                self._prune(u, l)
            ep = [u for _, u in w]
        if level > self.max_level:
            self.max_level = level
            self.entry = idx

    # -- freeze ---------------------------------------------------------------

    def freeze(self) -> HNSWGraph:
        adjacency, level_nodes, local_index = [], [], []
        for l, adj in enumerate(self.neighbors):
            m_max = self.m0 if l == 0 else self.m
            if l == 0:
                nodes = np.arange(self.n, dtype=np.int32)
            else:
                nodes = np.array(sorted(adj.keys()), dtype=np.int32)
            mat = np.full((len(nodes), m_max), -1, dtype=np.int32)
            for row, u in enumerate(nodes):
                lst = adj.get(int(u), [])[:m_max]
                mat[row, : len(lst)] = lst
            g2l = np.full(self.n, -1, dtype=np.int32)
            g2l[nodes] = np.arange(len(nodes), dtype=np.int32)
            adjacency.append(mat)
            level_nodes.append(nodes)
            local_index.append(g2l)
        return HNSWGraph(
            metric_p=self.p,
            m=self.m,
            m0=self.m0,
            ef_construction=self.efc,
            entry_point=self.entry,
            max_level=self.max_level,
            adjacency=adjacency,
            level_nodes=level_nodes,
            local_index=local_index,
            data=self.data,
            levels=self.levels,
        )


def build_hnsw(
    data: np.ndarray,
    metric_p: float = 2.0,
    m: int = 32,
    ef_construction: int = 500,
    seed: int = 0,
    extend_candidates: bool = False,
    progress_every: int = 0,
) -> HNSWGraph:
    """Build an HNSW index over `data` under base metric L`metric_p`.

    Defaults match the paper's G1/G2 settings (M=32, efConstruction=500).
    This is the faithful sequential builder; `build_hnsw_bulk` below is the
    vectorized fast path used at benchmark scale.
    """
    b = _Builder(data, metric_p, m, ef_construction, seed, extend_candidates)
    for i in range(b.n):
        b.insert(i)
        if progress_every and (i + 1) % progress_every == 0:
            print(f"  hnsw build p={metric_p}: {i + 1}/{b.n}")
    return b.freeze()


# ---------------------------------------------------------------------------
# Bulk builder: vectorized two-phase construction
# ---------------------------------------------------------------------------
#
# The sequential insert loop above is faithful to Malkov & Yashunin but is
# Python-bound (~30 ms/point on this container). For benchmark-scale corpora
# we use the standard accelerator-ANN bulk recipe (as in NSG/Vamana-style
# builders): exact kNN candidate pools + vectorized relative-neighborhood
# (heuristic) pruning, applied per HNSW level. Query semantics and the
# frozen-graph layout are identical; tests assert the bulk graph reaches at
# least the sequential graph's search recall.
#
# For non-L2 base metrics, phase 1 prefilters candidates with the (MXU-
# friendly) L2 metric over a generous pool, then re-ranks the pool under the
# exact base metric. The pool is large enough (default 8x the neighbor list)
# that the final edges coincide with exact base-metric kNN edges in practice.


def _chunked_l2_topk(data: np.ndarray, nodes: np.ndarray, pool: int,
                     chunk: int = 512) -> np.ndarray:
    """Exact L2 top-`pool` ids among `nodes` for each node (excluding self)."""
    sub = data[nodes]
    nn = len(nodes)
    norms = np.einsum("nd,nd->n", sub, sub)
    out = np.empty((nn, pool), dtype=np.int64)
    for s in range(0, nn, chunk):
        e = min(s + chunk, nn)
        d2 = norms[s:e, None] + norms[None, :] - 2.0 * (sub[s:e] @ sub.T)
        np.fill_diagonal(d2[:, s:e], np.inf)
        idx = np.argpartition(d2, pool - 1, axis=1)[:, :pool]
        row_d = np.take_along_axis(d2, idx, axis=1)
        order = np.argsort(row_d, axis=1, kind="stable")
        out[s:e] = np.take_along_axis(idx, order, axis=1)
    return out  # local indices into `nodes`


def _rerank_pool(data: np.ndarray, nodes: np.ndarray, pool_ids: np.ndarray,
                 p: float, k: int, chunk: int = 256) -> tuple[np.ndarray, np.ndarray]:
    """Re-rank each node's candidate pool under exact L_p; keep top-k."""
    sub = data[nodes]
    nn, pool = pool_ids.shape
    ids = np.empty((nn, k), dtype=np.int64)
    dists = np.empty((nn, k), dtype=np.float32)
    for s in range(0, nn, chunk):
        e = min(s + chunk, nn)
        cand = sub[pool_ids[s:e]]                      # (c, pool, d)
        diff = np.abs(cand - sub[s:e, None, :])
        if p == 2.0:
            dd = np.einsum("cpd,cpd->cp", diff, diff)
        elif p == 1.0:
            dd = diff.sum(axis=2)
        else:
            dd = (diff**p).sum(axis=2)
        idx = np.argsort(dd, axis=1, kind="stable")[:, :k]
        ids[s:e] = np.take_along_axis(pool_ids[s:e], idx, axis=1)
        dists[s:e] = np.take_along_axis(dd, idx, axis=1)
    return ids, dists


def _pairwise_p(a: np.ndarray, b: np.ndarray, p: float) -> np.ndarray:
    """(x, d) x (y, d) -> (x, y) exact Lp^p distances."""
    if p == 2.0:
        aa = np.einsum("xd,xd->x", a, a)
        bb = np.einsum("yd,yd->y", b, b)
        return np.maximum(aa[:, None] + bb[None, :] - 2.0 * (a @ b.T), 0.0)
    diff = np.abs(a[:, None, :] - b[None, :, :])
    if p == 1.0:
        return diff.sum(axis=2)
    return (diff**p).sum(axis=2)


def _vectorized_heuristic_prune(
    sub: np.ndarray, cand_ids: np.ndarray, m_max: int,
    alpha: float = 1.0, backfill: bool = False, chunk: int = 256,
) -> np.ndarray:
    """HNSW heuristic selection (Alg. 4), vectorized over nodes.

    cand_ids rows must be sorted ascending by *base-metric* distance to the
    node (-1 padded). For each node, iterate candidates in that order; select
    c_j iff d(node, c_j) <= alpha * min over already-selected s of d(c_j, s).
    alpha = 1 is the exact HNSW rule; alpha > 1 (Vamana-style) keeps
    additional longer edges, which bulk construction needs for navigability
    (sequential HNSW gets long edges for free from early low-density
    insertions).

    The diversity-rule distances are evaluated in L2^2 (MXU/matmul-friendly)
    regardless of the base metric; the *ordering* — which dominates edge
    quality — is exact base metric via the caller's sort. This keeps the
    pruning pass O(matmul) instead of O(k^2 d) elementwise for L1/Lp bases.

    With backfill=True, nodes whose selection kept < m_max edges are topped
    up with their nearest skipped candidates (used for the post-symmetrize
    cap, mirroring hnswlib's overflow pruning). Returns (nn, m_max) local
    ids, -1 padded.
    """
    nn, k = cand_ids.shape
    out = np.full((nn, m_max), -1, dtype=np.int64)
    for s in range(0, nn, chunk):
        e = min(s + chunk, nn)
        c = e - s
        ids_blk = cand_ids[s:e]
        valid = ids_blk >= 0
        safe = np.clip(ids_blk, 0, None)
        cand_vec = sub[safe.reshape(-1)].reshape(c, k, -1)
        node_vec = sub[s:e]
        # rule distances in L2^2: node->cand and cand->cand, via matmuls
        sq = np.einsum("ckd,ckd->ck", cand_vec, cand_vec)
        nsq = np.einsum("cd,cd->c", node_vec, node_vec)
        d_u = np.maximum(
            nsq[:, None] + sq - 2.0 * np.einsum("cd,ckd->ck", node_vec, cand_vec), 0.0
        )
        d_u = np.where(valid, d_u, np.inf)
        pair = np.maximum(
            sq[:, :, None] + sq[:, None, :]
            - 2.0 * np.einsum("cid,cjd->cij", cand_vec, cand_vec),
            0.0,
        )
        run_min = np.full((c, k), np.inf, dtype=np.float32)
        count = np.zeros(c, dtype=np.int64)
        selected = np.zeros((c, k), dtype=bool)
        for j in range(k):
            sel = valid[:, j] & (d_u[:, j] <= alpha * run_min[:, j]) & (count < m_max)
            selected[:, j] = sel
            count += sel
            run_min = np.where(sel[:, None], np.minimum(run_min, pair[:, j, :]), run_min)
        for row in range(c):
            sel_ids = ids_blk[row, selected[row]]
            if backfill and len(sel_ids) < m_max:
                skipped = ids_blk[row, ~selected[row] & valid[row]]
                sel_ids = np.concatenate([sel_ids, skipped[: m_max - len(sel_ids)]])
            out[s + row, : min(len(sel_ids), m_max)] = sel_ids[:m_max]
    return out


def _sort_ragged_by_base(sub: np.ndarray, lists: list[list[int]], p: float
                         ) -> np.ndarray:
    """Ragged adjacency lists -> (n, Lmax) id matrix sorted by base metric."""
    n_l = len(lists)
    lmax = max((len(l) for l in lists), default=1) or 1
    ids = np.full((n_l, lmax), -1, dtype=np.int64)
    for u, lst in enumerate(lists):
        if not lst:
            continue
        arr = np.unique(np.asarray(lst, dtype=np.int64))
        dd = _np_lp(sub[u], sub[arr], p)
        order = np.argsort(dd, kind="stable")
        ids[u, : len(arr)] = arr[order]
    return ids


def _repair_connectivity(
    mat: np.ndarray, nodes: np.ndarray, data: np.ndarray, p: float,
    entry_local: int,
) -> np.ndarray:
    """Bridge disconnected components to the entry's component.

    Bulk kNN graphs over clustered data form islands; sequential HNSW avoids
    this via early long-range insertions. We restore the property explicitly:
    BFS from the entry point, then for every unreachable component add a
    bidirectional bridge between its closest cross pair (replacing the
    farthest neighbor slot when lists are full). One pass suffices because
    every component bridges directly into the entry component.
    """
    n_l = len(nodes)
    sub = data[nodes]
    from collections import deque

    protected: dict[int, set[int]] = {}

    def add_edge(a, b):
        row = mat[a]
        existing = np.nonzero(row == b)[0]
        if len(existing):  # already linked; just protect the slot
            protected.setdefault(a, set()).add(int(existing[0]))
            return
        slot = np.nonzero(row < 0)[0]
        if len(slot):
            chosen = int(slot[0])
        else:
            # replace the farthest neighbor, but never evict a bridge edge
            dd = _np_lp(sub[a], sub[row], p)
            for s in protected.get(a, ()):  # bridges are load-bearing
                dd[s] = -np.inf
            chosen = int(np.argmax(dd))
        row[chosen] = b
        protected.setdefault(a, set()).add(chosen)

    # bridge evictions can themselves orphan nodes whose only in-edge was
    # the evicted slot — iterate to a fixed point (converges in 1-3 rounds)
    for _round in range(10):
        comp = np.full(n_l, -1, dtype=np.int64)

        def bfs(start, label):
            q = deque([start])
            comp[start] = label
            while q:
                u = q.popleft()
                for v in mat[u]:
                    if v >= 0 and comp[v] < 0:
                        comp[v] = label
                        q.append(int(v))

        bfs(entry_local, 0)
        label = 0
        for u in range(n_l):
            if comp[u] < 0:
                label += 1
                bfs(u, label)
        if label == 0:
            return mat

        main = np.nonzero(comp == 0)[0]
        main_vec = sub[main]
        for c_label in range(1, label + 1):
            members = np.nonzero(comp == c_label)[0]
            # nearest cross pair under the base metric (chunked)
            best = (np.inf, -1, -1)
            for s in range(0, len(members), 128):
                mm = members[s : s + 128]
                dd = _pairwise_p(sub[mm], main_vec, p)
                i, j = np.unravel_index(np.argmin(dd), dd.shape)
                if dd[i, j] < best[0]:
                    best = (float(dd[i, j]), int(mm[i]), int(main[j]))
            _, u, v = best
            add_edge(u, v)
            add_edge(v, u)
    return mat


def build_hnsw_bulk(
    data: np.ndarray,
    metric_p: float = 2.0,
    m: int = 32,
    k_graph: int | None = None,
    pool_factor: int = 4,
    seed: int = 0,
    alpha: float = 1.2,
    progress_every: int = 0,
) -> HNSWGraph:
    """Vectorized bulk HNSW construction (see module comment)."""
    data = np.ascontiguousarray(data, dtype=np.float32)
    n, d = data.shape
    m0 = 2 * m
    k_graph = k_graph or m0
    rng = np.random.default_rng(seed)
    ml = 1.0 / math.log(m)
    levels = np.minimum(
        (-np.log(np.maximum(rng.random(n), 1e-12)) * ml).astype(np.int32), 30
    )
    max_level = int(levels.max())
    entry = int(np.argmax(levels))

    adjacency, level_nodes, local_index = [], [], []
    for l in range(max_level + 1):
        nodes = np.nonzero(levels >= l)[0].astype(np.int32)
        sub = data[nodes]
        m_max = m0 if l == 0 else m
        # the heuristic needs a candidate pool wider than m_max to have
        # anything to prune: 2x the neighbor budget, re-ranked exactly.
        kk = min(max(k_graph if l == 0 else 2 * m, 2 * m_max), len(nodes) - 1)
        if kk <= 0:
            sel = np.full((len(nodes), m_max), -1, dtype=np.int64)
        else:
            if metric_p == 2.0:
                cand_local = _chunked_l2_topk(data, nodes, kk)
            else:
                pool = min(max(pool_factor * kk, kk), len(nodes) - 1)
                pool_local = _chunked_l2_topk(data, nodes, pool)
                cand_local, _ = _rerank_pool(data, nodes, pool_local, metric_p, kk)
            # phase 1: diversity selection (no backfill -> sparse, spread edges)
            sel = _vectorized_heuristic_prune(sub, cand_local, m_max, alpha=alpha)
        # phase 2: symmetrize, then alpha-prune the overflowed merged lists
        # (backfilled -> dense); this keeps the spread edges reverse edges
        # would otherwise evict.
        adj_lists: list[list[int]] = [list(r[r >= 0]) for r in sel]
        for u_local, row in enumerate(sel):
            for v_local in row[row >= 0]:
                if u_local not in adj_lists[v_local]:
                    adj_lists[int(v_local)].append(u_local)
        merged = _sort_ragged_by_base(sub, adj_lists, metric_p)
        pruned = _vectorized_heuristic_prune(
            sub, merged, m_max, alpha=alpha, backfill=True
        )
        # top up to full degree from the kNN pool (diversity edges keep their
        # slots; hnswlib level-0 lists also sit near-full in practice, and
        # the beam search needs the expansion factor)
        if kk > 0:
            for u_local in range(len(nodes)):
                row = pruned[u_local]
                nsel = int((row >= 0).sum())
                if nsel >= m_max:
                    continue
                have = set(row[row >= 0].tolist())
                have.add(u_local)
                for c_id in cand_local[u_local]:
                    if nsel >= m_max:
                        break
                    if int(c_id) not in have:
                        row[nsel] = c_id
                        have.add(int(c_id))
                        nsel += 1
        mat = pruned.astype(np.int32)
        # restore the navigability property sequential HNSW gets for free
        entry_local = int(np.nonzero(nodes == entry)[0][0])
        mat = _repair_connectivity(mat, nodes, data, metric_p, entry_local)
        # translate local ids -> global ids (keep -1 padding)
        mat = np.where(mat >= 0, nodes[np.clip(mat, 0, None)], -1).astype(np.int32)
        g2l = np.full(n, -1, dtype=np.int32)
        g2l[nodes] = np.arange(len(nodes), dtype=np.int32)
        adjacency.append(mat)
        level_nodes.append(nodes)
        local_index.append(g2l)
        if progress_every:
            print(f"  bulk build p={metric_p}: level {l}/{max_level} ({len(nodes)} nodes)")

    return HNSWGraph(
        metric_p=metric_p,
        m=m,
        m0=m0,
        ef_construction=-1,  # marks bulk construction
        entry_point=entry,
        max_level=max_level,
        adjacency=adjacency,
        level_nodes=level_nodes,
        local_index=local_index,
        data=data,
        levels=levels,
    )
