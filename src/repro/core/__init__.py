"""The paper's primary contribution: U-HNSW — graph-based ANNS under universal Lp metrics.

Public API:
  metrics        — Lp distance computation (jnp) + TPU cost model
  datasets       — synthetic dataset generators shaped like the paper's six corpora
  build          — HNSW graph construction (L1 / L2 / arbitrary-Lp base metrics)
  hnsw           — batched JAX beam search over a built HNSW graph
  uhnsw          — Algorithm 1: base-index selection + early-terminated Lp verification
  mlsh           — MLSH baseline (query-aware p-stable LSH, L1 + L0.5 indexes)
"""

from repro.core.metrics import lp_distance, pairwise_lp, rowwise_lp  # noqa: F401
from repro.core.build import HNSWGraph, build_hnsw  # noqa: F401
from repro.core.uhnsw import UHNSW, UHNSWParams, recall  # noqa: F401
