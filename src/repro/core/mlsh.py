"""MLSH baseline (Lu & Kudo 2021): mixed p-stable LSH for ANNS-U-Lp, p <= 1.

Reimplemented from the published description (the authors' C++ is not
available offline): two QALSH-style query-aware LSH indexes, one built with
Cauchy projections (p-stable for L1) and one with symmetric 0.5-stable
projections (for L0.5). A query (q, p) uses the index whose base metric is
closer to p (cutoff 0.75, the midpoint), then performs QALSH virtual
rehashing: count collisions inside a window around the query's projection in
each hash table, verify frequent points with exact Lp, and expand the search
radius until enough verified candidates are found.

The paper compares against *idealized* MLSH — only the Q2D Lp distance cost
N_p * T_p is charged (§4.1.4). We therefore count N_p exactly; T_p comes from
the same TPU cost model used for U-HNSW, making the comparison
implementation-agnostic exactly as the paper intends.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.metrics import lp_distance_cost_model


def sym_stable(alpha: float, size, rng: np.random.Generator) -> np.ndarray:
    """Symmetric alpha-stable samples via Chambers-Mallows-Stuck."""
    if alpha == 1.0:
        return rng.standard_cauchy(size).astype(np.float32)
    theta = rng.uniform(-np.pi / 2, np.pi / 2, size)
    w = rng.exponential(1.0, size)
    num = np.sin(alpha * theta)
    den = np.cos(theta) ** (1.0 / alpha)
    tail = (np.cos(theta * (1.0 - alpha)) / w) ** ((1.0 - alpha) / alpha)
    return (num / den * tail).astype(np.float32)


@dataclass
class _QalshIndex:
    """One query-aware p-stable LSH index (QALSH, Huang et al. 2017)."""

    p: float
    a: np.ndarray          # (m, d) projection vectors
    proj_sorted: np.ndarray  # (m, n) data projections, sorted per hash
    order: np.ndarray      # (m, n) argsort of projections per hash
    w: float               # bucket width
    freq_threshold: int    # collision-count threshold l

    @classmethod
    def build(cls, data: np.ndarray, p: float, m: int, seed: int,
              w: float | None = None, freq_frac: float = 0.5):
        n, d = data.shape
        rng = np.random.default_rng(seed)
        a = sym_stable(p, (m, d), rng)
        proj = a @ data.T  # (m, n)
        order = np.argsort(proj, axis=1).astype(np.int32)
        proj_sorted = np.take_along_axis(proj, order, axis=1)
        if w is None:
            # scale-adaptive bucket width: median nn-projection gap times a
            # constant; QALSH uses w ~ 2.719 for L2 / 2.0 for L1 on unit data
            spread = np.median(np.abs(np.diff(proj_sorted, axis=1)))
            w = float(spread * 64.0)
        return cls(p=p, a=a, proj_sorted=proj_sorted, order=order, w=w,
                   freq_threshold=max(1, int(m * freq_frac)))

    def candidates(self, q: np.ndarray, radius: float) -> np.ndarray:
        """Ids whose projection collides with q's in >= l of m hash tables."""
        qp = self.a @ q  # (m,)
        half = self.w * radius / 2.0
        m, n = self.proj_sorted.shape
        counts = np.zeros(n, dtype=np.int32)
        for i in range(m):
            lo = np.searchsorted(self.proj_sorted[i], qp[i] - half, side="left")
            hi = np.searchsorted(self.proj_sorted[i], qp[i] + half, side="right")
            counts[self.order[i, lo:hi]] += 1
        return np.nonzero(counts >= self.freq_threshold)[0]


@dataclass
class MLSHStats:
    n_p: int               # exact Lp distance evaluations (the idealized cost)
    rounds: int            # virtual-rehashing rounds
    base_p: float          # which index served the query


class MLSH:
    """Two p-stable indexes (L1 + L0.5) with per-query index selection."""

    def __init__(self, data: np.ndarray, m: int = 32, seed: int = 0,
                 cutoff: float = 0.75):
        self.data = np.ascontiguousarray(data, dtype=np.float32)
        self.cutoff = cutoff
        self.idx1 = _QalshIndex.build(self.data, 1.0, m, seed)
        self.idx05 = _QalshIndex.build(self.data, 0.5, m, seed + 1)

    def index_size_bytes(self) -> int:
        total = 0
        for idx in (self.idx1, self.idx05):
            total += idx.proj_sorted.nbytes + idx.order.nbytes + idx.a.nbytes
        return total

    def search(self, q: np.ndarray, p: float, k: int,
               cand_factor: float = 10.0, max_rounds: int = 12):
        """Top-k under Lp for one query. Returns (ids, dists, MLSHStats)."""
        if not 0.5 <= p <= 1.0:
            raise ValueError("MLSH supports 0.5 <= p <= 1 only (paper §4.2)")
        idx = self.idx05 if p < self.cutoff else self.idx1
        need = int(min(max(cand_factor * k, 2 * k), len(self.data)))
        radius, rounds = 1.0, 0
        cand = np.empty(0, dtype=np.int64)
        while len(cand) < need and rounds < max_rounds:
            cand = idx.candidates(q, radius)
            radius *= 2.0
            rounds += 1
        if len(cand) < k:  # degenerate fallback: verify everything
            cand = np.arange(len(self.data))
        # exact Lp verification — this is the idealized-MLSH cost N_p
        diff = np.abs(self.data[cand] - q[None, :])
        dists = (diff**p).sum(axis=1)
        top = np.argsort(dists, kind="stable")[:k]
        stats = MLSHStats(n_p=len(cand), rounds=rounds, base_p=idx.p)
        return cand[top], dists[top] ** (1.0 / p), stats

    def search_batch(self, Q: np.ndarray, p: float, k: int):
        ids, dists, nps = [], [], []
        for q in Q:
            i, d, s = self.search(q, p, k)
            ids.append(i)
            dists.append(d)
            nps.append(s.n_p)
        return np.stack(ids), np.stack(dists), np.array(nps)

    def idealized_query_cost(self, n_p: float, p: float, d: int) -> float:
        """Idealized MLSH cost = N_p * T_p (paper §4.1.4), same T_p model as
        U-HNSW's."""
        return float(n_p) * lp_distance_cost_model(p, d)
