"""Synthetic dataset generators shaped like the paper's six corpora (Table 1).

The real corpora (SIFT/GIST/Deep/GloVe/Sun/Trevi) are not downloadable in
this offline container, so we generate synthetic stand-ins that preserve the
two properties U-HNSW's evaluation depends on:

  * clusteredness — graph indexes exploit local neighborhood structure;
  * heavy-tailed, per-dimension-heterogeneous coordinates — this is what makes
    Lp orderings *diverge* across p (if coordinates were i.i.d. Gaussian, all
    Lp metrics would rank neighbors nearly identically and the universal-p
    problem would be trivial).

Each generator is deterministic in (name, n, seed).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# (n_full, d, type) from paper Table 1
PAPER_DATASETS = {
    "sun": (78_306, 512, "image"),
    "trevi": (99_100, 4096, "image"),
    "gist": (1_000_000, 960, "image"),
    "deep": (1_000_000, 256, "image"),
    "glove": (1_191_714, 100, "text"),
    "sift": (2_000_000, 128, "image"),
}


@dataclass
class Dataset:
    name: str
    data: np.ndarray    # (n, d) float32
    queries: np.ndarray  # (nq, d) float32
    d: int
    n: int


def _clustered_heavy_tail(
    rng: np.random.Generator, n: int, d: int, n_clusters: int, df: float,
    nonneg: bool,
) -> np.ndarray:
    """Mixture of Student-t clusters with per-dimension scale heterogeneity."""
    centers = rng.standard_normal((n_clusters, d)).astype(np.float32) * 4.0
    # heavy-tailed per-dim scales (image descriptors have very uneven energy)
    dim_scale = np.exp(rng.standard_normal(d).astype(np.float32) * 0.8)
    assign = rng.integers(0, n_clusters, size=n)
    noise = rng.standard_t(df, size=(n, d)).astype(np.float32)
    x = centers[assign] + noise * dim_scale[None, :]
    if nonneg:
        x = np.abs(x)  # SIFT-like descriptors are non-negative histograms
    return np.ascontiguousarray(x, dtype=np.float32)


def make_dataset(
    name: str,
    n: int | None = None,
    n_queries: int = 100,
    seed: int = 0,
    scale: float = 0.01,
) -> Dataset:
    """Generate a synthetic stand-in for one of the paper's datasets.

    n defaults to scale * the paper's full size (clamped to >= 2000) so the
    CPU container can afford graph construction; pass n explicitly to
    override.
    """
    if name not in PAPER_DATASETS:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(PAPER_DATASETS)}")
    n_full, d, kind = PAPER_DATASETS[name]
    if n is None:
        n = max(2000, int(n_full * scale))
    # zlib.crc32, not hash(): Python string hashing is salted per process,
    # which would make "deterministic" datasets differ between runs
    import zlib

    rng = np.random.default_rng(seed ^ (zlib.crc32(name.encode()) & 0xFFFF))
    n_clusters = max(8, int(np.sqrt(n) / 2))
    nonneg = name in ("sift", "sun")
    df = 3.0 if kind == "image" else 5.0
    pool = _clustered_heavy_tail(rng, n + n_queries, d, n_clusters, df, nonneg)
    # queries are drawn from the same distribution and jittered (paper samples
    # them from the held-out query sets of each corpus)
    data = pool[:n]
    queries = pool[n:] + 0.05 * rng.standard_normal((n_queries, d)).astype(np.float32)
    return Dataset(name=name, data=data, queries=queries.astype(np.float32), d=d, n=n)


def paper_p_values() -> list[float]:
    """The p grid used in the paper's §4.2 evaluation (uniform over this set)."""
    return [0.5, 0.6, 0.7, 0.8, 0.9]


def fig4_p_values() -> list[float]:
    """The p grid for the fixed-p HNSW comparison (§4.3: range [0.5, 1.9])."""
    return [0.5, 0.7, 0.9, 1.1, 1.3, 1.5, 1.7, 1.9]
