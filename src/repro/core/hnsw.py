"""Batched JAX beam search over a frozen HNSW graph.

HNSW traversal is pointer-chasing, which is hostile to TPU's dense execution
model. We restructure it (DESIGN.md §2) as fixed-width tensor ops inside
`jax.lax.while_loop`:

  * upper layers: greedy descent, one `while_loop` per layer (layer count is
    static per graph), each hop = gather M neighbors -> one batched base-metric
    distance -> argmin;
  * layer 0: ef-beam-search with the beam kept as a sorted (ef,) array and
    W-way multi-expansion (`expand_width`, DESIGN.md §2 hot path). Each hop
    expands the W best unexpanded beam entries at once: gather their W*m0
    neighbors, dedupe across lists (sort + first-occurrence mask), test-and-
    set a per-query visited *bitmask* (uint32 words, carry-safe scatter-add
    of distinct bits), compute base-metric distances for unseen neighbors in
    one fused block, and merge via a single `lax.sort`. W=1 is the classic
    single-expansion search.

The whole search vmaps over the query batch and jits; query batches shard
over the ('pod','data') mesh axes at serve time (see repro.retrieval).

Distances here are *base metric* (L1/L2) — the cheap family (paper §2.1); we
use root=False powers, which are ordering-equivalent. N_b (the number of
base-metric Q2D evaluations, Eq. 1) is counted exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import lp_distance


@jax.tree_util.register_pytree_node_class
class GraphArrays:
    """Frozen device-resident HNSW topology. Padding sentinel is `n`.

    Registered as a pytree with (n, metric_p) as *static* aux data so the
    traversal code can specialize on them inside jit.
    """

    def __init__(self, adj0, upper_adj, upper_g2l, entry, n: int, metric_p: float):
        self.adj0 = adj0          # (n, m0) int32 neighbor ids, pad = n
        self.upper_adj = upper_adj  # per level l>=1: (n_l, m) global ids, pad = n
        self.upper_g2l = upper_g2l  # per level l>=1: (n,) global->local, -1 absent
        self.entry = entry        # () int32
        self.n = n
        self.metric_p = metric_p

    def tree_flatten(self):
        children = (self.adj0, self.upper_adj, self.upper_g2l, self.entry)
        return children, (self.n, self.metric_p)

    @classmethod
    def tree_unflatten(cls, aux, children):
        adj0, upper_adj, upper_g2l, entry = children
        return cls(adj0, upper_adj, upper_g2l, entry, aux[0], aux[1])

    @classmethod
    def from_graph(cls, g) -> "GraphArrays":
        """Device topology for a built graph.

        Accepts the host `HNSWGraph` (re-packs adjacency, -1 -> sentinel n)
        or any graph exposing `graph_arrays()` — e.g. the bulk builder's
        `DeviceGraph` (repro.core.bulk_build), whose topology is already
        device-resident and is returned as-is.
        """
        if hasattr(g, "graph_arrays"):
            return g.graph_arrays()
        n = g.n

        def pad(a):
            a = np.asarray(a, dtype=np.int32).copy()
            a[a < 0] = n
            return jnp.asarray(a)

        adj0 = pad(g.adjacency[0])
        upper_adj = tuple(pad(a) for a in g.adjacency[1:])
        upper_g2l = tuple(jnp.asarray(a) for a in g.local_index[1:])
        return cls(
            adj0=adj0,
            upper_adj=upper_adj,
            upper_g2l=upper_g2l,
            entry=jnp.asarray(g.entry_point, dtype=jnp.int32),
            n=n,
            metric_p=g.metric_p,
        )

    def pad_to(self, n_pad: int, n_levels: int,
               level_sizes: tuple[int, ...],
               upper_m: int | None = None) -> "GraphArrays":
        """Re-pad to a uniform shape so segments can stack (repro.index).

        Grows the node capacity to n_pad (sentinel n -> n_pad everywhere),
        the upper-level count to n_levels and each level-l row count to
        level_sizes[l]. Missing levels become a single all-sentinel row with
        every node mapped onto it: one greedy-descent hop sees only invalid
        neighbors, adds 0 to N_b, and falls through to the next level.
        """
        assert n_pad >= self.n and n_levels >= len(self.upper_adj)
        old_n = self.n

        def repad(a, rows):
            a = np.asarray(a)
            a = np.where(a == old_n, n_pad, a).astype(np.int32)
            out = np.full((rows, a.shape[1]), n_pad, dtype=np.int32)
            out[: a.shape[0]] = a
            return jnp.asarray(out)

        m = upper_m or (
            self.upper_adj[0].shape[1] if self.upper_adj else self.adj0.shape[1]
        )
        upper_adj, upper_g2l = [], []
        for l in range(n_levels):
            if l < len(self.upper_adj):
                upper_adj.append(repad(self.upper_adj[l], level_sizes[l]))
                g2l = np.full(n_pad, -1, dtype=np.int32)
                g2l[:old_n] = np.asarray(self.upper_g2l[l])
            else:
                upper_adj.append(
                    jnp.full((level_sizes[l], m), n_pad, dtype=jnp.int32)
                )
                g2l = np.zeros(n_pad, dtype=np.int32)  # -> harmless row 0
            upper_g2l.append(jnp.asarray(g2l))
        return GraphArrays(
            adj0=repad(self.adj0, n_pad),
            upper_adj=tuple(upper_adj),
            upper_g2l=tuple(upper_g2l),
            entry=self.entry,
            n=n_pad,
            metric_p=self.metric_p,
        )

    @staticmethod
    def stack(arrays: "list[GraphArrays]") -> "GraphArrays":
        """Stack same-shaped GraphArrays on a leading segment axis.

        All inputs must already be pad_to'd to identical shapes (and share
        metric_p); the result vmaps over axis 0 in knn_search.
        """
        n = arrays[0].n
        p = arrays[0].metric_p
        assert all(a.n == n and a.metric_p == p for a in arrays)
        leaves = [a.tree_flatten()[0] for a in arrays]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)
        return GraphArrays(*stacked, n=n, metric_p=p)


def _base_dist(q: jax.Array, x: jax.Array, p: float) -> jax.Array:
    """Ordering-equivalent base-metric distance (root-free power sum)."""
    return lp_distance(q, x, p, root=False)


def _greedy_descend(q, X, adj_l, g2l, ep, ep_dist, nb, p, max_hops):
    """Greedy ef=1 search on one upper layer. Returns (ep, ep_dist, nb)."""
    n = X.shape[0]

    def cond(s):
        return s[0] & (s[5] < max_hops)

    def body(s):
        _, ep, ep_dist, nb, _, hops = s
        nbrs = adj_l[g2l[ep]]  # (m,) global ids, pad = n
        valid = nbrs < n
        dv = _base_dist(q, X[jnp.clip(nbrs, 0, n - 1)], p)
        dv = jnp.where(valid, dv, jnp.inf)
        j = jnp.argmin(dv)
        better = dv[j] < ep_dist
        ep2 = jnp.where(better, nbrs[j], ep)
        d2 = jnp.minimum(dv[j], ep_dist)
        return (better, ep2, d2, nb + valid.sum(), j, hops + 1)

    go = jnp.asarray(True)
    s = (go, ep, ep_dist, nb, jnp.int32(0), jnp.int32(0))
    s = jax.lax.while_loop(cond, body, s)
    return s[1], s[2], s[3]


def _beam_search_l0(q, X, adj0, entry, entry_dist, nb0, p, ef, max_hops,
                    width: int = 1, thresh=None):
    """Level-0 ef-beam search for one query. Returns (ids, dists, nb, hops).

    `width` (W) is the multi-expansion factor (DESIGN.md §2 hot path): each
    `while_loop` hop expands the W closest unexpanded beam entries at once —
    one (W*m0,) gather, one batched visited test-and-set, one fused distance
    block, one merge sort. Trip count drops ~W×; each trip's tensor work is
    W× wider, which the hardware prefers to W serialized skinny hops. W=1
    reproduces the classic single-expansion search exactly.

    `thresh` (traced scalar, or None for the unmodified program) is the
    cross-segment pruning bound (DESIGN.md §3): a neighbor whose base-metric
    distance exceeds it is counted in N_b (the evaluation happened) and
    marked visited, but is *not admitted* to the beam — it can neither be
    expanded nor returned. The loop therefore terminates once the
    sub-threshold region reachable from the entry is exhausted, instead of
    flooding the whole ef-neighborhood. The entry itself is always admitted
    (it seeds navigation even when its own distance exceeds the bound).
    """
    n, m0 = X.shape[0], adj0.shape[1]
    words = (n + 31) // 32
    w = width

    ids0 = jnp.full((ef,), n, dtype=jnp.int32).at[0].set(entry)
    dist0 = jnp.full((ef,), jnp.inf, dtype=jnp.float32).at[0].set(entry_dist)
    # sentinel slots start "expanded" so they are never selected
    exp0 = jnp.ones((ef,), dtype=jnp.int32).at[0].set(0)
    visited0 = jnp.zeros((words,), dtype=jnp.uint32)
    visited0 = visited0.at[entry >> 5].set(jnp.uint32(1) << (entry.astype(jnp.uint32) & 31))

    def cond(s):
        ids, dist, exp, visited, nb, hops = s
        active = (exp == 0) & (ids < n)
        return jnp.any(active) & (hops < max_hops)

    def body(s):
        ids, dist, exp, visited, nb, hops = s
        # 1. select the W closest unexpanded beam entries
        sel_key = jnp.where((exp == 0) & (ids < n), dist, jnp.inf)
        if w == 1:
            js = jnp.argmin(sel_key)[None]        # (1,)
            sel_ok = jnp.isfinite(sel_key[js])
        else:
            neg, js = jax.lax.top_k(-sel_key, w)  # (W,) best = smallest dist
            sel_ok = jnp.isfinite(neg)            # fewer than W unexpanded?
        exp = exp.at[js].set(1)
        # 2. gather all W neighbor lists; unselected slots contribute
        #    sentinels only
        srcs = jnp.where(sel_ok, ids[js], n)                  # (W,)
        nbrs = adj0[jnp.clip(srcs, 0, n - 1)]                 # (W, m0)
        nbrs = jnp.where(sel_ok[:, None], nbrs, n).reshape(-1)  # (W*m0,)
        if w > 1:
            # the W lists can share neighbors; sort + first-occurrence mask
            # dedupes so the bitmask scatter-add below stays carry-free
            nbrs = jax.lax.sort(nbrs)
            first = jnp.concatenate(
                [jnp.ones((1,), bool), nbrs[1:] != nbrs[:-1]]
            )
        else:
            # a single adjacency row holds distinct ids by construction
            first = jnp.ones((m0,), bool)
        # 3. batched visited-bitmask test-and-set
        valid = nbrs < n
        safe = jnp.clip(nbrs, 0, n - 1)
        word = safe >> 5
        bit = jnp.uint32(1) << (safe.astype(jnp.uint32) & 31)
        seen = (visited[word] & bit) != 0
        new = valid & ~seen & first
        # distinct ids -> distinct (word, bit); duplicates are masked to 0,
        # so the scatter-add below is carry-free.
        visited = visited.at[word].add(bit * new.astype(jnp.uint32))
        # 4. one fused base-metric distance block for unseen neighbors only
        dv = _base_dist(q, X[safe], p)
        dv = jnp.where(new, dv, jnp.inf)
        nb = nb + new.sum()
        if thresh is not None:
            # cross-segment early-cut: evaluated (counted above, visited
            # stays set) but above the inherited global bound -> inf, which
            # the merge below flags expanded and sorts past the beam
            dv = jnp.where(dv <= thresh, dv, jnp.inf)
        # 5. merge beam + frontier with a single sort, keep top-ef
        all_ids = jnp.concatenate([ids, nbrs])
        all_dist = jnp.concatenate([dist, dv])
        # frontier entries join unexpanded; anything with inf distance
        # (sentinels, masked duplicates) is flagged expanded so it can never
        # be selected -> guarantees loop progress. The isinf mask is needed
        # on the (W*m0) frontier half only: beam entries with inf distance
        # already carry exp=1 (sentinel init + this very forcing in every
        # earlier merge), so rebuilding it over the full (ef + W*m0) concat
        # each hop was redundant work (measured in
        # benchmarks/beam_width.py's merge micro-bench).
        all_exp = jnp.concatenate([exp, jnp.isinf(dv).astype(jnp.int32)])
        sd, si, se = jax.lax.sort((all_dist, all_ids, all_exp), num_keys=1)
        return (si[:ef], sd[:ef], se[:ef], visited, nb, hops + 1)

    s = (ids0, dist0, exp0, visited0, nb0, jnp.int32(0))
    ids, dist, exp, visited, nb, hops = jax.lax.while_loop(cond, body, s)
    return ids, dist, nb, hops


def _greedy_descend_l0(q, X, adj0, ep, ep_dist, nb, p, max_hops,
                       thresh=None):
    """Greedy ef=1 descent on the *level-0* adjacency (ids are global, no
    g2l remap). Used only on the thresholded cross-segment path: it walks
    downhill before the admission-cut beam starts, so a far-off entry
    whose whole neighborhood sits above the bound cannot strand the
    search before it reaches the query's region. The walk stops as soon
    as the entry drops below `thresh` — the beam takes over from there,
    so descending further only duplicates evaluations the beam will
    redo."""
    n = X.shape[0]

    def cond(s):
        return s[0] & (s[4] < max_hops)

    def body(s):
        _, ep, ep_dist, nb, hops = s
        nbrs = adj0[ep]  # (m0,) pad = n
        valid = nbrs < n
        dv = _base_dist(q, X[jnp.clip(nbrs, 0, n - 1)], p)
        dv = jnp.where(valid, dv, jnp.inf)
        j = jnp.argmin(dv)
        better = dv[j] < ep_dist
        ep2 = jnp.where(better, nbrs[j], ep)
        d2 = jnp.minimum(dv[j], ep_dist)
        go = better
        if thresh is not None:
            go = go & (d2 > thresh)
        return (go, ep2, d2, nb + valid.sum(), hops + 1)

    s = (jnp.asarray(True), ep, ep_dist, nb, jnp.int32(0))
    if thresh is not None:
        s = (ep_dist > thresh, ep, ep_dist, nb, jnp.int32(0))
    s = jax.lax.while_loop(cond, body, s)
    return s[1], s[2], s[3]


def _search_one(q, X, arrays: GraphArrays, ef: int, max_hops: int,
                expand_width: int = 1, thresh=None):
    p = arrays.metric_p
    n = arrays.n
    ep = arrays.entry
    ep_dist = _base_dist(q, X[ep], p)
    nb = jnp.int32(1)
    # descend upper layers, top to bottom (static python loop over levels)
    for adj_l, g2l in zip(reversed(arrays.upper_adj), reversed(arrays.upper_g2l)):
        ep, ep_dist, nb = _greedy_descend(
            q, X, adj_l, g2l, ep, ep_dist, nb, p, max_hops
        )
    if thresh is not None:
        # finish navigation greedily at level 0 before the admission cut
        # engages — see _greedy_descend_l0
        ep, ep_dist, nb = _greedy_descend_l0(
            q, X, arrays.adj0, ep, ep_dist, nb, p, max_hops, thresh=thresh
        )
    return _beam_search_l0(q, X, arrays.adj0, ep, ep_dist, nb, p, ef,
                           max_hops, width=expand_width, thresh=thresh)


@functools.partial(jax.jit, static_argnames=("ef", "t", "max_hops", "expand_width"))
def knn_search(
    arrays: GraphArrays,
    X: jax.Array,
    Q: jax.Array,
    ef: int,
    t: int,
    max_hops: int = 4096,
    expand_width: int = 1,
    thresh: jax.Array | None = None,
):
    """Batched t-NN search under the graph's base metric.

    Args:
      arrays: frozen graph topology (GraphArrays.from_graph).
      X: (n, d) dataset.
      Q: (B, d) query batch.
      ef: beam width (>= t).
      t: number of candidates to return per query (paper's t).
      expand_width: W-way multi-expansion factor for the level-0 beam
        (W best unexpanded entries per hop; W=1 = classic HNSW).
      thresh: optional (B,) per-query base-metric (root-free) pruning
        bounds — the cross-segment inherited k-th-best (DESIGN.md §3).
        Neighbors beyond a query's bound are evaluated (counted in n_b)
        but never admitted to its beam; slots past the admitted set come
        back as id n with dist inf. None (the default) compiles the
        unmodified program — bit-identical to the pre-threshold search.

    Returns:
      ids   (B, t) int32 candidate ids sorted by base-metric distance;
      dists (B, t) base-metric distances (root-free powers);
      n_b   (B,)   exact count of base-metric Q2D evaluations (Eq. 1 N_b);
      hops  (B,)   level-0 hop counts (while_loop trips — one trip expands
                   up to `expand_width` beam entries).
    """
    assert ef >= t, (ef, t)
    assert 1 <= expand_width <= ef, (
        f"expand_width must be in [1, ef]: got expand_width={expand_width}, "
        f"ef={ef} (top_k cannot select more entries than the beam holds)"
    )
    if thresh is None:
        ids, dists, nb, hops = jax.vmap(
            lambda q: _search_one(q, X, arrays, ef, max_hops, expand_width)
        )(Q)
    else:
        thresh = jnp.asarray(thresh, dtype=jnp.float32)
        ids, dists, nb, hops = jax.vmap(
            lambda q, th: _search_one(q, X, arrays, ef, max_hops,
                                      expand_width, thresh=th)
        )(Q, thresh)
    return ids[:, :t], dists[:, :t], nb, hops


@functools.partial(jax.jit, static_argnames=("p",))
def _exact_topk_merge_chunk(best_d, best_i, Q, xc, start, p: float):
    """One brute-force chunk: score + sort-merge into the running top-k.

    Jitted with `start` as a *traced* scalar, so the compile cache is keyed
    only on the chunk shape: one compilation covers every full chunk and one
    more covers the ragged tail, instead of re-tracing per chunk.
    """
    from repro.core.metrics import pairwise_lp

    k = best_d.shape[1]
    d = pairwise_lp(Q, xc, p, root=False)
    ids = jnp.arange(xc.shape[0], dtype=jnp.int32) + start
    ids = jnp.broadcast_to(ids[None, :], d.shape)
    all_d = jnp.concatenate([best_d, d], axis=1)
    all_i = jnp.concatenate([best_i, ids], axis=1)
    sd, si = jax.lax.sort((all_d, all_i), num_keys=1)
    return sd[:, :k], si[:, :k]


def exact_topk(X: jax.Array, Q: jax.Array, p: float, k: int, chunk: int = 8192):
    """Brute-force Lp top-k oracle (used for ground truth and recall).

    When n < k the trailing slots hold id -1 with inf distance — padding,
    not real points; `recall()` and downstream consumers must mask ids < 0.
    """
    n = X.shape[0]
    best_d = jnp.full((Q.shape[0], k), jnp.inf)
    best_i = jnp.full((Q.shape[0], k), -1, dtype=jnp.int32)
    for start in range(0, n, chunk):
        xc = X[start : start + chunk]
        best_d, best_i = _exact_topk_merge_chunk(
            best_d, best_i, Q, xc, jnp.int32(start), p
        )
    return best_i, best_d
