"""The cheap-op-sequence table for |diff|^p and s^(1/p) (paper §2.1).

Single source of truth for the per-p-family op sequences, shared by the
pure-jnp reference metrics (repro.core.metrics) and the Pallas kernel
bodies (repro.kernels.lp_distance / lp_topk). Both sides used to carry
private copies; keeping one table here means the hardware cost asymmetry
(basic ALU for p ∈ {1, 2}, one sqrt for p ∈ {0.5, 1.5}, exp+log for
general p) cannot drift between reference and kernel.

Everything here is plain jnp elementwise math, so the same functions
trace correctly inside `pl.pallas_call` kernel bodies and in ordinary
jitted code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Guard for log(0) in the general-p transcendental path.
EPS = 1e-30


def abs_pow(diff: jax.Array, p: float) -> jax.Array:
    """|diff|^p elementwise, using the cheapest op sequence for this p."""
    a = jnp.abs(diff)
    if p == 1.0:
        return a
    if p == 2.0:
        return diff * diff
    if p == 0.5:
        return jnp.sqrt(a)
    if p == 1.5:
        return a * jnp.sqrt(a)
    # General p: exp(p * log|d|), masking the log singularity at 0.
    safe = jnp.maximum(a, EPS)
    return jnp.where(a == 0, 0.0, jnp.exp(p * jnp.log(safe)))


def lp_root(s: jax.Array, p: float) -> jax.Array:
    """s^(1/p) elementwise (the outer root of the Lp norm)."""
    if p == 1.0:
        return s
    if p == 2.0:
        return jnp.sqrt(s)
    if p == 0.5:
        return s * s
    safe = jnp.maximum(s, EPS)
    return jnp.where(s == 0, 0.0, jnp.exp(jnp.log(safe) / p))
