"""The cheap-op-sequence table for |diff|^p and s^(1/p) (paper §2.1).

Single source of truth for the per-p-family op sequences, shared by the
pure-jnp reference metrics (repro.core.metrics) and the Pallas kernel
bodies (repro.kernels.lp_distance / lp_topk). Both sides used to carry
private copies; keeping one table here means the hardware cost asymmetry
(basic ALU for p ∈ {1, 2}, one sqrt for p ∈ {0.5, 1.5}, exp+log for
general p) cannot drift between reference and kernel.

Scalar-vs-vector p contract (DESIGN.md §6): `p` may be

  * a Python float — compile-time specialization, only that p's op
    sequence is emitted (the classic per-p path); or
  * a jax scalar / array broadcastable against the data — one traced
    program serves any mix of p values. The vector path evaluates every
    family's op sequence elementwise and `jnp.where`-selects per element,
    so the value produced for a given p is *bit-identical* to the scalar
    specialization of that p (a select returns the chosen operand's bits
    unchanged). That bit-parity is what lets the mixed-p serving engine
    promise "one batched call == per-p grouped calls" exactly.

Everything here is plain jnp elementwise math, so the same functions
trace correctly inside `pl.pallas_call` kernel bodies (where vector p
shows up as a traced per-row scalar) and in ordinary jitted code.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Guard for log(0) in the general-p transcendental path.
EPS = 1e-30


def is_static_p(p) -> bool:
    """True when p is a concrete host scalar (per-p static specialization).

    Accepts Python ints/floats and 0-d numpy scalars/arrays — anything a
    caller can hand over as "one p for the whole call". Dispatchers must
    coerce with float(p) before using it as a static jit argument (numpy
    0-d arrays are unhashable). jax arrays — including concrete 0-d ones —
    take the traced vector-p path.
    """
    if isinstance(p, bool):
        return False
    if isinstance(p, (int, float)):
        return True
    import numpy as np

    return isinstance(p, (np.generic, np.ndarray)) and np.ndim(p) == 0


def pow_from_abs(a: jax.Array, p) -> jax.Array:
    """a^p elementwise for a >= 0 (a is already |diff|), cheapest op
    sequence per p family. `abs_pow` is the |.|-including wrapper; the
    early-abandoning blocked scan (DESIGN.md §8) calls this directly so
    the one `jnp.abs` it shares with the base-metric accumulator is not
    recomputed per family. For p == 2, a*a carries the same bits as
    diff*diff (abs only flips the sign bit), so both entry points emit
    one op-sequence table.
    """
    if is_static_p(p):
        if p == 1.0:
            return a
        if p == 2.0:
            return a * a
        if p == 0.5:
            return jnp.sqrt(a)
        if p == 1.5:
            return a * jnp.sqrt(a)
        # General p: exp(p * log|d|), masking the log singularity at 0.
        safe = jnp.maximum(a, EPS)
        return jnp.where(a == 0, 0.0, jnp.exp(p * jnp.log(safe)))
    # Traced p: evaluate every family, select per element. Each branch is
    # the *same expression* the static path emits for that p, so selected
    # values are bit-identical to the per-p specialization.
    safe = jnp.maximum(a, EPS)
    out = jnp.where(a == 0, 0.0, jnp.exp(p * jnp.log(safe)))
    out = jnp.where(p == 1.0, a, out)
    out = jnp.where(p == 2.0, a * a, out)
    out = jnp.where(p == 0.5, jnp.sqrt(a), out)
    out = jnp.where(p == 1.5, a * jnp.sqrt(a), out)
    return out


def abs_pow(diff: jax.Array, p) -> jax.Array:
    """|diff|^p elementwise, using the cheapest op sequence for this p.

    p: Python float (static specialization) or an array broadcastable to
    `diff` (per-element selection; see module docstring for the contract).
    """
    if is_static_p(p) and p == 2.0:
        return diff * diff  # skip the (bit-neutral) abs on the L2 hot path
    return pow_from_abs(jnp.abs(diff), p)


def _lp_root_impl(s: jax.Array, p, static_fold: bool) -> jax.Array:
    if is_static_p(p):
        if p == 1.0:
            return s
        if p == 2.0:
            return jnp.sqrt(s)
        if p == 0.5:
            return s * s
        safe = jnp.maximum(s, EPS)
        if static_fold:
            return jnp.where(s == 0, 0.0, jnp.exp(jnp.log(safe) / p))
        # Force the divisor to a *runtime* operand: XLA strength-reduces
        # division by a literal constant into multiplication by its
        # reciprocal, which rounds differently from the true division a
        # traced-p program performs. The barrier makes the static-p and
        # vector-p programs emit the identical divide, which is what the
        # mixed-p serving engine's bit-parity guarantee rests on.
        pr = jax.lax.optimization_barrier(jnp.asarray(p, jnp.float32))
        return jnp.where(s == 0, 0.0, jnp.exp(jnp.log(safe) / pr))
    safe = jnp.maximum(s, EPS)
    out = jnp.where(s == 0, 0.0, jnp.exp(jnp.log(safe) / p))
    out = jnp.where(p == 1.0, s, out)
    out = jnp.where(p == 2.0, jnp.sqrt(s), out)
    out = jnp.where(p == 0.5, s * s, out)
    return out


def lp_root(s: jax.Array, p) -> jax.Array:
    """s^(1/p) elementwise (the outer root of the Lp norm).

    Same scalar-vs-vector p contract as `abs_pow`; for static general p the
    divisor is barriered so the emitted division rounds identically to the
    vector-p program's (see `_lp_root_impl`).
    """
    return _lp_root_impl(s, p, static_fold=False)


def lp_root_folded(s: jax.Array, p) -> jax.Array:
    """`lp_root` without the division barrier — for Pallas kernel *bodies*,
    where `lax.optimization_barrier` is not guaranteed to lower through
    Mosaic and the historical constant-folded codegen should be kept."""
    return _lp_root_impl(s, p, static_fold=True)


# ---------------------------------------------------------------------------
# Early-abandoning verification bounds (DESIGN.md §8).
#
# The blocked-dimension scan abandons a candidate once a provable *lower
# bound* on its final root-free power sum exceeds the running k-th-best.
# Two bound families, both exact inequalities of real arithmetic:
#
#   * entry bound — from the base-metric beam distance Sb (already paid for
#     under Eq. 1's N_b), before ANY dimension block is scanned:
#       base L1:  sum|v|^p >= S1^p            for p <= 1  (norm monotonicity)
#                 sum|v|^p >= d^(1-p) * S1^p  for p >  1  (Jensen, x^p convex)
#       base L2:  sum|v|^p >= S2^(p/2)        for p <= 2  (superadditivity of
#                                                          x^(p/2), p/2 <= 1)
#   * suffix bound — mid-scan, from the *remaining* base mass
#     R = Sb - (base partial sum over scanned dims): the same inequalities
#     applied to the unscanned dimension suffix (d_rem dims).
#
# Float safety: the bounds are deflated by BOUND_SLACK so accumulated f32
# rounding (non-negative sums err by <= ~d*ulp relative, far below 1e-3)
# can never promote a bound above a value it does not exceed in real
# arithmetic — a too-small bound only scans more, never breaks exactness.
# Exponentials all route through `_safe_pow` (runtime exp/log, no
# static-p fast path) so the static-p and traced-p programs emit the same
# divide-free op sequence and round identically.
# ---------------------------------------------------------------------------

BOUND_SLACK = 1e-3


def _safe_pow(x: jax.Array, e) -> jax.Array:
    """x^e for x >= 0 via exp(e*log x), with x == 0 -> 0."""
    safe = jnp.maximum(x, EPS)
    return jnp.where(x <= 0, 0.0, jnp.exp(e * jnp.log(safe)))


def lp_entry_bound(sb: jax.Array, base_p: float, p, d) -> jax.Array:
    """Lower bound on sum|q-x|^p from the base-metric power sum `sb` of a
    d-dimensional difference vector.

    base_p is static (1.0 or 2.0 — the graph that generated the
    candidates); p is a Python float or traced per-row scalar/array
    broadcastable to sb; d may be a static int or traced (the blocked
    scan passes its shrinking remaining-dim count). Callers pass sb = 0
    to disable (bound becomes 0).
    """
    sb = jnp.maximum(sb, 0.0)
    if base_p == 1.0:
        lb = _safe_pow(sb, p)
        dd = jnp.maximum(jnp.asarray(d, jnp.float32), 1.0)
        if is_static_p(p):
            if p > 1.0:
                lb = lb * _safe_pow(dd, 1.0 - p)
        else:
            lb = jnp.where(p > 1.0, lb * _safe_pow(dd, 1.0 - p), lb)
    else:
        lb = _safe_pow(sb, p / 2.0 if is_static_p(p) else p * 0.5)
    return lb * (1.0 - BOUND_SLACK)


def lp_suffix_bound(r: jax.Array, base_p: float, p, d_rem) -> jax.Array:
    """Lower bound on the unscanned suffix's power sum from its remaining
    base mass r (= Sb - scanned base partial, clamped >= 0) over d_rem
    dims — the same inequalities as `lp_entry_bound` applied to the
    suffix, so it *is* that bound (one implementation to keep the two
    abandonment paths from drifting)."""
    return lp_entry_bound(r, base_p, p, d_rem)
