"""Logical-axis sharding: Runtime + the logical -> mesh-axis mapping.

Every parameter / activation / cache spec in the repo names its dims with
*logical* axes (see repro.models.params for the vocabulary). This module owns
the single mapping from those names to physical mesh axes:

  tensor-parallel ('model') : vocab, heads, ff, experts, inner, cache_seq
  data-parallel / FSDP      : embed, batch  -> ('pod', 'data') — whichever of
                              the two exist on the mesh, in that order
  replicated                : everything else (kv, head, eff, state, layers,
                              lora, seq_act unless rt.seq_shard, ...)

Two fallbacks keep every (arch x mesh) cell compilable instead of erroring:
  * missing axis — a rule that names a mesh axis the mesh doesn't have
    replicates that dim (lets the same specs drive 1-device tests and the
    512-chip dry-run);
  * divisibility — a dim that doesn't divide by its axis size replicates
    (e.g. qwen's 40 heads on a 16-wide 'model' axis). Callers can collect
    these via the `fallbacks` list to surface them in dry-run reports.

`Runtime` is a frozen dataclass so experiment variants derive via
`dataclasses.replace` (e.g. the weights-once path overrides rules['embed']).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical axes that shard over the tensor-parallel ('model') axis
_TP_AXES = frozenset({"vocab", "heads", "ff", "experts", "inner", "cache_seq"})
# logical axes that shard over the data-parallel / FSDP axes
_DP_AXES = frozenset({"embed", "batch"})


@dataclass(frozen=True)
class Runtime:
    """Mesh + parallelism mode flags, threaded through every model call.

    rules: per-logical-axis overrides (axis name, axis tuple, or None to
    replicate) consulted before the built-in mapping.
    """

    mesh: Any
    rules: dict = field(default_factory=dict)
    remat: bool = False
    explicit_tp: bool = False      # shard_map FFN matmuls instead of GSPMD
    seq_shard: bool = False        # shard activation seq dim over 'model'
    moe_decode_gather: bool = False  # weights-stationary decode MoE
    full_dp: bool = False          # ZeRO-3 over *all* mesh axes, no TP

    @property
    def dp_axes(self) -> tuple[str, ...]:
        if self.full_dp:
            return tuple(self.mesh.axis_names)
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def tp_axis(self) -> str:
        return "model"

    @property
    def dp_size(self) -> int:
        return int(math.prod(self.mesh.shape[a] for a in self.dp_axes))

    @property
    def tp_size(self) -> int:
        if self.full_dp or "model" not in self.mesh.axis_names:
            return 1
        return int(self.mesh.shape["model"])


def _resolve(name: str | None, rt: Runtime):
    """Logical axis name -> mesh axis name / axis tuple / None (replicate)."""
    if name is None:
        return None
    if name in rt.rules:
        return rt.rules[name]
    if name in _DP_AXES:
        dp = rt.dp_axes
        if not dp:
            return None
        return dp if len(dp) > 1 else dp[0]
    if name == "seq_act":
        return rt.tp_axis if rt.seq_shard and not rt.full_dp else None
    if name in _TP_AXES:
        return None if rt.full_dp else rt.tp_axis
    return None


def logical_to_spec(
    logical: tuple[str | None, ...],
    shape: tuple[int, ...],
    rt: Runtime,
    fallbacks: list | None = None,
) -> P:
    """Map logical dim names to a PartitionSpec, with safety fallbacks.

    A dim replicates (None entry) when its rule names a mesh axis that does
    not exist, or when the dim size is not divisible by the axis size; the
    latter is recorded in `fallbacks` as (logical_name, dim, axis_size).
    """
    assert len(logical) == len(shape), (logical, shape)
    names = set(rt.mesh.axis_names)
    entries = []
    for name, dim in zip(logical, shape):
        ax = _resolve(name, rt)
        if ax is None:
            entries.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in names for a in axes):
            entries.append(None)
            continue
        size = int(math.prod(rt.mesh.shape[a] for a in axes))
        if size > 1 and dim % size != 0:
            if fallbacks is not None:
                fallbacks.append((name, dim, size))
            entries.append(None)
            continue
        entries.append(ax)
    return P(*entries)


def set_mesh(mesh):
    """Context manager activating `mesh` across jax versions.

    jax >= 0.5 exposes jax.sharding.set_mesh; on older versions the Mesh
    object itself is the context manager (NamedSharding / shard_map carry
    their mesh explicitly, so the context only backs bare-PartitionSpec
    jit/pjit uses).
    """
    sm = getattr(jax.sharding, "set_mesh", None)
    if sm is not None:
        return sm(mesh)
    return mesh


def abstract_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...]):
    """AbstractMesh across jax versions (ctor signature changed ~0.5)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def constrain(x: jax.Array, rt: Runtime, logical: tuple[str | None, ...]):
    """with_sharding_constraint under the logical mapping (activation pin)."""
    spec = logical_to_spec(logical, x.shape, rt)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rt.mesh, spec))


def spec_shardings(specs, rt: Runtime):
    """ParamSpec tree -> NamedSharding tree (same structure as the params)."""
    from repro.models.params import _map_specs

    def mk(s):
        return NamedSharding(rt.mesh, logical_to_spec(s.logical, s.shape, rt))

    return _map_specs(mk, specs)


def param_struct(specs, rt: Runtime):
    """ParamSpec tree -> sharded ShapeDtypeStruct tree (dry-run contract)."""
    from repro.models.params import _map_specs

    def mk(s):
        sh = NamedSharding(rt.mesh, logical_to_spec(s.logical, s.shape, rt))
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)

    return _map_specs(mk, specs)
