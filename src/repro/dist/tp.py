"""Explicit tensor-parallel FFN matmuls (shard_map) — the rt.explicit_tp path.

GSPMD usually derives these collectives itself; the explicit path exists so
the dry-run can compare hand-placed collectives against the compiler's
(EXPERIMENTS.md §Perf). Layout contract matches the param specs:

  wi (d, f)  logical ('embed', 'ff')  -> (dp-sharded, 'model'-sharded)
  wo (f, d)  logical ('ff', 'embed')  -> ('model'-sharded, dp-sharded)

col_matmul_ffn produces activations column-sharded on f over 'model';
row_matmul_ffn contracts the f shards and completes with a psum, returning
the activation replicated over 'model' (batch stays dp-sharded throughout).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _dp_spec(rt):
    dp = rt.dp_axes
    if not dp:
        return None
    return dp if len(dp) > 1 else dp[0]


def col_matmul_ffn(x: jax.Array, w: jax.Array, rt) -> jax.Array:
    """x (B, S, d) @ w (d, f) -> (B, S, f) column-sharded on f over 'model'."""
    if rt.tp_size == 1:
        return jnp.einsum("bsd,df->bsf", x, w)
    dp, tp = rt.dp_axes, rt.tp_axis
    dps = _dp_spec(rt)

    def inner(xl, wl):
        # un-FSDP the weight's d axis for this layer's matmul
        wf = jax.lax.all_gather(wl, dp, axis=0, tiled=True) if dp else wl
        return jnp.einsum("bsd,df->bsf", xl, wf)

    return shard_map(
        inner, mesh=rt.mesh,
        in_specs=(P(dps, None, None), P(dps, tp)),
        out_specs=P(dps, None, tp),
        check_rep=False,
    )(x, w)


def row_matmul_ffn(x: jax.Array, w: jax.Array, rt) -> jax.Array:
    """x (B, S, f) f-sharded @ w (f, d) -> (B, S, d), psum over 'model'."""
    if rt.tp_size == 1:
        return jnp.einsum("bsf,fd->bsd", x, w)
    dp, tp = rt.dp_axes, rt.tp_axis
    dps = _dp_spec(rt)

    def inner(xl, wl):
        wf = jax.lax.all_gather(wl, dp, axis=1, tiled=True) if dp else wl
        y = jnp.einsum("bsf,fd->bsd", xl, wf)
        return jax.lax.psum(y, tp)

    return shard_map(
        inner, mesh=rt.mesh,
        in_specs=(P(dps, None, tp), P(tp, dps)),
        out_specs=P(dps, None, None),
        check_rep=False,
    )(x, w)
