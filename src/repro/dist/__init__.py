"""Mesh / sharding / collective helpers.

  sharding — Runtime (mesh + parallelism flags), logical-axis -> PartitionSpec
             mapping with divisibility fallbacks, spec-tree shardings
  tp       — explicit tensor-parallel matmuls (shard_map) for the FFN path
"""

from repro.dist.sharding import (  # noqa: F401
    Runtime,
    constrain,
    logical_to_spec,
    param_struct,
    spec_shardings,
)
