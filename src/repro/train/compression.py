"""Gradient compression: int8 quantization with error feedback.

Under pure data parallelism, gradient all-reduce volume dominates the
inter-pod link budget. We quantize each gradient leaf to int8 with a
per-leaf scale before the (GSPMD-inserted) all-reduce and carry the
quantization error into the next step (error feedback), which provably
preserves SGD convergence (Karimireddy et al. 2019) and empirically
preserves Adam training at 4x lower collective volume.

Implementation note: in the SPMD programming model the all-reduce is
inserted by the compiler, so "compress -> all-reduce -> decompress" is
expressed as quantize -> dequantize around the point where the gradient is
consumed; XLA hoists the quantized representation through the collective
when profitable. The *semantic* contract (int8 wire format + error
feedback) is what we test; the §Perf collective-bytes accounting uses the
int8 volume.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compression_init(params):
    """Error-feedback buffers, one per parameter leaf (same sharding)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize_leaf(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress_grads(grads, error_buf):
    """Returns (dequantized grads, new error buffers).

    new_error = (g + e) - dequant(quant(g + e))
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize_leaf(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_buf)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = tree.unflatten([o[0] for o in out])
    new_e = tree.unflatten([o[1] for o in out])
    return deq, new_e
