"""Train step factory: loss -> grads -> (compress) -> AdamW, with optional
microbatch gradient accumulation (scan) and activation remat.

The returned step function is pjit-ready: all inputs/outputs carry
NamedShardings derived from the param spec tree, so `.lower().compile()`
against ShapeDtypeStructs is exactly the multi-pod dry-run contract.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import Runtime
from repro.models.model import loss_fn
from repro.optim.adamw import adamw_update, cosine_schedule
from repro.train.compression import compress_decompress_grads


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatches: int = 1          # gradient accumulation factor
    grad_compression: bool = False  # int8 + error feedback
    weights_once: bool = False     # pre-gather FSDP weights once per step
    #                                (dense bf16 copy resident across the
    #                                microbatch loop; trades HBM for 3x
    #                                fewer weight collectives — §Perf)
    b1: float = 0.9
    b2: float = 0.95


def make_train_step(cfg: ArchConfig, rt: Runtime, tc: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt" {m, v, step}, ["err"]} — all sharded.
    batch leaves have leading dim global_batch (or
    (microbatches, global_batch/microbatches) when accumulating).
    """
    schedule = cosine_schedule(tc.lr, tc.warmup_steps, tc.total_steps)

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, rt), has_aux=True
        )(params)
        return grads, metrics

    def _constrain_mb(mb):
        """Pin each sliced microbatch to the dp sharding — without this,
        GSPMD reshards the scan xs so every device processes the *full*
        per-device batch each iteration (measured: flops x microbatches)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        dp = rt.dp_axes
        dp = dp if len(dp) > 1 else dp[0]
        return jax.tree.map(
            lambda a: jax.lax.with_sharding_constraint(
                a, NamedSharding(rt.mesh, P(dp, *([None] * (a.ndim - 1))))
            ),
            mb,
        )

    def _pregather(params):
        """Replicate the FSDP ('embed') dim of the *forward* weight copy so
        the per-microbatch all-gathers hoist out of the accumulation loop
        (the stored params + moments stay ZeRO-sharded; grads reshard back
        through the constraint's transpose)."""
        from dataclasses import replace as _replace

        from repro.dist.sharding import spec_shardings
        from repro.models.params import param_specs

        rt2 = _replace(rt, rules={**rt.rules, "embed": None})
        shardings = spec_shardings(param_specs(cfg), rt2)
        return jax.tree.map(
            lambda p, s: jax.lax.with_sharding_constraint(p, s),
            params, shardings,
        )

    def train_step(state, batch):
        params = state["params"]
        if tc.microbatches > 1 and tc.weights_once:
            params = _pregather(params)
        if tc.microbatches > 1:
            def acc_body(carry, mb):
                g_acc, _ = carry
                g, metrics = compute_grads(params, _constrain_mb(mb))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, metrics), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            # metrics carry must match the model's metric structure exactly
            # (e.g. MTP archs emit extra entries)
            mb0 = jax.tree.map(lambda a: a[0], batch)
            _, m_shape = jax.eval_shape(compute_grads, params, mb0)
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_shape)
            (grads, metrics), _ = jax.lax.scan(acc_body, (g0, m0), batch)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
        else:
            grads, metrics = compute_grads(params, batch)

        if tc.grad_compression:
            grads, new_err = compress_decompress_grads(grads, state["err"])
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], schedule,
            b1=tc.b1, b2=tc.b2,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip,
        )
        new_state = {"params": new_params, "opt": new_opt}
        if tc.grad_compression:
            new_state["err"] = new_err
        metrics = {**metrics, **opt_metrics}
        return new_state, metrics

    return train_step


def init_train_state(cfg: ArchConfig, rt: Runtime, tc: TrainConfig, key):
    from repro.models.model import init_params
    from repro.optim.adamw import adamw_init
    from repro.train.compression import compression_init

    params = init_params(cfg, key)
    state = {"params": params, "opt": adamw_init(params)}
    if tc.grad_compression:
        state["err"] = compression_init(params)
    return state
