"""Straggler mitigation + heartbeat monitoring.

On a real multi-pod deployment these hooks attach to the coordinator:
  * StepWatchdog flags hosts whose step times exceed k x the fleet median
    (persistent stragglers, not transient jitter) and emits a rebalance
    plan that shrinks the slow host's data shard;
  * HeartbeatMonitor watches a progress file and lets the supervisor kill
    and restart a hung process (the checkpoint/restart path then resumes).

The policies are pure functions over observed timings so they are unit-
testable in-container; the supervisor (launch/supervisor.py) wires them to
real processes.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from pathlib import Path


@dataclass
class StepWatchdog:
    """Flags persistent stragglers from per-host step-time streams."""

    threshold: float = 1.5      # x median
    patience: int = 3           # consecutive slow steps before flagging
    history: dict = field(default_factory=dict)   # host -> [durations]
    slow_counts: dict = field(default_factory=dict)

    def observe(self, step_times: dict[int, float]) -> list[int]:
        """step_times: host -> seconds for one step. Returns flagged hosts."""
        times = sorted(step_times.values())
        median = times[len(times) // 2]
        flagged = []
        for host, t in step_times.items():
            self.history.setdefault(host, []).append(t)
            if t > self.threshold * median:
                self.slow_counts[host] = self.slow_counts.get(host, 0) + 1
            else:
                self.slow_counts[host] = 0
            if self.slow_counts[host] >= self.patience:
                flagged.append(host)
        return flagged

    def rebalance_plan(self, hosts: list[int], flagged: list[int],
                       shards_per_host: int) -> dict[int, int]:
        """Shrink flagged hosts' data shards, spreading them to healthy hosts.

        Returns host -> shard_count (total preserved)."""
        plan = {h: shards_per_host for h in hosts}
        healthy = [h for h in hosts if h not in flagged]
        if not healthy:
            return plan
        moved = 0
        for h in flagged:
            give = max(shards_per_host // 2, 1)
            plan[h] -= give
            moved += give
        for i in range(moved):
            plan[healthy[i % len(healthy)]] += 1
        assert sum(plan.values()) == shards_per_host * len(hosts)
        return plan


@dataclass
class HeartbeatMonitor:
    """Progress-file watchdog: stalls longer than `timeout_s` are hangs."""

    path: str
    timeout_s: float = 300.0

    def beat(self, step: int, metrics: dict | None = None):
        payload = {"step": step, "time": time.time(), **(metrics or {})}
        Path(self.path).parent.mkdir(parents=True, exist_ok=True)
        tmp = f"{self.path}.tmp"
        Path(tmp).write_text(json.dumps(payload))
        os.replace(tmp, self.path)

    def is_stalled(self, now: float | None = None) -> bool:
        try:
            payload = json.loads(Path(self.path).read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            return False  # not started yet
        return ((now or time.time()) - payload["time"]) > self.timeout_s

    def last_step(self) -> int | None:
        try:
            return json.loads(Path(self.path).read_text())["step"]
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            return None
