from repro.train.step import (  # noqa: F401
    TrainConfig,
    make_train_step,
)
from repro.train.compression import (  # noqa: F401
    compress_decompress_grads,
    compression_init,
)
