"""Sharded, fault-tolerant checkpointing (no external deps).

Layout (one directory per step):
  <dir>/step_000123/
    manifest.json          — tree structure, global shapes/dtypes, mesh shape
    host_<p>_shard_<i>.npz — this host's addressable shards, keyed by flat path

Properties:
  * atomic commit: write to step_XXXX.tmp, fsync, rename — a crash mid-write
    never corrupts the latest checkpoint;
  * elastic restore: the manifest stores *global* array metadata, each shard
    records its index-window, so restore can re-assemble onto a different
    mesh (resharding happens through jax.make_array_from_callback);
  * async: AsyncCheckpointer snapshots device arrays to host (blocking only
    for the device->host copy) and writes in a background thread.

At multi-host scale each process writes only its addressable shards; this
container is single-process, which is the degenerate case of the same code
path.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
from pathlib import Path

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_into(skeleton, values: dict):
    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        if isinstance(node, tuple):
            return tuple(walk(v, f"{prefix}/{i}") for i, v in enumerate(node))
        return values[prefix]

    return walk(skeleton, "")


def save_checkpoint(directory, step: int, tree, *, _blocking: bool = True):
    """Write `tree` (pytree of jax arrays) as step_<step>. Returns the path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "arrays": {}, "format": 1}
    shard_payload: dict[str, np.ndarray] = {}
    shard_meta: dict[str, dict] = {}

    def _encode(a: np.ndarray) -> np.ndarray:
        # npz silently degrades ml_dtypes (bf16 -> void); store the bit
        # pattern as uint16 and record the logical dtype in the manifest
        if a.dtype == jax.numpy.bfloat16:
            return a.view(np.uint16)
        return a

    for path, arr in _flatten(tree):
        arr = jax.numpy.asarray(arr) if np.isscalar(arr) else arr
        manifest["arrays"][path] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
        if hasattr(arr, "addressable_shards"):
            shards = [
                (np.asarray(s.data),
                 [[sl.start or 0, sl.stop if sl.stop is not None else dim]
                  for sl, dim in zip(s.index, arr.shape)] if arr.ndim else [])
                for s in arr.addressable_shards
            ]
        else:  # host snapshot (AsyncCheckpointer) or plain numpy
            a = np.asarray(arr)
            shards = [(a, [[0, d] for d in a.shape])]
        for i, (data, index) in enumerate(shards):
            key = f"{path}::{i}"
            shard_payload[key] = _encode(data)
            shard_meta[key] = {"index": index}
    manifest["shards"] = shard_meta
    pid = jax.process_index()
    np.savez(tmp / f"host_{pid}_shards.npz", **shard_payload)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    with open(tmp / "manifest.json", "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def _list_steps(directory) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    return sorted(
        int(p.name.split("_")[1])
        for p in directory.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    )


def latest_step(directory) -> int | None:
    steps = _list_steps(directory)
    return steps[-1] if steps else None


def _read_manifest(src: Path) -> dict:
    """Load + structurally validate one step's manifest. Raises ValueError
    on anything a crash could have left behind (missing file, truncated
    JSON, wrong structure)."""
    try:
        manifest = json.loads((src / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ValueError(f"{src}: unreadable manifest ({e})") from e
    if not isinstance(manifest, dict) or "arrays" not in manifest \
            or "shards" not in manifest:
        raise ValueError(f"{src}: manifest is not a checkpoint manifest")
    return manifest


def restore_checkpoint(directory, skeleton, shardings, step: int | None = None):
    """Restore onto `shardings` (which may target a *different* mesh than the
    checkpoint was written from — elastic restart).

    With step=None, the newest *durable* step wins: a directory whose
    manifest is missing or invalid (a crash landed between partial file
    writes and the atomic rename being observed, or post-crash corruption)
    is skipped with a warning and restore falls back to the previous step,
    instead of trusting the newest name blindly. An explicitly requested
    step is never second-guessed — corruption there raises.
    """
    directory = Path(directory)
    if step is None:
        candidates = _list_steps(directory)
        if not candidates:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        manifest = None
        for cand in reversed(candidates):
            try:
                manifest = _read_manifest(directory / f"step_{cand:08d}")
                step = cand
                break
            except ValueError as e:
                warnings.warn(f"skipping non-durable checkpoint: {e}",
                              stacklevel=2)
        if manifest is None:
            raise FileNotFoundError(
                f"no durable checkpoint under {directory}: every step_* "
                f"directory has a missing/invalid manifest")
        src = directory / f"step_{step:08d}"
    else:
        src = directory / f"step_{step:08d}"
        manifest = json.loads((src / "manifest.json").read_text())
    payloads = {}
    for f in src.glob("host_*_shards.npz"):
        payloads[f.name] = np.load(f)

    flat_shardings = dict(_flatten(shardings))
    values = {}
    # pre-index shard keys by path (avoids O(paths x keys) scans)
    by_path: dict[str, list[tuple[str, object]]] = {}
    for npz in payloads.values():
        for key in npz.files:
            p, _, _ = key.rpartition("::")
            by_path.setdefault(p, []).append((key, npz))
    for path, meta in manifest["arrays"].items():
        shape = tuple(meta["shape"])
        is_bf16 = meta["dtype"] == "bfloat16"
        dtype = jax.numpy.bfloat16 if is_bf16 else np.dtype(meta["dtype"])
        full = np.zeros(shape, dtype=np.float32 if is_bf16 else dtype)
        for key, npz in by_path.get(path, ()):
            window = manifest["shards"][key]["index"]
            sl = tuple(slice(a, b) for a, b in window)
            data = npz[key]
            if is_bf16:
                data = data.view(np.uint16).view(jax.numpy.bfloat16)
            full[sl] = data.astype(full.dtype)
        sharding = flat_shardings[path]
        arr = jax.device_put(full.astype(dtype), sharding)
        values[path] = arr
    return _unflatten_into(skeleton, values), step


class AsyncCheckpointer:
    """Background-thread checkpoint writer with snapshot-to-host semantics."""

    def __init__(self, directory):
        self.directory = Path(directory)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree):
        self.wait()
        # snapshot to host memory synchronously (cheap vs. serialization)
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        # non-daemon: interpreter shutdown (including SystemExit from fault
        # injection) joins the writer, so an in-flight checkpoint commits
        # instead of being torn down mid-write and losing the step
        self._thread = threading.Thread(target=work, daemon=False)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
