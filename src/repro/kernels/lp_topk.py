"""Fused Lp-distance + running-top-k Pallas kernel (beyond-paper).

The verification step (Algorithm 1) computes candidate distances and then
selects the best K. Done separately, the (B, C) distance matrix makes a
round trip through HBM. This kernel fuses both: the grid walks candidate
tiles left-to-right while a VMEM scratch carries each query's running
top-k (distances + indices), merged per tile with a bitonic-free
sort-of-concatenation (jax.lax.sort inside the kernel). Only (B, K) leaves
the kernel.

TPU mapping: the distance tile rides the same MXU/VPU paths as
lp_distance.py; the merge is a small VPU sort over (K + TC) keys per query
row. For K = 50 and TC = 256 the merge is <3% of tile FLOPs.

Validated against ref_topk (pure jnp: rowwise_lp + lax.top_k) in interpret
mode across shapes/dtypes/p (tests/test_kernels_topk.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lp_distance import _abs_pow, _root


def _fused_kernel(q_ref, c_ref, out_d_ref, out_i_ref, accd_ref, acci_ref,
                  *, p: float, k: int, root: bool, n_tiles: int):
    """Grid: (B, C/TC). Scratch accd/acci carry the running top-k per query."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        accd_ref[...] = jnp.full_like(accd_ref, jnp.inf)
        acci_ref[...] = jnp.full_like(acci_ref, -1)

    qv = q_ref[0, :].astype(jnp.float32)          # (d,)
    c = c_ref[0, :, :].astype(jnp.float32)        # (TC, d)
    tc = c.shape[0]
    d = jnp.sum(_abs_pow(c - qv[None, :], p), axis=-1)  # (TC,)
    idx = (j * tc + jnp.arange(tc)).astype(jnp.int32)

    merged_d = jnp.concatenate([accd_ref[...], d])
    merged_i = jnp.concatenate([acci_ref[...], idx])
    sd, si = jax.lax.sort((merged_d, merged_i), num_keys=1)
    accd_ref[...] = sd[:k]
    acci_ref[...] = si[:k]

    @pl.when(j == n_tiles - 1)
    def _finish():
        out_d_ref[0, :] = (_root(accd_ref[...], p) if root
                           else accd_ref[...]).astype(out_d_ref.dtype)
        out_i_ref[0, :] = acci_ref[...]


def pallas_lp_topk(
    q: jax.Array,   # (B, d)
    c: jax.Array,   # (B, C, d) per-query candidate blocks
    p: float,
    k: int,
    *,
    root: bool = True,
    block_c: int = 256,
    interpret: bool | None = None,
):
    """Fused top-k candidate verification: returns (dists (B,k), ids (B,k)).

    ids index into each query's candidate block (0..C-1); C is padded up to
    a tile multiple internally (padding distances are +inf)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, d = q.shape
    _, cc, _ = c.shape
    assert k <= cc, (k, cc)
    block_c = min(block_c, max(((cc + 127) // 128) * 128, 128))
    pad_c = (cc + block_c - 1) // block_c * block_c
    if pad_c != cc:
        # pad with +inf-distance sentinels (vector of +inf works for all p)
        filler = jnp.full((b, pad_c - cc, d), 1e30, dtype=c.dtype)
        c = jnp.concatenate([c, filler], axis=1)
    n_tiles = pad_c // block_c

    kernel = functools.partial(
        _fused_kernel, p=p, k=k, root=root, n_tiles=n_tiles
    )
    out_d, out_i = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((1, block_c, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((k,), jnp.float32),
            pltpu.VMEM((k,), jnp.int32),
        ],
        interpret=interpret,
    )(q, c)
    return out_d, out_i


def ref_lp_topk(q, c, p: float, k: int, root: bool = True):
    """Pure-jnp oracle: rowwise distances + top-k (ascending)."""
    from repro.core.metrics import rowwise_lp

    d = rowwise_lp(q, c, p, root=root)
    neg_d, idx = jax.lax.top_k(-d, k)
    return -neg_d, idx.astype(jnp.int32)
