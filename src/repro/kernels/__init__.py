"""Pallas TPU kernels for the paper's compute hot-spot: Q2D Lp distance.

The paper optimizes Lp distance computation with AVX-512 SIMD (its §2.1 /
Fig. 1). On TPU the same hot-spot maps to VMEM-tiled Pallas kernels:

  lp_distance.py — pairwise (B,d)x(N,d)->(B,N) and rowwise (B,d)x(B,C,d)->(B,C)
                   distance kernels with per-p-family inner loops
                   (L2 rides the MXU; L1/L0.5/L1.5 ride the VPU fast path;
                   general p pays exp/log transcendentals), plus the fused
                   gather+distance kernel ids (B,C) + X (n,d) -> (B,C) used
                   by the verification hot path, plus the early-abandoning
                   blocked-dimension variant (DESIGN.md §8) that skips the
                   transcendental work of candidates already beaten by the
                   running k-th best.
  ops.py         — jit'd dispatching wrappers with VMEM-aware tile selection;
                   `lp_gather_distance` is the single backend-aware entry
                   point for exact-Lp candidate scoring in query code, and
                   `lp_gather_abandon` its adaptive-T_p sibling.
  ref.py         — pure-jnp oracles (re-exported from repro.core.metrics,
                   plus the blocked abandon oracle).
"""

from repro.kernels.ops import (  # noqa: F401
    lp_gather_abandon,
    lp_gather_distance,
    pallas_pairwise_lp,
    pallas_rowwise_lp,
)
