"""Jit'd dispatching wrappers over the Pallas Lp distance kernels.

Responsibilities:
  * VMEM-aware tile-size selection (the BlockSpec working set must fit VMEM);
  * padding arbitrary (B, N, C) up to tile multiples and slicing the result;
  * interpret-mode fallback on non-TPU backends (this container is CPU-only,
    so tests/benches run the kernel bodies in interpret mode; on a real TPU
    the same code lowers to Mosaic);
  * `lp_gather_distance` — the single entry point for exact-Lp candidate
    scoring in the query path (verify_candidates, delta scans). On TPU it
    runs the fused gather+distance kernel (rows gathered tile-by-tile in
    VMEM, no (B, C, d) HBM intermediate); off-TPU it falls back to the
    plain jnp reference, which XLA:CPU handles better than an interpreted
    per-row DMA loop;
  * scalar-vs-vector p (DESIGN.md §6): every wrapper takes p as a Python
    float (compile-time per-p specialization) or a (B,) array (one traced
    program serves a mixed-p batch, each row bit-identical to its scalar
    specialization). Scalar p stays on the original static-argname jits,
    so existing per-p callers compile exactly as before.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lp_ops import is_static_p, lp_root
from repro.core.metrics import rowwise_lp
from repro.kernels import lp_distance as _k

# VMEM budget we allow a single kernel instance to claim (bytes). v5e has
# ~16 MiB per core; leave room for double-buffering of input tiles.
_VMEM_BUDGET = 6 * 1024 * 1024
_LANE = 128  # TPU lane width: last-dim tiles should be multiples of this


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_tiles_pairwise(b: int, n: int, d: int) -> tuple[int, int]:
    """Choose (TB, TN). Working set ~ 4*(TB*d + 2*TN*d + TB*TN) bytes."""
    # Start from the preferred MXU-aligned tiles and shrink TN for large d.
    tb = min(128, _round_up(b, 8))
    tn = 512
    while tn > _LANE and 4 * (tb * d + 2 * tn * d + tb * tn) > _VMEM_BUDGET:
        tn //= 2
    while tb > 8 and 4 * (tb * d + 2 * tn * d + tb * tn) > _VMEM_BUDGET:
        tb //= 2
    return max(tb, 8), max(tn, _LANE)


def _pick_tiles_rowwise(b: int, c: int, d: int) -> tuple[int, int]:
    """Choose (TB, TC). Working set ~ 4*(TB*d + 2*TB*TC*d) bytes."""
    tb = min(8, _round_up(b, 1))
    tc = min(512, _round_up(c, _LANE))
    while tc > _LANE and 4 * (tb * d + 2 * tb * tc * d) > _VMEM_BUDGET:
        tc //= 2
    while tb > 1 and 4 * (tb * d + 2 * tb * tc * d) > _VMEM_BUDGET:
        tb //= 2
    return max(tb, 1), max(tc, _LANE)


def _pad_axis(a: jax.Array, axis: int, to: int, fill: float) -> jax.Array:
    pad = to - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=fill)


def _pad_p_col(p: jax.Array, to: int) -> jax.Array:
    """(B,) per-row p -> pre-padded (to, 1) f32 kernel operand.

    Padding rows get p=1.0 — the cheapest family; their outputs are sliced
    off, so any valid p would do.
    """
    p = jnp.asarray(p, dtype=jnp.float32).reshape(-1)
    return _pad_axis(p, 0, to, 1.0)[:, None]


@functools.partial(
    jax.jit, static_argnames=("p", "root", "interpret", "block_b", "block_n")
)
def _pallas_pairwise_lp_s(
    q: jax.Array,
    x: jax.Array,
    p: float,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    b, d = q.shape
    n, _ = x.shape
    tb, tn = _pick_tiles_pairwise(b, n, d)
    if block_b is not None:
        tb = block_b
    if block_n is not None:
        tn = block_n
    bp, np_ = _round_up(b, tb), _round_up(n, tn)
    qp = _pad_axis(q, 0, bp, 0.0)
    xp = _pad_axis(x, 0, np_, 0.0)
    # root applied *outside* the kernel (like the gather entry point): the
    # in-kernel static-p root const-folds its division while a traced-p
    # kernel divides at runtime — rooting on the (B, N) result with the
    # barriered lp_root keeps static-p and vector-p wrappers bit-consistent.
    out = _k.pairwise_lp_kernel_call(
        qp, xp, p, root=False, block_b=tb, block_n=tn, interpret=interpret
    )[:b, :n]
    return lp_root(out, p) if root else out


@functools.partial(
    jax.jit, static_argnames=("root", "interpret", "block_b", "block_n")
)
def _pallas_pairwise_lp_v(
    q: jax.Array,
    x: jax.Array,
    p: jax.Array,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    b, d = q.shape
    p = jnp.broadcast_to(p, (b,))  # (1,) = "one p for every row"
    n, _ = x.shape
    tb, tn = _pick_tiles_pairwise(b, n, d)
    if block_b is not None:
        tb = block_b
    if block_n is not None:
        tn = block_n
    bp, np_ = _round_up(b, tb), _round_up(n, tn)
    qp = _pad_axis(q, 0, bp, 0.0)
    xp = _pad_axis(x, 0, np_, 0.0)
    out = _k.pairwise_lp_kernel_call(
        qp, xp, _pad_p_col(p, bp), root=False, block_b=tb, block_n=tn,
        interpret=interpret,
    )[:b, :n]
    return lp_root(out, p[:, None]) if root else out


def pallas_pairwise_lp(
    q: jax.Array,
    x: jax.Array,
    p,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Pairwise Lp distances (B, d) x (N, d) -> (B, N) via the Pallas kernel.

    p: Python float (per-p compiled program) or a (B,) array scoring each
    query row under its own metric (one compiled program for any p mix —
    DESIGN.md §6).
    """
    if is_static_p(p):
        return _pallas_pairwise_lp_s(q, x, float(p), root, interpret,
                                     block_b, block_n)
    return _pallas_pairwise_lp_v(q, x, jnp.atleast_1d(
        jnp.asarray(p, jnp.float32)), root, interpret, block_b, block_n)


@functools.partial(
    jax.jit, static_argnames=("p", "root", "interpret", "block_b", "block_c")
)
def _pallas_rowwise_lp_s(
    q: jax.Array,
    c: jax.Array,
    p: float,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    b, d = q.shape
    _, cc, _ = c.shape
    tb, tc = _pick_tiles_rowwise(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    cpad = _pad_axis(_pad_axis(c, 1, cp, 0.0), 0, bp, 0.0)
    # root outside the kernel — see _pallas_pairwise_lp_s for why
    out = _k.rowwise_lp_kernel_call(
        qp, cpad, p, root=False, block_b=tb, block_c=tc, interpret=interpret
    )[:b, :cc]
    return lp_root(out, p) if root else out


@functools.partial(
    jax.jit, static_argnames=("root", "interpret", "block_b", "block_c")
)
def _pallas_rowwise_lp_v(
    q: jax.Array,
    c: jax.Array,
    p: jax.Array,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    b, d = q.shape
    p = jnp.broadcast_to(p, (b,))  # (1,) = "one p for every row"
    _, cc, _ = c.shape
    tb, tc = _pick_tiles_rowwise(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    cpad = _pad_axis(_pad_axis(c, 1, cp, 0.0), 0, bp, 0.0)
    out = _k.rowwise_lp_kernel_call(
        qp, cpad, _pad_p_col(p, bp), root=False, block_b=tb, block_c=tc,
        interpret=interpret,
    )[:b, :cc]
    return lp_root(out, p[:, None]) if root else out


def pallas_rowwise_lp(
    q: jax.Array,
    c: jax.Array,
    p,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    """Rowwise Lp distances (B, d) x (B, C, d) -> (B, C) via the Pallas kernel.

    p: Python float (per-p compiled program) or a (B,) array scoring each
    query row under its own metric (one compiled program for any p mix —
    DESIGN.md §6).
    """
    if is_static_p(p):
        return _pallas_rowwise_lp_s(q, c, float(p), root, interpret,
                                    block_b, block_c)
    return _pallas_rowwise_lp_v(q, c, jnp.atleast_1d(
        jnp.asarray(p, jnp.float32)), root, interpret, block_b, block_c)


def lp_pairwise_distance(
    q: jax.Array,    # (B, d) f32
    x: jax.Array,    # (N, d) f32
    p,
    root: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Backend-aware pairwise Lp dispatch -> (B, N) f32.

    The all-pairs sibling of `lp_gather_distance` (same dispatch contract):
    on TPU the tiled Pallas pairwise kernel, off-TPU the jnp reference —
    which XLA:CPU compiles far better than an interpreted kernel body. Used
    by the bulk graph builder's chunked scoring passes (DESIGN.md §7);
    `interpret=True` forces the kernel in interpret mode for parity tests.

    p follows the scalar-vs-vector contract (DESIGN.md §6): a Python float
    or a (B,) array scoring each query row under its own metric.
    """
    if interpret is None and not _on_tpu():
        from repro.core.metrics import pairwise_lp

        return pairwise_lp(q, x, p, root=root)
    return pallas_pairwise_lp(q, x, p, root=root, interpret=interpret)


def _pick_tiles_gather(b: int, c: int, d: int) -> tuple[int, int]:
    """Choose (TB, TC) for the gather kernel.

    VMEM working set ~ 4*(TB*d + TB*TC + TC*d + TB*TC) bytes: the q tile,
    the ids tile, the (TC, d) gathered-row scratch, and the out tile — X
    itself stays in HBM, so d no longer multiplies TC*TB. TB stays a
    multiple of the 8-wide sublane (like the other pickers) so the tile
    refs lower cleanly on TPU.
    """
    tb = min(8, _round_up(b, 8))
    # tc is a power-of-two multiple of _LANE (128/256/512) so the halving
    # below can never leave the lane-aligned grid (e.g. 384 -> 192 would)
    tc = _LANE
    while tc < min(512, c):
        tc *= 2
    while tc > _LANE and 4 * (tb * d + tc * d + 2 * tb * tc) > _VMEM_BUDGET:
        tc //= 2
    return max(tb, 8), max(tc, _LANE)


@functools.partial(
    jax.jit, static_argnames=("p", "root", "interpret", "block_b", "block_c")
)
def _lp_gather_distance_s(
    q: jax.Array,
    ids: jax.Array,
    x: jax.Array,
    p: float,
    root: bool = False,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    n = x.shape[0]
    if ids.ndim == 1:
        valid = (ids >= 0) & (ids < n)
        xs = x[jnp.clip(ids, 0, n - 1)]  # gathered once, shared by all rows
        d = pallas_pairwise_lp(q, xs, p, root=False, interpret=interpret)
        d = jnp.where(valid[None, :], d, jnp.inf)
        return lp_root(d, p) if root else d
    if interpret is None and not _on_tpu():
        valid = (ids >= 0) & (ids < n)
        d = rowwise_lp(q, x[jnp.clip(ids, 0, n - 1)], p, root=False)
        d = jnp.where(valid, d, jnp.inf)
        return lp_root(d, p) if root else d
    if interpret is None:
        interpret = False
    b, d = q.shape
    _, cc = ids.shape
    tb, tc = _pick_tiles_gather(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    # pad ids with -1 (sentinel) so padded slots score inf, not garbage
    ip = jnp.pad(
        ids.astype(jnp.int32),
        ((0, bp - b), (0, cp - cc)),
        constant_values=-1,
    )
    # apply the root *outside* the kernel on the (B, C) result: for root=True
    # callers this keeps the kernel body identical across root modes.
    out = _k.gather_lp_kernel_call(
        ip, qp, x, p, root=False, block_b=tb, block_c=tc, interpret=interpret
    )[:b, :cc]
    return lp_root(out, p) if root else out


@functools.partial(
    jax.jit, static_argnames=("root", "interpret", "block_b", "block_c")
)
def _lp_gather_distance_v(
    q: jax.Array,
    ids: jax.Array,
    x: jax.Array,
    p: jax.Array,    # (B,) per-query metric
    root: bool = False,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    n = x.shape[0]
    p = jnp.broadcast_to(p, (q.shape[0],))  # (1,) = "one p for every row"
    if ids.ndim == 1:
        valid = (ids >= 0) & (ids < n)
        xs = x[jnp.clip(ids, 0, n - 1)]  # gathered once, shared by all rows
        d = pallas_pairwise_lp(q, xs, p, root=False, interpret=interpret)
        d = jnp.where(valid[None, :], d, jnp.inf)
        return lp_root(d, p[:, None]) if root else d
    if interpret is None and not _on_tpu():
        valid = (ids >= 0) & (ids < n)
        d = rowwise_lp(q, x[jnp.clip(ids, 0, n - 1)], p, root=False)
        d = jnp.where(valid, d, jnp.inf)
        return lp_root(d, p[:, None]) if root else d
    if interpret is None:
        interpret = False
    b, d = q.shape
    _, cc = ids.shape
    tb, tc = _pick_tiles_gather(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    ip = jnp.pad(
        ids.astype(jnp.int32),
        ((0, bp - b), (0, cp - cc)),
        constant_values=-1,
    )
    out = _k.gather_lp_kernel_call(
        ip, qp, x, _pad_p_col(p, bp), root=False, block_b=tb, block_c=tc,
        interpret=interpret,
    )[:b, :cc]
    return lp_root(out, p[:, None]) if root else out


def pick_abandon_block_d(d: int) -> int:
    """Dimension-block width for the early-abandoning scan (DESIGN.md §8).

    32 dims = 4 native (8, 128) f32 vregs per block in the transposed
    (d, TC) layout — enough compute per block to amortize the per-block
    alive-mask branch, fine enough that a junk candidate dies after a
    small fraction of d. Falls back to 16/8 (sublane granularity floor)
    when they divide d, else a single full-width block: entry-bound-only
    abandonment, zero mid-scan checks.
    """
    for bd in (32, 16, 8):
        if d % bd == 0:
            return bd
    return d


def _pick_tiles_abandon(b: int, c: int, d: int) -> tuple[int, int]:
    """Choose (TB, TC) for the abandon kernel.

    Like `_pick_tiles_gather` plus the transposed (d, TC) diff tile the
    blocked scan keeps live: ~ 4*(TB*d + 2*TC*d + 3*TB*TC) bytes.
    """
    tb = min(8, _round_up(b, 8))
    tc = _LANE
    while tc < min(512, c):
        tc *= 2
    while tc > _LANE and 4 * (tb * d + 2 * tc * d + 3 * tb * tc) > _VMEM_BUDGET:
        tc //= 2
    return max(tb, 8), max(tc, _LANE)


@functools.partial(
    jax.jit,
    static_argnames=("p", "base_p", "root", "interpret", "block_b",
                     "block_c", "block_d"),
)
def _lp_gather_abandon_s(
    q: jax.Array,
    ids: jax.Array,
    x: jax.Array,
    thresh: jax.Array,
    sb: jax.Array,
    p: float,
    base_p: float,
    root: bool = False,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
    block_d: int | None = None,
):
    b, d = q.shape
    bd = block_d or pick_abandon_block_d(d)
    if interpret is None and not _on_tpu():
        from repro.kernels.ref import gather_lp_abandon_ref

        out, nd = gather_lp_abandon_ref(q, ids, x, thresh, sb, p, base_p, bd)
        return (lp_root(out, p) if root else out), nd
    if interpret is None:
        interpret = False
    _, cc = ids.shape
    tb, tc = _pick_tiles_abandon(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    ip = jnp.pad(ids.astype(jnp.int32), ((0, bp - b), (0, cp - cc)),
                 constant_values=-1)
    # padding rows get threshold -inf: every candidate dies at entry, so
    # the kernel skips their DMA gathers entirely
    tp = _pad_axis(thresh.astype(jnp.float32), 0, bp, -jnp.inf)[:, None]
    sp = _pad_axis(_pad_axis(sb.astype(jnp.float32), 1, cp, 0.0), 0, bp, 0.0)
    out, nd = _k.gather_lp_abandon_kernel_call(
        ip, qp, tp, sp, x, p, base_p=base_p, block_b=tb, block_c=tc,
        block_d=bd, interpret=interpret,
    )
    out, nd = out[:b, :cc], nd[:b, :cc]
    return (lp_root(out, p) if root else out), nd


@functools.partial(
    jax.jit,
    static_argnames=("base_p", "root", "interpret", "block_b", "block_c",
                     "block_d"),
)
def _lp_gather_abandon_v(
    q: jax.Array,
    ids: jax.Array,
    x: jax.Array,
    thresh: jax.Array,
    sb: jax.Array,
    p: jax.Array,    # (B,) per-query metric
    base_p: float,
    root: bool = False,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
    block_d: int | None = None,
):
    b, d = q.shape
    p = jnp.broadcast_to(p, (b,))  # (1,) = "one p for every row"
    bd = block_d or pick_abandon_block_d(d)
    if interpret is None and not _on_tpu():
        from repro.kernels.ref import gather_lp_abandon_ref

        out, nd = gather_lp_abandon_ref(q, ids, x, thresh, sb, p, base_p, bd)
        return (lp_root(out, p[:, None]) if root else out), nd
    if interpret is None:
        interpret = False
    _, cc = ids.shape
    tb, tc = _pick_tiles_abandon(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    ip = jnp.pad(ids.astype(jnp.int32), ((0, bp - b), (0, cp - cc)),
                 constant_values=-1)
    tp = _pad_axis(thresh.astype(jnp.float32), 0, bp, -jnp.inf)[:, None]
    sp = _pad_axis(_pad_axis(sb.astype(jnp.float32), 1, cp, 0.0), 0, bp, 0.0)
    out, nd = _k.gather_lp_abandon_kernel_call(
        ip, qp, tp, sp, x, _pad_p_col(p, bp), base_p=base_p, block_b=tb,
        block_c=tc, block_d=bd, interpret=interpret,
    )
    out, nd = out[:b, :cc], nd[:b, :cc]
    return (lp_root(out, p[:, None]) if root else out), nd


def lp_gather_abandon(
    q: jax.Array,       # (B, d) f32 queries
    ids: jax.Array,     # (B, C) int32 candidate ids; out-of-range = padding
    x: jax.Array,       # (n, d) f32 dataset
    thresh: jax.Array,  # (B,) per-query abandon bound (power-sum space;
                        # +inf = no abandonment, -inf = skip the whole row)
    sb: jax.Array,      # (B, C) base-metric power sums of the candidates
                        # (the beam's distances), or 0 to disable bounds
    p,
    base_p: float = 1.0,
    root: bool = False,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
    block_d: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Early-abandoning exact-Lp scoring (DESIGN.md §8) -> (dists, nd).

    The adaptive-T_p sibling of `lp_gather_distance`: per-query-row
    thresholds abandon candidates whose blocked partial power sum (or the
    base-distance entry/suffix lower bound, core/lp_ops) already exceeds
    the running k-th best — abandoned and padding candidates score +inf,
    which is exact for top-k purposes because a power sum only grows.
    `nd` (B, C) int32 counts the dimensions actually scanned per candidate
    (0 for entry-abandoned), the numerator of `SearchStats.n_dim_frac`.

    p follows the scalar-vs-vector contract (DESIGN.md §6); base_p (static
    1.0/2.0) names the metric of `sb`. Dispatch matches
    `lp_gather_distance`: fused Pallas kernel on TPU, the blocked jnp
    reference (kernels/ref.py — computes-then-masks, same `nd`
    accounting) off TPU, `interpret=True` for CPU kernel-parity tests.
    """
    if is_static_p(p):
        return _lp_gather_abandon_s(q, ids, x, thresh, sb, float(p),
                                    float(base_p), root, interpret,
                                    block_b, block_c, block_d)
    return _lp_gather_abandon_v(
        q, ids, x, thresh, sb,
        jnp.atleast_1d(jnp.asarray(p, jnp.float32)), float(base_p), root,
        interpret, block_b, block_c, block_d)


def _pick_tiles_screen(b: int, c: int, d: int) -> tuple[int, int]:
    """Choose (TB, TC) for the compressed-band screen kernel.

    Like `_pick_tiles_abandon` but the gathered-rows scratch is int8
    (1 byte/dim) while the dequantized |q - x̂| tile stays f32:
    ~ tc*d + 4*(tb*d + tc*d + 3*tb*tc) bytes.
    """
    tb = min(8, _round_up(b, 8))
    tc = _LANE
    while tc < min(512, c):
        tc *= 2
    while tc > _LANE and \
            tc * d + 4 * (tb * d + tc * d + 3 * tb * tc) > _VMEM_BUDGET:
        tc //= 2
    return max(tb, 8), max(tc, _LANE)


@functools.partial(
    jax.jit,
    static_argnames=("p", "base_p", "interpret", "block_b", "block_c",
                     "block_d"),
)
def _lp_gather_screen_s(
    q: jax.Array,
    ids: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    radius: jax.Array,
    thresh: jax.Array,
    sb: jax.Array,
    p: float,
    base_p: float,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
    block_d: int | None = None,
):
    b, d = q.shape
    bd = block_d or pick_abandon_block_d(d)
    if interpret is None and not _on_tpu():
        from repro.kernels.ref import gather_lp_screen_ref

        return gather_lp_screen_ref(q, ids, codes, scale, radius, thresh,
                                    sb, p, base_p, bd)
    if interpret is None:
        interpret = False
    _, cc = ids.shape
    tb, tc = _pick_tiles_screen(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    ip = jnp.pad(ids.astype(jnp.int32), ((0, bp - b), (0, cp - cc)),
                 constant_values=-1)
    # padding rows get threshold -inf: every candidate dies at entry, so
    # the kernel skips their DMA gathers entirely
    tp = _pad_axis(thresh.astype(jnp.float32), 0, bp, -jnp.inf)[:, None]
    sp = _pad_axis(_pad_axis(sb.astype(jnp.float32), 1, cp, 0.0), 0, bp, 0.0)
    keep, nd = _k.gather_lp_screen_kernel_call(
        ip, qp, tp, sp, scale.reshape(1, d), radius.reshape(1, d), codes,
        p, base_p=base_p, block_b=tb, block_c=tc, block_d=bd,
        interpret=interpret,
    )
    return keep[:b, :cc].astype(bool), nd[:b, :cc]


@functools.partial(
    jax.jit,
    static_argnames=("base_p", "interpret", "block_b", "block_c", "block_d"),
)
def _lp_gather_screen_v(
    q: jax.Array,
    ids: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    radius: jax.Array,
    thresh: jax.Array,
    sb: jax.Array,
    p: jax.Array,    # (B,) per-query metric
    base_p: float,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
    block_d: int | None = None,
):
    b, d = q.shape
    p = jnp.broadcast_to(p, (b,))  # (1,) = "one p for every row"
    bd = block_d or pick_abandon_block_d(d)
    if interpret is None and not _on_tpu():
        from repro.kernels.ref import gather_lp_screen_ref

        return gather_lp_screen_ref(q, ids, codes, scale, radius, thresh,
                                    sb, p, base_p, bd)
    if interpret is None:
        interpret = False
    _, cc = ids.shape
    tb, tc = _pick_tiles_screen(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    ip = jnp.pad(ids.astype(jnp.int32), ((0, bp - b), (0, cp - cc)),
                 constant_values=-1)
    tp = _pad_axis(thresh.astype(jnp.float32), 0, bp, -jnp.inf)[:, None]
    sp = _pad_axis(_pad_axis(sb.astype(jnp.float32), 1, cp, 0.0), 0, bp, 0.0)
    keep, nd = _k.gather_lp_screen_kernel_call(
        ip, qp, tp, sp, scale.reshape(1, d), radius.reshape(1, d), codes,
        _pad_p_col(p, bp), base_p=base_p, block_b=tb, block_c=tc,
        block_d=bd, interpret=interpret,
    )
    return keep[:b, :cc].astype(bool), nd[:b, :cc]


def lp_gather_screen(
    q: jax.Array,       # (B, d) f32 queries, band (permuted) coord order
    ids: jax.Array,     # (B, C) int32 candidate ids; out-of-range = padding
    codes: jax.Array,   # (n, d) int8 compressed band (index/compressed.py)
    scale: jax.Array,   # (d,) f32 per-coordinate dequant scales
    radius: jax.Array,  # (d,) f32 per-coordinate max dequant error
    thresh: jax.Array,  # (B,) per-query screen bound (power-sum space;
                        # +inf = keep everything, -inf = screen out the row)
    sb: jax.Array,      # (B, C) base-metric power sums of the candidates
                        # (the beam's distances), or 0 to disable bounds
    p,
    base_p: float = 1.0,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
    block_d: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Compressed-band candidate screen (DESIGN.md §10) -> (keep, nd).

    The storage-side sibling of `lp_gather_abandon`: per-query thresholds
    kill candidates whose *certified lower bound* — the blocked power sum
    of max(|q_j - x̂_j| - radius_j, 0) over int8 band rows, deflated by
    BOUND_SLACK — already exceeds the running k-th best. `keep` (B, C)
    bool marks the survivors whose f32 rows the exact rerank must gather
    (padding never survives); `nd` (B, C) int32 counts band dimensions
    scanned (the int8 byte-traffic numerator of `SearchStats.n_band_frac`).

    q must be in the band's coordinate order (Q[:, band.perm]). p follows
    the scalar-vs-vector contract (DESIGN.md §6); base_p (static 1.0/2.0)
    names the metric of `sb`. Dispatch matches `lp_gather_abandon`: fused
    Pallas kernel on TPU, the blocked jnp reference (kernels/ref.py) off
    TPU, `interpret=True` for CPU kernel-parity tests.
    """
    if is_static_p(p):
        return _lp_gather_screen_s(q, ids, codes, scale, radius, thresh,
                                   sb, float(p), float(base_p), interpret,
                                   block_b, block_c, block_d)
    return _lp_gather_screen_v(
        q, ids, codes, scale, radius, thresh, sb,
        jnp.atleast_1d(jnp.asarray(p, jnp.float32)), float(base_p),
        interpret, block_b, block_c, block_d)


def lp_gather_distance(
    q: jax.Array,    # (B, d) f32 queries
    ids: jax.Array,  # (B, C) int32 candidate ids; anything outside [0, n) is
                     # padding (-1 from merges, n from beam sentinels)
    x: jax.Array,    # (n, d) f32 dataset
    p,
    root: bool = False,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    """Exact-Lp distances for per-query candidate id blocks -> (B, C) f32.

    THE dispatch entry point for all exact-Lp scoring in the query path
    (DESIGN.md §2 "hot path"). Padding ids score +inf so they can never
    enter a result set.

    `p` — the scalar-vs-vector contract (DESIGN.md §6):

      * Python float — one compiled program per distinct p (the classic
        grouped-serving path);
      * (B,) array (f32) — row i is scored under p[i]; ONE compiled
        program serves any mix of p values, and each row's result is
        bit-identical to the scalar-p call with p = p[i] on the same path
        (the per-row op-sequence selection in core/lp_ops guarantees it).

    `interpret`:

      * None (default) — backend-aware: fused Pallas kernel on TPU, jnp
        reference (gather + rowwise powers) elsewhere;
      * True  — force the Pallas kernel in interpret mode (kernel-parity
        tests on CPU);
      * False — force the compiled Pallas kernel.

    ids may also be 1-D (C,): "every query scores the same candidate
    rows" (the delta-scan shape). That routes to the pairwise kernel on a
    once-gathered (C, d) block — no per-query re-gather, and p=2 keeps
    its MXU matmul — instead of broadcasting the id row B times.
    """
    if is_static_p(p):
        return _lp_gather_distance_s(q, ids, x, float(p), root, interpret,
                                     block_b, block_c)
    return _lp_gather_distance_v(
        q, ids, x, jnp.atleast_1d(jnp.asarray(p, jnp.float32)),
        root, interpret, block_b, block_c)
