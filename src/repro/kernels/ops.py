"""Jit'd dispatching wrappers over the Pallas Lp distance kernels.

Responsibilities:
  * VMEM-aware tile-size selection (the BlockSpec working set must fit VMEM);
  * padding arbitrary (B, N, C) up to tile multiples and slicing the result;
  * interpret-mode fallback on non-TPU backends (this container is CPU-only,
    so tests/benches run the kernel bodies in interpret mode; on a real TPU
    the same code lowers to Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import lp_distance as _k

# VMEM budget we allow a single kernel instance to claim (bytes). v5e has
# ~16 MiB per core; leave room for double-buffering of input tiles.
_VMEM_BUDGET = 6 * 1024 * 1024
_LANE = 128  # TPU lane width: last-dim tiles should be multiples of this


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_tiles_pairwise(b: int, n: int, d: int) -> tuple[int, int]:
    """Choose (TB, TN). Working set ~ 4*(TB*d + 2*TN*d + TB*TN) bytes."""
    # Start from the preferred MXU-aligned tiles and shrink TN for large d.
    tb = min(128, _round_up(b, 8))
    tn = 512
    while tn > _LANE and 4 * (tb * d + 2 * tn * d + tb * tn) > _VMEM_BUDGET:
        tn //= 2
    while tb > 8 and 4 * (tb * d + 2 * tn * d + tb * tn) > _VMEM_BUDGET:
        tb //= 2
    return max(tb, 8), max(tn, _LANE)


def _pick_tiles_rowwise(b: int, c: int, d: int) -> tuple[int, int]:
    """Choose (TB, TC). Working set ~ 4*(TB*d + 2*TB*TC*d) bytes."""
    tb = min(8, _round_up(b, 1))
    tc = min(512, _round_up(c, _LANE))
    while tc > _LANE and 4 * (tb * d + 2 * tb * tc * d) > _VMEM_BUDGET:
        tc //= 2
    while tb > 1 and 4 * (tb * d + 2 * tb * tc * d) > _VMEM_BUDGET:
        tb //= 2
    return max(tb, 1), max(tc, _LANE)


def _pad_axis(a: jax.Array, axis: int, to: int, fill: float) -> jax.Array:
    pad = to - a.shape[axis]
    if pad <= 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=fill)


@functools.partial(
    jax.jit, static_argnames=("p", "root", "interpret", "block_b", "block_n")
)
def pallas_pairwise_lp(
    q: jax.Array,
    x: jax.Array,
    p: float,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_n: int | None = None,
) -> jax.Array:
    """Pairwise Lp distances (B, d) x (N, d) -> (B, N) via the Pallas kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    b, d = q.shape
    n, _ = x.shape
    tb, tn = _pick_tiles_pairwise(b, n, d)
    if block_b is not None:
        tb = block_b
    if block_n is not None:
        tn = block_n
    bp, np_ = _round_up(b, tb), _round_up(n, tn)
    qp = _pad_axis(q, 0, bp, 0.0)
    xp = _pad_axis(x, 0, np_, 0.0)
    out = _k.pairwise_lp_kernel_call(
        qp, xp, p, root=root, block_b=tb, block_n=tn, interpret=interpret
    )
    return out[:b, :n]


@functools.partial(
    jax.jit, static_argnames=("p", "root", "interpret", "block_b", "block_c")
)
def pallas_rowwise_lp(
    q: jax.Array,
    c: jax.Array,
    p: float,
    root: bool = True,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
) -> jax.Array:
    """Rowwise Lp distances (B, d) x (B, C, d) -> (B, C) via the Pallas kernel."""
    if interpret is None:
        interpret = not _on_tpu()
    b, d = q.shape
    _, cc, _ = c.shape
    tb, tc = _pick_tiles_rowwise(b, cc, d)
    if block_b is not None:
        tb = block_b
    if block_c is not None:
        tc = block_c
    bp, cp = _round_up(b, tb), _round_up(cc, tc)
    qp = _pad_axis(q, 0, bp, 0.0)
    cpad = _pad_axis(_pad_axis(c, 1, cp, 0.0), 0, bp, 0.0)
    out = _k.rowwise_lp_kernel_call(
        qp, cpad, p, root=root, block_b=tb, block_c=tc, interpret=interpret
    )
    return out[:b, :cc]
