"""Pure-jnp oracles for the Lp distance kernels.

The single source of truth for Lp semantics is repro.core.metrics; the
kernels must match these to float tolerance across all shapes/dtypes/p.
Like the kernels, the oracles accept p as a Python float or as a (B,)
per-query-row array (the mixed-p contract, DESIGN.md §6) — so every
vector-p kernel has a vector-p oracle with identical semantics.
"""

from repro.core.metrics import (  # noqa: F401
    lp_distance,
    numpy_lp,
    pairwise_lp,
    rowwise_lp,
)

# Aliases matching the kernel entry points one-to-one.
pairwise_lp_ref = pairwise_lp
rowwise_lp_ref = rowwise_lp
