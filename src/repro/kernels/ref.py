"""Pure-jnp oracles for the Lp distance kernels.

The single source of truth for Lp semantics is repro.core.metrics; the
kernels must match these to float tolerance across all shapes/dtypes/p.
Like the kernels, the oracles accept p as a Python float or as a (B,)
per-query-row array (the mixed-p contract, DESIGN.md §6) — so every
vector-p kernel has a vector-p oracle with identical semantics.

`gather_lp_abandon_ref` is additionally the *off-TPU production path* for
the early-abandoning blocked verification (DESIGN.md §8): XLA:CPU cannot
skip masked work, so it computes every block and masks — the scanned-dim
accounting (`nd`) still reports exactly what the TPU kernel would skip.
"""

import jax.numpy as jnp
from jax import lax

from repro.core.lp_ops import (
    BOUND_SLACK,
    is_static_p,
    lp_entry_bound,
    lp_suffix_bound,
    pow_from_abs,
)
from repro.core.metrics import (  # noqa: F401
    lp_distance,
    numpy_lp,
    pairwise_lp,
    rowwise_lp,
)

# Aliases matching the kernel entry points one-to-one.
pairwise_lp_ref = pairwise_lp
rowwise_lp_ref = rowwise_lp


def gather_lp_abandon_ref(
    q: jnp.ndarray,       # (B, d) f32
    ids: jnp.ndarray,     # (B, C) int32; out-of-range = padding
    x: jnp.ndarray,       # (n, d) f32
    thresh: jnp.ndarray,  # (B,) abandon bound, power-sum space
    sb: jnp.ndarray,      # (B, C) base-metric power sums (0 = no bound)
    p,                    # Python float or (B,) f32
    base_p: float,
    block_d: int,
):
    """Blocked early-abandoning oracle for `gather_lp_abandon_kernel_call`.

    Identical scan semantics to the kernel — same block order (a candidate
    that is abandoned mid-scan has exactly the same partial sum on both
    paths), same entry/suffix bounds (shared helpers in core/lp_ops), same
    `(dists, nd)` outputs; abandoned and padding candidates score +inf and
    dims scanned after a candidate dies are not counted. The per-block
    reduction mirrors the kernel's transposed (block_d, TC) axis-0 sum.
    Requires d % block_d == 0 (the dispatcher picks block_d accordingly).
    """
    n, d = x.shape
    assert d % block_d == 0, (d, block_d)
    nb = d // block_d
    valid = (ids >= 0) & (ids < n)
    diff = x[jnp.clip(ids, 0, n - 1)] - q[:, None, :]   # (B, C, d)
    dt = jnp.swapaxes(diff, 1, 2)                       # (B, d, C)
    if is_static_p(p):
        p_blk = p_row = p
    else:
        p_blk = p[:, None, None]
        p_row = p[:, None]
    thr = thresh[:, None]
    lb = lp_entry_bound(sb, base_p, p_row, d)
    alive = valid & (lb <= thr)
    s = jnp.zeros_like(sb)
    sbase = jnp.zeros_like(sb)
    nd = jnp.zeros(sb.shape, jnp.int32)
    for b in range(nb):
        blk = lax.slice_in_dim(dt, b * block_d, (b + 1) * block_d, axis=1)
        a = jnp.abs(blk)
        bs = jnp.sum(pow_from_abs(a, p_blk), axis=1)
        bb = jnp.sum(a if base_p == 1.0 else a * a, axis=1)
        s = jnp.where(alive, s + bs, s)
        sbase = jnp.where(alive, sbase + bb, sbase)
        nd = nd + jnp.where(alive, block_d, 0)
        dead = s > thr
        d_rem = d - (b + 1) * block_d
        if d_rem > 0:
            rem = lp_suffix_bound(sb - sbase, base_p, p_row,
                                  float(d_rem))
            dead = dead | (s + rem > thr)
        alive = alive & ~dead
    return jnp.where(alive, s, jnp.inf), nd


def gather_lp_screen_ref(
    q: jnp.ndarray,       # (B, d) f32 queries, band (permuted) coord order
    ids: jnp.ndarray,     # (B, C) int32; out-of-range = padding
    codes: jnp.ndarray,   # (n, d) int8 compressed band (band coord order)
    scale: jnp.ndarray,   # (d,) f32 per-coordinate dequant scales
    radius: jnp.ndarray,  # (d,) f32 per-coordinate max dequant error
    thresh: jnp.ndarray,  # (B,) screen bound, power-sum space
    sb: jnp.ndarray,      # (B, C) base-metric power sums (0 = no bound)
    p,                    # Python float or (B,) f32
    base_p: float,
    block_d: int,
):
    """Blocked compressed-band screen oracle (DESIGN.md §10) for
    `gather_lp_screen_kernel_call`.

    Accumulates the certified per-coordinate lower bound
    max(|q_j - x̂_j| - radius_j, 0)^p over dimension blocks and kills a
    candidate as soon as the deflated running bound exceeds the per-query
    threshold — such a candidate's *true* f32 power sum provably exceeds
    the running k-th best, so the two-band scan never gathers its f32
    row. Unlike `gather_lp_abandon_ref` the accumulated sum is a float-
    evaluated *bound*, not an exact partial of the true distance, so the
    kill comparison deflates by BOUND_SLACK (the same slack the entry/
    suffix bounds carry); the mid-scan suffix bound uses the remaining
    base mass net of the accumulated per-coordinate *upper* bounds
    (|q_j - x̂_j| + radius_j), keeping the remainder an underestimate.

    Returns (keep (B, C) bool — True iff the candidate survived the
    screen (padding never survives), nd (B, C) int32 band dimensions
    scanned; like the abandon oracle this computes-then-masks off TPU
    while reporting exactly what the TPU kernel would skip).
    """
    n, d = codes.shape
    assert d % block_d == 0, (d, block_d)
    nb = d // block_d
    valid = (ids >= 0) & (ids < n)
    xh = codes[jnp.clip(ids, 0, n - 1)].astype(jnp.float32) \
        * scale[None, None, :]                              # (B, C, d)
    a0 = jnp.abs(xh - q[:, None, :])
    al = jnp.maximum(a0 - radius[None, None, :], 0.0)       # lower bounds
    au = a0 + radius[None, None, :]                         # upper bounds
    alt = jnp.swapaxes(al, 1, 2)                            # (B, d, C)
    aut = jnp.swapaxes(au, 1, 2)
    if is_static_p(p):
        p_blk = p_row = p
    else:
        p_blk = p[:, None, None]
        p_row = p[:, None]
    thr = thresh[:, None]
    lb = lp_entry_bound(sb, base_p, p_row, d)
    alive = valid & (lb <= thr)
    s = jnp.zeros_like(sb)
    sbase = jnp.zeros_like(sb)
    nd = jnp.zeros(sb.shape, jnp.int32)
    deflate = 1.0 - BOUND_SLACK
    for b in range(nb):
        blk = lax.slice_in_dim(alt, b * block_d, (b + 1) * block_d, axis=1)
        ublk = lax.slice_in_dim(aut, b * block_d, (b + 1) * block_d, axis=1)
        bs = jnp.sum(pow_from_abs(blk, p_blk), axis=1)
        bb = jnp.sum(ublk if base_p == 1.0 else ublk * ublk, axis=1)
        s = jnp.where(alive, s + bs, s)
        sbase = jnp.where(alive, sbase + bb, sbase)
        nd = nd + jnp.where(alive, block_d, 0)
        dead = s * deflate > thr
        d_rem = d - (b + 1) * block_d
        if d_rem > 0:
            rem = lp_suffix_bound(sb - sbase, base_p, p_row,
                                  float(d_rem))
            dead = dead | ((s + rem) * deflate > thr)
        alive = alive & ~dead
    return alive, nd
