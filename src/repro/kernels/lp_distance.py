"""Pallas TPU kernels for batched Q2D Lp distance (the paper's hot spot).

Hardware mapping (see DESIGN.md §2):

  * p = 2   — the MXU path. Inside each (TB, TN) output tile we compute
              ||q-x||^2 = ||q||^2 + ||x||^2 - 2 q @ x^T with a single VMEM-
              resident matmul (`jnp.dot` lowers onto the 128x128 systolic
              array). This is the TPU analogue of the paper's AVX-512 L2.
  * p = 1, 0.5, 1.5 — the VPU fast family: abs/add (+sqrt for the fractional
              pair), full-rate elementwise over a (TN, d) diff tile per query
              row, looped over the TB query rows with `lax.fori_loop` so the
              VMEM working set stays one diff-tile wide.
  * other p — the slow family: |d|^p = exp(p * log |d|) costs two
              transcendentals per element; same loop structure.

Tiling: grid is (B/TB, N/TN). Per grid step the kernel holds
  q tile (TB, d) + x tile (TN, d) + one (TN, d) diff scratch + out (TB, TN)
in VMEM; ops.py picks TB/TN so this fits the ~16 MiB v5e VMEM with headroom.
The query tile is reused across the whole row of candidate tiles (index_map
pins it per-i), amortizing its HBM read N/TN times — the VMEM analogue of
the paper keeping the query vector L1-cache-resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The per-p op-sequence table is shared with the jnp reference metrics
# (repro.core.lp_ops) so kernel and oracle cannot drift.
from repro.core.lp_ops import abs_pow as _abs_pow
from repro.core.lp_ops import (
    BOUND_SLACK,
    is_static_p,
    lp_entry_bound,
    lp_suffix_bound,
    pow_from_abs,
)
# Kernel bodies use the fold-friendly root: no optimization_barrier inside
# Mosaic-lowered code (traced per-row p takes runtime division regardless).
from repro.core.lp_ops import lp_root_folded as _root

# Every kernel here takes p either as a Python float (per-p compile-time
# specialization — the classic path) or as a per-query-row array (the
# mixed-p serving path, DESIGN.md §6). Vector p reaches the kernel as a
# pre-padded (B, 1) f32 operand tiled (TB, 1); the body reads one traced
# scalar per query row and the shared op-sequence table's where-select
# reproduces each row's scalar op sequence bit-for-bit (rows with p == 2
# additionally take the same MXU matmul-identity branch the scalar p=2
# kernel uses). All three vector-p kernels share `_row_dist_block` so the
# parity-critical op sequence cannot drift between entry points.


def _row_dist_block(qi: jax.Array, c: jax.Array, pi) -> jax.Array:
    """One query row vs a (TC, d) candidate tile under traced per-row p.

    The elementwise family table scores every p; rows with pi == 2 take
    the MXU matmul-identity value instead (the same expression the scalar
    p=2 kernels emit, including the cancellation clamp).
    """
    s = jnp.sum(_abs_pow(c - qi[None, :], pi), axis=-1)
    s2 = jnp.sum(qi * qi) + jnp.sum(c * c, axis=-1) - 2.0 * jnp.dot(
        c, qi, preferred_element_type=jnp.float32
    )
    return jnp.where(pi == 2.0, jnp.maximum(s2, 0.0), s)


# ---------------------------------------------------------------------------
# pairwise kernel: Q (B, d) x X (N, d) -> (B, N)
# ---------------------------------------------------------------------------


def _pairwise_l2_kernel(q_ref, x_ref, o_ref, *, root: bool):
    """MXU path: one matmul per output tile."""
    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    qq = jnp.sum(q * q, axis=-1)[:, None]
    xx = jnp.sum(x * x, axis=-1)[None, :]
    s = qq + xx - 2.0 * jnp.dot(q, x.T, preferred_element_type=jnp.float32)
    s = jnp.maximum(s, 0.0)
    o_ref[...] = (jnp.sqrt(s) if root else s).astype(o_ref.dtype)


def _pairwise_vpu_kernel(q_ref, x_ref, o_ref, *, p: float, root: bool):
    """VPU path: loop over query rows; one (TN, d) diff tile live at a time."""
    x = x_ref[...].astype(jnp.float32)
    tb = q_ref.shape[0]

    def body(i, _):
        qi = q_ref[i, :].astype(jnp.float32)
        s = jnp.sum(_abs_pow(x - qi[None, :], p), axis=-1)
        o_ref[i, :] = (_root(s, p) if root else s).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, tb, body, 0)


def _pairwise_vec_kernel(p_ref, q_ref, x_ref, o_ref, *, root: bool):
    """Mixed-p path: per-row traced p; p==2 rows take the MXU identity.

    The identity term is hoisted as one (TB, TN) matmul — the same shape
    the scalar `_pairwise_l2_kernel` emits, so p==2 rows are bit-identical
    to the scalar p=2 kernel. (The fast/slow VPU families match the scalar
    VPU kernel's op sequences exactly; XLA's fusion choices can still
    reassociate the d-axis sum by 1-2 ulp on non-lane-aligned tile shapes
    for p=1.5 — pinned with an explicit ulp tolerance in
    tests/test_kernels.py::test_pairwise_vector_p_vs_scalar_ulp_pinned —
    so only the gather/rowwise entry points — the serving hot path — carry
    the hard bit-parity contract.)
    """
    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    qq = jnp.sum(q * q, axis=-1)
    xx = jnp.sum(x * x, axis=-1)
    s2 = qq[:, None] + xx[None, :] - 2.0 * jnp.dot(
        q, x.T, preferred_element_type=jnp.float32
    )
    s2 = jnp.maximum(s2, 0.0)
    tb = q.shape[0]

    def body(i, _):
        pi = p_ref[i, 0]
        qi = q[i, :]
        s = jnp.sum(_abs_pow(x - qi[None, :], pi), axis=-1)
        s = jnp.where(pi == 2.0, s2[i, :], s)
        o_ref[i, :] = (_root(s, pi) if root else s).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, tb, body, 0)


def pairwise_lp_kernel_call(
    q: jax.Array,
    x: jax.Array,
    p,
    *,
    root: bool = True,
    block_b: int = 128,
    block_n: int = 512,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Raw pallas_call for pre-padded inputs (B % block_b == N % block_n == 0).

    p: Python float, or a pre-padded (B, 1) f32 array (one metric per query
    row — the mixed-p contract described in the module preamble).
    """
    b, d = q.shape
    n, _ = x.shape
    assert b % block_b == 0 and n % block_n == 0, (b, n, block_b, block_n)

    if not is_static_p(p):
        assert p.shape == (b, 1), (p.shape, b)
        return pl.pallas_call(
            functools.partial(_pairwise_vec_kernel, root=root),
            grid=(b // block_b, n // block_n),
            in_specs=[
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
                pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((b, n), out_dtype),
            interpret=interpret,
        )(p, q, x)

    if p == 2.0:
        kernel = functools.partial(_pairwise_l2_kernel, root=root)
    else:
        kernel = functools.partial(_pairwise_vpu_kernel, p=p, root=root)

    return pl.pallas_call(
        kernel,
        grid=(b // block_b, n // block_n),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), out_dtype),
        interpret=interpret,
    )(q, x)


# ---------------------------------------------------------------------------
# rowwise kernel: Q (B, d) x C (B, C, d) -> (B, C)
# (the verification-step shape: per-query gathered candidate blocks)
# ---------------------------------------------------------------------------


def _rowwise_l2_kernel(q_ref, c_ref, o_ref, *, root: bool):
    q = q_ref[...].astype(jnp.float32)  # (TB, d)
    tb = q.shape[0]

    def body(i, _):
        c = c_ref[i, :, :].astype(jnp.float32)  # (TC, d)
        qi = q[i, :]
        s = jnp.sum(qi * qi) + jnp.sum(c * c, axis=-1) - 2.0 * jnp.dot(
            c, qi, preferred_element_type=jnp.float32
        )
        s = jnp.maximum(s, 0.0)
        o_ref[i, :] = (jnp.sqrt(s) if root else s).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, tb, body, 0)


def _rowwise_vpu_kernel(q_ref, c_ref, o_ref, *, p: float, root: bool):
    tb = q_ref.shape[0]

    def body(i, _):
        qi = q_ref[i, :].astype(jnp.float32)
        c = c_ref[i, :, :].astype(jnp.float32)
        s = jnp.sum(_abs_pow(c - qi[None, :], p), axis=-1)
        o_ref[i, :] = (_root(s, p) if root else s).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, tb, body, 0)


def _rowwise_vec_kernel(p_ref, q_ref, c_ref, o_ref, *, root: bool):
    """Mixed-p path: per-row traced p; p==2 rows take the MXU identity."""
    tb = q_ref.shape[0]

    def body(i, _):
        pi = p_ref[i, 0]
        qi = q_ref[i, :].astype(jnp.float32)
        c = c_ref[i, :, :].astype(jnp.float32)
        s = _row_dist_block(qi, c, pi)
        o_ref[i, :] = (_root(s, pi) if root else s).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, tb, body, 0)


def rowwise_lp_kernel_call(
    q: jax.Array,
    c: jax.Array,
    p,
    *,
    root: bool = True,
    block_b: int = 8,
    block_c: int = 256,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Raw pallas_call for pre-padded inputs (B % block_b == C % block_c == 0).

    p: Python float, or a pre-padded (B, 1) f32 array (one metric per query
    row — the mixed-p contract described in the module preamble).
    """
    b, d = q.shape
    b2, cc, _ = c.shape
    assert b == b2 and b % block_b == 0 and cc % block_c == 0

    if not is_static_p(p):
        assert p.shape == (b, 1), (p.shape, b)
        return pl.pallas_call(
            functools.partial(_rowwise_vec_kernel, root=root),
            grid=(b // block_b, cc // block_c),
            in_specs=[
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, block_c, d), lambda i, j: (i, j, 0)),
            ],
            out_specs=pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((b, cc), out_dtype),
            interpret=interpret,
        )(p, q, c)

    if p == 2.0:
        kernel = functools.partial(_rowwise_l2_kernel, root=root)
    else:
        kernel = functools.partial(_rowwise_vpu_kernel, p=p, root=root)

    return pl.pallas_call(
        kernel,
        grid=(b // block_b, cc // block_c),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_c, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, cc), out_dtype),
        interpret=interpret,
    )(q, c)


# ---------------------------------------------------------------------------
# fused gather + distance kernel: ids (B, C) + X (n, d) -> dists (B, C)
#
# The verification hot path (core/uhnsw.verify_candidates) scores per-query
# candidate id blocks against the frozen dataset. The un-fused route is
# X[ids] -> (B, C, d) in HBM, then the rowwise kernel — i.e. every gathered
# row makes an HBM round trip before it is read once. Here the gather happens
# *inside* the kernel: X stays HBM-resident (memory_space=ANY), and each
# (TB, TC) output tile DMAs its TC candidate rows one-by-one into a (TC, d)
# VMEM scratch, then runs one vectorized distance block over the scratch
# (MXU dot for p=2, VPU elementwise otherwise). The (B, C, d) intermediate
# never exists.
#
# Ids outside [0, n) are padding sentinels (-1 from merges, n from beams):
# they gather a clamped dummy row and score +inf, so callers can pass padded
# id blocks straight through.
# ---------------------------------------------------------------------------


def _dma_gather_rows(ids_row, x_hbm, gx_ref, sem, n: int, block_c: int):
    """DMA the TC candidate rows of one query into the VMEM scratch.

    DMAs issue sequentially (start/wait per row); a double-buffered variant
    would overlap row j+1's copy with row j's compute, but the VMEM scratch
    already bounds the win to DMA latency. Shared by the scalar and
    vector-p gather kernels.
    """

    def gather(j, _):
        safe = jnp.clip(ids_row[j], 0, n - 1)
        cp = pltpu.make_async_copy(
            x_hbm.at[pl.ds(safe, 1), :], gx_ref.at[pl.ds(j, 1), :], sem
        )
        cp.start()
        cp.wait()
        return 0

    jax.lax.fori_loop(0, block_c, gather, 0)


def _gather_lp_kernel(ids_ref, q_ref, x_hbm, o_ref, gx_ref, sem,
                      *, p: float, root: bool, n: int, block_c: int):
    """One (TB, TC) output tile.

    Per query row: TC row DMAs (HBM -> VMEM scratch), then one vectorized
    (TC, d) distance block.
    """
    tb = q_ref.shape[0]

    def per_query(i, _):
        ids_row = ids_ref[i, :]  # (TC,)
        _dma_gather_rows(ids_row, x_hbm, gx_ref, sem, n, block_c)
        qi = q_ref[i, :].astype(jnp.float32)
        ct = gx_ref[...].astype(jnp.float32)  # (TC, d)
        if p == 2.0:
            s = jnp.sum(qi * qi) + jnp.sum(ct * ct, axis=-1) - 2.0 * jnp.dot(
                ct, qi, preferred_element_type=jnp.float32
            )
            s = jnp.maximum(s, 0.0)
        else:
            s = jnp.sum(_abs_pow(ct - qi[None, :], p), axis=-1)
        val = _root(s, p) if root else s
        ok = (ids_row >= 0) & (ids_row < n)
        o_ref[i, :] = jnp.where(ok, val, jnp.inf).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, tb, per_query, 0)


def _gather_lp_vec_kernel(ids_ref, q_ref, p_ref, x_hbm, o_ref, gx_ref, sem,
                          *, root: bool, n: int, block_c: int):
    """Mixed-p variant of `_gather_lp_kernel`: same per-row DMA gather, with
    each query row scored under its own traced p (p==2 rows take the same
    MXU-identity branch the scalar p=2 kernel emits)."""
    tb = q_ref.shape[0]

    def per_query(i, _):
        ids_row = ids_ref[i, :]  # (TC,)
        _dma_gather_rows(ids_row, x_hbm, gx_ref, sem, n, block_c)
        pi = p_ref[i, 0]
        qi = q_ref[i, :].astype(jnp.float32)
        ct = gx_ref[...].astype(jnp.float32)  # (TC, d)
        s = _row_dist_block(qi, ct, pi)
        val = _root(s, pi) if root else s
        ok = (ids_row >= 0) & (ids_row < n)
        o_ref[i, :] = jnp.where(ok, val, jnp.inf).astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, tb, per_query, 0)


def gather_lp_kernel_call(
    ids: jax.Array,  # (B, C) int32 candidate ids; out-of-range = padding
    q: jax.Array,    # (B, d)
    x: jax.Array,    # (n, d) HBM-resident dataset
    p,
    *,
    root: bool = False,
    block_b: int = 8,
    block_c: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Raw pallas_call for pre-padded inputs (B % block_b == C % block_c == 0).

    p: Python float, or a pre-padded (B, 1) f32 array (one metric per query
    row — the mixed-p contract described in the module preamble).
    """
    b, d = q.shape
    b2, cc = ids.shape
    n = x.shape[0]
    assert b == b2 and b % block_b == 0 and cc % block_c == 0, \
        (b, b2, cc, block_b, block_c)

    if not is_static_p(p):
        assert p.shape == (b, 1), (p.shape, b)
        return pl.pallas_call(
            functools.partial(
                _gather_lp_vec_kernel, root=root, n=n, block_c=block_c
            ),
            grid=(b // block_b, cc // block_c),
            in_specs=[
                pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
                pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),  # X stays in HBM
            ],
            out_specs=pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((b, cc), out_dtype),
            scratch_shapes=[
                pltpu.VMEM((block_c, d), jnp.float32),
                pltpu.SemaphoreType.DMA,
            ],
            interpret=interpret,
        )(ids, q, p, x)

    return pl.pallas_call(
        functools.partial(
            _gather_lp_kernel, p=p, root=root, n=n, block_c=block_c
        ),
        grid=(b // block_b, cc // block_c),
        in_specs=[
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # X stays in HBM
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, cc), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_c, d), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(ids, q, x)


# ---------------------------------------------------------------------------
# early-abandoning gather + blocked-dimension distance kernel (DESIGN.md §8):
# ids (B, C) + thresholds (B, 1) + base sums (B, C) + X (n, d)
#   -> dists (B, C) power sums (+inf for abandoned), nd (B, C) scanned dims
#
# The adaptive-T_p hot path: root-free Lp power sums accumulate non-negative
# terms, so a candidate's partial sum over a prefix of dimension blocks is a
# monotone lower bound on its final distance — any candidate whose partial
# sum (or provable lower bound, core/lp_ops.lp_entry_bound/lp_suffix_bound)
# exceeds the per-query threshold tile is abandoned exactly, skipping all
# remaining blocks' transcendental work.
#
# Layout: the gathered (TC, d) rows are transposed ONCE to (d, TC) so
# dimension blocks are *sublane* slices (granularity 8, block_d=32 default)
# while candidates occupy full 128-wide lanes — fine-grained abandonment
# checks without wasting lanes (a (TC, 32) lane-dim slice would run the VPU
# at 1/4 occupancy). Per block, `lax.cond` on the row's alive mask skips the
# transcendental family entirely once every candidate in the tile is dead;
# a row whose candidates are all dead at entry (threshold -inf = frozen
# query, or every entry bound beaten) skips its DMA gather too.
# ---------------------------------------------------------------------------


def _abandon_row(ids_row, qi, thr, sb_row, pi, x_hbm, gx_ref, sem,
                 *, base_p: float, n: int, block_c: int, block_d: int):
    """One query row of the abandoning scan. Returns (dists, nd) (TC,)."""
    d = qi.shape[0]
    nb = d // block_d
    valid = (ids_row >= 0) & (ids_row < n)
    lb = lp_entry_bound(sb_row, base_p, pi, d)
    alive0 = valid & (lb <= thr)

    def dead_row(_):
        return (jnp.full((block_c,), jnp.inf, jnp.float32),
                jnp.zeros((block_c,), jnp.int32))

    def scan_row(_):
        _dma_gather_rows(ids_row, x_hbm, gx_ref, sem, n, block_c)
        # one transpose + subtract; dimension blocks below are sublane
        # slices of this (d, TC) diff tile
        dt = gx_ref[...].astype(jnp.float32).T - qi[:, None]

        def block_step(b, carry):
            s, sbase, alive, nd = carry

            def compute(args):
                s, sbase, alive, nd = args
                blk = jax.lax.dynamic_slice(
                    dt, (b * block_d, 0), (block_d, block_c))
                a = jnp.abs(blk)
                bs = jnp.sum(pow_from_abs(a, pi), axis=0)
                bb = jnp.sum(a if base_p == 1.0 else a * a, axis=0)
                s = jnp.where(alive, s + bs, s)
                sbase = jnp.where(alive, sbase + bb, sbase)
                nd = nd + jnp.where(alive, block_d, 0)
                dead = s > thr
                d_rem = (d - (b + 1) * block_d).astype(jnp.float32)
                rem = lp_suffix_bound(sb_row - sbase, base_p, pi, d_rem)
                dead = dead | ((d_rem > 0) & (s + rem > thr))
                return (s, sbase, alive & ~dead, nd)

            return jax.lax.cond(jnp.any(carry[2]), compute,
                                lambda args: args, carry)

        s0 = jnp.zeros((block_c,), jnp.float32)
        carry = (s0, s0, alive0, jnp.zeros((block_c,), jnp.int32))
        s, _, alive, nd = jax.lax.fori_loop(0, nb, block_step, carry)
        return jnp.where(alive, s, jnp.inf), nd

    return jax.lax.cond(jnp.any(alive0), scan_row, dead_row, 0)


def _gather_abandon_kernel(ids_ref, q_ref, th_ref, sb_ref, x_hbm,
                           o_ref, nd_ref, gx_ref, sem,
                           *, p: float, base_p: float, n: int,
                           block_c: int, block_d: int):
    tb = q_ref.shape[0]

    def per_query(i, _):
        out, nd = _abandon_row(
            ids_ref[i, :], q_ref[i, :].astype(jnp.float32), th_ref[i, 0],
            sb_ref[i, :], p, x_hbm, gx_ref, sem,
            base_p=base_p, n=n, block_c=block_c, block_d=block_d,
        )
        o_ref[i, :] = out.astype(o_ref.dtype)
        nd_ref[i, :] = nd
        return 0

    jax.lax.fori_loop(0, tb, per_query, 0)


def _gather_abandon_vec_kernel(ids_ref, q_ref, th_ref, sb_ref, p_ref, x_hbm,
                               o_ref, nd_ref, gx_ref, sem,
                               *, base_p: float, n: int,
                               block_c: int, block_d: int):
    """Mixed-p variant: each query row scanned under its own traced p."""
    tb = q_ref.shape[0]

    def per_query(i, _):
        out, nd = _abandon_row(
            ids_ref[i, :], q_ref[i, :].astype(jnp.float32), th_ref[i, 0],
            sb_ref[i, :], p_ref[i, 0], x_hbm, gx_ref, sem,
            base_p=base_p, n=n, block_c=block_c, block_d=block_d,
        )
        o_ref[i, :] = out.astype(o_ref.dtype)
        nd_ref[i, :] = nd
        return 0

    jax.lax.fori_loop(0, tb, per_query, 0)


def gather_lp_abandon_kernel_call(
    ids: jax.Array,     # (B, C) int32 candidate ids; out-of-range = padding
    q: jax.Array,       # (B, d)
    thresh: jax.Array,  # (B, 1) per-query abandon bound (power-sum space;
                        # -inf = row frozen, +inf = no abandonment)
    sb: jax.Array,      # (B, C) base-metric power sums (0 = no bound info)
    x: jax.Array,       # (n, d) HBM-resident dataset
    p,
    *,
    base_p: float = 1.0,
    block_b: int = 8,
    block_c: int = 128,
    block_d: int = 32,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Raw pallas_call for pre-padded inputs (B % block_b == C % block_c == 0,
    d % block_d == 0). Returns (dists (B, C) root-free power sums with +inf
    for abandoned/padding candidates, nd (B, C) int32 dimensions scanned).

    p: Python float, or a pre-padded (B, 1) f32 array (one metric per query
    row — the mixed-p contract in the module preamble). base_p (static 1.0
    or 2.0) names the metric of `sb` for the entry/suffix bounds.
    """
    b, d = q.shape
    b2, cc = ids.shape
    n = x.shape[0]
    assert b == b2 and b % block_b == 0 and cc % block_c == 0, \
        (b, b2, cc, block_b, block_c)
    assert d % block_d == 0, (d, block_d)

    common = dict(
        grid=(b // block_b, cc // block_c),
        out_specs=(
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, cc), out_dtype),
            jax.ShapeDtypeStruct((b, cc), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_c, d), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )
    if not is_static_p(p):
        assert p.shape == (b, 1), (p.shape, b)
        return pl.pallas_call(
            functools.partial(
                _gather_abandon_vec_kernel, base_p=base_p, n=n,
                block_c=block_c, block_d=block_d,
            ),
            in_specs=[
                pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
                pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),  # X stays in HBM
            ],
            **common,
        )(ids, q, thresh, sb, p, x)
    return pl.pallas_call(
        functools.partial(
            _gather_abandon_kernel, p=float(p), base_p=base_p, n=n,
            block_c=block_c, block_d=block_d,
        ),
        in_specs=[
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # X stays in HBM
        ],
        **common,
    )(ids, q, thresh, sb, x)


# ---------------------------------------------------------------------------
# compressed-band screen kernel (DESIGN.md §10): ids (B, C) + thresholds
# (B, 1) + base sums (B, C) + int8 codes (n, d) + scale/radius (1, d)
#   -> keep (B, C) int32 0/1, nd (B, C) int32 band dimensions scanned
#
# The storage-side sibling of the abandon kernel: instead of gathering f32
# rows and accumulating *exact* partial power sums, it gathers int8 band
# rows (1/4 the DMA bytes) and accumulates the certified per-coordinate
# lower bound max(|q_j - x̂_j| - radius_j, 0)^p (index/compressed.py).
# A candidate whose deflated running bound exceeds the per-query threshold
# provably cannot enter the top-k, so the two-band scan never issues its
# f32 gather — the screen's survivors are the only rows the exact rerank
# touches. Same transposed (d, TC) layout, same per-block lax.cond alive
# gating, same entry/suffix bounds from the beam's base power sums as the
# abandon kernel; the suffix bound's scanned base mass accumulates the
# per-coordinate *upper* bounds (|q_j - x̂_j| + radius_j) so the remaining
# mass stays an underestimate. Because the accumulated sum is a float-
# evaluated bound (not an exact partial of the true distance), every kill
# comparison deflates by BOUND_SLACK.
# ---------------------------------------------------------------------------


def _screen_row(ids_row, qi, thr, sb_row, pi, scale_col, radius_col,
                codes_hbm, gx_ref, sem,
                *, base_p: float, n: int, block_c: int, block_d: int):
    """One query row of the compressed screen. Returns (keep, nd) (TC,)."""
    d = qi.shape[0]
    nb = d // block_d
    deflate = 1.0 - BOUND_SLACK
    valid = (ids_row >= 0) & (ids_row < n)
    lb = lp_entry_bound(sb_row, base_p, pi, d)
    alive0 = valid & (lb <= thr)

    def dead_row(_):
        return (jnp.zeros((block_c,), jnp.int32),
                jnp.zeros((block_c,), jnp.int32))

    def scan_row(_):
        _dma_gather_rows(ids_row, codes_hbm, gx_ref, sem, n, block_c)
        # dequant + subtract once; dimension blocks below are sublane
        # slices of this (d, TC) |q - x̂| tile
        a0 = jnp.abs(
            gx_ref[...].astype(jnp.float32).T * scale_col - qi[:, None])

        def block_step(b, carry):
            s, sbase, alive, nd = carry

            def compute(args):
                s, sbase, alive, nd = args
                blk = jax.lax.dynamic_slice(
                    a0, (b * block_d, 0), (block_d, block_c))
                rblk = jax.lax.dynamic_slice(
                    radius_col, (b * block_d, 0), (block_d, 1))
                al = jnp.maximum(blk - rblk, 0.0)   # certified lower bounds
                au = blk + rblk                     # upper bounds (suffix)
                bs = jnp.sum(pow_from_abs(al, pi), axis=0)
                bb = jnp.sum(au if base_p == 1.0 else au * au, axis=0)
                s = jnp.where(alive, s + bs, s)
                sbase = jnp.where(alive, sbase + bb, sbase)
                nd = nd + jnp.where(alive, block_d, 0)
                dead = s * deflate > thr
                d_rem = (d - (b + 1) * block_d).astype(jnp.float32)
                rem = lp_suffix_bound(sb_row - sbase, base_p, pi, d_rem)
                dead = dead | ((d_rem > 0) & ((s + rem) * deflate > thr))
                return (s, sbase, alive & ~dead, nd)

            return jax.lax.cond(jnp.any(carry[2]), compute,
                                lambda args: args, carry)

        s0 = jnp.zeros((block_c,), jnp.float32)
        carry = (s0, s0, alive0, jnp.zeros((block_c,), jnp.int32))
        _, _, alive, nd = jax.lax.fori_loop(0, nb, block_step, carry)
        return alive.astype(jnp.int32), nd

    return jax.lax.cond(jnp.any(alive0), scan_row, dead_row, 0)


def _gather_screen_kernel(ids_ref, q_ref, th_ref, sb_ref, sc_ref, rad_ref,
                          codes_hbm, keep_ref, nd_ref, gx_ref, sem,
                          *, p: float, base_p: float, n: int,
                          block_c: int, block_d: int):
    tb = q_ref.shape[0]
    scale_col = sc_ref[...].astype(jnp.float32).T    # (d, 1)
    radius_col = rad_ref[...].astype(jnp.float32).T  # (d, 1)

    def per_query(i, _):
        keep, nd = _screen_row(
            ids_ref[i, :], q_ref[i, :].astype(jnp.float32), th_ref[i, 0],
            sb_ref[i, :], p, scale_col, radius_col, codes_hbm, gx_ref, sem,
            base_p=base_p, n=n, block_c=block_c, block_d=block_d,
        )
        keep_ref[i, :] = keep
        nd_ref[i, :] = nd
        return 0

    jax.lax.fori_loop(0, tb, per_query, 0)


def _gather_screen_vec_kernel(ids_ref, q_ref, th_ref, sb_ref, p_ref, sc_ref,
                              rad_ref, codes_hbm, keep_ref, nd_ref, gx_ref,
                              sem, *, base_p: float, n: int,
                              block_c: int, block_d: int):
    """Mixed-p variant: each query row screened under its own traced p."""
    tb = q_ref.shape[0]
    scale_col = sc_ref[...].astype(jnp.float32).T
    radius_col = rad_ref[...].astype(jnp.float32).T

    def per_query(i, _):
        keep, nd = _screen_row(
            ids_ref[i, :], q_ref[i, :].astype(jnp.float32), th_ref[i, 0],
            sb_ref[i, :], p_ref[i, 0], scale_col, radius_col, codes_hbm,
            gx_ref, sem,
            base_p=base_p, n=n, block_c=block_c, block_d=block_d,
        )
        keep_ref[i, :] = keep
        nd_ref[i, :] = nd
        return 0

    jax.lax.fori_loop(0, tb, per_query, 0)


def gather_lp_screen_kernel_call(
    ids: jax.Array,     # (B, C) int32 candidate ids; out-of-range = padding
    q: jax.Array,       # (B, d) queries, band (permuted) coordinate order
    thresh: jax.Array,  # (B, 1) per-query screen bound (power-sum space;
                        # -inf = row frozen, +inf = keep everything)
    sb: jax.Array,      # (B, C) base-metric power sums (0 = no bound info)
    scale: jax.Array,   # (1, d) f32 per-coordinate dequant scales
    radius: jax.Array,  # (1, d) f32 per-coordinate max dequant error
    codes: jax.Array,   # (n, d) int8 HBM-resident compressed band
    p,
    *,
    base_p: float = 1.0,
    block_b: int = 8,
    block_c: int = 128,
    block_d: int = 32,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw pallas_call for pre-padded inputs (B % block_b == C % block_c == 0,
    d % block_d == 0). Returns (keep (B, C) int32 — 1 iff the candidate
    survived the screen and its f32 row must be gathered for the exact
    rerank, nd (B, C) int32 band dimensions scanned).

    p: Python float, or a pre-padded (B, 1) f32 array (one metric per
    query row — the mixed-p contract in the module preamble). base_p
    (static 1.0 or 2.0) names the metric of `sb` for the entry/suffix
    bounds. scale/radius ride as (1, d) operands pinned per grid step.
    """
    b, d = q.shape
    b2, cc = ids.shape
    n = codes.shape[0]
    assert b == b2 and b % block_b == 0 and cc % block_c == 0, \
        (b, b2, cc, block_b, block_c)
    assert d % block_d == 0, (d, block_d)
    assert scale.shape == (1, d) and radius.shape == (1, d), \
        (scale.shape, radius.shape, d)

    common = dict(
        grid=(b // block_b, cc // block_c),
        out_specs=(
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, cc), jnp.int32),
            jax.ShapeDtypeStruct((b, cc), jnp.int32),
        ),
        scratch_shapes=[
            pltpu.VMEM((block_c, d), jnp.int8),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )
    if not is_static_p(p):
        assert p.shape == (b, 1), (p.shape, b)
        return pl.pallas_call(
            functools.partial(
                _gather_screen_vec_kernel, base_p=base_p, n=n,
                block_c=block_c, block_d=block_d,
            ),
            in_specs=[
                pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
                pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
                pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
                pl.BlockSpec((1, d), lambda i, j: (0, 0)),
                pl.BlockSpec((1, d), lambda i, j: (0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),  # codes stay in HBM
            ],
            **common,
        )(ids, q, thresh, sb, p, scale, radius, codes)
    return pl.pallas_call(
        functools.partial(
            _gather_screen_kernel, p=float(p), base_p=base_p, n=n,
            block_c=block_c, block_d=block_d,
        ),
        in_specs=[
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec((1, d), lambda i, j: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # codes stay in HBM
        ],
        **common,
    )(ids, q, thresh, sb, scale, radius, codes)
