"""Durable snapshots, WAL replay, and crash recovery (repro.index.persist).

The contract under test (DESIGN.md §9): recover(dir) — newest durable
snapshot + replay of the WAL's durable prefix — lands BIT-IDENTICALLY on
the state of a never-crashed index (ids and distances, at every p,
un-compacted delta inserts included), and any torn/corrupt file left by a
crash is *detected* and stepped past, never loaded. The kill-in-the-middle
sweep truncates the log at every record boundary and mid-record; the
fallback tests corrupt the newest snapshot and the WAL history.
"""

import warnings
from pathlib import Path

import numpy as np
import pytest

from repro.index.persist import (
    DurableIndex,
    RecoveryError,
    SnapshotError,
    latest_durable_snapshot,
    list_snapshots,
    load_snapshot,
    read_manifest,
    recover,
    save_snapshot,
)
from repro.index.sharded import ShardedUHNSW
from repro.index.wal import (
    WalCorruption,
    WriteAheadLog,
    list_wals,
    replay,
    wal_path,
)

P_SWEEP = [0.5, 1.0, 1.25, 2.0]
D = 16


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    return (rng.standard_normal((120, D)).astype(np.float32),   # frozen
            rng.standard_normal((30, D)).astype(np.float32),    # streamed
            rng.standard_normal((5, D)).astype(np.float32))     # queries


def _build(frozen):
    return ShardedUHNSW.build(frozen, num_segments=2, m=12, seed=3,
                              delta_capacity=12)


def _search_all_p(idx, Q, k=10):
    out = {}
    for p in P_SWEEP:
        ids, dists, _ = idx.search(Q, p, k)
        out[p] = (np.asarray(ids), np.asarray(dists))
    return out


def _assert_identical(a, b):
    for p in P_SWEEP:
        np.testing.assert_array_equal(a[p][0], b[p][0], err_msg=f"ids p={p}")
        np.testing.assert_array_equal(a[p][1], b[p][1],
                                      err_msg=f"dists p={p}")


# ---------------------------------------------------------------------------
# WAL unit semantics
# ---------------------------------------------------------------------------


def test_wal_roundtrip_and_boundaries(tmp_path):
    path = wal_path(tmp_path, 0)
    rng = np.random.default_rng(0)
    batches = [(np.arange(i * 3, i * 3 + 3),
                rng.standard_normal((3, D)).astype(np.float32))
               for i in range(4)]
    bounds = []
    with WriteAheadLog(path, sync=False) as wal:
        for ids, vecs in batches:
            bounds.append(wal.append(ids, vecs))
    got, clean = replay(path)
    assert clean and len(got) == 4
    for (ids, vecs), (gids, gvecs) in zip(batches, got):
        np.testing.assert_array_equal(gids, ids)
        np.testing.assert_array_equal(gvecs, vecs)
    # record boundaries are strictly increasing file offsets
    assert bounds == sorted(set(bounds))

    # torn tail: truncate at every boundary -> exactly that prefix replays
    raw = path.read_bytes()
    for n_rec, cut in enumerate(bounds):
        path.write_bytes(raw[:cut])
        got, clean = replay(path)
        assert clean and len(got) == n_rec + 1
        # ... and mid-record (a few bytes past the boundary) drops the
        # torn record but keeps everything before it; the last boundary
        # is EOF, so there is no next record to tear into
        if cut + 7 <= len(raw):
            path.write_bytes(raw[:cut + 7])
            got, clean = replay(path)
            assert not clean and len(got) == n_rec + 1


def test_wal_detects_corruption_not_just_truncation(tmp_path):
    path = wal_path(tmp_path, 0)
    with WriteAheadLog(path, sync=False) as wal:
        wal.append([0], np.ones((1, D), np.float32))
        wal.append([1], np.ones((1, D), np.float32))
    raw = bytearray(path.read_bytes())
    raw[len(raw) // 2] ^= 0xFF                   # flip a payload byte
    path.write_bytes(bytes(raw))
    got, clean = replay(path)
    assert not clean and len(got) < 2            # CRC stops replay

    # a non-WAL file is a caller bug, not a torn write
    bogus = tmp_path / "wal_00000009.log"
    bogus.write_bytes(b"definitely not a WAL, long enough to have a header")
    with pytest.raises(WalCorruption):
        replay(bogus)


# ---------------------------------------------------------------------------
# snapshot roundtrip + recovery identity
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_bitwise(tmp_path, corpus):
    frozen, streamed, Q = corpus
    idx = _build(frozen)
    for v in streamed[:5]:                       # leave delta non-empty
        idx.add(v)
    path = save_snapshot(idx, tmp_path)
    assert read_manifest(path)["seq"] == 0
    back = load_snapshot(path)
    assert back.n == idx.n
    assert back._next_id == idx._next_id
    assert len(back.delta) == len(idx.delta) == 5
    np.testing.assert_array_equal(back._X_host, idx._X_host)
    np.testing.assert_array_equal(back.delta.ids(), idx.delta.ids())
    _assert_identical(_search_all_p(back, Q), _search_all_p(idx, Q))


def test_recovery_identity_with_compactions_and_delta(tmp_path, corpus):
    """The acceptance criterion: crash -> recover == never crashed, at
    every p, across compaction boundaries AND with un-compacted delta
    inserts pending."""
    frozen, streamed, Q = corpus
    idx = _build(frozen)
    dur = DurableIndex.create(idx, tmp_path)
    for v in streamed:                           # 30 adds, compacts at 12/24
        dur.add(v)
    assert idx.num_segments == 4                 # 2 built + 2 compacted
    assert len(idx.delta) == 6                   # un-compacted tail
    rec = recover(tmp_path)
    assert rec.n == idx.n and len(rec.delta) == 6
    assert rec._build_method == idx._build_method
    _assert_identical(_search_all_p(rec, Q), _search_all_p(idx, Q))
    dur.close()


def test_kill_in_the_middle_sweep(tmp_path, corpus):
    """Truncate the live WAL at every record boundary and mid-record:
    recovery must land exactly on the corresponding prefix of adds —
    structural state at every cut, full bitwise search identity at the
    interesting cuts (empty, mid-delta, post-compaction, full).

    The crash simulation is time-consistent: a crash while WAL s was the
    live log means snapshots/WALs with seq > s did not exist yet, so each
    cut re-materializes the state directory as it looked at that moment.
    """
    import shutil

    frozen, streamed, Q = corpus
    n0 = len(frozen)
    state = tmp_path / "state"
    dur = DurableIndex.create(_build(frozen), state)
    # 14 adds: boundary 12 triggers a compaction + rotation mid-stream
    n_adds = 14
    for v in streamed[:n_adds]:
        dur.add(v)
    dur.close()
    pristine = tmp_path / "pristine"
    shutil.copytree(state, pristine)

    # reference searches for the interesting prefixes, from a fresh
    # never-persisted index replaying the same add stream
    interesting = {0, 6, 12, n_adds}
    ref_results, ref_segs = {}, {}
    ref = _build(frozen)
    for count in range(n_adds + 1):
        if count:
            ref.add(streamed[count - 1])
        ref_segs[count] = ref.num_segments
        if count in interesting:
            ref_results[count] = _search_all_p(ref, Q)

    # map every WAL record boundary to its durable add count: wal 0 holds
    # adds 1..12 (the rotation point), wal 1 the tail
    wals = {seq: p.read_bytes() for seq, p in list_wals(pristine)}
    assert len(wals) == 2
    rec_bytes = 12 + 8 + (8 + 4 * D)             # framing + payload, 1 vec
    cuts = []                                    # (wal_seq, byte_len, count)
    base_count = 0
    for seq in sorted(wals):
        batches, clean = replay(wal_path(pristine, seq))
        assert clean
        off = 8                                  # file header
        cuts.append((seq, off, base_count))
        for ids, _vecs in batches:
            assert len(ids) == 1                 # one record per add()
            off += rec_bytes
            base_count += 1
            cuts.append((seq, off, base_count))
        assert off == len(wals[seq])
    assert base_count == n_adds

    for seq, cut, count in cuts:
        for extra in (0, 7):                     # boundary and mid-record
            # re-materialize the directory as of the crash instant
            shutil.rmtree(state)
            shutil.copytree(pristine, state)
            for s_snap, p_snap in list_snapshots(state):
                if s_snap > seq:
                    shutil.rmtree(p_snap)
            for s_wal, p_wal in list_wals(state):
                if s_wal > seq:
                    p_wal.unlink()
                elif s_wal == seq:
                    p_wal.write_bytes(wals[seq][:cut + extra])
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                rec = recover(state)
            assert rec.n == n0 + count, (seq, cut, extra)
            assert rec.num_segments == ref_segs[count], (seq, cut, extra)
            if count in ref_results and extra == 0:
                _assert_identical(_search_all_p(rec, Q),
                                  ref_results[count])


def test_torn_newest_snapshot_falls_back(tmp_path, corpus):
    """Post-crash corruption of the newest snapshot: recovery must warn,
    fall back to the previous durable snapshot, and rebuild the SAME
    state from the retained WAL history."""
    frozen, streamed, Q = corpus
    idx = _build(frozen)
    dur = DurableIndex.create(idx, tmp_path)
    for v in streamed[:14]:                      # rotation at add 12
        dur.add(v)
    dur.close()
    want = _search_all_p(idx, Q)
    snaps = list_snapshots(tmp_path)
    assert len(snaps) == 2
    # tear the newest snapshot's array file (CRC must catch it)
    newest = snaps[-1][1] / "arrays.npz"
    newest.write_bytes(newest.read_bytes()[:100])
    with pytest.raises(SnapshotError):
        read_manifest(snaps[-1][1])
    with pytest.warns(UserWarning, match="skipping non-durable snapshot"):
        assert latest_durable_snapshot(tmp_path) == snaps[0][1]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        rec = recover(tmp_path)
    assert rec.n == idx.n
    _assert_identical(_search_all_p(rec, Q), want)


def test_wal_gap_refuses_silent_recovery(tmp_path, corpus):
    """A lost WAL segment (newest snapshot torn AND the old WAL's records
    unreadable) must raise RecoveryError, not silently drop inserts."""
    frozen, streamed, _ = corpus
    dur = DurableIndex.create(_build(frozen), tmp_path)
    for v in streamed[:14]:
        dur.add(v)
    dur.close()
    for _, p in list_snapshots(tmp_path)[1:]:
        (p / "arrays.npz").write_bytes(b"torn")
    # wipe wal 0's records (keep the header): replay yields nothing there,
    # so wal 1's first gid jumps past the fallback snapshot's n
    w0 = wal_path(tmp_path, 0)
    w0.write_bytes(w0.read_bytes()[:8])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(RecoveryError, match="id gap"):
            recover(tmp_path)


def test_recovered_durable_index_keeps_accepting_writes(tmp_path, corpus):
    """DurableIndex.recover re-arms durability: post-recovery inserts are
    themselves durable (a second recover sees them)."""
    frozen, streamed, Q = corpus
    dur = DurableIndex.create(_build(frozen), tmp_path)
    for v in streamed[:5]:
        dur.add(v)
    dur.close()
    dur2 = DurableIndex.recover(tmp_path)
    for v in streamed[5:10]:
        dur2.add(v)
    want = _search_all_p(dur2.index, Q)
    n_want = dur2.n
    dur2.close()
    rec = recover(tmp_path)
    assert rec.n == n_want
    _assert_identical(_search_all_p(rec, Q), want)


def test_prune_keeps_fallback_window(tmp_path, corpus):
    """Rotation prunes old snapshots but always keeps enough WAL history
    that the *previous* snapshot alone can still rebuild the full state."""
    frozen, streamed, _ = corpus
    dur = DurableIndex.create(_build(frozen), tmp_path, keep_snapshots=2)
    for v in streamed:                           # 30 adds -> 2 rotations
        dur.add(v)
    dur.close()
    seqs = [s for s, _ in list_snapshots(tmp_path)]
    assert len(seqs) == 2                        # pruned to the window
    # every retained WAL seq >= oldest kept snapshot - 1
    assert all(s >= seqs[0] - 1 for s, _ in list_wals(tmp_path))
    # drop the newest snapshot entirely: the previous one + WALs suffice
    snaps = list_snapshots(tmp_path)
    import shutil
    shutil.rmtree(snaps[-1][1])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        rec = recover(tmp_path)
    assert rec.n == len(frozen) + len(streamed)


def test_recover_raises_when_all_snapshots_torn(tmp_path, corpus):
    """Every snapshot torn (CRC fails on each) means there is NO durable
    baseline: recovery must raise, never hand back an empty or partial
    index (DESIGN.md §11 — quarantine recovery leans on this guarantee)."""
    frozen, streamed, _ = corpus
    dur = DurableIndex.create(_build(frozen), tmp_path)
    for v in streamed[:14]:                      # rotation -> 2 snapshots
        dur.add(v)
    dur.close()
    snaps = list_snapshots(tmp_path)
    assert len(snaps) >= 2
    for _, p in snaps:                           # tear ALL of them
        f = p / "arrays.npz"
        f.write_bytes(f.read_bytes()[:64])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert latest_durable_snapshot(tmp_path) is None
        with pytest.raises(FileNotFoundError):
            recover(tmp_path)
        with pytest.raises(FileNotFoundError):
            DurableIndex.recover(tmp_path)


def test_restore_segment_roundtrip_and_mismatch(tmp_path, corpus):
    """restore_segment re-materializes one segment's rows bit-exactly from
    the newest durable snapshot (manifest CRC re-verified on the way) and
    returns False when no snapshot holds that segment's id set."""
    from repro.index.persist import restore_segment
    from repro.retrieval.engine.faults import poison_segment

    frozen, _, Q = corpus
    idx = _build(frozen)
    want = _search_all_p(idx, Q)
    DurableIndex.create(idx, tmp_path).close()
    before = np.array(idx._X_host, copy=True)
    poison_segment(idx, 1)
    assert not np.isfinite(np.asarray(idx.segments.X)[1]).all()
    assert restore_segment(idx, 1, tmp_path) is True
    np.testing.assert_array_equal(idx._X_host, before)
    _assert_identical(_search_all_p(idx, Q), want)
    # a segment whose id set is absent from every snapshot: no restore
    idx.segments.global_ids[0] = idx.segments.global_ids[0] + 100_000
    assert restore_segment(idx, 0, tmp_path) is False
    # and an empty directory has nothing to offer at all
    empty = tmp_path / "nothing"
    empty.mkdir()
    assert restore_segment(idx, 1, empty) is False


def test_load_snapshot_rejects_garbage_dir(tmp_path):
    bad = tmp_path / "snapshot_00000000"
    bad.mkdir()
    (bad / "manifest.json").write_text("{not json")
    with pytest.raises(SnapshotError):
        load_snapshot(bad)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert latest_durable_snapshot(tmp_path) is None
        with pytest.raises(FileNotFoundError):
            recover(tmp_path)
