"""Batched device-side bulk construction (core/bulk_build, DESIGN.md §7).

Pinned here:
  * the bulk-built pair is searchable at every index layer — monolithic
    UHNSW, sharded/segmented, and the post-compaction delta path;
  * downstream recall parity vs the incremental builder at matched ef on a
    small corpus, p in {0.5, 1.25, 2.0};
  * NN-Descent round monotonicity: pool recall vs exact kNN is
    non-decreasing per round (merges are exact-distance keep-best-k);
  * degree / padding invariants of the emitted GraphArrays (via the
    -1-padded `adjacency_host` view): rows hold <= m_level real ids,
    packed before the padding, no self-loops, no duplicates, neighbors
    live at the level they appear on.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.bulk_build import build_bulk_pair, nn_descent_pools
from repro.core.build import build_hnsw
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSW, UHNSWParams, recall
from repro.index.sharded import ShardedUHNSW

P_GRID = (0.5, 1.25, 2.0)
N = 800
M = 8


@pytest.fixture(scope="module")
def data(small_ds):
    return np.ascontiguousarray(small_ds.data[:N])


@pytest.fixture(scope="module")
def queries(small_ds):
    return jnp.asarray(small_ds.queries[:16])


@pytest.fixture(scope="module")
def bulk_pair(data):
    return build_bulk_pair(data, m=M, seed=3)


def _recall_at(idx, data, queries, p, k=10):
    ids, _, _ = idx.search(queries, p, k)
    true, _ = exact_topk(jnp.asarray(data), queries, p, k)
    return recall(np.asarray(ids), np.asarray(true))


# ---------------------------------------------------------------------------
# searchable at every layer
# ---------------------------------------------------------------------------


def test_monolithic_searchable(bulk_pair, data, queries):
    idx = UHNSW(*bulk_pair, UHNSWParams(t=100))
    for p in P_GRID:
        r = _recall_at(idx, data, queries, p)
        assert r >= 0.9, (p, r)


def test_sharded_and_post_compaction_searchable(data, queries):
    idx = ShardedUHNSW.build(data, num_segments=2, m=M, method="bulk",
                             params=UHNSWParams(t=100), delta_capacity=24,
                             seed=5)
    for p in P_GRID:
        r = _recall_at(idx, data, queries, p)
        assert r >= 0.88, (p, r)
    # streaming inserts -> compaction builds the new segment via the same
    # bulk method; inserted vectors must be findable at every p afterwards
    rng = np.random.default_rng(0)
    new = data[:24] + rng.normal(scale=1e-3, size=(24, data.shape[1])
                                 ).astype(np.float32)
    gids = [idx.add(v) for v in new]
    assert idx.num_segments == 3  # the delta buffer compacted
    assert len(idx.delta) == 0
    ids, _, _ = idx.search(jnp.asarray(new[:8]), 0.5, 5)
    ids2, _, _ = idx.search(jnp.asarray(new[:8]), 2.0, 5)
    for i in range(8):
        assert gids[i] in set(np.asarray(ids)[i].tolist()), i
        assert gids[i] in set(np.asarray(ids2)[i].tolist()), i


# ---------------------------------------------------------------------------
# recall parity vs the incremental builder (matched ef)
# ---------------------------------------------------------------------------


def test_recall_parity_vs_incremental(data, queries):
    sub = data[:600]
    gi1 = build_hnsw(sub, 1.0, m=M, ef_construction=48, seed=0)
    gi2 = build_hnsw(sub, 2.0, m=M, ef_construction=48, seed=1)
    gb1, gb2 = build_bulk_pair(sub, m=M, seed=0)
    prm = UHNSWParams(t=100)  # matched t/ef for both pairs
    inc = UHNSW(gi1, gi2, prm)
    bulk = UHNSW(gb1, gb2, prm)
    for p in P_GRID:
        r_inc = _recall_at(inc, sub, queries, p)
        r_bulk = _recall_at(bulk, sub, queries, p)
        # the benchmark gates the 0.5 pt bound at scale; here allow 2 pt of
        # small-sample noise on 16 queries
        assert r_bulk >= r_inc - 0.02, (p, r_inc, r_bulk)


# ---------------------------------------------------------------------------
# NN-Descent round monotonicity
# ---------------------------------------------------------------------------


def test_nn_descent_rounds_monotone(data):
    # exact_seed_threshold=0 forces the above-threshold path (random seed
    # pools + sampled NN-Descent rounds) on a corpus small enough to score
    # exact ground truth against
    sub = data[:500]
    k = 16
    pools, snaps = nn_descent_pools(sub, (1.0, 2.0), k=k, rounds=4, seed=7,
                                    trajectory=True, exact_seed_threshold=0)
    assert len(snaps) == 5  # seed + 4 rounds
    x = jnp.asarray(sub)
    for p in (1.0, 2.0):
        true, _ = exact_topk(x, x, p, k + 1)
        true = np.asarray(true)[:, 1:]  # drop self (distance 0)
        rs = [recall(s[p], true) for s in snaps]
        for a, b in zip(rs, rs[1:]):
            assert b >= a - 1e-12, rs  # keep-best-k merges cannot regress
        assert rs[-1] > rs[0], rs      # and the rounds actually help
        assert rs[-1] >= 0.9, rs       # near-exact kNN after 4 rounds
        np.testing.assert_array_equal(snaps[-1][p], pools[p][0])


def test_exact_seed_matches_exact_topk(data):
    # at segment scale the seed pass is exact kNN for L2 (full matmul
    # scan) and exact-within-pool for L1 (generous shared-pool rerank)
    sub = data[:300]
    k = 8
    pools = nn_descent_pools(sub, (1.0, 2.0), k=k, seed=3)
    x = jnp.asarray(sub)
    # L1 sits just under 0.99 on this corpus: its ordering diverges from
    # the L2 prefilter on heavy-tailed dims, and a 0.98 floor is the
    # honest pool-coverage bound at pool_factor=8
    for p, floor in ((1.0, 0.98), (2.0, 1.0)):
        true, _ = exact_topk(x, x, p, k + 1)
        true = np.asarray(true)[:, 1:]
        assert recall(pools[p][0], true) >= floor, p


# ---------------------------------------------------------------------------
# degree / padding invariants + determinism
# ---------------------------------------------------------------------------


def test_degree_and_padding_invariants(bulk_pair):
    for g in bulk_pair:
        n = g.n
        assert g.levels[g.entry_point] == g.max_level
        for level in range(g.max_level + 1):
            mat = g.adjacency_host(level)
            nodes = (np.arange(n) if level == 0
                     else np.nonzero(g.levels >= level)[0])
            m_max = g.m0 if level == 0 else g.m
            assert mat.shape == (len(nodes), m_max)
            assert mat.min() >= -1 and mat.max() < n
            for row, u in zip(mat, nodes):
                real = row[row >= 0]
                # padding is contiguous at the tail (packed rows)
                assert (row[len(real):] == -1).all()
                assert u not in real                     # no self-loops
                assert len(set(real.tolist())) == len(real)  # no dups
                # neighbors at level l live at level >= l
                assert (g.levels[real] >= level).all()
        # the device arrays use the sentinel-n convention
        adj0 = np.asarray(g.arrays.adj0)
        assert adj0.max() <= n and adj0.min() >= 0


def test_single_metric_build_bulk(data):
    """build_bulk (one metric, arbitrary p) is searchable standalone."""
    from repro.core.bulk_build import build_bulk
    from repro.core.hnsw import GraphArrays, knn_search

    sub = data[:400]
    g = build_bulk(sub, metric_p=1.5, m=M, seed=2)
    assert g.metric_p == 1.5
    x = jnp.asarray(sub)
    q = x[:8]
    ids, _, _, _ = knn_search(GraphArrays.from_graph(g), x, q, ef=64, t=10)
    true, _ = exact_topk(x, q, 1.5, 10)
    assert recall(np.asarray(ids), np.asarray(true)) >= 0.95


def test_build_methods_reachable_from_uhnsw(data):
    """Every README build-method name resolves on UHNSW.build."""
    sub = data[:200]
    for method in ("bulk", "bulk_host"):
        idx = UHNSW.build(sub, m=4, method=method)
        ids, _, _ = idx.search(jnp.asarray(sub[:4]), 1.25, 3)
        assert np.asarray(ids).shape == (4, 3)
    with pytest.raises(ValueError):
        UHNSW.build(sub, m=4, method="nope")


def test_bulk_pair_deterministic(data):
    sub = data[:400]
    a1, a2 = build_bulk_pair(sub, m=M, seed=11)
    b1, b2 = build_bulk_pair(sub, m=M, seed=11)
    for ga, gb in ((a1, b1), (a2, b2)):
        assert ga.entry_point == gb.entry_point
        np.testing.assert_array_equal(np.asarray(ga.arrays.adj0),
                                      np.asarray(gb.arrays.adj0))
        for ua, ub in zip(ga.arrays.upper_adj, gb.arrays.upper_adj):
            np.testing.assert_array_equal(np.asarray(ua), np.asarray(ub))
