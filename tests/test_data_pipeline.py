"""Synthetic pipeline: determinism, host sharding, label alignment."""

import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data.pipeline import SyntheticTokenPipeline


@pytest.fixture(scope="module")
def cfg():
    return get_arch("tinyllama_1_1b", smoke=True)


def test_deterministic_per_step(cfg):
    a = SyntheticTokenPipeline(cfg, 4, 32, seed=3).batch(7)
    b = SyntheticTokenPipeline(cfg, 4, 32, seed=3).batch(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    np.testing.assert_array_equal(np.asarray(a["labels"]), np.asarray(b["labels"]))


def test_steps_differ(cfg):
    p = SyntheticTokenPipeline(cfg, 4, 32, seed=3)
    a, b = p.batch(0), p.batch(1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_host_shards_differ_and_split(cfg):
    full = SyntheticTokenPipeline(cfg, 8, 16, seed=0, host_index=0, host_count=1)
    h0 = SyntheticTokenPipeline(cfg, 8, 16, seed=0, host_index=0, host_count=2)
    h1 = SyntheticTokenPipeline(cfg, 8, 16, seed=0, host_index=1, host_count=2)
    b0, b1 = h0.batch(0), h1.batch(0)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]), np.asarray(b1["tokens"]))
    assert full.batch(0)["tokens"].shape == (8, 16)


def test_labels_are_next_tokens(cfg):
    b = SyntheticTokenPipeline(cfg, 2, 24, seed=1).batch(0)
    tokens = np.asarray(b["tokens"])
    labels = np.asarray(b["labels"])
    # labels[t] == tokens[t+1] for all but the last position
    np.testing.assert_array_equal(labels[:, :-1], tokens[:, 1:])


def test_learnable_structure(cfg):
    """The stream is Markov: next-token entropy *conditioned on the current
    bucket* is far below the unigram entropy (the structure an LM learns)."""
    b = SyntheticTokenPipeline(cfg, 16, 256, seed=0)
    pipe_batches = [b.batch(i) for i in range(3)]
    toks = np.concatenate(
        [np.asarray(x["tokens"]).ravel() for x in pipe_batches]
    )
    nxt = np.concatenate(
        [np.asarray(x["labels"]).ravel() for x in pipe_batches]
    )
    # unigram entropy
    _, c = np.unique(nxt, return_counts=True)
    p = c / c.sum()
    h_unigram = -(p * np.log(p)).sum()
    # conditional entropy H(next | current bucket)
    buckets = toks % b.n_buckets
    h_cond, total = 0.0, len(nxt)
    for bk in np.unique(buckets):
        sub = nxt[buckets == bk]
        _, c = np.unique(sub, return_counts=True)
        p = c / c.sum()
        h_cond += len(sub) / total * -(p * np.log(p)).sum()
    assert h_cond < 0.8 * h_unigram, (h_cond, h_unigram)


def test_frontend_frames():
    cfg = get_arch("musicgen_large", smoke=True)
    b = SyntheticTokenPipeline(cfg, 2, 16, seed=0).batch(0)
    assert "frames" in b and "tokens" not in b
    assert b["frames"].shape == (2, 16, cfg.frontend_dim)
