"""Batched JAX beam search: recall vs brute force + search invariants."""

import jax.numpy as jnp
import numpy as np

from repro.core.hnsw import GraphArrays, exact_topk, knn_search
from repro.core.uhnsw import recall


def test_search_recall_bulk(graphs_bulk, small_ds):
    X = jnp.asarray(small_ds.data)
    Q = jnp.asarray(small_ds.queries)
    for g in graphs_bulk:
        arrays = GraphArrays.from_graph(g)
        ids, dists, nb, hops = knn_search(arrays, X, Q, ef=300, t=100)
        true_ids, _ = exact_topk(X, Q, g.metric_p, 100)
        r = recall(ids, true_ids)
        assert r > 0.9, f"recall {r} too low for p={g.metric_p}"
        # the whole point: far fewer distance evals than brute force
        assert float(nb.mean()) < 0.8 * small_ds.n


def test_search_recall_incremental(graph_incremental):
    g = graph_incremental
    X = jnp.asarray(g.data)
    Q = X[:16] + 0.01  # near-duplicate queries
    arrays = GraphArrays.from_graph(g)
    ids, dists, nb, hops = knn_search(arrays, X, Q, ef=100, t=10)
    true_ids, _ = exact_topk(X, Q, g.metric_p, 10)
    assert recall(ids, true_ids) > 0.9


def test_search_returns_sorted_unique(graphs_bulk, small_ds):
    g1, _ = graphs_bulk
    X = jnp.asarray(small_ds.data)
    Q = jnp.asarray(small_ds.queries[:8])
    ids, dists, nb, hops = knn_search(GraphArrays.from_graph(g1), X, Q, ef=120, t=60)
    ids, dists = np.asarray(ids), np.asarray(dists)
    for i in range(ids.shape[0]):
        # ascending distances
        assert (np.diff(dists[i]) >= -1e-6).all()
        real = ids[i][ids[i] < small_ds.n]
        assert len(set(real.tolist())) == len(real)


def test_exact_topk_chunking_consistent(small_ds):
    X = jnp.asarray(small_ds.data)
    Q = jnp.asarray(small_ds.queries[:4])
    a, da = exact_topk(X, Q, 1.3, 20, chunk=100)
    b, db = exact_topk(X, Q, 1.3, 20, chunk=1 << 20)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), rtol=1e-6)


def test_nb_counts_bounded(graphs_bulk, small_ds):
    """N_b can never exceed n (each point's distance computed at most once
    per query) and must be at least ef."""
    g1, _ = graphs_bulk
    X = jnp.asarray(small_ds.data)
    Q = jnp.asarray(small_ds.queries)
    _, _, nb, _ = knn_search(GraphArrays.from_graph(g1), X, Q, ef=100, t=50)
    nb = np.asarray(nb)
    assert (nb <= small_ds.n).all()
    assert (nb >= 100).all()
