"""Segment health + degraded-coverage serving (DESIGN.md §11).

The load-bearing invariant: a degraded search with segment set H masked
alive is *bitwise identical* to an independent search over an index built
from only H's segments — same ids, same distances, for every policy and
every p, delta hits included — and `coverage_frac` is exact. The chaos
half pins the NaN-poison path: detection at query time, O(log S)
bisection to the segment, quarantine, recovery from the durable snapshot,
canary-gated re-admission — and zero poisoned ids ever returned.

Chaos seeds: the CI chaos lane sweeps REPRO_SEGFAULT_SEED so the injector
schedules differ per matrix entry while each entry stays deterministic.
"""

import copy
import os
import tempfile
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.uhnsw import UHNSWParams
from repro.index import (
    HEALTHY,
    QUARANTINED,
    RECOVERING,
    SUSPECT,
    DurableIndex,
    HealthPolicy,
    SegmentedGraphs,
    SegmentHealthTracker,
    ShardedUHNSW,
)
from repro.index.sharded import ShardedParams
from repro.retrieval.engine import (
    EnginePolicy,
    FaultInjector,
    ManualClock,
    ServingEngine,
    segment_site,
)
from repro.retrieval.engine.faults import poison_segment

CHAOS = int(os.environ.get("REPRO_SEGFAULT_SEED", "0"))

P_GRID = [0.5, 1.0, 1.25, 2.0]
N, D, T = 400, 16, 60


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(11)
    return rng.standard_normal((N, D)).astype(np.float32)


@pytest.fixture(scope="module")
def base_index(data):
    """One expensive 4-segment graph build for the whole module; tests
    wrap its graphs in fresh ShardedUHNSW instances (cheap)."""
    return ShardedUHNSW.build(data, num_segments=4, m=8,
                              params=UHNSWParams(t=T), seed=0)


def fresh_wrap(base_index, data, deep=False, **kw):
    """A fresh wrapper over the module build's graphs. deep=True copies
    the graph objects too, so poison tests can rebind .data without
    corrupting the shared build."""
    segs = base_index.segments

    def g(graphs):
        return [copy.copy(x) for x in graphs] if deep else list(graphs)

    clone = SegmentedGraphs(graphs1=g(segs.graphs1), graphs2=g(segs.graphs2),
                            global_ids=[i.copy() for i in segs.global_ids])
    kw.setdefault("params", UHNSWParams(t=T))
    return ShardedUHNSW(clone, data, **kw)


def make_requests(eng, data, n, start=0, p=1.3, k=5):
    return [eng.make_request(SimpleNamespace(
        vector=data[(start + i) % len(data)], p=p, k=k,
        request_id=start + i)) for i in range(n)]


# ---------------------------------------------------------------------------
# health state machine
# ---------------------------------------------------------------------------


def test_ewma_walks_healthy_suspect_quarantined():
    tr = SegmentHealthTracker(3)
    assert tr.alive() == [0, 1, 2]
    # alpha=0.3: failures move the EWMA 0 -> .3 -> .51 -> .657 -> .76
    tr.record_failure(0)
    assert tr.state(0) == SUSPECT          # .3 >= suspect_threshold
    tr.record_failure(0)
    tr.record_failure(0)
    assert tr.state(0) == SUSPECT          # .657 < quarantine_threshold
    tr.record_failure(0)
    assert tr.state(0) == QUARANTINED      # .76 >= .7
    assert tr.alive() == [1, 2] and tr.quarantined() == [0]
    assert tr.counters["quarantined"] == 1


def test_success_decays_suspect_back_to_healthy():
    tr = SegmentHealthTracker(2)
    tr.record_failure(1)
    assert tr.state(1) == SUSPECT
    for _ in range(4):
        tr.record_success(1)
    assert tr.state(1) == HEALTHY


def test_recovery_requires_probe_streak():
    tr = SegmentHealthTracker(2, HealthPolicy(probe_successes=2))
    gen0 = tr.generation
    tr.quarantine(0)
    assert tr.generation > gen0            # serving set changed
    tr.quarantine(0)                       # idempotent
    assert tr.counters["quarantined"] == 1
    with pytest.raises(ValueError):
        tr.readmit(0)                      # not RECOVERING
    tr.begin_recovery(0)
    assert tr.state(0) == RECOVERING
    assert tr.alive() == [1]               # RECOVERING does not serve
    tr.record_probe(0, True)
    with pytest.raises(ValueError):
        tr.readmit(0)                      # streak 1 < 2
    tr.record_probe(0, False)              # failure resets the streak
    tr.record_probe(0, True)
    tr.record_probe(0, True)
    assert tr.probe_passed(0)
    gen1 = tr.generation
    tr.readmit(0)
    assert tr.state(0) == HEALTHY and tr.generation > gen1
    assert tr.ewma[0] == 0.0


def test_resize_is_grow_only_and_preserves_state():
    tr = SegmentHealthTracker(2)
    tr.quarantine(1)
    tr.resize(4)
    assert tr.state(1) == QUARANTINED and tr.alive() == [0, 2, 3]
    with pytest.raises(ValueError):
        tr.resize(3)


# ---------------------------------------------------------------------------
# satellite: ShardedParams validated at construction
# ---------------------------------------------------------------------------


def test_probe_exceeding_segments_raises(base_index, data):
    with pytest.raises(ValueError, match="probe"):
        fresh_wrap(base_index, data,
                   sharded_params=ShardedParams(policy="two_phase", probe=5))
    # probe == n_segments is the degenerate-but-legal boundary
    fresh_wrap(base_index, data,
               sharded_params=ShardedParams(policy="two_phase", probe=4))


def test_thresh_rank_exceeding_t_raises(base_index, data):
    with pytest.raises(ValueError, match="thresh_rank"):
        fresh_wrap(base_index, data,
                   sharded_params=ShardedParams(policy="two_phase", probe=2,
                                                thresh_rank=T + 1))


# ---------------------------------------------------------------------------
# the §11 parity invariant: degraded == subset-built, bitwise
# ---------------------------------------------------------------------------


def _subset_clone(idx, alive):
    """An independent index holding only `alive`'s segments, in the SAME
    global-id space (full data array + copied delta + same id cursor)."""
    segs = idx.segments
    sub = ShardedUHNSW(
        SegmentedGraphs(
            graphs1=[segs.graphs1[i] for i in alive],
            graphs2=[segs.graphs2[i] for i in alive],
            global_ids=[segs.global_ids[i].copy() for i in alive],
        ),
        idx._X_host, params=idx.params, sharded_params=idx.sharded_params,
    )
    sub._next_id = idx._next_id
    for v, g in zip(idx.delta.vectors(), idx.delta.ids()):
        sub.delta.add(np.asarray(v), int(g))
    return sub


@pytest.mark.parametrize("policy_kw", [
    dict(policy="independent"),
    dict(policy="two_phase", probe=2),
    dict(policy="round_robin", probe=2),
], ids=["independent", "two_phase", "round_robin"])
def test_degraded_bitwise_equals_subset_index(policy_kw, base_index, data):
    idx = fresh_wrap(base_index, data, delta_capacity=64,
                     sharded_params=ShardedParams(**policy_kw))
    rng = np.random.default_rng(3)
    for _ in range(5):  # delta hits ride along at reduced coverage
        idx.add((data.mean(axis=0)
                 + 3.0 * rng.standard_normal(D)).astype(np.float32))
    alive = [0, 2, 3]
    idx.health.quarantine(1)
    sub = _subset_clone(idx, alive)
    Q = data[:16]
    for p in P_GRID:
        ids_d, dists_d, st_d = idx.search(Q, p, k=8)
        ids_s, dists_s, st_s = sub.search(Q, p, k=8)
        np.testing.assert_array_equal(np.asarray(ids_d), np.asarray(ids_s))
        np.testing.assert_array_equal(np.asarray(dists_d),
                                      np.asarray(dists_s))
        assert st_d.degraded and not st_s.degraded
    # mixed-p vector rides the same programs
    p_vec = np.array([0.5, 1.0, 1.25, 2.0] * 4, np.float32)
    ids_d, dists_d, _ = idx.search(Q, p_vec, k=8)
    ids_s, dists_s, _ = sub.search(Q, p_vec, k=8)
    np.testing.assert_array_equal(np.asarray(ids_d), np.asarray(ids_s))
    np.testing.assert_array_equal(np.asarray(dists_d), np.asarray(dists_s))


def test_coverage_frac_is_exact(base_index, data):
    idx = fresh_wrap(base_index, data, delta_capacity=64)
    sizes = [g.n for g in idx.segments.graphs1]
    rng = np.random.default_rng(5)
    for _ in range(5):
        idx.add(rng.standard_normal(D).astype(np.float32))
    idx.health.quarantine(1)
    expect = (sum(sizes) - sizes[1] + 5) / (sum(sizes) + 5)
    assert idx.coverage_frac() == pytest.approx(expect, abs=1e-12)
    _, _, st = idx.search(data[:4], 1.3, k=5)
    assert st.coverage_frac == pytest.approx(expect, abs=1e-12)
    assert st.degraded


# ---------------------------------------------------------------------------
# NaN poison: query-time guard, canary probes, zero leaked ids
# ---------------------------------------------------------------------------


def test_poison_detected_at_every_p_and_never_returned(base_index, data):
    idx = fresh_wrap(base_index, data, deep=True)
    gids = set(map(int, poison_segment(idx, 2)))
    Q = data[:8]
    for p in P_GRID:
        ids, dists, st = idx.search(Q, p, k=5)
        assert np.asarray(st.poisoned).any(), f"p={p}: guard missed"
        got = {int(i) for i in np.asarray(ids).ravel() if i >= 0}
        assert not (got & gids), f"p={p}: poisoned ids leaked"
        real = np.asarray(ids) >= 0
        assert np.isfinite(np.asarray(dists)[real]).all()


def test_canary_probe_localizes_poison(base_index, data):
    idx = fresh_wrap(base_index, data, deep=True)
    poison_segment(idx, 2)
    assert idx.canary_probe(3, seed=CHAOS) is True
    assert idx.canary_probe(2, seed=CHAOS) is False
    # subset probes see only their own segments' poison
    c_clean = idx.search_stage_candidates(data[:4], 2.0, k=5, alive=[0, 1])
    c_bad = idx.search_stage_candidates(data[:4], 2.0, k=5, alive=[2, 3])
    assert not np.asarray(c_clean.poisoned).any()
    assert np.asarray(c_bad.poisoned).any()


# ---------------------------------------------------------------------------
# engine: bisection to the segment, bounded probes, recovery, floors
# ---------------------------------------------------------------------------


def _durable_engine(data, td, min_coverage=0.0, max_retries=2,
                    injector=None):
    idx = ShardedUHNSW.build(data, num_segments=4, m=8,
                             params=UHNSWParams(t=32), seed=0)
    dur = DurableIndex.create(idx, td)
    eng = ServingEngine(
        dur,
        EnginePolicy(min_bucket=4, max_batch=16, max_wait_ms=0.0,
                     max_retries=max_retries, min_coverage=min_coverage),
        clock=ManualClock(), fault_injector=injector)
    return dur, eng


def test_engine_bisects_poison_to_segment_within_bound(data):
    with tempfile.TemporaryDirectory() as td:
        dur, eng = _durable_engine(data, td)
        eng.serve(make_requests(eng, data, 8))      # warm, clean
        gids = set(map(int, poison_segment(dur, 2)))

        probes = []
        orig = dur.index.search_stage_candidates

        def counting(Q, base_p, k=None, alive=None):
            if alive is not None:
                probes.append(sorted(alive))
            return orig(Q, base_p, k=k, alive=alive)

        dur.index.search_stage_candidates = counting
        out = eng.serve(make_requests(eng, data, 8, start=100))
        del dur.index.search_stage_candidates
        assert len(out) == 8 and not eng.failures
        assert dur.health.state(2) == QUARANTINED
        assert dur.health.alive() == [0, 1, 3]
        got = {int(i) for ids, _ in out.values() for i in np.asarray(ids)}
        assert not (got & gids), "poisoned ids leaked through the engine"
        # detection bound: ceil(log2 S)+1 = 3 probes per poison event
        # (one current-alive-set check + the bisection), at most
        # (max_retries+1) events per wave
        n_events = eng.stats["poison_detected"] and eng.stats["faults"]
        assert len(probes) <= (eng.policy.max_retries + 1) * 3
        assert eng.stats["seg_quarantined"] == 1
        assert eng.stats["poison_detected"] > 0 and n_events


def test_engine_recovers_quarantined_segment_from_snapshot(data):
    with tempfile.TemporaryDirectory() as td:
        dur, eng = _durable_engine(data, td)
        eng.serve(make_requests(eng, data, 4))
        poison_segment(dur, 1)
        eng.serve(make_requests(eng, data, 8, start=100))
        assert dur.health.state(1) == QUARANTINED
        eng.pump()                      # background maintenance slot
        assert dur.health.state(1) == HEALTHY
        assert eng.stats["seg_recovered"] == 1
        assert dur.coverage_frac() == 1.0
        # restored rows are byte-identical to the snapshot (checksummed)
        out = eng.serve(make_requests(eng, data, 4, start=200))
        assert len(out) == 4 and not eng.failures


def test_min_coverage_fails_requests_without_durable_home(base_index, data):
    idx = fresh_wrap(base_index, data)
    for seg in (1, 2, 3):
        idx.health.quarantine(seg)
    eng = ServingEngine(
        idx, EnginePolicy(min_bucket=4, max_batch=16, max_wait_ms=0.0,
                          min_coverage=0.9),
        clock=ManualClock())
    out = eng.serve(make_requests(eng, data, 4))
    assert out == {}
    fails = eng.take_failures()
    assert len(fails) == 4
    for err in fails.values():
        assert "coverage" in err and "0.9" in err  # coverage attached
    assert eng.stats["min_coverage_failed"] == 4
    assert eng.stats["failed"] == 4


def test_min_coverage_retries_after_recovery(data):
    with tempfile.TemporaryDirectory() as td:
        dur, eng = _durable_engine(data, td, min_coverage=0.9,
                                   max_retries=3)
        eng.serve(make_requests(eng, data, 4))
        poison_segment(dur, 3)
        # poison -> quarantine -> retry at 0.75 < 0.9 -> inline recovery
        # -> CoverageError retry -> served at full coverage
        out = eng.serve(make_requests(eng, data, 8, start=100))
        assert len(out) == 8 and not eng.failures
        assert eng.stats["seg_recovered"] >= 1
        assert dur.health.state(3) == HEALTHY
        assert eng.stats["min_coverage_failed"] == 0


def test_segment_fault_sites_drive_ewma_quarantine(base_index, data):
    idx = fresh_wrap(base_index, data)
    inj = FaultInjector(rate=1.0, seed=CHAOS, sites=(segment_site(1),))
    eng = ServingEngine(
        idx, EnginePolicy(min_bucket=4, max_batch=16, max_wait_ms=0.0,
                          max_retries=6),
        clock=ManualClock(), fault_injector=inj)
    out = eng.serve(make_requests(eng, data, 4))
    # rate-1.0 faults on segment 1's site walk its EWMA to quarantine
    # (4 failures at alpha=0.3), after which its site is no longer drawn
    # and the wave serves at reduced coverage
    assert idx.health.state(1) == QUARANTINED
    assert len(out) == 4 and not eng.failures
    assert inj.injected_by_site == {segment_site(1): 4}
    assert eng.stats["seg_quarantined"] == 1


# ---------------------------------------------------------------------------
# satellite: injector seeded-schedule + reset contract
# ---------------------------------------------------------------------------


def _schedule(inj, calls):
    out = []
    for site in calls:
        try:
            inj.check(site)
            out.append(None)
        except Exception as e:
            out.append(type(e).__name__)
    return out


def test_filtered_sites_consume_no_draw():
    seed = CHAOS * 31 + 5
    plain = _schedule(FaultInjector(rate=0.5, seed=seed, sites=("search",)),
                      ["search"] * 20)
    # interleaving disabled sites (filtered classic + unnamed segment)
    # must not shift the schedule the enabled site sees
    mixed_calls = []
    for _ in range(20):
        mixed_calls += [segment_site(0), "verify", "search"]
    mixed = _schedule(FaultInjector(rate=0.5, seed=seed, sites=("search",)),
                      mixed_calls)
    assert [o for c, o in zip(mixed_calls, mixed) if c == "search"] == plain
    assert all(o is None for c, o in zip(mixed_calls, mixed) if c != "search")


def test_segment_wildcard_enables_all_segment_sites():
    inj = FaultInjector(rate=1.0, seed=CHAOS, sites=("segment",))
    assert inj.enabled(segment_site(0)) and inj.enabled(segment_site(7))
    assert not inj.enabled("search")    # filter excludes classic sites
    with pytest.raises(Exception):
        inj.check(segment_site(3))
    assert inj.injected_by_site == {segment_site(3): 1}


def test_reset_replays_schedule_and_clears_counters():
    inj = FaultInjector(rate=0.5, timeout_rate=0.2, seed=CHAOS * 7 + 1)
    calls = ["search", "verify", "collect"] * 10
    first = _schedule(inj, calls)
    counts = dict(inj.injected_by_site)
    assert inj.injected == sum(counts.values()) and inj.injected > 0
    inj.reset()
    assert inj.injected == 0 and inj.injected_by_site == {}
    assert _schedule(inj, calls) == first   # byte-identical replay
    assert dict(inj.injected_by_site) == counts


def test_unknown_site_rejected():
    with pytest.raises(AssertionError):
        FaultInjector(sites=("search", "bogus"))
    FaultInjector(sites=("search", "segment", "segment:3"))  # all legal


# ---------------------------------------------------------------------------
# satellite: durability x quarantine interactions
# ---------------------------------------------------------------------------


def test_wal_replay_into_index_with_quarantined_segments(data):
    from repro.index.persist import recover
    with tempfile.TemporaryDirectory() as td:
        idx = ShardedUHNSW.build(data, num_segments=4, m=8,
                                 params=UHNSWParams(t=32), seed=0)
        dur = DurableIndex.create(idx, td)
        rng = np.random.default_rng(CHAOS)
        vecs = rng.standard_normal((6, D)).astype(np.float32) * 3
        added = [dur.add(v) for v in vecs[:3]]
        dur.health.quarantine(2)         # quarantine mid-stream
        added += [dur.add(v) for v in vecs[3:]]
        # delta tier always serves; quarantine only drops frozen coverage
        for gid, v in zip(added, vecs):
            ids, _, st = dur.search(v[None], 1.3, k=1)
            assert int(np.asarray(ids)[0, 0]) == gid
            assert st.degraded and st.coverage_frac < 1.0
        dur.close()
        # recovery replays the WAL into a FRESH health generation: the
        # quarantine was runtime state, not durable state
        rec = recover(td, params=UHNSWParams(t=32))
        assert rec.health.alive() == list(range(rec.num_segments))
        assert rec.n == dur.n
        for gid, v in zip(added, vecs):
            ids, _, st = rec.search(v[None], 1.3, k=1)
            assert int(np.asarray(ids)[0, 0]) == gid
            assert not st.degraded


def test_compaction_resizes_health_tracker(base_index, data):
    idx = fresh_wrap(base_index, data, delta_capacity=64)
    idx.health.quarantine(3)
    rng = np.random.default_rng(2)
    for _ in range(4):
        idx.add(rng.standard_normal(D).astype(np.float32))
    idx.compact()
    # new frozen segment arrives HEALTHY; old quarantine survives
    assert idx.health.num_segments == idx.num_segments
    assert idx.health.state(3) == QUARANTINED
    assert idx.health.state(idx.num_segments - 1) == HEALTHY
