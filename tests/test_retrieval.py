"""Retrieval integration: universal vector service + kNN-LM over U-HNSW."""

import numpy as np
import pytest

from repro.core.uhnsw import UHNSW, UHNSWParams
from repro.retrieval.knn_lm import KnnLM
from repro.retrieval.service import QueryRequest, UniversalVectorService


@pytest.fixture(scope="module")
def service(small_ds, graphs_bulk):
    return UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=150))
    )


def test_mixed_p_request_stream(service, small_ds):
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(24):
        p = rng.choice([0.5, 0.7, 1.0, 1.3, 2.0])
        reqs.append(QueryRequest(vector=small_ds.queries[i % 8], p=float(p),
                                 k=5, request_id=i))
    out = service.serve(reqs)
    assert set(out) == set(range(24))
    for ids, dists in out.values():
        assert len(ids) == 5
        assert (np.diff(dists) >= -1e-5).all()
    # identical vectors with identical p must agree regardless of grouping
    a = service.serve([QueryRequest(small_ds.queries[0], 0.7, 5, 0)])
    b = service.serve([QueryRequest(small_ds.queries[0], 0.7, 5, 1),
                       QueryRequest(small_ds.queries[1], 1.3, 5, 2)])
    np.testing.assert_array_equal(a[0][0], b[1][0])


def test_service_stats_accumulate(service, small_ds):
    before = dict(service.stats)
    service.serve([QueryRequest(small_ds.queries[0], 0.8, 5, 0)])
    assert service.stats["queries"] == before["queries"] + 1
    assert service.stats["n_p"] > before["n_p"]


def test_knn_lm_recalls_memorized_continuations(rng):
    """Datastore of (hidden, next_token): querying with a stored hidden state
    must put high probability on the memorized token, for any p."""
    n, d, v = 1200, 24, 50
    hidden = rng.standard_normal((n, d)).astype(np.float32) * 2
    next_tokens = rng.integers(0, v, size=n).astype(np.int32)
    knn = KnnLM.build_from_hidden(hidden, next_tokens, vocab_size=v, m=8,
                                  k=4, temperature=10.0)
    q = hidden[:16] + 0.01 * rng.standard_normal((16, d)).astype(np.float32)
    for p in (0.6, 1.0, 1.6):
        lp = knn.knn_logprobs(q, p)
        pred = lp.argmax(axis=1)
        acc = (pred == next_tokens[:16]).mean()
        assert acc > 0.85, f"p={p}: acc {acc}"


def test_knn_lm_mixing_lowers_nll(rng):
    n, d, v = 800, 16, 32
    hidden = rng.standard_normal((n, d)).astype(np.float32)
    next_tokens = rng.integers(0, v, size=n).astype(np.int32)
    knn = KnnLM.build_from_hidden(hidden, next_tokens, vocab_size=v, m=8,
                                  k=4, lam=0.5, temperature=10.0)
    q = hidden[:32]
    gold = next_tokens[:32]
    # a deliberately uninformative LM distribution
    lm_logprobs = np.full((32, v), -np.log(v))
    mixed = knn.mix(lm_logprobs, q, p=0.8)
    nll_lm = -lm_logprobs[np.arange(32), gold].mean()
    nll_mixed = -mixed[np.arange(32), gold].mean()
    assert nll_mixed < nll_lm - 0.5
