"""Per-architecture smoke tests: reduced config, one train step on CPU,
output shapes + finite loss + finite grads (deliverable f)."""

import jax
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist.sharding import Runtime, set_mesh
from repro.launch.mesh import make_local_mesh
from repro.models.model import loss_fn
from repro.models.params import count_params, init_params, layer_plan
from repro.train.step import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def rt():
    return Runtime(mesh=make_local_mesh())


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_train_step(arch_id, rt):
    cfg = get_arch(arch_id, smoke=True)
    tc = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    pipe = SyntheticTokenPipeline(cfg, global_batch=2, seq_len=32, seed=1)
    with set_mesh(rt.mesh):
        state = init_train_state(cfg, rt, tc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, rt, tc), donate_argnums=(0,))
        state, metrics = step(state, pipe.batch(0))
        loss = float(metrics["loss"])
        assert np.isfinite(loss), f"{arch_id}: loss={loss}"
        assert float(metrics["grad_norm"]) > 0
        # params updated and still finite
        leaf = jax.tree.leaves(state["params"])[0]
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_param_counts(arch_id):
    """The full (published) configs must land near their nameplate sizes."""
    expected = {
        "musicgen_large": (1.5e9, 3.5e9),
        "tinyllama_1_1b": (1.0e9, 1.3e9),
        "qwen2_5_32b": (30e9, 36e9),
        "nemotron_4_340b": (330e9, 350e9),
        "minitron_4b": (3.5e9, 5e9),
        "recurrentgemma_2b": (2.0e9, 3.6e9),
        "deepseek_v3_671b": (650e9, 690e9),
        "llama4_scout_17b_a16e": (100e9, 115e9),
        "mamba2_1_3b": (1.2e9, 1.6e9),
        "llava_next_34b": (32e9, 36e9),
    }
    cfg = get_arch(arch_id)
    n = count_params(cfg)
    lo, hi = expected[arch_id]
    assert lo <= n <= hi, f"{arch_id}: {n / 1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_arch("deepseek_v3_671b")
    active = cfg.active_param_count()
    total = cfg.param_count()
    # DeepSeek-V3: 37B active of 671B total
    assert 30e9 < active < 45e9, active / 1e9
    assert total / active > 14


def test_layer_plans():
    assert layer_plan(get_arch("deepseek_v3_671b")) == [
        (("mla+ffn",), 3), (("mla+moe",), 58)
    ]
    assert layer_plan(get_arch("recurrentgemma_2b")) == [
        (("rglru+ffn", "rglru+ffn", "local_attn+ffn"), 8), (("rglru+ffn",), 2)
    ]
    assert layer_plan(get_arch("mamba2_1_3b")) == [(("ssd",), 48)]
    assert layer_plan(get_arch("tinyllama_1_1b")) == [(("gqa+ffn",), 22)]


def test_sub_quadratic_gating():
    subq = {a for a in ARCH_IDS if get_arch(a).sub_quadratic}
    assert subq == {"recurrentgemma_2b", "mamba2_1_3b"}


@pytest.mark.parametrize("arch_id", ["musicgen_large", "llava_next_34b"])
def test_frontend_stub_inputs(arch_id, rt):
    """Audio/VLM archs consume precomputed frame/patch embeddings."""
    cfg = get_arch(arch_id, smoke=True)
    pipe = SyntheticTokenPipeline(cfg, global_batch=2, seq_len=16, seed=0)
    batch = pipe.batch(0)
    assert "frames" in batch and batch["frames"].shape == (2, 16, cfg.frontend_dim)
    with set_mesh(rt.mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        loss, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, rt))(params, batch)
    assert np.isfinite(float(loss))
