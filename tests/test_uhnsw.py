"""Algorithm 1 (U-HNSW query) semantics + end-to-end recall."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hnsw import exact_topk
from repro.core.metrics import numpy_lp
from repro.core.uhnsw import UHNSW, UHNSWParams, recall, verify_candidates


def _reference_verify(Q, cand_ids, X, p, k, kappa, tau):
    """Literal NumPy transcription of paper Algorithm 1 lines 7-11."""
    out_ids, out_np = [], []
    for qi in range(Q.shape[0]):
        q = Q[qi]
        C = list(cand_ids[qi])
        dist = {c: float(numpy_lp(q[None], X[c][None], p, root=False)[0, 0]) for c in C[:k]}
        R = sorted(C[:k], key=lambda c: (dist[c], c))
        n_p = k
        i = k
        while i + kappa <= len(C):
            batch = C[i : i + kappa]
            i += kappa
            for c in batch:
                dist[c] = float(numpy_lp(q[None], X[c][None], p, root=False)[0, 0])
            n_p += kappa
            union = R + batch
            R_new = sorted(union, key=lambda c: (dist[c], c))[:k]
            inter = len(set(R_new) & set(R))
            R = R_new
            if inter / k >= tau:
                break
        out_ids.append(R)
        out_np.append(n_p)
    return np.array(out_ids), np.array(out_np)


def test_verify_matches_reference(small_ds, rng):
    """The jitted while_loop implements Algorithm 1 exactly."""
    X = small_ds.data
    Q = small_ds.queries[:6]
    k, kappa, tau, t = 10, 5, 0.9, 60
    cand = np.stack([rng.permutation(small_ds.n)[:t] for _ in range(len(Q))]).astype(np.int32)
    ids, dists, n_p, iters, *_ = verify_candidates(
        jnp.asarray(Q), jnp.asarray(cand), jnp.asarray(X), 0.7, k, kappa, tau
    )
    ref_ids, ref_np = _reference_verify(Q, cand, X, 0.7, k, kappa, tau)
    # same result *sets* (order may differ on exact ties)
    for i in range(len(Q)):
        assert set(np.asarray(ids)[i].tolist()) == set(ref_ids[i].tolist())
    np.testing.assert_array_equal(np.asarray(n_p), ref_np)


def test_early_termination_saves_work(small_ds, graphs_bulk):
    """tau < 1 must verify fewer candidates than exhaustive re-ranking."""
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=200))
    Q = jnp.asarray(small_ds.queries)
    _, _, stats = idx.search(Q, 0.8, 20)
    n_p = np.asarray(stats.n_p)
    assert (n_p <= 200).all()
    assert n_p.mean() < 150  # early termination really triggers
    assert (n_p >= 20).all()  # at least the initial K


@pytest.mark.parametrize("p", [0.5, 0.8, 1.2, 1.4, 1.7, 2.0])
def test_end_to_end_recall(p, small_ds, graphs_bulk):
    """Paper target: recall >= 0.9 across the universal p range."""
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=200))
    X = jnp.asarray(small_ds.data)
    Q = jnp.asarray(small_ds.queries)
    K = 20
    ids, dists, stats = idx.search(Q, p, K)
    true_ids, _ = exact_topk(X, Q, p, K)
    r = recall(ids, true_ids)
    assert r >= 0.9, f"p={p}: recall {r}"


def test_base_metric_shortcut(small_ds, graphs_bulk):
    """p == base metric skips verification entirely (N_p == 0)."""
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=150))
    Q = jnp.asarray(small_ds.queries[:8])
    for p in (1.0, 2.0):
        _, _, stats = idx.search(Q, p, 10)
        assert float(stats.n_p.sum()) == 0
        assert stats.base_p == p


def test_base_index_selection(graphs_bulk):
    idx = UHNSW(*graphs_bulk)
    assert idx.base_graph_for(0.5)[1] == 1.0
    assert idx.base_graph_for(1.4)[1] == 1.0
    assert idx.base_graph_for(1.5)[1] == 2.0
    assert idx.base_graph_for(2.0)[1] == 2.0


def test_returned_distances_are_exact_lp(small_ds, graphs_bulk):
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=150))
    Q = jnp.asarray(small_ds.queries[:4])
    p = 1.3
    ids, dists, _ = idx.search(Q, p, 10)
    ids, dists = np.asarray(ids), np.asarray(dists)
    for i in range(len(ids)):
        want = numpy_lp(small_ds.queries[i][None], small_ds.data[ids[i]], p)[0]
        np.testing.assert_allclose(dists[i], want, rtol=2e-4)


def test_modeled_cost_eq1(graphs_bulk, small_ds):
    """Eq. 1: T = N_b T_b + N_p T_p with T_p >> T_b for general p."""
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=150))
    Q = jnp.asarray(small_ds.queries[:8])
    _, _, stats = idx.search(Q, 0.8, 10)
    cost = idx.modeled_query_cost(stats, 0.8, small_ds.d)
    assert cost["T_p"] > 5 * cost["T_b"]
    assert cost["total"] == pytest.approx(
        cost["N_b"] * cost["T_b"] + cost["N_p"] * cost["T_p"]
    )
