"""Training substrate: loss decreases, microbatch equivalence, gradient
compression + error feedback, checkpoint-resume trajectory continuity."""

import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist.sharding import Runtime, set_mesh
from repro.launch.mesh import make_local_mesh
from repro.train.compression import compress_decompress_grads, compression_init
from repro.train.step import TrainConfig, init_train_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("tinyllama_1_1b", smoke=True)
    rt = Runtime(mesh=make_local_mesh())
    return cfg, rt


def _run_steps(cfg, rt, tc, n_steps, batch_fn, seed=0):
    with set_mesh(rt.mesh):
        state = init_train_state(cfg, rt, tc, jax.random.PRNGKey(seed))
        step = jax.jit(make_train_step(cfg, rt, tc), donate_argnums=(0,))
        losses = []
        for i in range(n_steps):
            state, m = step(state, batch_fn(i))
            losses.append(float(m["loss"]))
    return losses, state


def test_loss_decreases(setup):
    cfg, rt = setup
    tc = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    pipe = SyntheticTokenPipeline(cfg, 8, 64, seed=0)
    losses, _ = _run_steps(cfg, rt, tc, 25, pipe.batch)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_microbatch_equivalence(setup):
    """Gradient accumulation over 2 microbatches == full-batch step."""
    cfg, rt = setup
    pipe = SyntheticTokenPipeline(cfg, 8, 32, seed=5)
    batch = pipe.batch(0)
    tc1 = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=4, microbatches=1)
    tc2 = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=4, microbatches=2)

    def batch1(_):
        return batch

    def batch2(_):
        return jax.tree.map(
            lambda a: a.reshape(2, a.shape[0] // 2, *a.shape[1:]), batch
        )

    _, s1 = _run_steps(cfg, rt, tc1, 1, batch1)
    _, s2 = _run_steps(cfg, rt, tc2, 1, batch2)
    w1 = np.asarray(jax.tree.leaves(s1["params"])[0], dtype=np.float32)
    w2 = np.asarray(jax.tree.leaves(s2["params"])[0], dtype=np.float32)
    np.testing.assert_allclose(w1, w2, rtol=0, atol=2e-2)  # bf16 params


def test_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 1e-3,
                          dtype=jnp.float32)}
    err = compression_init(g)
    total_true = np.zeros((64, 64))
    total_deq = np.zeros((64, 64))
    for step in range(20):
        gs = jax.tree.map(lambda a: a * (1 + 0.1 * step), g)
        deq, err = compress_decompress_grads(gs, err)
        total_true += np.asarray(gs["w"])
        total_deq += np.asarray(deq["w"])
    # error feedback keeps the *accumulated* quantized stream faithful
    resid = np.abs(total_deq - total_true).max()
    scale = np.abs(total_true).max()
    assert resid < 0.02 * scale


def test_compressed_training_converges(setup):
    cfg, rt = setup
    tc = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=30,
                     grad_compression=True)
    pipe = SyntheticTokenPipeline(cfg, 8, 64, seed=0)
    losses, _ = _run_steps(cfg, rt, tc, 20, pipe.batch)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


@pytest.mark.slow
def test_crash_resume_trajectory(tmp_path):
    """Kill at step 7, resume, and match the uninterrupted trajectory."""
    env = {"PYTHONPATH": "src"}
    import os
    env = {**os.environ, "PYTHONPATH": "src"}
    base = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "tinyllama_1_1b", "--smoke", "--steps", "12",
        "--batch", "4", "--seq", "32", "--save-every", "5",
        "--log-every", "1",
    ]
    # uninterrupted reference
    ref = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ref"),
                "--metrics-out", str(tmp_path / "ref.json")],
        env=env, capture_output=True, text=True, cwd=Path(__file__).parent.parent,
    )
    assert ref.returncode == 0, ref.stderr[-2000:]
    # crash at 7, then resume
    crash = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ft"), "--fail-at-step", "7"],
        env=env, capture_output=True, text=True, cwd=Path(__file__).parent.parent,
    )
    assert crash.returncode == 42
    resume = subprocess.run(
        base + ["--ckpt-dir", str(tmp_path / "ft"),
                "--metrics-out", str(tmp_path / "ft.json")],
        env=env, capture_output=True, text=True, cwd=Path(__file__).parent.parent,
    )
    assert resume.returncode == 0, resume.stderr[-2000:]
    assert "resumed from step 4" in resume.stdout
    import json
    ref_losses = json.loads((tmp_path / "ref.json").read_text())["losses"]
    ft_losses = json.loads((tmp_path / "ft.json").read_text())["losses"]
    # the resumed run covers steps 5..11; its final losses must match the
    # uninterrupted run's (deterministic pipeline + bitwise state restore)
    np.testing.assert_allclose(ft_losses[-3:], ref_losses[-3:], atol=1e-2)
