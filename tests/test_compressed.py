"""Compressed storage band (DESIGN.md §10): certified bounds, two-band parity.

The band's contract is *certification*, not approximation — every test
here pins one leg of it:

  * admissibility — the (deflated) compressed lower bound never exceeds
    the true f32 power sum, on random corpora AND on adversarial rows
    parked at quantization midpoints (the worst dequant error);
  * screen soundness — a candidate the screen kills provably could not
    enter the top-k (its true power sum exceeds the threshold), and
    padding ids never survive;
  * dispatch parity — the Pallas screen kernel (interpret mode) is
    bitwise the blocked jnp reference;
  * two-band exactness — `verify_candidates(band=...)` returns ids AND
    distances bitwise-identical to the uncompressed path at every p,
    scalar and vector, and end-to-end through `UHNSW.search`;
  * energy order — the permutation is a bijection, variance-sorted, and
    search under `energy_perm=True` returns the same ids;
  * persistence — a snapshot carries the band byte-for-byte (codes,
    scales, radii, manifest-authoritative perm) through save/load.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lp_ops import BOUND_SLACK
from repro.core.uhnsw import UHNSW, UHNSWParams, verify_candidates
from repro.index.compressed import (
    CompressedBand,
    build_band,
    compressed_lower_bound,
    energy_order,
)
from repro.index.persist import load_snapshot, read_manifest, save_snapshot
from repro.index.sharded import ShardedUHNSW
from repro.kernels.ops import lp_gather_distance, lp_gather_screen

P_GRID = (0.5, 0.8, 1.25, 2.0)


def _corpus(n=300, d=48, seed=0, nq=6):
    """Heterogeneous per-coordinate energy (the regime the band targets)."""
    rng = np.random.default_rng(seed)
    dim_scale = np.exp(rng.standard_normal(d) * 0.8).astype(np.float32)
    X = (rng.standard_normal((n, d)) * dim_scale).astype(np.float32)
    Q = (rng.standard_normal((nq, d)) * dim_scale).astype(np.float32)
    return X, Q


def _true_power_sums(Q, X, p):
    """f32 true Lp power sums (B, n) — the quantity the bound certifies."""
    return np.asarray(
        lp_gather_distance(
            jnp.asarray(Q),
            jnp.broadcast_to(jnp.arange(X.shape[0], dtype=jnp.int32),
                             (Q.shape[0], X.shape[0])),
            jnp.asarray(X), p, root=False))


def _midpoint_corpus(d=32, seed=3):
    """Rows parked exactly at quantization midpoints: scale * (k + 0.5).

    round() moves each coordinate by half a step — the maximum possible
    dequant error — so radii are as large as the scheme ever makes them
    and the max(|q - x̂| - radius, 0) clamp is exercised at its boundary.
    """
    rng = np.random.default_rng(seed)
    # a carrier row pins absmax (hence scale); midpoint rows ride inside
    carrier = (np.exp(rng.standard_normal(d) * 0.5) * 127).astype(np.float32)
    scale = np.maximum(np.abs(carrier), 1e-12) / 127.0
    ks = rng.integers(-126, 126, size=(64, d)).astype(np.float32)
    mids = ((ks + 0.5) * scale).astype(np.float32)
    X = np.concatenate([carrier[None, :], -carrier[None, :], mids])
    Q = (rng.standard_normal((4, d)) * scale * 64).astype(np.float32)
    return X.astype(np.float32), Q


# ---------------------------------------------------------------------------
# admissibility of the certified lower bound
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", P_GRID)
def test_bound_admissible_random(p):
    X, Q = _corpus()
    band = build_band(X)
    Qp = jnp.take(jnp.asarray(Q), band.perm, axis=1)
    lb = np.asarray(compressed_lower_bound(Qp, band.codes, band.scale,
                                           band.radius, p))
    true = _true_power_sums(Q, X, p)
    # the scan compares the BOUND_SLACK-deflated bound; that deflation is
    # what absorbs accumulated f32 rounding on both sides
    assert np.all(lb * (1.0 - BOUND_SLACK) <= true), \
        f"bound violation at p={p}: max excess " \
        f"{float((lb * (1 - BOUND_SLACK) - true).max())}"


@pytest.mark.parametrize("p", P_GRID)
def test_bound_admissible_midpoint_adversary(p):
    X, Q = _midpoint_corpus()
    band = build_band(X)
    Qp = jnp.take(jnp.asarray(Q), band.perm, axis=1)
    lb = np.asarray(compressed_lower_bound(Qp, band.codes, band.scale,
                                           band.radius, p))
    true = _true_power_sums(Q, X, p)
    assert np.all(lb * (1.0 - BOUND_SLACK) <= true)
    # the adversary really does sit at max dequant error: radii ~ scale/2
    r = np.asarray(band.radius)
    s = np.asarray(band.scale)
    assert np.all(r >= 0.49 * s), "midpoint rows failed to maximize radii"


def test_bound_admissible_vector_p():
    X, Q = _corpus(seed=7)
    band = build_band(X)
    Qp = jnp.take(jnp.asarray(Q), band.perm, axis=1)
    ps = np.resize(np.asarray(P_GRID, np.float32), Q.shape[0])
    lb = np.asarray(compressed_lower_bound(Qp, band.codes, band.scale,
                                           band.radius, jnp.asarray(ps)))
    for i, p in enumerate(ps):
        true = _true_power_sums(Q[i:i + 1], X, float(p))
        assert np.all(lb[i] * (1.0 - BOUND_SLACK) <= true[0]), f"p={p}"


def test_bound_tightness_not_vacuous():
    """The bound must actually bite (> 90% of the true sum on smooth
    data), else the screen never kills anything and the band is dead
    weight that the parity tests would never notice."""
    X, Q = _corpus(seed=2)
    band = build_band(X)
    Qp = jnp.take(jnp.asarray(Q), band.perm, axis=1)
    for p in (0.5, 2.0):
        lb = np.asarray(compressed_lower_bound(Qp, band.codes, band.scale,
                                               band.radius, p))
        true = _true_power_sums(Q, X, p)
        ratio = lb / np.maximum(true, 1e-20)
        assert float(np.median(ratio)) > 0.9, f"vacuous bound at p={p}"


# ---------------------------------------------------------------------------
# the blocked screen: soundness + kernel/reference parity
# ---------------------------------------------------------------------------


def _screen_case(p, d=32, c=64, seed=5):
    X, Q = _corpus(n=200, d=d, seed=seed, nq=4)
    band = build_band(X)
    Qp = jnp.take(jnp.asarray(Q), band.perm, axis=1)
    rng = np.random.default_rng(seed)
    ids = np.stack([rng.permutation(X.shape[0])[:c] for _ in Q])
    ids[:, -3:] = [-1, X.shape[0], -1]          # padding must die
    ids = jnp.asarray(ids.astype(np.int32))
    true = _true_power_sums(Q, X, p if np.isscalar(p) else 1.0)
    if np.isscalar(p):
        # a mid-quantile threshold: some kills, some survivors
        thr = jnp.asarray(np.quantile(true, 0.25, axis=1).astype(np.float32))
    else:
        thr = jnp.full((Q.shape[0],), jnp.inf)
    sb = jnp.zeros(ids.shape, jnp.float32)      # no base bounds: screen only
    return X, Q, band, Qp, ids, thr, sb


@pytest.mark.parametrize("p", [0.5, 0.8, 1.25, 2.0])
def test_screen_kills_are_certified(p):
    X, Q, band, Qp, ids, thr, sb = _screen_case(p)
    keep, nd = lp_gather_screen(Qp, ids, band.codes, band.scale, band.radius,
                                thr, sb, p)
    keep = np.asarray(keep)
    ids_np = np.asarray(ids)
    valid = (ids_np >= 0) & (ids_np < X.shape[0])
    assert not np.any(keep & ~valid), "padding survived the screen"
    assert keep[valid].any(), "screen killed everything: thresholds bogus"
    true = _true_power_sums(Q, X, p)
    thr_np = np.asarray(thr)
    for b in range(ids_np.shape[0]):
        killed = ids_np[b][valid[b] & ~keep[b]]
        # soundness: every certified kill truly exceeds the threshold
        assert np.all(true[b, killed] > thr_np[b]), f"unsound kill row {b}"
    assert np.all(np.asarray(nd) >= 0)


@pytest.mark.parametrize("vec_p", [False, True])
def test_screen_kernel_matches_reference(vec_p):
    """interpret-mode Pallas screen == blocked jnp reference, bitwise."""
    p = jnp.asarray(np.resize([0.8, 2.0, 1.25, 0.5], 4).astype(np.float32)) \
        if vec_p else 0.8
    X, Q, band, Qp, ids, thr, sb = _screen_case(1.0 if vec_p else p, d=32)
    ref = lp_gather_screen(Qp, ids, band.codes, band.scale, band.radius,
                           thr, sb, p)                       # off-TPU ref
    ker = lp_gather_screen(Qp, ids, band.codes, band.scale, band.radius,
                           thr, sb, p, interpret=True)       # Pallas path
    np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(ker[0]))
    np.testing.assert_array_equal(np.asarray(ref[1]), np.asarray(ker[1]))


# ---------------------------------------------------------------------------
# two-band verification: bitwise parity with the uncompressed path
# ---------------------------------------------------------------------------


def _verify_case(d=48, seed=9):
    X, Q = _corpus(n=400, d=d, seed=seed, nq=5)
    rng = np.random.default_rng(seed)
    t = 60
    cand = np.stack([rng.permutation(X.shape[0])[:t] for _ in Q])
    # sort by L1 base distance, like the beam hands candidates over
    base = np.abs(Q[:, None, :] - X[cand]).sum(-1)
    order = np.argsort(base, axis=1, kind="stable")
    cand = np.take_along_axis(cand, order, axis=1).astype(np.int32)
    base = np.take_along_axis(base, order, axis=1).astype(np.float32)
    return (jnp.asarray(Q), jnp.asarray(X), jnp.asarray(cand),
            jnp.asarray(base))


@pytest.mark.parametrize("p", P_GRID)
def test_two_band_bitwise_parity_scalar(p):
    Q, X, cand, base = _verify_case()
    band = build_band(X)
    k, kappa, tau = 10, 16, 0.92
    c = verify_candidates(Q, cand, X, p, k, kappa, tau, cand_base=base,
                          base_p=1.0, band=band)
    f = verify_candidates(Q, cand, X, p, k, kappa, tau, abandon=False)
    np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(f[0]),
                                  err_msg=f"ids differ at p={p}")
    np.testing.assert_array_equal(np.asarray(c[1]), np.asarray(f[1]),
                                  err_msg=f"dists differ at p={p}")
    np.testing.assert_array_equal(np.asarray(c[2]), np.asarray(f[2]))
    # the screen actually saved f32 gathers, and band traffic is counted
    assert float(np.mean(np.asarray(c[5]))) < 1.0
    assert float(np.mean(np.asarray(c[6]))) > 0.0
    assert np.all(np.asarray(f[5]) == 1.0) and np.all(np.asarray(f[6]) == 0.0)


def test_two_band_bitwise_parity_vector_p():
    Q, X, cand, base = _verify_case(seed=11)
    band = build_band(X)
    ps = np.resize(np.asarray(P_GRID, np.float32), Q.shape[0])
    k, kappa, tau = 10, 16, 0.92
    c = verify_candidates(Q, cand, X, jnp.asarray(ps), k, kappa, tau,
                          cand_base=base, base_p=1.0, band=band)
    f = verify_candidates(Q, cand, X, jnp.asarray(ps), k, kappa, tau,
                          abandon=False)
    np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(f[0]))
    np.testing.assert_array_equal(np.asarray(c[1]), np.asarray(f[1]))


@pytest.mark.parametrize("p", P_GRID)
def test_uhnsw_search_parity_end_to_end(p, small_ds, graphs_bulk):
    on = UHNSW(*graphs_bulk, UHNSWParams(t=120, kappa=32,
                                         compressed_band=True))
    off = UHNSW(*graphs_bulk, UHNSWParams(t=120, kappa=32, abandon=False))
    Q = jnp.asarray(small_ds.queries[:8])
    ids_c, d_c, st_c = on.search(Q, p, 10)
    ids_f, d_f, st_f = off.search(Q, p, 10)
    np.testing.assert_array_equal(np.asarray(ids_c), np.asarray(ids_f))
    np.testing.assert_array_equal(np.asarray(d_c), np.asarray(d_f))
    if p != 2.0:  # p == base metric takes the exact skip: nothing verifies
        assert float(np.mean(np.asarray(st_c.n_f32_rows_frac))) < 1.0
    else:
        assert float(np.sum(np.asarray(st_c.n_p))) == 0.0


def test_energy_perm_search_same_ids(small_ds, graphs_bulk):
    on = UHNSW(*graphs_bulk, UHNSWParams(t=120, kappa=32, energy_perm=True))
    off = UHNSW(*graphs_bulk, UHNSWParams(t=120, kappa=32))
    Q = jnp.asarray(small_ds.queries[:8])
    for p in (0.8, 1.5):
        ids_e, _, _ = on.search(Q, p, 10)
        ids_o, _, _ = off.search(Q, p, 10)
        np.testing.assert_array_equal(np.asarray(ids_e), np.asarray(ids_o),
                                      err_msg=f"p={p}")


# ---------------------------------------------------------------------------
# energy order: bijection, variance-sorted, round-trip identity
# ---------------------------------------------------------------------------


def test_energy_order_roundtrip_identity():
    X, _ = _corpus(seed=13)
    perm = energy_order(X)
    assert sorted(perm.tolist()) == list(range(X.shape[1]))
    var = np.var(np.asarray(X, np.float64), axis=0)[perm]
    assert np.all(np.diff(var) <= 1e-12), "not in decreasing-variance order"
    inv = np.argsort(perm)
    np.testing.assert_array_equal(X[:, perm][:, inv], X)
    # deterministic, and build_band derives the same ordering
    np.testing.assert_array_equal(perm, energy_order(X))
    np.testing.assert_array_equal(np.asarray(build_band(X).perm), perm)


def test_build_band_deterministic():
    X, _ = _corpus(seed=17)
    a, b = build_band(X), build_band(X)
    np.testing.assert_array_equal(np.asarray(a.codes), np.asarray(b.codes))
    np.testing.assert_array_equal(np.asarray(a.scale), np.asarray(b.scale))
    np.testing.assert_array_equal(np.asarray(a.radius), np.asarray(b.radius))


# ---------------------------------------------------------------------------
# persistence: the band rides the snapshot byte-for-byte
# ---------------------------------------------------------------------------


def test_snapshot_band_roundtrip(tmp_path):
    X, Q = _corpus(n=240, d=24, seed=19)
    params = UHNSWParams(t=80, kappa=32, compressed_band=True)
    idx = ShardedUHNSW.build(X, num_segments=2, m=12, seed=3, params=params)
    band = idx.compressed_band()            # materialize before snapshot
    path = save_snapshot(idx, tmp_path)
    man = read_manifest(path)
    assert man["band"] is not None
    np.testing.assert_array_equal(np.asarray(band.perm),
                                  np.asarray(man["band"]["perm"]))
    back = load_snapshot(path)
    assert isinstance(back._band, CompressedBand)
    np.testing.assert_array_equal(np.asarray(back._band.codes),
                                  np.asarray(band.codes))
    np.testing.assert_array_equal(np.asarray(back._band.scale),
                                  np.asarray(band.scale))
    np.testing.assert_array_equal(np.asarray(back._band.radius),
                                  np.asarray(band.radius))
    np.testing.assert_array_equal(np.asarray(back._band.perm),
                                  np.asarray(band.perm))
    Qj = jnp.asarray(Q)
    for p in (0.5, 1.25):
        a_ids, a_d, _ = idx.search(Qj, p, 10)
        b_ids, b_d, _ = back.search(Qj, p, 10)
        np.testing.assert_array_equal(np.asarray(a_ids), np.asarray(b_ids))
        np.testing.assert_array_equal(np.asarray(a_d), np.asarray(b_d))


def test_snapshot_without_band_has_null_manifest_entry(tmp_path):
    X, _ = _corpus(n=150, d=16, seed=23)
    idx = ShardedUHNSW.build(X, num_segments=2, m=12, seed=3)
    path = save_snapshot(idx, tmp_path)
    assert read_manifest(path)["band"] is None
    assert load_snapshot(path)._band is None
