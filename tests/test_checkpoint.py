"""Checkpoint store: roundtrip, atomicity, async, bf16, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from jax.sharding import NamedSharding, PartitionSpec as P


def _tree():
    return {
        "params": {
            "w": jnp.arange(24, dtype=jnp.bfloat16).reshape(4, 6),
            "b": jnp.ones((3,), jnp.float32) * 0.5,
        },
        "opt": {"step": jnp.int32(7), "m": [jnp.zeros((2, 2))]},
    }


def _shardings(mesh):
    rep = NamedSharding(mesh, P())
    return {
        "params": {"w": rep, "b": rep},
        "opt": {"step": rep, "m": [rep]},
    }


def test_roundtrip(tmp_path):
    mesh = jax.make_mesh((1,), ("data",))
    tree = _tree()
    save_checkpoint(tmp_path, 3, tree)
    skeleton = jax.tree.map(lambda a: a, tree)
    restored, step = restore_checkpoint(tmp_path, skeleton, _shardings(mesh))
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, dtype=np.float32), np.asarray(b, dtype=np.float32)
        )


def test_latest_step_and_multiple(tmp_path):
    t = _tree()
    assert latest_step(tmp_path) is None
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 10, t)
    save_checkpoint(tmp_path, 5, t)
    assert latest_step(tmp_path) == 10


def test_atomic_commit_ignores_partial(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 2, t)
    # simulate a crash mid-write: a stale .tmp directory
    (tmp_path / "step_00000009.tmp").mkdir()
    assert latest_step(tmp_path) == 2
    mesh = jax.make_mesh((1,), ("data",))
    _, step = restore_checkpoint(tmp_path, t, _shardings(mesh))
    assert step == 2


def test_async_checkpointer(tmp_path):
    t = _tree()
    ck = AsyncCheckpointer(tmp_path)
    ck.save(4, t)
    ck.wait()
    assert latest_step(tmp_path) == 4
    mesh = jax.make_mesh((1,), ("data",))
    restored, _ = restore_checkpoint(tmp_path, t, _shardings(mesh))
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"], dtype=np.float32),
        np.asarray(t["params"]["w"], dtype=np.float32),
    )


def test_async_error_surfaces(tmp_path):
    # a directory path under a regular *file* cannot be created — even by root
    blocker = tmp_path / "blocker"
    blocker.write_text("x")
    ck = AsyncCheckpointer(blocker / "sub")
    try:
        ck.save(0, _tree())
        with pytest.raises(Exception):
            ck.wait()
    except (PermissionError, NotADirectoryError):
        pass  # raised synchronously on some systems — equally fine


def test_restore_falls_back_past_corrupt_newest(tmp_path):
    """Crash-landed newest step (manifest truncated mid-write or missing):
    step=None restore warns and falls back to the previous durable step
    instead of trusting the newest directory name blindly."""
    t = _tree()
    save_checkpoint(tmp_path, 2, t)
    save_checkpoint(tmp_path, 5, t)
    (tmp_path / "step_00000005" / "manifest.json").write_text('{"step": 5,')
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.warns(UserWarning, match="skipping non-durable checkpoint"):
        restored, step = restore_checkpoint(tmp_path, t, _shardings(mesh))
    assert step == 2
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["b"]), np.asarray(t["params"]["b"]))

    # a *missing* manifest (rename never observed) falls back the same way
    save_checkpoint(tmp_path, 9, t)
    (tmp_path / "step_00000009" / "manifest.json").unlink()
    with pytest.warns(UserWarning, match="skipping non-durable checkpoint"):
        _, step = restore_checkpoint(tmp_path, t, _shardings(mesh))
    assert step == 2


def test_restore_explicit_step_not_second_guessed(tmp_path):
    """An explicitly requested corrupt step raises — no silent fallback."""
    t = _tree()
    save_checkpoint(tmp_path, 2, t)
    save_checkpoint(tmp_path, 5, t)
    (tmp_path / "step_00000005" / "manifest.json").write_text("garbage")
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(Exception):
        restore_checkpoint(tmp_path, t, _shardings(mesh), step=5)


def test_restore_no_durable_step_is_actionable(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    (tmp_path / "step_00000001" / "manifest.json").write_text("{}")
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.warns(UserWarning, match="skipping non-durable checkpoint"):
        with pytest.raises(FileNotFoundError, match="no durable checkpoint"):
            restore_checkpoint(tmp_path, t, _shardings(mesh))


def test_bf16_bit_exact(tmp_path):
    # values that straddle bf16 rounding: must round-trip bit-exactly
    w = (jnp.arange(64, dtype=jnp.float32) * 0.1234567).astype(jnp.bfloat16)
    save_checkpoint(tmp_path, 0, {"w": w})
    mesh = jax.make_mesh((1,), ("data",))
    restored, _ = restore_checkpoint(
        tmp_path, {"w": w}, {"w": NamedSharding(mesh, P())}
    )
    assert (
        np.asarray(restored["w"]).view(np.uint16)
        == np.asarray(w).view(np.uint16)
    ).all()
