"""Straggler watchdog + heartbeat policies."""

import time

from repro.train.monitor import HeartbeatMonitor, StepWatchdog


def test_watchdog_flags_persistent_straggler():
    wd = StepWatchdog(threshold=1.5, patience=3)
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    slow = {0: 1.0, 1: 1.0, 2: 1.0, 3: 2.5}
    assert wd.observe(base) == []
    assert wd.observe(slow) == []       # patience 1
    assert wd.observe(slow) == []       # patience 2
    assert wd.observe(slow) == [3]      # flagged


def test_watchdog_ignores_transient_jitter():
    wd = StepWatchdog(threshold=1.5, patience=3)
    slow = {0: 1.0, 1: 1.0, 2: 1.0, 3: 2.5}
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    wd.observe(slow)
    wd.observe(slow)
    wd.observe(base)  # recovery resets the counter
    assert wd.observe(slow) == []


def test_rebalance_conserves_shards():
    wd = StepWatchdog()
    hosts = list(range(8))
    plan = wd.rebalance_plan(hosts, flagged=[2, 5], shards_per_host=4)
    assert sum(plan.values()) == 32
    assert plan[2] < 4 and plan[5] < 4
    assert all(plan[h] >= 4 for h in hosts if h not in (2, 5))


def test_heartbeat(tmp_path):
    hb = HeartbeatMonitor(str(tmp_path / "hb.json"), timeout_s=100.0)
    assert not hb.is_stalled()  # no file yet
    hb.beat(5, {"loss": 1.0})
    assert hb.last_step() == 5
    assert not hb.is_stalled()
    assert hb.is_stalled(now=time.time() + 200.0)
