"""HNSW construction invariants (both sequential and bulk builders)."""

from collections import deque

import numpy as np


def _components(adj0):
    n = adj0.shape[0]
    comp = np.full(n, -1)
    label = 0
    for s in range(n):
        if comp[s] >= 0:
            continue
        q = deque([s])
        comp[s] = label
        while q:
            u = q.popleft()
            for v in adj0[u]:
                if v >= 0 and comp[v] < 0:
                    comp[v] = label
                    q.append(int(v))
        label += 1
    return label, comp


def _check_invariants(g):
    n = g.n
    assert g.entry_point >= 0 and g.levels[g.entry_point] == g.max_level
    assert len(g.adjacency) == g.max_level + 1
    for l, (mat, nodes, g2l) in enumerate(
        zip(g.adjacency, g.level_nodes, g.local_index)
    ):
        m_max = g.m0 if l == 0 else g.m
        assert mat.shape == (len(nodes), m_max)
        # ids are valid or -1 padding
        assert mat.max() < n
        assert mat.min() >= -1
        # no self-edges
        for row, u in zip(mat, nodes):
            real = row[row >= 0]
            assert u not in real
            # neighbors at level l must themselves have level >= l
            assert (g.levels[real] >= l).all()
            # no duplicate edges
            assert len(set(real.tolist())) == len(real)
        # local index is a correct inverse
        assert (g2l[nodes] == np.arange(len(nodes))).all()
    # level sizes decay
    sizes = [len(nodes) for nodes in g.level_nodes]
    assert sizes[0] == n
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))


def test_bulk_builder_invariants(graphs_bulk):
    g1, g2 = graphs_bulk
    _check_invariants(g1)
    _check_invariants(g2)
    assert g1.metric_p == 1.0 and g2.metric_p == 2.0


def test_incremental_builder_invariants(graph_incremental):
    _check_invariants(graph_incremental)


def test_bulk_level0_connected(graphs_bulk):
    """The repair pass must leave level 0 reachable from the entry point."""
    for g in graphs_bulk:
        ncomp, comp = _components(g.adjacency[0])
        assert ncomp == 1, f"level-0 graph has {ncomp} components"


def test_index_size_accounting(graphs_bulk):
    g1, _ = graphs_bulk
    size = g1.index_size_bytes()
    assert size > 0
    # excludes the dataset
    assert size < g1.data.nbytes + 10_000_000
    raw_adj = sum(a.nbytes for a in g1.adjacency)
    assert size >= raw_adj


def test_builders_deterministic(small_ds):
    from repro.core.build import build_hnsw_bulk

    a = build_hnsw_bulk(small_ds.data[:500], 2.0, m=8, seed=3)
    b = build_hnsw_bulk(small_ds.data[:500], 2.0, m=8, seed=3)
    assert a.entry_point == b.entry_point
    for x, y in zip(a.adjacency, b.adjacency):
        np.testing.assert_array_equal(x, y)
