"""Pallas kernel vs pure-jnp oracle: shape/dtype/p sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests_hypothesis_compat import given, settings, st  # optional dep shim

from repro.kernels.ops import pallas_pairwise_lp, pallas_rowwise_lp
from repro.kernels.ref import pairwise_lp_ref, rowwise_lp_ref

P_GRID = [0.5, 0.8, 1.0, 1.3, 1.5, 2.0]
SHAPES_PW = [(1, 1, 8), (3, 130, 32), (17, 333, 96), (128, 512, 128), (9, 1000, 760)]
SHAPES_RW = [(1, 1, 8), (5, 33, 64), (16, 300, 128), (8, 257, 960)]


def _rel_err(a, b):
    return float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1e-5)))


@pytest.mark.parametrize("p", P_GRID)
@pytest.mark.parametrize("shape", SHAPES_PW)
def test_pairwise_kernel_matches_ref(p, shape):
    b, n, d = shape
    kq, kx = jax.random.split(jax.random.PRNGKey(b * 31 + n))
    q = jax.random.normal(kq, (b, d), dtype=jnp.float32) * 3
    x = jax.random.normal(kx, (n, d), dtype=jnp.float32) * 3
    got = pallas_pairwise_lp(q, x, p)
    want = pairwise_lp_ref(q, x, p)
    assert got.shape == want.shape
    assert _rel_err(got, want) < 3e-5


@pytest.mark.parametrize("p", P_GRID)
@pytest.mark.parametrize("shape", SHAPES_RW)
def test_rowwise_kernel_matches_ref(p, shape):
    b, c, d = shape
    kq, kc = jax.random.split(jax.random.PRNGKey(b * 17 + c))
    q = jax.random.normal(kq, (b, d), dtype=jnp.float32) * 3
    cands = jax.random.normal(kc, (b, c, d), dtype=jnp.float32) * 3
    got = pallas_rowwise_lp(q, cands, p)
    want = rowwise_lp_ref(q, cands, p)
    assert got.shape == want.shape
    assert _rel_err(got, want) < 3e-5


@pytest.mark.parametrize("p", [0.5, 1.0, 2.0])
def test_pairwise_kernel_bf16_inputs(p):
    kq, kx = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(kq, (8, 64), dtype=jnp.bfloat16)
    x = jax.random.normal(kx, (100, 64), dtype=jnp.bfloat16)
    got = pallas_pairwise_lp(q, x, p)
    want = pairwise_lp_ref(q.astype(jnp.float32), x.astype(jnp.float32), p)
    assert got.dtype == jnp.float32  # kernels accumulate in f32
    assert _rel_err(got, want) < 2e-2  # bf16 input quantization


@pytest.mark.parametrize("p", P_GRID)
def test_root_free_variant(p):
    kq, kx = jax.random.split(jax.random.PRNGKey(3))
    q = jax.random.normal(kq, (4, 48))
    x = jax.random.normal(kx, (77, 48))
    got = pallas_pairwise_lp(q, x, p, root=False)
    want = pairwise_lp_ref(q, x, p, root=False)
    assert _rel_err(got, want) < 3e-5


def test_explicit_tile_override():
    q = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 32))
    a = pallas_pairwise_lp(q, x, 1.0, block_b=8, block_n=128)
    b = pallas_pairwise_lp(q, x, 1.0, block_b=16, block_n=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 9),
    n=st.integers(1, 150),
    d=st.integers(2, 80),
    p=st.sampled_from(P_GRID),
)
def test_pairwise_kernel_property(b, n, d, p):
    """Any (B, N, d) — including awkward non-tile-multiples — matches ref."""
    kq, kx = jax.random.split(jax.random.PRNGKey(b * 1000 + n * 10 + d))
    q = jax.random.normal(kq, (b, d), dtype=jnp.float32)
    x = jax.random.normal(kx, (n, d), dtype=jnp.float32)
    got = pallas_pairwise_lp(q, x, p)
    want = pairwise_lp_ref(q, x, p)
    assert _rel_err(got, want) < 5e-5


def test_zero_distance_diagonal():
    """d(x, x) == 0 exactly for the general-p path (log-singularity guard)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (12, 40))
    for p in (0.7, 1.3):
        d = pallas_pairwise_lp(x, x, p)
        np.testing.assert_allclose(np.asarray(jnp.diag(d)), 0.0, atol=1e-5)
