"""Pallas kernel vs pure-jnp oracle: shape/dtype/p sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests_hypothesis_compat import given, settings, st  # optional dep shim

from repro.kernels.ops import (
    lp_gather_distance,
    pallas_pairwise_lp,
    pallas_rowwise_lp,
)
from repro.kernels.ref import pairwise_lp_ref, rowwise_lp_ref

P_GRID = [0.5, 0.8, 1.0, 1.3, 1.5, 2.0]
SHAPES_PW = [(1, 1, 8), (3, 130, 32), (17, 333, 96), (128, 512, 128), (9, 1000, 760)]
SHAPES_RW = [(1, 1, 8), (5, 33, 64), (16, 300, 128), (8, 257, 960)]


def _rel_err(a, b):
    return float(jnp.max(jnp.abs(a - b) / (jnp.abs(b) + 1e-5)))


@pytest.mark.parametrize("p", P_GRID)
@pytest.mark.parametrize("shape", SHAPES_PW)
def test_pairwise_kernel_matches_ref(p, shape):
    b, n, d = shape
    kq, kx = jax.random.split(jax.random.PRNGKey(b * 31 + n))
    q = jax.random.normal(kq, (b, d), dtype=jnp.float32) * 3
    x = jax.random.normal(kx, (n, d), dtype=jnp.float32) * 3
    got = pallas_pairwise_lp(q, x, p)
    want = pairwise_lp_ref(q, x, p)
    assert got.shape == want.shape
    assert _rel_err(got, want) < 3e-5


@pytest.mark.parametrize("p", P_GRID)
@pytest.mark.parametrize("shape", SHAPES_RW)
def test_rowwise_kernel_matches_ref(p, shape):
    b, c, d = shape
    kq, kc = jax.random.split(jax.random.PRNGKey(b * 17 + c))
    q = jax.random.normal(kq, (b, d), dtype=jnp.float32) * 3
    cands = jax.random.normal(kc, (b, c, d), dtype=jnp.float32) * 3
    got = pallas_rowwise_lp(q, cands, p)
    want = rowwise_lp_ref(q, cands, p)
    assert got.shape == want.shape
    assert _rel_err(got, want) < 3e-5


@pytest.mark.parametrize("p", [0.5, 1.0, 2.0])
def test_pairwise_kernel_bf16_inputs(p):
    kq, kx = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(kq, (8, 64), dtype=jnp.bfloat16)
    x = jax.random.normal(kx, (100, 64), dtype=jnp.bfloat16)
    got = pallas_pairwise_lp(q, x, p)
    want = pairwise_lp_ref(q.astype(jnp.float32), x.astype(jnp.float32), p)
    assert got.dtype == jnp.float32  # kernels accumulate in f32
    assert _rel_err(got, want) < 2e-2  # bf16 input quantization


@pytest.mark.parametrize("p", P_GRID)
def test_root_free_variant(p):
    kq, kx = jax.random.split(jax.random.PRNGKey(3))
    q = jax.random.normal(kq, (4, 48))
    x = jax.random.normal(kx, (77, 48))
    got = pallas_pairwise_lp(q, x, p, root=False)
    want = pairwise_lp_ref(q, x, p, root=False)
    assert _rel_err(got, want) < 3e-5


def test_explicit_tile_override():
    q = jax.random.normal(jax.random.PRNGKey(1), (16, 32))
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 32))
    a = pallas_pairwise_lp(q, x, 1.0, block_b=8, block_n=128)
    b = pallas_pairwise_lp(q, x, 1.0, block_b=16, block_n=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 9),
    n=st.integers(1, 150),
    d=st.integers(2, 80),
    p=st.sampled_from(P_GRID),
)
def test_pairwise_kernel_property(b, n, d, p):
    """Any (B, N, d) — including awkward non-tile-multiples — matches ref."""
    kq, kx = jax.random.split(jax.random.PRNGKey(b * 1000 + n * 10 + d))
    q = jax.random.normal(kq, (b, d), dtype=jnp.float32)
    x = jax.random.normal(kx, (n, d), dtype=jnp.float32)
    got = pallas_pairwise_lp(q, x, p)
    want = pairwise_lp_ref(q, x, p)
    assert _rel_err(got, want) < 5e-5


def test_zero_distance_diagonal():
    """d(x, x) == 0 exactly for the general-p path (log-singularity guard)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (12, 40))
    for p in (0.7, 1.3):
        d = pallas_pairwise_lp(x, x, p)
        np.testing.assert_allclose(np.asarray(jnp.diag(d)), 0.0, atol=1e-5)


def _ulp_diff(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """f32 ulp distance via the monotone int32 bit-pattern view."""
    ai = a.view(np.int32).astype(np.int64)
    bi = b.view(np.int32).astype(np.int64)
    return np.abs(ai - bi)


@pytest.mark.parametrize("shape", [(9, 37, 24), (16, 256, 64), (17, 333, 96)])
@pytest.mark.parametrize("p", [0.5, 0.8, 1.0, 1.5, 2.0])
def test_pairwise_vector_p_vs_scalar_ulp_pinned(shape, p):
    """Scalar-p vs vector-p *pairwise kernel* parity, pinned to <= 4 ulp.

    This is the known wobble (CHANGES.md PR-3), pinned with an explicit ulp
    tolerance rather than bit-equality. Divergence point: both kernels sum
    |q-x|^p over the d axis, but the vector-p body evaluates every family's
    op sequence and where-selects per element (core/lp_ops), and at tile
    shapes where d is not lane-aligned XLA:CPU reassociates that reduction
    differently from the scalar body's single-family sum — observed only
    for p=1.5 (the a*sqrt(a) family), max 2 ulp pre-root on the pinned
    toolchain; the bound of 4 leaves one extra reassociation of headroom.
    The selected *values* are identical (a select returns the chosen
    operand's bits) — only the summation order wobbles, which is why the
    serving path's gather/rowwise entry points (hard bit-parity contract,
    tests/test_mixed_p.py) are unaffected: their kernels loop query rows
    and never fuse across the family select.

    root=False on purpose: the root is applied outside the kernel by the
    same lp_root on both paths, so any post-root difference is just this
    pre-root wobble amplified by s^(1/p).
    """
    b, n, d = shape
    rng = np.random.default_rng(b * 7 + d)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32) * 3)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 3)
    scalar = np.asarray(
        pallas_pairwise_lp(q, x, p, root=False, interpret=True))
    vector = np.asarray(pallas_pairwise_lp(
        q, x, jnp.full((b,), p, dtype=jnp.float32), root=False,
        interpret=True))
    worst = int(_ulp_diff(scalar, vector).max())
    assert worst <= 4, (
        f"pairwise scalar-vs-vector p={p} wobble grew to {worst} ulp "
        f"at shape {shape} — the 1-2 ulp reassociation pin has drifted")


# ---------------------------------------------------------------------------
# fused gather+distance kernel (the verification hot path)
# ---------------------------------------------------------------------------

P_GATHER = [0.5, 0.8, 1.25, 2.0]


def _gather_case(seed, b, c, n, d, sentinels=True):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32) * 3)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 3)
    ids = rng.integers(0, n, size=(b, c)).astype(np.int32)
    if sentinels:
        # the padding vocabulary of the query path: -1 (merge pad),
        # n (beam sentinel), and a stray overflow value
        ids[0, 0] = -1
        ids[min(1, b - 1), c // 2] = n
        ids[:, c - 1] = n + 7
    return q, jnp.asarray(ids), x, ids


@pytest.mark.parametrize("p", P_GATHER)
@pytest.mark.parametrize("root", [False, True])
def test_gather_kernel_matches_rowwise_ref(p, root):
    """Fused kernel == gather-then-rowwise_lp, with padding ids -> inf."""
    q, ids, x, ids_np = _gather_case(11, b=6, c=37, n=200, d=48)
    n = x.shape[0]
    got = np.asarray(lp_gather_distance(q, ids, x, p, root=root,
                                        interpret=True))
    valid = (ids_np >= 0) & (ids_np < n)
    want = np.asarray(rowwise_lp_ref(q, x[np.clip(ids_np, 0, n - 1)], p,
                                     root=root))
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.isinf(got), ~valid)
    err = np.max(np.abs(got[valid] - want[valid]) /
                 (np.abs(want[valid]) + 1e-5))
    assert err < 3e-5, (p, root, err)


@pytest.mark.parametrize("p", P_GATHER)
def test_gather_dispatch_paths_agree(p):
    """Backend-aware fallback (jnp reference) == forced interpret kernel."""
    q, ids, x, _ = _gather_case(7, b=5, c=130, n=90, d=33)
    auto = np.asarray(lp_gather_distance(q, ids, x, p))  # CPU -> reference
    kern = np.asarray(lp_gather_distance(q, ids, x, p, interpret=True))
    np.testing.assert_array_equal(np.isinf(auto), np.isinf(kern))
    finite = np.isfinite(auto)
    np.testing.assert_allclose(auto[finite], kern[finite], rtol=5e-5)


def test_gather_all_padding_row():
    """A fully-padded id row (underfilled beam) scores inf everywhere."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(50, 16)).astype(np.float32))
    ids = jnp.concatenate([
        jnp.full((1, 12), -1, jnp.int32),
        jnp.full((1, 12), 50, jnp.int32),
    ])
    for interpret in (None, True):
        out = np.asarray(lp_gather_distance(q, ids, x, 1.25,
                                            interpret=interpret))
        assert np.isinf(out).all()


@pytest.mark.parametrize("p", [0.8, 2.0])
def test_gather_shared_ids_matches_broadcast(p):
    """1-D ids (the delta-scan shape) == the same ids broadcast per query."""
    rng = np.random.default_rng(17)
    b, c, n, d = 6, 23, 60, 32
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ids1d = rng.integers(-1, n + 1, size=(c,)).astype(np.int32)
    shared = np.asarray(lp_gather_distance(q, jnp.asarray(ids1d), x, p,
                                           root=True))
    bcast = np.asarray(lp_gather_distance(
        q, jnp.broadcast_to(jnp.asarray(ids1d)[None, :], (b, c)), x, p,
        root=True))
    np.testing.assert_array_equal(np.isinf(shared), np.isinf(bcast))
    finite = np.isfinite(shared)
    np.testing.assert_allclose(shared[finite], bcast[finite], rtol=5e-5)


def test_gather_explicit_tile_override():
    q, ids, x, _ = _gather_case(9, b=8, c=256, n=120, d=24, sentinels=False)
    a = lp_gather_distance(q, ids, x, 0.8, interpret=True,
                           block_b=2, block_c=128)
    b = lp_gather_distance(q, ids, x, 0.8, interpret=True,
                           block_b=8, block_c=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
