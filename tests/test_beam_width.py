"""W-way multi-expansion beam search (DESIGN.md §2 hot path).

Invariants under test:
  * exhaustive beams (ef >= n) make the search order-insensitive, so every
    W must return the identical top-t set *and* the identical N_b (every
    reachable node is evaluated exactly once, whatever the hop width);
  * N_b accounting is exact under cross-list duplication: when the W
    expanded nodes share neighbors, each shared neighbor is evaluated and
    counted once (never dropped, never double-counted);
  * the point of the feature: W=4 cuts level-0 while_loop trips >= 2x at
    matching recall on a realistic graph.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.build import build_hnsw_bulk
from repro.core.hnsw import GraphArrays, knn_search
from repro.core.uhnsw import UHNSW, UHNSWParams, recall


@pytest.fixture(scope="module")
def tiny_graph(small_ds):
    data = small_ds.data[:500]
    g = build_hnsw_bulk(data, 1.0, m=8, seed=3)
    return GraphArrays.from_graph(g), jnp.asarray(data)


@pytest.mark.parametrize("w", [2, 4, 8])
def test_exhaustive_beam_identical_across_widths(tiny_graph, small_ds, w):
    """ef >= n: the beam holds every reachable node, so top-t and N_b must
    not depend on the expansion width."""
    arrays, X = tiny_graph
    Q = jnp.asarray(small_ds.queries[:8])
    ef = X.shape[0]
    i1, d1, nb1, _ = knn_search(arrays, X, Q, ef=ef, t=50, expand_width=1)
    iw, dw, nbw, _ = knn_search(arrays, X, Q, ef=ef, t=50, expand_width=w)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(iw))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(dw), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(nb1), np.asarray(nbw))


@pytest.mark.parametrize("w", [1, 2, 4, 8])
def test_nb_exact_under_cross_list_duplication(w):
    """All-to-all adjacency: the W expanded nodes share *every* neighbor.

    With an exhaustive beam each of the n nodes must be base-metric
    evaluated exactly once — N_b == n proves the dedup neither drops
    (undercount) nor re-evaluates (overcount) duplicated neighbors, and
    that the visited-bitmask scatter stays carry-free under duplication.
    """
    n, d = 64, 16
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    # every node's neighbor list = all node ids (self included; the visited
    # bitmask makes self-edges harmless)
    adj0 = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (n, n))
    arrays = GraphArrays(adj0=adj0, upper_adj=(), upper_g2l=(),
                         entry=jnp.int32(0), n=n, metric_p=1.0)
    Q = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    ids, dists, nb, hops = knn_search(arrays, X, Q, ef=n, t=n, expand_width=w)
    np.testing.assert_array_equal(np.asarray(nb), n)
    # and the result is the full exact ordering of all n nodes
    assert sorted(np.asarray(ids)[0].tolist()) == list(range(n))


def test_w4_halves_hops_at_matching_recall(graphs_bulk, small_ds):
    """The tentpole claim, at test scale: >= 2x fewer level-0 trips, recall
    within 0.01, N_b never undercounting the W=1 baseline's coverage."""
    g1, _ = graphs_bulk
    arrays = GraphArrays.from_graph(g1)
    X = jnp.asarray(small_ds.data)
    Q = jnp.asarray(small_ds.queries)
    from repro.core.hnsw import exact_topk

    true_ids, _ = exact_topk(X, Q, 1.0, 10)
    i1, _, nb1, h1 = knn_search(arrays, X, Q, ef=128, t=64, expand_width=1)
    i4, _, nb4, h4 = knn_search(arrays, X, Q, ef=128, t=64, expand_width=4)
    r1 = recall(np.asarray(i1[:, :10]), np.asarray(true_ids))
    r4 = recall(np.asarray(i4[:, :10]), np.asarray(true_ids))
    assert abs(r1 - r4) <= 0.01, (r1, r4)
    assert float(h4.mean()) <= float(h1.mean()) / 2, (h1.mean(), h4.mean())
    # wider hops may explore slightly past the W=1 frontier but must never
    # skip evaluations the accounting owes: mean N_b stays >= 97% of W=1
    assert float(nb4.mean()) >= 0.97 * float(nb1.mean())


def test_uhnsw_search_threads_expand_width(graphs_bulk, small_ds):
    """expand_width flows from UHNSWParams through search(); stats expose
    hop counts; fractional-p results stay equivalent-quality."""
    g1, g2 = graphs_bulk
    Q = jnp.asarray(small_ds.queries[:8])
    res = {}
    for w in (1, 4):
        idx = UHNSW(g1, g2, UHNSWParams(t=100, expand_width=w))
        ids, dists, stats = idx.search(Q, 0.8, 10)
        res[w] = (np.asarray(ids), np.asarray(stats.hops), stats)
    hops1, hops4 = res[1][1], res[4][1]
    assert hops4.mean() < hops1.mean()
    # same candidate quality -> overwhelmingly overlapping verified top-k
    overlap = np.mean([
        len(set(a) & set(b)) / 10 for a, b in zip(res[1][0], res[4][0])
    ])
    assert overlap >= 0.9, overlap
