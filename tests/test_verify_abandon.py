"""Early-abandoning blocked-dimension verification: exactness (DESIGN.md §8).

The subsystem's contract is that abandonment is *free* in result space:
a candidate is abandoned only when a monotone lower bound on its final
root-free power sum (its partial sum over scanned dimension blocks, or
the base-distance entry/suffix bound) already exceeds the running
k-th-best, so the returned top-k (ids AND distances) must be identical
to the full-dimension verification at matched (t, kappa, tau).

Layers pinned here:

  * bound validity — `lp_entry_bound` / `lp_suffix_bound` never exceed
    the true power sum (the property exactness rests on);
  * kernel parity — `lp_gather_abandon` interpret=True vs the blocked
    jnp reference, bitwise, including the scanned-dim counts;
  * scalar-vs-vector p — one traced program rows == per-p programs;
  * verification — abandoning vs full-dimension `verify_candidates`:
    identical ids and n_p, distances to 1-ulp-class tolerance (the
    blocked scan reassociates the d-axis sum; single-block shapes are
    bitwise);
  * the `abandon=False` escape hatch — bit-parity with the legacy
    sort-merge loop, including n_dim_frac == 1;
  * end-to-end — UHNSW / ShardedUHNSW (+ delta tier) searches with
    abandonment on vs off return identical ids at every p, while
    n_dim_frac < 1 when the workload actually abandons.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.lp_ops import lp_entry_bound, lp_suffix_bound
from repro.core.metrics import lp_distance
from repro.core.uhnsw import UHNSW, UHNSWParams, verify_candidates
from repro.kernels.ops import (
    lp_gather_abandon,
    lp_gather_distance,
    pick_abandon_block_d,
)

P_GRID = [0.5, 0.8, 1.25, 1.5, 1.7]


def _close_with_inf(got, want, err=""):
    np.testing.assert_array_equal(np.isinf(got), np.isinf(want), err_msg=err)
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-6, err_msg=err)


def _case(seed=0, b=6, c=40, n=250, d=64):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32) * 2)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 2)
    ids = rng.integers(-1, n + 2, size=(b, c)).astype(np.int32)
    return q, x, jnp.asarray(ids), rng


def _base_power(q, x, ids, base_p):
    """True base-metric power sums for the candidate block (inf padding)."""
    n = x.shape[0]
    valid = (np.asarray(ids) >= 0) & (np.asarray(ids) < n)
    d = np.asarray(lp_distance(q[:, None, :],
                               x[np.clip(np.asarray(ids), 0, n - 1)],
                               base_p, root=False))
    return jnp.asarray(np.where(valid, d, np.inf).astype(np.float32))


# ---------------------------------------------------------------------------
# bound validity: the inequalities exactness rests on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", P_GRID)
@pytest.mark.parametrize("base_p", [1.0, 2.0])
def test_entry_bound_never_exceeds_true_power(p, base_p):
    rng = np.random.default_rng(3)
    for d in (8, 96, 300):
        v = rng.standard_t(3.0, size=(200, d)).astype(np.float32) * \
            np.exp(rng.standard_normal(d).astype(np.float32))
        true_p = np.asarray(lp_distance(jnp.asarray(v), 0.0, p, root=False))
        sb = np.asarray(lp_distance(jnp.asarray(v), 0.0, base_p,
                                    root=False))
        lb = np.asarray(lp_entry_bound(jnp.asarray(sb), base_p, p, d))
        assert np.all(lb <= true_p * (1 + 1e-5)), (
            f"entry bound exceeds true power sum: p={p} base={base_p} d={d} "
            f"worst={(lb / np.maximum(true_p, 1e-30)).max()}")


@pytest.mark.parametrize("p", P_GRID)
@pytest.mark.parametrize("base_p", [1.0, 2.0])
def test_suffix_bound_never_exceeds_true_power(p, base_p):
    rng = np.random.default_rng(4)
    d_rem = 40
    v = rng.standard_t(3.0, size=(300, d_rem)).astype(np.float32) * 3
    true_p = np.asarray(lp_distance(jnp.asarray(v), 0.0, p, root=False))
    r = np.asarray(lp_distance(jnp.asarray(v), 0.0, base_p, root=False))
    lb = np.asarray(lp_suffix_bound(jnp.asarray(r), base_p, p,
                                    float(d_rem)))
    assert np.all(lb <= true_p * (1 + 1e-5))


# ---------------------------------------------------------------------------
# kernel layer: dispatch semantics + interpret parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [0.8, 1.25])
def test_abandon_inf_threshold_equals_full_scan(p):
    """thresh=+inf scans everything: must equal the full-dimension path
    (bitwise here — the block widths divide d, and XLA:CPU reduces the
    32-wide blocks exactly like the fused d-axis sum at these shapes)."""
    q, x, ids, _ = _case(d=64)
    full = np.asarray(lp_gather_distance(q, ids, x, p, root=False))
    thr = jnp.full((q.shape[0],), jnp.inf)
    sb = jnp.zeros(ids.shape, jnp.float32)
    out, nd = lp_gather_abandon(q, ids, x, thr, sb, p, base_p=1.0)
    valid = (np.asarray(ids) >= 0) & (np.asarray(ids) < x.shape[0])
    np.testing.assert_array_equal(np.asarray(out)[valid], full[valid])
    assert np.all(np.asarray(nd)[valid] == q.shape[1])
    assert np.all(np.isinf(np.asarray(out)[~valid]))
    assert np.all(np.asarray(nd)[~valid] == 0)


@pytest.mark.parametrize("p", [0.5, 0.8, 1.25, 1.5])
@pytest.mark.parametrize("base_p", [1.0, 2.0])
def test_abandon_exactness_vs_threshold(p, base_p):
    """Everything the full path scores <= thresh must survive with its
    exact full-path value; everything abandoned must truly exceed thresh."""
    q, x, ids, rng = _case(seed=11, d=96)
    full = np.asarray(lp_gather_distance(q, ids, x, p, root=False))
    valid = (np.asarray(ids) >= 0) & (np.asarray(ids) < x.shape[0])
    thr_v = np.nanquantile(np.where(valid, full, np.nan), 0.4,
                           axis=1).astype(np.float32)
    sb = _base_power(q, x, ids, base_p)
    out, nd = lp_gather_abandon(q, ids, x, jnp.asarray(thr_v), sb, p,
                                base_p=base_p)
    out = np.asarray(out)
    # blocked (3 x 32) association differs from the fused d=96 sum by ~1
    # ulp, so near-threshold comparisons carry a 1e-6 relative margin;
    # clear keepers must survive with their blocked value, clear losers
    # must be provably over the bound.
    must_survive = valid & (full <= thr_v[:, None] * (1 - 1e-6))
    assert np.isfinite(out[must_survive]).all(), "abandoned a keeper"
    np.testing.assert_allclose(out[must_survive], full[must_survive],
                               rtol=1e-6)
    abandoned = valid & np.isinf(out)
    assert np.all(full[abandoned] > thr_v[:, None].repeat(
        out.shape[1], 1)[abandoned] * (1 - 1e-6)), \
        "abandoned candidate was competitive"
    # savings exist at this threshold for p > 1: the Jensen entry bound
    # d^(1-p)*S1^p (or S2^(p/2)) kills clear losers before any block.
    # For p <= 1 on i.i.d. data no aggregate bound can bite (power sums
    # of spread vectors concentrate), so only exactness is asserted.
    if p > 1.0:
        assert np.asarray(nd)[valid].mean() < q.shape[1]


@pytest.mark.parametrize("p", [0.8, 1.25])
@pytest.mark.parametrize("d", [32, 64, 96])
def test_abandon_kernel_interpret_matches_ref(p, d):
    """interpret=True Pallas kernel vs the blocked jnp reference: bitwise
    on distances AND scanned-dim counts, scalar and vector p."""
    q, x, ids, rng = _case(seed=5, d=d)
    thr = jnp.asarray(rng.uniform(20, 200, size=q.shape[0]).astype(
        np.float32))
    sb = _base_power(q, x, ids, 1.0)
    r_out, r_nd = lp_gather_abandon(q, ids, x, thr, sb, p, base_p=1.0)
    k_out, k_nd = lp_gather_abandon(q, ids, x, thr, sb, p, base_p=1.0,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(r_out), np.asarray(k_out))
    np.testing.assert_array_equal(np.asarray(r_nd), np.asarray(k_nd))
    ps = jnp.full((q.shape[0],), p, jnp.float32)
    v_out, v_nd = lp_gather_abandon(q, ids, x, thr, sb, ps, base_p=1.0,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(r_out), np.asarray(v_out))
    np.testing.assert_array_equal(np.asarray(r_nd), np.asarray(v_nd))


def test_abandon_vector_p_rows_match_scalar():
    """One traced mixed-p program == per-p scalar programs, row by row."""
    q, x, ids, rng = _case(seed=9, d=64)
    ps = rng.choice(P_GRID, size=q.shape[0]).astype(np.float32)
    thr = jnp.asarray(rng.uniform(20, 300, size=q.shape[0]).astype(
        np.float32))
    sb = _base_power(q, x, ids, 1.0)
    v_out, v_nd = lp_gather_abandon(q, ids, x, thr, sb, jnp.asarray(ps),
                                    base_p=1.0)
    for i, p in enumerate(ps):
        s_out, s_nd = lp_gather_abandon(q[i:i + 1], ids[i:i + 1], x,
                                        thr[i:i + 1], sb[i:i + 1],
                                        float(p), base_p=1.0)
        np.testing.assert_array_equal(np.asarray(v_out)[i],
                                      np.asarray(s_out)[0], err_msg=f"p={p}")
        np.testing.assert_array_equal(np.asarray(v_nd)[i],
                                      np.asarray(s_nd)[0], err_msg=f"p={p}")


def test_pick_abandon_block_d():
    assert pick_abandon_block_d(96) == 32
    assert pick_abandon_block_d(256) == 32
    assert pick_abandon_block_d(48) == 16
    assert pick_abandon_block_d(40) == 8
    assert pick_abandon_block_d(100) == 100  # ragged: one full-width block


# ---------------------------------------------------------------------------
# verification layer: abandoning loop vs full-dimension loop
# ---------------------------------------------------------------------------


def _verify_case(seed=23, b=8, t=60, n=300, d=32, base_p=1.0):
    """Candidates sorted ascending by base distance (the beam contract),
    with trailing padding, plus their true base power sums."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    base = np.asarray(lp_distance(q[:, None, :], x[None, :, :], base_p,
                                  root=False))
    order = np.argsort(base, axis=1)[:, :t].astype(np.int32)
    cand_base = np.take_along_axis(base, order, axis=1).astype(np.float32)
    order[:, -2:] = -1
    cand_base[:, -2:] = np.inf
    return q, x, jnp.asarray(order), jnp.asarray(cand_base)


@pytest.mark.parametrize("p", P_GRID)
def test_verify_abandon_matches_full_scalar(p):
    """ids and n_p identical at matched (t, kappa, tau); dists to 1-ulp.

    The abandoning scan reduces (d, TC)-transposed blocks (the layout
    that makes dimension blocks TPU sublane slices, DESIGN.md §8) while
    the legacy path reduces the (B, C, d) last axis — XLA:CPU
    reassociates the two by <= 1 ulp on some elements (max measured
    rel diff 1.8e-7 at p=1.5), exactly the wobble class pinned for the
    pairwise vector-p kernel in test_kernels. Selection is tie-free at
    that scale on continuous data, so ids and N_p stay bitwise.
    """
    q, x, cand, cand_base = _verify_case(d=32)
    k, kappa, tau = 10, 25, 0.95
    a = verify_candidates(q, cand, x, p, k, kappa, tau, cand_base=cand_base,
                          base_p=1.0, abandon=True)
    f = verify_candidates(q, cand, x, p, k, kappa, tau, abandon=False)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(f[0]))
    _close_with_inf(np.asarray(a[1]), np.asarray(f[1]))
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(f[2]))
    assert np.all(np.asarray(f[4]) == 1.0)


@pytest.mark.parametrize("p", [0.8, 1.25])
@pytest.mark.parametrize("base_p", [1.0, 2.0])
def test_verify_abandon_matches_full_multiblock(p, base_p):
    """Multi-block d: identical ids/n_p, dists within reassociation ulp,
    and the scanned fraction actually drops (the savings are real)."""
    q, x, cand, cand_base = _verify_case(d=96, base_p=base_p)
    k, kappa, tau = 10, 25, 1.0  # tau=1: scan deep into the junk tail
    a = verify_candidates(q, cand, x, p, k, kappa, tau, cand_base=cand_base,
                          base_p=base_p, abandon=True)
    f = verify_candidates(q, cand, x, p, k, kappa, tau, abandon=False)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(f[0]))
    _close_with_inf(np.asarray(a[1]), np.asarray(f[1]))
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(f[2]))
    frac = np.asarray(a[4])
    assert np.all(frac <= 1.0) and np.all(frac > 0.0)
    assert frac.mean() < 1.0, "no dimension work was saved"


@pytest.mark.parametrize("interpret", [None, True])
def test_verify_abandon_vector_p_matches_scalar(interpret):
    """Mixed-batch abandoning verification: each row == the scalar-p call
    (ids/n_p/n_dim_frac bitwise, dists to cross-program tolerance)."""
    q, x, cand, cand_base = _verify_case(d=64)
    k, kappa = 10, 10
    rng = np.random.default_rng(1)
    ps = rng.choice(P_GRID, size=q.shape[0]).astype(np.float32)
    mv = verify_candidates(q, cand, x, jnp.asarray(ps), k, kappa, 0.92,
                           interpret=interpret, cand_base=cand_base,
                           base_p=1.0, abandon=True)
    for i, p in enumerate(ps):
        sv = verify_candidates(q[i:i + 1], cand[i:i + 1], x, float(p),
                               k, kappa, 0.92, interpret=interpret,
                               cand_base=cand_base[i:i + 1], base_p=1.0,
                               abandon=True)
        np.testing.assert_array_equal(np.asarray(mv[0])[i],
                                      np.asarray(sv[0])[0], err_msg=f"p={p}")
        np.testing.assert_allclose(np.asarray(mv[1])[i],
                                   np.asarray(sv[1])[0], rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(mv[2])[i],
                                      np.asarray(sv[2])[0])
        np.testing.assert_allclose(np.asarray(mv[4])[i],
                                   np.asarray(sv[4])[0], rtol=1e-6)


def test_verify_abandon_padding_rows():
    """Sentinel candidate ids (-1 / n) can never enter the result set."""
    q, x, cand, cand_base = _verify_case(d=32)
    n = x.shape[0]
    cand = np.asarray(cand).copy()
    cand[:, 15:] = np.where(np.arange(cand.shape[1] - 15)[None, :] % 2 == 0,
                            -1, n)
    cand_base = np.asarray(cand_base).copy()
    cand_base[:, 15:] = np.inf
    ids, dists, n_p, _, frac, *_ = verify_candidates(
        q, jnp.asarray(cand), x, 0.8, 10, 5, 0.92,
        cand_base=jnp.asarray(cand_base), base_p=1.0, abandon=True)
    assert np.all(np.asarray(ids) >= 0) and np.all(np.asarray(ids) < n)
    assert np.isfinite(np.asarray(dists)).all()


def test_verify_abandon_false_is_legacy_bitwise():
    """The escape hatch: abandon=False must be the pre-abandonment loop
    bit-for-bit (pinned against a hand-rolled sort-merge reference)."""
    q, x, cand, _ = _verify_case(d=32)
    k, kappa, tau, p = 10, 5, 0.92, 0.8
    ids, dists, n_p, iters, frac, *_ = verify_candidates(
        q, cand, x, p, k, kappa, tau, abandon=False)
    assert np.all(np.asarray(frac) == 1.0)
    # reference: the legacy loop in numpy (full-dimension, lax.sort merge)
    full = np.asarray(lp_gather_distance(q, cand, x, p, root=False))
    B, t = cand.shape
    for i in range(B):
        order = np.argsort(full[i, :k], kind="stable")
        r_ids = np.asarray(cand)[i, :k][order]
        r_d = full[i, :k][order]
        j = 0
        while j < (t - k) // kappa:
            s = k + j * kappa
            b_ids = np.asarray(cand)[i, s:s + kappa]
            b_d = full[i, s:s + kappa]
            all_d = np.concatenate([r_d, b_d])
            all_i = np.concatenate([r_ids, b_ids])
            oo = np.argsort(all_d, kind="stable")[:k]
            inter = len(set(all_i[oo]) & set(r_ids))
            r_ids, r_d = all_i[oo], all_d[oo]
            j += 1
            if inter / k >= tau:
                break
        np.testing.assert_array_equal(np.asarray(ids)[i], r_ids)
        np.testing.assert_array_equal(np.asarray(n_p)[i], k + j * kappa)


# ---------------------------------------------------------------------------
# end-to-end: index layers
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def abandon_index(small_ds):
    params = UHNSWParams(t=120, kappa=32, abandon=True)
    return UHNSW.build(small_ds.data, m=12, method="bulk", params=params)


@pytest.mark.parametrize("p", [0.5, 0.8, 1.25, 1.5])
def test_index_search_abandon_identical_ids(abandon_index, small_ds, p):
    from dataclasses import replace

    idx = abandon_index
    Q = jnp.asarray(small_ds.queries)
    idx.params = replace(idx.params, abandon=True)
    ia, da, sa = idx.search(Q, p, 10)
    idx.params = replace(idx.params, abandon=False)
    if_, df, sf = idx.search(Q, p, 10)
    idx.params = replace(idx.params, abandon=True)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(if_))
    _close_with_inf(np.asarray(da), np.asarray(df))
    np.testing.assert_array_equal(np.asarray(sa.n_p), np.asarray(sf.n_p))
    frac = np.asarray(sa.n_dim_frac)
    assert np.all((frac > 0) & (frac <= 1.0))
    assert np.all(np.asarray(sf.n_dim_frac) == 1.0)


def test_sharded_with_delta_abandon_identical(small_ds, make_sharded):
    from dataclasses import replace

    # fresh wrapper over the session's frozen 4-segment build (this test
    # mutates params and the delta tier, so no sharing with sharded_index)
    idx = make_sharded(params=UHNSWParams(t=120, abandon=True),
                       delta_capacity=128)
    rng = np.random.default_rng(2)
    for _ in range(30):
        idx.add(rng.normal(size=small_ds.data.shape[1]).astype(np.float32))
    Q = jnp.asarray(small_ds.queries)
    ps = np.asarray([0.5, 0.8, 1.25, 1.5, 2.0, 1.0] * 4, np.float32)
    i1, d1, s1 = idx.search(Q, ps, 10)
    idx.params = replace(idx.params, abandon=False)
    i2, d2, s2 = idx.search(Q, ps, 10)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    _close_with_inf(np.asarray(d1), np.asarray(d2))
    frac = np.asarray(s1.n_dim_frac)
    assert np.all((frac > 0) & (frac <= 1.0))
    # the delta scan abandons against the verified k-th best: with junk
    # inserts present, some rows must actually skip dimension work
    assert frac.mean() < 1.0
