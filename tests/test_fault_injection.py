"""Chaos tests: fault-injected serving (DESIGN.md §9).

The CI chaos lane runs this file once per REPRO_CHAOS_SEED matrix cell;
the cell seed is folded into the local seed set, so three cells exercise
nine distinct injected-failure schedules — every one deterministic and
reproducible from the cell name alone.

The contract under test: with a seeded FaultInjector at the engine's
device-call boundary, every admitted request reaches a response or a
deterministic terminal FAILED state (no hangs, no lost requests, no
unbounded retries), and every response is bitwise-identical to a
fault-free run of the same request set.
"""

import os

import numpy as np
import pytest

from repro.core.uhnsw import UHNSW, UHNSWParams
from repro.retrieval.engine import (
    DRAINING,
    ENGINE_FAILED,
    EngineClosed,
    FaultInjector,
    ManualClock,
)
from repro.retrieval.service import QueryRequest, UniversalVectorService

CHAOS = int(os.environ.get("REPRO_CHAOS_SEED", "0"))
SEEDS = [CHAOS * 100 + i for i in range(3)]

P_MIX = [0.5, 0.8, 1.0, 1.25, 2.0]


def _requests(small_ds, n, seed=0, p=None):
    rng = np.random.default_rng(seed)
    return [
        QueryRequest(
            vector=small_ds.queries[int(rng.integers(len(small_ds.queries)))],
            p=float(p if p is not None
                    else P_MIX[int(rng.integers(len(P_MIX)))]),
            k=10, request_id=i,
        )
        for i in range(n)
    ]


@pytest.fixture()
def svc_factory(small_ds, graphs_bulk):
    def make(**kw):
        kw.setdefault("max_batch", 32)
        kw.setdefault("min_bucket", 8)
        return UniversalVectorService(
            index=UHNSW(*graphs_bulk, UHNSWParams(t=80)), **kw)
    return make


def _assert_fault_accounting(svc, injector, out, failures, all_ids):
    """The no-lost-requests invariant + counter consistency."""
    assert set(out).isdisjoint(failures)
    assert set(out) | set(failures) == all_ids
    st = svc.stats
    # every caught fault resolved into exactly one of retry/split/FAILED
    assert st["faults"] == (st["retries"] + st["quarantine_splits"]
                            + st["failed"])
    assert st["faults"] == injector.injected   # no real faults in the mix
    assert st["failed"] == len(failures)


@pytest.mark.parametrize("seed", SEEDS)
def test_faulted_serving_matches_clean_bitwise(svc_factory, small_ds, seed):
    """rate >= 10% transient faults: everything still served, responses
    bitwise-equal to the fault-free run, counters consistent."""
    reqs = _requests(small_ds, 40, seed=seed)
    clean = svc_factory().serve(reqs)
    assert len(clean) == 40

    inj = FaultInjector(rate=0.25, seed=seed)
    svc = svc_factory(fault_injector=inj)
    out = svc.serve(reqs)
    failures = svc.engine.take_failures()
    _assert_fault_accounting(svc, inj, out, failures,
                             {r.request_id for r in reqs})
    assert svc.stats["faults"] > 0             # the schedule actually fired
    for rid in out:
        np.testing.assert_array_equal(out[rid][0], clean[rid][0])
        np.testing.assert_array_equal(out[rid][1], clean[rid][1])
    # at rate 0.25 with max_retries=2 the retry budget absorbs almost
    # everything; whatever failed must carry the injector's message
    for rid, err in failures.items():
        assert "Injected" in err


def test_timeout_faults_recovered_like_any_exception(svc_factory, small_ds):
    """InjectedTimeout (distinct type) rides the same bounded recovery."""
    reqs = _requests(small_ds, 24, seed=CHAOS)
    clean = svc_factory().serve(reqs)
    inj = FaultInjector(rate=0.1, timeout_rate=0.15, seed=CHAOS)
    svc = svc_factory(fault_injector=inj)
    out = svc.serve(reqs)
    failures = svc.engine.take_failures()
    _assert_fault_accounting(svc, inj, out, failures,
                             {r.request_id for r in reqs})
    for rid in out:
        np.testing.assert_array_equal(out[rid][0], clean[rid][0])
        np.testing.assert_array_equal(out[rid][1], clean[rid][1])


def test_same_seed_same_failure_schedule(svc_factory, small_ds):
    """Identical seed -> identical faults, outcomes, and counters."""
    reqs = _requests(small_ds, 32, seed=CHAOS + 5)
    runs = []
    for _ in range(2):
        inj = FaultInjector(rate=0.3, seed=CHAOS + 7)
        svc = svc_factory(fault_injector=inj)
        out = svc.serve(reqs)
        runs.append((set(out), svc.engine.take_failures(),
                     {k: svc.stats[k] for k in ("faults", "retries",
                                                "quarantine_splits",
                                                "failed")},
                     inj.injected))
    assert runs[0] == runs[1]


def test_poison_request_quarantined_by_bisection(svc_factory, small_ds,
                                                 monkeypatch):
    """A request that deterministically kills its device call is isolated
    by bisection and terminally FAILED; its healthy wave-mates are all
    served, bitwise-equal to a run without the poison."""
    d = small_ds.queries.shape[1]
    poison_vec = np.full(d, 123.456, np.float32)
    reqs = _requests(small_ds, 16, seed=1, p=0.8)   # one verify bucket
    poison_id = 5
    reqs[poison_id] = QueryRequest(vector=poison_vec, p=0.8, k=10,
                                   request_id=poison_id)
    healthy = [r for r in reqs if r.request_id != poison_id]
    clean = svc_factory().serve(healthy)

    svc = svc_factory()
    real = svc.index.search_stage_candidates

    def guarded(q, base, **kw):
        rows = np.asarray(q)
        if np.any(np.all(np.abs(rows - 123.456) < 1e-3, axis=1)):
            raise RuntimeError("poison request aborted the device call")
        return real(q, base, **kw)

    monkeypatch.setattr(svc.index, "search_stage_candidates", guarded)
    out = svc.serve(reqs)
    failures = svc.engine.take_failures()
    assert set(failures) == {poison_id}
    assert "RuntimeError: poison request" in failures[poison_id]
    assert set(out) == {r.request_id for r in healthy}
    assert svc.stats["quarantine_splits"] >= 1   # bisection actually ran
    assert svc.stats["failed"] == 1
    assert svc.stats["retries"] >= 1             # whole-wave retries first
    for rid in out:
        np.testing.assert_array_equal(out[rid][0], clean[rid][0])
        np.testing.assert_array_equal(out[rid][1], clean[rid][1])


def test_rate_one_fails_everything_bounded(svc_factory, small_ds):
    """Total device blackout: every request ends deterministically FAILED
    (none served, none lost) and total device calls respect the
    (max_retries+1)*(2n-1) bound — no unbounded retries, no hang."""
    n = 8
    inj = FaultInjector(rate=1.0, seed=CHAOS)
    svc = svc_factory(fault_injector=inj)
    reqs = _requests(small_ds, n, seed=2, p=0.8)    # one bucket of n
    out = svc.serve(reqs)
    failures = svc.engine.take_failures()
    assert out == {}
    assert set(failures) == {r.request_id for r in reqs}
    assert svc.stats["failed"] == n
    max_retries = svc.engine.policy.max_retries
    assert inj.injected <= (max_retries + 1) * (2 * n - 1)
    for err in failures.values():
        assert "injected transient fault" in err


def test_close_rejects_new_admissions(svc_factory, small_ds):
    """close() drains, then the engine is terminally draining: submit,
    make_request, and admit all raise EngineClosed instead of queueing
    into an engine that will never serve."""
    svc = svc_factory()
    reqs = _requests(small_ds, 8, seed=3)
    out = svc.serve(reqs)
    assert len(out) == 8
    eng = svc.engine
    final = eng.close()
    assert final == {}                      # nothing left in flight
    assert eng.state == DRAINING
    with pytest.raises(EngineClosed, match="draining"):
        eng.make_request(reqs[0])
    with pytest.raises(EngineClosed, match="draining"):
        eng.submit(reqs[0])
    with pytest.raises(EngineClosed, match="draining"):
        eng.admit([])
    with pytest.raises(EngineClosed):
        svc.serve(reqs)                     # the service path is guarded too


def test_broken_recovery_fails_engine_terminally(svc_factory, small_ds,
                                                 monkeypatch):
    """If the recovery machinery itself raises, request accounting can no
    longer be trusted: the engine enters its terminal failed state, the
    error propagates (with partial_results), and later admissions raise
    EngineClosed."""
    inj = FaultInjector(rate=1.0, seed=CHAOS)
    svc = svc_factory(fault_injector=inj)
    eng = svc.engine

    def broken(wave, exc, work):
        raise RuntimeError("recovery machinery broke")

    monkeypatch.setattr(eng, "_recover", broken)
    reqs = _requests(small_ds, 4, seed=4)
    with pytest.raises(RuntimeError, match="recovery machinery broke") as ei:
        svc.serve(reqs)
    assert isinstance(ei.value.partial_results, dict)
    assert eng.state == ENGINE_FAILED
    with pytest.raises(EngineClosed, match="failed"):
        eng.submit(reqs[0])


def test_backoff_advances_injected_clock(svc_factory, small_ds, monkeypatch):
    """retry_backoff_ms against a ManualClock: the retry advances
    simulated time exponentially instead of sleeping."""
    clk = ManualClock()
    svc = svc_factory(clock=clk, retry_backoff_ms=5.0)
    real = svc.index.search_stage_candidates
    calls = {"n": 0}

    def flaky(q, base, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient")
        return real(q, base, **kw)

    monkeypatch.setattr(svc.index, "search_stage_candidates", flaky)
    reqs = _requests(small_ds, 4, seed=5, p=0.8)
    t0 = clk()
    out = svc.serve(reqs)
    assert set(out) == {r.request_id for r in reqs}
    assert svc.stats["faults"] == 1 and svc.stats["retries"] == 1
    assert clk() - t0 >= 0.005 - 1e-12      # 5ms * 2^(attempt-1), attempt=1


def test_fault_counters_ride_latency_summary(svc_factory, small_ds):
    inj = FaultInjector(rate=0.25, seed=SEEDS[0])
    svc = svc_factory(fault_injector=inj)
    svc.serve(_requests(small_ds, 24, seed=6))
    summary = svc.latency_summary()["faults"]
    for key in ("faults", "retries", "quarantine_splits", "failed"):
        assert summary[key] == svc.stats[key]
    assert summary["faults"] > 0


def test_no_injector_means_no_fault_accounting(svc_factory, small_ds):
    """fault_injector=None: the boundary is a single None-check and the
    fault counters stay exactly zero (the zero-overhead criterion)."""
    svc = svc_factory()
    out = svc.serve(_requests(small_ds, 16, seed=7))
    assert len(out) == 16
    st = svc.stats
    assert (st["faults"], st["retries"],
            st["quarantine_splits"], st["failed"]) == (0, 0, 0, 0)
    assert svc.engine.take_failures() == {}
