"""Fused Lp+top-k kernel vs jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lp_topk import pallas_lp_topk, ref_lp_topk

CASES = [
    # (B, C, d, k)
    (1, 64, 16, 5),
    (4, 300, 128, 50),
    (3, 257, 96, 10),   # non-tile-multiple C
    (2, 1000, 64, 25),
]


@pytest.mark.parametrize("p", [0.5, 1.0, 1.3, 2.0])
@pytest.mark.parametrize("case", CASES)
def test_fused_topk_matches_ref(p, case):
    b, c, d, k = case
    kq, kc = jax.random.split(jax.random.PRNGKey(b * 7 + c))
    q = jax.random.normal(kq, (b, d), dtype=jnp.float32)
    cands = jax.random.normal(kc, (b, c, d), dtype=jnp.float32)
    got_d, got_i = pallas_lp_topk(q, cands, p, k)
    want_d, want_i = ref_lp_topk(q, cands, p, k)
    np.testing.assert_allclose(
        np.asarray(got_d), np.asarray(want_d), rtol=3e-5, atol=1e-5
    )
    # indices may differ on exact distance ties; compare as sets + distances
    for row in range(b):
        gi, wi = set(np.asarray(got_i)[row]), set(np.asarray(want_i)[row])
        if gi != wi:
            dd = np.asarray(
                ref_lp_topk(q[row : row + 1], cands[row : row + 1], p, c)[0]
            )[0]
            # every disagreement must be a tie at the k-th distance
            assert np.isclose(
                sorted(dd)[k - 1], np.asarray(got_d)[row, -1], rtol=1e-5
            )


def test_fused_topk_sorted_and_valid():
    q = jax.random.normal(jax.random.PRNGKey(0), (5, 32))
    c = jax.random.normal(jax.random.PRNGKey(1), (5, 200, 32))
    d, i = pallas_lp_topk(q, c, 1.3, 20)
    d, i = np.asarray(d), np.asarray(i)
    assert (np.diff(d, axis=1) >= -1e-6).all()
    assert ((i >= 0) & (i < 200)).all()


def test_fused_topk_root_free():
    q = jax.random.normal(jax.random.PRNGKey(2), (2, 24))
    c = jax.random.normal(jax.random.PRNGKey(3), (2, 100, 24))
    d_r, i_r = pallas_lp_topk(q, c, 0.7, 8, root=True)
    d_n, i_n = pallas_lp_topk(q, c, 0.7, 8, root=False)
    np.testing.assert_array_equal(np.asarray(i_r), np.asarray(i_n))
    np.testing.assert_allclose(
        np.asarray(d_r), np.asarray(d_n) ** (1 / 0.7), rtol=1e-4
    )
