"""Elastic restart: checkpoints restore onto a *different* mesh shape.

Runs in a subprocess with 8 forced host devices: train 3 steps on a (4, 2)
mesh, checkpoint, restore onto (2, 4) and (8, 1) meshes, and verify the
training trajectory continues identically (the global arrays are mesh-
independent; only their sharding changes)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

_SCRIPT = textwrap.dedent("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.configs.base import get_arch
from repro.dist.sharding import Runtime, set_mesh
from repro.data.pipeline import SyntheticTokenPipeline
from repro.train.step import TrainConfig, init_train_state, make_train_step
from repro.checkpoint.store import save_checkpoint, restore_checkpoint
from repro.launch.train import state_shardings

cfg = get_arch("tinyllama_1_1b", smoke=True)
tc = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10)
pipe = SyntheticTokenPipeline(cfg, 8, 32, seed=0)
ckpt = tempfile.mkdtemp()

def run(mesh_shape, start, steps, state=None):
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    rt = Runtime(mesh=mesh)
    with set_mesh(mesh):
        step = jax.jit(make_train_step(cfg, rt, tc), donate_argnums=(0,))
        if state is None:
            skeleton = jax.eval_shape(
                lambda: init_train_state(cfg, rt, tc, jax.random.PRNGKey(0)))
            state, _ = restore_checkpoint(ckpt, skeleton, state_shardings(cfg, rt, tc))
        losses = []
        for i in range(start, start + steps):
            state, m = step(state, pipe.batch(i))
            losses.append(float(m["loss"]))
    return state, losses

# phase 1: train on (4,2), checkpoint at step 2
mesh = jax.make_mesh((4, 2), ("data", "model"))
rt = Runtime(mesh=mesh)
with set_mesh(mesh):
    state = init_train_state(cfg, rt, tc, jax.random.PRNGKey(0))
state, ref_pre = run((4, 2), 0, 3, state)
save_checkpoint(ckpt, 2, state)
_, ref_post = run((4, 2), 3, 3, state)

# phase 2: resume on two different meshes — trajectories must match
for shape in [(2, 4), (8, 1)]:
    _, got = run(shape, 3, 3)
    np.testing.assert_allclose(got, ref_post, atol=2e-2), (shape, got, ref_post)
    print(f"mesh {shape}: resumed losses match {got}")
print("ELASTIC-OK")
""")


@pytest.mark.slow
def test_elastic_mesh_restore():
    env = {**os.environ, "PYTHONPATH": "src"}
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, cwd=Path(__file__).parent.parent, timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "ELASTIC-OK" in res.stdout
