"""Sharding rules, divisibility fallbacks, runtime axes, HLO cost model."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import Runtime, abstract_mesh, logical_to_spec
from repro.launch.hlo_cost import analyze_hlo


@pytest.fixture(scope="module")
def rt():
    return Runtime(mesh=jax.make_mesh((1, 1), ("data", "model")))


def test_runtime_axes(rt):
    assert rt.dp_axes == ("data",)
    assert rt.tp_axis == "model"
    assert rt.dp_size == 1 and rt.tp_size == 1


def test_logical_mapping_divisible(rt):
    spec = logical_to_spec(("embed", "ff"), (64, 128), rt)
    assert spec == P("data", "model")


def test_divisibility_fallback():
    # AbstractMesh lets us model a multi-device mesh on the 1-CPU container
    rt = Runtime(mesh=abstract_mesh((1, 2), ("data", "model")))
    fallbacks = []
    spec = logical_to_spec(("heads", "head"), (41, 8), rt, fallbacks)
    assert spec == P(None, None)  # 41 not divisible by 2 -> replicated
    assert fallbacks and fallbacks[0][0] == "heads"


def test_missing_axis_fallback():
    rt = Runtime(mesh=abstract_mesh((2,), ("data",)))  # no 'model'
    spec = logical_to_spec(("ff",), (64,), rt)
    assert spec == P(None)


def test_production_mesh_rules_16x16():
    """The real production-mesh rules at 16x16 sizes (abstract devices)."""
    rt = Runtime(mesh=abstract_mesh((2, 16, 16), ("pod", "data", "model")))
    assert rt.dp_axes == ("pod", "data")
    assert rt.dp_size == 32 and rt.tp_size == 16
    # qwen: 40 heads not divisible by 16 -> replicated; ff 27648 shards
    assert logical_to_spec(("heads",), (40,), rt) == P(None)
    assert logical_to_spec(("ff",), (27648,), rt) == P("model")
    assert logical_to_spec(("embed",), (5120,), rt) == P(("pod", "data"))
    # full-DP mode spans all axes
    rt2 = Runtime(mesh=rt.mesh, full_dp=True)
    assert rt2.dp_size == 512
    assert logical_to_spec(("ff",), (27648,), rt2) == P(None)


def test_pod_axis_detection():
    # only run when enough devices were forced (the dry-run process);
    # locally validate the single-pod path
    rt = Runtime(mesh=jax.make_mesh((1, 1), ("data", "model")))
    assert "pod" not in rt.dp_axes


# ---------------------------------------------------------------------------
# loop-aware HLO cost model
# ---------------------------------------------------------------------------


def test_hlo_cost_counts_scan_trips():
    def withscan(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    x = jnp.ones((64, 128))
    ws = jnp.ones((8, 128, 128))
    compiled = jax.jit(withscan).lower(x, ws).compile()
    got = analyze_hlo(compiled.as_text())["flops"]
    exact = 2 * 64 * 128 * 128 * 8
    assert abs(got - exact) / exact < 0.05
    # and the raw XLA number is ~8x off (documents why we parse the HLO)
    xla = compiled.cost_analysis()
    if isinstance(xla, (list, tuple)):  # older jax returns one dict per device
        xla = xla[0]
    xla = xla["flops"]
    assert got / max(xla, 1) > 6


def test_hlo_cost_nested_scan():
    def nested(x, ws):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner, x, jnp.arange(4))
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    x = jnp.ones((64, 128))
    ws = jnp.ones((8, 128, 128))
    compiled = jax.jit(nested).lower(x, ws).compile()
    got = analyze_hlo(compiled.as_text())["flops"]
    exact = 2 * 64 * 128 * 128 * 8 * 4
    assert abs(got - exact) / exact < 0.05


def test_hlo_cost_dot_flops_exact():
    f = lambda a, b: a @ b
    a = jnp.ones((32, 64))
    b = jnp.ones((64, 48))
    compiled = jax.jit(f).lower(a, b).compile()
    got = analyze_hlo(compiled.as_text())["flops"]
    assert got == pytest.approx(2 * 32 * 64 * 48, rel=0.01)
