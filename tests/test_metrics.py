"""Unit + property tests for Lp distance semantics (repro.core.metrics)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics
from tests_hypothesis_compat import given, settings, st  # optional dep shim

P_GRID = [0.5, 0.6, 0.8, 1.0, 1.2, 1.4, 1.5, 1.7, 2.0]


@pytest.mark.parametrize("p", P_GRID)
def test_lp_matches_numpy_oracle(p, rng):
    q = rng.standard_normal((5, 33)).astype(np.float32)
    x = rng.standard_normal((11, 33)).astype(np.float32)
    got = np.asarray(metrics.pairwise_lp(jnp.asarray(q), jnp.asarray(x), p))
    want = metrics.numpy_lp(q, x, p)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


@pytest.mark.parametrize("p", P_GRID)
def test_root_free_is_ordering_equivalent(p, rng):
    q = rng.standard_normal((3, 17)).astype(np.float32)
    x = rng.standard_normal((40, 17)).astype(np.float32)
    rooted = np.asarray(metrics.pairwise_lp(jnp.asarray(q), jnp.asarray(x), p, root=True))
    raw = np.asarray(metrics.pairwise_lp(jnp.asarray(q), jnp.asarray(x), p, root=False))
    for i in range(q.shape[0]):
        np.testing.assert_array_equal(np.argsort(rooted[i]), np.argsort(raw[i]))


@pytest.mark.parametrize("p", P_GRID)
def test_rowwise_matches_pairwise(p, rng):
    q = rng.standard_normal((4, 21)).astype(np.float32)
    x = rng.standard_normal((9, 21)).astype(np.float32)
    c = jnp.broadcast_to(jnp.asarray(x)[None], (4, 9, 21))
    rw = np.asarray(metrics.rowwise_lp(jnp.asarray(q), c, p))
    pw = np.asarray(metrics.pairwise_lp(jnp.asarray(q), jnp.asarray(x), p))
    np.testing.assert_allclose(rw, pw, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# hypothesis property tests (metric-space invariants)
# ---------------------------------------------------------------------------

vecs = st.integers(2, 24).flatmap(
    lambda d: st.tuples(
        st.lists(st.floats(-50, 50, width=32), min_size=d, max_size=d),
        st.lists(st.floats(-50, 50, width=32), min_size=d, max_size=d),
        st.lists(st.floats(-50, 50, width=32), min_size=d, max_size=d),
    )
)


@settings(max_examples=40, deadline=None)
@given(vecs, st.sampled_from([1.0, 1.3, 1.5, 2.0]))
def test_triangle_inequality_p_ge_1(xyz, p):
    x, y, z = (jnp.asarray(v, dtype=jnp.float32) for v in xyz)
    dxy = float(metrics.lp_distance(x, y, p))
    dyz = float(metrics.lp_distance(y, z, p))
    dxz = float(metrics.lp_distance(x, z, p))
    assert dxz <= dxy + dyz + 1e-3 * (1 + dxy + dyz)


@settings(max_examples=40, deadline=None)
@given(vecs, st.sampled_from([0.5, 0.7, 1.0, 1.5, 2.0]))
def test_symmetry_and_identity(xyz, p):
    x, y, _ = (jnp.asarray(v, dtype=jnp.float32) for v in xyz)
    dxy = float(metrics.lp_distance(x, y, p))
    dyx = float(metrics.lp_distance(y, x, p))
    assert dxy == pytest.approx(dyx, rel=1e-5, abs=1e-5)
    assert float(metrics.lp_distance(x, x, p)) == pytest.approx(0.0, abs=1e-5)
    assert dxy >= 0.0


@settings(max_examples=30, deadline=None)
@given(vecs)
def test_lp_monotone_norm_equivalence(xyz):
    """||v||_p is non-increasing in p (norm equivalence backbone of Fig. 2)."""
    x, y, _ = (jnp.asarray(v, dtype=jnp.float32) for v in xyz)
    ds = [float(metrics.lp_distance(x, y, p)) for p in (0.5, 1.0, 1.5, 2.0)]
    for a, b in zip(ds, ds[1:]):
        assert b <= a * (1 + 1e-4) + 1e-4


def test_cost_model_asymmetry():
    """The paper's Fig. 1 shape: general p >> sqrt family >= L1/L2."""
    d = 128
    basic = [metrics.lp_distance_cost_model(p, d) for p in (1.0, 2.0)]
    sqrt_fam = [metrics.lp_distance_cost_model(p, d) for p in (0.5, 1.5)]
    general = [metrics.lp_distance_cost_model(p, d) for p in (0.7, 1.3, 1.9)]
    assert max(basic) < min(sqrt_fam)
    assert max(sqrt_fam) < min(general)
    # >= "more than an order of magnitude" between L2-MXU and general p
    assert min(general) / metrics.lp_distance_cost_model(2.0, d) > 10


def test_base_metric_selector():
    assert metrics.base_metric_for(0.5) == 1.0
    assert metrics.base_metric_for(1.4) == 1.0
    assert metrics.base_metric_for(1.41) == 2.0
    assert metrics.base_metric_for(2.0) == 2.0
    with pytest.raises(ValueError):
        metrics.base_metric_for(2.5)
