"""Shared fixtures: small synthetic datasets + prebuilt indexes.

NOTE: no XLA_FLAGS device-count forcing here — smoke tests and benches must
see the real single-device CPU backend. Only launch/dryrun.py forces 512
placeholder devices, and it does so before importing jax.
"""

import numpy as np
import pytest

from repro.core.build import build_hnsw, build_hnsw_bulk
from repro.core.datasets import make_dataset


@pytest.fixture(scope="session")
def small_ds():
    """~2k-point SIFT-like dataset: big enough for meaningful recall."""
    return make_dataset("sift", n=2000, n_queries=24, seed=7)


@pytest.fixture(scope="session")
def graphs_bulk(small_ds):
    g1 = build_hnsw_bulk(small_ds.data, 1.0, m=12, seed=0)
    g2 = build_hnsw_bulk(small_ds.data, 2.0, m=12, seed=1)
    return g1, g2


@pytest.fixture(scope="session")
def graph_incremental(small_ds):
    # smaller subset: the sequential builder is Python-bound
    data = small_ds.data[:600]
    return build_hnsw(data, 2.0, m=8, ef_construction=60, seed=0)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
