"""Shared fixtures: small synthetic datasets + prebuilt indexes.

NOTE: no XLA_FLAGS device-count forcing here — smoke tests and benches must
see the real single-device CPU backend. Only launch/dryrun.py forces 512
placeholder devices, and it does so before importing jax.
"""

import jax
import numpy as np
import pytest

from repro.core.build import build_hnsw, build_hnsw_bulk
from repro.core.datasets import make_dataset
from repro.core.uhnsw import UHNSW, UHNSWParams
from repro.index import SegmentedGraphs, ShardedUHNSW, build_segments


@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_cache():
    """Drop compiled executables after each test module.

    The CPU XLA JIT keeps every compiled program alive for the whole
    process; once the suite grew past ~500 tests, the accumulated state
    reliably segfaulted LLVM inside a later large Pallas compile (the
    vector-p abandoning-verify program) in single-process `pytest -x -q`
    runs. Clearing per module bounds the live set to one module's worth.
    Device arrays are unaffected, so session fixtures (datasets, built
    graphs) survive; the cost is cross-module recompiles of the shared
    search programs.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def small_ds():
    """~2k-point SIFT-like dataset: big enough for meaningful recall."""
    return make_dataset("sift", n=2000, n_queries=24, seed=7)


@pytest.fixture(scope="session")
def graphs_bulk(small_ds):
    g1 = build_hnsw_bulk(small_ds.data, 1.0, m=12, seed=0)
    g2 = build_hnsw_bulk(small_ds.data, 2.0, m=12, seed=1)
    return g1, g2


@pytest.fixture(scope="session")
def graph_incremental(small_ds):
    # smaller subset: the sequential builder is Python-bound
    data = small_ds.data[:600]
    return build_hnsw(data, 2.0, m=8, ef_construction=60, seed=0)


@pytest.fixture(scope="session")
def segments4(small_ds):
    """Frozen 4-segment build of small_ds (both base graphs per segment).

    The per-segment graph builds are the expensive part of every sharded
    test; they happen once per session here. Tests never search this object
    directly — they wrap it via `sharded_index` (read-only) or
    `make_sharded` (fresh mutable wrapper per call)."""
    return build_segments(small_ds.data, num_segments=4, m=12, seed=0)


def _wrap_segments(segs4, data, **kwargs):
    """Fresh ShardedUHNSW over the frozen per-segment graphs: the wrapper's
    mutable state (segment lists, delta buffer, params, phase caches) is
    new, while the graphs themselves are shared and never rebuilt
    (compaction appends, it does not modify existing segments)."""
    clone = SegmentedGraphs(
        graphs1=list(segs4.graphs1),
        graphs2=list(segs4.graphs2),
        global_ids=[ids.copy() for ids in segs4.global_ids],
    )
    return ShardedUHNSW(clone, data, **kwargs)


@pytest.fixture(scope="session")
def sharded_index(small_ds, segments4):
    """Session-shared 4-segment index (t=150). READ-ONLY: tests that add(),
    compact(), or mutate params/sharded_params must use make_sharded."""
    return _wrap_segments(segments4, small_ds.data,
                          params=UHNSWParams(t=150), delta_capacity=16)


@pytest.fixture
def make_sharded(small_ds, segments4):
    """Factory for throwaway ShardedUHNSW instances over the session's
    frozen 4-segment build. kwargs forward to ShardedUHNSW.__init__
    (params, delta_capacity, sharded_params)."""
    def _make(**kwargs):
        kwargs.setdefault("params", UHNSWParams(t=150))
        return _wrap_segments(segments4, small_ds.data, **kwargs)

    return _make


@pytest.fixture(scope="session")
def monolithic_index(small_ds, graphs_bulk):
    """Session-shared monolithic UHNSW at the same t as sharded_index —
    the recall-parity reference. READ-ONLY."""
    return UHNSW(*graphs_bulk, UHNSWParams(t=150))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
