"""MoE mode equivalence on a real multi-device mesh (subprocess: the test
process itself must keep the single-device default).

Validates the §Perf 'weights-stationary decode MoE' optimization: the
token-gather path must produce the same outputs as the baseline
weight-gather path at decode shapes.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_arch
    from repro.dist.sharding import Runtime, set_mesh
    from repro.models.ffn import moe_forward
    from repro.models.params import init_params

    cfg = get_arch("deepseek_v3_671b", smoke=True)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rt_base = Runtime(mesh=mesh)
    rt_gather = Runtime(mesh=mesh, moe_decode_gather=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    moe_params = params["segments"][1]["blocks"][0]["channel"]
    moe_params = jax.tree.map(lambda a: a[0], moe_params)  # unstack layer 0
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model),
                          dtype=jnp.bfloat16)
    with set_mesh(mesh):
        base = jax.jit(lambda p, v: moe_forward(p, v, cfg, rt_base))(moe_params, x)
        fast = jax.jit(lambda p, v: moe_forward(p, v, cfg, rt_gather))(moe_params, x)
    base = np.asarray(base, dtype=np.float32)
    fast = np.asarray(fast, dtype=np.float32)
    err = np.abs(base - fast).max() / (np.abs(base).max() + 1e-6)
    assert err < 5e-2, f"moe mode mismatch: rel err {err}"
    print(f"OK rel_err={err:.2e}")
""")


@pytest.mark.slow
def test_moe_decode_gather_matches_baseline():
    env = {**os.environ, "PYTHONPATH": "src"}
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, cwd=Path(__file__).parent.parent, timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout
