"""MLSH baseline sanity (the paper's comparison target)."""

import numpy as np
import pytest

from repro.core.metrics import numpy_lp
from repro.core.mlsh import MLSH, sym_stable


@pytest.fixture(scope="module")
def mlsh(small_ds):
    return MLSH(small_ds.data, m=24, seed=0)


def test_mlsh_recall_and_np(mlsh, small_ds):
    K = 20
    for p in (0.5, 0.75, 1.0):
        ids, dists, nps = mlsh.search_batch(small_ds.queries[:12], p, K)
        rec = 0.0
        for i, q in enumerate(small_ds.queries[:12]):
            d = numpy_lp(q[None], small_ds.data, p, root=False)[0]
            true = set(np.argsort(d, kind="stable")[:K].tolist())
            rec += len(true & set(ids[i].tolist())) / K
        rec /= 12
        assert rec > 0.85, f"p={p} recall {rec}"
        assert (nps <= small_ds.n).all()
        # LSH verifies far more candidates than U-HNSW (the paper's point),
        # but must at least filter *something*
        assert nps.mean() < small_ds.n


def test_mlsh_rejects_out_of_range_p(mlsh, small_ds):
    with pytest.raises(ValueError):
        mlsh.search(small_ds.queries[0], 1.5, 10)


def test_mlsh_index_selection(mlsh, small_ds):
    _, _, s_low = mlsh.search(small_ds.queries[0], 0.5, 5)
    _, _, s_high = mlsh.search(small_ds.queries[0], 0.9, 5)
    assert s_low.base_p == 0.5
    assert s_high.base_p == 1.0


def test_sym_stable_tails():
    """alpha=0.5 stable must be much heavier-tailed than Cauchy (alpha=1)."""
    rng = np.random.default_rng(0)
    s05 = np.abs(sym_stable(0.5, 20000, rng))
    s10 = np.abs(sym_stable(1.0, 20000, rng))
    q05 = np.quantile(s05, 0.99)
    q10 = np.quantile(s10, 0.99)
    assert q05 > 10 * q10


def test_idealized_cost_monotone_in_np(mlsh):
    c1 = mlsh.idealized_query_cost(100, 0.7, 128)
    c2 = mlsh.idealized_query_cost(1000, 0.7, 128)
    assert c2 == pytest.approx(10 * c1)
