"""Cross-segment threshold propagation (DESIGN.md §3): parity + invariants.

The two-phase policy probes a prior-ordered subset of segments with the
full beam, then searches the remaining segments with the probe's running
rank-r base distance as an admission bound. The properties pinned here:

  * knob validation + rank derivation — `ShardedParams` rejects bad
    configs; `resolve_thresh_rank` always returns an ADMISSIBLE rank:
    r >= ceil(t * probe / S) (each probed segment holds at least t/S of
    any merged top-t on average, so bounding at that rank can only prune
    candidates outside the merged top-t) and r >= k (never prunes inside
    the caller's top-k), clamped to [1, t];
  * merge monotonicity — `merge_phase_lists` / `merge_tagged_lists` can
    only tighten the running list: every output rank's distance is <= the
    same rank's distance before the merge. This is the inductive step of
    threshold monotonicity across phases: the bound the cascade hands to
    segment i+1 is never looser than the one it handed to segment i;
  * threshold semantics — thresh=+inf is bitwise the unthresholded
    program (the None-vs-inf jit split must not change results), and a
    degenerate two_phase (probe >= S) is bitwise the independent policy;
  * exactness under the conservative bound — with thresh_rank=t (the
    loosest admissible rank: nothing that could enter the merged top-t is
    ever pruned) the two-phase ids match the exhaustive independent
    policy's ids exactly at the base metrics, where the pruning bound and
    the result metric coincide;
  * recall parity vs the monolithic index at p in {0.5, 1.0, 1.25, 2.0},
    with the delta tier live before AND after compaction — delta-resident
    hits are scanned exactly and must never be pruned by the inherited
    bound;
  * phase attribution — n_b == n_b_probe + n_b_spill exactly, per row,
    and the split surfaces through both serving paths' stats.

Property tests use the optional-hypothesis shim (they skip when the dep
is missing); every property also has a seeded-parametrize fallback that
always runs, so the invariants stay enforced in the bare container.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.hnsw import GraphArrays, exact_topk, knn_search
from repro.core.uhnsw import recall
from repro.index import ShardedParams, build_segments
from repro.index.sharded import (
    merge_phase_lists,
    merge_tagged_lists,
    segmented_knn_search,
)
from repro.retrieval.service import QueryRequest, UniversalVectorService
from tests_hypothesis_compat import given, settings, st  # optional dep shim

P_GRID = [0.5, 1.0, 1.25, 2.0]
K = 10


# ---------------------------------------------------------------------------
# ShardedParams: validation + rank derivation
# ---------------------------------------------------------------------------


def test_params_validation():
    with pytest.raises(ValueError, match="unknown policy"):
        ShardedParams(policy="telepathic")
    with pytest.raises(ValueError, match="probe"):
        ShardedParams(policy="two_phase", probe=0)
    with pytest.raises(ValueError, match="ef_shrink"):
        ShardedParams(policy="two_phase", ef_shrink=0.0)
    with pytest.raises(ValueError, match="ef_shrink"):
        ShardedParams(policy="two_phase", ef_shrink=1.5)
    assert ShardedParams().policy == "independent"  # seed-compatible default


def test_resolve_thresh_rank_cases():
    sp = ShardedParams(policy="two_phase")
    # derived: max(k, ceil(t * probe / S)), clamped to [1, t]
    assert sp.resolve_thresh_rank(t=100, num_segments=4, k=10) == 25
    assert sp.resolve_thresh_rank(t=100, num_segments=4, k=None) == 25
    assert sp.resolve_thresh_rank(t=100, num_segments=4, k=60) == 60
    assert sp.resolve_thresh_rank(t=100, num_segments=4, k=300) == 100
    sp2 = ShardedParams(policy="two_phase", probe=2)
    assert sp2.resolve_thresh_rank(t=100, num_segments=4, k=1) == 50
    # probe clamps to S: the degenerate single-phase case derives rank t
    sp8 = ShardedParams(policy="two_phase", probe=8)
    assert sp8.resolve_thresh_rank(t=100, num_segments=4, k=1) == 100
    # explicit rank wins, clamped to [1, t]
    spx = ShardedParams(policy="two_phase", thresh_rank=999)
    assert spx.resolve_thresh_rank(t=50, num_segments=4, k=10) == 50
    assert ShardedParams(policy="two_phase", thresh_rank=-3) \
        .resolve_thresh_rank(t=50, num_segments=4, k=10) == 1


def _assert_rank_admissible(t, s, probe, k):
    sp = ShardedParams(policy="two_phase", probe=probe)
    r = sp.resolve_thresh_rank(t=t, num_segments=s, k=k)
    pe = max(1, min(probe, s))
    assert 1 <= r <= t
    assert r * s >= t * pe, f"inadmissible rank {r} (t={t} S={s} probe={pe})"
    if k is not None and k <= t:
        assert r >= k, "derived rank prunes inside the caller's top-k"


@pytest.mark.parametrize("seed", range(8))
def test_derived_rank_admissible_seeded(seed):
    rng = np.random.default_rng(seed)
    for _ in range(50):
        t = int(rng.integers(1, 500))
        s = int(rng.integers(1, 12))
        probe = int(rng.integers(1, 12))
        k = None if rng.random() < 0.2 else int(rng.integers(1, t + 1))
        _assert_rank_admissible(t, s, probe, k)


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 500), st.integers(1, 12), st.integers(1, 12),
       st.one_of(st.none(), st.integers(1, 500)))
def test_derived_rank_admissible_property(t, s, probe, k):
    _assert_rank_admissible(t, s, probe, k)


# ---------------------------------------------------------------------------
# merge primitives: the running list only ever tightens
# ---------------------------------------------------------------------------


def _rand_lists(rng, b, w1, w2):
    d_a = np.sort(rng.exponential(1.0, (b, w1)), axis=1).astype(np.float32)
    d_b = np.sort(rng.exponential(1.0, (b, w2)), axis=1).astype(np.float32)
    g_a = rng.integers(0, 10_000, (b, w1)).astype(np.int32)
    g_b = rng.integers(0, 10_000, (b, w2)).astype(np.int32)
    return (jnp.asarray(g_a), jnp.asarray(d_a),
            jnp.asarray(g_b), jnp.asarray(d_b))


def _assert_merge_tightens(g_a, d_a, g_b, d_b, t):
    sg, sd, sf = merge_phase_lists(g_a, d_a, g_b, d_b, t)
    sd, sf = np.asarray(sd), np.asarray(sf)
    # sorted ascending, and never looser than the pre-merge list at any rank
    assert (np.diff(sd, axis=1) >= 0).all()
    w = min(t, d_a.shape[1])
    assert (sd[:, :w] <= np.asarray(d_a)[:, :w] + 1e-7).all(), \
        "merge loosened the running bound"
    # flags attribute each survivor to its source list
    assert np.isin(sf, (0, 1)).all()
    # cascade form: one more merge with a fresh list keeps tightening
    sg2, sd2, sf2 = merge_tagged_lists(sg, jnp.asarray(sd),
                                       jnp.asarray(sf, np.int32),
                                       g_b, d_b, t)
    assert (np.asarray(sd2) <= sd[:, :t] + 1e-7).all()
    assert (np.diff(np.asarray(sd2), axis=1) >= 0).all()


@pytest.mark.parametrize("seed", range(6))
def test_merge_monotone_seeded(seed):
    rng = np.random.default_rng(100 + seed)
    b = int(rng.integers(1, 6))
    w1 = int(rng.integers(1, 40))
    w2 = int(rng.integers(1, 40))
    t = int(rng.integers(1, w1 + w2 + 1))
    _assert_merge_tightens(*_rand_lists(rng, b, w1, w2), t)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 5), st.integers(1, 40),
       st.integers(1, 40))
def test_merge_monotone_property(seed, b, w1, w2):
    rng = np.random.default_rng(seed)
    t = int(rng.integers(1, w1 + w2 + 1))
    _assert_merge_tightens(*_rand_lists(rng, b, w1, w2), t)


# ---------------------------------------------------------------------------
# threshold semantics at the search primitives
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_segments(small_ds):
    """240-point 4-segment corpus: small enough for exhaustive beams."""
    return small_ds.data[:240], build_segments(small_ds.data[:240],
                                               num_segments=4, m=8, seed=3)


def test_thresh_inf_bitwise_equals_none(graph_incremental, small_ds):
    g = graph_incremental
    arrays = GraphArrays.from_graph(g)
    X = jnp.asarray(g.data)
    Q = jnp.asarray(small_ds.queries[:8])
    ids, dists, nb, hops = knn_search(arrays, X, Q, ef=32, t=8)
    inf = jnp.full((Q.shape[0],), jnp.inf)
    ids_i, dists_i, nb_i, hops_i = knn_search(arrays, X, Q, ef=32, t=8,
                                              thresh=inf)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ids_i))
    np.testing.assert_array_equal(np.asarray(dists), np.asarray(dists_i))
    np.testing.assert_array_equal(np.asarray(nb), np.asarray(nb_i))


def test_segmented_thresh_inf_bitwise_equals_none(tiny_segments, small_ds):
    _, segs = tiny_segments
    Q = jnp.asarray(small_ds.queries[:8])
    a = segmented_knn_search(segs.arrays1, segs.X, segs.node_ids, Q,
                             ef=32, t=K)
    b = segmented_knn_search(segs.arrays1, segs.X, segs.node_ids, Q,
                             ef=32, t=K,
                             thresh=jnp.full((Q.shape[0],), jnp.inf))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("base_p", [1.0, 2.0])
def test_oracle_threshold_sound_and_cheaper(tiny_segments, small_ds, base_p):
    """Bound the search at the TRUE k-th-best base distance (the tightest
    admissible oracle). The admission cut never fabricates results: every
    finite returned distance is a true top-k distance, exactly (any point
    at base distance <= the true k-th best IS a top-k member). It also
    must save base-metric work vs the open search. Recall under a bound
    this tight is NOT exactly 1.0 — pruned nodes are not expanded, so a
    below-bound point whose only graph paths run through above-bound
    nodes can strand (measured ~0.95 here). That reachability loss is why
    the two_phase policy derives a looser rank-based bound, and why the
    conservative thresh_rank=t variant (tested below) recovers exact ids
    parity."""
    data, segs = tiny_segments
    Q = jnp.asarray(small_ds.queries[:12])
    arrays = segs.arrays1 if base_p == 1.0 else segs.arrays2
    n_seg = max(g.n for g in segs.graphs1)
    true_ids, true_d = exact_topk(jnp.asarray(data), Q, base_p, K)
    thresh = jnp.asarray(true_d[:, K - 1] * (1 + 1e-6))
    gids, gdists, nb_t, _, _ = segmented_knn_search(
        arrays, segs.X, segs.node_ids, Q, ef=n_seg, t=K, thresh=thresh)
    gids, gdists = np.asarray(gids), np.asarray(gdists)
    true_ids, true_d = np.asarray(true_ids), np.asarray(true_d)
    thresh_np = np.asarray(thresh)
    for i in range(gids.shape[0]):
        # below-bound survivors only: entry-point seeds stay in the list
        # with finite above-bound distances (they are never admitted to
        # expansion, but they do occupy result slots)
        fin = gdists[i] <= thresh_np[i]
        assert set(gids[i][fin]) <= set(true_ids[i]), \
            "thresholded search admitted a non-top-k candidate"
        for j in np.flatnonzero(fin):
            pos = int(np.where(true_ids[i] == gids[i, j])[0][0])
            np.testing.assert_allclose(gdists[i, j], true_d[i, pos],
                                       rtol=1e-5, atol=1e-5)
    assert recall(jnp.asarray(gids), jnp.asarray(true_ids)) >= 0.9
    # the bound actually saved base-metric work vs the open search
    _, _, nb_open, _, _ = segmented_knn_search(
        arrays, segs.X, segs.node_ids, Q, ef=n_seg, t=K)
    assert float(jnp.mean(nb_t)) < float(jnp.mean(nb_open))


# ---------------------------------------------------------------------------
# policy parity on the session 4-segment index
# ---------------------------------------------------------------------------


def test_degenerate_two_phase_is_independent_bitwise(make_sharded, small_ds):
    """probe >= S leaves nothing to spill: bitwise the independent policy."""
    Q = jnp.asarray(small_ds.queries)
    ref = make_sharded(sharded_params=ShardedParams(policy="independent"))
    deg = make_sharded(sharded_params=ShardedParams(policy="two_phase",
                                                    probe=4))
    for p in (0.8, 2.0):
        ids_r, d_r, st_r = ref.search(Q, p, K)
        ids_d, d_d, st_d = deg.search(Q, p, K)
        np.testing.assert_array_equal(np.asarray(ids_r), np.asarray(ids_d))
        np.testing.assert_array_equal(np.asarray(d_r), np.asarray(d_d))
        np.testing.assert_array_equal(np.asarray(st_r.n_b),
                                      np.asarray(st_d.n_b))
        assert float(jnp.max(jnp.asarray(st_d.n_b_spill))) == 0.0


@pytest.mark.parametrize("p", [1.0, 2.0])
def test_conservative_rank_ids_equal_independent(make_sharded, small_ds, p):
    """thresh_rank=t (the loosest admissible bound: nothing that could
    enter the merged top-t is pruned) at the base metrics, where the
    pruning bound and the result metric coincide: ids must match the
    exhaustive independent policy exactly — sharding with threshold
    propagation is then a pure speedup."""
    t = 150
    Q = jnp.asarray(small_ds.queries)
    ref = make_sharded(sharded_params=ShardedParams(policy="independent"))
    safe = make_sharded(sharded_params=ShardedParams(
        policy="two_phase", thresh_rank=t))
    ids_r, d_r, st_r = ref.search(Q, p, K)
    ids_s, d_s, st_s = safe.search(Q, p, K)
    np.testing.assert_array_equal(np.asarray(ids_r), np.asarray(ids_s))
    np.testing.assert_allclose(np.asarray(d_r), np.asarray(d_s), rtol=1e-6)
    # and it must actually be cheaper than exhaustive search
    assert float(jnp.mean(st_s.n_b)) < float(jnp.mean(st_r.n_b))


@pytest.mark.parametrize("policy", ["two_phase", "round_robin"])
@pytest.mark.parametrize("p", P_GRID)
def test_recall_parity_vs_monolithic(make_sharded, monolithic_index,
                                     small_ds, policy, p):
    """Thresholded policies vs the monolithic index across the p grid:
    bounded recall cost (the bench gates the exact budget; here we pin a
    generous invariant floor) at visibly lower N_b than independent."""
    Q = jnp.asarray(small_ds.queries)
    true_ids, _ = exact_topk(jnp.asarray(small_ds.data), Q, p, K)
    idx = make_sharded(sharded_params=ShardedParams(policy=policy))
    ids, _, stats = idx.search(Q, p, K)
    ids_m, _, _ = monolithic_index.search(Q, p, K)
    r_s, r_m = recall(ids, true_ids), recall(ids_m, true_ids)
    assert r_s >= r_m - 0.05, f"{policy} p={p}: {r_s:.3f} vs mono {r_m:.3f}"
    ref = make_sharded(sharded_params=ShardedParams(policy="independent"))
    _, _, st_ref = ref.search(Q, p, K)
    assert float(jnp.mean(stats.n_b)) < float(jnp.mean(st_ref.n_b))


@pytest.mark.parametrize("p", P_GRID)
def test_delta_hits_survive_threshold_pre_and_post_compaction(
        make_sharded, small_ds, p):
    """Delta-resident rows are scanned exactly — the inherited bound must
    never prune them, before or after compaction."""
    idx = make_sharded(sharded_params=ShardedParams(policy="two_phase"),
                       delta_capacity=64)
    rng = np.random.default_rng(7)
    v = (small_ds.data.mean(axis=0)
         + 6.0 * rng.standard_normal(small_ds.data.shape[1])
         ).astype(np.float32)
    gid = idx.add(v)
    assert len(idx.delta) == 1
    ids, dists, _ = idx.search(v[None, :], p, k=3)
    assert int(ids[0, 0]) == gid
    # self-distance ~0 up to the exact-lane's expanded-form |x-q|^2
    # cancellation at this vector scale (identical under independent)
    assert float(dists[0, 0]) == pytest.approx(0.0, abs=0.05)
    idx.compact()
    assert len(idx.delta) == 0
    ids, dists, _ = idx.search(v[None, :], p, k=3)
    assert int(ids[0, 0]) == gid, "compacted insert lost under thresholding"


# ---------------------------------------------------------------------------
# phase attribution: stats stay conserved through every layer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["independent", "two_phase",
                                    "round_robin"])
def test_phase_split_conserves_totals(make_sharded, small_ds, policy):
    Q = jnp.asarray(small_ds.queries)
    idx = make_sharded(sharded_params=ShardedParams(policy=policy))
    for p in (0.8, 1.25):
        _, _, stats = idx.search(Q, p, K)
        nb_pr, nb_sp = stats.phase_n_b()
        np_pr, np_sp = stats.phase_n_p()
        np.testing.assert_allclose(
            np.asarray(nb_pr) + np.asarray(nb_sp), np.asarray(stats.n_b),
            err_msg=f"{policy} p={p}: n_b != probe + spill")
        assert (np.asarray(np_pr) + np.asarray(np_sp)
                <= np.asarray(stats.n_p) + 1e-5).all()
        if policy == "independent":
            assert float(np.max(np.asarray(nb_sp))) == 0.0
        else:
            assert float(np.mean(np.asarray(nb_sp))) > 0.0


def test_serving_paths_surface_phase_stats(make_sharded, small_ds):
    """Both serving paths (v1 submit/drain and the continuous-batching
    engine) aggregate the probe/spill split into their stats dicts."""
    idx = make_sharded(sharded_params=ShardedParams(policy="two_phase"))
    reqs = [QueryRequest(vector=small_ds.queries[i % 8],
                         p=[0.8, 1.25, 2.0][i % 3], k=K, request_id=i)
            for i in range(12)]
    # v1 path
    svc = UniversalVectorService(index=idx, max_batch=16)
    svc.submit(reqs)
    out = svc.drain()
    assert len(out) == 12
    st = svc.stats
    assert st["n_b_spill"] > 0.0
    np.testing.assert_allclose(st["n_b_probe"] + st["n_b_spill"], st["n_b"],
                               rtol=1e-6)
    # engine path (serve)
    svc2 = UniversalVectorService(index=idx, max_batch=16)
    out2 = svc2.serve(reqs)
    assert len(out2) == 12
    st2 = svc2.stats
    assert st2["n_b_spill"] > 0.0
    np.testing.assert_allclose(st2["n_b_probe"] + st2["n_b_spill"],
                               st2["n_b"], rtol=1e-6)
