"""Optional-hypothesis shim: property tests skip when the dep is missing.

`hypothesis` is a dev-only dependency (requirements-dev.txt) and is absent
from the runtime container. Importing `given / settings / st` from here lets
a test module define its strategies and property tests unconditionally: with
hypothesis installed they run as usual; without it only those tests skip —
the module's plain pytest tests (the oracle/parametrized bulk) still run.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
except ImportError:

    class _Strategy:
        """Stand-in whose every method / combinator yields another stand-in,
        so module-level strategy expressions still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

        def __call__(self, *a, **k):
            return _Strategy()

    st = _Strategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass

            skipped.__name__ = f.__name__
            skipped.__doc__ = f.__doc__
            return skipped

        return deco
