"""The perf-regression gate (tools/check_bench.py).

The CI bench-guard job is only as good as its checker, so the checker's
semantics are pinned here: in-band drift passes, >20% regressions on gated
metrics fail, dropped rows fail (coverage loss), new rows are skipped,
quick-mode mismatches skip rather than compare apples to oranges, and the
--selftest (injected 25% regression) trips on the committed baselines.
"""

import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
import check_bench  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]


def _beam_payload(**overrides):
    row = {
        "dataset": "trevi", "p": 0.8, "k": 50, "expand_width": 4,
        "recall": 0.98, "mean_hops": 150.0, "mean_n_b": 1000.0,
        "hops_speedup_vs_w1": 4.0,
    }
    row.update(overrides)
    return {"bench": "beam", "status": "ok", "quick": True, "rows": [row]}


def test_identical_payloads_pass():
    base = _beam_payload()
    problems, notes = check_bench.compare_bench("beam", base, base)
    assert problems == [] and notes == []


def test_in_band_drift_passes():
    base = _beam_payload()
    fresh = _beam_payload(mean_hops=150.0 * 1.15,          # +15% < 20% band
                          hops_speedup_vs_w1=4.0 * 0.85,   # -15%
                          recall=0.97)                     # -1 pt < 2 pt band
    problems, _ = check_bench.compare_bench("beam", base, fresh)
    assert problems == []


@pytest.mark.parametrize("overrides", [
    {"mean_hops": 150.0 * 1.25},            # lower-is-better +25%
    {"hops_speedup_vs_w1": 4.0 * 0.75},     # higher-is-better -25%
    {"recall": 0.95},                       # -3 pt > 2 pt recall band
])
def test_25pct_regression_fails(overrides):
    problems, _ = check_bench.compare_bench(
        "beam", _beam_payload(), _beam_payload(**overrides))
    assert len(problems) == 1, problems


def test_dropped_row_fails_and_new_row_skips():
    base = _beam_payload()
    fresh = _beam_payload(expand_width=8)  # different key: old row gone
    problems, notes = check_bench.compare_bench("beam", base, fresh)
    assert any("coverage dropped" in p for p in problems)
    assert any("new row" in n for n in notes)


def test_quick_mode_mismatch_skips():
    base = _beam_payload()
    fresh = _beam_payload()
    fresh["quick"] = False
    problems, notes = check_bench.compare_bench("beam", base, fresh)
    assert problems == [] and any("quick-mode mismatch" in n for n in notes)


def test_expect_quick_flags_stale_fresh(tmp_path, capsys):
    """With --expect-quick (the CI invocation), a fresh file that is NOT
    from a quick run means the bench silently didn't overwrite the
    committed full-run JSON — that must fail, not skip."""
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    stale = _beam_payload()
    stale["quick"] = False
    (bdir / "BENCH_beam.json").write_text(json.dumps(_beam_payload()))
    (fdir / "BENCH_beam.json").write_text(json.dumps(stale))
    assert check_bench.run_check(bdir, fdir, ["beam"],
                                 expect_quick=True) == 1
    assert "did it run at all" in capsys.readouterr().out
    # without the flag the mismatch stays a documented skip
    assert check_bench.run_check(bdir, fdir, ["beam"]) == 0


def test_expect_quick_flags_bad_baseline(tmp_path):
    """--expect-quick also refuses an unhealthy baseline (full-run or
    errored payload committed to results/baselines/quick) instead of
    silently skipping the whole bench."""
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    (fdir / "BENCH_beam.json").write_text(json.dumps(_beam_payload()))
    stale = _beam_payload()
    stale["quick"] = False
    (bdir / "BENCH_beam.json").write_text(json.dumps(stale))
    assert check_bench.run_check(bdir, fdir, ["beam"],
                                 expect_quick=True) == 1
    errored = _beam_payload()
    errored["status"] = "error"
    (bdir / "BENCH_beam.json").write_text(json.dumps(errored))
    assert check_bench.run_check(bdir, fdir, ["beam"],
                                 expect_quick=True) == 1


def test_malformed_baseline_is_actionable(tmp_path, capsys):
    """A truncated/garbage baseline JSON (e.g. a kill mid-write before the
    file was committed) must produce an actionable failure naming the file
    and the regeneration command — never a JSONDecodeError traceback."""
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    (bdir / "BENCH_beam.json").write_text('{"bench": "beam", "rows": [')
    (fdir / "BENCH_beam.json").write_text(json.dumps(_beam_payload()))
    assert check_bench.run_check(bdir, fdir, ["beam"]) == 1
    out = capsys.readouterr().out
    assert "is malformed" in out
    assert "benchmarks.run --quick --only beam" in out


def test_malformed_fresh_is_actionable(tmp_path, capsys):
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    (bdir / "BENCH_beam.json").write_text(json.dumps(_beam_payload()))
    (fdir / "BENCH_beam.json").write_text("[1, 2, 3]")  # not an object
    assert check_bench.run_check(bdir, fdir, ["beam"]) == 1
    out = capsys.readouterr().out
    assert "is malformed" in out and "expected an object" in out
    assert "interrupted or wrote garbage" in out


def test_missing_fresh_names_the_regen_command(tmp_path, capsys):
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    (bdir / "BENCH_beam.json").write_text(json.dumps(_beam_payload()))
    assert check_bench.run_check(bdir, fdir, ["beam"]) == 1
    out = capsys.readouterr().out
    assert "did the bench run?" in out
    assert "benchmarks.run --quick --only beam" in out


def test_missing_baseline_note_says_how_to_gate(tmp_path, capsys):
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    (fdir / "BENCH_beam.json").write_text(json.dumps(_beam_payload()))
    assert check_bench.run_check(bdir, fdir, ["beam"]) == 0  # note, not fail
    out = capsys.readouterr().out
    assert "no committed baseline" in out
    assert "benchmarks.run --quick --only beam" in out


def test_errored_fresh_run_fails():
    fresh = {"bench": "beam", "status": "error", "quick": True,
             "error": "boom", "rows": []}
    problems, _ = check_bench.compare_bench("beam", _beam_payload(), fresh)
    assert any("status='error'" in p for p in problems)


def test_bool_metric_flip_fails():
    row = {"dataset": "deep", "distinct_p": 8, "k": 10,
           "recall_mixed": 0.95, "speedup_warm": 1.2, "speedup_cold": 2.0,
           "bitwise_equal": True}
    base = {"bench": "serving", "status": "ok", "quick": True, "rows": [row]}
    fresh = json.loads(json.dumps(base))
    fresh["rows"][0]["bitwise_equal"] = False
    problems, _ = check_bench.compare_bench("serving", base, fresh)
    assert any("bitwise_equal" in p for p in problems)


def test_selftest_trips_on_committed_baselines():
    """The exact invocation the CI bench-guard job runs: self-compare must
    pass, the injected 25% regression must fail."""
    baselines = ROOT / "results" / "baselines" / "quick"
    if not baselines.exists() or not list(baselines.glob("BENCH_*.json")):
        pytest.skip("no committed quick baselines")
    assert check_bench.selftest(baselines, ["build", "beam", "serving"]) == 0
