"""Cache-semantics correctness: prefill + decode must reproduce the
full-sequence forward logits position by position (teacher forcing).

This is the strongest test of the serving path: it exercises KV caches
(GQA), latent caches (absorbed-MLA), ring buffers (local attention),
recurrent states (RG-LRU) and SSD states in one invariant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch
from repro.dist.sharding import Runtime, set_mesh
from repro.launch.mesh import make_local_mesh
from repro.models.model import (
    _head_matrix,
    decode_step,
    forward_train,
    prefill,
)
from repro.models.params import init_params

# tolerance is on max |log-prob| difference: the flash path (chunked fp32
# online softmax over bf16 activations) and the dense decode path accumulate
# in different orders, so ~5e-2 noise is expected; semantic cache bugs
# (wrong position, mask, ring indexing) produce O(1)-O(10) differences and
# near-zero argmax agreement, which the second assertion catches.
ARCHS = [
    ("tinyllama_1_1b", 1.5e-1, jnp.bfloat16),       # GQA + rope
    ("qwen2_5_32b", 1.5e-1, jnp.bfloat16),          # GQA + qkv bias
    # MLA absorbed-decode is algebraically exact (fp32 err == 0.0, verified)
    # but its low-rank bottlenecks amplify bf16 noise into O(1) logit shifts
    # on random-init models — test the *semantics* at fp32
    ("deepseek_v3_671b", 1e-3, jnp.float32),        # MLA + MoE, absorbed decode
    ("recurrentgemma_2b", 2e-1, jnp.bfloat16),      # RG-LRU + local ring buffer
    ("mamba2_1_3b", 2e-1, jnp.bfloat16),            # SSD chunked vs recurrent
    ("llama4_scout_17b_a16e", 2e-1, jnp.bfloat16),  # MoE decode dispatch
]


@pytest.mark.parametrize("arch_id,tol,dtype", ARCHS)
def test_prefill_decode_matches_forward(arch_id, tol, dtype):
    cfg = get_arch(arch_id, smoke=True)
    rt = Runtime(mesh=make_local_mesh())
    B, S0, S = 2, 16, 32
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    with set_mesh(rt.mesh):
        params = init_params(cfg, jax.random.PRNGKey(1), dtype=dtype)
        head = _head_matrix(params, cfg)
        # ground truth: full forward, logits at every position
        hidden = forward_train(params, {"tokens": tokens}, cfg, rt)
        full_logits = jnp.einsum("bsd,dv->bsv", hidden, head)

        # serve path: prefill on the first S0 tokens, then decode
        _, cache = prefill(params, {"tokens": tokens[:, :S0]}, cfg, rt, s_max=S)
        agree, total = 0, 0
        for t in range(S0, S):
            logits, cache = decode_step(
                params, tokens[:, t : t + 1], cache, jnp.int32(t), cfg, rt
            )
            got = np.asarray(logits[:, 0, : cfg.vocab_size], dtype=np.float32)
            want = np.asarray(full_logits[:, t, : cfg.vocab_size], dtype=np.float32)
            # compare post-softmax (logit offsets don't matter)
            g = jax.nn.log_softmax(got, axis=-1)
            w = jax.nn.log_softmax(want, axis=-1)
            err = float(jnp.max(jnp.abs(g - w)))
            assert err < tol, f"{arch_id} step {t}: max log-prob err {err}"
            agree += int((np.argmax(g, -1) == np.argmax(w, -1)).sum())
            total += g.shape[0]
        # random-init models have near-flat logits, so tiny numerical noise
        # can flip the argmax: 0.85 still catches any semantic cache bug
        # (those drive agreement to ~chance = 1/vocab)
        assert agree / total >= 0.85, f"{arch_id}: argmax agreement {agree}/{total}"


def test_serve_engine_greedy_deterministic():
    from repro.serve.engine import ServeEngine

    cfg = get_arch("tinyllama_1_1b", smoke=True)
    rt = Runtime(mesh=make_local_mesh())
    with set_mesh(rt.mesh):
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, rt, params, max_seq=64)
        prompts = np.ones((2, 8), dtype=np.int32)
        a = eng.generate(prompts, steps=6)
        b = eng.generate(prompts, steps=6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 6)
    assert (a >= 0).all() and (a < cfg.vocab_size).all()
