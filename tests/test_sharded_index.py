"""Segmented sharded U-HNSW: merge correctness, recall parity, delta tier."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hnsw import GraphArrays, exact_topk, knn_search
from repro.core.uhnsw import UHNSWParams, recall
from repro.index import ShardedUHNSW, build_segments
from repro.index.sharded import segmented_knn_search

P_GRID = [0.5, 1.25, 2.0]
K = 10


# the 4-segment and monolithic indexes come from the session fixtures
# sharded_index / monolithic_index (tests/conftest.py): one graph build
# per session, shared read-only across test modules.

# ---------------------------------------------------------------------------
# pad_to / stack: padding must not change search results
# ---------------------------------------------------------------------------


def test_padded_stacked_search_matches_unpadded(graph_incremental, small_ds):
    g = graph_incremental
    arrays = GraphArrays.from_graph(g)
    X = jnp.asarray(g.data)
    Q = jnp.asarray(small_ds.queries[:8])
    ids, dists, nb, hops = knn_search(arrays, X, Q, ef=32, t=8)

    # pad: +37 phantom nodes, +2 phantom levels, wider level rows
    n_levels = len(arrays.upper_adj) + 2
    sizes = tuple(
        (arrays.upper_adj[l].shape[0] + 5 if l < len(arrays.upper_adj) else 1)
        for l in range(n_levels)
    )
    padded = arrays.pad_to(g.n + 37, n_levels, sizes, upper_m=g.m)
    Xp = jnp.concatenate([X, jnp.zeros((37, g.d))], axis=0)
    ids_p, dists_p, nb_p, _ = knn_search(padded, Xp, Q, ef=32, t=8)

    valid = np.asarray(ids) < g.n
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(ids), -1),
        np.where(np.asarray(ids_p) < padded.n, np.asarray(ids_p), -1),
    )
    np.testing.assert_allclose(np.asarray(dists), np.asarray(dists_p))
    # phantom levels/nodes must not add base-metric evaluations
    np.testing.assert_array_equal(np.asarray(nb), np.asarray(nb_p))

    # a single padded segment stacked S=1 gives identical results again
    stacked = GraphArrays.stack([padded])
    node_ids = jnp.concatenate(
        [jnp.arange(g.n, dtype=jnp.int32),
         jnp.full((37,), -1, dtype=jnp.int32)]
    )[None, :]
    gids, gdists, gnb, _, _ = segmented_knn_search(
        stacked, Xp[None], node_ids, Q, ef=32, t=8
    )
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(ids), -1), np.asarray(gids)
    )
    np.testing.assert_allclose(np.asarray(dists), np.asarray(gdists))


# ---------------------------------------------------------------------------
# merge correctness: exhaustive per-segment beams -> merge must equal oracle
# ---------------------------------------------------------------------------


def test_segment_merge_equals_exact_topk(small_ds):
    """With beams wide enough to visit every node, the S-way merge must
    reproduce the monolithic exact top-k (this isolates the merge logic
    from graph-quality effects)."""
    data = small_ds.data[:240]
    segs = build_segments(data, num_segments=4, m=8, seed=3)
    Q = jnp.asarray(small_ds.queries[:12])
    n_seg = max(g.n for g in segs.graphs1)
    for base_p, arrays in ((1.0, segs.arrays1), (2.0, segs.arrays2)):
        gids, gdists, _, _, _ = segmented_knn_search(
            arrays, segs.X, segs.node_ids, Q, ef=n_seg, t=K
        )
        true_ids, true_d = exact_topk(jnp.asarray(data), Q, base_p, K)
        np.testing.assert_allclose(
            np.asarray(gdists), np.asarray(true_d), rtol=1e-5, atol=1e-5
        )
        assert recall(gids, true_ids) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# recall parity vs the monolithic index (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", P_GRID)
def test_recall_parity_with_monolithic(p, sharded_index, monolithic_index,
                                       small_ds):
    Q = jnp.asarray(small_ds.queries)
    true_ids, _ = exact_topk(jnp.asarray(small_ds.data), Q, p, K)
    ids_s, dists_s, stats_s = sharded_index.search(Q, p, K)
    ids_m, _, _ = monolithic_index.search(Q, p, K)
    r_s, r_m = recall(ids_s, true_ids), recall(ids_m, true_ids)
    assert r_s >= r_m - 0.02, f"p={p}: sharded {r_s:.3f} vs mono {r_m:.3f}"
    # distances come out sorted and rooted
    d = np.asarray(dists_s)
    assert (np.diff(d, axis=1) >= -1e-5).all()
    # early termination must be live: N_p stays well under t for non-base p
    if p not in (1.0, 2.0):
        assert float(jnp.mean(stats_s.n_p)) < 150


def test_base_p_skips_verification(sharded_index, small_ds):
    Q = jnp.asarray(small_ds.queries[:8])
    for p in (1.0, 2.0):
        _, _, stats = sharded_index.search(Q, p, K)
        assert float(jnp.max(stats.n_p)) == 0.0


# ---------------------------------------------------------------------------
# delta tier: streaming inserts
# ---------------------------------------------------------------------------


def test_delta_insert_findable_at_every_p(small_ds):
    idx = ShardedUHNSW.build(
        small_ds.data[:500], num_segments=4, m=8,
        params=UHNSWParams(t=64), seed=1, delta_capacity=64,
    )
    rng = np.random.default_rng(5)
    v = (small_ds.data[:500].mean(axis=0)
         + 6.0 * rng.standard_normal(small_ds.data.shape[1])).astype(np.float32)
    gid = idx.add(v)
    assert len(idx.delta) == 1  # still in the delta tier

    def assert_found():
        for p in P_GRID + [1.0, 1.7]:
            ids, dists, _ = idx.search(v[None, :], p, k=3)
            assert int(ids[0, 0]) == gid, (p, np.asarray(ids[0]))
            assert float(dists[0, 0]) == pytest.approx(0.0, abs=1e-4)

    assert_found()                     # before compaction (delta scan path)
    segs_before = idx.num_segments
    idx.compact()                      # freeze the delta into a new segment
    assert idx.num_segments == segs_before + 1 and len(idx.delta) == 0
    assert_found()                     # after compaction (graph path)


def test_auto_compaction_at_capacity(small_ds):
    idx = ShardedUHNSW.build(
        small_ds.data[:300], num_segments=2, m=8,
        params=UHNSWParams(t=32), seed=2, delta_capacity=8,
    )
    rng = np.random.default_rng(9)
    gids = [idx.add(rng.standard_normal(small_ds.data.shape[1]).astype(np.float32) * 3)
            for _ in range(20)]
    # 20 adds at capacity 8 -> 2 compactions, 4 residents in the delta
    assert idx.num_segments == 4
    assert len(idx.delta) == 4
    assert idx.n == 320
    # every insert remains findable, whichever tier it landed in
    for gid in gids[::3]:
        q = idx.get_vector(gid)[None, :]
        ids, _, _ = idx.search(q, 1.3, k=1)
        assert int(ids[0, 0]) == gid
