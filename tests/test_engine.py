"""Continuous-batching serving engine (repro.retrieval.engine).

Scheduler semantics run against a ManualClock — every deadline test is
deterministic and nothing here ever sleeps. Device-facing tests pin the
engine's correctness contract: staged execution (candidates -> finish)
is bitwise-identical to the fused index call, and engine serving is
bitwise-identical to `serve_grouped` / `serve_v1` for the same request
set, delta tier included.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.uhnsw import UHNSW, UHNSWParams
from repro.index.sharded import ShardedUHNSW
from repro.retrieval.engine import (
    DEADLINE,
    DRAIN,
    FULL,
    BucketScheduler,
    EnginePolicy,
    EngineRequest,
    ManualClock,
    bucket_ladder,
    chunk_plan,
)
from repro.retrieval.service import QueryRequest, UniversalVectorService

P_ACCEPT = [0.5, 0.8, 1.25, 2.0]


# ---------------------------------------------------------------------------
# pure scheduler semantics (no device, no sleeps)
# ---------------------------------------------------------------------------


def _ereq(rid, p=0.8, k=10, now=0.0, max_wait_s=0.005, d=4):
    base = 1.0 if p <= 1.4 else 2.0
    return EngineRequest(
        vector=np.zeros(d, np.float32), p=p, k=k, request_id=rid,
        base=base, exact=p == base, arrival_t=now,
        deadline_t=now + max_wait_s,
    )


def test_bucket_ladder_half_octave():
    assert bucket_ladder(8, 128) == [8, 12, 16, 24, 32, 48, 64, 96, 128]
    assert bucket_ladder(8, 32) == [8, 12, 16, 24, 32]
    # max_batch always present even off-ladder
    assert 20 in bucket_ladder(8, 20)


def test_chunk_plan_minimizes_padding_then_calls():
    lad = bucket_ladder(8, 128)
    assert chunk_plan(96, lad) == [96]        # exact fit, one call
    assert chunk_plan(60, lad) == [48, 12]    # exact fit beats 64 (4 pad)
    assert chunk_plan(30, lad) == [32]        # same 2-pad as 24+8, 1 call
    assert chunk_plan(11, lad) == [12]
    assert chunk_plan(5, lad) == [8]          # sub-min tail pads
    for n in range(1, 129):                   # plans always cover n
        assert sum(chunk_plan(n, lad)) >= n


def test_deadline_flush_under_manual_clock():
    clk = ManualClock()
    sched = BucketScheduler(EnginePolicy(max_batch=32, min_bucket=8), clk)
    for i in range(3):
        sched.admit(_ereq(i, now=clk(), max_wait_s=0.005))
    assert sched.poll() == []         # partial bucket, deadline unexpired
    clk.advance(0.004)
    assert sched.poll() == []         # still inside max_wait
    clk.advance(0.002)                # 6ms > 5ms deadline
    flushes = sched.poll()
    assert len(flushes) == 1
    assert flushes[0].reason == DEADLINE
    assert [r.request_id for r in flushes[0].requests] == [0, 1, 2]
    assert sched.depth == 0
    for r in flushes[0].requests:     # flush time recorded off the clock
        assert r.flush_t == pytest.approx(0.006)


def test_full_bucket_flush_keeps_fifo_and_remainder():
    sched = BucketScheduler(EnginePolicy(max_batch=4, min_bucket=2),
                            ManualClock())
    for i in range(9):
        sched.admit(_ereq(i, max_wait_s=1.0))
    flushes = sched.poll()            # two full flushes, 1 request left
    assert [f.reason for f in flushes] == [FULL, FULL]
    assert [r.request_id for f in flushes for r in f.requests] == \
        list(range(8))
    assert sched.depth == 1
    rest = sched.flush_all()
    assert rest[0].reason == DRAIN
    assert [r.request_id for r in rest[0].requests] == [8]


def test_requeue_goes_to_bucket_front():
    sched = BucketScheduler(EnginePolicy(max_batch=32, min_bucket=8),
                            ManualClock())
    old = [_ereq(i) for i in range(3)]
    for r in old:
        sched.admit(r)
    flushed = sched.flush_all()[0].requests
    sched.admit(_ereq(99))            # arrived after the failure
    sched.requeue(flushed)            # failure recovery: old go first
    out = sched.flush_all()[0].requests
    assert [r.request_id for r in out] == [0, 1, 2, 99]


def test_buckets_key_on_base_k_exact():
    sched = BucketScheduler(EnginePolicy(max_batch=32, min_bucket=8),
                            ManualClock())
    for i, p in enumerate([0.5, 0.8, 1.25]):   # all G1 verify lane
        sched.admit(_ereq(i, p=p))
    sched.admit(_ereq(3, p=1.0))               # G1 exact lane
    sched.admit(_ereq(4, p=2.0))               # G2 exact lane
    sched.admit(_ereq(5, p=0.5, k=5))          # distinct k
    flushes = sched.flush_all()
    keys = {(f.base, f.k, f.exact): len(f.requests) for f in flushes}
    assert keys == {(1.0, 10, False): 3, (1.0, 10, True): 1,
                    (2.0, 10, True): 1, (1.0, 5, False): 1}


# ---------------------------------------------------------------------------
# staged index API: composition identity
# ---------------------------------------------------------------------------


def test_stage_composition_matches_fused_search(small_ds, graphs_bulk):
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=80))
    Q = jnp.asarray(small_ds.queries[:8])
    # scalar verify path (p != base), scalar exact path (p == base)
    for p, base in ((0.8, 1.0), (2.0, 2.0), (1.25, 1.0)):
        fused_ids, fused_d, fused_st = idx.search(Q, p, 10)
        cands = idx.search_stage_candidates(Q, base)
        sids, sd, sst = idx.search_stage_finish(Q, cands, p, 10)
        np.testing.assert_array_equal(np.asarray(fused_ids),
                                      np.asarray(sids), err_msg=f"p={p}")
        np.testing.assert_array_equal(np.asarray(fused_d), np.asarray(sd))
        np.testing.assert_array_equal(np.asarray(fused_st.n_b),
                                      np.asarray(sst.n_b))
    # vector-p over one base: stage composition == the homogeneous slice
    # of the fused mixed call
    ps = np.array([0.5, 0.8, 1.0, 1.25] * 2, np.float32)  # all G1
    fused_ids, fused_d, _ = idx.search(Q, ps, 10)
    cands = idx.search_stage_candidates(Q, 1.0)
    sids, sd, _ = idx.search_stage_finish(Q, cands, ps, 10)
    np.testing.assert_array_equal(np.asarray(fused_ids), np.asarray(sids))
    np.testing.assert_array_equal(np.asarray(fused_d), np.asarray(sd))


def test_sharded_stage_composition_with_delta(small_ds):
    sh = ShardedUHNSW.build(small_ds.data, num_segments=3, m=12,
                            params=UHNSWParams(t=60), seed=0,
                            delta_capacity=64)
    for i in range(6):   # delta-resident rows must merge inside stage B
        sh.add(small_ds.data[i] + 0.01)
    Q = jnp.asarray(small_ds.queries[:6])
    for p, base in ((0.8, 1.0), (2.0, 2.0)):
        fused_ids, fused_d, _ = sh.search(Q, p, 10)
        cands = sh.search_stage_candidates(Q, base)
        sids, sd, _ = sh.search_stage_finish(Q, cands, p, 10)
        np.testing.assert_array_equal(np.asarray(fused_ids),
                                      np.asarray(sids), err_msg=f"p={p}")
        np.testing.assert_array_equal(np.asarray(fused_d), np.asarray(sd))
    ps = np.array([1.5, 2.0, 1.75, 2.0, 1.5, 1.9], np.float32)  # all G2
    fused_ids, fused_d, _ = sh.search(Q, ps, 10)
    cands = sh.search_stage_candidates(Q, 2.0)
    sids, sd, _ = sh.search_stage_finish(Q, cands, ps, 10)
    np.testing.assert_array_equal(np.asarray(fused_ids), np.asarray(sids))
    np.testing.assert_array_equal(np.asarray(fused_d), np.asarray(sd))


# ---------------------------------------------------------------------------
# engine end-to-end (service-level)
# ---------------------------------------------------------------------------


def _requests(small_ds, n, seed=0, k=10):
    rng = np.random.default_rng(seed)
    return [
        QueryRequest(vector=small_ds.queries[i % len(small_ds.queries)],
                     p=float(rng.choice(P_ACCEPT)), k=k, request_id=i)
        for i in range(n)
    ]


@pytest.fixture()
def svc(small_ds, graphs_bulk):
    return UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)), max_batch=32,
        min_bucket=8)


def test_engine_deadline_flush_end_to_end(small_ds, graphs_bulk):
    clk = ManualClock()
    svc = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)), max_batch=32,
        min_bucket=8, max_wait_ms=5.0, clock=clk)
    eng = svc.engine
    reqs = [eng.make_request(QueryRequest(vector=small_ds.queries[i],
                                          p=0.8, k=10, request_id=i))
            for i in range(3)]                   # one (G1, 10, verify) bucket
    eng.admit(reqs)
    eng.pump()
    assert svc.stats["flushes"][DEADLINE] == 0   # nothing due yet
    clk.advance(0.006)                           # past the 5ms deadline
    eng.pump()                                   # deadline flush dispatches
    assert svc.stats["flushes"][DEADLINE] == 1
    out = eng.drain()
    assert len(out) == 3
    assert svc.stats["flushes"][DRAIN] == 0      # nothing left to drain
    # queue-wait in the records is the simulated deadline wait
    rec = list(svc.stats["latency_records"])[-3:]
    for total, queue, compute, _cold in rec:
        assert queue == pytest.approx(6.0)


def test_engine_partial_bucket_dispatch(svc, small_ds):
    before = svc.stats["batches"]
    out = svc.serve(_requests(small_ds, 5, seed=2))
    assert len(out) == 5
    assert svc.stats["flushes"][DRAIN] >= 1      # partial buckets drained
    assert svc.stats["batches"] > before
    assert svc.stats["queries"] == 5             # padding not counted


def test_engine_full_flush_reason(svc, small_ds):
    reqs = [QueryRequest(vector=small_ds.queries[i % 8], p=0.8, k=10,
                         request_id=i) for i in range(32)]
    svc.serve(reqs)
    assert svc.stats["flushes"][FULL] == 1       # 32 == max_batch
    assert svc.stats["batches"] == 1             # one exact-fit wave


def test_engine_admission_shed(small_ds, graphs_bulk):
    svc = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)), max_batch=32,
        watermark=4, overload="shed")
    reqs = _requests(small_ds, 10, seed=3)
    out = svc.serve(reqs)
    assert svc.stats["shed"] == 6                # watermark 4: 6 rejected
    assert len(out) == 4
    served = set(out)
    assert served == {r.request_id for r in reqs[:4]}


def test_engine_admission_degrade_exact_base_lane(small_ds, graphs_bulk):
    svc = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)), max_batch=32,
        watermark=2, overload="degrade")
    reqs = [QueryRequest(vector=small_ds.queries[i], p=0.8, k=10,
                         request_id=i) for i in range(6)]
    out = svc.serve(reqs)
    assert len(out) == 6                         # nobody dropped
    assert svc.stats["degraded"] == 4            # but 4 short-circuited
    # degraded rows carry the base-metric (G1) answer: the exact fast lane
    q = np.stack([r.vector for r in reqs[2:]]).astype(np.float32)
    bids, bdists, _ = svc.index.search(q, 1.0, 10)
    for i, r in enumerate(reqs[2:]):
        np.testing.assert_array_equal(out[r.request_id][0],
                                      np.asarray(bids)[i])


def test_engine_transient_failure_retried_transparently(svc, small_ds,
                                                        monkeypatch):
    """A device call that fails once is retried in place (DESIGN.md §9):
    the caller sees every request served, bitwise-identical to a clean
    run, with the fault visible only in the stats counters."""
    # 40 one-bucket requests -> a full 32-wave + an 8-row drain wave
    reqs = [QueryRequest(vector=small_ds.queries[i % 8], p=0.8, k=10,
                         request_id=i) for i in range(40)]
    clean = svc.serve(reqs)
    real = svc.index.search_stage_candidates
    calls = {"n": 0}

    def flaky(Q, base_p, **kw):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("boom")
        return real(Q, base_p, **kw)

    monkeypatch.setattr(svc.index, "search_stage_candidates", flaky)
    svc2 = UniversalVectorService(index=svc.index, max_batch=32,
                                  min_bucket=8)
    out = svc2.serve(reqs)
    # nothing lost, nothing double-served, nobody sees the fault
    assert set(out) == set(range(40))
    assert svc2.engine.take_failures() == {}
    assert svc2.stats["faults"] == 1
    assert svc2.stats["retries"] == 1
    assert svc2.stats["failed"] == 0
    # the retried wave's results are bitwise-identical to the clean run
    for rid, (ids, dists) in out.items():
        np.testing.assert_array_equal(ids, clean[rid][0])
        np.testing.assert_array_equal(dists, clean[rid][1])


def test_engine_bitwise_vs_grouped_and_v1_sharded_delta(small_ds):
    sh = ShardedUHNSW.build(small_ds.data, num_segments=3, m=12,
                            params=UHNSWParams(t=60), seed=0,
                            delta_capacity=64)
    for i in range(6):
        sh.add(small_ds.data[i] + 0.01)
    svc = UniversalVectorService(index=sh, max_batch=16, min_bucket=8)
    reqs = _requests(small_ds, 20, seed=4)
    engine_out = svc.serve(reqs)
    grouped = svc.serve_grouped(reqs)
    v1 = svc.serve_v1(reqs)
    for r in reqs:
        np.testing.assert_array_equal(engine_out[r.request_id][0],
                                      grouped[r.request_id][0],
                                      err_msg=f"ids p={r.p}")
        np.testing.assert_array_equal(engine_out[r.request_id][1],
                                      grouped[r.request_id][1])
        np.testing.assert_array_equal(engine_out[r.request_id][0],
                                      v1[r.request_id][0])
        np.testing.assert_array_equal(engine_out[r.request_id][1],
                                      v1[r.request_id][1])


def test_engine_bitwise_vs_grouped_interpret(small_ds, graphs_bulk):
    svc = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=60, interpret=True)),
        max_batch=16, min_bucket=8)
    reqs = _requests(small_ds, 8, seed=5)
    engine_out = svc.serve(reqs)
    grouped = svc.serve_grouped(reqs)
    for r in reqs:
        np.testing.assert_array_equal(engine_out[r.request_id][0],
                                      grouped[r.request_id][0],
                                      err_msg=f"ids p={r.p}")
        np.testing.assert_array_equal(engine_out[r.request_id][1],
                                      grouped[r.request_id][1])


# ---------------------------------------------------------------------------
# service hardening + latency attribution satellites
# ---------------------------------------------------------------------------


def test_submit_validation_hardening(svc, small_ds):
    good = small_ds.queries[0]
    with pytest.raises(ValueError, match="k must be >= 1"):
        svc.submit([QueryRequest(vector=good, p=0.8, k=0, request_id=1)])
    with pytest.raises(ValueError, match="non-finite"):
        bad = good.copy()
        bad[0] = np.nan
        svc.submit([QueryRequest(vector=bad, p=0.8, k=5, request_id=2)])
    with pytest.raises(ValueError, match=r"expected d=\d+, got d=3"):
        svc.submit([QueryRequest(vector=np.zeros(3, np.float32), p=0.8,
                                 k=5, request_id=3)])
    assert svc.queue_depth == 0                  # nothing partially queued
    # engine serve validates identically (same _validate)
    with pytest.raises(ValueError, match="k must be >= 1"):
        svc.serve([QueryRequest(vector=good, p=0.8, k=0, request_id=4)])


def test_engine_warmup_precompiles_every_ladder_shape(svc, small_ds):
    eng = svc.engine
    # one verify p per base + one exact-base p: 3 lanes x 5 ladder sizes
    batches = eng.warmup(k=10, ps=(0.8, 1.8, 2.0))
    assert batches == 3 * len(eng.policy.ladder)
    # warmup must not leak into the served counters...
    assert svc.stats["queries"] == 0 and len(svc.stats["latency_ms"]) == 0
    assert eng.take_results() == {}
    # ...but after it, no traffic at these lanes ever rides a compile
    svc.serve(_requests(small_ds, 13, seed=9))     # 13 -> an odd wave mix
    lat = svc.latency_summary()
    assert lat["count"] == 13
    assert lat["cold_count"] == 0


def test_latency_summary_attribution(svc, small_ds):
    svc.serve(_requests(small_ds, 12, seed=6))
    lat = svc.latency_summary()
    assert lat["count"] == 12
    assert lat["p95"] >= lat["p50"] > 0
    # the attribution fix: queue-wait + device-compute == total, per the
    # engine's clock, and first-compile requests are flagged cold
    assert lat["queue_ms"]["p50"] >= 0
    assert lat["compute_ms"]["p50"] > 0
    assert lat["cold_count"] >= 1
    recs = list(svc.stats["latency_records"])
    for total, queue, compute, _cold in recs:
        assert total == pytest.approx(queue + compute, rel=1e-6, abs=1e-6)
    warm = [r for r in recs if not r[3]]
    if warm:
        assert lat["warm"]["count"] == len(warm)
