"""Mixed-p (vector-p) serving: bit-parity vs per-p grouped serving.

The tentpole guarantee (DESIGN.md §6): a mixed-p batch served in ONE
device call returns bitwise-identical (ids, dists) to per-p grouped
serving, on both the jnp-reference and the interpret=True Pallas paths.

Two parity layers are pinned here:

  * STRUCTURAL (bitwise): the traced-p program computes each row from that
    row's data alone, so its per-row results are invariant to batch
    composition and batch size. `serve` and `serve_grouped` run the same
    traced-p programs, so mixed == grouped bit-for-bit.
  * CROSS-PROGRAM (tight rtol): a traced-p row vs the *static-p
    specialized* program at that row's p. The op sequences are selected
    bit-identically (core/lp_ops), but XLA may reassociate the d-axis
    reduction by ~1 ulp on some tile shapes, so this layer asserts
    rtol=1e-6 + identical inf masks rather than bit equality.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.metrics import base_metric_for, pairwise_lp, rowwise_lp
from repro.core.uhnsw import UHNSW, UHNSWParams, verify_candidates
from repro.kernels.ops import lp_gather_distance, pallas_rowwise_lp
from repro.retrieval.service import (
    QueryRequest,
    QueueFull,
    UniversalVectorService,
)

# the acceptance grid: two verification ps (one per base graph), one
# G1-base special p, one G2-base special p
P_ACCEPT = [0.5, 0.8, 1.25, 2.0]
P_ALL = P_ACCEPT + [1.0, 1.5, 0.9]


def _close_with_inf(got, want, err=""):
    np.testing.assert_array_equal(np.isinf(got), np.isinf(want), err_msg=err)
    fin = np.isfinite(want)
    np.testing.assert_allclose(got[fin], want[fin], rtol=1e-6, err_msg=err)


def _mixed_case(seed, b, c, n, d):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32) * 3)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32) * 3)
    ids = rng.integers(-1, n + 2, size=(b, c)).astype(np.int32)
    ps = rng.choice(P_ALL, size=b).astype(np.float32)
    return q, x, jnp.asarray(ids), ps


# ---------------------------------------------------------------------------
# kernel layer
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("interpret", [None, True])
@pytest.mark.parametrize("root", [False, True])
def test_gather_vector_p_rows_match_scalar(interpret, root):
    """Cross-program: vector-p gather rows vs scalar-p specialization."""
    q, x, ids, ps = _mixed_case(3, b=9, c=37, n=120, d=24)
    got = np.asarray(lp_gather_distance(q, ids, x, jnp.asarray(ps),
                                        root=root, interpret=interpret))
    for i, p in enumerate(ps):
        want = np.asarray(lp_gather_distance(q[i:i + 1], ids[i:i + 1], x,
                                             float(p), root=root,
                                             interpret=interpret))[0]
        _close_with_inf(got[i], want, err=f"p={p}")


@pytest.mark.parametrize("interpret", [None, True])
def test_gather_vector_p_batch_invariance_bitwise(interpret):
    """STRUCTURAL: traced-p rows are bit-invariant to batch composition —
    the property mixed-vs-grouped serving parity rests on."""
    q, x, ids, ps = _mixed_case(7, b=16, c=41, n=150, d=24)
    full = np.asarray(lp_gather_distance(q, ids, x, jnp.asarray(ps),
                                         root=True, interpret=interpret))
    for bs in (1, 3, 7, 11):
        sub = np.asarray(lp_gather_distance(q[:bs], ids[:bs], x,
                                            jnp.asarray(ps[:bs]),
                                            root=True, interpret=interpret))
        np.testing.assert_array_equal(full[:bs], sub, err_msg=f"bs={bs}")


def test_gather_vector_p_1d_ids_match_scalar():
    """The delta-scan (shared 1-D ids) shape under vector p."""
    rng = np.random.default_rng(5)
    b, n, d = 8, 90, 16
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ps = rng.choice(P_ALL, size=b).astype(np.float32)
    ids1 = jnp.asarray(rng.integers(-1, n + 1, size=(33,)).astype(np.int32))
    for interpret in (None, True):
        got = np.asarray(lp_gather_distance(q, ids1, x, jnp.asarray(ps),
                                            root=True, interpret=interpret))
        for i, p in enumerate(ps):
            want = np.asarray(lp_gather_distance(q[i:i + 1], ids1, x,
                                                 float(p), root=True,
                                                 interpret=interpret))[0]
            _close_with_inf(got[i], want, err=f"p={p} int={interpret}")


def test_rowwise_kernel_vector_p_matches_scalar():
    rng = np.random.default_rng(11)
    b, c, d = 6, 40, 32
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    cands = jnp.asarray(rng.normal(size=(b, c, d)).astype(np.float32))
    ps = rng.choice(P_ALL, size=b).astype(np.float32)
    got = np.asarray(pallas_rowwise_lp(q, cands, jnp.asarray(ps),
                                       root=True, interpret=True))
    for i, p in enumerate(ps):
        want = np.asarray(pallas_rowwise_lp(q[i:i + 1], cands[i:i + 1],
                                            float(p), root=True,
                                            interpret=True))[0]
        _close_with_inf(got[i], want, err=f"p={p}")


def test_reference_metrics_vector_p_match_scalar():
    rng = np.random.default_rng(17)
    b, n, c, d = 6, 50, 21, 12
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    cands = jnp.asarray(rng.normal(size=(b, c, d)).astype(np.float32))
    ps = np.asarray(P_ALL[:b], dtype=np.float32)
    pw = np.asarray(pairwise_lp(q, x, jnp.asarray(ps)))
    rw = np.asarray(rowwise_lp(q, cands, jnp.asarray(ps)))
    for i, p in enumerate(ps):
        _close_with_inf(pw[i],
                        np.asarray(pairwise_lp(q[i:i + 1], x, float(p)))[0],
                        err=f"pairwise p={p}")
        _close_with_inf(rw[i],
                        np.asarray(rowwise_lp(q[i:i + 1], cands[i:i + 1],
                                              float(p)))[0],
                        err=f"rowwise p={p}")


def test_base_metric_for_vectorized():
    base = base_metric_for(np.asarray([0.5, 1.4, 1.41, 2.0], np.float32))
    np.testing.assert_array_equal(base, [1.0, 1.0, 2.0, 2.0])
    with pytest.raises(ValueError):
        base_metric_for(np.asarray([0.4, 1.0], np.float32))
    with pytest.raises(ValueError):
        base_metric_for(2.5)


# ---------------------------------------------------------------------------
# verification layer
# ---------------------------------------------------------------------------


def _verify_case(seed=23, b=8, t=60, n=300, d=16):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    # plausible candidate lists: random ids with a little padding
    ids = rng.permuted(np.tile(np.arange(n), (b, 1)), axis=1)[:, :t]
    ids[:, -2:] = -1
    ids = jnp.asarray(ids.astype(np.int32))
    ps = rng.choice(P_ACCEPT, size=b).astype(np.float32)
    return q, x, ids, ps


@pytest.mark.parametrize("interpret", [None, True])
def test_verify_candidates_vector_p_batch_invariance(interpret):
    """STRUCTURAL: mixed-batch verification freezes each row at its own
    convergence point — per-row (ids, dists, n_p) are bit-invariant to
    batch mixing. (The convergence while_loop runs until the *slowest*
    row finishes, but finished rows' states are frozen.)"""
    q, x, ids, ps = _verify_case()
    k, kappa = 10, 5
    mv = verify_candidates(q, ids, x, jnp.asarray(ps), k, kappa, 0.92,
                           interpret=interpret)
    for bs in (1, 3, 5):
        sv = verify_candidates(q[:bs], ids[:bs], x, jnp.asarray(ps[:bs]),
                               k, kappa, 0.92, interpret=interpret)
        for j in range(3):  # ids, dists, n_p
            np.testing.assert_array_equal(np.asarray(mv[j])[:bs],
                                          np.asarray(sv[j]), err_msg=f"{j}")


def test_verify_candidates_vector_p_matches_scalar():
    """Cross-program: each vector-p row vs the static-p specialization."""
    q, x, ids, ps = _verify_case()
    k, kappa = 10, 5
    mv = verify_candidates(q, ids, x, jnp.asarray(ps), k, kappa, 0.92)
    for i, p in enumerate(ps):
        sv = verify_candidates(q[i:i + 1], ids[i:i + 1], x, float(p),
                               k, kappa, 0.92)
        np.testing.assert_array_equal(np.asarray(mv[0])[i],
                                      np.asarray(sv[0])[0], err_msg=f"p={p}")
        np.testing.assert_allclose(np.asarray(mv[1])[i],
                                   np.asarray(sv[1])[0], rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(mv[2])[i],
                                      np.asarray(sv[2])[0])


# ---------------------------------------------------------------------------
# index + scheduler layer (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module", params=[None, True],
                ids=["jnp-ref", "pallas-interpret"])
def service_pair(request, small_ds, graphs_bulk):
    """A service on the monolithic index, per exact-Lp dispatch path."""
    params = UHNSWParams(t=100, interpret=request.param)
    return UniversalVectorService(
        index=UHNSW(*graphs_bulk, params), max_batch=32, min_bucket=8,
    ), small_ds


def _accept_stream(small_ds, n=40, seed=0):
    rng = np.random.default_rng(seed)
    return [
        QueryRequest(vector=small_ds.queries[i % len(small_ds.queries)],
                     p=float(rng.choice(P_ACCEPT)), k=10, request_id=i)
        for i in range(n)
    ]


def test_mixed_batch_bitwise_equals_grouped(service_pair):
    """ACCEPTANCE: one mixed-p batched call == per-p grouped serving,
    bitwise on (ids, dists), at p in {0.5, 0.8, 1.25, 2.0}, on both the
    jnp reference and the interpret=True Pallas path."""
    service, small_ds = service_pair
    reqs = _accept_stream(small_ds)
    mixed = service.serve(reqs)
    grouped = service.serve_grouped(reqs)
    for r in reqs:
        np.testing.assert_array_equal(mixed[r.request_id][0],
                                      grouped[r.request_id][0],
                                      err_msg=f"ids p={r.p}")
        np.testing.assert_array_equal(mixed[r.request_id][1],
                                      grouped[r.request_id][1],
                                      err_msg=f"dists p={r.p}")


def test_index_mixed_search_matches_grouped(small_ds, graphs_bulk):
    """Direct index-level vector-p search (no scheduler) is bitwise equal
    to per-p constant-vector calls (structural), and matches the static
    scalar specialization on ids + near-bitwise dists."""
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=100))
    rng = np.random.default_rng(1)
    Q = jnp.asarray(small_ds.queries[:16])
    ps = rng.choice(P_ACCEPT, size=16).astype(np.float32)
    mids, mdists, mstats = idx.search(Q, ps, 10)
    assert np.asarray(mstats.n_b).shape == (16,)
    for pval in np.unique(ps):
        sel = np.flatnonzero(ps == pval)
        # structural: the same traced-p program, grouped batch
        gids, gdists, gstats = idx.search(Q[sel], np.full(sel.size, pval),
                                          10)
        np.testing.assert_array_equal(np.asarray(mids)[sel], np.asarray(gids))
        np.testing.assert_array_equal(np.asarray(mdists)[sel],
                                      np.asarray(gdists))
        np.testing.assert_array_equal(np.asarray(mstats.n_p)[sel],
                                      np.asarray(gstats.n_p))
        np.testing.assert_array_equal(np.asarray(mstats.n_b)[sel],
                                      np.asarray(gstats.n_b))
        # cross-program: the classic static-p path
        sids, sdists, _ = idx.search(Q[sel], float(pval), 10)
        np.testing.assert_array_equal(np.asarray(mids)[sel],
                                      np.asarray(sids))
        np.testing.assert_allclose(np.asarray(mdists)[sel],
                                   np.asarray(sdists), rtol=1e-6)


def test_sharded_mixed_search_with_delta_matches_grouped(small_ds,
                                                         make_sharded):
    # fresh wrapper over the session's frozen 4-segment build: this test
    # mutates the index (delta adds), so it cannot share sharded_index
    sh = make_sharded(params=UHNSWParams(t=80), delta_capacity=64)
    for i in range(8):  # delta-resident rows must merge identically
        sh.add(small_ds.data[i] + 0.01)
    rng = np.random.default_rng(2)
    Q = jnp.asarray(small_ds.queries[:12])
    ps = rng.choice(P_ACCEPT, size=12).astype(np.float32)
    mids, mdists, _ = sh.search(Q, ps, 10)
    for pval in np.unique(ps):
        sel = np.flatnonzero(ps == pval)
        gids, gdists, _ = sh.search(Q[sel], np.full(sel.size, pval), 10)
        np.testing.assert_array_equal(np.asarray(mids)[sel], np.asarray(gids))
        np.testing.assert_array_equal(np.asarray(mdists)[sel],
                                      np.asarray(gdists))


# ---------------------------------------------------------------------------
# scheduler behavior
# ---------------------------------------------------------------------------


def test_scheduler_buckets_two_entry_points(service_pair):
    """A stream with many distinct p values runs in (bases x chunks)
    device batches — not one batch per distinct p."""
    service, small_ds = service_pair
    before = service.stats["batches"]
    many_p = [0.5 + 0.015 * i for i in range(32)]  # 32 distinct ps, all G1
    reqs = [QueryRequest(vector=small_ds.queries[i % 8],
                         p=many_p[i], k=10, request_id=i)
            for i in range(32)]
    out = service.serve(reqs)
    assert len(out) == 32
    n_batches = service.stats["batches"] - before
    bases = {base_metric_for(p) for p in many_p}
    assert n_batches == len(bases), (
        f"{n_batches} device batches for 32 distinct ps; expected one per "
        f"base graph ({len(bases)})"
    )


def test_scheduler_bucket_padding_shapes(service_pair):
    """Chunk sizes pad to the power-of-two ladder; stats exclude padding."""
    service, small_ds = service_pair
    before_q = service.stats["queries"]
    before_pad = service.stats["padded_rows"]
    reqs = _accept_stream(small_ds, n=11, seed=4)
    service.serve(reqs)
    assert service.stats["queries"] - before_q == 11  # padding not counted
    assert service.stats["padded_rows"] > before_pad  # 11 never fits ladder


def test_scheduler_queue_bound_and_stats(small_ds, graphs_bulk):
    service = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)),
        max_batch=16, queue_capacity=8,
    )
    reqs = _accept_stream(small_ds, n=9, seed=5)
    with pytest.raises(QueueFull):
        service.submit(reqs)
    assert service.queue_depth == 0  # no partial enqueue
    service.submit(reqs[:8])
    assert service.queue_depth == 8
    assert service.stats["queue_peak"] == 8
    out = service.drain()
    assert len(out) == 8 and service.queue_depth == 0
    # serve() waves respect the bound internally
    out = service.serve(reqs)
    assert len(out) == 9
    # p out of range rejected before enqueue
    bad = [QueryRequest(vector=small_ds.queries[0], p=3.0, k=5,
                        request_id=99)]
    with pytest.raises(ValueError):
        service.submit(bad)
    assert service.queue_depth == 0


def test_drain_failure_recovers_queue_and_partial_results(small_ds,
                                                          graphs_bulk):
    """A failing bucket re-queues every unserved request and hands back the
    already-computed responses via exc.partial_results; a retry drains the
    remainder (no request is ever lost or double-served)."""
    service = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)), max_verify_batch=8)
    reqs = [QueryRequest(vector=small_ds.queries[i % 8], p=0.8, k=5,
                         request_id=i) for i in range(10)]  # 2 buckets
    service.submit(reqs)
    real_search = service.index.search
    calls = {"n": 0}

    def flaky(q, p, k):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("boom")
        return real_search(q, p, k)

    service.index.search = flaky
    try:
        with pytest.raises(RuntimeError) as ei:
            service.drain()
        served = ei.value.partial_results
        assert len(served) == 8 and service.queue_depth == 2
    finally:
        service.index.search = real_search
    rest = service.drain()
    assert set(served) | set(rest) == set(range(10))
    assert not set(served) & set(rest)


def test_numpy_scalar_p_is_static(small_ds, graphs_bulk):
    """np.float32 / 0-d numpy p must hit the static specialization, not
    crash in the vector path (regression)."""
    idx = UHNSW(*graphs_bulk, UHNSWParams(t=80))
    Q = jnp.asarray(small_ds.queries[:4])
    a, ad, _ = idx.search(Q, np.float32(0.8), 5)
    b, bd, _ = idx.search(Q, 0.8, 5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ad), np.asarray(bd))
    got = pairwise_lp(Q, Q, np.float32(1.5))
    want = pairwise_lp(Q, Q, 1.5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_serve_with_prequeued_requests(small_ds, graphs_bulk):
    """serve() must tolerate a pre-populated queue: no spurious QueueFull,
    and the earlier submissions are served too (FIFO)."""
    service = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)), queue_capacity=8)
    early = _accept_stream(small_ds, n=6, seed=7)
    for r in early:
        r.request_id += 1000
    service.submit(early)
    late = _accept_stream(small_ds, n=10, seed=8)  # 6 + 10 > capacity 8
    out = service.serve(late)
    assert {r.request_id for r in early} <= set(out)
    assert {r.request_id for r in late} <= set(out)
    assert service.queue_depth == 0


def test_scheduler_per_p_and_per_base_stats(small_ds, graphs_bulk):
    """The stats fix: Eq. 1 counters are attributable per base graph and
    per requested p, and agree with the aggregate."""
    service = UniversalVectorService(
        index=UHNSW(*graphs_bulk, UHNSWParams(t=80)))
    reqs = _accept_stream(small_ds, n=24, seed=6)
    service.serve(reqs)
    st = service.stats
    assert st["queries"] == 24
    per_p_q = sum(v["queries"] for v in st["per_p"].values())
    per_base_q = sum(v["queries"] for v in st["per_base"].values())
    assert per_p_q == per_base_q == 24
    assert st["per_base"]["G1"]["queries"] > 0  # 0.5 / 0.8 rows
    assert st["per_base"]["G2"]["queries"] > 0  # 1.25 / 2.0 rows
    np.testing.assert_allclose(
        sum(v["n_p"] for v in st["per_p"].values()), st["n_p"])
    np.testing.assert_allclose(
        sum(v["n_b"] for v in st["per_base"].values()), st["n_b"])
    # p == base metric rows ride the exact lane: no verification at all
    assert st["per_p"]["2"]["n_p"] == 0
    lat = service.latency_summary()
    assert lat["count"] == 24 and lat["p95"] >= lat["p50"] > 0
