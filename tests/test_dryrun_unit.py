"""Unit tests for dry-run machinery that don't need 512 devices."""

import pytest

from repro.configs.base import get_arch
from repro.configs.base import cells as cells_fn


def test_cells_inventory():
    """40 assigned cells; long_500k runnable only for sub-quadratic archs."""
    all_cells = cells_fn(include_skips=True)
    assert len(all_cells) == 40
    skips = [(a, s) for a, s, skip in all_cells if skip]
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    runnable = cells_fn(include_skips=False)
    assert len(runnable) == 32


def test_optimized_settings_table():
    from repro.launch.dryrun import optimized_settings

    # MoE decode -> weights-stationary
    s = optimized_settings("deepseek_v3_671b", "decode_32k")
    assert s.get("moe_decode_gather") is True
    s = optimized_settings("llama4_scout_17b_a16e", "decode_32k")
    assert s.get("moe_decode_gather") is True
    # small GQA-dense train -> full DP
    assert optimized_settings("tinyllama_1_1b", "train_4k").get("full_dp")
    assert optimized_settings("minitron_4b", "train_4k").get("full_dp")
    # excluded by counter-measurements: recurrent mixers, MHA audio, decode
    assert not optimized_settings("recurrentgemma_2b", "train_4k").get("full_dp")
    assert not optimized_settings("mamba2_1_3b", "train_4k").get("full_dp")
    assert not optimized_settings("musicgen_large", "train_4k").get("full_dp")
    assert not optimized_settings("tinyllama_1_1b", "decode_32k").get("full_dp")
    # big models: mb16; deepseek: mb4 (measured optimum)
    assert optimized_settings("nemotron_4_340b", "train_4k")["microbatches"] == 16
    assert optimized_settings("deepseek_v3_671b", "train_4k")["microbatches"] == 4
    # non-train shapes get no microbatching
    assert "microbatches" not in optimized_settings("qwen2_5_32b", "prefill_32k")


def test_roofline_model_flops():
    from benchmarks.roofline import model_flops

    # dense train: 6 N D
    cfg = get_arch("tinyllama_1_1b")
    got = model_flops("tinyllama_1_1b", "train_4k")
    assert got == pytest.approx(6 * cfg.param_count() * 256 * 4096)
    # MoE uses active params
    ds = get_arch("deepseek_v3_671b")
    got = model_flops("deepseek_v3_671b", "train_4k")
    assert got == pytest.approx(6 * ds.active_param_count() * 256 * 4096)
    # decode: 2 N per token
    got = model_flops("qwen2_5_32b", "decode_32k")
    assert got == pytest.approx(2 * get_arch("qwen2_5_32b").param_count() * 128)


def test_collective_shape_parser():
    from repro.launch.dryrun import _shape_bytes

    assert _shape_bytes("f32[16,4096,2048]{2,1,0}") == 16 * 4096 * 2048 * 4
    assert _shape_bytes("(bf16[8,4]{1,0}, s32[2])") == 8 * 4 * 2 + 2 * 4
    assert _shape_bytes("pred[]") == 1


def test_hlo_cost_collective_kinds():
    """Collective classification covers the ops the spec enumerates."""
    from repro.launch.hlo_cost import COLLECTIVES

    assert set(COLLECTIVES) == {
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute",
    }
