"""Quickstart: build a U-HNSW index and answer ANNS-U-Lp queries.

    python examples/quickstart.py [--n 4000] [--dataset sift]

Builds the two base graphs (G1/L1, G2/L2), answers the same query batch
under six different Lp metrics — one index, universal p — and reports
recall vs brute force plus the paper's Eq. 1 cost split. Then serves the
whole mixed-p batch in ONE device call via the per-query-p vector form
(DESIGN.md §6) and checks it returns identical results.

Runs on CPU in well under a minute at the default size; exits 0.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core.datasets import make_dataset
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSW, UHNSWParams, recall

P_DEMO = [0.5, 0.8, 1.0, 1.3, 1.7, 2.0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift")
    ap.add_argument("--n", type=int, default=4_000)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--m", type=int, default=16)
    args = ap.parse_args()

    print(f"generating {args.dataset}-like dataset (n={args.n}) ...")
    ds = make_dataset(args.dataset, n=args.n, n_queries=args.queries, seed=0)

    print("building U-HNSW (two graphs: G1 under L1, G2 under L2) ...")
    t0 = time.time()
    from repro.core.build import build_hnsw_bulk

    g1 = build_hnsw_bulk(ds.data, 1.0, m=args.m, seed=0)
    g2 = build_hnsw_bulk(ds.data, 2.0, m=args.m, seed=1)
    index = UHNSW(g1, g2, UHNSWParams(t=150))
    print(f"  built in {time.time() - t0:.0f}s; index "
          f"{index.index_size_bytes() / 1e6:.1f} MB (excl. data)")

    X, Q = jnp.asarray(ds.data), jnp.asarray(ds.queries)
    print(f"\n{'p':>5} {'recall':>7} {'N_b':>6} {'N_p':>6} "
          f"{'modeled cost':>13} {'wall ms/q':>10}")
    per_p = {}
    for p in P_DEMO:
        t0 = time.time()
        ids, dists, stats = index.search(Q, p, args.k)
        wall = (time.time() - t0) / args.queries * 1e3
        true_ids, _ = exact_topk(X, Q, p, args.k)
        r = recall(ids, true_ids)
        per_p[p] = np.asarray(ids)
        c = index.modeled_query_cost(stats, p, ds.d)
        print(f"{p:>5} {r:>7.3f} {c['N_b']:>6.0f} {c['N_p']:>6.0f} "
              f"{c['total']:>13.0f} {wall:>10.2f}")
    print("\nsame index, every p — no per-p graphs (the paper's point).")

    # the serving form: every query carries its OWN p, one batched call
    # (DESIGN.md §6). Row i of the batch uses metric p_vec[i].
    rng = np.random.default_rng(0)
    tenant = rng.integers(len(P_DEMO), size=args.queries)
    p_vec = np.array([P_DEMO[j] for j in tenant], np.float32)
    mids, _, _ = index.search(Q, p_vec, args.k)
    ok = all(
        np.array_equal(np.asarray(mids)[i], per_p[P_DEMO[tenant[i]]][i])
        for i in range(args.queries)
    )
    print(f"mixed-p batch (one call, {len(set(p_vec.tolist()))} distinct "
          f"p values) matches per-p results: {ok}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
