"""Quickstart: build a U-HNSW index and answer ANNS-U-Lp queries.

    PYTHONPATH=src python examples/quickstart.py [--n 20000] [--dataset sift]

Builds the two base graphs (G1/L1, G2/L2), then answers the same query
batch under five different Lp metrics — one index, universal p — and
reports recall vs brute force plus the paper's Eq. 1 cost split.
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.datasets import make_dataset
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSW, UHNSWParams, recall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sift")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--m", type=int, default=16)
    args = ap.parse_args()

    print(f"generating {args.dataset}-like dataset (n={args.n}) ...")
    ds = make_dataset(args.dataset, n=args.n, n_queries=args.queries, seed=0)

    print("building U-HNSW (two graphs: G1 under L1, G2 under L2) ...")
    t0 = time.time()
    from repro.core.build import build_hnsw_bulk

    g1 = build_hnsw_bulk(ds.data, 1.0, m=args.m, seed=0)
    g2 = build_hnsw_bulk(ds.data, 2.0, m=args.m, seed=1)
    index = UHNSW(g1, g2, UHNSWParams(t=300))
    print(f"  built in {time.time() - t0:.0f}s; index "
          f"{index.index_size_bytes() / 1e6:.1f} MB (excl. data)")

    X, Q = jnp.asarray(ds.data), jnp.asarray(ds.queries)
    print(f"\n{'p':>5} {'recall':>7} {'N_b':>6} {'N_p':>6} "
          f"{'modeled cost':>13} {'wall ms/q':>10}")
    for p in [0.5, 0.8, 1.0, 1.3, 1.7, 2.0]:
        t0 = time.time()
        ids, dists, stats = index.search(Q, p, args.k)
        wall = (time.time() - t0) / args.queries * 1e3
        true_ids, _ = exact_topk(X, Q, p, args.k)
        r = recall(ids, true_ids)
        c = index.modeled_query_cost(stats, p, ds.d)
        print(f"{p:>5} {r:>7.3f} {c['N_b']:>6.0f} {c['N_p']:>6.0f} "
              f"{c['total']:>13.0f} {wall:>10.2f}")
    print("\nsame index, every p — no per-p graphs (the paper's point).")


if __name__ == "__main__":
    main()
