"""Train a ~100M-parameter LM end to end on the synthetic pipeline.

    PYTHONPATH=src python examples/train_lm.py --steps 300        # ~100M model
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 50  # CI-sized

Demonstrates the full training substrate: config system, data pipeline,
AdamW, checkpointing (async, auto-resume), heartbeat monitoring. On real
hardware the same driver scales through launch/mesh.py's production meshes;
here it runs on the local device mesh.
"""

import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.tiny:
        # reduced same-family config (fast CPU smoke)
        argv = ["--arch", "tinyllama_1_1b", "--smoke",
                "--batch", "8", "--seq", "64"]
    else:
        # ~100M llama-family model: override tinyllama's width/depth
        import repro.configs.tinyllama_1_1b as tl

        cfg100 = tl.config().with_overrides(
            name="llama_100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_ff=2048, vocab_size=32_000,
        )
        # register it so --arch can find it
        import sys
        import types

        mod = types.ModuleType("repro.configs.llama_100m")
        mod.config = lambda: cfg100
        mod.smoke = lambda: cfg100
        sys.modules["repro.configs.llama_100m"] = mod
        argv = ["--arch", "llama_100m", "--batch", "4", "--seq", "256"]

    argv += ["--steps", str(args.steps), "--ckpt-dir", args.ckpt_dir,
             "--save-every", "50", "--log-every", "10", "--lr", "3e-4"]
    print(f"launching: train {' '.join(argv)}")
    raise SystemExit(train_main(argv))


if __name__ == "__main__":
    main()
