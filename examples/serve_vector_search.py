"""End-to-end serving driver: a universal-Lp vector search service under a
batched mixed-p request stream (the paper's deployment scenario).

    PYTHONPATH=src python examples/serve_vector_search.py [--requests 512]

Simulates a multi-tenant retrieval tier: each tenant has tuned its own
metric p (per the paper's motivation — the optimal p is task-specific),
requests arrive interleaved, the service groups them by p and serves them
in device batches. Reports throughput, per-p recall, and the Eq. 1 cost
accounting aggregated across the stream.
"""

import argparse
import time

import numpy as np

from repro.core.datasets import make_dataset
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSWParams
from repro.retrieval.service import QueryRequest, UniversalVectorService

TENANT_PS = [0.5, 0.7, 0.9, 1.2, 1.6, 2.0]  # each tenant's tuned metric


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="deep")
    ap.add_argument("--n", type=int, default=10_000)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    ds = make_dataset(args.dataset, n=args.n, n_queries=256, seed=1)
    print(f"building service over {args.dataset}-like corpus n={ds.n} d={ds.d} ...")
    t0 = time.time()
    service = UniversalVectorService.build(
        ds.data, UHNSWParams(t=200), m=16, seed=0
    )
    print(f"  index built in {time.time() - t0:.0f}s")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        tenant = int(rng.integers(len(TENANT_PS)))
        q = ds.queries[int(rng.integers(len(ds.queries)))]
        reqs.append(QueryRequest(vector=q, p=TENANT_PS[tenant], k=args.k,
                                 request_id=i))

    print(f"serving {len(reqs)} mixed-p requests "
          f"({len(TENANT_PS)} tenants) ...")
    t0 = time.time()
    results = service.serve(reqs)
    dt = time.time() - t0
    print(f"  {len(results)} responses in {dt:.1f}s "
          f"({len(results) / dt:.0f} qps on 1 CPU; "
          f"batches={service.stats['batches']})")
    print(f"  Eq.1 accounting: avg N_b={service.stats['n_b']/len(reqs):.0f} "
          f"avg N_p={service.stats['n_p']/len(reqs):.0f} per query")

    # spot-check recall per tenant metric
    import jax.numpy as jnp

    X = jnp.asarray(ds.data)
    print(f"\n{'tenant p':>9} {'recall@10':>10}")
    for p in TENANT_PS:
        sub = [r for r in reqs if r.p == p][:20]
        if not sub:
            continue
        Q = jnp.asarray(np.stack([r.vector for r in sub]))
        true_ids, _ = exact_topk(X, Q, p, args.k)
        hits = sum(
            len(set(map(int, results[r.request_id][0])) & set(map(int, t)))
            for r, t in zip(sub, np.asarray(true_ids))
        )
        print(f"{p:>9} {hits / (len(sub) * args.k):>10.3f}")


if __name__ == "__main__":
    main()
