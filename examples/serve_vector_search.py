"""End-to-end serving driver: a universal-Lp vector search service under a
batched mixed-p request stream (the paper's deployment scenario).

    python examples/serve_vector_search.py [--requests 256]

Simulates a multi-tenant retrieval tier: each tenant has tuned its own
metric p (per the paper's motivation — the optimal p is task-specific),
requests arrive interleaved, and the micro-batching scheduler serves them
in padded fixed-shape buckets with p as a per-query tensor (DESIGN.md
§6) — two compiled entry points regardless of how many tenants there
are. Reports throughput, latency percentiles, the per-base-graph /
per-p Eq. 1 accounting, and spot-checks recall per tenant metric.

Runs on CPU in about a minute at the default size; exits 0.
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.datasets import make_dataset
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSWParams
from repro.retrieval.service import QueryRequest, UniversalVectorService

TENANT_PS = [0.5, 0.7, 0.9, 1.2, 1.6, 2.0]  # each tenant's tuned metric


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="deep")
    ap.add_argument("--n", type=int, default=3_000)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    ds = make_dataset(args.dataset, n=args.n, n_queries=128, seed=1)
    print(f"building service over {args.dataset}-like corpus "
          f"n={ds.n} d={ds.d} ...")
    t0 = time.time()
    service = UniversalVectorService.build(
        ds.data, UHNSWParams(t=150), m=16, seed=0, max_batch=128,
    )
    print(f"  index built in {time.time() - t0:.0f}s")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        tenant = int(rng.integers(len(TENANT_PS)))
        q = ds.queries[int(rng.integers(len(ds.queries)))]
        reqs.append(QueryRequest(vector=q, p=TENANT_PS[tenant], k=args.k,
                                 request_id=i))

    print(f"serving {len(reqs)} mixed-p requests "
          f"({len(TENANT_PS)} tenants) ...")
    t0 = time.time()
    results = service.serve(reqs)
    dt = time.time() - t0
    st = service.stats
    lat = service.latency_summary()
    print(f"  {len(results)} responses in {dt:.1f}s "
          f"({len(results) / dt:.0f} qps on 1 CPU; "
          f"{st['batches']} padded buckets, "
          f"{st['padded_rows']} padding rows, "
          f"queue peak {st['queue_peak']})")
    print(f"  latency: p50={lat['p50']:.0f}ms p95={lat['p95']:.0f}ms")
    print(f"  Eq.1 accounting: avg N_b={st['n_b'] / len(reqs):.0f} "
          f"avg N_p={st['n_p'] / len(reqs):.0f} per query")
    for gname, pb in st["per_base"].items():
        if pb["queries"]:
            print(f"    {gname}: {pb['queries']} queries in "
                  f"{pb['batches']} buckets, "
                  f"avg N_b={pb['n_b'] / pb['queries']:.0f} "
                  f"avg N_p={pb['n_p'] / pb['queries']:.0f}")

    # spot-check recall per tenant metric
    import jax.numpy as jnp

    X = jnp.asarray(ds.data)
    print(f"\n{'tenant p':>9} {'recall@10':>10}")
    worst = 1.0
    for p in TENANT_PS:
        sub = [r for r in reqs if r.p == p][:20]
        if not sub:
            continue
        Q = jnp.asarray(np.stack([r.vector for r in sub]))
        true_ids, _ = exact_topk(X, Q, p, args.k)
        hits = sum(
            len(set(map(int, results[r.request_id][0])) & set(map(int, t)))
            for r, t in zip(sub, np.asarray(true_ids))
        )
        r_at_k = hits / (len(sub) * args.k)
        worst = min(worst, r_at_k)
        print(f"{p:>9} {r_at_k:>10.3f}")
    return 0 if worst > 0.5 else 1


if __name__ == "__main__":
    sys.exit(main())
