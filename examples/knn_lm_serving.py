"""Retrieval-augmented decoding (kNN-LM) with a per-request Lp metric.

    PYTHONPATH=src python examples/knn_lm_serving.py

1. Briefly trains a small LM on the synthetic Markov stream.
2. Builds a U-HNSW datastore of (hidden state -> next token) pairs from the
   trained model's own activations.
3. Serves held-out contexts with plain LM decoding and with kNN-LM mixing,
   sweeping the retrieval metric p — the knob the paper makes free.

Expected: kNN-LM lowers NLL vs the plain LM, and the best p varies with
the datastore geometry (the paper's motivation for universal-p serving).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.data.pipeline import SyntheticTokenPipeline
from repro.dist.sharding import Runtime, set_mesh
from repro.launch.mesh import make_local_mesh
from repro.models.model import _head_matrix, forward_train
from repro.retrieval.knn_lm import KnnLM
from repro.train.step import TrainConfig, init_train_state, make_train_step


def main():
    cfg = get_arch("tinyllama_1_1b", smoke=True)
    rt = Runtime(mesh=make_local_mesh())
    tc = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    pipe = SyntheticTokenPipeline(cfg, global_batch=8, seq_len=64, seed=0)

    with set_mesh(rt.mesh):
        print("training a small LM on the synthetic stream ...")
        state = init_train_state(cfg, rt, tc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, rt, tc), donate_argnums=(0,))
        for i in range(40):
            state, m = step(state, pipe.batch(i))
            if i % 10 == 0:
                print(f"  step {i}: loss {float(m['loss']):.3f}")
        params = state["params"]

        print("building the (hidden -> next token) datastore ...")
        # the datastore covers the serving distribution (in production it is
        # built from the corpus the service answers over — kNN-LM's value is
        # recalling continuations the parametric model undertrained on)
        hiddens, nexts = [], []
        fwd = jax.jit(lambda p, b: forward_train(p, b, cfg, rt))
        for i in list(range(40, 48)) + [99]:
            batch = pipe.batch(i)
            h = fwd(params, batch)
            hiddens.append(np.asarray(h, dtype=np.float32).reshape(-1, cfg.d_model))
            nexts.append(np.asarray(batch["labels"]).reshape(-1))
        hidden = np.concatenate(hiddens)[:5000]
        next_tok = np.concatenate(nexts)[:5000]
        knn = KnnLM.build_from_hidden(hidden, next_tok, cfg.vocab_size,
                                      m=8, k=8, lam=0.3, temperature=1.0)

        print("evaluating held-out contexts: plain LM vs kNN-LM across p ...")
        batch = pipe.batch(99)
        h = np.asarray(fwd(params, batch), dtype=np.float32)
        head = np.asarray(_head_matrix(params, cfg), dtype=np.float32)
        labels = np.asarray(batch["labels"])
        B, S = labels.shape
        flat_h = h.reshape(-1, cfg.d_model)[:256]
        flat_y = labels.reshape(-1)[:256]
        logits = flat_h @ head
        logits = logits[:, : cfg.vocab_size]
        lm_lp = jax.nn.log_softmax(jnp.asarray(logits), axis=-1)
        lm_lp = np.asarray(lm_lp)
        nll_lm = -lm_lp[np.arange(len(flat_y)), flat_y].mean()
        print(f"  plain LM NLL: {nll_lm:.3f}")
        for p in [0.5, 0.8, 1.0, 1.4, 2.0]:
            mixed = knn.mix(lm_lp, flat_h, p)
            nll = -mixed[np.arange(len(flat_y)), flat_y].mean()
            print(f"  kNN-LM (p={p}): NLL {nll:.3f} "
                  f"({'better' if nll < nll_lm else 'worse'})")


if __name__ == "__main__":
    main()
