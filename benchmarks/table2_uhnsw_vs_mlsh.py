"""Paper Table 2: U-HNSW vs (idealized) MLSH on ANNS-U-Lp, p in [0.5, 0.9].

Per the paper's §4.1.4 methodology, MLSH is charged only its Q2D Lp cost
N_p * T_p (idealized), with the *same* per-distance cost T_p as U-HNSW —
implementation-agnostic. U-HNSW pays Eq. 1: N_b*T_b + N_p*T_p. We report
  * recall (target >= 0.9),
  * modeled query cost (TPU cost model) + measured CPU wall-clock,
  * index sizes (U-HNSW: G1 only, since p <= 1 — paper §4.2),
and the speedup ratio of idealized-MLSH over U-HNSW.

Claim under test: U-HNSW is 4.4x-15x faster than idealized MLSH at equal or
better recall with a smaller index (paper Table 2).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    BENCH_SIZES, K_DEFAULT, emit, get_dataset, get_uhnsw, ground_truth,
)
from repro.core.metrics import lp_distance_cost_model
from repro.core.mlsh import MLSH
from repro.core.uhnsw import recall

P_VALUES = [0.5, 0.6, 0.7, 0.8, 0.9]  # paper: uniform over this set


def run(quick: bool = False):
    datasets = ["sift", "gist"] if quick else list(BENCH_SIZES)
    rows = []
    for name in datasets:
        ds = get_dataset(name)
        idx = get_uhnsw(name)
        mlsh = MLSH(ds.data, m=24, seed=0)
        d = ds.d
        u_rec, u_cost, u_wall = [], [], []
        m_rec, m_cost = [], []
        for p in P_VALUES:
            true_ids, _ = ground_truth(name, p, K_DEFAULT)
            t0 = time.perf_counter()
            ids, _, stats = idx.search(jnp.asarray(ds.queries), p, K_DEFAULT)
            ids = np.asarray(ids)
            u_wall.append((time.perf_counter() - t0) / len(ds.queries) * 1e3)
            u_rec.append(recall(ids, true_ids))
            c = idx.modeled_query_cost(stats, p, d)
            u_cost.append(c["total"])
            m_ids, _, nps = mlsh.search_batch(ds.queries, p, K_DEFAULT)
            m_rec.append(recall(m_ids, true_ids))
            m_cost.append(float(nps.mean()) * lp_distance_cost_model(p, d))
        row = {
            "bench": "table2", "dataset": name, "n": ds.n, "d": d,
            "recall_uhnsw": round(float(np.mean(u_rec)), 3),
            "recall_mlsh": round(float(np.mean(m_rec)), 3),
            "model_cost_uhnsw": round(float(np.mean(u_cost)), 0),
            "model_cost_mlsh_idealized": round(float(np.mean(m_cost)), 0),
            "speedup_vs_idealized_mlsh": round(
                float(np.mean(m_cost) / np.mean(u_cost)), 2
            ),
            "wall_ms_uhnsw": round(float(np.mean(u_wall)), 2),
            "index_mb_uhnsw_g1": round(idx.g1.index_size_bytes() / 1e6, 2),
            "index_mb_mlsh": round(mlsh.index_size_bytes() / 1e6, 2),
        }
        rows.append(row)
        print(f"# {name}: U-HNSW recall {row['recall_uhnsw']} vs MLSH "
              f"{row['recall_mlsh']}; speedup {row['speedup_vs_idealized_mlsh']}x "
              f"(paper: 4.4x-15x)")
    emit(rows, "table2_uhnsw_vs_mlsh")
    return rows


if __name__ == "__main__":
    run()
