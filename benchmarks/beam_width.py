"""W-sweep of the multi-expansion beam search (DESIGN.md §2 hot path).

For W ∈ {1, 2, 4, 8} runs the same ANNS-U-Lp workload (fractional p, so the
full generate+verify pipeline executes) and records recall, mean level-0
`while_loop` trip count (stats.hops), mean N_b / N_p (paper Eq. 1), and
wall-clock per query. The tentpole claim this tracks: W=4 cuts the level-0
trip count >= 2x vs W=1 at equal recall — the serialized pointer-chase
becomes a quarter as many hops, each doing 4x wider (hardware-friendly)
tensor work.

  PYTHONPATH=src python -m benchmarks.run --only beam [--quick]
"""

from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import K_DEFAULT, get_dataset, get_uhnsw, ground_truth
from repro.core.uhnsw import recall

P_QUERY = 0.8  # fractional p: G1 candidates + exact-Lp verification
WIDTHS = (1, 2, 4, 8)
TIMING_REPS = 3


def _merge_microbench(quick: bool) -> dict:
    """Cost of the level-0 (ef + W*m0) merge's expanded-mask construction
    (DESIGN.md §2.1): the historical code rebuilt `jnp.isinf` over the
    full concatenated array every hop; the hoisted form masks only the
    (W*m0) frontier half, relying on the invariant that beam entries with
    inf distance always carry exp=1 (sentinel init + every earlier
    merge's forcing). Both variants are measured here so the note in
    DESIGN.md §2.1 stays pinned to data; the merge sort itself dominates,
    which is why the win is a few percent of the hop, not a multiple.
    """
    ef, w, m0 = 600, 4, 32
    reps = 200 if quick else 1000
    rng = np.random.default_rng(0)
    dist = jnp.asarray(rng.exponential(size=ef).astype(np.float32))
    dv = jnp.asarray(
        np.where(rng.random(w * m0) < 0.3, np.inf,
                 rng.exponential(size=w * m0)).astype(np.float32))
    ids = jnp.asarray(rng.permutation(ef * 4)[:ef].astype(np.int32))
    nbrs = jnp.asarray(rng.permutation(ef * 4)[:w * m0].astype(np.int32))
    exp = jnp.asarray((rng.random(ef) < 0.5).astype(np.int32))

    @jax.jit
    def merge_full_mask(ids, dist, exp, nbrs, dv):
        all_ids = jnp.concatenate([ids, nbrs])
        all_dist = jnp.concatenate([dist, dv])
        all_exp = jnp.concatenate([exp, jnp.zeros((w * m0,), jnp.int32)])
        all_exp = jnp.where(jnp.isinf(all_dist), 1, all_exp)
        sd, si, se = jax.lax.sort((all_dist, all_ids, all_exp), num_keys=1)
        return si[:ef], sd[:ef], se[:ef]

    @jax.jit
    def merge_hoisted(ids, dist, exp, nbrs, dv):
        all_ids = jnp.concatenate([ids, nbrs])
        all_dist = jnp.concatenate([dist, dv])
        all_exp = jnp.concatenate([exp, jnp.isinf(dv).astype(jnp.int32)])
        sd, si, se = jax.lax.sort((all_dist, all_ids, all_exp), num_keys=1)
        return si[:ef], sd[:ef], se[:ef]

    def timed(fn):
        jax.block_until_ready(fn(ids, dist, exp, nbrs, dv))
        t0 = time.time()
        for _ in range(reps):
            out = fn(ids, dist, exp, nbrs, dv)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps * 1e6

    us_full = timed(merge_full_mask)
    us_hoist = timed(merge_hoisted)
    row = {
        "dataset": "merge-microbench", "p": None, "k": None,
        "expand_width": w, "ef": ef, "m0": m0,
        "us_per_merge_full_mask": round(us_full, 2),
        "us_per_merge_hoisted": round(us_hoist, 2),
        "mask_hoist_speedup": round(us_full / us_hoist, 3),
    }
    print(f"  merge micro-bench (ef={ef}, W*m0={w * m0}): full-mask "
          f"{us_full:.1f}us vs hoisted {us_hoist:.1f}us "
          f"({row['mask_hoist_speedup']}x)", flush=True)
    return row


def run(quick: bool = False):
    name = "trevi" if quick else "sun"
    widths = (1, 4) if quick else WIDTHS
    ds = get_dataset(name)
    idx = get_uhnsw(name)
    Q = jnp.asarray(ds.queries)
    true_ids, _ = ground_truth(name, P_QUERY, K_DEFAULT)

    rows = []
    for w in widths:
        idx.params = replace(idx.params, expand_width=w)
        # warm the per-W jit cache, then time steady-state
        ids, _, stats = idx.search(Q, P_QUERY, K_DEFAULT)
        jax.block_until_ready(ids)
        t0 = time.time()
        for _ in range(TIMING_REPS):
            ids, _, stats = idx.search(Q, P_QUERY, K_DEFAULT)
            jax.block_until_ready(ids)
        ms_per_query = (time.time() - t0) / TIMING_REPS / Q.shape[0] * 1e3
        rows.append({
            "dataset": name,
            "p": P_QUERY,
            "k": K_DEFAULT,
            "expand_width": w,
            "recall": round(recall(np.asarray(ids), true_ids), 4),
            "mean_hops": round(float(jnp.mean(stats.hops)), 1),
            "mean_n_b": round(float(jnp.mean(stats.n_b)), 1),
            "mean_n_p": round(float(jnp.mean(stats.n_p)), 1),
            "ms_per_query": round(ms_per_query, 3),
        })
        print(f"  W={w}: recall={rows[-1]['recall']:.4f} "
              f"hops={rows[-1]['mean_hops']} N_b={rows[-1]['mean_n_b']} "
              f"N_p={rows[-1]['mean_n_p']} {ms_per_query:.2f} ms/q",
              flush=True)

    base = rows[0]
    for r in rows[1:]:
        r["hops_speedup_vs_w1"] = round(base["mean_hops"] / r["mean_hops"], 2)
    rows.append(_merge_microbench(quick))
    return rows
