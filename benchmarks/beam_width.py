"""W-sweep of the multi-expansion beam search (DESIGN.md §2 hot path).

For W ∈ {1, 2, 4, 8} runs the same ANNS-U-Lp workload (fractional p, so the
full generate+verify pipeline executes) and records recall, mean level-0
`while_loop` trip count (stats.hops), mean N_b / N_p (paper Eq. 1), and
wall-clock per query. The tentpole claim this tracks: W=4 cuts the level-0
trip count >= 2x vs W=1 at equal recall — the serialized pointer-chase
becomes a quarter as many hops, each doing 4x wider (hardware-friendly)
tensor work.

  PYTHONPATH=src python -m benchmarks.run --only beam [--quick]
"""

from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import K_DEFAULT, get_dataset, get_uhnsw, ground_truth
from repro.core.uhnsw import recall

P_QUERY = 0.8  # fractional p: G1 candidates + exact-Lp verification
WIDTHS = (1, 2, 4, 8)
TIMING_REPS = 3


def run(quick: bool = False):
    name = "trevi" if quick else "sun"
    widths = (1, 4) if quick else WIDTHS
    ds = get_dataset(name)
    idx = get_uhnsw(name)
    Q = jnp.asarray(ds.queries)
    true_ids, _ = ground_truth(name, P_QUERY, K_DEFAULT)

    rows = []
    for w in widths:
        idx.params = replace(idx.params, expand_width=w)
        # warm the per-W jit cache, then time steady-state
        ids, _, stats = idx.search(Q, P_QUERY, K_DEFAULT)
        jax.block_until_ready(ids)
        t0 = time.time()
        for _ in range(TIMING_REPS):
            ids, _, stats = idx.search(Q, P_QUERY, K_DEFAULT)
            jax.block_until_ready(ids)
        ms_per_query = (time.time() - t0) / TIMING_REPS / Q.shape[0] * 1e3
        rows.append({
            "dataset": name,
            "p": P_QUERY,
            "k": K_DEFAULT,
            "expand_width": w,
            "recall": round(recall(np.asarray(ids), true_ids), 4),
            "mean_hops": round(float(jnp.mean(stats.hops)), 1),
            "mean_n_b": round(float(jnp.mean(stats.n_b)), 1),
            "mean_n_p": round(float(jnp.mean(stats.n_p)), 1),
            "ms_per_query": round(ms_per_query, 3),
        })
        print(f"  W={w}: recall={rows[-1]['recall']:.4f} "
              f"hops={rows[-1]['mean_hops']} N_b={rows[-1]['mean_n_b']} "
              f"N_p={rows[-1]['mean_n_p']} {ms_per_query:.2f} ms/q",
              flush=True)

    base = rows[0]
    for r in rows[1:]:
        r["hops_speedup_vs_w1"] = round(base["mean_hops"] / r["mean_hops"], 2)
    return rows
