"""Paper Fig. 4: U-HNSW vs the original per-p HNSW on fixed-p ANNS-Lp.

The per-p HNSW baseline builds a graph under L_p and pays T_p for EVERY
traversal distance (N_b_hnsw * T_p). U-HNSW pays N_b * T_b + N_p * T_p.
Both are tuned to recall >= 0.9; costs come from the same Eq. 1 cost model.

Claims under test: U-HNSW wins for general p (paper: 4.2x-11.5x), but
LOSES at p = 0.5 / 1.5 where SIMD (sqrt-family) makes T_p cheap — the
honest negative result the paper reports.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import K_DEFAULT, emit, get_dataset, get_hnsw_lp, get_uhnsw, ground_truth
from repro.core.hnsw import GraphArrays, knn_search
from repro.core.metrics import lp_distance_cost_model
from repro.core.uhnsw import recall

P_GRID = [0.5, 0.7, 0.9, 1.1, 1.3, 1.5, 1.7, 1.9]
DATASETS = ["sift", "gist"]
EF_LADDER = [100, 200, 400, 800]


def _hnsw_fixed_p(name, p, k, target=0.9):
    """Tune per-p HNSW's ef up to the recall target; return (recall, N_b)."""
    ds = get_dataset(name)
    g = get_hnsw_lp(name, p)
    arrays = GraphArrays.from_graph(g)
    X = jnp.asarray(ds.data)
    Q = jnp.asarray(ds.queries)
    true_ids, _ = ground_truth(name, p, k)
    best = None
    for ef in EF_LADDER:
        ids, _, nb, _ = knn_search(arrays, X, Q, ef=ef, t=k)
        r = recall(ids, true_ids)
        best = (r, float(np.asarray(nb).mean()))
        if r >= target:
            break
    return best


def run(quick: bool = False):
    datasets = DATASETS[:1] if quick else DATASETS
    grid = P_GRID[::2] if quick else P_GRID
    rows = []
    for name in datasets:
        ds = get_dataset(name)
        base = get_uhnsw(name)
        d = ds.d
        for p in grid:
            true_ids, _ = ground_truth(name, p, K_DEFAULT)
            # paper protocol: both schemes tuned until recall >= 0.9
            from repro.core.uhnsw import UHNSW, UHNSWParams

            for ef in (600, 1200, 2400):
                idx = UHNSW(base.g1, base.g2, UHNSWParams(t=300, ef=ef))
                ids, _, stats = idx.search(jnp.asarray(ds.queries), p, K_DEFAULT)
                u_r = recall(np.asarray(ids), true_ids)
                if u_r >= 0.9:
                    break
            c = idx.modeled_query_cost(stats, p, d)
            h_r, h_nb = _hnsw_fixed_p(name, p, K_DEFAULT)
            h_cost = h_nb * lp_distance_cost_model(p, d)
            rows.append({
                "bench": "fig4", "dataset": name, "p": p,
                "recall_uhnsw": round(u_r, 3), "recall_hnsw": round(h_r, 3),
                "cost_uhnsw": round(c["total"], 0),
                "cost_hnsw": round(h_cost, 0),
                "uhnsw_speedup": round(h_cost / c["total"], 2),
            })
    emit(rows, "fig4_uhnsw_vs_hnsw")
    for name in datasets:
        sub = [r for r in rows if r["dataset"] == name]
        gen = [r["uhnsw_speedup"] for r in sub if r["p"] not in (0.5, 1.5)]
        sp = [r["uhnsw_speedup"] for r in sub if r["p"] in (0.5, 1.5)]
        print(f"# {name}: U-HNSW speedup on general p: "
              f"{min(gen):.1f}-{max(gen):.1f}x (paper: 4.2-11.5x); "
              f"at p=0.5/1.5: {', '.join(f'{s:.2f}x' for s in sp)} "
              f"(paper: HNSW wins there)")
    return rows


if __name__ == "__main__":
    run()
