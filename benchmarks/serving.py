"""Serving-engine benchmark: throughput, latency, and flush behavior.

Three comparisons per distinct-p count (every request carries its own
p — the paper's ANNS-U-Lp deployment premise, DESIGN.md §6), between
the continuous-batching engine (`serve`, the default path), the
per-(p, k) grouped baseline (`serve_grouped`), and the v1 synchronous
power-of-two micro-batcher (`serve_v1`). All three run the same traced
per-query-p kernel programs, so every comparison is pure *scheduling*
with bit-identical results (`bitwise_equal` checks engine == grouped ==
v1 on every request of every stream served).

1. **Throughput.** Cold = the first stream ever served (compiles
   included). Warm/steady = serving *fresh* request streams (new
   random p mixes and stream lengths) after a warm-up — the production
   traffic shape. This is the measure that exposes the grouped
   baseline's structural cost: its batch shapes are data-dependent, so
   every fresh stream retraces, while the engine's exact-fit ladder
   shapes are all hot after warm-up. `speedup_warm_repeat`
   (informational, ungated) re-serves one identical stream best-of-3 —
   the one scenario with no shape churn, where grouped's zero-padding
   exact shapes are hard to beat.

2. **Paced latency** (open loop: requests arrive in bursts on a
   simulated arrival clock, device time is measured wall time) — the
   engine's admit/pump/deadline loop against the v1 submit/drain cycle
   at identical arrival schedules, paced to ~70% of the engine's warm
   capacity. Per-request latency = simulated finish - simulated
   arrival; the engine's deadline-triggered flushes and exact-fit
   ladder waves vs v1's drain-the-backlog padding show up as the
   p50/p95 gap (`p50_vs_v1` < 1 means the engine is faster). No
   wall-clock sleeps: arrivals advance the simulated clock directly.

3. **Flush accounting** — why engine batches dispatched during the
   paced scenario (full / deadline / drain), reported per row.

Rows land in results/BENCH_serving.json via benchmarks/run.py; the CI
bench-guard gates recall, warm/cold speedup, bitwise equality, and the
p50/p95 latency ratios (tools/check_bench.py).
"""

from __future__ import annotations

import time
from collections import deque

import numpy as np

from benchmarks.common import emit, get_dataset, get_uhnsw, ground_truth
from repro.retrieval.service import QueryRequest, UniversalVectorService

K = 10
BURST = 12          # paced-scenario burst size (requests per arrival event)
UTILIZATION = 0.9   # fraction of engine warm capacity the pacing targets


def _p_grid(d: int) -> list[float]:
    """d distinct metrics spread over the universal range [0.5, 2]."""
    if d == 1:
        return [0.8]
    return [round(float(p), 4) for p in np.linspace(0.5, 2.0, d)]


def _make_stream(ds, ps: list[float], n_requests: int, seed: int):
    """Returns (requests, per-request query index into ds.queries)."""
    rng = np.random.default_rng(seed)
    reqs, qidx = [], []
    for i in range(n_requests):
        qi = int(rng.integers(len(ds.queries)))
        qidx.append(qi)
        reqs.append(QueryRequest(vector=ds.queries[qi],
                                 p=float(rng.choice(ps)), k=K,
                                 request_id=i))
    return reqs, qidx


def _timed(fn, reqs):
    t0 = time.perf_counter()
    out = fn(reqs)
    return out, time.perf_counter() - t0


def _best_of(fn, reqs, n: int = 3) -> float:
    """Min wall time over n identical passes (warm-path timing)."""
    return min(_timed(fn, reqs)[1] for _ in range(n))

def _mean_recall(name: str, reqs, qidx, out) -> float:
    """Recall@K over the stream, using cached per-p exact ground truth."""
    gt = {}
    hits, denom = 0, 0
    for r, qi in zip(reqs, qidx):
        p = float(r.p)
        if p not in gt:
            gt[p] = ground_truth(name, p, k=K)[0]
        true = {int(v) for v in gt[p][qi] if v >= 0}
        got = {int(v) for v in out[r.request_id][0] if v >= 0}
        hits += len(got & true)
        denom += len(true)
    return hits / max(denom, 1)


def _bitwise(a: dict, b: dict, n: int) -> bool:
    return all(
        np.array_equal(a[i][0], b[i][0]) and np.array_equal(a[i][1], b[i][1])
        for i in range(n)
    )


# -- the paced open-loop latency scenario ---------------------------------
#
# Arrivals happen on a *simulated* clock (bursts of BURST requests every
# `gap` seconds); device work advances that clock by its measured wall
# time. Per-request latency is simulated finish - simulated arrival, so
# the comparison captures each scheduler's *batch-forming* behavior
# (engine: deadline flush + exact-fit ladder waves; v1: drain whatever
# queued into padded power-of-two buckets) under identical load, without
# a single wall-clock sleep.

def _paced_schedule(n: int, gap: float) -> list[float]:
    return [gap * (i // BURST) for i in range(n)]


def _sim_engine(service: UniversalVectorService, reqs, schedule):
    """Drive the engine's admit/pump loop on the simulated clock."""
    eng = service.engine
    arrival = {r.request_id: ts for r, ts in zip(reqs, schedule)}
    pend = deque(zip(reqs, schedule))
    t = 0.0
    lat, out = {}, {}

    def harvest(got):
        for rid, res in got.items():
            lat[rid] = (t - arrival[rid]) * 1e3
            out[rid] = res

    while pend or eng.pending:
        while pend and pend[0][1] <= t:
            r, ts = pend.popleft()
            eng.admit([eng.make_request(r, now=ts)])
        w0 = time.perf_counter()
        eng.pump(now=t)
        t += time.perf_counter() - w0
        got = eng.take_results()
        harvest(got)
        if got:
            continue
        # nothing completed: jump the simulated clock to the next event
        # (an arrival or the oldest queued deadline)
        nxt = [pend[0][1]] if pend else []
        nd = eng.sched.next_deadline()
        if nd is not None:
            nxt.append(nd)
        if nxt:
            t = max(t, min(nxt))
        elif eng.pending:
            # only the in-flight wave remains
            w0 = time.perf_counter()
            got = eng.drain(now=t)
            t += time.perf_counter() - w0
            harvest(got)
    return lat, out


def _sim_v1(service: UniversalVectorService, reqs, schedule):
    """The v1 synchronous cycle on the same simulated clock: drain
    everything queued, and whatever arrived during the (simulated) drain
    waits for the next cycle — the convoy the engine's deadline flush
    replaces."""
    arrival = {r.request_id: ts for r, ts in zip(reqs, schedule)}
    pend = deque(zip(reqs, schedule))
    t = 0.0
    lat, out = {}, {}
    while pend or service.queue_depth:
        if not service.queue_depth and pend and pend[0][1] > t:
            t = pend[0][1]
        while pend and pend[0][1] <= t:
            service.submit([pend.popleft()[0]])
        w0 = time.perf_counter()
        got = service.drain()
        t += time.perf_counter() - w0
        for rid, res in got.items():
            lat[rid] = (t - arrival[rid]) * 1e3
            out[rid] = res
    return lat, out


def _pcts(lat: dict) -> tuple[float, float]:
    arr = np.asarray(list(lat.values()), dtype=np.float64)
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 95))


# Steady-state stream lengths. Warm-up lengths are chosen so their
# engine chunk plans (280 -> [128][128][24], 104 -> [96, 8]) cover every
# ladder shape the measured streams need (152 -> [128][24],
# 136 -> [128][8]) — after warm-up the engine serves fresh streams with
# zero compiles, which is the point of a bounded shape set. The grouped
# baseline's shapes are data-dependent, so no warm-up can cover a
# stream length/mix it hasn't literally seen; it retraces on the
# measured streams exactly as it would on live traffic.
WARMUP_LENS = (280, 104)
STEADY_LENS = (152, 136)


def run(quick: bool = False):
    name = "sun" if quick else "deep"
    n_requests = 96 if quick else 384
    # quick (the CI-gated lane) covers the structurally differentiated
    # mixed-stream cases. d=1 and d=2 are near-ties by construction —
    # grouped's max_batch chunks coincide with the engine's ladder at
    # d=1, and at d=2 both schedulers emit near-identical per-burst
    # shapes — so gating CI on them would gate on noise; they stay in
    # the full grid for the record.
    d_grid = [4, 8] if quick else [1, 2, 4, 8, 16]
    t = 100 if quick else 150
    ds = get_dataset(name)

    index = get_uhnsw(name, m=16, t=t)
    service = UniversalVectorService(index=index, max_batch=128)

    rows = []
    for d in d_grid:
        ps = _p_grid(d)
        reqs, qidx = _make_stream(ds, ps, n_requests, seed=d)

        # -- cold: the first stream ever served at this distinct-p count -
        g_out, g_cold = _timed(service.serve_grouped, reqs)
        e_out, e_cold = _timed(service.serve, reqs)
        v_out, v_cold = _timed(service.serve_v1, reqs)
        bitwise = (_bitwise(g_out, e_out, n_requests)
                   and _bitwise(g_out, v_out, n_requests))

        # -- same-stream repeat (informational): zero shape churn --------
        g_rep = _best_of(service.serve_grouped, reqs)
        e_rep = _best_of(service.serve, reqs)

        # one-time boot warmup (after the first cold row, so the engine's
        # own organic compile cost is on the record): pre-compiles every
        # ladder shape for the verify lanes and the exact-base p values
        # the _p_grid streams contain, so no steady/paced measurement
        # rides a compiling program
        if not getattr(service.engine, "_bench_warmed", False):
            service.engine.warmup(k=K, ps=(0.8, 1.8, 1.0, 2.0))
            service.engine._bench_warmed = True

        # -- steady state: fresh streams after warm-up -------------------
        paths = [("grouped", service.serve_grouped),
                 ("engine", service.serve),
                 ("v1", service.serve_v1)]
        for n_w, off in zip(WARMUP_LENS, (51, 52)):
            w_reqs, _ = _make_stream(ds, ps, n_w, seed=d + off)
            for _, fn in paths:
                fn(w_reqs)
        steady = {pname: 0.0 for pname, _ in paths}
        for n_s, off in zip(STEADY_LENS, (101, 102)):
            s_reqs, _ = _make_stream(ds, ps, n_s, seed=d + off)
            outs = {}
            for pname, fn in paths:
                outs[pname], dt = _timed(fn, s_reqs)
                steady[pname] += dt
            bitwise = (bitwise
                       and _bitwise(outs["grouped"], outs["engine"], n_s)
                       and _bitwise(outs["grouped"], outs["v1"], n_s))
        g_st, e_st, v_st = steady["grouped"], steady["engine"], steady["v1"]
        n_steady = sum(STEADY_LENS)

        # -- paced open-loop latency -------------------------------------
        gap = BURST * (e_rep / n_requests) / UTILIZATION
        schedule = _paced_schedule(n_requests, gap)
        _sim_v1(service, reqs, schedule)        # warm-up (odd shapes)
        v1_lat, _ = _sim_v1(service, reqs, schedule)
        _sim_engine(service, reqs, schedule)    # warm-up (odd shapes)
        fl0 = dict(service.stats["flushes"])
        eng_lat, _ = _sim_engine(service, reqs, schedule)
        fl = {k: service.stats["flushes"][k] - fl0[k]
              for k in service.stats["flushes"]}
        e_p50, e_p95 = _pcts(eng_lat)
        v_p50, v_p95 = _pcts(v1_lat)

        row = {
            "bench": "serving", "dataset": name, "distinct_p": d,
            "requests": n_requests, "k": K,
            "grouped_qps_cold": round(n_requests / g_cold, 1),
            "mixed_qps_cold": round(n_requests / e_cold, 1),
            "speedup_cold": round(g_cold / e_cold, 2),
            # steady state: fresh streams (lengths 152 + 136), hot caches
            "grouped_qps_warm": round(n_steady / g_st, 1),
            "mixed_qps_warm": round(n_steady / e_st, 1),
            "v1_qps_warm": round(n_steady / v_st, 1),
            "speedup_warm": round(g_st / e_st, 2),
            "speedup_warm_vs_v1": round(v_st / e_st, 2),
            # informational: re-serving one identical stream (no churn)
            "speedup_warm_repeat": round(g_rep / e_rep, 2),
            "recall_grouped": round(_mean_recall(name, reqs, qidx, g_out), 4),
            "recall_mixed": round(_mean_recall(name, reqs, qidx, e_out), 4),
            "bitwise_equal": bitwise,
            # paced open-loop latency (simulated arrivals, measured compute)
            "engine_p50_ms": round(e_p50, 1),
            "engine_p95_ms": round(e_p95, 1),
            "v1_p50_ms": round(v_p50, 1),
            "v1_p95_ms": round(v_p95, 1),
            "p50_vs_v1": round(e_p50 / v_p50, 3),
            "p95_vs_v1": round(e_p95 / v_p95, 3),
            "flush_full": fl.get("full", 0),
            "flush_deadline": fl.get("deadline", 0),
            "flush_drain": fl.get("drain", 0),
        }
        rows.append(row)
        print(f"  D={d}: steady {row['speedup_warm']}x vs grouped / "
              f"{row['speedup_warm_vs_v1']}x vs v1 "
              f"(repeat {row['speedup_warm_repeat']}x), cold "
              f"{row['speedup_cold']}x; paced p50 {row['engine_p50_ms']}ms "
              f"vs v1 {row['v1_p50_ms']}ms (ratio {row['p50_vs_v1']}); "
              f"flushes full={row['flush_full']} "
              f"deadline={row['flush_deadline']} drain={row['flush_drain']}; "
              f"recall {row['recall_mixed']} (bitwise_equal={bitwise})",
              flush=True)

    emit(rows, "serving")
    # acceptance is evaluated over the structurally differentiated rows
    # (the quick-lane grid, d >= 4); bitwise equality must hold on every
    # row including the d<=2 near-tie ones
    ok = (all(r["bitwise_equal"] for r in rows)
          and all(r["speedup_warm"] >= 1.0 and r["p50_vs_v1"] < 1.0
                  for r in rows if r["distinct_p"] >= 4))
    print(f"acceptance (engine >= grouped on steady fresh streams and p50 "
          f"below v1 at every gated distinct-p count, bitwise everywhere): "
          f"{'PASS' if ok else 'FAIL'}")
    return rows


# -- faulted-stream degraded serving (DESIGN.md §11) -----------------------
#
# The robustness counterpart of the scheduling rows above: the same engine
# over a durable 8-segment index, serving one clean pass and one chaos
# pass — one flaky segment's fault site injected at rate FAULT_RATE plus
# one mid-stream NaN-poisoned segment (detected by the query-time guard,
# bisected to the segment, quarantined, restored from the snapshot and
# canary-readmitted by background maintenance). Reported: achieved
# coverage, faulted/clean throughput and p50 ratios, and the hard zero:
# no poisoned id in any faulted-stream result.

FAULT_RATE = 0.05
N_SEGMENTS = 8
POISON_SEG = 3
FLAKY_SEG = 1


def run_faulted(quick: bool = False):
    import os
    import tempfile

    from repro.core.uhnsw import UHNSWParams
    from repro.index import DurableIndex, ShardedUHNSW
    from repro.retrieval.engine import FaultInjector
    from repro.retrieval.engine.faults import poison_segment, segment_site

    name = "sun" if quick else "deep"
    t = 100 if quick else 150
    # streams long enough that the one-off poison event (wasted wave +
    # bisection probes + snapshot restore) amortizes: the gated ratio
    # measures sustained degraded throughput, not the event spike
    n_requests = 128 if quick else 192
    n_streams = 4 if quick else 6
    seed = int(os.environ.get("REPRO_SEGFAULT_SEED", "0"))
    ds = get_dataset(name)
    ps = _p_grid(4)

    t0 = time.perf_counter()
    index = ShardedUHNSW.build(ds.data, num_segments=N_SEGMENTS, m=12,
                               params=UHNSWParams(t=t), seed=0)
    print(f"  built {N_SEGMENTS}-segment {name} in "
          f"{time.perf_counter() - t0:.0f}s", flush=True)

    def streams(offset):
        return [_make_stream(ds, ps, n_requests, seed=offset + i)[0]
                for i in range(n_streams)]

    def serve_all(service, reqs_list):
        dt = 0.0
        outs = []
        for reqs in reqs_list:
            out, d = _timed(service.serve, reqs)
            outs.append(out)
            dt += d
        return outs, dt

    with tempfile.TemporaryDirectory() as td:
        dur = DurableIndex.create(index, td)
        # one persistently flaky segment at rate 0.05 per wave (the
        # "segment" wildcard would compound to 1-0.95^8 = 34% of waves
        # faulting — a different scenario than the advertised 5%), plus a
        # mid-stream NaN poisoning of a *different* segment so both the
        # EWMA-retry path and the quarantine/recovery path are measured
        injector = FaultInjector(rate=FAULT_RATE, seed=seed,
                                 sites=(segment_site(FLAKY_SEG),))
        service = UniversalVectorService(index=dur, max_batch=64,
                                         fault_injector=injector,
                                         min_coverage=0.5)
        eng = service.engine

        # warm every ladder shape, then pre-warm the degraded-mask and
        # bisection-probe programs (poison -> detect -> restore) so the
        # measured chaos pass pays chaos, not compiles
        eng.warmup(k=K, ps=tuple(ps))
        keep, eng.fault_injector = eng.fault_injector, None
        serve_all(service, streams(900))
        gids = poison_segment(dur, POISON_SEG)
        serve_all(service, streams(910))     # detect + quarantine (compile)
        eng.pump()                           # restore + readmit (compile)
        assert dur.health.alive() == list(range(N_SEGMENTS))
        eng.fault_injector = keep
        injector.reset()

        # -- clean pass (injector detached, index fully healthy) ---------
        eng.fault_injector = None
        base = dict(service.stats)
        service.stats["latency_ms"].clear()      # per-pass p50 windows
        service.stats["latency_records"].clear()
        clean_outs, clean_dt = serve_all(service, streams(1000))
        clean_lat = service.latency_summary()
        n_served_clean = sum(len(o) for o in clean_outs)

        # -- faulted pass: segment-site chaos + one mid-stream poison ----
        # (counters are cumulative over the service lifetime — the warmup
        # pass above deliberately poisons/recovers once to compile those
        # paths, so the row must report measured-pass deltas)
        eng.fault_injector = injector
        q0 = service.stats["queries"]
        cov0 = service.stats["coverage_w"]
        ctr0 = {key: int(service.stats[key])
                for key in ("poison_detected", "seg_quarantined",
                            "seg_recovered")}
        service.stats["latency_ms"].clear()
        service.stats["latency_records"].clear()
        fault_dt = 0.0
        outs = []
        for i, reqs in enumerate(streams(2000)):
            if i == n_streams // 2:          # mid-stream corruption
                poison_segment(dur, POISON_SEG)
            out, d = _timed(service.serve, reqs)
            outs.append(out)
            fault_dt += d
        fault_lat = service.latency_summary()
        n_served = sum(len(o) for o in outs)
        st = service.stats
        coverage_mean = ((st["coverage_w"] - cov0)
                         / max(st["queries"] - q0, 1))
        # the hard zero applies to the stream served WHILE the segment
        # held poisoned rows (quarantine keeps them out of every result);
        # once background maintenance restores + readmits the segment, its
        # ids are clean again and legitimately servable
        poisoned = set(map(int, gids))
        leaked = {int(i)
                  for ids, _ in outs[n_streams // 2].values()
                  for i in np.asarray(ids) if int(i) >= 0} & poisoned
        recovered_all = dur.health.alive() == list(range(N_SEGMENTS))

    qps_clean = n_served_clean / clean_dt
    qps_fault = n_served / fault_dt
    row = {
        "bench": "health", "dataset": name, "segments": N_SEGMENTS,
        "fault_rate": FAULT_RATE, "requests": n_streams * n_requests,
        "seed": seed,
        "served": n_served,
        "failed": int(st["failed"] - base.get("failed", 0)),
        "coverage_mean": round(float(coverage_mean), 4),
        "clean_qps": round(qps_clean, 1),
        "faulted_qps": round(qps_fault, 1),
        "throughput_ratio": round(qps_fault / qps_clean, 3),
        "p50_ratio": round(fault_lat["p50"] / max(clean_lat["p50"], 1e-9), 3),
        "no_poisoned_ids": not leaked,
        "poison_detected": int(st["poison_detected"]) - ctr0["poison_detected"],
        "seg_quarantined": int(st["seg_quarantined"]) - ctr0["seg_quarantined"],
        "seg_recovered": int(st["seg_recovered"]) - ctr0["seg_recovered"],
        "injected_faults": int(injector.injected),
        "recovered_all_segments": bool(recovered_all),
    }
    print(f"  chaos rate={FAULT_RATE}: coverage {row['coverage_mean']}, "
          f"throughput {row['throughput_ratio']}x clean "
          f"({row['faulted_qps']} vs {row['clean_qps']} qps), "
          f"p50 ratio {row['p50_ratio']}; "
          f"quarantined={row['seg_quarantined']} "
          f"recovered={row['seg_recovered']} "
          f"poison rows caught={row['poison_detected']} "
          f"(leaked ids: {len(leaked)})", flush=True)
    emit([row], "health")
    ok = (row["coverage_mean"] >= 0.95 and row["throughput_ratio"] >= 0.8
          and row["no_poisoned_ids"] and row["recovered_all_segments"])
    print(f"acceptance (>=0.95 coverage, >=0.8x clean throughput, zero "
          f"poisoned ids, all segments re-admitted): "
          f"{'PASS' if ok else 'FAIL'}")
    return [row]


if __name__ == "__main__":
    run()
