"""Mixed-p batched serving vs per-(p, k) grouped serving (DESIGN.md §6).

The load generator simulates the paper's deployment scenario — every
request carries its own p — with an increasing number of *distinct* p
values in the stream. Both paths run the same traced per-query-p kernel
programs (so this is a pure *scheduling* comparison with bit-identical
results): the grouped baseline fragments into one device call per exact
(p, k) group, whose data-dependent batch sizes retrace one compiled
program per distinct group shape and squander batching on tiny groups;
the mixed engine pads fixed power-of-two buckets and keys its jit cache
only on (base graph × bucket × k), flat in the number of distinct p
values.

Reported per distinct-p count: cold throughput (first pass, compiles
included — the realistic churning-traffic case), warm throughput (second
identical pass), recall at equal k (identical by the bit-parity
guarantee, measured anyway), and the mixed engine's *cold-pass* latency
percentiles. Rows land in results/BENCH_serving.json via
benchmarks/run.py.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_dataset, get_uhnsw, ground_truth
from repro.retrieval.service import QueryRequest, UniversalVectorService

K = 10


def _p_grid(d: int) -> list[float]:
    """d distinct metrics spread over the universal range [0.5, 2]."""
    if d == 1:
        return [0.8]
    return [round(float(p), 4) for p in np.linspace(0.5, 2.0, d)]


def _make_stream(ds, ps: list[float], n_requests: int, seed: int):
    """Returns (requests, per-request query index into ds.queries)."""
    rng = np.random.default_rng(seed)
    reqs, qidx = [], []
    for i in range(n_requests):
        qi = int(rng.integers(len(ds.queries)))
        qidx.append(qi)
        reqs.append(QueryRequest(vector=ds.queries[qi],
                                 p=float(rng.choice(ps)), k=K,
                                 request_id=i))
    return reqs, qidx


def _timed(fn, reqs):
    t0 = time.time()
    out = fn(reqs)
    dt = time.time() - t0
    return out, dt


def _mean_recall(name: str, reqs, qidx, out) -> float:
    """Recall@K over the stream, using cached per-p exact ground truth."""
    gt = {}
    hits, denom = 0, 0
    for r, qi in zip(reqs, qidx):
        p = float(r.p)
        if p not in gt:
            gt[p] = ground_truth(name, p, k=K)[0]
        true = {int(v) for v in gt[p][qi] if v >= 0}
        got = {int(v) for v in out[r.request_id][0] if v >= 0}
        hits += len(got & true)
        denom += len(true)
    return hits / max(denom, 1)


def run(quick: bool = False):
    name = "sun" if quick else "deep"
    n_requests = 96 if quick else 384
    d_grid = [1, 4, 8] if quick else [1, 2, 4, 8, 16]
    t = 100 if quick else 150
    ds = get_dataset(name)

    index = get_uhnsw(name, m=16, t=t)
    service = UniversalVectorService(index=index, max_batch=128)

    rows = []
    for d in d_grid:
        ps = _p_grid(d)
        reqs, qidx = _make_stream(ds, ps, n_requests, seed=d)
        # cold = first pass over this stream (compiles included: the cost a
        # serving tier pays whenever traffic brings new p values / shapes);
        # warm = identical second pass.
        g_out, g_cold = _timed(service.serve_grouped, reqs)
        _, g_warm = _timed(service.serve_grouped, reqs)
        service.stats["latency_ms"].clear()
        m_out, m_cold = _timed(service.serve, reqs)
        lat = service.latency_summary()  # cold-pass latency only
        _, m_warm = _timed(service.serve, reqs)
        bitwise = all(
            np.array_equal(g_out[i][0], m_out[i][0])
            and np.array_equal(g_out[i][1], m_out[i][1])
            for i in range(n_requests)
        )
        row = {
            "bench": "serving", "dataset": name, "distinct_p": d,
            "requests": n_requests, "k": K,
            "grouped_qps_cold": round(n_requests / g_cold, 1),
            "mixed_qps_cold": round(n_requests / m_cold, 1),
            "speedup_cold": round(g_cold / m_cold, 2),
            "grouped_qps_warm": round(n_requests / g_warm, 1),
            "mixed_qps_warm": round(n_requests / m_warm, 1),
            "speedup_warm": round(g_warm / m_warm, 2),
            "recall_grouped": round(_mean_recall(name, reqs, qidx, g_out), 4),
            "recall_mixed": round(_mean_recall(name, reqs, qidx, m_out), 4),
            "bitwise_equal": bitwise,
            "mixed_p50_ms": round(lat["p50"], 1),
            "mixed_p95_ms": round(lat["p95"], 1),
        }
        rows.append(row)
        print(f"  D={d}: cold {row['grouped_qps_cold']} -> "
              f"{row['mixed_qps_cold']} qps ({row['speedup_cold']}x), "
              f"warm {row['speedup_warm']}x, "
              f"recall {row['recall_mixed']} "
              f"(bitwise_equal={bitwise})", flush=True)

    emit(rows, "serving")
    worst8 = [r for r in rows if r["distinct_p"] >= 8]
    if worst8:
        ok = all(r["speedup_cold"] > 1.0 and
                 r["recall_mixed"] >= r["recall_grouped"] for r in worst8)
        print(f"acceptance (mixed beats grouped at >=8 distinct p, equal "
              f"recall): {'PASS' if ok else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run()
