"""Shared benchmark infrastructure: datasets, cached indexes, ground truth.

Scale: the paper's corpora shrunk to CPU-feasible sizes (documented in
DESIGN.md §Paper-fidelity deviations). Indexes and brute-force ground truth
are cached under results/bench_cache so the full `python -m benchmarks.run`
pass stays within minutes.
"""

from __future__ import annotations

import pickle
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core.build import build_hnsw_bulk
from repro.core.datasets import make_dataset
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSW, UHNSWParams

CACHE = Path(__file__).parent.parent / "results" / "bench_cache"

# dataset -> n at benchmark scale (paper Table 1 shapes, shrunk)
BENCH_SIZES = {
    "sun": 4000,
    "trevi": 1500,
    "gist": 5000,
    "deep": 8000,
    "glove": 10000,
    "sift": 20000,
}
N_QUERIES = 64
K_DEFAULT = 50


def _cached(name: str, fn):
    CACHE.mkdir(parents=True, exist_ok=True)
    path = CACHE / f"{name}.pkl"
    if path.exists():
        with open(path, "rb") as f:
            return pickle.load(f)
    obj = fn()
    with open(path, "wb") as f:
        pickle.dump(obj, f)
    return obj


def get_dataset(name: str):
    return _cached(
        f"ds_{name}",
        lambda: make_dataset(name, n=BENCH_SIZES[name], n_queries=N_QUERIES,
                             seed=42),
    )


def get_uhnsw(name: str, m: int = 16, t: int = 300) -> UHNSW:
    ds = get_dataset(name)

    def build():
        t0 = time.time()
        g1 = build_hnsw_bulk(ds.data, 1.0, m=m, seed=0)
        g2 = build_hnsw_bulk(ds.data, 2.0, m=m, seed=1)
        print(f"  built {name} G1+G2 in {time.time() - t0:.0f}s", flush=True)
        return g1, g2

    g1, g2 = _cached(f"uhnsw_{name}_m{m}", build)
    return UHNSW(g1, g2, UHNSWParams(t=t))


def get_hnsw_lp(name: str, p: float, m: int = 16):
    """Per-p HNSW baseline graph (what 'original HNSW' must build per p)."""
    ds = get_dataset(name)
    return _cached(
        f"hnsw_{name}_p{p}_m{m}",
        lambda: build_hnsw_bulk(ds.data, p, m=m, seed=0),
    )


def ground_truth(name: str, p: float, k: int = K_DEFAULT):
    ds = get_dataset(name)

    def compute():
        ids, dists = exact_topk(jnp.asarray(ds.data), jnp.asarray(ds.queries),
                                p, k)
        return np.asarray(ids), np.asarray(dists)

    return _cached(f"gt_{name}_p{p}_k{k}", compute)


def emit(rows: list[dict], name: str):
    """Write a benchmark's rows to results/ as json; print CSV to stdout."""
    import json

    out = Path(__file__).parent.parent / "results" / f"{name}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=2))
    if rows:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for r in rows:
            print(",".join(str(r[k]) for k in keys))
    return rows
