"""Paper Fig. 3: tuning t (candidate count) and tau (early-stop threshold).

(a) recall vs t when the candidate set is the TRUE top-t under the base
    metric, at the most demanding setting p=0.5 (base L1), K=50;
(b) end-to-end U-HNSW recall and N_p vs tau.

Claims under test: recall saturates by t=300; tau=0.92 (target 0.9 + 0.02)
meets the 0.9 target while keeping N_p << t.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import K_DEFAULT, emit, get_dataset, get_uhnsw, ground_truth
from repro.core.uhnsw import UHNSWParams, recall, UHNSW

T_GRID = [50, 100, 150, 200, 300, 400]
TAU_GRID = [0.80, 0.86, 0.90, 0.92, 0.96, 1.0]
P_DEMANDING = 0.5
DATASETS = ["sift", "gist"]


def run(quick: bool = False):
    datasets = DATASETS[:1] if quick else DATASETS
    rows = []
    for name in datasets:
        ds = get_dataset(name)
        true_lp, _ = ground_truth(name, P_DEMANDING, k=K_DEFAULT)
        # (a) t sweep with true top-t candidates
        big_t = max(T_GRID)
        true_base, _ = ground_truth(name, 1.0, k=big_t)
        for t in T_GRID:
            hits = sum(
                len(set(true_lp[i]) & set(true_base[i][:t]))
                for i in range(true_lp.shape[0])
            )
            rows.append({
                "bench": "fig3a", "dataset": name, "t": t, "tau": "",
                "recall": round(hits / true_lp.size, 4), "n_p": "",
            })
        # (b) tau sweep, full pipeline
        idx = get_uhnsw(name)
        for tau in TAU_GRID:
            idx_tau = UHNSW(idx.g1, idx.g2, UHNSWParams(t=300, tau=tau))
            ids, _, stats = idx_tau.search(
                jnp.asarray(ds.queries), P_DEMANDING, K_DEFAULT
            )
            r = recall(ids, true_lp)
            rows.append({
                "bench": "fig3b", "dataset": name, "t": 300, "tau": tau,
                "recall": round(r, 4),
                "n_p": round(float(np.asarray(stats.n_p).mean()), 1),
            })
    emit(rows, "fig3_param_tuning")
    for name in datasets:
        sat = [r for r in rows if r["bench"] == "fig3a" and r["dataset"] == name]
        print(f"# {name}: recall@t=300 = {sat[-2]['recall']} (saturation; paper: ~1.0)")
        tau92 = [r for r in rows if r["bench"] == "fig3b"
                 and r["dataset"] == name and r["tau"] == 0.92]
        print(f"# {name}: tau=0.92 -> recall {tau92[0]['recall']} "
              f"N_p {tau92[0]['n_p']} (target 0.9, N_p << 300)")
    return rows


if __name__ == "__main__":
    run()
