"""Roofline analysis (deliverable g): read the dry-run JSONs and emit the
per-(arch x shape x mesh) three-term roofline table.

  compute    = per_device_FLOPs / 197 TFLOP/s (bf16)
  memory     = per_device_bytes / 819 GB/s
  collective = per_device_collective_bytes / 50 GB/s per-link ICI

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens processed;
the HLO/MODEL ratio surfaces remat + attention + dead compute overheads.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit
from repro.configs.base import SHAPES, get_arch

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

HINTS = {
    "collective": "shard the residual stream over 'model' (sequence "
                  "parallelism) / fuse FSDP gathers across layers",
    "memory": "raise arithmetic intensity: larger per-device microbatch, "
              "bf16 loss chunks, fewer remat passes",
    "compute": "already MXU-bound: improve achieved MFU via layout "
               "(head-dim multiples of 128) and fused attention kernels",
}


def model_flops(arch_id: str, shape_name: str) -> float:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def load_cells(directory: str) -> list[dict]:
    cells = []
    for path in sorted(Path(directory).glob("*.json")):
        cells.append(json.loads(path.read_text()))
    return cells


def analyze(cells: list[dict]) -> list[dict]:
    rows = []
    for c in cells:
        if c.get("status") == "skipped":
            rows.append({
                "bench": "roofline", "arch": c["arch"], "shape": c["shape"],
                "mesh": c.get("mesh", ""), "status": "skipped",
                "compute_s": "", "memory_s": "", "collective_s": "",
                "dominant": "", "model_flops_ratio": "",
                "roofline_fraction": "", "hint": c.get("reason", ""),
            })
            continue
        if c.get("status") != "ok":
            continue
        rf = c["roofline_seconds"]
        dominant = max(rf, key=rf.get)
        n_chips = c["n_chips"]
        mf = model_flops(c["arch"], c["shape"]) / n_chips
        hlo = c["per_device"]["flops"]
        ratio = mf / hlo if hlo else 0.0
        # roofline fraction: useful compute time / modeled step time
        step_time = max(rf.values())
        useful = mf / PEAK_FLOPS
        frac = useful / step_time if step_time else 0.0
        rows.append({
            "bench": "roofline", "arch": c["arch"], "shape": c["shape"],
            "mesh": c.get("mesh", ""), "status": "ok",
            "compute_s": f"{rf['compute']:.4g}",
            "memory_s": f"{rf['memory']:.4g}",
            "collective_s": f"{rf['collective']:.4g}",
            "dominant": dominant,
            "model_flops_ratio": round(ratio, 3),
            "roofline_fraction": round(frac, 4),
            "hint": HINTS[dominant],
        })
    return rows


def run(quick: bool = False, directory: str | None = None):
    tables = (
        [(directory, "roofline")]
        if directory
        else [
            ("results/dryrun", "roofline"),
            ("results/dryrun_opt", "roofline_opt"),
            ("results/dryrun_mp", "roofline_mp"),
        ]
    )
    all_rows = []
    for d, name in tables:
        if not Path(d).exists():
            print(f"# no dry-run results under {d}; run "
                  f"`python -m repro.launch.dryrun --all --out {d}` first")
            continue
        print(f"--- {name} ({d}) ---")
        rows = analyze(load_cells(d))
        emit(rows, name)
        all_rows.extend(rows)
    return all_rows


if __name__ == "__main__":
    run()
