"""Bulk vs incremental graph construction: build time + recall parity.

The tentpole claim this tracks (DESIGN.md §7): the batched device-side bulk
builder (`core/bulk_build.build_bulk_pair`) constructs the full G1+G2 pair
in one shared candidate-generation pass, >= 5x faster than the paper-
faithful incremental builder at segment scale on CPU, with downstream
recall within 0.5 pt at matched ef.

Two build timings are reported:

  * cold  — first build in the process, jit compiles included (what a
    one-off build pays);
  * steady — an identical rebuild with the jit cache warm. This is the
    operationally relevant segment-build cost: streaming compaction
    (index/delta.py -> ShardedUHNSW.compact) rebuilds frozen segments of
    the *same shape* over and over, so every build after the first runs at
    steady-state. The acceptance gate (`speedup_steady` >= 5) uses it; the
    cold ratio is tracked alongside.

Recall parity runs the same UHNSW query stack (same t/ef/k) over both
index pairs at p in {0.5, 1.0, 1.25, 2.0} against fresh exact ground truth
on the subset.

  PYTHONPATH=src python -m benchmarks.run --only build [--quick]
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, get_dataset
from repro.core.build import build_hnsw
from repro.core.bulk_build import build_bulk_pair
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSW, UHNSWParams, recall

P_SWEEP = (0.5, 1.0, 1.25, 2.0)
M = 16
T = 150
K = 10


def _build_incremental(data, m):
    # efc matches the segment builder's incremental setting
    # (index/segment.py) so this measures the same build the index layer
    # would actually run
    efc = min(200, max(16, 4 * m))
    g1 = build_hnsw(data, 1.0, m=m, ef_construction=efc, seed=0)
    g2 = build_hnsw(data, 2.0, m=m, ef_construction=efc, seed=1)
    return g1, g2


def run(quick: bool = False):
    name = "deep"
    n = 640 if quick else 2048
    ds = get_dataset(name)
    data = np.ascontiguousarray(ds.data[:n])
    queries = jnp.asarray(ds.queries)
    x_dev = jnp.asarray(data)

    t0 = time.time()
    gi1, gi2 = _build_incremental(data, M)
    t_inc = time.time() - t0
    print(f"  incremental pair: {t_inc:.1f}s", flush=True)

    t0 = time.time()
    gb1, gb2 = build_bulk_pair(data, m=M, seed=0)
    t_cold = time.time() - t0
    t0 = time.time()
    gb1, gb2 = build_bulk_pair(data, m=M, seed=0)
    t_steady = time.time() - t0
    print(f"  bulk pair: cold {t_cold:.1f}s, steady {t_steady:.1f}s",
          flush=True)

    prm = UHNSWParams(t=T)
    idx_inc = UHNSW(gi1, gi2, prm)
    idx_bulk = UHNSW(gb1, gb2, prm)

    rows = []
    worst_delta = 0.0
    for p in P_SWEEP:
        true_ids, _ = exact_topk(x_dev, queries, p, K)
        true_ids = np.asarray(true_ids)
        r = {}
        for label, idx in (("incremental", idx_inc), ("bulk", idx_bulk)):
            ids, _, _ = idx.search(queries, p, K)
            r[label] = recall(np.asarray(ids), true_ids)
        delta_pt = (r["incremental"] - r["bulk"]) * 100
        worst_delta = max(worst_delta, delta_pt)
        rows.append({
            "bench": "build", "dataset": name, "n": n, "d": data.shape[1],
            "m": M, "t": T, "k": K, "p": p,
            "recall_incremental": round(r["incremental"], 4),
            "recall_bulk": round(r["bulk"], 4),
            "recall_delta_pt": round(delta_pt, 2),
        })
        print(f"  p={p}: recall inc={r['incremental']:.4f} "
              f"bulk={r['bulk']:.4f} (delta {delta_pt:+.2f} pt)", flush=True)

    summary = {
        "bench": "build", "dataset": name, "n": n, "d": data.shape[1],
        "m": M, "t": T, "k": K, "p": "summary",
        # worst-case aggregates of the per-p columns (keeps emit()'s CSV
        # header uniform across rows)
        "recall_incremental": min(r["recall_incremental"] for r in rows),
        "recall_bulk": min(r["recall_bulk"] for r in rows),
        "recall_delta_pt": round(worst_delta, 2),
        "seconds_incremental": round(t_inc, 1),
        "seconds_bulk_cold": round(t_cold, 1),
        "seconds_bulk_steady": round(t_steady, 1),
        "speedup_cold": round(t_inc / t_cold, 2),
        "speedup_steady": round(t_inc / t_steady, 2),
        "worst_recall_delta_pt": round(worst_delta, 2),
    }
    rows.append(summary)
    ok = summary["speedup_steady"] >= 5.0 and worst_delta <= 0.5
    print(f"  speedup: cold {summary['speedup_cold']}x, "
          f"steady {summary['speedup_steady']}x; worst recall delta "
          f"{worst_delta:+.2f} pt", flush=True)
    print(f"acceptance (steady >=5x, recall within 0.5 pt): "
          f"{'PASS' if ok else 'FAIL'}")
    emit(rows, "build")
    return rows


if __name__ == "__main__":
    run()
