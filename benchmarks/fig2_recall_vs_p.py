"""Paper Fig. 2: recall of the Lp top-K inside the *true* base-metric top-t
candidate set, as a function of p, for both base metrics (G1/L1, G2/L2).

Claim under test: the two curves cross near p = 1.4 — the rationale for the
base-index selection cutoff.
"""

from __future__ import annotations


from benchmarks.common import K_DEFAULT, emit, get_dataset, ground_truth

P_GRID = [0.5, 0.7, 0.9, 1.1, 1.2, 1.3, 1.4, 1.5, 1.6, 1.8, 2.0]
T = 300
DATASETS = ["sift", "gist"]


def _candidate_recall(name: str, base_p: float, p: float, t: int, k: int):
    true_base, _ = ground_truth(name, base_p, k=t)   # true top-t under base
    true_lp, _ = ground_truth(name, p, k=k)          # true top-k under Lp
    hits = 0
    for i in range(true_lp.shape[0]):
        hits += len(set(true_lp[i]) & set(true_base[i]))
    return hits / true_lp.size


def run(quick: bool = False):
    datasets = DATASETS[:1] if quick else DATASETS
    grid = P_GRID[::2] if quick else P_GRID
    rows = []
    for name in datasets:
        get_dataset(name)
        for p in grid:
            r1 = _candidate_recall(name, 1.0, p, T, K_DEFAULT)
            r2 = _candidate_recall(name, 2.0, p, T, K_DEFAULT)
            rows.append({
                "bench": "fig2", "dataset": name, "p": p,
                "recall_G1_L1": round(r1, 4), "recall_G2_L2": round(r2, 4),
            })
    emit(rows, "fig2_recall_vs_p")
    # crossover check
    for name in datasets:
        sub = [r for r in rows if r["dataset"] == name]
        cross = None
        for a, b in zip(sub, sub[1:]):
            d_a = a["recall_G1_L1"] - a["recall_G2_L2"]
            d_b = b["recall_G1_L1"] - b["recall_G2_L2"]
            if d_a >= 0 and d_b < 0:
                cross = (a["p"] + b["p"]) / 2
        print(f"# {name}: G1/G2 recall crossover ~ p={cross} "
              f"(paper: ~1.4)")
    return rows


if __name__ == "__main__":
    run()
