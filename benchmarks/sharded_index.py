"""Sharded vs monolithic U-HNSW: recall parity, Eq. 1 counts, insert path.

Tracks the cost of segmentation (N_b grows ~linearly in S at fixed
per-segment t — DESIGN.md §3) against what it buys: parallel builds,
device placement, and streaming inserts. Rows land in
results/sharded_index.json and BENCH_sharded.json (via benchmarks/run.py).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import K_DEFAULT, emit, get_dataset, get_uhnsw, ground_truth
from repro.core.uhnsw import UHNSWParams, recall
from repro.index import ShardedUHNSW

P_GRID = [0.5, 1.25, 2.0]


def _timed_search(index, Q, p, k):
    ids, dists, stats = index.search(Q, p, k)  # includes compile on first p
    np.asarray(ids)
    t0 = time.time()
    ids, dists, stats = index.search(Q, p, k)
    np.asarray(ids)
    dt = time.time() - t0
    return ids, stats, dt


def run(quick: bool = False):
    name = "sun" if quick else "sift"
    num_segments = 4 if quick else 8
    t = 150 if quick else 300
    ds = get_dataset(name)
    Q = jnp.asarray(ds.queries)
    k = K_DEFAULT

    mono = get_uhnsw(name, m=16, t=t)
    t0 = time.time()
    sharded = ShardedUHNSW.build(
        ds.data, num_segments=num_segments, m=16,
        params=UHNSWParams(t=t), seed=0,
    )
    build_s = time.time() - t0

    rows = []
    for p in P_GRID:
        true_ids, _ = ground_truth(name, p, k=k)
        for label, index in (("monolithic", mono), ("sharded", sharded)):
            ids, stats, dt = _timed_search(index, Q, p, k)
            rows.append({
                "bench": "sharded", "dataset": name, "index": label,
                "segments": getattr(index, "num_segments", 1), "p": p,
                "recall": round(recall(ids, true_ids), 4),
                "query_time_s": round(dt, 4),
                "qps": round(len(ds.queries) / max(dt, 1e-9), 1),
                "N_b": round(float(jnp.mean(stats.n_b)), 1),
                "N_p": round(float(jnp.mean(stats.n_p)), 1),
            })

    # streaming-insert path: add() latency + self-NN consistency
    rng = np.random.default_rng(0)
    v = (ds.data.mean(axis=0)
         + 5.0 * rng.standard_normal(ds.d)).astype(np.float32)
    t0 = time.time()
    gid = sharded.add(v)
    add_s = time.time() - t0
    ids, _, _ = sharded.search(v[None, :], 1.25, k=1)
    insert_row = {
        "bench": "sharded", "dataset": name, "index": "sharded",
        "segments": sharded.num_segments, "metric": "insert",
        "add_time_s": round(add_s, 5), "build_time_s": round(build_s, 1),
        "self_nn_ok": bool(int(ids[0, 0]) == gid),
    }
    emit(rows, "sharded_index")
    worst = min(
        (r["recall"] - m["recall"])
        for r in rows if r["index"] == "sharded"
        for m in rows if m["index"] == "monolithic" and m["p"] == r["p"]
    )
    print(f"insert: add={insert_row['add_time_s']}s "
          f"self_nn_ok={insert_row['self_nn_ok']} | "
          f"worst sharded-vs-mono recall delta: {worst:+.4f} "
          f"(acceptance: >= -0.02)")
    return rows + [insert_row]


if __name__ == "__main__":
    run()
