"""Sharded vs monolithic U-HNSW: the segments x policy sweep.

Before threshold propagation, sharding was a pure tax: S independent
per-segment beam searches cost ~S x the monolithic N_b at matched
per-segment t. This bench tracks what the cross-segment policies
(DESIGN.md §3) buy back:

  independent    exhaustive per-segment search (the reference: its merged
                 ids are what the cheaper policies are compared against)
  round_robin    sequential cascade — each segment inherits the running
                 k-th-best base distance from the segments before it
  two_phase      probe the largest segment(s) at full beam, then spill to
                 the rest with the inherited bound + a shrunken beam
  two_phase_safe two_phase with thresh_rank pinned to t (the conservative
                 bound): every merged candidate the independent policy
                 would produce survives the cut, so ids match exactly

The flagship acceptance (gated by tools/check_bench.py): on the quick
lane, 4-segment two_phase must land within 2x the monolithic N_b (vs
~4-6x for independent) at <= 0.5 pt recall cost, and two_phase_safe must
return ids identical to independent. Rows land in
results/sharded_index.json and BENCH_sharded.json (via benchmarks/run.py).

The monolithic reference uses the repo-standard m=16 build; the sharded
quick build uses m=12 with t=ef=125 — degree and beam scaled to the
2500-point segments so the per-segment graphs are not over-provisioned
(policy rows all share that one build, so policy deltas are apples to
apples).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import K_DEFAULT, emit, get_dataset, get_uhnsw, ground_truth
from repro.core.uhnsw import UHNSWParams, recall
from repro.index import ShardedParams, ShardedUHNSW

P_GRID = [0.5, 1.25, 2.0]


def _policy_grid(t: int):
    """(label, ShardedParams) pairs; independent first — it is the
    ids-equality reference for the other policies."""
    return [
        ("independent", ShardedParams(policy="independent")),
        ("round_robin", ShardedParams(policy="round_robin")),
        ("two_phase", ShardedParams(policy="two_phase", probe=1,
                                    ef_shrink=0.5)),
        ("two_phase_safe", ShardedParams(policy="two_phase", probe=1,
                                         ef_shrink=0.5, thresh_rank=t)),
    ]


def _timed_search(index, Q, p, k):
    ids, dists, stats = index.search(Q, p, k)  # includes compile on first p
    np.asarray(ids)
    t0 = time.time()
    ids, dists, stats = index.search(Q, p, k)
    np.asarray(ids)
    dt = time.time() - t0
    return ids, stats, dt


def _mean(x) -> float:
    return round(float(np.mean(np.asarray(x, np.float64))), 1)


def run(quick: bool = False):
    if quick:
        name, seg_grid = "glove", [4]
        mono_t, shard_m, shard_prm = 150, 12, UHNSWParams(t=125, ef=125)
    else:
        name, seg_grid = "sift", [4, 8]
        mono_t, shard_m, shard_prm = 300, 16, UHNSWParams(t=300)
    ds = get_dataset(name)
    Q = jnp.asarray(ds.queries)
    k = K_DEFAULT

    mono = get_uhnsw(name, m=16, t=mono_t)
    rows = []
    mono_stats = {}  # p -> (recall, N_b) for the ratio columns
    for p in P_GRID:
        true_ids, _ = ground_truth(name, p, k=k)
        ids, stats, dt = _timed_search(mono, Q, p, k)
        rec, n_b = round(recall(ids, true_ids), 4), _mean(stats.n_b)
        mono_stats[p] = (rec, n_b)
        rows.append({
            "bench": "sharded", "dataset": name, "index": "monolithic",
            "policy": "-", "segments": 1, "p": p,
            "recall": rec,
            "query_time_s": round(dt, 4),
            "qps": round(len(ds.queries) / max(dt, 1e-9), 1),
            "N_b": n_b, "N_p": _mean(stats.n_p),
        })

    sharded = None
    build_s = 0.0
    for num_segments in seg_grid:
        t0 = time.time()
        sharded = ShardedUHNSW.build(
            ds.data, num_segments=num_segments, m=shard_m,
            params=shard_prm, seed=0,
        )
        build_s = time.time() - t0
        for p in P_GRID:
            true_ids, _ = ground_truth(name, p, k=k)
            ref_ids = None  # independent-policy ids at this (S, p)
            for label, sp in _policy_grid(shard_prm.t):
                sharded.sharded_params = sp  # query-time knob: same build
                ids, stats, dt = _timed_search(sharded, Q, p, k)
                ids = np.asarray(ids)
                if label == "independent":
                    ref_ids = ids
                rec = round(recall(ids, true_ids), 4)
                nb_pr, nb_sp = stats.phase_n_b()
                mono_rec, mono_nb = mono_stats[p]
                rows.append({
                    "bench": "sharded", "dataset": name, "index": "sharded",
                    "policy": label, "segments": num_segments, "p": p,
                    "recall": rec,
                    "recall_delta_vs_mono": round(rec - mono_rec, 4),
                    "query_time_s": round(dt, 4),
                    "qps": round(len(ds.queries) / max(dt, 1e-9), 1),
                    "N_b": _mean(stats.n_b),
                    "N_b_probe": _mean(nb_pr), "N_b_spill": _mean(nb_sp),
                    "N_p": _mean(stats.n_p),
                    "nb_ratio_vs_mono": round(
                        _mean(stats.n_b) / max(mono_nb, 1e-9), 4),
                    "ids_match_independent": bool(
                        np.array_equal(ids, ref_ids)),
                })

    # streaming-insert path: add() latency + self-NN consistency (on the
    # last-built sharded index, after the sweep so the delta tier stays
    # empty during the policy rows)
    rng = np.random.default_rng(0)
    v = (ds.data.mean(axis=0)
         + 5.0 * rng.standard_normal(ds.d)).astype(np.float32)
    t0 = time.time()
    gid = sharded.add(v)
    add_s = time.time() - t0
    ids, _, _ = sharded.search(v[None, :], 1.25, k=1)
    insert_row = {
        "bench": "sharded", "dataset": name, "index": "sharded",
        "segments": sharded.num_segments, "metric": "insert",
        "add_time_s": round(add_s, 5), "build_time_s": round(build_s, 1),
        "self_nn_ok": bool(int(ids[0, 0]) == gid),
    }
    emit(rows, "sharded_index")

    flag = [r for r in rows if r.get("policy") == "two_phase"
            and r["p"] == 1.25 and r["segments"] == seg_grid[0]]
    safe = [r for r in rows if r.get("policy") == "two_phase_safe"
            and r["p"] == 2.0 and r["segments"] == seg_grid[0]]
    if flag and safe:
        print(f"flagship: two_phase S={seg_grid[0]} p=1.25 "
              f"N_b={flag[0]['N_b']} = {flag[0]['nb_ratio_vs_mono']}x mono "
              f"(acceptance <= 2.0), recall delta "
              f"{flag[0]['recall_delta_vs_mono']:+.4f} (>= -0.005) | "
              f"two_phase_safe p=2.0 ids==independent: "
              f"{safe[0]['ids_match_independent']}")
    print(f"insert: add={insert_row['add_time_s']}s "
          f"self_nn_ok={insert_row['self_nn_ok']}")
    return rows + [insert_row]


if __name__ == "__main__":
    run(quick=True)
