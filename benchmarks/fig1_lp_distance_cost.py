"""Paper Fig. 1: Lp distance computation cost vs p and d.

Two reproductions:
  1. MEASURED (this container's CPU SIMD — the paper's own methodology):
     wall-clock per Q2D distance for each p family via the jnp kernels.
  2. MODELED (TPU target): the analytic VPU/MXU op-cost model from
     repro.core.metrics (what the §Roofline accounting uses).

Claim under test: L1/L2 are >= an order of magnitude cheaper than general
Lp; the sqrt family (0.5, 1.5) sits in between (paper §2.1).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.metrics import lp_distance_cost_model, pairwise_lp

P_CLASSES = [
    ("L1", 1.0), ("L2", 2.0), ("L0.5", 0.5), ("L1.5", 1.5),
    ("L0.7 (general)", 0.7), ("L1.3 (general)", 1.3), ("L1.9 (general)", 1.9),
]
DIMS = [128, 256, 512, 960]
N_POINTS = 2000


def _measure(p: float, d: int, reps: int = 5) -> float:
    """Microseconds per Q2D distance on this host (XLA:CPU SIMD)."""
    q = jnp.asarray(np.random.default_rng(0).standard_normal((8, d)),
                    dtype=jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((N_POINTS, d)),
                    dtype=jnp.float32)
    pairwise_lp(q, x, p).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        pairwise_lp(q, x, p).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return dt / (8 * N_POINTS) * 1e6


def run(quick: bool = False):
    dims = DIMS[:2] if quick else DIMS
    rows = []
    for d in dims:
        base = None
        for label, p in P_CLASSES:
            us = _measure(p, d)
            model = lp_distance_cost_model(p, d)
            if p in (1.0, 2.0):
                base = us if base is None else min(base, us)
            rows.append({
                "bench": "fig1", "d": d, "p": p, "label": label,
                "us_per_call": round(us, 4),
                "tpu_model_cycles": round(model, 1),
            })
        # annotate ratios vs the cheapest base metric at this d
        for r in rows:
            if r["d"] == d:
                r["ratio_vs_base"] = round(r["us_per_call"] / base, 2)
    emit(rows, "fig1_lp_distance_cost")

    # the paper's headline claim, checked on real hardware:
    for d in dims:
        sub = [r for r in rows if r["d"] == d]
        gen = min(r["us_per_call"] for r in sub if "general" in r["label"])
        fast = min(r["us_per_call"] for r in sub if r["p"] in (1.0, 2.0))
        print(f"# d={d}: general-p / base = {gen / fast:.1f}x "
              f"(paper claims >10x on AVX-512)")
    return rows


if __name__ == "__main__":
    run()
