"""Early-abandoning verification sweep (DESIGN.md §8).

For d ∈ {96, 256, 768} and p ∈ {0.5, 0.8, 1.25, 1.5} (sqrt family +
general transcendental family) runs the same ANNS-U-Lp workload with the
early-abandoning blocked-dimension verification ON and OFF at matched
(t, kappa, tau), and records:

  * n_dim_frac — fraction of verification dimension-work actually
    scanned (the tentpole metric: effective T_p in paper Eq. 1);
  * ids_equal — the abandoning path must return *identical* ids to the
    full-dimension path (abandonment is exact);
  * recall at equal k for both paths (identical by construction,
    measured anyway) and wall-clock for both.

The verification batch is sized to the hardware, kappa = 128: a TPU
lane-width batch costs one tile whether it holds 5 or 128 candidates, so
the paper's kappa = K/2 CPU heuristic underfills the vector unit by an
order of magnitude. Large kappa over-fetches candidates — exactly the
work early abandonment makes nearly free (the over-fetched tail is
dominated by the running k-th best and dies after a block or two, or at
the entry bound before any dimension work). On this CPU container the
jnp reference computes-then-masks, so `ms_per_query` shows the bookkeeping
overhead rather than the skip (the TPU kernel skips for real);
n_dim_frac is the machine-portable metric and is what CI gates.

  PYTHONPATH=src python -m benchmarks.run --only verify [--quick]
"""

from __future__ import annotations

import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import _cached
from repro.core.datasets import _clustered_heavy_tail
from repro.core.hnsw import exact_topk
from repro.core.uhnsw import UHNSW, UHNSWParams, recall

P_GRID = (0.5, 0.8, 1.25, 1.5)
D_GRID = (96, 256, 768)
K = 10
TIMING_REPS = 2


def _dataset(d: int, n: int, nq: int):
    rng = np.random.default_rng(1000 + d)
    pool = _clustered_heavy_tail(rng, n + nq, d,
                                 n_clusters=max(8, int(np.sqrt(n) / 2)),
                                 df=3.0, nonneg=False)
    data = pool[:n]
    queries = pool[n:] + 0.05 * rng.standard_normal((nq, d)).astype(
        np.float32)
    return data, queries.astype(np.float32)


def _index(d: int, n: int, nq: int, params: UHNSWParams):
    data, queries = _cached(f"verify_ds_d{d}_n{n}_q{nq}",
                            lambda: _dataset(d, n, nq))

    def build():
        t0 = time.time()
        idx = UHNSW.build(data, m=16, method="bulk", params=params)
        print(f"  built d={d} n={n} index in {time.time() - t0:.0f}s",
              flush=True)
        return idx.g1, idx.g2

    g1, g2 = _cached(f"verify_uhnsw_d{d}_n{n}", build)
    return UHNSW(g1, g2, params), data, queries


def _timed_search(idx, Q, p, k):
    ids, _, stats = idx.search(Q, p, k)   # warm the jit cache
    jax.block_until_ready(ids)
    t0 = time.time()
    for _ in range(TIMING_REPS):
        ids, dists, stats = idx.search(Q, p, k)
        jax.block_until_ready(ids)
    ms = (time.time() - t0) / TIMING_REPS / Q.shape[0] * 1e3
    return np.asarray(ids), stats, ms


def run(quick: bool = False):
    n = 1500 if quick else 4000
    nq = 16 if quick else 32
    # hardware-shaped verification: lane-width kappa (see module docstring);
    # energy_perm scans coordinates in decreasing-variance order so the
    # abandon bound tightens in fewer blocks (DESIGN.md §10)
    params = UHNSWParams(t=300, kappa=128, tau=0.92, abandon=True,
                         energy_perm=True)

    rows = []
    for d in D_GRID:
        idx, data, queries = _index(d, n, nq, params)
        Q = jnp.asarray(queries)
        Xj = jnp.asarray(data)
        for p in P_GRID:
            true_ids = _cached(
                f"verify_gt_d{d}_n{n}_q{nq}_p{p}_k{K}",
                lambda: np.asarray(exact_topk(Xj, Q, p, K)[0]))
            idx.params = replace(params, abandon=True)
            ids_a, stats_a, ms_a = _timed_search(idx, Q, p, K)
            idx.params = replace(params, abandon=False)
            ids_f, stats_f, ms_f = _timed_search(idx, Q, p, K)
            frac = float(jnp.mean(stats_a.n_dim_frac))
            row = {
                "bench": "verify", "dataset": f"decay-d{d}", "d": d,
                "n": n, "p": p, "k": K, "t": params.t,
                "kappa": params.kappa, "tau": params.tau,
                "n_dim_frac": round(frac, 4),
                "ids_equal": bool(np.array_equal(ids_a, ids_f)),
                "recall_abandon": round(recall(ids_a, true_ids), 4),
                "recall_full": round(recall(ids_f, true_ids), 4),
                "mean_n_p": round(float(jnp.mean(stats_a.n_p)), 1),
                "ms_per_query_abandon": round(ms_a, 3),
                "ms_per_query_full": round(ms_f, 3),
            }
            rows.append(row)
            print(f"  d={d} p={p}: n_dim_frac={frac:.3f} "
                  f"ids_equal={row['ids_equal']} "
                  f"recall={row['recall_abandon']:.4f} "
                  f"(full {row['recall_full']:.4f}) "
                  f"{ms_a:.1f} vs {ms_f:.1f} ms/q", flush=True)

    # acceptance: >= 30% dimension-work reduction for the general
    # transcendental family at d >= 256, ids identical everywhere
    gate = [r for r in rows if r["d"] >= 256 and r["p"] in (0.8, 1.25)]
    ok = (all(r["n_dim_frac"] <= 0.7 for r in gate)
          and all(r["ids_equal"] for r in rows))
    print(f"acceptance (n_dim_frac <= 0.7 for p in {{0.8, 1.25}} at "
          f"d >= 256, ids identical): {'PASS' if ok else 'FAIL'}")
    return rows


if __name__ == "__main__":
    run()
