"""Benchmark aggregator: one function per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1,sharded]

Each benchmark's rows also land in results/BENCH_<name>.json together with
wall time and the quick flag, so the perf trajectory (query time, recall,
N_b/N_p, ...) is machine-readable across PRs.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).parent.parent / "results"


def _write_bench_result(name: str, rows, seconds: float, quick: bool,
                        error: str | None = None):
    RESULTS.mkdir(parents=True, exist_ok=True)
    payload = {
        "bench": name,
        "status": "error" if error else "ok",
        "quick": quick,
        "seconds": round(seconds, 1),
        "rows": rows if isinstance(rows, list) else [],
    }
    if error:
        payload["error"] = error
    (RESULTS / f"BENCH_{name}.json").write_text(json.dumps(payload, indent=2))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small dataset subset (CI mode)")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    from benchmarks import (
        beam_width,
        build,
        compressed,
        fig1_lp_distance_cost,
        fig2_recall_vs_p,
        fig3_param_tuning,
        fig4_uhnsw_vs_hnsw,
        roofline,
        serving,
        sharded_index,
        table2_uhnsw_vs_mlsh,
        verify,
    )

    benches = {
        "build": build.run,
        "fig1": fig1_lp_distance_cost.run,
        "fig2": fig2_recall_vs_p.run,
        "fig3": fig3_param_tuning.run,
        "table2": table2_uhnsw_vs_mlsh.run,
        "fig4": fig4_uhnsw_vs_hnsw.run,
        "sharded": sharded_index.run,
        "beam": beam_width.run,
        "roofline": roofline.run,
        "serving": serving.run,
        "health": serving.run_faulted,
        "verify": verify.run,
        "compressed": compressed.run,
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    unknown = only - set(benches)
    if unknown:
        # a typo must not silently run nothing and exit 0 (the bench-guard
        # gate would then compare stale committed JSONs)
        print(f"unknown benchmark name(s) {sorted(unknown)}; "
              f"options: {sorted(benches)}")
        return 2
    failures = []
    for name, fn in benches.items():
        if name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
            _write_bench_result(name, rows, time.time() - t0, args.quick)
        except Exception as e:  # keep going; report at the end
            import traceback
            traceback.print_exc()
            _write_bench_result(name, None, time.time() - t0, args.quick,
                                error=repr(e))
            failures.append((name, repr(e)))
        print(f"===== {name} done in {time.time() - t0:.0f}s =====", flush=True)
    if failures:
        print("\nFAILED:", failures)
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
