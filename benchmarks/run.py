"""Benchmark aggregator: one function per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small dataset subset (CI mode)")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    from benchmarks import (
        fig1_lp_distance_cost,
        fig2_recall_vs_p,
        fig3_param_tuning,
        fig4_uhnsw_vs_hnsw,
        roofline,
        table2_uhnsw_vs_mlsh,
    )

    benches = {
        "fig1": fig1_lp_distance_cost.run,
        "fig2": fig2_recall_vs_p.run,
        "fig3": fig3_param_tuning.run,
        "table2": table2_uhnsw_vs_mlsh.run,
        "fig4": fig4_uhnsw_vs_hnsw.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    failures = []
    for name, fn in benches.items():
        if name not in only:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn(quick=args.quick)
        except Exception as e:  # keep going; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
        print(f"===== {name} done in {time.time() - t0:.0f}s =====", flush=True)
    if failures:
        print("\nFAILED:", failures)
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
